//! Kernel based sampling (§3 of the paper).
//!
//! A kernel `K(h, w_i) = ⟨φ(h), φ(w_i)⟩ ≥ 0` induces the sampling
//! distribution `q_i = K(h, w_i) / ⟨φ(h), Σ_j φ(w_j)⟩` (eq. 8): the
//! partition function collapses to a dot product against a precomputable
//! summary `z = Σ_j φ(w_j)`, which is what makes adaptive sampling cheap.
//!
//! * [`QuadraticMap`] — the paper's suggested kernel `α⟨h,w⟩² + 1` with the
//!   explicit feature map `φ(a) = [√α vec(a ⊗ a), 1]`, `D = d² + 1`
//!   (eq. 10). The layout matches `phi_quadratic_ref` in
//!   python/compile/kernels/ref.py (row-major outer product, constant last).
//! * [`flat`] — exact O(n·d) sampling directly from kernel scores; the
//!   correctness oracle for the tree and the only option for kernels with
//!   intractable feature maps (quartic: D = d⁴).
//! * [`tree`] — the paper's divide-and-conquer sampler (§3.2): O(D log n)
//!   draws and updates via per-subset summaries `z(C)`.

pub mod flat;
pub mod multi;
pub mod tree;

/// Explicit feature map of a kernel: `K(a,b) = ⟨φ(a), φ(b)⟩`.
pub trait FeatureMap: Send + Sync {
    /// Input dimension d.
    fn d(&self) -> usize;
    /// Feature dimension D.
    fn dim(&self) -> usize;
    /// Write φ(a) into `out` (len = D). f64: the tree's z statistics are
    /// updated incrementally and must not drift.
    fn phi(&self, a: &[f32], out: &mut [f64]);
    /// Closed-form kernel value (cheaper than materializing φ: the paper's
    /// §3.2.2 leaf-step trick relies on K being O(d) to evaluate).
    fn kernel(&self, a: &[f32], b: &[f32]) -> f64;
}

/// The paper's quadratic kernel, eq. (10): `K(a,b) = α⟨a,b⟩² + 1`.
#[derive(Clone, Debug)]
pub struct QuadraticMap {
    d: usize,
    alpha: f64,
}

impl QuadraticMap {
    pub fn new(d: usize, alpha: f64) -> QuadraticMap {
        assert!(d > 0 && alpha >= 0.0);
        QuadraticMap { d, alpha }
    }

    pub fn alpha(&self) -> f64 {
        self.alpha
    }
}

impl FeatureMap for QuadraticMap {
    fn d(&self) -> usize {
        self.d
    }

    fn dim(&self) -> usize {
        self.d * self.d + 1
    }

    fn phi(&self, a: &[f32], out: &mut [f64]) {
        debug_assert_eq!(a.len(), self.d);
        debug_assert_eq!(out.len(), self.dim());
        let sqrt_alpha = self.alpha.sqrt();
        for i in 0..self.d {
            let ai = sqrt_alpha * a[i] as f64;
            let row = &mut out[i * self.d..(i + 1) * self.d];
            for (j, slot) in row.iter_mut().enumerate() {
                *slot = ai * a[j] as f64;
            }
        }
        out[self.d * self.d] = 1.0;
    }

    fn kernel(&self, a: &[f32], b: &[f32]) -> f64 {
        let dot: f64 = a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum();
        self.alpha * dot * dot + 1.0
    }
}

/// Kernels usable by the flat sampler (weight as a function of the logit
/// `o = ⟨h, w⟩`, the `K(a,b) = f(⟨a,b⟩)` family of §3.2.2).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum KernelKind {
    /// `α o² + 1` — the paper's main proposal.
    Quadratic { alpha: f64 },
    /// `o⁴ + 1` — the 4th-degree polynomial extra from Figure 2 (no
    /// tractable feature map: D = O(d⁴), so flat sampling only).
    Quartic,
}

impl KernelKind {
    /// Kernel value from a precomputed logit.
    #[inline]
    pub fn weight(&self, o: f32) -> f64 {
        let o = o as f64;
        match self {
            KernelKind::Quadratic { alpha } => alpha * o * o + 1.0,
            KernelKind::Quartic => {
                let o2 = o * o;
                o2 * o2 + 1.0
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            KernelKind::Quadratic { .. } => "quadratic-flat",
            KernelKind::Quartic => "quartic",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::testing::check;

    #[test]
    fn phi_inner_product_equals_kernel() {
        check("⟨φ(a),φ(b)⟩ == α⟨a,b⟩²+1", 100, |g| {
            let d = g.usize_in(1, 12);
            let alpha = g.f64_in(0.0, 200.0);
            let map = QuadraticMap::new(d, alpha);
            let a = g.vec_f32(d, -2.0, 2.0);
            let b = g.vec_f32(d, -2.0, 2.0);
            let mut pa = vec![0.0; map.dim()];
            let mut pb = vec![0.0; map.dim()];
            map.phi(&a, &mut pa);
            map.phi(&b, &mut pb);
            let ip: f64 = pa.iter().zip(&pb).map(|(x, y)| x * y).sum();
            let k = map.kernel(&a, &b);
            assert!((ip - k).abs() < 1e-6 * k.abs().max(1.0), "ip={ip} k={k}");
        });
    }

    #[test]
    fn quadratic_kernel_is_positive() {
        let map = QuadraticMap::new(4, 100.0);
        let mut rng = Rng::new(3);
        for _ in 0..100 {
            let a: Vec<f32> = (0..4).map(|_| rng.normal_f32(0.0, 2.0)).collect();
            let b: Vec<f32> = (0..4).map(|_| rng.normal_f32(0.0, 2.0)).collect();
            assert!(map.kernel(&a, &b) >= 1.0);
        }
    }

    #[test]
    fn kernel_kind_weights() {
        let q = KernelKind::Quadratic { alpha: 100.0 };
        assert_eq!(q.weight(0.0), 1.0);
        assert_eq!(q.weight(2.0), 401.0);
        assert_eq!(q.weight(-2.0), 401.0); // symmetric
        let f = KernelKind::Quartic;
        assert_eq!(f.weight(0.0), 1.0);
        assert_eq!(f.weight(2.0), 17.0);
        assert_eq!(f.weight(-2.0), 17.0);
    }

    #[test]
    fn phi_layout_matches_python_oracle() {
        // pins the layout contract with ref.phi_quadratic_ref: row-major
        // outer product scaled by √α, then the constant 1.
        let map = QuadraticMap::new(2, 4.0);
        let mut out = vec![0.0; 5];
        map.phi(&[1.0, 2.0], &mut out);
        assert_eq!(out, vec![2.0, 4.0, 4.0, 8.0, 1.0]);
    }
}
