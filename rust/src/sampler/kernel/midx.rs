//! Inverted multi-index (midx) sampling: two-level coarse-quantized
//! kernel sampling for 10M-class vocabularies.
//!
//! The kernel tree's descent is O(log n) per draw, but every node touch
//! is a kernel-dimension dot product — at production vocabularies the
//! ~log₂(C) descent constant dominates. The midx sampler replaces the
//! tree with a **two-level inverted index** (the IMI construction of
//! *Adaptive Sampled Softmax with Inverted Multi-Index*, PAPERS.md):
//!
//! ```text
//! build (once per embedding generation):
//!     k-means over the class embeddings        K ≈ √C clusters
//!     cluster-blocked member panel             like the HSM head layout
//!     Z_k = Σ_{c ∈ k} φ(w_c)                   per-cluster aggregate
//!
//! per example (once, shared by its m draws):
//!     φ(h);  M_k = ⟨φ(h), Z_k⟩  for all k      ONE kernel-dim op per
//!     coarse CDF over sanitize(M_k)            cluster — K ops total,
//!                                              vs O(D log C) per draw
//! per draw:
//!     cluster  k  ~  M_k / ΣM                  coarse CDF
//!     class    c  ~  K(h,c) / S_k              exact within-cluster
//!                                              refine (memoized per
//!                                              example, f32 panel →
//!                                              f64 exact kernels)
//!     report   q = (M_k/ΣM) · (K(h,c)/S_k)     composed proposal
//! ```
//!
//! # The composed proposal q
//!
//! `S_k = Σ_{c∈k} sanitize(K(h,c))` is the *refined* cluster mass — the
//! exact f64 kernel sweep the within-cluster CDF is built from — while
//! `M_k = ⟨φ(h), Z_k⟩` is the aggregate the coarse CDF uses. The two are
//! equal in exact arithmetic (`⟨φ(h), Σφ(w_c)⟩ = Σ K(h,c)`, eq. 8
//! linearity), so the composed q collapses to the flat eq. (8)
//! distribution `K(h,c)/ΣM` and the eq. (2) corrections `ln(m·q)` are
//! unchanged — the property test below pins the relative gap to ≤ 1e-12.
//! As with the two-pass sampler, the *reported* q is the probability of
//! the realized two-stage procedure — `(M_k/ΣM)·(K(h,c)/S_k)` — so the
//! χ² goodness-of-fit holds exactly even at f64 rounding.
//!
//! # Degenerate masses
//!
//! Every division is guarded by the [`positive_pool_mass`] checked
//! constructor (the QPOS guard idiom):
//!
//! * total coarse mass degenerate → uniform over all classes,
//!   q = 1/n (counted in `kss_sampler_midx_zero_cluster_total`);
//! * a selected cluster's refined mass degenerate (its aggregate said
//!   positive, its exact kernels underflowed) → uniform member,
//!   q = p_coarse/len (also counted).
//!
//! A zero-aggregate cluster is never *selected*: its coarse CDF increment
//! is exactly zero and [`step_down_to_positive`] skips it.
//!
//! # Updates and re-assignment
//!
//! [`MidxIndex::apply_update`] maintains `Z_k += φ(w_new) − φ(w_old)`
//! incrementally (f64 aggregates, same discipline as the tree's z
//! statistics) and accumulates the centroid drift `Σ‖Δw‖₂`. Cluster
//! membership is *not* chased per update — after `reassign_every`
//! updated rows the sampler runs one Lloyd re-assignment sweep
//! ([`MidxIndex::sweep`]: recompute centroids from the current
//! assignment, re-assign every class, rebuild panels and aggregates from
//! scratch), the same periodic-compaction policy as the vocab tier. On
//! the serve side the sweep happens behind the publisher: a new tree
//! generation warm-restarts the index from the previous centroids
//! (counted in `kss_sampler_midx_reassign_total`).
//!
//! # Determinism
//!
//! The k-means build (k-means++ seeding over the repo [`Rng`], Lloyd
//! iterations on the `ops` panel primitives) is sequential and seeded —
//! bit-identical across runs and thread counts. Draws are strictly
//! per-row ([`row_rng`] streams), so unlike two-pass the midx sampler is
//! **not** batch-coupled: `sample_batch` is bit-identical to a per-row
//! [`Sampler::sample`] loop at any fan-out.

use super::tree::{sanitize_mass, step_down_to_positive};
use super::two_pass::positive_pool_mass;
use super::FeatureMap;
use crate::obs::{Counter, Gauge, MetricsRegistry};
use crate::ops;
use crate::sampler::{row_rng, BatchSampleInput, Needs, Sample, SampleInput, Sampler};
use crate::util::rng::{sample_cum, Rng};
use crate::util::threadpool::{par_chunks_mut, Pool};
use anyhow::Result;
use std::sync::{Arc, Mutex};

/// Build-time RNG salt for the k-means++ seeding stream: disjoint from
/// every [`row_rng`] stream and from the two-pass pool salt.
pub const MIDX_BUILD_SEED: u64 = 0x1DA8_5EED_91B7_4C21;

/// Lloyd iterations after k-means++ seeding (each: assign + recompute).
pub const DEFAULT_LLOYD_ITERS: usize = 2;

/// k-means++ seeding subsample: candidates scored per cluster. Seeding
/// over all n rows is O(K·n·d) — at n = 1e7, K ≈ 3163 that alone dwarfs
/// the Lloyd sweeps — so seeds are chosen from a deterministic
/// with-replacement subsample of `min(n, 32·K)` rows.
const SEED_SAMPLE_PER_CLUSTER: usize = 32;

/// Default K for `n` classes: ⌈√n⌉ (the IMI balance point — coarse scan
/// and expected within-cluster refine both ~√n kernel evals).
pub fn default_clusters(n: usize) -> usize {
    ((n.max(1) as f64).sqrt().ceil() as usize).clamp(1, n.max(1))
}

/// The two-level index: cluster assignment, cluster-blocked member
/// panel, per-cluster φ-aggregates, and the k-means centroids. Immutable
/// on the draw path (draws go through a [`MidxScratch`]); the owning
/// sampler mutates it through [`MidxIndex::apply_update`] /
/// [`MidxIndex::sweep`], the serve core rebuilds it per generation.
pub struct MidxIndex {
    n: usize,
    d: usize,
    /// Feature dimension D of the kernel map (aggregate row width).
    dim: usize,
    k: usize,
    /// class → cluster.
    assign: Vec<u32>,
    /// Cluster-blocked offsets into `member`/`packed`: cluster `k` owns
    /// slots `panel_lo[k]..panel_lo[k+1]` (len k+1, like the HSM head).
    panel_lo: Vec<u32>,
    /// Class ids grouped by cluster, ascending id within each cluster —
    /// the canonical aggregation order (port check mirrors it).
    member: Vec<u32>,
    /// class → slot in `member`/`packed`.
    slot_of: Vec<u32>,
    /// Cluster-blocked (n × d) member-embedding panel: cluster `k`'s
    /// rows are contiguous, so the within-cluster refine is one
    /// `kernel_many` sweep — no strided row gathers.
    packed: Vec<f32>,
    /// Per-cluster aggregates `Z_k = Σ_{c∈k} φ(w_c)`, (k × D) row-major
    /// f64 — maintained incrementally like the tree's z statistics.
    zstats: Vec<f64>,
    /// k-means centroids, (k × d) row-major f32.
    centroids: Vec<f32>,
}

impl MidxIndex {
    /// Seeded, thread-count-invariant k-means build. `warm` restarts
    /// from a previous index's centroids (assignment sweeps only, no
    /// re-seeding) — the behind-the-publisher path; `None` runs
    /// k-means++ seeding first. All-degenerate geometry (e.g. the
    /// all-zero table at startup) falls back to contiguous even blocks,
    /// the same shape as the tree's leaves.
    pub fn build<M: FeatureMap>(
        map: &M,
        emb: &[f32],
        n: usize,
        d: usize,
        clusters: Option<usize>,
        lloyd_iters: usize,
        seed: u64,
        warm: Option<&MidxIndex>,
    ) -> MidxIndex {
        assert!(n > 0 && d > 0, "midx needs n > 0, d > 0");
        debug_assert_eq!(emb.len(), n * d);
        let k = clusters.map(|c| c.clamp(1, n)).unwrap_or_else(|| default_clusters(n));
        let mut idx = MidxIndex {
            n,
            d,
            dim: map.dim(),
            k,
            assign: vec![0u32; n],
            panel_lo: vec![0u32; k + 1],
            member: vec![0u32; n],
            slot_of: vec![0u32; n],
            packed: vec![0.0f32; n * d],
            zstats: vec![0.0f64; k * map.dim()],
            centroids: vec![0.0f32; k * d],
        };
        let seeded = match warm {
            Some(prev) if prev.d == d && prev.k == k => {
                idx.centroids.copy_from_slice(&prev.centroids);
                true
            }
            _ => idx.seed_centroids(emb, seed),
        };
        if seeded {
            // Lloyd: assign under the current centroids, then recompute
            // them; end on an assignment against the final centroids.
            for _ in 0..lloyd_iters {
                idx.assign_all(emb);
                idx.recompute_centroids(emb);
            }
            idx.assign_all(emb);
        } else {
            // Degenerate geometry: contiguous even blocks.
            for c in 0..n {
                idx.assign[c] = ((c as u64 * k as u64) / n as u64) as u32;
            }
            idx.recompute_centroids(emb);
        }
        idx.finalize(map, emb);
        idx
    }

    /// k-means++ over a deterministic subsample. Returns false when the
    /// sampled geometry is fully degenerate (zero total spread).
    fn seed_centroids(&mut self, emb: &[f32], seed: u64) -> bool {
        let (n, d, k) = (self.n, self.d, self.k);
        let mut rng = Rng::new(seed ^ MIDX_BUILD_SEED);
        let cap = (SEED_SAMPLE_PER_CLUSTER * k).max(1);
        // With-replacement subsample (duplicates are harmless to seeding:
        // a duplicate of a chosen seed has distance 0 and zero weight).
        let sample: Vec<u32> = if n <= cap {
            (0..n as u32).collect()
        } else {
            (0..cap).map(|_| rng.below(n as u64) as u32).collect()
        };
        let s = sample.len();
        let row = |c: u32| &emb[c as usize * d..(c as usize + 1) * d];
        let norm2: Vec<f64> = sample.iter().map(|&c| ops::dot_f32(row(c), row(c))).collect();
        // First seed uniform; the rest D²-weighted against the nearest
        // chosen seed.
        let first = sample[rng.below(s as u64) as usize];
        self.centroids[..d].copy_from_slice(row(first));
        let first_n2 = ops::dot_f32(row(first), row(first));
        let mut best2 = vec![0.0f64; s];
        let mut cum = vec![0.0f64; s];
        for (j, &c) in sample.iter().enumerate() {
            best2[j] =
                sanitize_mass(norm2[j] - 2.0 * ops::dot_f32(row(c), row(first)) + first_n2);
        }
        for next in 1..k {
            let total = ops::fill_cum_into(&best2, &mut cum);
            let Some(spread) = positive_pool_mass(total) else {
                // All remaining candidates coincide with chosen seeds
                // (or the table is all-zero): no usable spread.
                return next > 1;
            };
            let pick = sample[step_down_to_positive(&cum, sample_cum(&cum, spread, &mut rng))];
            let mu = &emb[pick as usize * d..(pick as usize + 1) * d];
            let mu_n2 = ops::dot_f32(mu, mu);
            self.centroids[next * d..(next + 1) * d].copy_from_slice(mu);
            for (j, &c) in sample.iter().enumerate() {
                let d2 = sanitize_mass(norm2[j] - 2.0 * ops::dot_f32(row(c), mu) + mu_n2);
                best2[j] = best2[j].min(d2);
            }
        }
        true
    }

    /// Assign every class to its nearest centroid: one
    /// [`ops::dot_many_f32`] sweep per class over the centroid panel,
    /// argmax of `μᵀw − ½‖μ‖²` (ties → lowest cluster id, so the result
    /// is deterministic).
    fn assign_all(&mut self, emb: &[f32]) {
        let (n, d, k) = (self.n, self.d, self.k);
        let half_norm: Vec<f64> = (0..k)
            .map(|j| 0.5 * ops::dot_f32(&self.centroids[j * d..(j + 1) * d],
                &self.centroids[j * d..(j + 1) * d]))
            .collect();
        let mut scores = vec![0.0f64; k];
        for c in 0..n {
            ops::dot_many_f32(&emb[c * d..(c + 1) * d], &self.centroids, &mut scores);
            let mut best = 0usize;
            let mut best_s = scores[0] - half_norm[0];
            for (j, &sc) in scores.iter().enumerate().skip(1) {
                let s = sc - half_norm[j];
                if s > best_s {
                    best_s = s;
                    best = j;
                }
            }
            self.assign[c] = best as u32;
        }
    }

    /// Recompute centroids as member means (f64 accumulation through
    /// [`ops::add_assign`]); empty clusters keep their previous centroid.
    fn recompute_centroids(&mut self, emb: &[f32]) {
        let (n, d, k) = (self.n, self.d, self.k);
        let mut sums = vec![0.0f64; k * d];
        let mut counts = vec![0u64; k];
        let mut row64 = vec![0.0f64; d];
        for c in 0..n {
            let kc = self.assign[c] as usize;
            counts[kc] += 1;
            for (slot, &x) in row64.iter_mut().zip(&emb[c * d..(c + 1) * d]) {
                *slot = x as f64;
            }
            ops::add_assign(&mut sums[kc * d..(kc + 1) * d], &row64);
        }
        for j in 0..k {
            let cnt = counts[j];
            if cnt == 0 {
                continue;
            }
            for (slot, &a) in self.centroids[j * d..(j + 1) * d]
                .iter_mut()
                .zip(&sums[j * d..(j + 1) * d])
            {
                *slot = (a / cnt as f64) as f32;
            }
        }
    }

    /// Rebuild the cluster-blocked layout and the φ-aggregates from the
    /// current assignment. Members are laid out in ascending class id
    /// within each cluster — the canonical aggregation order every
    /// incremental path and the port check reproduce.
    fn finalize<M: FeatureMap>(&mut self, map: &M, emb: &[f32]) {
        let (n, d, k, dim) = (self.n, self.d, self.k, self.dim);
        let mut counts = vec![0u32; k];
        for &a in &self.assign {
            counts[a as usize] += 1;
        }
        self.panel_lo[0] = 0;
        for j in 0..k {
            self.panel_lo[j + 1] = self.panel_lo[j] + counts[j];
        }
        let mut cursor: Vec<u32> = self.panel_lo[..k].to_vec();
        for c in 0..n as u32 {
            let kc = self.assign[c as usize] as usize;
            let slot = cursor[kc];
            self.member[slot as usize] = c;
            self.slot_of[c as usize] = slot;
            cursor[kc] += 1;
        }
        for slot in 0..n {
            let c = self.member[slot] as usize;
            self.packed[slot * d..(slot + 1) * d].copy_from_slice(&emb[c * d..(c + 1) * d]);
        }
        self.zstats.fill(0.0);
        let mut phi = vec![0.0f64; dim];
        for slot in 0..n {
            let kc = self.assign[self.member[slot] as usize] as usize;
            map.phi(&self.packed[slot * d..(slot + 1) * d], &mut phi);
            ops::add_assign(&mut self.zstats[kc * dim..(kc + 1) * dim], &phi);
        }
    }

    /// One Lloyd re-assignment sweep over the current embeddings:
    /// centroids from the live assignment, re-assign, rebuild layout and
    /// aggregates from scratch (so incremental float drift in `zstats`
    /// is also squashed — the compaction analogy is exact).
    pub fn sweep<M: FeatureMap>(&mut self, map: &M, emb: &[f32]) {
        self.recompute_centroids(emb);
        self.assign_all(emb);
        self.finalize(map, emb);
    }

    /// Incremental single-class update: `Z_k += φ(w_new) − φ(w_old)`,
    /// mirror rows rewritten in place (membership unchanged — the
    /// periodic [`MidxIndex::sweep`] re-clusters). Returns `‖Δw‖₂`, the
    /// caller's drift contribution. `phi_old`/`phi_new` are caller
    /// scratch (len D); `emb` is the caller's class-major mirror.
    pub fn apply_update<M: FeatureMap>(
        &mut self,
        map: &M,
        class: usize,
        w_new: &[f32],
        emb: &mut [f32],
        phi_old: &mut [f64],
        phi_new: &mut [f64],
    ) -> f64 {
        let d = self.d;
        debug_assert!(class < self.n && w_new.len() == d);
        let kc = self.assign[class] as usize;
        let dim = self.dim;
        let old = &emb[class * d..(class + 1) * d];
        map.phi(old, phi_old);
        map.phi(w_new, phi_new);
        let drift2 = sanitize_mass(
            ops::dot_f32(old, old) - 2.0 * ops::dot_f32(old, w_new)
                + ops::dot_f32(w_new, w_new),
        );
        let z = &mut self.zstats[kc * dim..(kc + 1) * dim];
        ops::add_assign(z, phi_new);
        ops::sub_assign(z, phi_old);
        emb[class * d..(class + 1) * d].copy_from_slice(w_new);
        let slot = self.slot_of[class] as usize;
        self.packed[slot * d..(slot + 1) * d].copy_from_slice(w_new);
        drift2.sqrt()
    }

    pub fn num_classes(&self) -> usize {
        self.n
    }

    pub fn embed_dim(&self) -> usize {
        self.d
    }

    pub fn clusters(&self) -> usize {
        self.k
    }

    /// Cluster of `class` (tests and the port check).
    pub fn cluster_of(&self, class: usize) -> usize {
        self.assign[class] as usize
    }

    /// Per-cluster aggregate row `Z_k` (tests and the port check).
    pub fn zstat_row(&self, k: usize) -> &[f64] {
        &self.zstats[k * self.dim..(k + 1) * self.dim]
    }

    /// Largest cluster cardinality (sizes the refine scratch).
    fn max_cluster_len(&self) -> usize {
        (0..self.k)
            .map(|j| (self.panel_lo[j + 1] - self.panel_lo[j]) as usize)
            .max()
            .unwrap_or(0)
    }

    /// Scratch sized for this index.
    pub fn new_scratch(&self) -> MidxScratch {
        MidxScratch {
            phi_h: vec![0.0; self.dim],
            masses: vec![0.0; self.k],
            coarse_cum: vec![0.0; self.k],
            coarse_total: 0.0,
            kvals: vec![0.0; self.max_cluster_len()],
            wcum: vec![0.0; self.n],
            inner_total: vec![0.0; self.k],
            stamp: vec![0u32; self.k],
            epoch: 0,
            o_coarse: 0,
            o_refine: 0,
            o_zero: 0,
        }
    }

    /// Resize a pooled scratch that last served a different generation's
    /// index (serve path: k/n can change across publishes).
    fn fit_scratch(&self, s: &mut MidxScratch) {
        if s.phi_h.len() != self.dim
            || s.masses.len() != self.k
            || s.wcum.len() != self.n
            || s.kvals.len() != self.max_cluster_len()
        {
            *s = self.new_scratch();
        }
    }

    /// Prime `s` for one example's draws: φ(h), the per-cluster
    /// aggregate masses (one [`ops::dot_many`] over the `Z` panel — the
    /// "one kernel-dim op per cluster" that replaces the tree descent),
    /// and the coarse CDF. The m draws of the example share the scratch,
    /// so each cluster's exact refine runs at most once per example.
    pub fn begin_example<M: FeatureMap>(&self, map: &M, h: &[f32], s: &mut MidxScratch) {
        self.fit_scratch(s);
        s.epoch = s.epoch.wrapping_add(1);
        if s.epoch == 0 {
            s.stamp.fill(0);
            s.epoch = 1;
        }
        map.phi(h, &mut s.phi_h);
        ops::dot_many(&s.phi_h, &self.zstats, &mut s.masses);
        for m in s.masses.iter_mut() {
            *m = sanitize_mass(*m);
        }
        s.coarse_total = ops::fill_cum_into(&s.masses, &mut s.coarse_cum);
    }

    /// Exact within-cluster refine: one `kernel_many` sweep over the
    /// cluster's contiguous packed panel (f32 rows → f64 kernels), then
    /// the inclusive prefix-sum CDF into the class-slot arena.
    fn refine<M: FeatureMap>(&self, map: &M, h: &[f32], kc: usize, s: &mut MidxScratch) {
        let (lo, hi) = (self.panel_lo[kc] as usize, self.panel_lo[kc + 1] as usize);
        let kv = &mut s.kvals[..hi - lo];
        map.kernel_many(h, &self.packed[lo * self.d..hi * self.d], kv);
        for v in kv.iter_mut() {
            *v = sanitize_mass(*v);
        }
        s.inner_total[kc] = ops::fill_cum_into(kv, &mut s.wcum[lo..hi]);
        s.stamp[kc] = s.epoch;
        s.o_refine += 1;
    }

    /// One draw given a scratch primed by [`Self::begin_example`].
    /// Returns (class, q); q is strictly positive in every case.
    pub fn draw<M: FeatureMap>(
        &self,
        map: &M,
        h: &[f32],
        s: &mut MidxScratch,
        rng: &mut Rng,
    ) -> (u32, f64) {
        let Some(coarse_mass) = positive_pool_mass(s.coarse_total) else {
            // Total aggregate mass degenerate: uniform over all classes
            // (member slots cover each class exactly once), exact q.
            s.o_zero += 1;
            let slot = rng.below(self.n as u64) as usize;
            return (self.member[slot], (1.0 / self.n as f64).max(f64::MIN_POSITIVE));
        };
        s.o_coarse += 1;
        let kc = step_down_to_positive(&s.coarse_cum, sample_cum(&s.coarse_cum, coarse_mass, rng));
        let inc = s.coarse_cum[kc] - if kc == 0 { 0.0 } else { s.coarse_cum[kc - 1] };
        let p_coarse = inc / coarse_mass;
        if s.stamp[kc] != s.epoch {
            self.refine(map, h, kc, s);
        }
        let (lo, hi) = (self.panel_lo[kc] as usize, self.panel_lo[kc + 1] as usize);
        debug_assert!(hi > lo, "selected cluster has positive mass but no members");
        let Some(cluster_mass) = positive_pool_mass(s.inner_total[kc]) else {
            // Aggregate said positive but the exact kernels underflowed:
            // uniform member under the realized coarse step.
            s.o_zero += 1;
            let slot = lo + rng.below((hi - lo) as u64) as usize;
            let len = (hi - lo) as f64;
            return (self.member[slot], (p_coarse / len).max(f64::MIN_POSITIVE));
        };
        let seg = &s.wcum[lo..hi];
        let j = step_down_to_positive(seg, sample_cum(seg, cluster_mass, rng));
        let w = seg[j] - if j == 0 { 0.0 } else { seg[j - 1] };
        let q = (p_coarse * (w / cluster_mass)).max(f64::MIN_POSITIVE);
        (self.member[lo + j], q)
    }

    /// Composed probability of `class` for the example primed in `s` —
    /// the same guarded algebra as [`Self::draw`], so `prob` agrees with
    /// reported draw q exactly.
    pub fn prob_of<M: FeatureMap>(
        &self,
        map: &M,
        h: &[f32],
        class: u32,
        s: &mut MidxScratch,
    ) -> f64 {
        let kc = self.assign[class as usize] as usize;
        let Some(coarse_mass) = positive_pool_mass(s.coarse_total) else {
            return (1.0 / self.n as f64).max(f64::MIN_POSITIVE);
        };
        let inc = s.coarse_cum[kc] - if kc == 0 { 0.0 } else { s.coarse_cum[kc - 1] };
        if inc <= 0.0 {
            // Zero-aggregate cluster: unreachable through the coarse CDF.
            return 0.0;
        }
        let p_coarse = inc / coarse_mass;
        if s.stamp[kc] != s.epoch {
            self.refine(map, h, kc, s);
        }
        let (lo, hi) = (self.panel_lo[kc] as usize, self.panel_lo[kc + 1] as usize);
        let Some(cluster_mass) = positive_pool_mass(s.inner_total[kc]) else {
            let len = (hi - lo) as f64;
            return (p_coarse / len).max(f64::MIN_POSITIVE);
        };
        let slot = self.slot_of[class as usize] as usize;
        let j = slot - lo;
        let seg = &s.wcum[lo..hi];
        let w = seg[j] - if j == 0 { 0.0 } else { seg[j - 1] };
        if w <= 0.0 {
            return 0.0;
        }
        (p_coarse * (w / cluster_mass)).max(f64::MIN_POSITIVE)
    }
}

/// Per-worker draw scratch: φ(h), the coarse CDF, and the per-cluster
/// refine arena (epoch-stamped so each cluster refines at most once per
/// example, exactly the tree's leaf-CDF memo discipline). Telemetry
/// accumulates in the `o_*` locals and flushes on pool put — the draw
/// loop never touches an atomic.
pub struct MidxScratch {
    phi_h: Vec<f64>,
    masses: Vec<f64>,
    coarse_cum: Vec<f64>,
    coarse_total: f64,
    kvals: Vec<f64>,
    /// Class-slot CDF arena: cluster `k` owns `wcum[lo..hi]` — flat, no
    /// hashing (same shape as the tree's leaf arena).
    wcum: Vec<f64>,
    inner_total: Vec<f64>,
    stamp: Vec<u32>,
    epoch: u32,
    o_coarse: u64,
    o_refine: u64,
    o_zero: u64,
}

/// Shared telemetry cells for one midx engine (accumulate-in-scratch,
/// flush-on-put — see [`MidxObs::flush_scratch`]).
#[derive(Clone)]
pub struct MidxObs {
    /// Master switch (mirrors `TreeObs::enabled`).
    pub enabled: bool,
    clusters: Arc<Gauge>,
    coarse: Arc<Counter>,
    refine: Arc<Counter>,
    reassign: Arc<Counter>,
    zero_cluster: Arc<Counter>,
    drift: Arc<Gauge>,
}

impl Default for MidxObs {
    fn default() -> Self {
        MidxObs {
            enabled: true,
            clusters: Arc::new(Gauge::new()),
            coarse: Arc::new(Counter::new()),
            refine: Arc::new(Counter::new()),
            reassign: Arc::new(Counter::new()),
            zero_cluster: Arc::new(Counter::new()),
            drift: Arc::new(Gauge::new()),
        }
    }
}

impl MidxObs {
    /// Bind every cell to `reg` under the stable `kss_sampler_midx_*`
    /// names (see the README metric catalog).
    pub fn register_into(&self, reg: &MetricsRegistry) {
        reg.register_gauge(
            "kss_sampler_midx_clusters",
            "clusters",
            "sampler",
            "k-means clusters in the live inverted multi-index",
            Arc::clone(&self.clusters),
        );
        reg.register_counter(
            "kss_sampler_midx_coarse_draw_total",
            "draws",
            "sampler",
            "cluster-level coarse CDF draws",
            Arc::clone(&self.coarse),
        );
        reg.register_counter(
            "kss_sampler_midx_refine_total",
            "sweeps",
            "sampler",
            "within-cluster exact kernel refine sweeps (≤ one per cluster per example)",
            Arc::clone(&self.refine),
        );
        reg.register_counter(
            "kss_sampler_midx_reassign_total",
            "sweeps",
            "sampler",
            "Lloyd re-assignment sweeps (periodic, or behind a publish)",
            Arc::clone(&self.reassign),
        );
        reg.register_counter(
            "kss_sampler_midx_zero_cluster_total",
            "draws",
            "sampler",
            "draws routed through a degenerate-mass uniform fallback",
            Arc::clone(&self.zero_cluster),
        );
        reg.register_gauge(
            "kss_sampler_midx_drift",
            "l2",
            "sampler",
            "accumulated centroid drift Σ‖Δw‖₂ since the last re-assignment sweep",
            Arc::clone(&self.drift),
        );
    }

    /// Flush a scratch's accumulated counts into the shared cells (and
    /// zero the locals either way, so a disabled engine stays clean).
    fn flush_scratch(&self, s: &mut MidxScratch) {
        if self.enabled {
            self.coarse.add(s.o_coarse);
            self.refine.add(s.o_refine);
            self.zero_cluster.add(s.o_zero);
        }
        s.o_coarse = 0;
        s.o_refine = 0;
        s.o_zero = 0;
    }

    pub fn clusters(&self) -> f64 {
        self.clusters.get()
    }

    pub fn coarse_draw_total(&self) -> u64 {
        self.coarse.get()
    }

    pub fn refine_total(&self) -> u64 {
        self.refine.get()
    }

    pub fn reassign_total(&self) -> u64 {
        self.reassign.get()
    }

    pub fn zero_cluster_total(&self) -> u64 {
        self.zero_cluster.get()
    }

    pub fn drift(&self) -> f64 {
        self.drift.get()
    }
}

/// The owning trainer-side sampler: class-major embedding mirror +
/// [`MidxIndex`] + periodic re-assignment policy.
pub struct MidxKernelSampler<M: FeatureMap> {
    map: M,
    name: String,
    n: usize,
    d: usize,
    emb: Vec<f32>,
    index: MidxIndex,
    obs: MidxObs,
    scratch: Pool<MidxScratch>,
    phi_a: Vec<f64>,
    phi_b: Vec<f64>,
    updates_since_sweep: usize,
    /// Updated rows between Lloyd re-assignment sweeps (default: half
    /// the vocabulary — membership can survive many small steps, and a
    /// sweep is one full assignment pass, so amortize it like the vocab
    /// tier amortizes compaction).
    reassign_every: usize,
    drift: f64,
    lloyd_iters: usize,
    seed: u64,
}

impl<M: FeatureMap> MidxKernelSampler<M> {
    /// `clusters = None` → K = ⌈√n⌉.
    pub fn new(map: M, n: usize, clusters: Option<usize>) -> MidxKernelSampler<M> {
        Self::with_config(map, n, clusters, DEFAULT_LLOYD_ITERS, MIDX_BUILD_SEED)
    }

    pub fn with_config(
        map: M,
        n: usize,
        clusters: Option<usize>,
        lloyd_iters: usize,
        seed: u64,
    ) -> MidxKernelSampler<M> {
        assert!(n > 0, "midx sampler needs at least one class");
        let d = map.d();
        let dim = map.dim();
        let emb = vec![0.0f32; n * d];
        let index = MidxIndex::build(&map, &emb, n, d, clusters, lloyd_iters, seed, None);
        let obs = MidxObs::default();
        obs.clusters.set(index.k as f64);
        let name = format!("{}-midx", map.name());
        MidxKernelSampler {
            map,
            name,
            n,
            d,
            emb,
            index,
            obs,
            scratch: Pool::new(),
            phi_a: vec![0.0; dim],
            phi_b: vec![0.0; dim],
            updates_since_sweep: 0,
            reassign_every: (n / 2).max(1),
            drift: 0.0,
            lloyd_iters,
            seed,
        }
    }

    pub fn obs(&self) -> &MidxObs {
        &self.obs
    }

    pub fn feature_map(&self) -> &M {
        &self.map
    }

    pub fn set_obs_enabled(&mut self, enabled: bool) {
        self.obs.enabled = enabled;
    }

    pub fn index(&self) -> &MidxIndex {
        &self.index
    }

    pub fn clusters(&self) -> usize {
        self.index.k
    }

    /// Override the re-assignment period (tests; 1 = sweep every step).
    pub fn set_reassign_every(&mut self, every: usize) {
        self.reassign_every = every.max(1);
    }

    /// Run the Lloyd re-assignment sweep now (also resets the drift).
    pub fn force_sweep(&mut self) {
        self.index.sweep(&self.map, &self.emb);
        self.updates_since_sweep = 0;
        self.drift = 0.0;
        if self.obs.enabled {
            self.obs.reassign.inc();
            self.obs.drift.set(0.0);
            self.obs.clusters.set(self.index.k as f64);
        }
    }

    fn after_updates(&mut self) {
        if self.updates_since_sweep >= self.reassign_every {
            self.force_sweep();
        } else if self.obs.enabled {
            self.obs.drift.set(self.drift);
        }
    }
}

impl<M: FeatureMap> Sampler for MidxKernelSampler<M> {
    fn name(&self) -> &str {
        &self.name
    }

    fn needs(&self) -> Needs {
        Needs { h: true, ..Needs::default() }
    }

    fn sample(&self, input: &SampleInput, m: usize, rng: &mut Rng, out: &mut Sample) -> Result<()> {
        let h = input
            .h
            .ok_or_else(|| anyhow::anyhow!("sampler '{}' needs h", self.name))?;
        anyhow::ensure!(h.len() == self.d, "h has dim {}, sampler has d={}", h.len(), self.d);
        let mut s = self.scratch.take(|| self.index.new_scratch());
        self.index.begin_example(&self.map, h, &mut s);
        out.clear();
        for _ in 0..m {
            let (class, q) = self.index.draw(&self.map, h, &mut s, rng);
            out.push(class, q);
        }
        self.obs.flush_scratch(&mut s);
        self.scratch.put(s);
        Ok(())
    }

    fn sample_batch(
        &self,
        inputs: &BatchSampleInput,
        m: usize,
        step_seed: u64,
        out: &mut [Sample],
    ) -> Result<()> {
        anyhow::ensure!(
            out.len() == inputs.n,
            "out has {} slots, batch has {} rows",
            out.len(),
            inputs.n
        );
        inputs.validate(self.name(), self.needs())?;
        // Per-row streams (midx is NOT batch-coupled); one pooled scratch
        // per worker amortizes the refine arena across its rows.
        par_chunks_mut(out, inputs.threads, |base, chunk| {
            let mut s = self.scratch.take(|| self.index.new_scratch());
            for (k, slot) in chunk.iter_mut().enumerate() {
                let i = base + k;
                let h = inputs.row(i).h.expect("validated");
                let mut rng = row_rng(step_seed, i);
                self.index.begin_example(&self.map, h, &mut s);
                slot.clear();
                for _ in 0..m {
                    let (class, q) = self.index.draw(&self.map, h, &mut s, &mut rng);
                    slot.push(class, q);
                }
            }
            self.obs.flush_scratch(&mut s);
            self.scratch.put(s);
        });
        Ok(())
    }

    fn prob(&self, input: &SampleInput, class: u32) -> Option<f64> {
        let h = input.h?;
        if class as usize >= self.n {
            return None;
        }
        let mut s = self.scratch.take(|| self.index.new_scratch());
        self.index.begin_example(&self.map, h, &mut s);
        let p = self.index.prob_of(&self.map, h, class, &mut s);
        self.obs.flush_scratch(&mut s);
        self.scratch.put(s);
        Some(p)
    }

    fn update(&mut self, class: usize, w_new: &[f32]) {
        self.drift += self.index.apply_update(
            &self.map,
            class,
            w_new,
            &mut self.emb,
            &mut self.phi_a,
            &mut self.phi_b,
        );
        self.updates_since_sweep += 1;
        self.after_updates();
    }

    fn update_many(&mut self, classes: &[usize], rows: &[f32]) {
        if classes.is_empty() {
            return;
        }
        let d = rows.len() / classes.len();
        debug_assert_eq!(d, self.d);
        for (i, &class) in classes.iter().enumerate() {
            self.drift += self.index.apply_update(
                &self.map,
                class,
                &rows[i * d..(i + 1) * d],
                &mut self.emb,
                &mut self.phi_a,
                &mut self.phi_b,
            );
            self.updates_since_sweep += 1;
        }
        // At most one re-assignment sweep per batched update (the same
        // single-sweep shape as the tree's bottom-up aggregation).
        self.after_updates();
    }

    fn reset_embeddings(&mut self, w: &[f32], n: usize, d: usize) {
        assert_eq!(n, self.n, "midx sampler built for {} classes, reset with {n}", self.n);
        assert_eq!(d, self.d, "midx sampler built for d={}, reset with d={d}", self.d);
        self.emb.copy_from_slice(w);
        self.index = MidxIndex::build(
            &self.map,
            &self.emb,
            n,
            d,
            Some(self.index.k),
            self.lloyd_iters,
            self.seed,
            None,
        );
        self.updates_since_sweep = 0;
        self.drift = 0.0;
        if self.obs.enabled {
            self.obs.clusters.set(self.index.k as f64);
            self.obs.drift.set(0.0);
        }
    }
}

/// Serve-side midx engine for `SnapshotSampler`: rebuilds the index
/// behind each published tree generation (warm-restarting from the
/// previous centroids — that rebuild *is* the re-assignment sweep, so it
/// counts in `kss_sampler_midx_reassign_total`) and serves reads from an
/// `Arc` that workers clone out of one short critical section.
pub struct MidxCore {
    clusters: Option<usize>,
    lloyd_iters: usize,
    seed: u64,
    cache: Mutex<Option<(u64, Arc<MidxIndex>)>>,
    obs: MidxObs,
    scratch: Pool<MidxScratch>,
}

impl MidxCore {
    pub fn new(clusters: Option<usize>) -> MidxCore {
        MidxCore {
            clusters,
            lloyd_iters: DEFAULT_LLOYD_ITERS,
            seed: MIDX_BUILD_SEED,
            cache: Mutex::new(None),
            obs: MidxObs::default(),
            scratch: Pool::new(),
        }
    }

    pub fn obs(&self) -> &MidxObs {
        &self.obs
    }

    pub fn set_obs_enabled(&mut self, enabled: bool) {
        self.obs.enabled = enabled;
    }

    /// The index for `generation`, rebuilding on a generation change.
    /// The build runs under the cache lock: one rebuild per publish,
    /// and a blocked reader is strictly better than n concurrent
    /// identical k-means builds. No other lock is taken while held.
    fn index_for<M: FeatureMap>(
        &self,
        view: &super::tree::TreeView<'_, M>,
        generation: u64,
    ) -> Arc<MidxIndex> {
        // A poisoned cache means another worker panicked mid-build; the
        // slot it took stays `None`, so recovering the lock is safe — the
        // next line simply rebuilds. Workers must stay panic-free.
        let mut guard = self.cache.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        if let Some((g, idx)) = guard.as_ref() {
            if *g == generation {
                return Arc::clone(idx);
            }
        }
        let warm = guard.take().map(|(_, idx)| idx);
        let idx = Arc::new(MidxIndex::build(
            view.feature_map(),
            view.emb_panel(),
            view.num_classes(),
            view.embed_dim(),
            self.clusters,
            self.lloyd_iters,
            self.seed,
            warm.as_deref(),
        ));
        if self.obs.enabled {
            if warm.is_some() {
                self.obs.reassign.inc();
            }
            self.obs.clusters.set(idx.k as f64);
        }
        *guard = Some((generation, Arc::clone(&idx)));
        idx
    }

    /// One example's m draws against the index for `generation`.
    pub fn sample_view<M: FeatureMap>(
        &self,
        view: &super::tree::TreeView<'_, M>,
        generation: u64,
        h: &[f32],
        m: usize,
        rng: &mut Rng,
        out: &mut Sample,
    ) -> Result<()> {
        let idx = self.index_for(view, generation);
        let mut s = self.scratch.take(|| idx.new_scratch());
        idx.begin_example(view.feature_map(), h, &mut s);
        out.clear();
        for _ in 0..m {
            let (class, q) = idx.draw(view.feature_map(), h, &mut s, rng);
            out.push(class, q);
        }
        self.obs.flush_scratch(&mut s);
        self.scratch.put(s);
        Ok(())
    }

    /// Batch fan-out with per-row [`row_rng`] streams (bit-identical to
    /// a [`Self::sample_view`] loop at any thread count).
    pub fn sample_batch_view<M: FeatureMap>(
        &self,
        view: &super::tree::TreeView<'_, M>,
        generation: u64,
        inputs: &BatchSampleInput,
        m: usize,
        step_seed: u64,
        out: &mut [Sample],
    ) -> Result<()> {
        anyhow::ensure!(
            out.len() == inputs.n,
            "out has {} slots, batch has {} rows",
            out.len(),
            inputs.n
        );
        inputs.validate("midx", Needs { h: true, ..Needs::default() })?;
        let idx = self.index_for(view, generation);
        let map = view.feature_map();
        par_chunks_mut(out, inputs.threads, |base, chunk| {
            let mut s = self.scratch.take(|| idx.new_scratch());
            for (k, slot) in chunk.iter_mut().enumerate() {
                let i = base + k;
                let h = inputs.row(i).h.expect("validated");
                let mut rng = row_rng(step_seed, i);
                idx.begin_example(map, h, &mut s);
                slot.clear();
                for _ in 0..m {
                    let (class, q) = idx.draw(map, h, &mut s, &mut rng);
                    slot.push(class, q);
                }
            }
            self.obs.flush_scratch(&mut s);
            self.scratch.put(s);
        });
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::QuadraticMap;
    use super::*;

    fn fill_emb(rng: &mut Rng, n: usize, d: usize) -> Vec<f32> {
        let mut emb = vec![0.0f32; n * d];
        rng.fill_normal(&mut emb, 1.0);
        emb
    }

    /// Flat eq. (8) distribution — the correctness oracle.
    fn exact_dist(map: &QuadraticMap, emb: &[f32], n: usize, d: usize, h: &[f32]) -> Vec<f64> {
        let mut ks = vec![0.0f64; n];
        map.kernel_many(h, emb, &mut ks);
        let total: f64 = ks.iter().map(|&k| sanitize_mass(k)).sum();
        ks.iter().map(|&k| sanitize_mass(k) / total).collect()
    }

    fn sampler_with_emb(
        n: usize,
        d: usize,
        clusters: Option<usize>,
        seed: u64,
    ) -> (MidxKernelSampler<QuadraticMap>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let emb = fill_emb(&mut rng, n, d);
        let mut s = MidxKernelSampler::new(QuadraticMap::new(d, 1.0), n, clusters);
        s.reset_embeddings(&emb, n, d);
        (s, emb)
    }

    #[test]
    fn composed_q_matches_flat_eq8_within_1e12() {
        // The tentpole exactness property: across an interleaved
        // update/re-assign schedule, every reported composed q equals
        // the flat eq. (8) q to ≤ 1e-12 relative error.
        let (n, d, m) = (240, 4, 16);
        let (mut sampler, mut emb) = sampler_with_emb(n, d, Some(15), 7);
        let mut rng = Rng::new(99);
        sampler.set_reassign_every(usize::MAX); // manual sweeps below
        for step in 0..12 {
            // Update a strided subset of rows.
            let classes: Vec<usize> = (0..n).filter(|c| c % 7 == step % 7).collect();
            let mut rows = vec![0.0f32; classes.len() * d];
            rng.fill_normal(&mut rows, 1.0);
            for (i, &c) in classes.iter().enumerate() {
                emb[c * d..(c + 1) * d].copy_from_slice(&rows[i * d..(i + 1) * d]);
            }
            sampler.update_many(&classes, &rows);
            if step % 5 == 4 {
                sampler.force_sweep();
            }
            let mut h = vec![0.0f32; d];
            rng.fill_normal(&mut h, 1.0);
            let exact = exact_dist(sampler.feature_map(), &emb, n, d, &h);
            let input = SampleInput { h: Some(&h), ..Default::default() };
            let mut out = Sample::default();
            sampler.sample(&input, m, &mut rng, &mut out).unwrap();
            for (&class, &q) in out.classes.iter().zip(&out.q) {
                let flat = exact[class as usize];
                let rel = (q - flat).abs() / flat;
                assert!(
                    rel <= 1e-12,
                    "step {step}: class {class} composed q {q} vs flat {flat} (rel {rel:e})"
                );
                // prob() agrees with the reported draw q.
                let p = sampler.prob(&input, class).unwrap();
                let rel_p = (p - q).abs() / q;
                assert!(rel_p <= 1e-12, "prob {p} vs drawn q {q} (rel {rel_p:e})");
            }
        }
        assert!(sampler.obs().reassign_total() >= 2);
    }

    #[test]
    fn chi_square_gof_on_composed_proposal() {
        let (n, d) = (60, 3);
        let (sampler, _emb) = sampler_with_emb(n, d, Some(8), 11);
        let mut rng = Rng::new(5);
        let mut h = vec![0.0f32; d];
        rng.fill_normal(&mut h, 1.0);
        let input = SampleInput { h: Some(&h), ..Default::default() };
        let expected: Vec<f64> = (0..n as u32)
            .map(|c| sampler.prob(&input, c).unwrap())
            .collect();
        let total_p: f64 = expected.iter().sum();
        assert!((total_p - 1.0).abs() < 1e-9, "probs sum to {total_p}");
        let draws = 200_000usize;
        let mut counts = vec![0u64; n];
        let mut out = Sample::default();
        for _ in 0..draws / 50 {
            sampler.sample(&input, 50, &mut rng, &mut out).unwrap();
            for &c in &out.classes {
                counts[c as usize] += 1;
            }
        }
        let mut stat = 0.0f64;
        for c in 0..n {
            let e = expected[c] * draws as f64;
            if e > 0.0 {
                let diff = counts[c] as f64 - e;
                stat += diff * diff / e;
            }
        }
        let dof = (n - 1) as f64;
        let bound = dof + 6.0 * (2.0 * dof).sqrt();
        assert!(stat < bound, "χ² = {stat:.1} over bound {bound:.1}");
    }

    #[test]
    fn batch_is_bit_identical_to_per_row_loop_at_any_thread_count() {
        let (n, d, rows, m) = (120, 4, 33, 7);
        let (sampler, _emb) = sampler_with_emb(n, d, None, 21);
        let mut rng = Rng::new(3);
        let mut hs = vec![0.0f32; rows * d];
        rng.fill_normal(&mut hs, 1.0);
        let step_seed = 0xFEED_u64;
        // Reference: per-row sample() over row_rng streams.
        let mut want: Vec<Sample> = vec![Sample::default(); rows];
        for i in 0..rows {
            let input = SampleInput { h: Some(&hs[i * d..(i + 1) * d]), ..Default::default() };
            let mut r = row_rng(step_seed, i);
            sampler.sample(&input, m, &mut r, &mut want[i]).unwrap();
        }
        for threads in [0usize, 1, 4] {
            let inputs = BatchSampleInput {
                n: rows,
                d,
                n_classes: n,
                h: Some(&hs),
                threads,
                ..Default::default()
            };
            let mut got: Vec<Sample> = vec![Sample::default(); rows];
            sampler.sample_batch(&inputs, m, step_seed, &mut got).unwrap();
            for i in 0..rows {
                assert_eq!(got[i].classes, want[i].classes, "threads={threads} row {i}");
                assert_eq!(got[i].q, want[i].q, "threads={threads} row {i}");
            }
        }
    }

    #[test]
    fn tv_to_exact_matches_tree_at_matched_m() {
        use super::super::tree::KernelTreeSampler;
        let (n, d) = (200, 4);
        let mut rng = Rng::new(31);
        let emb = fill_emb(&mut rng, n, d);
        let mut midx = MidxKernelSampler::new(QuadraticMap::new(d, 1.0), n, None);
        midx.reset_embeddings(&emb, n, d);
        let mut tree = KernelTreeSampler::new(QuadraticMap::new(d, 1.0), n, None);
        tree.reset_embeddings(&emb, n, d);
        let mut h = vec![0.0f32; d];
        rng.fill_normal(&mut h, 1.0);
        let map = QuadraticMap::new(d, 1.0);
        let exact = exact_dist(&map, &emb, n, d, &h);
        let input = SampleInput { h: Some(&h), ..Default::default() };
        let draws = 120_000usize;
        let tv = |s: &dyn Sampler| {
            let mut counts = vec![0u64; n];
            let mut out = Sample::default();
            let mut r = Rng::new(777);
            for _ in 0..draws / 40 {
                s.sample(&input, 40, &mut r, &mut out).unwrap();
                for &c in &out.classes {
                    counts[c as usize] += 1;
                }
            }
            0.5 * counts
                .iter()
                .zip(&exact)
                .map(|(&c, &p)| (c as f64 / draws as f64 - p).abs())
                .sum::<f64>()
        };
        let tv_midx = tv(&midx);
        let tv_tree = tv(&tree);
        // Both proposals are the exact eq. (8) distribution; their
        // empirical TV differs only by sampling noise at matched m.
        assert!(tv_midx < 0.02, "midx TV {tv_midx}");
        assert!(tv_tree < 0.02, "tree TV {tv_tree}");
        assert!((tv_midx - tv_tree).abs() < 0.01, "midx {tv_midx} vs tree {tv_tree}");
    }

    #[test]
    fn incremental_aggregates_match_rebuild() {
        let (n, d) = (150, 4);
        let (mut sampler, mut emb) = sampler_with_emb(n, d, Some(12), 13);
        sampler.set_reassign_every(usize::MAX);
        let mut rng = Rng::new(8);
        for _ in 0..20 {
            let classes: Vec<usize> = (0..n).filter(|_| rng.bool(0.3)).collect();
            if classes.is_empty() {
                continue;
            }
            let mut rows = vec![0.0f32; classes.len() * d];
            rng.fill_normal(&mut rows, 1.0);
            for (i, &c) in classes.iter().enumerate() {
                emb[c * d..(c + 1) * d].copy_from_slice(&rows[i * d..(i + 1) * d]);
            }
            sampler.update_many(&classes, &rows);
        }
        // Rebuild the aggregates from scratch over the same membership
        // and compare: incremental ± φ must not drift.
        let map = QuadraticMap::new(d, 1.0);
        let idx = sampler.index();
        let mut phi = vec![0.0f64; map.dim()];
        for k in 0..idx.clusters() {
            let mut want = vec![0.0f64; map.dim()];
            for c in 0..n {
                if idx.cluster_of(c) == k {
                    map.phi(&emb[c * d..(c + 1) * d], &mut phi);
                    ops::add_assign(&mut want, &phi);
                }
            }
            for (a, b) in idx.zstat_row(k).iter().zip(&want) {
                let scale = b.abs().max(1.0);
                assert!(
                    (a - b).abs() / scale <= 1e-9,
                    "cluster {k}: incremental {a} vs rebuilt {b}"
                );
            }
        }
    }

    #[test]
    fn kmeans_build_is_deterministic() {
        let (n, d) = (300, 4);
        let mut rng = Rng::new(17);
        let emb = fill_emb(&mut rng, n, d);
        let map = QuadraticMap::new(d, 1.0);
        let a = MidxIndex::build(&map, &emb, n, d, None, 2, 42, None);
        let b = MidxIndex::build(&map, &emb, n, d, None, 2, 42, None);
        assert_eq!(a.assign, b.assign);
        assert_eq!(a.member, b.member);
        assert_eq!(a.centroids, b.centroids);
    }

    /// A kernel that is identically zero: drives every mass degenerate.
    struct ZeroMap {
        d: usize,
    }

    impl FeatureMap for ZeroMap {
        fn d(&self) -> usize {
            self.d
        }

        fn dim(&self) -> usize {
            2
        }

        fn name(&self) -> &'static str {
            "zero"
        }

        fn phi(&self, _a: &[f32], out: &mut [f64]) {
            out.fill(0.0);
        }

        fn kernel(&self, _a: &[f32], _b: &[f32]) -> f64 {
            0.0
        }
    }

    #[test]
    fn zero_mass_falls_back_to_uniform_with_positive_q() {
        let (n, d, m) = (64, 3, 32);
        let mut sampler = MidxKernelSampler::new(ZeroMap { d }, n, Some(8));
        let mut rng = Rng::new(2);
        let mut emb = vec![0.0f32; n * d];
        rng.fill_normal(&mut emb, 1.0);
        sampler.reset_embeddings(&emb, n, d);
        let h = vec![1.0f32; d];
        let input = SampleInput { h: Some(&h), ..Default::default() };
        let mut out = Sample::default();
        sampler.sample(&input, m, &mut rng, &mut out).unwrap();
        assert_eq!(out.classes.len(), m);
        for (&c, &q) in out.classes.iter().zip(&out.q) {
            assert!((c as usize) < n);
            assert!(q > 0.0 && q.is_finite());
            assert!((q - 1.0 / n as f64).abs() < 1e-15);
        }
        assert_eq!(sampler.obs().zero_cluster_total(), m as u64);
        assert_eq!(sampler.obs().coarse_draw_total(), 0);
    }

    #[test]
    fn telemetry_counts_refines_and_coarse_draws() {
        let (n, d, m) = (120, 4, 24);
        let (sampler, _emb) = sampler_with_emb(n, d, Some(10), 23);
        let mut rng = Rng::new(4);
        let mut h = vec![0.0f32; d];
        rng.fill_normal(&mut h, 1.0);
        let input = SampleInput { h: Some(&h), ..Default::default() };
        let mut out = Sample::default();
        sampler.sample(&input, m, &mut rng, &mut out).unwrap();
        let obs = sampler.obs();
        assert_eq!(obs.coarse_draw_total(), m as u64);
        // The refine memo caps the sweeps at min(m, K) per example.
        assert!(obs.refine_total() >= 1 && obs.refine_total() <= (10u64).min(m as u64));
        assert_eq!(obs.clusters(), 10.0);
    }
}

