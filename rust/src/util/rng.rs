//! Deterministic pseudo-random number generation and sampling distributions.
//!
//! Everything random in the system — data generation, parameter
//! initialization, negative sampling — flows from seeded [`Rng`] streams so
//! experiments are reproducible byte-for-byte.
//!
//! The generator is xoshiro256\*\* (Blackman & Vigna), seeded through
//! splitmix64 as its authors recommend. On top of it this module implements
//! the distributions the paper's experiments need:
//!
//! * uniform integers / floats,
//! * Gaussians (Box–Muller) for embedding initialization,
//! * Zipf via rejection-inversion (for the synthetic corpora's skewed class
//!   popularity),
//! * categorical sampling by CDF binary search (exact softmax / quartic
//!   samplers),
//! * Walker's alias method ([`AliasTable`]) for O(1) draws from static
//!   distributions (unigram sampler; also the future-work direction the
//!   paper sketches in §6 for non-negative feature maps).

/// splitmix64 step; used for seeding and cheap hashing.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256\*\* PRNG. Not cryptographic; fast, 256-bit state, passes BigCrush.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Gaussian from Box–Muller.
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent stream for a labeled subtask. Streams derived
    /// with different labels are de-correlated (label is hashed into the
    /// seed), which lets e.g. each batch row sample negatives in parallel
    /// with its own generator.
    pub fn fork(&mut self, label: u64) -> Rng {
        let mut sm = self.next_u64() ^ label.wrapping_mul(0x9E3779B97F4A7C15);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, n)`. Uses Lemire's multiply-shift rejection
    /// to avoid modulo bias.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0, "below(0) is undefined");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        // Avoid log(0).
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.gauss_spare = Some(r * s);
        r * c
    }

    /// Normal with the given mean and standard deviation, as f32.
    #[inline]
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Fill a slice with N(0, std) samples (embedding init).
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32(0.0, std);
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights in O(n).
    /// Returns `None` when the total mass is not positive and finite.
    pub fn categorical(&mut self, weights: &[f32]) -> Option<usize> {
        let total: f64 = weights.iter().map(|&w| w as f64).sum();
        if !(total > 0.0) || !total.is_finite() {
            return None;
        }
        let mut u = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w as f64;
            if u < 0.0 {
                return Some(i);
            }
        }
        // Floating-point slack: return the last strictly-positive weight.
        weights.iter().rposition(|&w| w > 0.0)
    }
}

/// The deterministic per-row RNG stream of the batch sampling API: row `i`
/// of a step seeded with `step_seed` always samples from this stream,
/// whether drawn through a sampler's `sample_batch`, a per-example
/// `sample` loop, or [`AliasTable::sample_many`] — and regardless of the
/// fan-out thread count. Canonical home of the stream definition (the
/// sampler layer re-exports it); the golden-ratio multiplier decorrelates
/// adjacent row seeds through [`splitmix64`]-style dispersion.
#[inline]
pub fn row_rng(step_seed: u64, row: usize) -> Rng {
    Rng::new(step_seed ^ (row as u64).wrapping_mul(0x9E3779B97F4A7C15))
}

/// The CDF prefix-sum fill lives in the ops layer ([`crate::ops::fill_cum`]
/// — strictly sequential by the accumulation-order contract); re-exported
/// here because it is half of the CDF-draw pair with [`sample_cum`]. The
/// caller must check the returned total is positive and finite before
/// sampling from `cum`.
pub use crate::ops::fill_cum;

/// Draw one index from an inclusive-prefix-sum CDF with positive finite
/// `total`. The returned index always has a strictly positive increment:
/// `partition_point` guarantees it when `u < total`, and the
/// floating-point slack case (`u` rounding up to `total`) clamps to the
/// last *positive-weight* index — a plain `len - 1` clamp could select a
/// zero-weight tail class, whose reported q of 0 would blow up the
/// eq. (2) correction downstream. The single implementation behind
/// [`Cdf::sample`] and the flat kernel sampler's scratch path, so the
/// zero-mass-tail invariant lives in one place.
pub fn sample_cum(cum: &[f64], total: f64, rng: &mut Rng) -> usize {
    debug_assert!(total > 0.0 && total.is_finite());
    let u = rng.f64() * total;
    // partition_point: first index with cum[i] > u (its increment is
    // then > 0 because cum[idx-1] <= u < cum[idx]).
    let idx = cum.partition_point(|&c| c <= u);
    if idx < cum.len() {
        idx
    } else {
        last_positive_cum_index(cum)
    }
}

/// Index of the last strictly positive CDF increment (exists whenever the
/// total mass is positive).
pub fn last_positive_cum_index(cum: &[f64]) -> usize {
    (0..cum.len())
        .rev()
        .find(|&i| {
            let lo = if i == 0 { 0.0 } else { cum[i - 1] };
            cum[i] - lo > 0.0
        })
        .expect("CDF invariant: total mass > 0")
}

/// Cumulative distribution over class weights, for O(log n) repeated draws
/// from the same (per-example) distribution. Built once per example by the
/// exact-softmax and flat-kernel samplers, then binary-searched `m` times.
///
/// # Dense-index contract
///
/// `Cdf` is **slot-addressed**: `weights[j]` belongs to index `j`, and
/// `sample`/`prob` speak that same index space. Callers whose classes are
/// identified by global ids with holes (a streaming vocabulary after
/// retirement, a sharded local range) must keep their own id→slot map and
/// translate at the boundary — passing a global id where a slot is
/// expected does not error, it *silently aliases into another class's
/// mass* and reports a wrong q (`prob` panics only when the id happens to
/// fall past the end). Use [`IdCdf`] when the id space is not dense
/// `0..C`; it carries the mapping explicitly and declines unknown ids.
pub struct Cdf {
    /// Inclusive prefix sums of the weights, `cum[i] = Σ_{j<=i} w_j`.
    cum: Vec<f64>,
    total: f64,
}

impl Cdf {
    /// Build from unnormalized non-negative weights.
    pub fn new(weights: &[f32]) -> Option<Cdf> {
        let mut cum = Vec::new();
        let acc = fill_cum(weights, &mut cum);
        if !(acc > 0.0) || !acc.is_finite() {
            return None;
        }
        Some(Cdf { cum, total: acc })
    }

    /// Total unnormalized mass.
    #[inline]
    pub fn total(&self) -> f64 {
        self.total
    }

    /// Probability of index `i`.
    pub fn prob(&self, i: usize) -> f64 {
        let lo = if i == 0 { 0.0 } else { self.cum[i - 1] };
        (self.cum[i] - lo) / self.total
    }

    /// Draw one index with strictly positive weight (see [`sample_cum`],
    /// the shared implementation).
    pub fn sample(&self, rng: &mut Rng) -> usize {
        sample_cum(&self.cum, self.total, rng)
    }

    /// Index of the last strictly positive weight (exists: construction
    /// rejects zero total mass). Test hook over [`last_positive_cum_index`],
    /// which `sample` reaches through [`sample_cum`].
    #[cfg(test)]
    fn last_positive_index(&self) -> usize {
        last_positive_cum_index(&self.cum)
    }
}

/// [`Cdf`] over an explicit, possibly holey global-id set.
///
/// Slot-addressed CDFs ([`Cdf`] above) assume ids are dense `0..C`; once a
/// vocabulary churns (retired ids leave holes, inserts mint ids past the
/// original range) that assumption fails *silently* — a global id used as
/// a slot reads another class's cumulative mass and comes back with a
/// plausible but wrong q. `IdCdf` carries the id→slot mapping inside the
/// structure: `sample` returns `(id, q)` pairs in id space, `prob_of`
/// declines unknown ids with `None`, and construction rejects duplicate
/// ids (which would split one class's mass across two slots). The
/// streaming-vocabulary memtable is the canonical producer of such holey
/// id sets (see `crate::vocab::memtable`).
pub struct IdCdf {
    /// Slot → global id, parallel to the weights the CDF was built from.
    ids: Vec<u32>,
    /// Global id → slot (the explicit inverse; no dense assumption).
    slot_of: std::collections::HashMap<u32, u32>,
    cum: Vec<f64>,
    total: f64,
}

impl IdCdf {
    /// Build from parallel `(ids, weights)`. Returns `None` when the
    /// lengths differ, an id repeats, or the total mass is not positive
    /// and finite — the same clean-decline contract as [`Cdf::new`].
    pub fn new(ids: &[u32], weights: &[f32]) -> Option<IdCdf> {
        if ids.len() != weights.len() {
            return None;
        }
        let mut cum = Vec::new();
        let acc = fill_cum(weights, &mut cum);
        if !(acc > 0.0) || !acc.is_finite() {
            return None;
        }
        let mut slot_of = std::collections::HashMap::with_capacity(ids.len());
        for (slot, &id) in ids.iter().enumerate() {
            if slot_of.insert(id, slot as u32).is_some() {
                return None;
            }
        }
        Some(IdCdf { ids: ids.to_vec(), slot_of, cum, total: acc })
    }

    /// Total unnormalized mass.
    #[inline]
    pub fn total(&self) -> f64 {
        self.total
    }

    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    /// Draw one `(global id, q)` pair (strictly positive weight, see
    /// [`sample_cum`]).
    pub fn sample(&self, rng: &mut Rng) -> (u32, f64) {
        let slot = sample_cum(&self.cum, self.total, rng);
        let lo = if slot == 0 { 0.0 } else { self.cum[slot - 1] };
        (self.ids[slot], (self.cum[slot] - lo) / self.total)
    }

    /// Probability of a *global id*; `None` for ids outside the set — the
    /// error mode dense CDFs cannot express.
    pub fn prob_of(&self, id: u32) -> Option<f64> {
        let &slot = self.slot_of.get(&id)?;
        let slot = slot as usize;
        let lo = if slot == 0 { 0.0 } else { self.cum[slot - 1] };
        Some((self.cum[slot] - lo) / self.total)
    }
}

/// Walker's alias method (Walker 1977): O(n) construction, O(1) sampling
/// from a fixed categorical distribution. Used by the unigram sampler and
/// the uniform sampler's fast path.
#[derive(Clone, Debug)]
pub struct AliasTable {
    prob: Vec<f64>,
    alias: Vec<u32>,
    /// Normalized probability of each class (kept for q-corrections).
    p: Vec<f64>,
}

impl AliasTable {
    /// Build from unnormalized non-negative weights. Returns `None` on any
    /// degenerate input (see [`AliasTable::try_new`] for the reasons).
    pub fn new(weights: &[f64]) -> Option<AliasTable> {
        AliasTable::try_new(weights).ok()
    }

    /// Build from unnormalized non-negative weights, with the degenerate
    /// cases reported as errors instead of a silently broken table (a
    /// negative or NaN weight used to flow straight into the normalized
    /// `p` and poison `prob_of` q-corrections): empty input, any
    /// non-finite or negative weight, and a total mass that is not
    /// positive and finite are all rejected.
    pub fn try_new(weights: &[f64]) -> anyhow::Result<AliasTable> {
        let n = weights.len();
        anyhow::ensure!(n > 0, "alias table needs at least one weight");
        for (i, &w) in weights.iter().enumerate() {
            anyhow::ensure!(
                w.is_finite() && w >= 0.0,
                "alias weight {i} is {w} (must be finite and ≥ 0)"
            );
        }
        let total: f64 = weights.iter().sum();
        anyhow::ensure!(
            total > 0.0 && total.is_finite(),
            "alias total mass is {total} (must be positive and finite)"
        );
        let p: Vec<f64> = weights.iter().map(|w| w / total).collect();
        let mut prob = vec![0.0f64; n];
        let mut alias = vec![0u32; n];
        // Scaled probabilities; classify into small/large stacks.
        let mut scaled: Vec<f64> = p.iter().map(|&x| x * n as f64).collect();
        let mut small: Vec<u32> = Vec::with_capacity(n);
        let mut large: Vec<u32> = Vec::with_capacity(n);
        for (i, &s) in scaled.iter().enumerate() {
            if s < 1.0 {
                small.push(i as u32);
            } else {
                large.push(i as u32);
            }
        }
        while let (Some(&s), Some(&l)) = (small.last(), large.last()) {
            small.pop();
            prob[s as usize] = scaled[s as usize];
            alias[s as usize] = l;
            scaled[l as usize] = (scaled[l as usize] + scaled[s as usize]) - 1.0;
            if scaled[l as usize] < 1.0 {
                large.pop();
                small.push(l);
            }
        }
        for &l in large.iter().chain(small.iter()) {
            prob[l as usize] = 1.0;
        }
        Ok(AliasTable { prob, alias, p })
    }

    /// Number of classes.
    pub fn len(&self) -> usize {
        self.prob.len()
    }

    /// True when the table is empty.
    pub fn is_empty(&self) -> bool {
        self.prob.is_empty()
    }

    /// Normalized probability of class `i` (needed for the sampled-softmax
    /// `ln(m q_i)` correction).
    #[inline]
    pub fn prob_of(&self, i: usize) -> f64 {
        self.p[i]
    }

    /// Draw one class in O(1).
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let n = self.prob.len();
        let i = rng.below(n as u64) as usize;
        if rng.f64() < self.prob[i] {
            i
        } else {
            self.alias[i] as usize
        }
    }

    /// Row-major batch fill: `rows × m` draws into `out` (cleared first),
    /// row `i` drawn from the batch API's deterministic [`row_rng`]
    /// stream — bit-identical to a per-row [`AliasTable::sample`] loop
    /// over those streams, for any caller-side fan-out.
    pub fn sample_many(&self, step_seed: u64, rows: usize, m: usize, out: &mut Vec<u32>) {
        out.clear();
        out.reserve(rows * m);
        for i in 0..rows {
            let mut rng = row_rng(step_seed, i);
            for _ in 0..m {
                out.push(self.sample(&mut rng) as u32);
            }
        }
    }
}

/// Zipf(s) distribution over `{0, .., n-1}` (rank 0 is the most frequent),
/// i.e. `P(k) ∝ (k+1)^-s`. Used by the synthetic corpora to mimic the skewed
/// class popularity of natural-language vocabularies and video catalogs.
///
/// Implementation: exact CDF inversion via a precomputed table (n is at most
/// a few hundred thousand in our experiments, so an O(n) table is cheap and
/// exact, unlike rejection-inversion approximations).
#[derive(Clone)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    /// Build a Zipf(s) sampler over n ranks.
    pub fn new(n: usize, s: f64) -> Zipf {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 0..n {
            acc += ((k + 1) as f64).powf(-s);
            cdf.push(acc);
        }
        let total = acc;
        for c in cdf.iter_mut() {
            *c /= total;
        }
        Zipf { cdf }
    }

    /// Probability of rank `k`.
    pub fn prob(&self, k: usize) -> f64 {
        if k == 0 {
            self.cdf[0]
        } else {
            self.cdf[k] - self.cdf[k - 1]
        }
    }

    /// Draw a rank.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        let idx = self.cdf.partition_point(|&c| c <= u);
        idx.min(self.cdf.len() - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2, "streams from different seeds should diverge");
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(3);
        let n = 10u64;
        let mut counts = [0usize; 10];
        let trials = 100_000;
        for _ in 0..trials {
            counts[r.below(n) as usize] += 1;
        }
        let expect = trials as f64 / n as f64;
        for &c in &counts {
            assert!((c as f64 - expect).abs() < 5.0 * expect.sqrt(), "count {c} vs {expect}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut sum, mut sum2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            sum += z;
            sum2 += z * z;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>(), "shuffle left input unchanged");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(9);
        let w = [1.0f32, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.categorical(&w).unwrap()] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio {ratio}");
    }

    #[test]
    fn categorical_rejects_zero_mass() {
        let mut r = Rng::new(9);
        assert!(r.categorical(&[0.0, 0.0]).is_none());
        assert!(r.categorical(&[]).is_none());
    }

    #[test]
    fn cdf_matches_categorical() {
        let mut r = Rng::new(13);
        let w = [0.5f32, 2.5, 1.0, 0.0, 4.0];
        let cdf = Cdf::new(&w).unwrap();
        let total: f32 = w.iter().sum();
        for (i, &wi) in w.iter().enumerate() {
            assert!((cdf.prob(i) - (wi / total) as f64).abs() < 1e-9);
        }
        let mut counts = [0usize; 5];
        for _ in 0..80_000 {
            counts[cdf.sample(&mut r)] += 1;
        }
        assert_eq!(counts[3], 0);
        for (i, &c) in counts.iter().enumerate() {
            let expect = 80_000.0 * cdf.prob(i);
            assert!((c as f64 - expect).abs() < 6.0 * expect.max(1.0).sqrt(), "class {i}: {c} vs {expect}");
        }
    }

    #[test]
    fn cdf_never_selects_zero_weight_tail() {
        // regression: the old top-end clamp (`idx.min(len - 1)`) could hand
        // out the last index even when its weight was zero, reporting q = 0.
        let cdf = Cdf::new(&[0.0f32, 3.0, 0.0, 0.0]).unwrap();
        let mut r = Rng::new(29);
        for _ in 0..20_000 {
            let i = cdf.sample(&mut r);
            assert_eq!(i, 1, "only the positive-weight class may be drawn");
            assert!(cdf.prob(i) > 0.0);
        }
        assert_eq!(cdf.last_positive_index(), 1);
        // and the all-positive case still reaches the true last index
        let cdf = Cdf::new(&[1.0f32, 1.0]).unwrap();
        assert_eq!(cdf.last_positive_index(), 1);
    }

    #[test]
    fn id_cdf_holey_id_space_does_not_alias() {
        // regression for the dense-id assumption: with global ids
        // {5, 17, 900}, feeding an id into the slot-addressed Cdf reads
        // another class's mass (id 5 would alias into slot 5 — out of
        // range here, but a *wrong class* in a bigger table). IdCdf keeps
        // the map explicit: draws come back in id space with the right q.
        let ids = [5u32, 17, 900];
        let w = [1.0f32, 3.0, 6.0];
        let cdf = IdCdf::new(&ids, &w).unwrap();
        assert_eq!(cdf.len(), 3);
        let total: f32 = w.iter().sum();
        for (slot, &id) in ids.iter().enumerate() {
            let got = cdf.prob_of(id).unwrap();
            assert!((got - (w[slot] / total) as f64).abs() < 1e-12, "id {id}");
        }
        // unknown / retired ids decline cleanly instead of mis-addressing
        assert_eq!(cdf.prob_of(0), None);
        assert_eq!(cdf.prob_of(6), None);
        assert_eq!(cdf.prob_of(u32::MAX), None);
        let mut r = Rng::new(31);
        let mut mass = std::collections::HashMap::new();
        for _ in 0..60_000 {
            let (id, q) = cdf.sample(&mut r);
            assert!(ids.contains(&id), "drew id {id} outside the set");
            assert_eq!(q, cdf.prob_of(id).unwrap());
            *mass.entry(id).or_insert(0usize) += 1;
        }
        for (slot, &id) in ids.iter().enumerate() {
            let c = mass[&id] as f64;
            let expect = 60_000.0 * (w[slot] / total) as f64;
            assert!((c - expect).abs() < 6.0 * expect.sqrt(), "id {id}: {c} vs {expect}");
        }
        // malformed inputs decline at construction
        assert!(IdCdf::new(&[1, 1], &[1.0, 2.0]).is_none(), "duplicate ids split mass");
        assert!(IdCdf::new(&[1, 2], &[1.0]).is_none(), "length mismatch");
        assert!(IdCdf::new(&[1], &[0.0]).is_none(), "zero total mass");
    }

    #[test]
    fn alias_table_matches_distribution() {
        let mut r = Rng::new(17);
        let w = [10.0f64, 1.0, 0.0, 5.0, 4.0];
        let t = AliasTable::new(&w).unwrap();
        let total: f64 = w.iter().sum();
        let mut counts = [0usize; 5];
        let trials = 200_000;
        for _ in 0..trials {
            counts[t.sample(&mut r)] += 1;
        }
        assert_eq!(counts[2], 0, "zero-weight class sampled");
        for (i, &c) in counts.iter().enumerate() {
            let expect = trials as f64 * w[i] / total;
            assert!((c as f64 - expect).abs() < 6.0 * expect.max(1.0).sqrt(), "class {i}: {c} vs {expect}");
            assert!((t.prob_of(i) - w[i] / total).abs() < 1e-12);
        }
    }

    #[test]
    fn alias_table_uniform_case() {
        let t = AliasTable::new(&vec![1.0; 64]).unwrap();
        let mut r = Rng::new(23);
        let mut counts = vec![0usize; 64];
        for _ in 0..64_000 {
            counts[t.sample(&mut r)] += 1;
        }
        for &c in &counts {
            assert!((c as i64 - 1000).abs() < 200, "count {c}");
        }
    }

    #[test]
    fn alias_rejects_bad_input() {
        assert!(AliasTable::new(&[]).is_none());
        assert!(AliasTable::new(&[0.0, 0.0]).is_none());
        assert!(AliasTable::new(&[f64::INFINITY]).is_none());
    }

    #[test]
    fn alias_try_new_reports_each_degenerate_case() {
        // The guard regression: these used to either return a bare None
        // (losing the reason) or — for negative/NaN weights — build a
        // silently broken table whose prob_of fed q < 0 downstream.
        let empty = AliasTable::try_new(&[]).unwrap_err().to_string();
        assert!(empty.contains("at least one weight"), "{empty}");
        let neg = AliasTable::try_new(&[1.0, -2.0]).unwrap_err().to_string();
        assert!(neg.contains("weight 1"), "{neg}");
        let nan = AliasTable::try_new(&[f64::NAN, 1.0]).unwrap_err().to_string();
        assert!(nan.contains("weight 0"), "{nan}");
        let zero = AliasTable::try_new(&[0.0, 0.0]).unwrap_err().to_string();
        assert!(zero.contains("total mass"), "{zero}");
        let inf = AliasTable::try_new(&[f64::MAX, f64::MAX]).unwrap_err().to_string();
        assert!(inf.contains("total mass"), "{inf}");
        assert!(AliasTable::new(&[1.0, -2.0]).is_none());
        assert!(AliasTable::try_new(&[3.0, 1.0]).is_ok());
    }

    #[test]
    fn alias_sample_many_equals_per_row_streams() {
        let t = AliasTable::new(&[10.0, 1.0, 5.0, 4.0, 0.5]).unwrap();
        let (step_seed, rows, m) = (0xABCD_u64, 13, 17);
        let mut got = Vec::new();
        t.sample_many(step_seed, rows, m, &mut got);
        assert_eq!(got.len(), rows * m);
        let mut want = Vec::with_capacity(rows * m);
        for i in 0..rows {
            let mut rng = row_rng(step_seed, i);
            for _ in 0..m {
                want.push(t.sample(&mut rng) as u32);
            }
        }
        assert_eq!(got, want);
        // A second fill reuses the buffer and clears the previous draws.
        t.sample_many(step_seed ^ 1, 2, 3, &mut got);
        assert_eq!(got.len(), 6);
    }

    #[test]
    fn zipf_is_skewed_and_normalized() {
        let z = Zipf::new(1000, 1.1);
        let total: f64 = (0..1000).map(|k| z.prob(k)).sum();
        assert!((total - 1.0).abs() < 1e-9);
        assert!(z.prob(0) > 10.0 * z.prob(99), "Zipf should be heavily skewed");
        let mut r = Rng::new(31);
        let mut head = 0usize;
        let trials = 50_000;
        for _ in 0..trials {
            if z.sample(&mut r) < 10 {
                head += 1;
            }
        }
        let expect: f64 = (0..10).map(|k| z.prob(k)).sum::<f64>() * trials as f64;
        assert!((head as f64 - expect).abs() < 6.0 * expect.sqrt());
    }

    #[test]
    fn fork_streams_are_decorrelated() {
        let mut root = Rng::new(99);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
