#!/usr/bin/env python3
"""Line-for-line Python port of the obs subsystem's numeric core, run
against the same pinned vectors and property checks as the Rust tests.

The build container has no rust toolchain, so — as in the earlier port
checks — the algorithmic heart of the change is ported faithfully (same
bit tricks, same guards, same arithmetic order where it matters) and
validated here:

  1. histogram bucketing: IEEE-754 shift bucketing (`struct.pack('<d')`
     reproduces `f64::to_bits`), pinned index vectors, monotonicity
  2. record / record_n / merge: blocked flush equals repeated records;
     merge of two snapshots equals the interleaved stream
  3. quantile readout: rank walk + midpoint representative clamped into
     exact [min, max]; <= 6.25% relative error vs an exact sort (half the
     widest sub-bucket); constant histograms read back exactly
  4. ess_fraction: eq. (2) weight ESS/m — full when q matches p,
     collapsed under a dominant weight, degenerate inputs guarded
  5. tv_from_pairs: plug-in TV-to-exact — exact under a uniform
     proposal, ~0 when the proposal equals softmax(o)
  6. QualityMonitor: Algorithm R reservoir with the splitmix64 ordinal
     coin — bounded, deterministic, statistically close to exact TV

Mirrors rust/src/obs/histogram.rs and rust/src/obs/monitor.rs; a change
to the bucketing constants or the reservoir coin must update both or CI
fails.

Run: python3 python/tools/obs_port_check.py
"""
import bisect
import math
import struct

# ---------------------------------------------------------------- histogram

SUB_BITS = 3
MIN_EXP = -30
MAX_EXP = 14
LO_RAW = (1023 + MIN_EXP) << SUB_BITS
HI_RAW = (1023 + MAX_EXP) << SUB_BITS
BUCKETS = (HI_RAW - LO_RAW) + 2

U64 = (1 << 64) - 1


def to_bits(v):
    return struct.unpack("<Q", struct.pack("<d", v))[0]


def from_bits(b):
    return struct.unpack("<d", struct.pack("<Q", b))[0]


def bucket_of(v):
    if not (v > 0.0):  # non-positive and NaN -> underflow bucket
        return 0
    raw = to_bits(v) >> (52 - SUB_BITS)
    if raw < LO_RAW:
        return 0
    if raw >= HI_RAW:
        return BUCKETS - 1
    return (raw - LO_RAW) + 1


def bucket_lower(i):
    assert 1 <= i <= BUCKETS - 1
    raw = LO_RAW + (i - 1)
    return from_bits(raw << (52 - SUB_BITS))


def representative(i):
    if i == 0:
        return bucket_lower(1)
    if i >= BUCKETS - 1:
        return bucket_lower(BUCKETS - 1)
    return 0.5 * (bucket_lower(i) + bucket_lower(i + 1))


class Histogram:
    """Port of Histogram + HistogramSnapshot (single-threaded: the atomics
    reduce to plain adds; bucket/count/min-bits/max-bits arithmetic is
    integer-exact, so parity with Rust is bitwise)."""

    def __init__(self):
        self.buckets = [0] * BUCKETS
        self.count = 0
        self.sum = 0.0
        self.min_bits = U64
        self.max_bits = 0

    def record(self, v):
        self.record_n(v, 1)

    def record_n(self, v, n):
        if n == 0:
            return
        self.buckets[bucket_of(v)] += n
        self.count += n
        self.sum += v * float(n) if n != 1 else v
        clamped = v if v > 0.0 else 0.0
        bits = to_bits(clamped)
        self.min_bits = min(self.min_bits, bits)
        self.max_bits = max(self.max_bits, bits)

    def merge(self, other):
        for i in range(BUCKETS):
            self.buckets[i] += other.buckets[i]
        self.count += other.count
        self.sum += other.sum
        self.min_bits = min(self.min_bits, other.min_bits)
        self.max_bits = max(self.max_bits, other.max_bits)

    def min(self):
        if self.count == 0 or self.min_bits == U64:
            return 0.0
        return from_bits(self.min_bits)

    def max(self):
        if self.count == 0:
            return 0.0
        return from_bits(self.max_bits)

    def quantile(self, q):
        if self.count == 0:
            return 0.0
        q = min(max(q, 0.0), 1.0)
        rank = max(int(math.ceil(q * self.count)), 1)
        cum = 0
        for i, b in enumerate(self.buckets):
            cum += b
            if cum >= rank:
                r = representative(i)
                return min(max(r, self.min()), self.max())
        return self.max()


def check_bucket_pins():
    assert BUCKETS == 354, BUCKETS
    pins = [
        (1e-9, 1),
        (1e-6, 81),
        (1e-3, 161),
        (0.5, 233),
        (1.0, 241),
        (1.5, 245),
        (3.0, 253),
        (1000.0, 320),
        (20000.0, 353),
        (0.0, 0),
        (-1.0, 0),
        (float("nan"), 0),
    ]
    for v, want in pins:
        got = bucket_of(v)
        assert got == want, f"bucket_of({v}) = {got}, want {want}"
    assert bucket_lower(BUCKETS - 1) == 16384.0
    assert abs(bucket_lower(161) - 0.0009765625) < 1e-18
    # monotone in v across the whole range incl. the clamp buckets
    # (same sequence as histogram.rs::bucket_monotone_in_value)
    rng = Rng(7)
    vals = sorted(2.0 ** (rng.f64() * 50.0 - 32.0) for _ in range(4000))
    for a, b in zip(vals, vals[1:]):
        assert bucket_of(a) <= bucket_of(b), (a, b)
    print("  histogram bucketing (pinned vectors + monotonicity): OK")


def check_record_merge():
    # same sequence as histogram.rs::merge_equals_interleaved
    rng = Rng(11)
    a, b, both = Histogram(), Histogram(), Histogram()
    for i in range(5000):
        v = rng.f64() * 1e3 + 1e-6
        both.record(v)
        (a if i % 2 == 0 else b).record(v)
    a.merge(b)
    assert a.buckets == both.buckets
    assert a.count == both.count
    assert a.min_bits == both.min_bits and a.max_bits == both.max_bits
    assert abs(a.sum - both.sum) <= 1e-9 * abs(both.sum)
    # blocked flush: record_n(v, k) == k repeated records, bitwise on the
    # integer cells
    h1, hk = Histogram(), Histogram()
    for v, k in [(0.125, 7), (3.5, 1), (1e-7, 900), (42.0, 3)]:
        for _ in range(k):
            h1.record(v)
        hk.record_n(v, k)
    assert h1.buckets == hk.buckets and h1.count == hk.count
    assert h1.min_bits == hk.min_bits and h1.max_bits == hk.max_bits
    assert abs(h1.sum - hk.sum) <= 1e-9 * abs(h1.sum)
    print("  record / record_n / merge (blocked flush == repeated records): OK")


def check_quantiles():
    # same sequence (and therefore same worst case) as
    # histogram.rs::quantile_error_bounded_vs_exact_sort
    rng = Rng(23)
    for trial in range(20):
        h = Histogram()
        n = 200 + (trial * 37) % 800
        vals = [2.0 ** (rng.f64() * 24.0 - 18.0) for _ in range(n)]
        for v in vals:
            h.record(v)
        vals.sort()
        for q in (0.0, 0.25, 0.5, 0.9, 0.95, 0.99, 1.0):
            rank = max(int(math.ceil(q * n)), 1)
            exact = vals[rank - 1]
            got = h.quantile(q)
            rel = abs(got - exact) / exact
            # worst-case midpoint error: half the 12.5%-wide bottom
            # sub-bucket of a binade = 6.25% (same bound as the Rust test)
            assert rel <= 0.0625, f"trial {trial} q {q}: {got} vs {exact} ({rel})"
    h = Histogram()
    for _ in range(100):
        h.record(0.125)
    assert h.quantile(0.5) == 0.125 and h.quantile(0.99) == 0.125
    assert h.min() == 0.125 and h.max() == 0.125
    empty = Histogram()
    assert empty.quantile(0.5) == 0.0 and empty.min() == 0.0 and empty.max() == 0.0
    print("  quantile readout (<=6.25% vs exact sort, constants exact): OK")


# ----------------------------------------------------------------- monitors


def ess_fraction(scored):
    m = len(scored)
    if m == 0:
        return None
    adj = [
        o - math.log(m * q)
        for (o, q) in scored
        if q > 0.0 and math.isfinite(q) and math.isfinite(o)
    ]
    if not adj:
        return None
    max_a = max(adj)
    e = [math.exp(a - max_a) for a in adj]
    z = sum(e)
    if not (z > 0.0 and math.isfinite(z)):
        return None
    sum_sq = sum((u / z) * (u / z) for u in e)
    return 1.0 / sum_sq / len(e)


def tv_from_pairs(pairs):
    valid = [
        (o, q)
        for (o, q) in pairs
        if q > 0.0 and math.isfinite(q) and math.isfinite(o)
    ]
    if not valid:
        return None
    max_o = max(o for (o, _) in valid)
    weights = [math.exp(o - max_o) / q for (o, q) in valid]
    zhat = sum(weights) / len(weights)
    if not (zhat > 0.0 and math.isfinite(zhat)):
        return None
    dev = sum(abs(w / zhat - 1.0) for w in weights)
    return 0.5 * dev / len(weights)


def splitmix64(state):
    state = (state + 0x9E3779B97F4A7C15) & U64
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & U64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & U64
    return state, (z ^ (z >> 31))


def _rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & U64


class Rng:
    """Port of util::rng::Rng (xoshiro256** seeded via splitmix64) so the
    property checks replay the *same* pseudo-random sequences as the Rust
    unit tests — bit-for-bit, since f64() is exact in binary64."""

    def __init__(self, seed):
        s = []
        for _ in range(4):
            seed, out = splitmix64(seed)
            s.append(out)
        self.s = s

    def next_u64(self):
        s = self.s
        result = (_rotl((s[1] * 5) & U64, 7) * 9) & U64
        t = (s[1] << 17) & U64
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return result

    def f64(self):
        return (self.next_u64() >> 11) * (1.0 / float(1 << 53))


class QualityMonitor:
    """Port of the Algorithm R reservoir with the splitmix64 ordinal coin
    (deterministic given the ingestion sequence — same contract as Rust)."""

    def __init__(self, cap):
        self.cap = max(cap, 1)
        self.seen_pairs = 0
        self.reservoir = []

    def observe(self, scored):
        for (o, q) in scored:
            if not (q > 0.0 and math.isfinite(q) and math.isfinite(o)):
                continue
            self.seen_pairs += 1
            if len(self.reservoir) < self.cap:
                self.reservoir.append((o, q))
            else:
                _, coin = splitmix64(self.seen_pairs)
                j = coin % self.seen_pairs
                if j < len(self.reservoir):
                    self.reservoir[j] = (o, q)

    def tv_estimate(self):
        return tv_from_pairs(self.reservoir)


def softmax(o):
    m = max(o)
    e = [math.exp(x - m) for x in o]
    z = sum(e)
    return [x / z for x in e]


def tv_distance(p, q):
    return 0.5 * sum(abs(a - b) for a, b in zip(p, q))


def check_ess():
    m = 16
    tri = m * (m + 1) / 2
    scored = [(math.log(m * ((i + 1) / tri)), (i + 1) / tri) for i in range(m)]
    f = ess_fraction(scored)
    assert abs(f - 1.0) < 1e-12, f
    m = 32
    scored = [(0.0, 1.0 / m)] * m
    scored[0] = (50.0, 1.0 / m)
    f = ess_fraction(scored)
    assert f < 1.5 / m, f
    assert ess_fraction([]) is None
    assert ess_fraction([(1.0, 0.0), (float("nan"), 0.5)]) is None
    f = ess_fraction([(0.0, 0.5), (0.0, 0.0)])
    assert abs(f - 1.0) < 1e-12, f
    print("  ess_fraction (full at q==p, collapse, guards): OK")


def check_tv():
    o = [1.0, -0.5, 2.0, 0.0, -1.5, 0.25]
    n = len(o)
    pairs = [(oi, 1.0 / n) for oi in o]
    got = tv_from_pairs(pairs)
    exact = tv_distance(softmax(o), [1.0 / n] * n)
    assert abs(got - exact) < 1e-12, (got, exact)
    o = [1.0, -0.5, 2.0, 0.0]
    p = softmax(o)
    assert tv_from_pairs(list(zip(o, p))) < 1e-12
    assert tv_from_pairs([]) is None
    assert tv_from_pairs([(1.0, 0.0)]) is None
    print("  tv_from_pairs (exact under uniform q, ~0 at q==p): OK")


def check_reservoir():
    a, b = QualityMonitor(8), QualityMonitor(8)
    for i in range(1000):
        pair = [(i * 0.01, 1.0 / (1.0 + i))]
        a.observe(pair)
        b.observe(pair)
    assert len(a.reservoir) == 8
    assert a.seen_pairs == 1000
    assert a.reservoir == b.reservoir
    # statistical: classes drawn from q, reservoir TV tracks exact TV(p, q)
    # (same sequence as monitor.rs::reservoir_statistical_tv_close_to_exact)
    n = 64
    rng = Rng(42)
    o = [rng.f64() * 3.0 - 1.5 for _ in range(n)]
    q = [rng.f64() + 0.05 for _ in range(n)]
    zq = sum(q)
    q = [x / zq for x in q]
    cum, acc = [], 0.0
    for x in q:
        acc += x
        cum.append(acc)
    mon = QualityMonitor(4096)
    for _ in range(20000):
        u = rng.f64() * acc
        c = min(bisect.bisect_left(cum, u), n - 1)
        mon.observe([(o[c], q[c])])
    est = mon.tv_estimate()
    exact = tv_distance(softmax(o), q)
    assert abs(est - exact) < 0.05 + 0.15 * exact, (est, exact)
    print("  QualityMonitor reservoir (bounded, deterministic, TV tracks exact): OK")


if __name__ == "__main__":
    print("obs port checks:")
    check_bucket_pins()
    check_record_merge()
    check_quantiles()
    check_ess()
    check_tv()
    check_reservoir()
    print("all obs port checks passed")
