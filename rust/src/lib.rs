//! # kernel-sampled-softmax (`kss`)
//!
//! A production-style reproduction of **"Adaptive Sampled Softmax with Kernel
//! Based Sampling" (Blanc & Rendle, ICML 2018)** as a three-layer system:
//!
//! * **L3 (this crate)** — the paper's system contribution: kernel based
//!   negative sampling with a divide-and-conquer tree over per-subset feature
//!   summaries `z(C) = Σ φ(w_j)` (O(D log n) draws and updates), every
//!   baseline sampler from the paper's evaluation, and the training
//!   coordinator that drives AOT-compiled XLA train steps through PJRT.
//! * **L2 (JAX, build time)** — the LSTM language model and retrieval MLP
//!   whose sampled-softmax train/eval steps are lowered to HLO text by
//!   `python/compile/aot.py`.
//! * **L1 (Pallas, build time)** — the fused sampled-softmax loss/gradient
//!   kernel called by L2 (`python/compile/kernels/sampled_softmax.py`).
//!
//! Python never runs on the training path: `make artifacts` lowers the
//! compute graphs once, and the rust binary loads and executes them.
//!
//! Module layout:
//!
//! * [`util`] — in-tree substrates (PRNG, JSON, CLI, threadpool, stats,
//!   property-test harness); the offline build has no external crates for
//!   these.
//! * [`ops`] — the vectorized compute core: blocked/unrolled `dot`
//!   families, panel `dot_many`, `axpy`, prefix sums and the max-shift+exp
//!   row primitive, each with a scalar reference implementation
//!   (`--features ops-scalar` selects it at build time). Every hot inner
//!   loop in the sampler, serve, hsm, runtime and util layers calls here.
//! * [`sampler`] — the `Sampler` trait, the paper's kernel samplers
//!   (quadratic/quartic; flat and tree-based) and the baselines (uniform,
//!   unigram, bigram, exact softmax).
//! * [`data`] — synthetic Penn-Tree-Bank-style corpus and YouTube-style
//!   next-watch generators (substitutes for the paper's private datasets;
//!   see DESIGN.md §3).
//! * [`runtime`] — PJRT engine: artifact manifest, executables, literals,
//!   parameter store.
//! * [`coordinator`] — training loop + the stage-overlapped pipeline
//!   engine (sample/step/publish overlap over serve-layer snapshots),
//!   metrics, experiment grid runner, config system.
//! * [`serve`] — online serving: snapshot-isolated concurrent sampling
//!   (epoch snapshots + double-buffered publishing), sharded trees behind
//!   a mass router, request micro-batching, and top-k beam retrieval; the
//!   `kss serve` subcommand's load generator lives here too.
//! * [`obs`] — unified telemetry: the global-free atomic metrics
//!   registry (counters / gauges / log-bucketed histograms), RAII phase
//!   spans wired through the pipeline/serve/sampler hot layers, online
//!   sampler-quality monitors (streaming TV-to-exact, eq. (2) ESS), and
//!   the JSONL + Prometheus-text export paths.
//! * [`vocab`] — streaming vocabulary: LSM-style two-tier sampler
//!   (memtable + arena + tombstones behind a mass router) for online class
//!   insertion/retirement with exact composite q, plus the compactor that
//!   folds the memtable into a fresh arena generation.
//! * [`hsm`] — hierarchical softmax baseline (related-work comparison).
//! * [`bench_harness`] — timing/stats harness used by `benches/` (criterion
//!   is unavailable offline); emits machine-readable `BENCH_*.json` next to
//!   the printed tables.

// Also denied workspace-wide via [workspace.lints]; the crate attribute
// keeps the guarantee under direct `rustc` invocations too.
#![deny(unsafe_op_in_unsafe_fn)]

pub mod bench_harness;
pub mod coordinator;
pub mod data;
pub mod hsm;
pub mod obs;
pub mod ops;
pub mod runtime;
pub mod sampler;
pub mod serve;
pub mod util;
pub mod vocab;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
