// pallas-lint fixture — must NOT trip UNSAFE.

pub fn reinterpret(data: &[f32]) -> &[u8] {
    // SAFETY: the pointer is valid for data.len() * 4 bytes (f32 is 4
    // bytes, no padding), u8 is align-1 and any bit pattern is valid; the
    // returned borrow is tied to `data`'s lifetime.
    unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) }
}

#[cfg(test)]
mod tests {
    /// Test-only unsafe is exempt (the audit binds shipping code).
    #[test]
    fn test_unsafe_is_exempt() {
        let x = [1.0f32];
        let b = unsafe { std::slice::from_raw_parts(x.as_ptr() as *const u8, 4) };
        assert_eq!(b.len(), 4);
    }
}
