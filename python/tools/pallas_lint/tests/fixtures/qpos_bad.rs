// pallas-lint fixture — MUST trip QPOS (unguarded division by a mass).
// Scanned by the self-tests under a rust/src/sampler/ logical path.

pub fn leaf_prob(k: f64, total: f64) -> f64 {
    k / total
}

pub struct Node {
    pub mass: f64,
}

pub fn branch_ratio(child: &Node, parent_mass: f64) -> f64 {
    child.mass / parent_mass
}

/// A plain rebind is NOT the guard-4 mint: the name never went through
/// `positive_pool_mass`, so the division must still be flagged.
pub fn pooled_unguarded(w: f64, cum_total: f64) -> f64 {
    let pool_mass = cum_total;
    w / pool_mass
}

/// The midx refine denominator without the mint: a raw prefix-sum total
/// can underflow to zero, so the within-cluster division must be flagged.
pub fn refine_unguarded(w: f64, wcum: &[f64]) -> f64 {
    let cluster_mass = wcum[wcum.len() - 1];
    w / cluster_mass
}
