#!/usr/bin/env python3
"""Line-for-line Python port of the ops-layer blocked primitives
(rust/src/ops/mod.rs), run against the same property checks as the Rust
tests (the build container has no rust toolchain — see
.claude/skills/verify/SKILL.md; serve_port_check.py / rff_port_check.py
are the PR-2/PR-3 precedents).

Ported and checked here:

  1. blocked dot (4-lane f64), dot32 (8-lane f32), dot_f32 / dot_mixed
     (4-lane, f64 accumulation): remainder-lane correctness against the
     scalar sequential reference for every len % block in {0..block-1}
  2. dot2_32 (fused sibling-panel dot): BITWISE equal to two single dot32
     calls — the tree memo caches per-node values, so the fused and single
     descent paths must be indistinguishable
  3. dot_many / dot_many_f32 (fused two-rows-per-pass panel sweep):
     bitwise equal to row-at-a-time dots for every (d, rows) shape
  4. fill_cum: strictly sequential prefix sums (each partial bitwise equal
     to the sequential fold — the CDF draw observes every partial)
  5. row_max: blocked lane max == sequential fold exactly (max is
     associative; NaNs ignored per f64::max), max_shift_exp normalizes
  6. the HSM cluster-blocked panel restructure (hsm/mod.rs): the
     panel_lo/row_of_class permutation is a bijection and panel-swept
     logits equal the old per-member strided gather bitwise
  7. tree-descent integration: fused-pair node masses == single-node
     masses bitwise on a synthetic z32 arena (float32 throughout)
  8. q-tolerance regression (the bugfix-audit satellite): switching the
     quadratic kernel's dot from sequential to blocked accumulation moves
     q by < 1e-9 relative at n = 10^4 classes — the Rust tests' closed-form
     tolerance cannot be violated by the ops migration

Run: python3 python/tools/ops_port_check.py
"""
import math
import os
import random
import sys

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

F32 = np.float32


# --- ports of rust/src/ops/mod.rs ----------------------------------------
def ref_dot(a, b):
    """reference::dot — sequential f64 fold."""
    acc = 0.0
    for x, y in zip(a, b):
        acc += float(x) * float(y)
    return acc


def blk_dot(a, b):
    """blocked::dot — 4 lanes, pairwise combine, sequential remainder."""
    n4 = len(a) // 4 * 4
    s = [0.0, 0.0, 0.0, 0.0]
    i = 0
    while i < n4:
        for k in range(4):
            s[k] += float(a[i + k]) * float(b[i + k])
        i += 4
    acc = (s[0] + s[1]) + (s[2] + s[3])
    for j in range(n4, len(a)):
        acc += float(a[j]) * float(b[j])
    return acc


def ref_dot32(a, b):
    """reference::dot32 — sequential f32 fold (a, b float32 arrays)."""
    acc = F32(0.0)
    for x, y in zip(a, b):
        acc = F32(acc + F32(x * y))
    return acc


def blk_dot32(a, b):
    """blocked::dot32 — 8 f32 lanes, left-fold lane combine, remainder."""
    acc = [F32(0.0)] * 8
    chunks = len(a) // 8
    for c in range(chunks):
        base = c * 8
        for k in range(8):
            acc[k] = F32(acc[k] + F32(a[base + k] * b[base + k]))
    total = F32(0.0)
    for k in range(8):  # acc.iter().sum::<f32>() is a left fold
        total = F32(total + acc[k])
    for j in range(chunks * 8, len(a)):
        total = F32(total + F32(a[j] * b[j]))
    return total


def blk_dot2_32(q, rows):
    """blocked::dot2_32 — fused two-row panel dot, per-row order == dot32."""
    n = len(q)
    l, r = rows[:n], rows[n:]
    al = [F32(0.0)] * 8
    ar = [F32(0.0)] * 8
    chunks = n // 8
    for c in range(chunks):
        base = c * 8
        for k in range(8):
            al[k] = F32(al[k] + F32(q[base + k] * l[base + k]))
            ar[k] = F32(ar[k] + F32(q[base + k] * r[base + k]))
    tl = F32(0.0)
    tr = F32(0.0)
    for k in range(8):
        tl = F32(tl + al[k])
        tr = F32(tr + ar[k])
    for j in range(chunks * 8, n):
        tl = F32(tl + F32(q[j] * l[j]))
        tr = F32(tr + F32(q[j] * r[j]))
    return tl, tr


def blk_dot_f32(a, b):
    """blocked::dot_f32 — f32 inputs, 4-lane f64 accumulation."""
    n4 = len(a) // 4 * 4
    s = [0.0, 0.0, 0.0, 0.0]
    i = 0
    while i < n4:
        for k in range(4):
            s[k] += float(a[i + k]) * float(b[i + k])
        i += 4
    acc = (s[0] + s[1]) + (s[2] + s[3])
    for j in range(n4, len(a)):
        acc += float(a[j]) * float(b[j])
    return acc


def blk_dot2_f32(q, a, b):
    """blocked::dot2_f32 — fused pair, per-row order == dot_f32."""
    n4 = len(q) // 4 * 4
    sa = [0.0] * 4
    sb = [0.0] * 4
    i = 0
    while i < n4:
        for k in range(4):
            sa[k] += float(q[i + k]) * float(a[i + k])
            sb[k] += float(q[i + k]) * float(b[i + k])
        i += 4
    ta = (sa[0] + sa[1]) + (sa[2] + sa[3])
    tb = (sb[0] + sb[1]) + (sb[2] + sb[3])
    for j in range(n4, len(q)):
        ta += float(q[j]) * float(a[j])
        tb += float(q[j]) * float(b[j])
    return ta, tb


def blk_dot_many_f32(q, panel, rows):
    """blocked::dot_many_f32 — two rows per pass, odd tail row single."""
    d = len(q)
    out = [0.0] * rows
    pairs = rows // 2
    for p in range(pairs):
        base = 2 * p * d
        x, y = blk_dot2_f32(q, panel[base : base + d], panel[base + d : base + 2 * d])
        out[2 * p] = x
        out[2 * p + 1] = y
    if rows % 2 == 1:
        i = rows - 1
        out[i] = blk_dot_f32(q, panel[i * d : (i + 1) * d])
    return out


def fill_cum(weights):
    """ops::fill_cum — strictly sequential f64 prefix over f32 weights."""
    cum = []
    acc = 0.0
    for w in weights:
        acc += float(w)
        cum.append(acc)
    return cum, acc


def row_max_ref(xs):
    """reference::row_max — sequential f64::max fold (NaN-ignoring)."""
    m = -math.inf
    for x in xs:
        m = float(np.fmax(m, float(x)))
    return m


def row_max_blk(xs):
    """blocked::row_max — 8 lanes of f64::max, lane fold, remainder."""
    lanes = [-math.inf] * 8
    chunks = len(xs) // 8
    for c in range(chunks):
        base = c * 8
        for k in range(8):
            lanes[k] = float(np.fmax(lanes[k], float(xs[base + k])))
    m = -math.inf
    for k in range(8):
        m = float(np.fmax(m, lanes[k]))
    for x in xs[chunks * 8 :]:
        m = float(np.fmax(m, float(x)))
    return m


def max_shift_exp(xs):
    """ops::max_shift_exp — out[i] = exp(x − max); returns (max, 4-lane Σ)."""
    mx = -math.inf
    for x in xs:
        mx = float(np.fmax(mx, x))
    out = [math.exp(x - mx) for x in xs]
    n4 = len(out) // 4 * 4
    s = [0.0] * 4
    i = 0
    while i < n4:
        for k in range(4):
            s[k] += out[i + k]
        i += 4
    z = (s[0] + s[1]) + (s[2] + s[3])
    for j in range(n4, len(out)):
        z += out[j]
    return mx, out, z


# every remainder lane for both block sizes, plus empty and singletons
LENS = list(range(0, 18)) + [24, 31, 32, 33, 63, 64, 65, 100]


# --- 1: remainder-lane correctness ----------------------------------------
def check_remainder_lanes():
    npr = np.random.default_rng(5)
    for n in LENS:
        a = npr.normal(0, 1, n)
        b = npr.normal(0, 1, n)
        got, want = blk_dot(a, b), ref_dot(a, b)
        assert abs(got - want) <= 1e-12 * max(abs(want), 1.0), (n, got, want)
        a32 = npr.normal(0, 1, n).astype(F32)
        b32 = npr.normal(0, 1, n).astype(F32)
        g32, w32 = blk_dot32(a32, b32), ref_dot32(a32, b32)
        assert abs(float(g32) - float(w32)) <= 1e-4 * max(abs(float(w32)), 1.0), (n, g32, w32)
        gf = blk_dot_f32(a32, b32)
        wf = ref_dot(a32, b32)
        assert abs(gf - wf) <= 1e-12 * max(abs(wf), 1.0), (n, gf, wf)
    print("  blocked dot/dot32/dot_f32 == scalar reference on every remainder lane: OK")


# --- 2: fused pair is bitwise two singles ----------------------------------
def check_fused_pair_bitwise():
    npr = np.random.default_rng(7)
    for n in LENS:
        q = npr.normal(0, 1, n).astype(F32)
        rows = npr.normal(0, 1, 2 * n).astype(F32)
        tl, tr = blk_dot2_32(q, rows)
        sl = blk_dot32(q, rows[:n])
        sr = blk_dot32(q, rows[n:])
        assert tl.tobytes() == sl.tobytes(), (n, tl, sl)
        assert tr.tobytes() == sr.tobytes(), (n, tr, sr)
    print("  dot2_32 fused pair == two single dot32 calls, bitwise: OK")


# --- 3: panel sweep is bitwise row-at-a-time -------------------------------
def check_dot_many_bitwise():
    npr = np.random.default_rng(9)
    for d in (1, 3, 4, 7, 8, 16, 65):
        for rows in (0, 1, 2, 3, 5, 8):
            q = npr.normal(0, 1, d).astype(F32)
            panel = npr.normal(0, 1, d * rows).astype(F32)
            out = blk_dot_many_f32(q, panel, rows)
            for i in range(rows):
                want = blk_dot_f32(q, panel[i * d : (i + 1) * d])
                assert out[i] == want, (d, rows, i, out[i], want)
    print("  dot_many_f32 panel sweep == per-row dot_f32, bitwise: OK")


# --- 4: prefix sums are sequential -----------------------------------------
def check_fill_cum_sequential():
    npr = np.random.default_rng(11)
    for n in LENS:
        w = npr.random(n).astype(F32)
        cum, total = fill_cum(w)
        acc = 0.0
        for i in range(n):
            acc += float(w[i])
            assert cum[i] == acc, (n, i)
        assert total == acc
    print("  fill_cum prefix sums strictly sequential: OK")


# --- 5: row max + max-shift-exp --------------------------------------------
def check_row_max_and_softmax():
    npr = np.random.default_rng(13)
    for n in LENS:
        xs = npr.normal(0, 2, n).astype(F32)
        assert row_max_blk(xs) == row_max_ref(xs), n
    assert row_max_blk(np.array([], dtype=F32)) == -math.inf
    assert row_max_blk(np.array([math.nan, 2.0, 1.0], dtype=F32)) == 2.0
    # max_shift_exp: overflow-proof and normalizing
    mx, out, z = max_shift_exp([700.0, 710.0, 5.0, -3000.0])
    assert mx == 710.0 and all(math.isfinite(e) for e in out) and out[1] == 1.0
    assert abs(sum(e / z for e in out) - 1.0) < 1e-12
    print("  row_max blocked == sequential (NaN-ignoring); max_shift_exp safe: OK")


# --- 6: HSM cluster-blocked panel ------------------------------------------
def frequency_binning(counts, n_clusters):
    """Port of hsm/mod.rs::frequency_binning."""
    n = len(counts)
    n_clusters = max(1, min(n_clusters, n))
    order = sorted(range(n), key=lambda c: (-counts[c], c))
    # rust sort_by_key(Reverse(count)) is stable: ties keep index order
    total = sum(counts) + n
    per_bin = total / n_clusters
    assign = [0] * n
    members = [[] for _ in range(n_clusters)]
    acc = 0.0
    bin_ = 0
    for cls in order:
        if acc >= per_bin * (bin_ + 1) and bin_ + 1 < n_clusters:
            bin_ += 1
        assign[cls] = bin_
        members[bin_].append(cls)
        acc += counts[cls] + 1
    for b in range(n_clusters):
        if not members[b]:
            donor = max(range(n_clusters), key=lambda i: len(members[i]))
            cls = members[donor].pop()
            assign[cls] = b
            members[b].append(cls)
    return assign, members


def check_hsm_panel():
    rng = random.Random(17)
    for case in range(20):
        n = rng.randint(3, 80)
        d = rng.randint(1, 9)
        counts = [rng.randint(0, 50) for _ in range(n)]
        assign, members = frequency_binning(counts, rng.randint(1, 12))
        # the panel construction of HsmHead::new
        panel_lo, row_of_class, row = [], [0] * n, 0
        for m in members:
            panel_lo.append(row)
            for cls in m:
                row_of_class[cls] = row
                row += 1
        panel_lo.append(row)
        assert row == n
        # bijection: every class owns exactly one row inside its cluster
        seen = [False] * n
        for c, m in enumerate(members):
            lo, hi = panel_lo[c], panel_lo[c + 1]
            assert hi - lo == len(m)
            for cls in m:
                r = row_of_class[cls]
                assert lo <= r < hi and not seen[r]
                seen[r] = True
        assert all(seen)
        # panel-swept logits == the old per-member strided gather, bitwise:
        # class_w rows laid out in panel order, gather indexes via class id
        npr = np.random.default_rng(case)
        class_w_panel = npr.normal(0, 0.1, (n, d)).astype(F32)  # panel order
        h = npr.normal(0, 1, d).astype(F32)
        for c, m in enumerate(members):
            lo, hi = panel_lo[c], panel_lo[c + 1]
            flat = class_w_panel[lo:hi].reshape(-1)
            swept = blk_dot_many_f32(h, flat, hi - lo)
            for j, cls in enumerate(m):
                gathered = blk_dot_f32(h, class_w_panel[row_of_class[cls]])
                assert swept[j] == gathered, (case, c, j)
    print("  hsm cluster-blocked panel: bijection + swept == gathered logits: OK")


# --- 7: tree descent with fused pair masses --------------------------------
def check_descent_pair_integration():
    """node_mass vs node_mass_pair on a synthetic adjacent-sibling arena:
    values and memo contents must be identical whichever path ran first."""
    npr = np.random.default_rng(23)
    dim, nodes = 37, 30  # odd dim exercises both remainders
    z32 = npr.normal(0, 1, nodes * dim).astype(F32)
    phi32 = npr.normal(0, 1, dim).astype(F32)

    def single(left):
        return (
            blk_dot32(phi32, z32[left * dim : (left + 1) * dim]),
            blk_dot32(phi32, z32[(left + 1) * dim : (left + 2) * dim]),
        )

    for left in range(0, nodes - 1, 2):
        fused = blk_dot2_32(phi32, z32[left * dim : (left + 2) * dim])
        sl, sr = single(left)
        assert fused[0].tobytes() == sl.tobytes(), left
        assert fused[1].tobytes() == sr.tobytes(), left
    print("  descent fused-pair node masses == single-node masses, bitwise: OK")


# --- 8: q tolerance under the accumulation-order change --------------------
def check_q_tolerance_regression(n=10_000, d=8, draws_checked=200):
    """The tree reports q = K(h,w)/Σ_j K(h,w_j). The ops migration changed
    the kernel's inner dot from a sequential fold to the 4-lane blocked
    order; this pins that the induced relative change in q stays far
    below the Rust tests' 1e-9 closed-form tolerance at catalog scale."""
    npr = np.random.default_rng(29)
    emb = npr.normal(0, 0.4, (n, d)).astype(F32)
    h = npr.normal(0, 1, d).astype(F32)
    alpha = 100.0

    def kernel(dot_fn, w):
        o = dot_fn(h, w)
        return alpha * o * o + 1.0

    # partition functions under both accumulation orders
    z_seq = 0.0
    z_blk = 0.0
    for j in range(n):
        z_seq += kernel(ref_dot, emb[j])
        z_blk += kernel(blk_dot_f32, emb[j])
    worst = 0.0
    for j in range(0, n, max(1, n // draws_checked)):
        q_seq = kernel(ref_dot, emb[j]) / z_seq
        q_blk = kernel(blk_dot_f32, emb[j]) / z_blk
        worst = max(worst, abs(q_blk - q_seq) / max(q_seq, 1e-300))
    assert worst < 1e-9, f"q moved by {worst:.2e} relative"
    print(f"  q drift under blocked accumulation at n={n}: {worst:.2e} rel (< 1e-9): OK")


if __name__ == "__main__":
    print("ops-layer port checks:")
    check_remainder_lanes()
    check_fused_pair_bitwise()
    check_dot_many_bitwise()
    check_fill_cum_sequential()
    check_row_max_and_softmax()
    check_hsm_panel()
    check_descent_pair_integration()
    check_q_tolerance_regression()
    print("all ops-layer port checks passed")
