//! Leveled stderr logger for the coordinator (no `log`/`env_logger` facade
//! needed for a single-binary system; level comes from `KSS_LOG`).

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};

/// Log verbosity, ordered.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(2); // Info

/// Initialize the level from the `KSS_LOG` environment variable
/// (`error|warn|info|debug`). Called once from `main`.
pub fn init_from_env() {
    if let Ok(v) = std::env::var("KSS_LOG") {
        set_level(match v.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "debug" => Level::Debug,
            _ => Level::Info,
        });
    }
}

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    level as u8 <= LEVEL.load(Ordering::Relaxed)
}

/// Core log write; prefer the macros.
pub fn log(level: Level, args: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let tag = match level {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
    };
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "[{tag}] {args}");
}

#[macro_export]
macro_rules! info {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($t)*)) };
}

#[macro_export]
macro_rules! warn_ {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($t)*)) };
}

#[macro_export]
macro_rules! debug_ {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($t)*)) };
}

#[macro_export]
macro_rules! error {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, format_args!($($t)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering_gates() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        set_level(Level::Info); // restore default for other tests
    }
}
