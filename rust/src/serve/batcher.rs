//! Request micro-batching: coalesce concurrent single-row sampling
//! requests into batched draws under a latency deadline.
//!
//! # Deadline contract
//!
//! A batch closes either because it reached `max_batch` rows or because
//! its oldest row has waited `max_wait` — so `max_wait` bounds how long an
//! *idle* worker lets a partial batch age before dispatching it. It is NOT
//! an end-to-end queueing bound: when every worker is busy executing,
//! requests wait until one returns to `next_batch`, however long that
//! takes. The end-to-end budget is the service's concern — it reports
//! per-request queued time and enforces `request_timeout` as the liveness
//! backstop, and load generators count misses against their own budget.
//!
//! The queue is bounded (`queue_cap`): past it, [`MicroBatcher::submit`]
//! fails fast with [`ServeError::Overloaded`] instead of letting latency
//! grow without bound — load shedding is the serving-layer tradition.
//!
//! # Determinism
//!
//! Batching only *groups* work; it never changes results. Each request is
//! stamped with an arrival sequence number, and workers draw request `seq`
//! from the stream `row_rng(service_seed, seq)` — the batch API's per-row
//! stream discipline from PR 1 — so a request's samples depend on its
//! arrival index alone, not on how the batcher happened to coalesce it.

use crate::obs::{Counter, Gauge, Histogram, MetricsRegistry};
use crate::sampler::Sample;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, PoisonError};
use std::time::{Duration, Instant};

/// Micro-batcher tuning.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Close a batch at this many rows.
    pub max_batch: usize,
    /// ... or when the oldest queued row has waited this long.
    pub max_wait: Duration,
    /// Reject submissions beyond this many queued rows.
    pub queue_cap: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 32,
            max_wait: Duration::from_millis(2),
            queue_cap: 4096,
        }
    }
}

/// Serving-path errors.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// The bounded queue is full — shed load and retry later.
    Overloaded,
    /// The service is shutting down.
    ShuttingDown,
    /// Malformed request (`got` vs `want` query-embedding length). Rejected
    /// at submit so a bad client cannot panic a worker and wedge the pool.
    BadRequest { got: usize, want: usize },
    /// Requested sample count is 0 or exceeds the service cap (also
    /// rejected at submit: a pathological `m` must not abort a worker's
    /// allocation).
    BadSampleCount { got: usize, max: usize },
    /// No response within the service's request timeout — the liveness
    /// backstop for a wedged/dead worker pool (blocking callers must never
    /// hang forever).
    Timeout,
    /// A worker panicked while holding the queue lock. Request paths
    /// surface this instead of propagating the panic into every caller;
    /// the pool drains and shuts down (a poisoned queue is not recoverable
    /// mid-flight, but shedding beats cascading aborts).
    Poisoned,
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Overloaded => write!(f, "serve queue full (overloaded)"),
            ServeError::ShuttingDown => write!(f, "service shutting down"),
            ServeError::BadRequest { got, want } => {
                write!(f, "bad request: h has {got} floats, the index expects {want}")
            }
            ServeError::BadSampleCount { got, max } => {
                write!(f, "bad request: m = {got} (must be 1..={max})")
            }
            ServeError::Timeout => write!(f, "no response within the request timeout"),
            ServeError::Poisoned => write!(f, "serve queue poisoned by a worker panic"),
        }
    }
}

impl std::error::Error for ServeError {}

/// One queued sampling request.
pub struct Request {
    /// Query embedding (owned: the caller moves on immediately).
    pub h: Vec<f32>,
    /// Number of negatives to draw.
    pub m: usize,
    /// Arrival sequence number — the request's RNG-stream identity.
    pub seq: u64,
    /// When the request entered the queue.
    pub enqueued: Instant,
    /// Where the worker sends the response.
    pub tx: mpsc::Sender<SampleResponse>,
}

/// What the worker sends back.
#[derive(Clone, Debug)]
pub struct SampleResponse {
    pub sample: Sample,
    /// Snapshot generations the draw used (one per shard it touched is
    /// overkill; the minimum generation across shards is what freshness
    /// SLAs care about).
    pub generation: u64,
    /// Time spent queued before a worker picked the batch up.
    pub queued: Duration,
    /// Rows in the batch this request rode in (observability).
    pub batch_rows: usize,
}

/// Shared telemetry cells for one batcher (all lock-free writes on paths
/// that already hold, or just released, the queue lock — the accounting
/// adds no new synchronization). Bind to a registry via
/// [`BatcherObs::register_into`].
#[derive(Clone, Default)]
pub struct BatcherObs {
    /// Requests accepted into the queue.
    submitted: Arc<Counter>,
    /// Requests rejected with [`ServeError::Overloaded`].
    shed: Arc<Counter>,
    /// Batches dispatched because the oldest row aged past `max_wait`
    /// (as opposed to filling to `max_batch` or draining at shutdown).
    deadline_hits: Arc<Counter>,
    /// Rows per dispatched batch (the coalescing payoff distribution).
    coalesce_rows: Arc<Histogram>,
    /// High-watermark of the queue depth at admission.
    queue_depth_max: Arc<Gauge>,
}

impl BatcherObs {
    /// Bind every cell to `reg` under the stable `kss_batcher_*` names.
    pub fn register_into(&self, reg: &MetricsRegistry) {
        reg.register_counter(
            "kss_batcher_submitted_total",
            "requests",
            "serve",
            "requests admitted to the coalescing queue",
            Arc::clone(&self.submitted),
        );
        reg.register_counter(
            "kss_batcher_shed_total",
            "requests",
            "serve",
            "requests rejected at admission (queue at capacity)",
            Arc::clone(&self.shed),
        );
        reg.register_counter(
            "kss_batcher_deadline_dispatch_total",
            "batches",
            "serve",
            "partial batches dispatched by the max_wait deadline",
            Arc::clone(&self.deadline_hits),
        );
        reg.register_histogram(
            "kss_batcher_coalesce_rows",
            "rows",
            "serve",
            "rows coalesced per dispatched batch",
            Arc::clone(&self.coalesce_rows),
        );
        reg.register_gauge(
            "kss_batcher_queue_depth_max",
            "requests",
            "serve",
            "queue-depth high-watermark at admission",
            Arc::clone(&self.queue_depth_max),
        );
    }

    pub fn submitted_total(&self) -> u64 {
        self.submitted.get()
    }

    pub fn shed_total(&self) -> u64 {
        self.shed.get()
    }

    pub fn deadline_dispatch_total(&self) -> u64 {
        self.deadline_hits.get()
    }

    /// Batches dispatched so far (= coalesce-histogram count).
    pub fn batches_dispatched(&self) -> u64 {
        self.coalesce_rows.count()
    }

    pub fn queue_depth_max(&self) -> f64 {
        self.queue_depth_max.get()
    }
}

struct Queue {
    items: VecDeque<Request>,
    open: bool,
}

/// The coalescing queue. Execution lives in the service's workers: they
/// loop on [`MicroBatcher::next_batch`], which blocks until a batch closes
/// (size or deadline) and returns its rows.
pub struct MicroBatcher {
    cfg: BatcherConfig,
    queue: Mutex<Queue>,
    /// Signaled on submit and shutdown.
    cv: Condvar,
    seq: AtomicU64,
    /// Requests rejected for overload (observability; kept alongside the
    /// equivalent [`BatcherObs`] counter for callers that poll it raw).
    pub rejected: AtomicU64,
    /// Telemetry cells (see [`BatcherObs`]).
    obs: BatcherObs,
}

impl MicroBatcher {
    pub fn new(cfg: BatcherConfig) -> Arc<MicroBatcher> {
        assert!(cfg.max_batch > 0 && cfg.queue_cap > 0);
        Arc::new(MicroBatcher {
            cfg,
            queue: Mutex::new(Queue { items: VecDeque::new(), open: true }),
            cv: Condvar::new(),
            seq: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            obs: BatcherObs::default(),
        })
    }

    pub fn config(&self) -> &BatcherConfig {
        &self.cfg
    }

    /// Telemetry cells (register into a registry via
    /// [`BatcherObs::register_into`]).
    pub fn obs(&self) -> &BatcherObs {
        &self.obs
    }

    /// Enqueue one request; returns the receiver for its response and the
    /// sequence number assigned. Fails fast when the queue is at capacity
    /// or the batcher has shut down.
    pub fn submit(
        &self,
        h: Vec<f32>,
        m: usize,
    ) -> Result<(u64, mpsc::Receiver<SampleResponse>), ServeError> {
        let (tx, rx) = mpsc::channel();
        let mut q = self.queue.lock().map_err(|_| ServeError::Poisoned)?;
        if !q.open {
            return Err(ServeError::ShuttingDown);
        }
        if q.items.len() >= self.cfg.queue_cap {
            self.rejected.fetch_add(1, Ordering::Relaxed);
            self.obs.shed.inc();
            return Err(ServeError::Overloaded);
        }
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        q.items.push_back(Request { h, m, seq, enqueued: Instant::now(), tx });
        let depth = q.items.len();
        let full = depth >= self.cfg.max_batch;
        drop(q);
        self.obs.submitted.inc();
        self.obs.queue_depth_max.set_max(depth as f64);
        // one waiter is enough for a single new row; a full batch may be
        // worth a second worker if more rows are already queued behind it
        if full {
            self.cv.notify_all();
        } else {
            self.cv.notify_one();
        }
        Ok((seq, rx))
    }

    /// Block until a batch closes, then return its rows (oldest first).
    /// `None` means shutdown with an empty queue — workers exit. A
    /// poisoned queue also returns `None`: the surviving workers exit
    /// cleanly instead of propagating the original panic across the pool
    /// (submitters see [`ServeError::Poisoned`] / dropped-channel timeouts).
    pub fn next_batch(&self) -> Option<Vec<Request>> {
        let mut q = self.queue.lock().ok()?;
        let mut deadline_hit = false;
        loop {
            if q.items.is_empty() {
                if !q.open {
                    return None;
                }
                q = self.cv.wait(q).ok()?;
                continue;
            }
            // a batch is open: close on size, shutdown, or oldest-row age
            if q.items.len() >= self.cfg.max_batch || !q.open {
                break;
            }
            let age = match q.items.front() {
                Some(front) => front.enqueued.elapsed(),
                None => continue, // unreachable: is_empty handled above
            };
            if age >= self.cfg.max_wait {
                deadline_hit = true;
                break;
            }
            let (guard, _timeout) =
                self.cv.wait_timeout(q, self.cfg.max_wait - age).ok()?;
            q = guard;
        }
        let take = q.items.len().min(self.cfg.max_batch);
        let batch: Vec<Request> = q.items.drain(..take).collect();
        drop(q);
        self.obs.coalesce_rows.record(take as f64);
        if deadline_hit {
            self.obs.deadline_hits.inc();
        }
        Some(batch)
    }

    /// Stop accepting new requests and wake every worker; queued requests
    /// are still drained (each worker keeps pulling until the queue is
    /// empty, then sees `None`). Shutdown must succeed even after a worker
    /// panic, so a poisoned lock is recovered — flipping `open` is sound
    /// regardless of what the panicking thread left behind.
    pub fn shutdown(&self) {
        let mut q = self.queue.lock().unwrap_or_else(PoisonError::into_inner);
        q.open = false;
        drop(q);
        self.cv.notify_all();
    }

    /// Queued rows right now (observability; reading a length is sound
    /// even under poison).
    pub fn depth(&self) -> usize {
        self.queue.lock().unwrap_or_else(PoisonError::into_inner).items.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(max_batch: usize, max_wait_ms: u64, cap: usize) -> BatcherConfig {
        BatcherConfig {
            max_batch,
            max_wait: Duration::from_millis(max_wait_ms),
            queue_cap: cap,
        }
    }

    #[test]
    fn coalesces_up_to_max_batch() {
        let b = MicroBatcher::new(cfg(4, 200, 64));
        for _ in 0..10 {
            b.submit(vec![0.0], 1).unwrap();
        }
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 4, "batch must close at max_batch");
        // sequence numbers are arrival order, oldest first
        let seqs: Vec<u64> = batch.iter().map(|r| r.seq).collect();
        assert_eq!(seqs, vec![0, 1, 2, 3]);
        assert_eq!(b.next_batch().unwrap().len(), 4);
        assert_eq!(b.next_batch().unwrap().len(), 2);
        // telemetry: three dispatches (4+4 full, 2 by deadline), all 10
        // admitted rows accounted, depth watermark saw the deepest queue
        assert_eq!(b.obs().batches_dispatched(), 3);
        assert_eq!(b.obs().submitted_total(), 10);
        assert_eq!(b.obs().deadline_dispatch_total(), 1);
        assert_eq!(b.obs().queue_depth_max(), 10.0);
    }

    #[test]
    fn deadline_dispatches_partial_batch() {
        let b = MicroBatcher::new(cfg(64, 10, 64));
        let t0 = Instant::now();
        b.submit(vec![1.0], 1).unwrap();
        let batch = b.next_batch().unwrap();
        let waited = t0.elapsed();
        assert_eq!(batch.len(), 1);
        // dispatched at ~max_wait, not at max_batch (generous upper slack
        // for a loaded CI box; the point is it did not wait forever)
        assert!(waited >= Duration::from_millis(9), "returned too early: {waited:?}");
        assert!(waited < Duration::from_secs(5), "deadline ignored: {waited:?}");
        // telemetry: exactly one dispatch, and it was deadline-triggered
        assert_eq!(b.obs().deadline_dispatch_total(), 1);
        assert_eq!(b.obs().batches_dispatched(), 1);
    }

    #[test]
    fn overload_rejects_and_counts() {
        let b = MicroBatcher::new(cfg(8, 50, 3));
        for _ in 0..3 {
            b.submit(vec![0.0], 1).unwrap();
        }
        assert_eq!(b.submit(vec![0.0], 1).unwrap_err(), ServeError::Overloaded);
        assert_eq!(b.rejected.load(Ordering::Relaxed), 1);
        assert_eq!(b.depth(), 3);
        // telemetry mirrors the raw counter and the admission watermark
        assert_eq!(b.obs().shed_total(), 1);
        assert_eq!(b.obs().submitted_total(), 3);
        assert_eq!(b.obs().queue_depth_max(), 3.0);
    }

    #[test]
    fn shutdown_drains_then_ends() {
        let b = MicroBatcher::new(cfg(2, 500, 64));
        for _ in 0..3 {
            b.submit(vec![0.0], 1).unwrap();
        }
        b.shutdown();
        assert_eq!(b.submit(vec![0.0], 1).unwrap_err(), ServeError::ShuttingDown);
        // queued rows still come out, then None
        assert_eq!(b.next_batch().unwrap().len(), 2);
        assert_eq!(b.next_batch().unwrap().len(), 1);
        assert!(b.next_batch().is_none());
        assert!(b.next_batch().is_none(), "None must be sticky");
    }

    /// Poison the queue mutex the only way possible: a thread panics while
    /// holding it (join consumes the Err so the test itself stays green).
    fn poison_queue(b: &Arc<MicroBatcher>) {
        let b2 = Arc::clone(b);
        let _ = std::thread::spawn(move || {
            let _g = b2.queue.lock().unwrap();
            panic!("poisoning the batcher queue");
        })
        .join();
        assert!(b.queue.is_poisoned(), "setup failed: queue not poisoned");
    }

    #[test]
    fn poisoned_submit_errors_instead_of_panicking() {
        let b = MicroBatcher::new(cfg(4, 10, 64));
        b.submit(vec![0.0], 1).unwrap();
        poison_queue(&b);
        assert_eq!(b.submit(vec![0.0], 1).unwrap_err(), ServeError::Poisoned);
    }

    #[test]
    fn poisoned_next_batch_returns_none_for_clean_worker_exit() {
        let b = MicroBatcher::new(cfg(4, 10, 64));
        b.submit(vec![0.0], 1).unwrap();
        poison_queue(&b);
        assert!(b.next_batch().is_none(), "workers must exit, not panic");
    }

    #[test]
    fn poisoned_shutdown_and_depth_recover_the_lock() {
        let b = MicroBatcher::new(cfg(4, 10, 64));
        b.submit(vec![0.0], 1).unwrap();
        poison_queue(&b);
        b.shutdown(); // must not panic
        assert_eq!(b.depth(), 1, "depth reads through the recovered lock");
    }

    #[test]
    fn concurrent_submitters_each_get_unique_seq() {
        let b = MicroBatcher::new(cfg(16, 5, 1024));
        let mut seqs: Vec<u64> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..8)
                .map(|_| {
                    let b = &b;
                    scope.spawn(move || {
                        (0..50).map(|_| b.submit(vec![0.5], 2).unwrap().0).collect::<Vec<u64>>()
                    })
                })
                .collect();
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
        });
        seqs.sort_unstable();
        let expect: Vec<u64> = (0..400).collect();
        assert_eq!(seqs, expect, "sequence numbers must be unique and dense");
        // drain everything so nothing leaks a blocked worker
        b.shutdown();
        let mut total = 0;
        while let Some(batch) = b.next_batch() {
            total += batch.len();
        }
        assert_eq!(total, 400);
    }
}
