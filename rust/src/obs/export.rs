//! Export paths for a [`MetricsSnapshot`]: Prometheus-style text
//! exposition and the `kind: "telemetry"` JSONL record shape.
//!
//! * [`MetricsSnapshot::render_prometheus`] — the text format served by
//!   `kss serve --metrics-path` and dumped on load-test exit. Counters
//!   and gauges render as single samples; histograms render as summaries
//!   (`{quantile="…"}` + `_sum`/`_count`) plus exact `_min`/`_max`
//!   samples, so a scrape sees the tails even between quantile points.
//! * [`MetricsSnapshot::to_value`] — the JSON document logged through the
//!   coordinator's `MetricsSink` as `{"kind": "telemetry", …}` records,
//!   interleaved with the existing `eval` / `phase_times` stream (one
//!   object per registry snapshot; see README "Observability" for how to
//!   join the two streams on `step`).

use crate::util::json::Value;

use super::histogram::HistogramSnapshot;
use super::registry::{MetricKind, MetricsSnapshot};

fn kind_str(k: MetricKind) -> &'static str {
    match k {
        MetricKind::Counter => "counter",
        MetricKind::Gauge => "gauge",
        MetricKind::Histogram => "summary",
    }
}

fn hist_to_value(h: &HistogramSnapshot) -> Value {
    Value::object(vec![
        ("count", Value::num(h.count() as f64)),
        ("sum", Value::num(h.sum())),
        ("mean", Value::num(h.mean())),
        ("min", Value::num(h.min())),
        ("max", Value::num(h.max())),
        ("p50", Value::num(h.p50())),
        ("p95", Value::num(h.p95())),
        ("p99", Value::num(h.p99())),
    ])
}

impl MetricsSnapshot {
    /// Prometheus-style text exposition of every registered series.
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        for (m, v) in &self.counters {
            out.push_str(&format!("# HELP {} {} ({}; {})\n", m.name, m.help, m.layer, m.unit));
            out.push_str(&format!("# TYPE {} {}\n", m.name, kind_str(m.kind)));
            out.push_str(&format!("{} {}\n", m.name, v));
        }
        for (m, v) in &self.gauges {
            out.push_str(&format!("# HELP {} {} ({}; {})\n", m.name, m.help, m.layer, m.unit));
            out.push_str(&format!("# TYPE {} {}\n", m.name, kind_str(m.kind)));
            out.push_str(&format!("{} {}\n", m.name, v));
        }
        for (m, h) in &self.hists {
            out.push_str(&format!("# HELP {} {} ({}; {})\n", m.name, m.help, m.layer, m.unit));
            out.push_str(&format!("# TYPE {} {}\n", m.name, kind_str(m.kind)));
            for (q, label) in [(0.5, "0.5"), (0.95, "0.95"), (0.99, "0.99")] {
                out.push_str(&format!(
                    "{}{{quantile=\"{}\"}} {}\n",
                    m.name,
                    label,
                    h.quantile(q)
                ));
            }
            out.push_str(&format!("{}_sum {}\n", m.name, h.sum()));
            out.push_str(&format!("{}_count {}\n", m.name, h.count()));
            out.push_str(&format!("{}_min {}\n", m.name, h.min()));
            out.push_str(&format!("{}_max {}\n", m.name, h.max()));
        }
        out
    }

    /// JSON document for the `kind: "telemetry"` MetricsSink record:
    /// `{"counters": {...}, "gauges": {...}, "histograms": {...}}` with
    /// insertion-ordered keys (first registration wins the position).
    pub fn to_value(&self) -> Value {
        let counters: Vec<(&str, Value)> = self
            .counters
            .iter()
            .map(|(m, v)| (m.name.as_str(), Value::num(*v as f64)))
            .collect();
        let gauges: Vec<(&str, Value)> =
            self.gauges.iter().map(|(m, v)| (m.name.as_str(), Value::num(*v))).collect();
        let hists: Vec<(&str, Value)> =
            self.hists.iter().map(|(m, h)| (m.name.as_str(), hist_to_value(h))).collect();
        Value::object(vec![
            ("counters", Value::object(counters)),
            ("gauges", Value::object(gauges)),
            ("histograms", Value::object(hists)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use crate::obs::registry::MetricsRegistry;
    use crate::util::json;

    fn sample_registry() -> MetricsRegistry {
        let reg = MetricsRegistry::new();
        let c = reg.counter("kss_batcher_shed_total", "requests", "serve", "rejected at admission");
        let g = reg.gauge("kss_batcher_queue_depth_max", "requests", "serve", "depth watermark");
        let h = reg.histogram("kss_publish_lag_seconds", "seconds", "serve", "build+swap lag");
        c.add(12);
        g.set(5.0);
        h.record(0.25);
        h.record(0.25);
        reg
    }

    #[test]
    fn prometheus_exposition_shape() {
        let text = sample_registry().snapshot().render_prometheus();
        assert!(text.contains("# TYPE kss_batcher_shed_total counter"), "{text}");
        assert!(text.contains("kss_batcher_shed_total 12"), "{text}");
        assert!(text.contains("# TYPE kss_batcher_queue_depth_max gauge"), "{text}");
        assert!(text.contains("kss_batcher_queue_depth_max 5"), "{text}");
        assert!(text.contains("# TYPE kss_publish_lag_seconds summary"), "{text}");
        assert!(text.contains("kss_publish_lag_seconds{quantile=\"0.5\"} 0.25"), "{text}");
        assert!(text.contains("kss_publish_lag_seconds_count 2"), "{text}");
        assert!(text.contains("kss_publish_lag_seconds_max 0.25"), "{text}");
    }

    #[test]
    fn telemetry_value_roundtrips() {
        let doc = sample_registry().snapshot().to_value();
        let parsed = json::parse(&doc.to_string_compact()).unwrap();
        let c = parsed.get("counters").unwrap().get("kss_batcher_shed_total").unwrap();
        assert_eq!(c.as_f64().unwrap(), 12.0);
        let h = parsed.get("histograms").unwrap().get("kss_publish_lag_seconds").unwrap();
        assert_eq!(h.get("count").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(h.get("p50").unwrap().as_f64().unwrap(), 0.25);
    }
}
