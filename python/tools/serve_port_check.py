#!/usr/bin/env python3
"""Line-for-line Python port of the PR's serve-layer algorithms, run
against the same property checks as the Rust tests.

The build container has no rust toolchain (see .claude/skills/verify/
SKILL.md), so — as in PR 1 — the algorithmic core of the change is ported
faithfully (same data layout, same guards, same arithmetic order where it
matters) and validated here:

  1. kernel tree draw with scratch memos (f32-shadow node masses, exact
     f64 fallback, guarded branches, leaf CDFs)  [baseline from PR 1]
  2. snapshot publisher: double-buffered reclaim + replay == straight-line
     update_many (arena equality, bitwise)
  3. shard router: merged q == K/ΣM == unsharded distribution; empirical
     chi-square; zero-mass fallback composition
  4. top-k beam: full width == exact ranking; width-1 finds a dominant
     class; zero-mass guard returns k distinct classes
  5. partial-leaf scratch draws: importance identity E[f/P(leaf)] = Σ f
  6. micro-batcher close rule: size-or-oldest-deadline simulation

Run: python3 python/tools/serve_port_check.py
"""
import math
import random

import numpy as np

NO_CHILD = -1


class QuadraticMap:
    def __init__(self, d, alpha):
        self.d, self.alpha = d, alpha

    def dim(self):
        return self.d * self.d + 1

    def phi(self, a):
        out = np.zeros(self.dim())
        sqrt_alpha = math.sqrt(self.alpha)
        for i in range(self.d):
            ai = sqrt_alpha * float(a[i])
            for j in range(self.d):
                out[i * self.d + j] = ai * float(a[j])
        out[self.d * self.d] = 1.0
        return out

    def kernel(self, a, b):
        dot = sum(float(x) * float(y) for x, y in zip(a, b))
        return self.alpha * dot * dot + 1.0


class ZeroMap:
    def __init__(self, d):
        self.d, self.alpha = d, 0.0

    def dim(self):
        return 2

    def phi(self, a):
        return np.zeros(2)

    def kernel(self, a, b):
        return 0.0


def sanitize_mass(x):
    if math.isnan(x):
        return 0.0
    return min(max(x, 0.0), 1.7976931348623157e308)


def to_f32_clamped(v):
    x = np.float32(v)
    if np.isfinite(x):
        return x
    if np.isnan(x):
        return np.float32(0.0)
    return np.float32(math.copysign(3.4028235e38, v))


def choose_branch(sl, sr, rng):
    total = sl + sr
    if total > 0.0 and math.isfinite(total):
        u = rng.random() * total
        if u < sl:
            return True, sl / total
        return False, sr / total
    return rng.random() < 0.5, 0.5


def step_down_to_positive(cum, off):
    while off > 0 and cum[off] - cum[off - 1] <= 0.0:
        off -= 1
    return off


class Tree:
    """Port of KernelTreeSampler's arena (tree.rs)."""

    def __init__(self, fmap, n, leaf_size):
        self.map, self.n, self.leaf = fmap, n, max(1, min(leaf_size, n))
        self.d = fmap.d
        self.dim = fmap.dim()
        self.emb = np.zeros((n, fmap.d), dtype=np.float32)
        self.meta = [[0, n, NO_CHILD]]
        head = 0
        while head < len(self.meta):
            lo, hi, _ = self.meta[head]
            if hi - lo > self.leaf:
                mid = lo + (hi - lo) // 2
                self.meta[head][2] = len(self.meta)
                self.meta.append([lo, mid, NO_CHILD])
                self.meta.append([mid, hi, NO_CHILD])
            head += 1
        self.z = np.zeros((len(self.meta), self.dim))
        self.z32 = np.zeros((len(self.meta), self.dim), dtype=np.float32)
        self.recompute_all()

    def clone(self):
        t = object.__new__(Tree)
        t.map, t.n, t.leaf, t.d, t.dim = self.map, self.n, self.leaf, self.d, self.dim
        t.emb = self.emb.copy()
        t.meta = [m[:] for m in self.meta]
        t.z = self.z.copy()
        t.z32 = self.z32.copy()
        return t

    def reset(self, emb):
        self.emb = np.array(emb, dtype=np.float32).reshape(self.n, self.d)
        self.recompute_all()

    def recompute_all(self):
        for idx in reversed(range(len(self.meta))):
            lo, hi, left = self.meta[idx]
            if left == NO_CHILD:
                acc = np.zeros(self.dim)
                for j in range(lo, hi):
                    acc += self.map.phi(self.emb[j])
                self.z[idx] = acc
            else:
                self.z[idx] = self.z[left] + self.z[left + 1]
        for i in range(len(self.meta)):
            self.z32[i] = [to_f32_clamped(v) for v in self.z[i]]

    def update_many(self, classes, rows):
        if not classes:
            return
        self._apply_rec(0, classes, rows)

    def _apply_rec(self, idx, classes, rows):
        lo, hi, left = self.meta[idx]
        delta = np.zeros(self.dim)
        if left == NO_CHILD:
            for (c, w_new) in zip(classes, rows):
                old = self.map.phi(self.emb[c])
                new = self.map.phi(np.array(w_new, dtype=np.float32))
                delta += new - old
                self.emb[c] = w_new
        else:
            mid = self.meta[left][1]
            split = sum(1 for c in classes if c < mid)
            if split > 0:
                delta += self._apply_rec(left, classes[:split], rows[:split])
            if split < len(classes):
                delta += self._apply_rec(left + 1, classes[split:], rows[split:])
        self.z[idx] += delta
        self.z32[idx] = [to_f32_clamped(v) for v in self.z[idx]]
        return delta

    # --- draw path with scratch memos -----------------------------------
    def begin_example(self, h):
        phi = self.map.phi(h)
        phi32 = np.array([to_f32_clamped(v) for v in phi], dtype=np.float32)
        total = float(np.dot(phi, self.z[0]))
        return {"phi": phi, "phi32": phi32, "total": total, "node": {}, "leafcdf": {}}

    def begin_example_prepared(self, phi, total):
        # total = caller's already-computed <phi, z(root)> (router reuse)
        phi32 = np.array([to_f32_clamped(v) for v in phi], dtype=np.float32)
        assert total == float(np.dot(phi, self.z[0]))
        return {"phi": phi, "phi32": phi32, "total": total, "node": {}, "leafcdf": {}}

    def node_mass(self, s, idx):
        if idx in s["node"]:
            return s["node"][idx]
        fast = float(np.dot(s["phi32"], self.z32[idx]).astype(np.float32))
        if math.isfinite(fast):
            v = max(fast, 0.0)
        else:
            v = sanitize_mass(float(np.dot(s["phi"], self.z[idx])))
        s["node"][idx] = v
        return v

    def leaf_cdf(self, s, h, idx):
        if idx not in s["leafcdf"]:
            lo, hi, _ = self.meta[idx]
            acc, cum = 0.0, []
            for j in range(lo, hi):
                acc += sanitize_mass(self.map.kernel(h, self.emb[j]))
                cum.append(acc)
            s["leafcdf"][idx] = cum
        return s["leafcdf"][idx], self.meta[idx][0]

    def draw(self, h, s, rng):
        total = s["total"]
        idx, p_path = 0, 1.0
        while True:
            lo, hi, left = self.meta[idx]
            if left == NO_CHILD:
                length = hi - lo
                cum, lo = self.leaf_cdf(s, h, idx)
                mass = cum[-1]
                if not mass > 0.0:
                    off = rng.randrange(length)
                    q = max(p_path / length, 5e-324)
                    return lo + off, q
                u = rng.random() * mass
                off = min(sum(1 for c in cum if c <= u), length - 1)
                off = step_down_to_positive(cum, off)
                k = cum[0] if off == 0 else cum[off] - cum[off - 1]
                q = k / total
                if not (q > 0.0 and math.isfinite(q)):
                    q = max(p_path * k / mass, 5e-324)
                return lo + off, q
            sl = self.node_mass(s, left)
            sr = self.node_mass(s, left + 1)
            go_left, p = choose_branch(sl, sr, rng)
            p_path *= p
            idx = left if go_left else left + 1

    def draw_leaf_scratch(self, s, rng):
        idx, p_leaf = 0, 1.0
        while True:
            lo, hi, left = self.meta[idx]
            if left == NO_CHILD:
                return (lo, hi), max(p_leaf, 5e-324)
            sl = self.node_mass(s, left)
            sr = self.node_mass(s, left + 1)
            go_left, p = choose_branch(sl, sr, rng)
            p_leaf *= p
            idx = left if go_left else left + 1

    def partition(self, phi):
        return float(np.dot(phi, self.z[0]))

    def topk_beam(self, h, k, beam_width):
        beam_width = max(1, beam_width)
        phi = self.map.phi(h)
        mass = lambda idx: sanitize_mass(float(np.dot(phi, self.z[idx])))
        frontier = [(0, mass(0))]
        while True:
            nxt, expanded = [], False
            for idx, m in frontier:
                lo, hi, left = self.meta[idx]
                if left == NO_CHILD:
                    nxt.append((idx, m))
                else:
                    expanded = True
                    nxt.append((left, mass(left)))
                    nxt.append((left + 1, mass(left + 1)))
            if not expanded:
                break
            nxt.sort(key=lambda t: (-t[1], t[0]))
            frontier = nxt[:beam_width]
        scored = []
        for idx, _ in frontier:
            lo, hi, _ = self.meta[idx]
            for c in range(lo, hi):
                scored.append((c, sanitize_mass(self.map.kernel(h, self.emb[c]))))
        scored.sort(key=lambda t: (-t[1], t[0]))
        return scored[:k]


# --- snapshot publisher (snapshot.rs) ----------------------------------
class Publisher:
    MAX_RETIRED = 6

    def __init__(self, tree):
        self.shadow = tree
        self.gen = 0
        # (generation, tree, pinned_flag-box) — pinned simulates readers
        snap = {"gen": 0, "tree": tree.clone(), "pins": 0}
        self.current = snap
        self.retired = [snap]
        self.log = []
        self.stats = {"publishes": 0, "reclaimed": 0, "copied": 0, "replayed": 0}

    def publish(self, classes, rows):
        self.shadow.update_many(classes, rows)
        self.gen += 1
        self.log.append((self.gen, list(classes), [list(r) for r in rows]))
        reclaimed = None
        # strong_count == 1 <=> not current and not pinned; scan the whole
        # queue (a pinned old generation must not block frees behind it),
        # oldest→newest so the newest free arena wins
        i = 0
        while i < len(self.retired):
            cand = self.retired[i]
            if cand is self.current or cand["pins"] > 0:
                i += 1
                continue
            reclaimed = self.retired.pop(i)
        if reclaimed is not None:
            for (g, cl, rw) in self.log:
                if g > reclaimed["gen"]:
                    reclaimed["tree"].update_many(cl, rw)
                    self.stats["replayed"] += 1
            reclaimed["gen"] = self.gen
            self.stats["reclaimed"] += 1
            nxt = reclaimed
        else:
            self.stats["copied"] += 1
            nxt = {"gen": self.gen, "tree": self.shadow.clone(), "pins": 0}
        self.retired.append(nxt)
        self.current = nxt
        self.stats["publishes"] += 1
        while len(self.retired) > self.MAX_RETIRED:
            self.retired.pop(0)
        min_gen = self.retired[0]["gen"] if self.retired else self.gen
        self.log = [b for b in self.log if b[0] > min_gen]
        return nxt


# --- shard router (shard.rs) -------------------------------------------
def shard_offsets(n, shards):
    shards = max(1, min(shards, n))
    return [s * n // shards for s in range(shards + 1)]


def draw_from_shards(trees, offsets, h, m, rng):
    phi = trees[0].map.phi(h)
    raw_totals = [t.partition(phi) for t in trees]
    masses = [sanitize_mass(r) for r in raw_totals]
    cum, acc = [], 0.0
    for ms in masses:
        acc += ms
        cum.append(acc)
    total = acc
    scratches = [None] * len(trees)
    out = []
    for _ in range(m):
        if total > 0.0 and math.isfinite(total):
            u = rng.random() * total
            sid = min(sum(1 for c in cum if c <= u), len(trees) - 1)
            sid = step_down_to_positive(cum, sid)
            p_shard = masses[sid] / total
        else:
            sid = rng.randrange(len(trees))
            p_shard = 1.0 / len(trees)
        if scratches[sid] is None:
            scratches[sid] = trees[sid].begin_example_prepared(phi, raw_totals[sid])
        local, q_local = trees[sid].draw(h, scratches[sid], rng)
        out.append((offsets[sid] + local, max(p_shard * q_local, 5e-324)))
    return out


# --- checks -------------------------------------------------------------
def exact_dist(fmap, h, emb):
    w = [fmap.kernel(h, e) for e in emb]
    z = sum(w)
    return [x / z for x in w]


def check_baseline_tree(trials=40):
    rng = random.Random(1)
    for case in range(trials):
        n = rng.randint(2, 40)
        d = rng.randint(1, 4)
        leaf = rng.randint(1, n)
        fmap = QuadraticMap(d, rng.uniform(1.0, 150.0))
        emb = np.random.default_rng(case).normal(0, 0.5, (n, d)).astype(np.float32)
        t = Tree(fmap, n, leaf)
        t.reset(emb)
        h = np.random.default_rng(case + 999).normal(0, 1, d).astype(np.float32)
        expected = exact_dist(fmap, h, emb)
        s = t.begin_example(h)
        for _ in range(32):
            c, q = t.draw(h, s, rng)
            assert abs(q - expected[c]) < 1e-9, (case, c, q, expected[c])
    print("  baseline tree q == closed form: OK")


def check_publisher(trials=12):
    rng = random.Random(7)
    for case in range(trials):
        n = rng.randint(4, 40)
        d = rng.randint(1, 3)
        fmap = QuadraticMap(d, 100.0)
        emb = np.random.default_rng(case).normal(0, 0.5, (n, d)).astype(np.float32)
        base = Tree(fmap, n, 4)
        base.reset(emb)
        reference = base.clone()
        pub = Publisher(base)
        npr = np.random.default_rng(1000 + case)
        reader_pin = None
        for step in range(10):
            k = rng.randint(1, 5)
            classes = sorted(rng.sample(range(n), k))
            rows = npr.normal(0, 0.7, (k, d)).astype(np.float32)
            reference.update_many(classes, rows)
            snap = pub.publish(classes, rows)
            # a reader pins every 3rd generation for a while
            if step % 3 == 0:
                if reader_pin is not None:
                    reader_pin["pins"] -= 1
                reader_pin = snap
                snap["pins"] += 1
            assert np.array_equal(snap["tree"].z, reference.z), (case, step)
            assert np.array_equal(snap["tree"].emb, reference.emb)
        assert pub.stats["reclaimed"] > 0, (case, pub.stats)
        assert pub.stats["publishes"] == 10
    # head-of-line: one reader pins an early generation forever; frees
    # behind it must still be reclaimed and replay must stay exact
    fmap = QuadraticMap(2, 100.0)
    emb = np.random.default_rng(77).normal(0, 0.5, (12, 2)).astype(np.float32)
    base = Tree(fmap, 12, 3)
    base.reset(emb)
    reference = base.clone()
    pub = Publisher(base)
    npr = np.random.default_rng(78)
    pinned = pub.publish([0, 5], npr.normal(0, 0.5, (2, 2)).astype(np.float32))
    reference.update_many([0, 5], pinned["tree"].emb[[0, 5]].copy())
    # re-derive reference rows exactly: use the same rows we published
    pinned["pins"] += 1
    pinned_z = pinned["tree"].z.copy()
    for _ in range(8):
        classes = sorted(rng.sample(range(12), 3))
        rows = npr.normal(0, 0.5, (3, 2)).astype(np.float32)
        reference.update_many(classes, rows)
        snap = pub.publish(classes, rows)
        assert np.array_equal(snap["tree"].z, reference.z)
    assert pub.stats["reclaimed"] >= 6, pub.stats
    assert np.array_equal(pinned["tree"].z, pinned_z), "pinned generation mutated"
    print("  publisher reclaim+replay == straight-line updates (bitwise): OK")


def check_shards(trials=16):
    rng = random.Random(3)
    for case in range(trials):
        n = rng.randint(4, 60)
        d = rng.randint(1, 4)
        shards = rng.randint(1, min(8, n))
        leaf = rng.randint(1, 8)
        fmap = QuadraticMap(d, rng.uniform(1.0, 150.0))
        emb = np.random.default_rng(case).normal(0, 0.5, (n, d)).astype(np.float32)
        offs = shard_offsets(n, shards)
        trees = []
        for lo, hi in zip(offs, offs[1:]):
            t = Tree(fmap, hi - lo, leaf)
            t.reset(emb[lo:hi])
            trees.append(t)
        h = np.random.default_rng(case + 55).normal(0, 1, d).astype(np.float32)
        expected = exact_dist(fmap, h, emb)
        for c, q in draw_from_shards(trees, offs, h, 64, rng):
            assert 0 <= c < n
            assert abs(q - expected[c]) < 1e-9, (case, c, q, expected[c])
    # chi-square of the merged empirical distribution
    n, d, shards = 40, 3, 5
    fmap = QuadraticMap(d, 100.0)
    emb = np.random.default_rng(42).normal(0, 0.5, (n, d)).astype(np.float32)
    offs = shard_offsets(n, shards)
    trees = []
    for lo, hi in zip(offs, offs[1:]):
        t = Tree(fmap, hi - lo, 3)
        t.reset(emb[lo:hi])
        trees.append(t)
    h = np.random.default_rng(43).normal(0, 1, d).astype(np.float32)
    expected = exact_dist(fmap, h, emb)
    rng = random.Random(9)
    counts = [0] * n
    draws = 120_000
    for _ in range(draws // 50):
        for c, _ in draw_from_shards(trees, offs, h, 50, rng):
            counts[c] += 1
    stat = sum(
        (counts[i] - expected[i] * draws) ** 2 / (expected[i] * draws)
        for i in range(n)
        if expected[i] * draws >= 1.0
    )
    assert stat < 39 + 5 * math.sqrt(78), stat
    # zero-mass composition: all q > 0, both halves hit
    zt = [Tree(ZeroMap(3), 8, 2) for _ in range(2)]
    zo = [0, 8, 16]
    seen = set()
    for c, q in draw_from_shards(zt, zo, np.ones(3, dtype=np.float32), 512, rng):
        assert q > 0.0
        seen.add(c // 8)
    assert seen == {0, 1}
    print(f"  shard router merged q == unsharded (chi2 {stat:.1f}, df 39): OK")


def check_topk(trials=20):
    rng = random.Random(11)
    for case in range(trials):
        n = rng.randint(4, 50)
        d = rng.randint(1, 4)
        k = rng.randint(1, n)
        fmap = QuadraticMap(d, rng.uniform(1.0, 150.0))
        emb = np.random.default_rng(case).normal(0, 0.5, (n, d)).astype(np.float32)
        t = Tree(fmap, n, rng.randint(1, n))
        t.reset(emb)
        h = np.random.default_rng(case + 5).normal(0, 1, d).astype(np.float32)
        exact = sorted(
            ((c, fmap.kernel(h, emb[c])) for c in range(n)), key=lambda x: (-x[1], x[0])
        )[:k]
        got = t.topk_beam(h, k, len(t.meta))
        assert [c for c, _ in got] == [c for c, _ in exact], (case, got, exact)
    # width-1 beam finds a dominant class
    n, d = 64, 3
    emb = np.random.default_rng(0).normal(0, 0.05, (n, d)).astype(np.float32)
    emb[17] = [4.0, -4.0, 4.0]
    t = Tree(QuadraticMap(d, 100.0), n, 4)
    t.reset(emb)
    top = t.topk_beam(np.array([1.0, -1.0, 1.0], dtype=np.float32), 1, 1)
    assert top[0][0] == 17, top
    # zero-mass guard: k distinct classes
    zt = Tree(ZeroMap(3), 16, 2)
    zk = zt.topk_beam(np.ones(3, dtype=np.float32), 4, 2)
    assert len({c for c, _ in zk}) == 4
    print("  top-k beam (full width == exact, dominance, zero-mass): OK")


def check_partial_leaf():
    rng = random.Random(13)
    n, d = 30, 3
    fmap = QuadraticMap(d, 100.0)
    emb = np.random.default_rng(30).normal(0, 0.6, (n, d)).astype(np.float32)
    t = Tree(fmap, n, 5)
    t.reset(emb)
    h = np.random.default_rng(31).normal(0, 1, d).astype(np.float32)
    f = lambda j: 1.0 + j * 0.1
    truth = sum(f(j) for j in range(n))
    s = t.begin_example(h)
    runs, acc = 30_000, 0.0
    for _ in range(runs):
        (lo, hi), p = t.draw_leaf_scratch(s, rng)
        for c in range(lo, hi):
            acc += f(c) / p
    est = acc / runs
    assert abs(est - truth) < 0.05 * truth, (est, truth)
    print(f"  partial-leaf scratch importance identity ({est:.2f} vs {truth:.2f}): OK")


def check_batcher_rule():
    # pure simulation of MicroBatcher::next_batch's close rule
    def close_points(arrivals, max_batch, max_wait):
        batches, queue = [], []
        events = sorted(arrivals)
        t, i = 0.0, 0
        while i < len(events) or queue:
            if not queue:
                t = events[i]
            while i < len(events) and events[i] <= t:
                queue.append(events[i])
                i += 1
            if len(queue) >= max_batch:
                batches.append((t, queue[:max_batch]))
                queue = queue[max_batch:]
                continue
            deadline = queue[0] + max_wait
            if i < len(events) and events[i] < deadline:
                t = events[i]
                continue
            t = deadline
            while i < len(events) and events[i] <= t:
                queue.append(events[i])
                i += 1
            take = min(len(queue), max_batch)
            batches.append((t, queue[:take]))
            queue = queue[take:]
        return batches

    rng = random.Random(17)
    for _ in range(200):
        arrivals = sorted(rng.uniform(0, 10) for _ in range(rng.randint(1, 40)))
        mb = rng.randint(1, 8)
        mw = rng.uniform(0.1, 2.0)
        total = 0
        for t_close, batch in close_points(arrivals, mb, mw):
            assert len(batch) <= mb
            # deadline contract: oldest row dispatched within max_wait
            assert t_close <= batch[0] + mw + 1e-9
            total += len(batch)
        assert total == len(arrivals)
    print("  micro-batcher close rule (size cap + oldest-row deadline): OK")


if __name__ == "__main__":
    print("serve-layer port checks:")
    check_baseline_tree()
    check_publisher()
    check_shards()
    check_topk()
    check_partial_leaf()
    check_batcher_rule()
    print("all serve-layer port checks passed")
