"""AOT pipeline: manifest structure and HLO-text artifact integrity.

Runs against the artifacts/ directory if `make artifacts` has produced it
(skipped otherwise, so pytest works on a fresh checkout too)."""

import json
import os

import pytest

from compile import configs as C
from compile.aot import artifact_filename, manifest_entry

ARTIFACTS = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
MANIFEST = os.path.join(ARTIFACTS, "manifest.json")

needs_artifacts = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="artifacts not built (run `make artifacts`)"
)


def test_artifact_filenames():
    assert artifact_filename("ptb", "encode") == "ptb_encode.hlo.txt"
    assert artifact_filename("ptb", "train_sampled", 32) == "ptb_train_sampled_m32.hlo.txt"


def test_manifest_entry_structure():
    cfg = C.CONFIGS["tiny"]
    files = {(op, None): artifact_filename("tiny", op) for op in
             ["encode", "score_all", "eval_full", "train_full"]}
    files[("train_sampled", 4)] = artifact_filename("tiny", "train_sampled", 4)
    e = manifest_entry(cfg, [4], files)
    assert e["n_classes"] == 128 and e["model"] == "recsys"
    assert [p["name"] for p in e["params"]] == ["item_emb", "w1", "b1", "w2", "b2", "out_w"]
    assert e["ops"]["encode"]["outputs"][0]["shape"] == [8, 16]
    ts = e["train_sampled"]["4"]
    in_names = [i["name"] for i in ts["inputs"]]
    assert in_names == ["user", "prev", "pos", "neg", "sub", "lr"]
    out_names = [o["name"] for o in ts["outputs"]]
    assert out_names[-2:] == ["loss", "rows"]


@needs_artifacts
def test_manifest_files_exist_and_are_hlo():
    with open(MANIFEST) as f:
        man = json.load(f)
    assert man["version"] == 1
    assert "tiny" in man["models"]
    for name, entry in man["models"].items():
        for op, rec in entry["ops"].items():
            path = os.path.join(ARTIFACTS, rec["file"])
            assert os.path.exists(path), f"{name}/{op} missing"
            with open(path) as f:
                head = f.read(64)
            assert head.startswith("HloModule"), f"{name}/{op} not HLO text"
        for m, rec in entry["train_sampled"].items():
            path = os.path.join(ARTIFACTS, rec["file"])
            assert os.path.exists(path), f"{name}/train_sampled m={m} missing"


@needs_artifacts
def test_manifest_shapes_consistent_with_configs():
    with open(MANIFEST) as f:
        man = json.load(f)
    for name, entry in man["models"].items():
        if name not in C.CONFIGS:
            continue
        cfg = C.CONFIGS[name]
        assert entry["n_classes"] == cfg.n_classes
        assert entry["d"] == cfg.d
        assert entry["abs_logits"] == cfg.abs_logits
        want = [(p[0], list(p[1])) for p in cfg.param_specs()]
        got = [(p["name"], p["shape"]) for p in entry["params"]]
        assert got == want, name
