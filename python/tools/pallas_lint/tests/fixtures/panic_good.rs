// pallas-lint fixture — must NOT trip PANIC. Same logical path as
// panic_bad.rs (rust/src/serve/batcher.rs): the compliant idioms.

pub struct B {
    q: std::sync::Mutex<Vec<u32>>,
}

pub enum E {
    Poisoned,
}

impl B {
    /// Request path: poison becomes an error, never a panic.
    pub fn submit(&self, x: u32) -> Result<(), E> {
        let mut g = self.q.lock().map_err(|_| E::Poisoned)?;
        g.push(x);
        Ok(())
    }

    /// Worker path: poison means clean exit; access via .get(), not [i].
    pub fn next_batch(&self, items: &[u32]) -> Option<u32> {
        let g = self.q.lock().ok()?;
        debug_assert!(!g.is_empty() || g.is_empty());
        items.first().copied()
    }

    /// Must-not-fail path: recover the poisoned lock.
    pub fn shutdown(&self) {
        let g = self.q.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        drop(g);
    }

    /// `vec!` macro brackets are literals, not indexing.
    pub fn depth(&self) -> usize {
        let seed = vec![0u32; 4];
        seed.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tests may unwrap and index freely.
    #[test]
    fn unwrap_in_tests_is_fine() {
        let b = B { q: std::sync::Mutex::new(vec![7]) };
        b.submit(1).ok();
        let items = [3u32, 4];
        assert_eq!(items[0], 3);
        assert_eq!(*b.q.lock().unwrap().first().unwrap(), 7);
    }
}
