//! The training loop — the paper's procedure, end to end:
//!
//! 1. `encode` (AOT artifact) produces the query embeddings `h` for the
//!    batch (only when the sampler is adaptive; static samplers skip it);
//!    `score_all` produces full logit rows for the exact/oracle samplers.
//! 2. every example's `m` negatives are drawn in parallel (threadpool) from
//!    the configured sampler, together with the eq. (2) corrections
//!    `ln(m q)`;
//! 3. the `train_sampled` artifact performs the fused sampled-softmax
//!    forward/backward (Pallas kernel) + SGD update on-device;
//! 4. the updated output-embedding rows (returned by the artifact for
//!    exactly the sampled classes) patch the host mirror, and the kernel
//!    tree updates its `z(C)` path statistics (Fig. 1(b)).
//!
//! The full-softmax baseline (`sampler = "full"`) replaces 1-4 with the
//! `train_full` artifact. Evaluation is always the *full* softmax loss on
//! held-out data — the quantity every figure in the paper plots.

use crate::coordinator::config::{build_dataset, TrainConfig};
use crate::coordinator::metrics::{EvalPoint, MetricsSink};
use crate::data::{Batch, Dataset};
use crate::runtime::{Engine, ModelSpec, ParamStore, Tensor};
use crate::sampler::kernel::FeatureMap;
use crate::sampler::{build_sampler, BatchSampleInput, QuadraticMap, Sample, Sampler};
use crate::serve::{ShardPublisher, ShardSet, SnapshotStore, TreeSnapshot};
use crate::util::rng::{splitmix64, Rng};
use crate::util::stats::{PhaseTimes, Stopwatch};
use crate::util::threadpool::default_threads;
use anyhow::{Context, Result};
use std::sync::Arc;

/// Result of a training run.
#[derive(Clone, Debug)]
pub struct TrainResult {
    pub final_loss: f64,
    pub best_loss: f64,
    pub curve: Vec<EvalPoint>,
    pub steps: usize,
    /// Mean training loss of the last epoch (sampled objective, *not*
    /// comparable across samplers — the eval curve is).
    pub last_train_loss: f64,
}

/// Drives one run. Owns the parameters, sampler and dataset; borrows the
/// engine (executable caches are shared across runs of the same model).
pub struct Trainer<'e> {
    engine: &'e Engine,
    spec: ModelSpec,
    cfg: TrainConfig,
    pub store: ParamStore,
    sampler: Option<Box<dyn Sampler>>,
    dataset: Box<dyn Dataset>,
    rng: Rng,
    /// Per-phase wall-clock accounting (encode/sample/step/update/eval).
    pub phases: PhaseTimes,
    threads: usize,
    step_count: usize,
    /// Serving publisher (see [`Trainer::enable_serving`]): a sharded
    /// mirror of the output-embedding table that republishes a snapshot
    /// generation after every sampled step. Kernel-erased so the trainer
    /// can publish whichever kernel family it trains (quadratic, rff, …).
    publisher: Option<Box<dyn ShardPublisher>>,
}

impl<'e> Trainer<'e> {
    pub fn new(engine: &'e Engine, cfg: TrainConfig) -> Result<Trainer<'e>> {
        let spec = engine.manifest().model(&cfg.model)?.clone();
        let cfg = cfg.with_model_defaults(&spec);
        let dataset = build_dataset(&spec, &cfg)?;
        let store = ParamStore::init(&spec.params, splitmix64(&mut (cfg.seed ^ 0x1417)))?;
        let sampler: Option<Box<dyn Sampler>> = if cfg.sampler == "full" {
            None
        } else {
            let stats = dataset.stats();
            Some(build_sampler(
                &cfg.sampler,
                spec.n_classes,
                spec.d,
                spec.alpha,
                spec.abs_logits,
                Some(&stats),
                Some(store.out_w().as_f32()?),
            )?)
        };
        let threads = if cfg.threads == 0 { default_threads() } else { cfg.threads };
        let rng = Rng::new(cfg.seed ^ 0x7141_1e5);
        Ok(Trainer {
            engine,
            spec,
            cfg,
            store,
            sampler,
            dataset,
            rng,
            phases: PhaseTimes::default(),
            threads,
            step_count: 0,
            publisher: None,
        })
    }

    /// Attach the serving publisher: a sharded kernel-tree mirror of the
    /// output-embedding table whose shards republish a fresh immutable
    /// snapshot generation after every sampled training step (the same
    /// Fig. 1(b) rows the sampler applies). Returns the per-shard publish
    /// points and shard offsets — exactly what
    /// [`crate::serve::SamplingService::start`] takes — so online readers
    /// sample the training-fresh distribution while the trainer keeps
    /// stepping. The quadratic-kernel convenience wrapper around
    /// [`Trainer::enable_serving_with`].
    #[allow(clippy::type_complexity)]
    pub fn enable_serving(
        &mut self,
        shards: usize,
    ) -> Result<(Vec<Arc<SnapshotStore<TreeSnapshot<QuadraticMap>>>>, Vec<u32>)> {
        let map = QuadraticMap::new(self.spec.d, self.spec.alpha as f64);
        self.enable_serving_with(map, shards)
    }

    /// [`Trainer::enable_serving`] over any kernel family: the publisher is
    /// stored kernel-erased, the returned stores keep the concrete map type
    /// the caller's [`crate::serve::SamplingService`] needs.
    #[allow(clippy::type_complexity)]
    pub fn enable_serving_with<M: FeatureMap + Clone + 'static>(
        &mut self,
        map: M,
        shards: usize,
    ) -> Result<(Vec<Arc<SnapshotStore<TreeSnapshot<M>>>>, Vec<u32>)> {
        let set = ShardSet::new(
            map,
            self.spec.n_classes,
            shards,
            None,
            Some(self.store.out_w().as_f32()?),
        );
        let stores = set.stores();
        let offsets = set.offsets().to_vec();
        self.publisher = Some(Box::new(set));
        Ok((stores, offsets))
    }

    /// Aggregated publish counters (None until serving is enabled).
    pub fn publish_stats(&self) -> Option<crate::serve::PublishStats> {
        self.publisher.as_ref().map(|p| p.publish_stats())
    }

    pub fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    pub fn cfg(&self) -> &TrainConfig {
        &self.cfg
    }

    pub fn dataset(&self) -> &dyn Dataset {
        self.dataset.as_ref()
    }

    pub fn steps_taken(&self) -> usize {
        self.step_count
    }

    /// Mean full-softmax CE on held-out data (capped at cfg.eval_batches).
    pub fn eval(&mut self) -> Result<f64> {
        let mut sw = Stopwatch::new();
        let op = self.spec.op("eval_full")?.clone();
        let mut total = 0.0f64;
        let mut count = 0usize;
        let batches = self.dataset.eval_batches();
        let cap = if self.cfg.eval_batches == 0 { batches.len() } else { self.cfg.eval_batches };
        anyhow::ensure!(!batches.is_empty(), "no eval batches (valid_size too small)");
        for batch in batches.iter().take(cap) {
            let args = self.args_with(&batch.data, &[]);
            let out = self.engine.execute(&op, self.store.len(), &args)?;
            total += out[0].scalar()? as f64;
            count += batch.n_examples();
        }
        self.phases.add("eval", sw.lap());
        Ok(total / count as f64)
    }

    /// One sampled-softmax (or full-softmax) training step.
    pub fn step(&mut self, batch: &Batch) -> Result<f32> {
        let loss = if self.sampler.is_none() {
            self.step_full(batch)?
        } else {
            self.step_sampled(batch)?
        };
        self.step_count += 1;
        Ok(loss)
    }

    fn step_full(&mut self, batch: &Batch) -> Result<f32> {
        let mut sw = Stopwatch::new();
        let op = self.spec.op("train_full")?.clone();
        let lr = Tensor::scalar_f32(self.cfg.lr);
        let args = self.args_with(&batch.data, &[&lr]);
        let out = self.engine.execute(&op, self.store.len(), &args)?;
        let n_p = self.store.len();
        self.store.set_all(&out[..n_p])?;
        self.phases.add("step", sw.lap());
        out[n_p].scalar()
    }

    fn step_sampled(&mut self, batch: &Batch) -> Result<f32> {
        let mut sw = Stopwatch::new();
        let sampler = self.sampler.as_deref().expect("sampled step without sampler");
        let needs = sampler.needs();
        let n = batch.n_examples();
        let m = self.cfg.m;
        let s_dim = m + 1;
        let d = self.spec.d;
        let n_classes = self.spec.n_classes;

        // 1. model-dependent inputs for the sampler
        let h_tensor = if needs.h {
            let op = self.spec.op("encode")?.clone();
            let data = &batch.data[..op.inputs.len()];
            let args = self.args_with(data, &[]);
            let out = self.engine.execute(&op, self.store.len(), &args)?;
            Some(out.into_iter().next().unwrap())
        } else {
            None
        };
        let logits_tensor = if needs.logits {
            let op = self.spec.op("score_all")?.clone();
            let data = &batch.data[..op.inputs.len()];
            let args = self.args_with(data, &[]);
            let out = self.engine.execute(&op, self.store.len(), &args)?;
            Some(out.into_iter().next().unwrap())
        } else {
            None
        };
        self.phases.add("encode", sw.lap());

        // 2. batch-level negative sampling. The sampler layer owns the
        // parallel fan-out; the per-row RNG streams (sampler::row_rng) keep
        // results deterministic for a fixed seed and any thread count.
        let step_seed = self.rng.next_u64();
        let inputs = BatchSampleInput {
            n,
            d,
            n_classes,
            h: h_tensor.as_ref().map(|t| t.as_f32()).transpose()?,
            logits: logits_tensor.as_ref().map(|t| t.as_f32()).transpose()?,
            prev: batch.prev.as_deref(),
            threads: self.threads,
        };
        let mut rows: Vec<Sample> = (0..n).map(|_| Sample::with_capacity(m)).collect();
        sampler.sample_batch(&inputs, m, step_seed, &mut rows)?;
        // assemble neg (N, m), sub (N, m+1) and s (N, S) host-side
        let mut neg = Vec::with_capacity(n * m);
        let mut sub = Vec::with_capacity(n * s_dim);
        let mut s_idx = Vec::with_capacity(n * s_dim);
        for (i, row) in rows.iter().enumerate() {
            debug_assert_eq!(row.classes.len(), m);
            sub.push(0.0f32); // positive: uncorrected (eq. 2)
            s_idx.push(batch.pos[i]);
            for (&c, &q) in row.classes.iter().zip(&row.q) {
                // the sampler layer guarantees q > 0 (see sampler/mod.rs);
                // a violation here would send ln(m·q) = -inf on-device.
                debug_assert!(q > 0.0 && q.is_finite(), "sampler reported q = {q}");
                neg.push(c as i32);
                sub.push(((m as f64) * q).ln() as f32);
                s_idx.push(c as i32);
            }
        }
        self.phases.add("sample", sw.lap());

        // 3. fused sampled-softmax step on-device
        let op = self.spec.train_sampled_op(m)?.clone();
        let neg_t = Tensor::i32s(&[n, m], neg);
        let sub_t = Tensor::f32s(&[n, s_dim], sub);
        let lr = Tensor::scalar_f32(self.cfg.lr);
        let args = self.args_with(&batch.data, &[&neg_t, &sub_t, &lr]);
        let out = self.engine.execute(&op, self.store.len(), &args)?;
        let n_p = self.store.len();
        self.store.set_all(&out[..n_p])?;
        let loss = out[n_p].scalar()?;
        self.phases.add("step", sw.lap());

        // 4. host mirror + adaptive-sampler update (Fig. 1(b))
        let changed = self
            .store
            .apply_sampled_rows(&s_idx, &out[n_p + 1])
            .context("applying updated rows")?;
        if needs.h || self.publisher.is_some() {
            // flat copy of the changed rows (sorted + deduped by
            // apply_sampled_rows), then one batched tree sweep
            let mut rows_flat = Vec::with_capacity(changed.len() * d);
            for &class in &changed {
                rows_flat.extend_from_slice(self.store.out_row(class));
            }
            if needs.h {
                self.sampler.as_mut().unwrap().update_many(&changed, &rows_flat);
            }
            self.phases.add("update", sw.lap());
            // 5. publish the step's rows to the serving snapshots: online
            // readers pick up generation G+1 at their next batch while any
            // in-flight request finishes on G
            if let Some(set) = &mut self.publisher {
                set.update_and_publish_rows(&changed, &rows_flat);
                self.phases.add("publish", sw.lap());
            }
        } else {
            self.phases.add("update", sw.lap());
        }
        Ok(loss)
    }

    /// params + data (+ extras) in artifact order.
    fn args_with<'a>(&'a self, data: &'a [Tensor], extra: &[&'a Tensor]) -> Vec<&'a Tensor> {
        let mut args: Vec<&Tensor> = self.store.values().iter().collect();
        args.extend(data.iter());
        args.extend(extra.iter().copied());
        args
    }

    /// Run the full schedule, logging eval points to the sink.
    pub fn train(&mut self, metrics: &mut MetricsSink) -> Result<TrainResult> {
        metrics.log_config(&self.cfg.to_json());
        let initial = self.eval()?;
        metrics.log_eval(EvalPoint { epoch: 0.0, step: 0, loss: initial });

        let mut last_train_loss = f32::NAN;
        for epoch in 0..self.cfg.epochs {
            let mut batches = self.dataset.train_batches(epoch);
            if self.cfg.max_steps_per_epoch > 0 {
                batches.truncate(self.cfg.max_steps_per_epoch);
            }
            anyhow::ensure!(!batches.is_empty(), "no train batches (train_size too small)");
            let steps_per_epoch = batches.len();
            let mut train_loss_sum = 0.0f64;
            for (bi, batch) in batches.iter().enumerate() {
                let loss = self.step(batch)?;
                train_loss_sum += loss as f64;
                let step = epoch * steps_per_epoch + bi + 1;
                if self.cfg.eval_every > 0 && step % self.cfg.eval_every == 0 {
                    let loss = self.eval()?;
                    let epoch_f = step as f64 / steps_per_epoch as f64;
                    metrics.log_eval(EvalPoint { epoch: epoch_f, step, loss });
                }
            }
            last_train_loss = (train_loss_sum / steps_per_epoch as f64) as f32;
            let loss = self.eval()?;
            let step = (epoch + 1) * steps_per_epoch;
            metrics.log_eval(EvalPoint { epoch: (epoch + 1) as f64, step, loss });
            crate::info!(
                "[{}] epoch {}/{} eval_loss {:.4} (train {:.4})",
                metrics.run_id(),
                epoch + 1,
                self.cfg.epochs,
                loss,
                last_train_loss
            );
        }
        // per-phase wall accounting + steps/sec into the metrics JSONL, so
        // ops-layer wins are visible outside the benches (kss train prints
        // the same breakdown at the end of the run)
        metrics.log_record("phase_times", vec![("timing", self.phases.to_json(self.step_count))]);
        Ok(TrainResult {
            final_loss: metrics.final_loss().unwrap_or(f64::NAN),
            best_loss: metrics.best_loss().unwrap_or(f64::NAN),
            curve: metrics.curve().to_vec(),
            steps: self.step_count,
            last_train_loss: last_train_loss as f64,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn engine() -> Option<Engine> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then(|| Engine::new(&dir).unwrap())
    }

    fn tiny_cfg(sampler: &str, m: usize) -> TrainConfig {
        TrainConfig {
            model: "tiny".into(),
            sampler: sampler.into(),
            m,
            lr: 0.3,
            epochs: 1,
            train_size: 640,
            valid_size: 160,
            eval_batches: 5,
            threads: 2,
            ..Default::default()
        }
    }

    #[test]
    fn full_softmax_baseline_learns() {
        let Some(engine) = engine() else { return };
        let mut t = Trainer::new(&engine, tiny_cfg("full", 0)).unwrap();
        let mut sink = MetricsSink::memory("t");
        let res = t.train(&mut sink).unwrap();
        assert!(res.steps > 10);
        assert!(
            res.final_loss < res.curve[0].loss - 0.1,
            "full softmax must reduce eval loss: {:?}",
            res.curve
        );
    }

    #[test]
    fn sampled_training_sampler_quality_ordering() {
        // The paper's core claim at tiny scale: adaptive samplers (softmax =
        // unbiased oracle, quadratic kernel) learn; uniform at small m
        // (8 of 128 classes) is visibly biased and ends up worse.
        let Some(engine) = engine() else { return };
        let mut finals = std::collections::BTreeMap::new();
        for sampler in ["uniform", "unigram", "softmax", "quadratic", "quadratic-flat", "quartic"] {
            let mut t = Trainer::new(&engine, tiny_cfg(sampler, 8)).unwrap();
            let mut sink = MetricsSink::memory(sampler);
            let res = t.train(&mut sink).unwrap();
            finals.insert(sampler, (res.curve[0].loss, res.final_loss));
        }
        for sampler in ["softmax", "quadratic", "quadratic-flat", "quartic"] {
            let (initial, fin) = finals[sampler];
            assert!(fin < initial - 0.05, "{sampler} failed to learn: {initial} -> {fin}");
        }
        // bias ordering (Figure 2's shape): model-adaptive < static
        assert!(finals["softmax"].1 < finals["uniform"].1, "{finals:?}");
        assert!(finals["quadratic"].1 < finals["uniform"].1, "{finals:?}");
        // the tree sampler and its flat oracle must land close together
        let diff = (finals["quadratic"].1 - finals["quadratic-flat"].1).abs();
        assert!(diff < 0.25, "tree vs flat quadratic diverged: {finals:?}");
    }

    #[test]
    fn bigram_on_lm_dataset_learns() {
        let Some(engine) = engine() else { return };
        let cfg = TrainConfig {
            model: "tiny-lm".into(),
            sampler: "bigram".into(),
            m: 4,
            lr: 0.5,
            epochs: 1,
            train_size: 3_000,
            valid_size: 600,
            eval_batches: 4,
            max_steps_per_epoch: 60,
            ..Default::default()
        };
        let mut t = Trainer::new(&engine, cfg).unwrap();
        let mut sink = MetricsSink::memory("bigram-lm");
        let res = t.train(&mut sink).unwrap();
        assert!(res.final_loss < res.curve[0].loss, "{:?}", res.curve);
    }

    #[test]
    fn deterministic_given_seed() {
        let Some(engine) = engine() else { return };
        let run = |seed: u64| {
            let mut cfg = tiny_cfg("quadratic", 4);
            cfg.seed = seed;
            cfg.epochs = 1;
            cfg.max_steps_per_epoch = 10;
            let mut t = Trainer::new(&engine, cfg).unwrap();
            let mut sink = MetricsSink::memory("det");
            t.train(&mut sink).unwrap().final_loss
        };
        let a = run(9);
        let b = run(9);
        let c = run(10);
        assert_eq!(a, b, "same seed must reproduce exactly");
        assert_ne!(a, c, "different seed should differ");
    }

    #[test]
    fn serving_publisher_tracks_training() {
        // snapshots must advance one generation per sampled step (per
        // touched shard) and agree with the sampler's own mirror
        let Some(engine) = engine() else { return };
        let mut cfg = tiny_cfg("quadratic", 4);
        cfg.max_steps_per_epoch = 6;
        let mut t = Trainer::new(&engine, cfg).unwrap();
        let (stores, offsets) = t.enable_serving(2).unwrap();
        assert_eq!(stores.len(), 2);
        assert!(stores.iter().all(|s| s.generation() == 0));
        let mut sink = MetricsSink::memory("serve-hook");
        t.train(&mut sink).unwrap();
        let stats = t.publish_stats().unwrap();
        assert_eq!(stats.publishes as usize, {
            // every step publishes each shard it touched
            let total: u64 = stores.iter().map(|s| s.generation()).sum();
            total as usize
        });
        assert!(stats.publishes >= 6, "no publishes happened: {stats:?}");
        // published snapshots mirror the trained table: q over the serve
        // snapshots must match the closed form over the live weights
        let w = t.store.out_w().as_f32().unwrap().to_vec();
        let spec = t.spec().clone();
        let h: Vec<f32> = (0..spec.d).map(|i| (i as f32 * 0.37).sin()).collect();
        let snaps: Vec<_> = stores.iter().map(|s| s.load().1).collect();
        let phi = snaps[0].tree.phi_query(&h);
        let total: f64 = snaps.iter().map(|s| s.tree.partition(&phi).max(0.0)).sum();
        let map = crate::sampler::QuadraticMap::new(spec.d, spec.alpha as f64);
        use crate::sampler::kernel::FeatureMap;
        for class in [0usize, spec.n_classes / 2, spec.n_classes - 1] {
            let sid = crate::serve::shard::shard_of_class(&offsets, class);
            let local = class - offsets[sid] as usize;
            let got = snaps[sid].tree.feature_map().kernel(&h, snaps[sid].tree.emb_row(local))
                / total;
            let want = map.kernel(&h, &w[class * spec.d..(class + 1) * spec.d])
                / (0..spec.n_classes)
                    .map(|j| map.kernel(&h, &w[j * spec.d..(j + 1) * spec.d]))
                    .sum::<f64>();
            assert!((got - want).abs() < 1e-6, "class {class}: {got} vs {want}");
        }
    }

    #[test]
    fn m_must_have_artifact() {
        let Some(engine) = engine() else { return };
        let mut cfg = tiny_cfg("uniform", 5); // no m=5 artifact for tiny
        cfg.max_steps_per_epoch = 1;
        let mut t = Trainer::new(&engine, cfg).unwrap();
        let mut sink = MetricsSink::memory("bad-m");
        let err = t.train(&mut sink).unwrap_err();
        assert!(err.to_string().contains("m=5"), "{err}");
    }
}
