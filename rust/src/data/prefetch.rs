//! Double-buffered epoch prefetch: generate epoch `e+1`'s batches on a
//! background thread while epoch `e` trains.
//!
//! The synthetic datasets materialize a full epoch of [`Batch`]es per
//! [`Dataset::train_batches`] call — deterministic, but not free (token
//! stream + tensor staging). The trainer used to pay that on the critical
//! path at every epoch boundary. [`BatchPrefetcher`] moves it off: a
//! `sync_channel(1)` gives classic double buffering (one epoch ready in
//! the buffer, the next being built, never more — bounded memory), and
//! [`BatchPrefetcher::next_epoch`] reports how long the trainer actually
//! waited so the `prefetch` phase in the step breakdown shows whether the
//! hiding worked.
//!
//! Determinism: batches are a pure function of `(dataset, epoch)`; the
//! thread only changes *when* they are built, never what they contain.

use crate::data::{Batch, Dataset};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

/// Background epoch-batch generator (see module docs).
pub struct BatchPrefetcher {
    rx: Option<mpsc::Receiver<(usize, Vec<Batch>)>>,
    handle: Option<JoinHandle<()>>,
}

impl BatchPrefetcher {
    /// Stream `epochs` epochs of training batches, each truncated to
    /// `max_steps` when non-zero (the trainer's `max_steps_per_epoch`).
    pub fn start(dataset: Arc<dyn Dataset>, epochs: usize, max_steps: usize) -> BatchPrefetcher {
        let (tx, rx) = mpsc::sync_channel::<(usize, Vec<Batch>)>(1);
        let handle = std::thread::Builder::new()
            .name("kss-prefetch".into())
            .spawn(move || {
                for epoch in 0..epochs {
                    let mut batches = dataset.train_batches(epoch);
                    if max_steps > 0 {
                        batches.truncate(max_steps);
                    }
                    // a dropped receiver (trainer bailed early) just ends
                    // the stream
                    if tx.send((epoch, batches)).is_err() {
                        return;
                    }
                }
            })
            .expect("spawn batch prefetcher");
        BatchPrefetcher { rx: Some(rx), handle: Some(handle) }
    }

    /// Block for the next epoch's batches. Returns `(epoch, batches,
    /// seconds waited)` — the wait is the non-hidden remainder of the
    /// generation cost — or `None` when every epoch has been consumed.
    pub fn next_epoch(&mut self) -> Option<(usize, Vec<Batch>, f64)> {
        let t0 = Instant::now();
        let rx = self.rx.as_ref()?;
        rx.recv().ok().map(|(epoch, batches)| (epoch, batches, t0.elapsed().as_secs_f64()))
    }
}

impl Drop for BatchPrefetcher {
    fn drop(&mut self) {
        // close the channel first so a blocked producer unblocks, then join
        drop(self.rx.take());
        if let Some(handle) = self.handle.take() {
            let _ = handle.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synptb::SynPtb;

    #[test]
    fn prefetched_epochs_match_direct_generation() {
        let ds: Arc<dyn Dataset> = Arc::new(SynPtb::generate(100, 4, 5, 1_500, 300, 9));
        let mut pf = BatchPrefetcher::start(ds.clone(), 3, 0);
        for want_epoch in 0..3 {
            let (epoch, batches, wait_s) = pf.next_epoch().expect("epoch missing");
            assert_eq!(epoch, want_epoch);
            assert!(wait_s >= 0.0);
            let direct = ds.train_batches(epoch);
            assert_eq!(batches.len(), direct.len());
            for (a, b) in batches.iter().zip(&direct) {
                assert_eq!(a.pos, b.pos, "epoch {epoch}");
                assert_eq!(a.data, b.data, "epoch {epoch}");
                assert_eq!(a.prev, b.prev, "epoch {epoch}");
            }
        }
        assert!(pf.next_epoch().is_none(), "stream must end after the last epoch");
    }

    #[test]
    fn max_steps_truncates_and_early_drop_is_clean() {
        let ds: Arc<dyn Dataset> = Arc::new(SynPtb::generate(100, 4, 5, 2_000, 300, 11));
        let mut pf = BatchPrefetcher::start(ds, 5, 2);
        let (_, batches, _) = pf.next_epoch().unwrap();
        assert_eq!(batches.len(), 2);
        // dropping with epochs still queued must not hang (producer
        // unblocks on the closed channel)
        drop(pf);
    }
}
