"""Inverted multi-index (midx) sampler port checks — no rust toolchain.

Line-for-line Python port of rust/src/sampler/kernel/midx.rs (the two-level
coarse-quantized kernel sampler) validated by the same properties the rust
unit tests assert:

  1. seeded k-means build: deterministic for a fixed seed, with-replacement
     subsample of min(n, 32·K) rows, k-means++ D²-weighting through the
     fill_cum/step_down_to_positive CDF machinery, warm restart copies
     centroids without re-seeding, and the all-degenerate (zero-spread)
     geometry falls back to contiguous even blocks assign[c] = (c·k)/n
  2. coarse-mass CDF exactness: the per-cluster φ-aggregate masses
     M_k = <phi(h), Z_k> equal the direct per-member kernel sums
  3. composed-q algebra: q = (M_k/ΣM)·(K(h,c)/S_k) collapses to the flat
     kernel distribution K(h,c)/ΣK within 1e-12 relative, for prob_of and
     for every drawn (class, q) pair, across an interleaved
     update/reassign schedule (eq. (2) correction exactness)
  4. zero-mass fallbacks: degenerate coarse total -> uniform over all
     classes; positive aggregate with underflowed exact refine -> uniform
     member under the realized coarse step; a genuinely zero-mass cluster
     is unreachable (prob_of = 0) and never drawn — q strictly positive
     in every reachable case
  5. incremental aggregate maintenance: Z_k += phi(w_new) - phi(w_old)
     stays within float drift of a from-scratch rebuild across a long
     interleaved update schedule, and a sweep squashes the drift exactly
  6. chi-square goodness of fit of draws against the composed proposal

The RNG core (xoshiro256** + splitmix64) is imported from
rff_port_check.py, the feature maps and CDF guards from
serve_port_check.py / vocab_port_check.py, and the q-positivity guard
from two_pass_port_check.py — the same layering the rust module uses.

Run: python3 python/tools/midx_port_check.py
"""
import math
import os
import sys

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from rff_port_check import MASK, RustRng  # noqa: E402
from serve_port_check import (  # noqa: E402
    QuadraticMap,
    ZeroMap,
    exact_dist,
    sanitize_mass,
    step_down_to_positive,
)
from two_pass_port_check import positive_pool_mass  # noqa: E402
from vocab_port_check import fill_cum, sample_cum  # noqa: E402

# rust f64::MIN_POSITIVE (smallest positive normal), the q clamp floor
F64_MIN_POSITIVE = 2.2250738585072014e-308

MIDX_BUILD_SEED = 0x1DA8_5EED_91B7_4C21
SEED_SAMPLE_PER_CLUSTER = 32
DEFAULT_LLOYD_ITERS = 2


def rng_below(rng, n):
    """Port of util::rng::Rng::below — Lemire's unbiased bounded draw."""
    assert n > 0, "below(0) is undefined"
    x = rng.next_u64()
    m = x * n
    lo = m & MASK
    if lo < n:
        t = ((1 << 64) - n) % n  # n.wrapping_neg() % n
        while lo < t:
            x = rng.next_u64()
            m = x * n
            lo = m & MASK
    return m >> 64


class _CdfRng:
    """Adapt RustRng to the .random() protocol sample_cum expects, so the
    CDF draw consumes the exact rng.f64() stream the rust draw path does."""

    def __init__(self, rng):
        self.rng = rng

    def random(self):
        return self.rng.f64()


def dot_f32(a, b):
    """Port of ops scalar dot_f32: sequential f64 accumulation."""
    return sum(float(x) * float(y) for x, y in zip(a, b))


def default_clusters(n):
    """Port of midx::default_clusters — K = ceil(sqrt(n)), clamped [1, n]."""
    n = max(n, 1)
    return min(max(int(math.ceil(math.sqrt(float(n)))), 1), n)


class MidxScratch:
    def __init__(self, k):
        self.phi_h = None
        self.masses = [0.0] * k
        self.coarse_cum = [0.0] * k
        self.coarse_total = 0.0
        self.wcum = [None] * k  # per-cluster inclusive CDF segments
        self.inner_total = [0.0] * k
        self.stamp = [0] * k
        self.epoch = 0
        self.o_coarse = 0
        self.o_refine = 0
        self.o_zero = 0


class MidxIndex:
    """Port of midx::MidxIndex: the two-level index (assignment, blocked
    member panel, per-cluster phi-aggregates, centroids)."""

    def __init__(self, fmap, emb, n, d, clusters=None, lloyd_iters=DEFAULT_LLOYD_ITERS,
                 seed=0, warm=None):
        assert n > 0 and d > 0
        self.n, self.d = n, d
        self.fmap = fmap
        self.dim = fmap.dim()
        k = min(max(clusters, 1), n) if clusters is not None else default_clusters(n)
        self.k = k
        self.assign = [0] * n
        self.panel_lo = [0] * (k + 1)
        self.member = [0] * n
        self.slot_of = [0] * n
        self.packed = np.zeros((n, d), dtype=np.float32)
        self.zstats = np.zeros((k, self.dim), dtype=np.float64)
        self.centroids = np.zeros((k, d), dtype=np.float32)
        if warm is not None and warm.d == d and warm.k == k:
            self.centroids[:] = warm.centroids
            seeded = True
        else:
            seeded = self.seed_centroids(emb, seed)
        if seeded:
            for _ in range(lloyd_iters):
                self.assign_all(emb)
                self.recompute_centroids(emb)
            self.assign_all(emb)
        else:
            for c in range(n):
                self.assign[c] = (c * k) // n
            self.recompute_centroids(emb)
        self.finalize(emb)

    def seed_centroids(self, emb, seed):
        n, d, k = self.n, self.d, self.k
        rng = RustRng((seed ^ MIDX_BUILD_SEED) & MASK)
        cap = max(SEED_SAMPLE_PER_CLUSTER * k, 1)
        if n <= cap:
            sample = list(range(n))
        else:
            sample = [rng_below(rng, n) for _ in range(cap)]
        s = len(sample)
        norm2 = [dot_f32(emb[c], emb[c]) for c in sample]
        first = sample[rng_below(rng, s)]
        self.centroids[0] = emb[first]
        first_n2 = dot_f32(emb[first], emb[first])
        best2 = [
            sanitize_mass(norm2[j] - 2.0 * dot_f32(emb[c], emb[first]) + first_n2)
            for j, c in enumerate(sample)
        ]
        cdf_rng = _CdfRng(rng)
        for nxt in range(1, k):
            cum, total = fill_cum(best2)
            spread = positive_pool_mass(total)
            if spread is None:
                return nxt > 1
            pick = sample[step_down_to_positive(cum, sample_cum(cum, spread, cdf_rng))]
            mu = emb[pick]
            mu_n2 = dot_f32(mu, mu)
            self.centroids[nxt] = mu
            for j, c in enumerate(sample):
                d2 = sanitize_mass(norm2[j] - 2.0 * dot_f32(emb[c], mu) + mu_n2)
                best2[j] = min(best2[j], d2)
        return True

    def assign_all(self, emb):
        n, k = self.n, self.k
        half_norm = [0.5 * dot_f32(self.centroids[j], self.centroids[j]) for j in range(k)]
        for c in range(n):
            best, best_s = 0, dot_f32(emb[c], self.centroids[0]) - half_norm[0]
            for j in range(1, k):
                score = dot_f32(emb[c], self.centroids[j]) - half_norm[j]
                if score > best_s:  # strict: ties keep the lowest cluster id
                    best_s, best = score, j
            self.assign[c] = best

    def recompute_centroids(self, emb):
        n, d, k = self.n, self.d, self.k
        sums = np.zeros((k, d), dtype=np.float64)
        counts = [0] * k
        for c in range(n):
            kc = self.assign[c]
            counts[kc] += 1
            sums[kc] += emb[c].astype(np.float64)
        for j in range(k):
            if counts[j] == 0:
                continue  # empty clusters keep their previous centroid
            self.centroids[j] = (sums[j] / counts[j]).astype(np.float32)

    def finalize(self, emb):
        n, k = self.n, self.k
        counts = [0] * k
        for a in self.assign:
            counts[a] += 1
        self.panel_lo[0] = 0
        for j in range(k):
            self.panel_lo[j + 1] = self.panel_lo[j] + counts[j]
        cursor = list(self.panel_lo[:k])
        for c in range(n):  # ascending class id within each cluster
            kc = self.assign[c]
            slot = cursor[kc]
            self.member[slot] = c
            self.slot_of[c] = slot
            cursor[kc] += 1
        for slot in range(n):
            self.packed[slot] = emb[self.member[slot]]
        self.zstats[:] = 0.0
        for slot in range(n):  # canonical aggregation order: slot order
            kc = self.assign[self.member[slot]]
            self.zstats[kc] += self.fmap.phi(self.packed[slot])
        return self

    def sweep(self, emb):
        self.recompute_centroids(emb)
        self.assign_all(emb)
        self.finalize(emb)

    def apply_update(self, class_, w_new, emb):
        kc = self.assign[class_]
        old = emb[class_]
        phi_old = self.fmap.phi(old)
        phi_new = self.fmap.phi(w_new)
        drift2 = sanitize_mass(
            dot_f32(old, old) - 2.0 * dot_f32(old, w_new) + dot_f32(w_new, w_new)
        )
        self.zstats[kc] += phi_new
        self.zstats[kc] -= phi_old
        emb[class_] = w_new
        self.packed[self.slot_of[class_]] = w_new
        return math.sqrt(drift2)

    def new_scratch(self):
        return MidxScratch(self.k)

    def begin_example(self, h, s):
        s.epoch = (s.epoch + 1) & 0xFFFF_FFFF
        if s.epoch == 0:
            s.stamp = [0] * self.k
            s.epoch = 1
        s.phi_h = self.fmap.phi(h)
        for j in range(self.k):
            s.masses[j] = sanitize_mass(dot_f32(s.phi_h, self.zstats[j]))
        s.coarse_cum, s.coarse_total = fill_cum(s.masses)

    def refine(self, h, kc, s):
        lo, hi = self.panel_lo[kc], self.panel_lo[kc + 1]
        kv = [sanitize_mass(self.fmap.kernel(h, self.packed[slot])) for slot in range(lo, hi)]
        s.wcum[kc], s.inner_total[kc] = fill_cum(kv)
        s.stamp[kc] = s.epoch
        s.o_refine += 1

    def draw(self, h, s, rng):
        coarse_mass = positive_pool_mass(s.coarse_total)
        if coarse_mass is None:
            s.o_zero += 1
            slot = rng_below(rng, self.n)
            return self.member[slot], max(1.0 / self.n, F64_MIN_POSITIVE)
        s.o_coarse += 1
        cdf_rng = _CdfRng(rng)
        kc = step_down_to_positive(s.coarse_cum, sample_cum(s.coarse_cum, coarse_mass, cdf_rng))
        inc = s.coarse_cum[kc] - (0.0 if kc == 0 else s.coarse_cum[kc - 1])
        p_coarse = inc / coarse_mass
        if s.stamp[kc] != s.epoch:
            self.refine(h, kc, s)
        lo, hi = self.panel_lo[kc], self.panel_lo[kc + 1]
        assert hi > lo, "selected cluster has positive mass but no members"
        cluster_mass = positive_pool_mass(s.inner_total[kc])
        if cluster_mass is None:
            s.o_zero += 1
            slot = lo + rng_below(rng, hi - lo)
            return self.member[slot], max(p_coarse / (hi - lo), F64_MIN_POSITIVE)
        seg = s.wcum[kc]
        j = step_down_to_positive(seg, sample_cum(seg, cluster_mass, cdf_rng))
        w = seg[j] - (0.0 if j == 0 else seg[j - 1])
        q = max(p_coarse * (w / cluster_mass), F64_MIN_POSITIVE)
        return self.member[lo + j], q

    def prob_of(self, h, class_, s):
        kc = self.assign[class_]
        coarse_mass = positive_pool_mass(s.coarse_total)
        if coarse_mass is None:
            return max(1.0 / self.n, F64_MIN_POSITIVE)
        inc = s.coarse_cum[kc] - (0.0 if kc == 0 else s.coarse_cum[kc - 1])
        if inc <= 0.0:
            return 0.0  # zero-aggregate cluster: unreachable via the coarse CDF
        p_coarse = inc / coarse_mass
        if s.stamp[kc] != s.epoch:
            self.refine(h, kc, s)
        lo, hi = self.panel_lo[kc], self.panel_lo[kc + 1]
        cluster_mass = positive_pool_mass(s.inner_total[kc])
        if cluster_mass is None:
            return max(p_coarse / (hi - lo), F64_MIN_POSITIVE)
        j = self.slot_of[class_] - lo
        seg = s.wcum[kc]
        w = seg[j] - (0.0 if j == 0 else seg[j - 1])
        if w <= 0.0:
            return 0.0
        return max(p_coarse * (w / cluster_mass), F64_MIN_POSITIVE)


# --- case builders --------------------------------------------------------


def make_emb(rng, n, d, std=0.3):
    return np.array(
        [[float(rng.normal_f32(0.0, std)) for _ in range(d)] for _ in range(n)],
        dtype=np.float32,
    )


def make_h(rng, d):
    return np.array([float(rng.normal_f32(0.0, 1.0)) for _ in range(d)], dtype=np.float32)


class DotMap:
    """phi(a) = a (so K(a, b) = <a, b> can be negative and sanitize to 0):
    exercises the unreachable zero-aggregate-cluster branch honestly."""

    def __init__(self, d):
        self.d, self.alpha = d, 0.0

    def dim(self):
        return self.d

    def phi(self, a):
        return np.array([float(x) for x in a], dtype=np.float64)

    def kernel(self, a, b):
        return dot_f32(a, b)


class CountMap:
    """phi(a) = [1] but kernel = 0: positive coarse aggregates whose exact
    refine underflows — the inner uniform-member fallback path."""

    def __init__(self, d):
        self.d, self.alpha = d, 0.0

    def dim(self):
        return 1

    def phi(self, a):
        return np.ones(1, dtype=np.float64)

    def kernel(self, a, b):
        return 0.0


# --- 1: seeded k-means build ----------------------------------------------


def check_kmeans_build():
    d = 6
    rng = RustRng(31)
    emb = make_emb(rng, 200, d)
    a = MidxIndex(QuadraticMap(d, 100.0), emb.copy(), 200, d, seed=7)
    b = MidxIndex(QuadraticMap(d, 100.0), emb.copy(), 200, d, seed=7)
    assert np.array_equal(a.centroids, b.centroids)
    assert a.assign == b.assign and a.member == b.member
    c = MidxIndex(QuadraticMap(d, 100.0), emb.copy(), 200, d, seed=8)
    assert not np.array_equal(a.centroids, c.centroids), "seed must steer seeding"
    assert a.k == default_clusters(200) == 15
    # layout invariants: blocked members, ascending within cluster, exact cover
    assert a.panel_lo[0] == 0 and a.panel_lo[-1] == 200
    assert sorted(a.member) == list(range(200))
    for j in range(a.k):
        seg = a.member[a.panel_lo[j]:a.panel_lo[j + 1]]
        assert seg == sorted(seg)
        assert all(a.assign[cls] == j for cls in seg)
    for cls in range(200):
        assert a.member[a.slot_of[cls]] == cls
    # warm restart: centroids copied verbatim, no re-seeding
    w = MidxIndex(QuadraticMap(d, 100.0), emb.copy(), 200, d, lloyd_iters=0,
                  seed=999, warm=a)
    assert np.array_equal(w.centroids, a.centroids)
    # with-replacement subsample cap: n > 32·K path still balanced
    big = make_emb(RustRng(32), 600, d)
    big_idx = MidxIndex(QuadraticMap(d, 100.0), big.copy(), 600, d, clusters=4, seed=1)
    assert big_idx.panel_lo[-1] == 600 and 600 > SEED_SAMPLE_PER_CLUSTER * 4
    # degenerate geometry (all-zero table): contiguous even blocks
    zero = np.zeros((50, d), dtype=np.float32)
    z = MidxIndex(QuadraticMap(d, 100.0), zero.copy(), 50, d, clusters=4, seed=3)
    assert z.assign == [(cidx * 4) // 50 for cidx in range(50)]
    # k-means++ D² weighting: two far blobs, K=2 -> one blob per cluster
    blob = np.zeros((40, 3), dtype=np.float32)
    blob[:20, 0], blob[20:, 1] = 10.0, -10.0
    blob += make_emb(RustRng(33), 40, 3, std=0.05)
    two = MidxIndex(QuadraticMap(3, 100.0), blob.copy(), 40, 3, clusters=2, seed=5)
    left = {two.assign[i] for i in range(20)}
    right = {two.assign[i] for i in range(20, 40)}
    assert len(left) == 1 and len(right) == 1 and left != right
    print("  seeded k-means build: deterministic, blocked layout, warm restart, "
          "even-block degenerate fallback, D² separation: OK")


# --- 2: coarse-mass CDF exactness -----------------------------------------


def check_coarse_aggregates():
    d = 4
    rng = RustRng(41)
    fmap = QuadraticMap(d, 100.0)
    emb = make_emb(rng, 120, d)
    idx = MidxIndex(fmap, emb.copy(), 120, d, seed=2)
    s = idx.new_scratch()
    for _ in range(4):
        h = make_h(rng, d)
        idx.begin_example(h, s)
        for j in range(idx.k):
            lo, hi = idx.panel_lo[j], idx.panel_lo[j + 1]
            direct = sum(fmap.kernel(h, idx.packed[slot]) for slot in range(lo, hi))
            rel = abs(s.masses[j] - direct) / max(direct, 1.0)
            assert rel <= 1e-12, (j, s.masses[j], direct)
        assert abs(s.coarse_total - sum(s.masses)) <= 1e-9 * s.coarse_total
    print("  coarse aggregates M_k = <phi(h), Z_k> match direct kernel sums "
          "(rel <= 1e-12): OK")


# --- 3: composed-q algebra across updates/sweeps --------------------------


def check_composed_q_exact():
    d, n = 4, 64
    rng = RustRng(51)
    fmap = QuadraticMap(d, 100.0)
    emb = make_emb(rng, n, d)
    idx = MidxIndex(fmap, emb, n, d, seed=11)
    s = idx.new_scratch()
    worst = 0.0
    for round_ in range(6):
        h = make_h(rng, d)
        idx.begin_example(h, s)
        flat = exact_dist(fmap, h, emb)
        for cls in range(n):
            q = idx.prob_of(h, cls, s)
            rel = abs(q - flat[cls]) / flat[cls]
            worst = max(worst, rel)
            assert rel <= 1e-12, (round_, cls, q, flat[cls])
        for _ in range(32):  # drawn q must equal prob_of bit-for-bit
            cls, q = idx.draw(h, s, rng)
            assert q == idx.prob_of(h, cls, s), (cls, q)
        # interleave: perturb a few classes, sweep every other round
        for _ in range(5):
            cls = rng_below(rng, n)
            w_new = make_h(rng, d) * np.float32(0.3)
            idx.apply_update(cls, w_new.astype(np.float32), emb)
        if round_ % 2 == 1:
            idx.sweep(emb)
    print(f"  composed q == flat K(h,c)/ΣK across update/sweep schedule "
          f"(worst rel {worst:.2e} <= 1e-12): OK")


# --- 4: zero-mass fallbacks -----------------------------------------------


def check_zero_mass_fallbacks():
    d, n = 3, 30
    rng = RustRng(61)
    emb = make_emb(rng, n, d)
    h = make_h(rng, d)

    # total coarse degenerate (ZeroMap): uniform over all classes, exact q
    zi = MidxIndex(ZeroMap(d), emb.copy(), n, d, clusters=4, seed=1)
    s = zi.new_scratch()
    zi.begin_example(h, s)
    assert s.coarse_total == 0.0
    seen = set()
    for _ in range(600):
        cls, q = zi.draw(h, s, rng)
        assert q == max(1.0 / n, F64_MIN_POSITIVE)
        seen.add(cls)
    assert seen == set(range(n)), "uniform fallback must cover every class"
    assert s.o_zero == 600 and s.o_coarse == 0
    assert all(zi.prob_of(h, cls, s) == 1.0 / n for cls in range(n))

    # positive aggregate, underflowed refine (CountMap): uniform member
    ci = MidxIndex(CountMap(d), emb.copy(), n, d, clusters=4, seed=1)
    s = ci.new_scratch()
    ci.begin_example(h, s)
    assert positive_pool_mass(s.coarse_total) is not None
    for _ in range(200):
        cls, q = ci.draw(h, s, rng)
        kc = ci.assign[cls]
        length = ci.panel_lo[kc + 1] - ci.panel_lo[kc]
        inc = s.coarse_cum[kc] - (0.0 if kc == 0 else s.coarse_cum[kc - 1])
        assert q == max(inc / s.coarse_total / length, F64_MIN_POSITIVE)
        assert q == ci.prob_of(h, cls, s)
    assert s.o_zero == 200

    # genuinely zero-mass cluster (DotMap, opposing blobs): unreachable
    blob = np.zeros((20, d), dtype=np.float32)
    blob[:10, 0], blob[10:, 0] = 2.0, -2.0
    blob += make_emb(RustRng(62), 20, d, std=0.05)
    di = MidxIndex(DotMap(d), blob.copy(), 20, d, clusters=2, seed=4)
    hp = np.array([1.0, 0.0, 0.0], dtype=np.float32)
    s = di.new_scratch()
    di.begin_example(hp, s)
    dead = [j for j in range(di.k) if s.masses[j] == 0.0]
    assert len(dead) == 1, "one blob must aggregate to non-positive mass"
    for cls in range(20):
        p = di.prob_of(hp, cls, s)
        if di.assign[cls] == dead[0]:
            assert p == 0.0
        else:
            assert p > 0.0
    for _ in range(400):
        cls, q = di.draw(hp, s, rng)
        assert di.assign[cls] != dead[0] and q > 0.0
    print("  zero-mass fallbacks: uniform-over-n, uniform-member, dead cluster "
          "unreachable, q > 0 on every reachable path: OK")


# --- 5: incremental aggregates vs rebuild ---------------------------------


def check_aggregate_matches_rebuild():
    d, n = 5, 80
    rng = RustRng(71)
    fmap = QuadraticMap(d, 100.0)
    emb = make_emb(rng, n, d)
    idx = MidxIndex(fmap, emb, n, d, seed=9)
    for step in range(120):
        cls = rng_below(rng, n)
        w_new = (make_h(rng, d) * np.float32(0.3)).astype(np.float32)
        w_old = emb[cls].copy()
        drift = idx.apply_update(cls, w_new, emb)
        assert abs(drift**2 - float(np.sum(
            (w_old.astype(np.float64) - w_new.astype(np.float64)) ** 2))) <= 1e-6
    rebuilt = np.zeros_like(idx.zstats)
    for slot in range(n):
        rebuilt[idx.assign[idx.member[slot]]] += fmap.phi(idx.packed[slot])
    scale = np.abs(rebuilt).max()
    assert np.abs(idx.zstats - rebuilt).max() <= 1e-9 * scale, "incremental drift"
    idx.sweep(emb)  # the compaction analogy: sweep rebuilds from scratch
    resweep = np.zeros_like(idx.zstats)
    for slot in range(n):
        resweep[idx.assign[idx.member[slot]]] += fmap.phi(idx.packed[slot])
    assert np.array_equal(idx.zstats, resweep), "sweep must equal exact rebuild"
    print("  incremental Z_k += phi(new) - phi(old) matches rebuild "
          "(<= 1e-9 rel), sweep squashes drift exactly: OK")


# --- 6: chi-square GOF of draws vs the composed proposal ------------------


def check_chi_square_draws():
    d, n = 3, 40
    rng = RustRng(81)
    fmap = QuadraticMap(d, 100.0)
    emb = make_emb(rng, n, d)
    idx = MidxIndex(fmap, emb, n, d, seed=13)
    h = make_h(rng, d)
    s = idx.new_scratch()
    idx.begin_example(h, s)
    probs = [idx.prob_of(h, cls, s) for cls in range(n)]
    assert abs(sum(probs) - 1.0) <= 1e-12
    draws = 60_000
    counts = [0] * n
    for _ in range(draws):
        cls, _ = idx.draw(h, s, rng)
        counts[cls] += 1
    stat = sum(
        (counts[j] - probs[j] * draws) ** 2 / (probs[j] * draws)
        for j in range(n)
        if probs[j] * draws >= 1.0
    )
    dof = sum(1 for pj in probs if pj * draws >= 1.0) - 1
    bound = dof + 6 * math.sqrt(2 * dof)
    assert stat < bound, (stat, dof, bound)
    print(f"  chi-square GOF on the composed proposal (chi2 {stat:.1f}, "
          f"dof {dof}): OK")


if __name__ == "__main__":
    print("midx (inverted multi-index) port checks:")
    check_kmeans_build()
    check_coarse_aggregates()
    check_composed_q_exact()
    check_zero_mass_fallbacks()
    check_aggregate_matches_rebuild()
    check_chi_square_draws()
    print("all midx port checks passed")
