//! Flat (exact, O(n)) kernel sampling — the oracle the tree is tested
//! against, and the only implementation for kernels whose feature map is
//! intractable (quartic: D = O(d⁴)) or infinite-dimensional (exact exp,
//! the `"rff-flat"` oracle the random-feature tree approximates).
//!
//! Consumes the logits row `o = W h` (from the score_all artifact, the same
//! input the exact-softmax sampler uses) since all of these kernels are
//! functions of the dot product: `K = f(⟨h, w_i⟩)`.
//!
//! Steady-state sampling allocates nothing: the per-row weight and CDF
//! buffers live in a [`Pool`]-backed scratch checked out per call (and per
//! worker in the batched path), the same freelist discipline as the tree's
//! `DrawScratch`. `Exp` rows are weighted relative to their max logit
//! ([`crate::ops::row_max`] via [`KernelKind::shift`]), so the oracle is
//! overflow-proof at any logit scale. The CDF fill is
//! [`crate::ops::fill_cum`]: weights are cast to f32 per element but the
//! prefix sums accumulate in f64 — the long sum is never f32.
//!
//! # Dense-index contract
//!
//! Like [`crate::util::rng::Cdf`], everything here is **slot-addressed**:
//! the logits row position `j` *is* the class id, dense `0..C`. A holey id
//! space (streaming vocabulary after retirement) must not reach this
//! sampler directly — a global id used as a row index aliases into another
//! class's logit and reports a plausible but wrong q. Holey catalogs go
//! through `crate::vocab` (tree tiers) or [`crate::util::rng::IdCdf`]
//! (flat), both of which carry the id→slot map explicitly.

use super::KernelKind;
use crate::sampler::{row_rng, BatchSampleInput, Needs, Sample, SampleInput, Sampler};
use crate::util::rng::{fill_cum, sample_cum, Rng};
use crate::util::threadpool::{par_chunks_mut, Pool};
use anyhow::Result;
use std::sync::Mutex;

/// Reusable per-caller buffers: shifted weights and their inclusive f64
/// prefix sums (the same arithmetic `util::rng::Cdf` uses, kept in a
/// caller-owned arena so repeated rows never reallocate).
#[derive(Default)]
struct FlatScratch {
    w: Vec<f32>,
    cum: Vec<f64>,
}

/// One row's precomputed sampling state: the `Exp` shift and the total
/// kernel mass. [`FlatKernelSampler::prob_prepared`] answers per-class
/// probability queries in O(1) against it instead of re-summing all n
/// logits per class.
#[derive(Clone, Copy, Debug)]
pub struct PreparedRow {
    shift: f64,
    total: f64,
}

impl PreparedRow {
    /// Total (shifted) kernel mass of the row.
    pub fn total(&self) -> f64 {
        self.total
    }
}

/// Exact sampler for `q_i ∝ f(o_i)`.
pub struct FlatKernelSampler {
    kind: KernelKind,
    /// Freelist of weight/CDF scratches (bounded by max concurrent users).
    scratch_pool: Pool<FlatScratch>,
}

impl FlatKernelSampler {
    pub fn new(kind: KernelKind) -> FlatKernelSampler {
        FlatKernelSampler { kind, scratch_pool: Pool::new() }
    }

    /// Precompute the row's shift + total once (O(n)); pair with
    /// [`Self::prob_prepared`] for O(1) per-class queries. Callers scoring
    /// many classes of one row (tests, the gradient-bias bench) should use
    /// this instead of [`Sampler::prob`], which prepares per call.
    pub fn prepare(&self, logits: &[f32]) -> PreparedRow {
        let shift = self.kind.shift(logits);
        let total: f64 = logits.iter().map(|&o| self.kind.weight_shifted(o, shift)).sum();
        PreparedRow { shift, total }
    }

    /// Probability of `class` given a row prepared by [`Self::prepare`].
    pub fn prob_prepared(&self, prepared: &PreparedRow, logits: &[f32], class: u32) -> f64 {
        self.kind.weight_shifted(logits[class as usize], prepared.shift) / prepared.total
    }

    /// Fill the scratch's weight + CDF arenas for one row and draw `m`
    /// samples — the single code path behind both `sample` and
    /// `sample_batch`, so the batched result is the per-row stream by
    /// construction. Draw semantics are [`sample_cum`]'s (the same
    /// implementation `Cdf` uses), so the zero-weight-tail invariant lives
    /// in one place; only the buffers are caller-owned here.
    fn sample_into(
        &self,
        logits: &[f32],
        m: usize,
        rng: &mut Rng,
        s: &mut FlatScratch,
        out: &mut Sample,
    ) -> Result<()> {
        out.clear();
        let shift = self.kind.shift(logits);
        s.w.clear();
        s.w.extend(logits.iter().map(|&o| self.kind.weight_shifted(o, shift) as f32));
        let total = fill_cum(&s.w, &mut s.cum);
        anyhow::ensure!(total > 0.0 && total.is_finite(), "degenerate kernel weights");
        for _ in 0..m {
            let idx = sample_cum(&s.cum, total, rng);
            let lo = if idx == 0 { 0.0 } else { s.cum[idx - 1] };
            let q = (s.cum[idx] - lo) / total;
            // the clamp keeps q > 0 even if the ratio to a huge total
            // underflows
            out.push(idx as u32, q.max(f64::MIN_POSITIVE));
        }
        Ok(())
    }
}

impl Sampler for FlatKernelSampler {
    fn name(&self) -> &str {
        self.kind.name()
    }

    fn needs(&self) -> Needs {
        Needs { logits: true, ..Needs::default() }
    }

    fn sample(&self, input: &SampleInput, m: usize, rng: &mut Rng, out: &mut Sample) -> Result<()> {
        let logits =
            input.logits.ok_or_else(|| anyhow::anyhow!("flat kernel sampler needs logits"))?;
        let mut scratch = self.scratch_pool.take(FlatScratch::default);
        let res = self.sample_into(logits, m, rng, &mut scratch, out);
        self.scratch_pool.put(scratch);
        res
    }

    /// Batched engine: one weight/CDF scratch per worker, reused across all
    /// of that worker's rows (zero steady-state allocation — the default
    /// fan-out would pay a fresh weight `Vec` + `Cdf` per row). Row `i`
    /// draws from [`row_rng`]`(step_seed, i)`, bit-identical to the
    /// per-example loop: both paths run [`Self::sample_into`].
    ///
    /// Shape validation cannot rule out a *degenerate* row (NaN logits
    /// from a diverging model, or weights overflowing the f32 cast), which
    /// the per-row path reports as a recoverable `Err` — so the fan-out
    /// records the first failure and surfaces it instead of panicking a
    /// worker.
    fn sample_batch(
        &self,
        inputs: &BatchSampleInput,
        m: usize,
        step_seed: u64,
        out: &mut [Sample],
    ) -> Result<()> {
        anyhow::ensure!(
            out.len() == inputs.n,
            "out has {} slots, batch has {} rows",
            out.len(),
            inputs.n
        );
        inputs.validate(self.name(), self.needs())?;
        let logits_all = inputs.logits.expect("validated: flat kernel needs logits");
        let nc = inputs.n_classes;
        let failed: Mutex<Option<anyhow::Error>> = Mutex::new(None);
        par_chunks_mut(out, inputs.threads, |base, chunk| {
            let mut scratch = self.scratch_pool.take(FlatScratch::default);
            for (k, slot) in chunk.iter_mut().enumerate() {
                let i = base + k;
                let logits = &logits_all[i * nc..(i + 1) * nc];
                let mut rng = row_rng(step_seed, i);
                if let Err(e) = self.sample_into(logits, m, &mut rng, &mut scratch, slot) {
                    let mut first = failed.lock().expect("failure slot poisoned");
                    first.get_or_insert(e.context(format!("batch row {i}")));
                    break;
                }
            }
            self.scratch_pool.put(scratch);
        });
        match failed.into_inner().expect("failure slot poisoned") {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    fn prob(&self, input: &SampleInput, class: u32) -> Option<f64> {
        let logits = input.logits?;
        let prepared = self.prepare(logits);
        Some(self.prob_prepared(&prepared, logits, class))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::test_util::empirical_tv;
    use crate::util::stats::chi_square_stat;

    #[test]
    fn quadratic_flat_matches_kernel_distribution() {
        let logits = vec![0.0f32, 1.0, -1.0, 2.0, 0.5];
        let s = FlatKernelSampler::new(KernelKind::Quadratic { alpha: 100.0 });
        let input = SampleInput { logits: Some(&logits), ..Default::default() };
        let w: Vec<f64> = logits.iter().map(|&o| 100.0 * (o as f64).powi(2) + 1.0).collect();
        let z: f64 = w.iter().sum();
        let expected: Vec<f64> = w.iter().map(|x| x / z).collect();
        for c in 0..5u32 {
            assert!((s.prob(&input, c).unwrap() - expected[c as usize]).abs() < 1e-9);
        }
        let tv = empirical_tv(&s, &input, &expected, 200_000, 13);
        assert!(tv < 0.02, "tv {tv}");
        // symmetry: o = ±1 get the same probability
        assert!((s.prob(&input, 1).unwrap() - s.prob(&input, 2).unwrap()).abs() < 1e-12);
    }

    #[test]
    fn quartic_sharper_than_quadratic() {
        // quartic upweights large logits more aggressively
        let logits = vec![0.1f32, 3.0];
        let quad = FlatKernelSampler::new(KernelKind::Quadratic { alpha: 1.0 });
        let quart = FlatKernelSampler::new(KernelKind::Quartic);
        let input = SampleInput { logits: Some(&logits), ..Default::default() };
        assert!(quart.prob(&input, 1).unwrap() > quad.prob(&input, 1).unwrap());
    }

    #[test]
    fn zero_logits_fall_back_to_uniform() {
        let logits = vec![0.0f32; 8];
        let s = FlatKernelSampler::new(KernelKind::Quadratic { alpha: 100.0 });
        let input = SampleInput { logits: Some(&logits), ..Default::default() };
        for c in 0..8u32 {
            assert!((s.prob(&input, c).unwrap() - 0.125).abs() < 1e-12);
        }
    }

    #[test]
    fn exp_flat_is_the_softmax_distribution() {
        // q ∝ exp(o) IS softmax(o): the Theorem 2.1 unbiased distribution,
        // and the target the random-feature tree approximates
        let logits = vec![0.4f32, -1.2, 2.0, 0.0, -0.3, 1.1];
        let s = FlatKernelSampler::new(KernelKind::Exp);
        assert_eq!(s.name(), "rff-flat");
        let input = SampleInput { logits: Some(&logits), ..Default::default() };
        let mx = 2.0f64;
        let w: Vec<f64> = logits.iter().map(|&o| ((o as f64) - mx).exp()).collect();
        let z: f64 = w.iter().sum();
        for c in 0..logits.len() as u32 {
            let want = w[c as usize] / z;
            let got = s.prob(&input, c).unwrap();
            assert!((got - want).abs() < 1e-12 * want.max(1e-12), "class {c}: {got} vs {want}");
        }
        // huge logits: the shift keeps weights finite and the distribution
        // unchanged relative to the small-logit row (tolerance: f32
        // rounding of o + 400 perturbs exponents by ~3e-5)
        let big: Vec<f32> = logits.iter().map(|&o| o + 400.0).collect();
        let input_big = SampleInput { logits: Some(&big), ..Default::default() };
        for c in 0..logits.len() as u32 {
            let a = s.prob(&input, c).unwrap();
            let b = s.prob(&input_big, c).unwrap();
            assert!((a - b).abs() < 1e-3 * a.max(1e-12), "class {c}: {a} vs {b}");
        }
    }

    #[test]
    fn quartic_flat_chi_square_goodness_of_fit() {
        // empirical draw counts on the quartic path against the closed-form
        // distribution (the flat sampler's sampling, not just prob())
        let mut rng = Rng::new(41);
        let logits: Vec<f32> = (0..40).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let s = FlatKernelSampler::new(KernelKind::Quartic);
        let input = SampleInput { logits: Some(&logits), ..Default::default() };
        let w: Vec<f64> = logits.iter().map(|&o| (o as f64).powi(4) + 1.0).collect();
        let z: f64 = w.iter().sum();
        let expected: Vec<f64> = w.iter().map(|x| x / z).collect();
        let mut counts = vec![0u64; logits.len()];
        let mut out = Sample::default();
        let draws = 200_000usize;
        let m = 50;
        for _ in 0..draws / m {
            s.sample(&input, m, &mut rng, &mut out).unwrap();
            for &c in &out.classes {
                counts[c as usize] += 1;
            }
        }
        let stat = chi_square_stat(&counts, &expected, draws as f64);
        // df = 39; mean 39, std √78 ≈ 8.8 — 39 + 5σ ≈ 83
        assert!(stat < 83.0, "chi-square {stat} too large for df=39");
    }

    #[test]
    fn exp_flat_chi_square_goodness_of_fit() {
        // the rff-flat oracle must *sample* softmax(o), not just report it
        let mut rng = Rng::new(43);
        let logits: Vec<f32> = (0..30).map(|_| rng.normal_f32(0.0, 1.2)).collect();
        let s = FlatKernelSampler::new(KernelKind::Exp);
        let input = SampleInput { logits: Some(&logits), ..Default::default() };
        let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
        let w: Vec<f64> = logits.iter().map(|&o| ((o as f64) - mx).exp()).collect();
        let z: f64 = w.iter().sum();
        let expected: Vec<f64> = w.iter().map(|x| x / z).collect();
        let mut counts = vec![0u64; logits.len()];
        let mut out = Sample::default();
        let draws = 200_000usize;
        let m = 50;
        for _ in 0..draws / m {
            s.sample(&input, m, &mut rng, &mut out).unwrap();
            for &c in &out.classes {
                counts[c as usize] += 1;
            }
        }
        let stat = chi_square_stat(&counts, &expected, draws as f64);
        // df = 29; mean 29, std √58 ≈ 7.6 — 29 + 5σ ≈ 67
        assert!(stat < 67.0, "chi-square {stat} too large for df=29");
    }

    #[test]
    fn flat_sample_batch_reproduces_per_row_streams() {
        // the native batched engine (pooled scratch) must be bit-identical
        // to the per-example loop for every kernel kind and thread count
        let (rows, nc, m) = (9, 24, 6);
        let mut rng = Rng::new(57);
        let logits: Vec<f32> = (0..rows * nc).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        for kind in [
            KernelKind::Quadratic { alpha: 100.0 },
            KernelKind::Quartic,
            KernelKind::Exp,
        ] {
            let s = FlatKernelSampler::new(kind);
            let step_seed = 0xF1A7;
            let mut per_row: Vec<Sample> = (0..rows).map(|_| Sample::default()).collect();
            for (i, slot) in per_row.iter_mut().enumerate() {
                let row = &logits[i * nc..(i + 1) * nc];
                let input = SampleInput { logits: Some(row), ..Default::default() };
                let mut r = row_rng(step_seed, i);
                s.sample(&input, m, &mut r, slot).unwrap();
            }
            for threads in [0usize, 1, 3, 8] {
                let inputs = BatchSampleInput {
                    n: rows,
                    n_classes: nc,
                    logits: Some(&logits),
                    threads,
                    ..Default::default()
                };
                let mut batched: Vec<Sample> = (0..rows).map(|_| Sample::default()).collect();
                s.sample_batch(&inputs, m, step_seed, &mut batched).unwrap();
                for (i, (a, b)) in batched.iter().zip(&per_row).enumerate() {
                    assert_eq!(a.classes, b.classes, "{} threads {threads} row {i}", s.name());
                    assert_eq!(a.q, b.q, "{} threads {threads} row {i}", s.name());
                }
            }
        }
    }

    #[test]
    fn degenerate_batch_row_errors_instead_of_panicking() {
        // shape validation can't catch a NaN row or an f32 weight overflow;
        // the fan-out must surface the per-row Err, not abort a worker
        let (rows, nc, m) = (3usize, 4usize, 4usize);
        for poison in [f32::NAN, 1e30] {
            let s = FlatKernelSampler::new(KernelKind::Quadratic { alpha: 100.0 });
            let mut logits = vec![0.5f32; rows * nc];
            logits[nc] = poison; // row 1 degenerates (NaN total / inf weight)
            let inputs = BatchSampleInput {
                n: rows,
                n_classes: nc,
                logits: Some(&logits),
                threads: 2,
                ..Default::default()
            };
            let mut out: Vec<Sample> = (0..rows).map(|_| Sample::default()).collect();
            let err = s.sample_batch(&inputs, m, 9, &mut out).unwrap_err();
            assert!(err.to_string().contains("batch row 1"), "{err}");
            // the per-row path reports the same failure recoverably
            let row = &logits[nc..2 * nc];
            let input = SampleInput { logits: Some(row), ..Default::default() };
            let mut one = Sample::default();
            let mut rng = Rng::new(1);
            assert!(s.sample(&input, m, &mut rng, &mut one).is_err());
            // and the sampler still works on clean rows afterwards
            let clean = &logits[..nc];
            let input = SampleInput { logits: Some(clean), ..Default::default() };
            s.sample(&input, m, &mut rng, &mut one).unwrap();
            assert_eq!(one.classes.len(), m);
        }
    }

    #[test]
    fn prepared_prob_matches_trait_prob() {
        let mut rng = Rng::new(71);
        let logits: Vec<f32> = (0..50).map(|_| rng.normal_f32(0.0, 2.0)).collect();
        for kind in [KernelKind::Quadratic { alpha: 10.0 }, KernelKind::Quartic, KernelKind::Exp] {
            let s = FlatKernelSampler::new(kind);
            let input = SampleInput { logits: Some(&logits), ..Default::default() };
            let prepared = s.prepare(&logits);
            let mut total = 0.0;
            for c in 0..logits.len() as u32 {
                let fast = s.prob_prepared(&prepared, &logits, c);
                let slow = s.prob(&input, c).unwrap();
                assert_eq!(fast, slow, "{} class {c}", s.name());
                total += fast;
            }
            assert!((total - 1.0).abs() < 1e-9, "{}: Σq = {total}", s.name());
        }
    }
}
