"""OBS — no invisible failure: dropped errors must increment a counter.

The telemetry PR's contract is that every shed, drop, and fallback on
the serve / pipeline paths is *countable*: `kss_batcher_shed_total`,
`kss_service_dropped_reply_total`, `kss_sampler_zero_mass_fallback_total`
and friends exist precisely so an operator can see what the code chose
to swallow. A `let _ = tx.send(reply)` defeats that — the response was
computed, the client hung up, and nothing anywhere records it happened
(the serve worker loop shipped exactly this; it now counts the drop).

In the serve and coordinator trees this rule flags error results that
are discarded with no metrics counter incremented next to the discard:

* `let _ = <expr>;` — a silently dropped value (almost always a
  `Result` or a `send`);
* `Err(_) => {}` / `Err(_) => ()` — an empty error match arm;
* statement-position `.ok();` — discarding a `Result` wholesale.

A discard is fine when the adjacent lines increment an atomic cell
(`.inc()`, `.add(…)`, a raw `fetch_add`) — the drop is then visible in
the registry. Test code is excluded; genuinely un-countable sites (the
metrics sink's own best-effort writer) carry baseline waivers with
written reasons.
"""

from __future__ import annotations

from pallas_lint.frontend import IDENT, PUNCT, SourceFile, snippet
from pallas_lint.rules import Finding, Rule

# evidence that the drop is counted: an increment on an obs cell within
# one line above / two lines below the discard site
_INCREMENT_MARKS = (".inc()", ".add(", "fetch_add")


class ObsVisibleDrops(Rule):
    id = "OBS"
    name = "telemetry-visible-drops"
    summary = "error discarded on a serve/pipeline path with no counter increment"
    contract = (
        "observability: every shed, dropped reply, and fallback is countable "
        "in the metrics registry — a swallowed Result with no adjacent "
        ".inc()/.add()/fetch_add is invisible to operators (rust/src/obs/)"
    )

    def applies(self, relpath: str) -> bool:
        return (
            relpath.startswith("rust/src/serve/")
            or relpath.startswith("rust/src/coordinator/")
            or relpath.startswith("rust/src/vocab/")
        )

    def _counted(self, sf: SourceFile, line: int) -> bool:
        return any(m in sf.window(line, before=1, after=2) for m in _INCREMENT_MARKS)

    def check(self, sf: SourceFile) -> list[Finding]:
        findings: list[Finding] = []
        code = sf.code

        def flag(line: int, message: str) -> None:
            if sf.in_test(line) or self._counted(sf, line):
                return
            findings.append(
                Finding(
                    rule=self.id,
                    file=sf.path,
                    line=line,
                    message=message,
                    snippet=snippet(sf, line),
                )
            )

        for i, tok in enumerate(code):
            nxt = code[i + 1] if i + 1 < len(code) else None
            nx2 = code[i + 2] if i + 2 < len(code) else None
            # let _ = <expr>;
            if (
                tok.kind == IDENT
                and tok.text == "let"
                and nxt is not None
                and nxt.kind == IDENT
                and nxt.text == "_"
                and nx2 is not None
                and nx2.kind == PUNCT
                and nx2.text == "="
            ):
                flag(
                    tok.line,
                    "`let _ =` discards a result on a serve/pipeline path — "
                    "count the drop (.inc() on an obs counter) or handle it",
                )
                continue
            # Err(_) => {}  /  Err(_) => ()
            if (
                tok.kind == IDENT
                and tok.text == "Err"
                and i + 6 < len(code)
                and code[i + 1].text == "("
                and code[i + 2].kind == IDENT
                and code[i + 2].text == "_"
                and code[i + 3].text == ")"
                and code[i + 4].text == "="
                and code[i + 5].text == ">"
                and (
                    (code[i + 6].text == "{" and code[i + 7].text == "}")
                    or (code[i + 6].text == "(" and code[i + 7].text == ")")
                )
            ):
                flag(
                    tok.line,
                    "empty `Err(_)` arm swallows a failure with no counter — "
                    "increment an obs cell so the error rate is observable",
                )
                continue
            # statement-position `.ok();`
            if (
                tok.kind == PUNCT
                and tok.text == "."
                and nxt is not None
                and nxt.kind == IDENT
                and nxt.text == "ok"
                and nx2 is not None
                and nx2.text == "("
                and i + 4 < len(code)
                and code[i + 3].text == ")"
                and code[i + 4].text == ";"
            ):
                flag(
                    tok.line,
                    "statement-position `.ok();` throws the error away — "
                    "count it or propagate it; silent drops defeat the "
                    "telemetry contract",
                )
        return findings
