//! Two-pass batch-shared sampling (TAPAS-style): amortize one candidate
//! pool across the whole batch.
//!
//! Per-row tree descent pays O(D log n) *per draw*; at m ≥ 100 negatives
//! per row the sampling stage dominates a training step even with the
//! depth-2 pipeline hiding part of it. The two-pass mode replaces the
//! per-row descents with:
//!
//! ```text
//! pass 1 (once per batch, calling thread):
//!     h̄ = mean of the batch's query rows
//!     pool = P iid tree descents from h̄          P ≈ B·m/α   (α = pool
//!     record each slot's exact coarse q̄(c)                     factor)
//!     sort slots → duplicates adjacent → runs (class, count, q̄)
//!     gather the unique-class embeddings into one contiguous panel
//!
//! pass 2 (per row, fanned out):
//!     one kernel_many sweep over the pool panel      K(h_i, c) per run
//!     run weight  w_i(c) = n_c · K(h_i, c) / q̄(c)    (importance
//!     resample m negatives from the CDF of w_i        reweighting)
//!     q_i(c) = w_i(c) / S_i,   S_i = Σ_runs w_i
//! ```
//!
//! # The composed proposal q
//!
//! Pool slots are iid draws from the coarse distribution `q̄(c) ∝ K(h̄, c)`
//! (the tree reports each slot's exact q̄ — eq. (8) closed form, guarded,
//! strictly positive). Given the realized pool, a row's draw picks run
//! `c` with probability exactly
//!
//! ```text
//! q_i(c) = n_c · K(h_i, c) / q̄(c)  /  S_i          (composed q)
//! ```
//!
//! This q is **exact for the realized two-stage procedure** — the pool is
//! part of the step's sampling randomness, and conditional on it the draw
//! distribution is known in closed form, so the eq. (2) corrections
//! `ln(m·q)` are computed from the true probability of every draw (and
//! `q > 0` always: a row whose pool mass degenerates redraws through the
//! full per-row tree descent, see below). Dividing by q̄ is the classic
//! sampling-importance-resampling reweighting: marginalized over pools the
//! composed distribution approaches the per-row kernel distribution
//! `K(h_i, ·)/Σ_c K(h_i, c)` as P grows (without it, coarse inclusion ×
//! kernel rescore would *square* the kernel). The residual pool-inclusion
//! bias — classes the pool happened to miss carry no mass this step — is
//! the TAPAS trade and vanishes with pool size; the tests below pin the
//! marginal TV against per-row descent and the partition-estimator bias.
//!
//! # Degenerate pools
//!
//! `S_i` can underflow to zero (or blow up non-finite) when every pooled
//! class scores ≈ 0 against row i. The guard is the checked constructor
//! [`positive_pool_mass`]: rows whose pool mass fails it redraw all m
//! negatives through the per-row tree descent (exact full-support q,
//! strictly positive by the tree's own guards) and are counted in
//! `kss_sampler_pool_fallback_total`.
//!
//! # Batch-API exception
//!
//! Two-pass is deliberately **batch-coupled**: the pool is shared by the
//! rows of one `sample_batch` call, so per-row [`Sampler::sample`] calls
//! are *not* bit-identical to batched rows (each `sample` call is its own
//! B = 1 batch). Thread-count invariance still holds: the pool is drawn
//! from `Rng::new(step_seed ^ POOL_SALT)` on the calling thread before the
//! fan-out, and each row resamples from its own [`row_rng`] stream. See
//! the "Batch API contract" note in `sampler/mod.rs`.
//!
//! # Scratch pooling
//!
//! Pass-1 state ([`PoolScratch`]) and per-worker pass-2 state
//! ([`RowScratch`]) round-trip through [`Pool`] freelists with
//! cap-and-reuse ([`cap_and_clear`]): buffers are reused across steps and
//! shrunk when a past oversized pool left ≥ 4× the needed capacity behind,
//! so steady-state batches allocate nothing and a pool-size spike cannot
//! pin memory forever.

use super::tree::{step_down_to_positive, DrawScratch, KernelTreeSampler, TreeObs, TreeView};
use super::FeatureMap;
use crate::obs::{Counter, Gauge, Histogram, MetricsRegistry};
use crate::sampler::{row_rng, BatchSampleInput, Needs, Sample, SampleInput, Sampler};
use crate::util::rng::Rng;
use crate::util::threadpool::{par_chunks_mut, Pool};
use anyhow::Result;
use std::sync::Arc;
use std::time::Instant;

/// Default pool divisor α: pool size P = ⌈B·m/α⌉ (clamped to ≥ m).
pub const DEFAULT_POOL_FACTOR: f64 = 4.0;

/// Salt for the pass-1 pool RNG stream: the pool must consume a stream
/// disjoint from every [`row_rng`] stream so pass 2 replays row streams
/// bit-identically regardless of pool size.
const POOL_SALT: u64 = 0xB00C_5EED_7A9A_5001;

/// The checked pool-mass constructor — the QPOS guard idiom for two-pass
/// divisions: `let Some(pool_mass) = positive_pool_mass(total) else { … }`
/// proves every later `w / pool_mass` is finite and strictly positive
/// (eq. (2) q-positivity). pallas-lint recognizes this binding shape.
#[inline]
pub(crate) fn positive_pool_mass(total: f64) -> Option<f64> {
    if total > 0.0 && total.is_finite() {
        Some(total)
    } else {
        None
    }
}

/// Clear a reusable buffer and bound its capacity: a buffer that once held
/// a much larger pool (capacity > 4× what the next batch needs) is shrunk
/// back, the same cap-and-reuse discipline as the pipeline's `StepScratch`
/// freelist — steady-state steps allocate nothing, and varying pool sizes
/// cannot ratchet memory up monotonically.
fn cap_and_clear<T>(v: &mut Vec<T>, need: usize) {
    v.clear();
    if v.capacity() > 4 * need.max(1) {
        v.shrink_to(need);
    }
}

/// Shared telemetry cells for one two-pass engine (same accumulate-in-
/// scratch, flush-on-put discipline as [`TreeObs`]; the draw loop never
/// touches an atomic).
#[derive(Clone)]
pub struct TwoPassObs {
    /// Master switch (mirrors [`TreeObs::enabled`]).
    pub enabled: bool,
    pool_size: Arc<Gauge>,
    pool_unique: Arc<Gauge>,
    hits: Arc<Counter>,
    misses: Arc<Counter>,
    fallback_rows: Arc<Counter>,
    rescore: Arc<Histogram>,
}

impl Default for TwoPassObs {
    fn default() -> Self {
        TwoPassObs {
            enabled: true,
            pool_size: Arc::new(Gauge::new()),
            pool_unique: Arc::new(Gauge::new()),
            hits: Arc::new(Counter::new()),
            misses: Arc::new(Counter::new()),
            fallback_rows: Arc::new(Counter::new()),
            rescore: Arc::new(Histogram::new()),
        }
    }
}

impl TwoPassObs {
    /// Bind every cell to `reg` under the stable `kss_sampler_pool_*`
    /// names (see the README metric catalog).
    pub fn register_into(&self, reg: &MetricsRegistry) {
        reg.register_gauge(
            "kss_sampler_pool_size",
            "slots",
            "sampler",
            "shared candidate-pool slots drawn for the last two-pass batch",
            Arc::clone(&self.pool_size),
        );
        reg.register_gauge(
            "kss_sampler_pool_unique",
            "classes",
            "sampler",
            "unique classes in the last two-pass candidate pool",
            Arc::clone(&self.pool_unique),
        );
        reg.register_counter(
            "kss_sampler_pool_hit_total",
            "draws",
            "sampler",
            "negatives resampled from the shared candidate pool",
            Arc::clone(&self.hits),
        );
        reg.register_counter(
            "kss_sampler_pool_miss_total",
            "draws",
            "sampler",
            "negatives a degenerate pool mass pushed to per-row descent",
            Arc::clone(&self.misses),
        );
        reg.register_counter(
            "kss_sampler_pool_fallback_total",
            "rows",
            "sampler",
            "rows whose pool mass degenerated (counted full redraw)",
            Arc::clone(&self.fallback_rows),
        );
        reg.register_histogram(
            "kss_sampler_pool_rescore_seconds",
            "seconds",
            "sampler",
            "per-worker wall time of the pass-2 kernel_many pool rescore",
            Arc::clone(&self.rescore),
        );
    }

    /// Pool slots drawn for the most recent batch.
    pub fn pool_size(&self) -> f64 {
        self.pool_size.get()
    }

    /// Unique classes in the most recent pool.
    pub fn pool_unique(&self) -> f64 {
        self.pool_unique.get()
    }

    /// Negatives served from the shared pool.
    pub fn hit_total(&self) -> u64 {
        self.hits.get()
    }

    /// Negatives that fell back to per-row descent.
    pub fn miss_total(&self) -> u64 {
        self.misses.get()
    }

    /// Rows that triggered the degenerate-pool fallback.
    pub fn fallback_total(&self) -> u64 {
        self.fallback_rows.get()
    }

    /// Pass-2 rescore-sweep latency histogram (one record per worker
    /// scratch checkout).
    pub fn rescore_count(&self) -> u64 {
        self.rescore.count()
    }
}

/// Pass-1 state, pooled per engine: the batch-mean query, the drawn pool
/// slots, the sorted run table (unique class, multiplicity, coarse q̄) and
/// the contiguous unique-class embedding panel pass 2 sweeps.
struct PoolScratch {
    /// Tree memo scratch for the P coarse descents.
    draw: DrawScratch,
    /// f64 accumulator for the batch-mean query (one pass over rows).
    hacc: Vec<f64>,
    /// h̄ materialized for `begin_example` / `draw`.
    hbar: Vec<f32>,
    /// The P drawn slots as (class, coarse q̄) — q̄ is deterministic per
    /// class (the tree's guarded closed form), so dedup keeps the first.
    slots: Vec<(u32, f64)>,
    /// Run table: unique classes ascending …
    run_class: Vec<u32>,
    /// … multiplicity n_c of each …
    run_count: Vec<u32>,
    /// … and its coarse draw probability q̄(c).
    run_qbar: Vec<f64>,
    /// Contiguous runs × d embedding panel (one kernel_many sweep/row).
    panel: Vec<f32>,
}

impl PoolScratch {
    fn new<M: FeatureMap>(tree: &TreeView<'_, M>) -> PoolScratch {
        PoolScratch {
            draw: tree.new_scratch(),
            hacc: Vec::new(),
            hbar: Vec::new(),
            slots: Vec::new(),
            run_class: Vec::new(),
            run_count: Vec::new(),
            run_qbar: Vec::new(),
            panel: Vec::new(),
        }
    }
}

/// Per-worker pass-2 state, pooled per engine: kernel scores and the
/// per-row CDF over the run table, a tree scratch for fallback rows, and
/// the telemetry locals drained on put.
struct RowScratch {
    draw: DrawScratch,
    /// kernel_many output, one slot per run.
    k: Vec<f64>,
    /// Inclusive prefix sums of the run weights (the resample CDF).
    cum: Vec<f64>,
    obs_on: bool,
    obs_hits: u64,
    obs_misses: u64,
    obs_fallback_rows: u64,
    obs_rescore_s: f64,
}

impl RowScratch {
    fn new<M: FeatureMap>(tree: &TreeView<'_, M>) -> RowScratch {
        RowScratch {
            draw: tree.new_scratch(),
            k: Vec::new(),
            cum: Vec::new(),
            obs_on: false,
            obs_hits: 0,
            obs_misses: 0,
            obs_fallback_rows: 0,
            obs_rescore_s: 0.0,
        }
    }

    /// Size the per-run buffers for this batch's run table (cap-and-reuse:
    /// an oversized leftover shrinks instead of pinning memory).
    fn prepare(&mut self, runs: usize) {
        cap_and_clear(&mut self.k, runs);
        cap_and_clear(&mut self.cum, runs);
        self.k.resize(runs, 0.0);
        self.cum.resize(runs, 0.0);
    }
}

/// The two-pass sampling engine: everything that is shared between the
/// owning [`TwoPassKernelSampler`] and the snapshot-backed trainer path
/// (`crate::serve::SnapshotSampler` in two-pass mode). Works over any
/// [`TreeView`], so live trees and pinned snapshot generations use the
/// same code byte for byte.
pub struct TwoPassCore {
    pool_factor: f64,
    pool_scratch: Pool<PoolScratch>,
    row_scratch: Pool<RowScratch>,
    obs: TwoPassObs,
}

impl TwoPassCore {
    /// `pool_factor` is the α of P = ⌈B·m/α⌉ (clamped to ≥ 1).
    pub fn new(pool_factor: f64) -> TwoPassCore {
        let pool_factor = if pool_factor.is_finite() && pool_factor >= 1.0 {
            pool_factor
        } else {
            DEFAULT_POOL_FACTOR
        };
        TwoPassCore {
            pool_factor,
            pool_scratch: Pool::new(),
            row_scratch: Pool::new(),
            obs: TwoPassObs::default(),
        }
    }

    /// The configured pool divisor α.
    pub fn pool_factor(&self) -> f64 {
        self.pool_factor
    }

    /// Telemetry cells (register via [`TwoPassObs::register_into`]).
    pub fn obs(&self) -> &TwoPassObs {
        &self.obs
    }

    /// Toggle telemetry accounting on the engine's own counters.
    pub fn set_obs_enabled(&mut self, on: bool) {
        self.obs.enabled = on;
    }

    /// Pool size for a batch: P = ⌈B·m/α⌉, never below m (a pool smaller
    /// than one row's draw count would resample with pathological
    /// duplication) and never above B·m (α < 1 is clamped at build).
    fn pool_size(&self, n_rows: usize, m: usize) -> usize {
        let target = ((n_rows * m) as f64 / self.pool_factor).ceil() as usize;
        target.clamp(m.max(1), (n_rows * m).max(1))
    }

    /// Pass 1: draw the shared pool from the batch-mean query and build
    /// the sorted run table + contiguous embedding panel. Runs on the
    /// calling thread, before any fan-out, from the dedicated pool RNG
    /// stream — so pass 2's row streams are untouched by pool size.
    fn build_pool<M: FeatureMap>(
        &self,
        tree: &TreeView<'_, M>,
        h_all: &[f32],
        n_rows: usize,
        p: usize,
        pool: &mut PoolScratch,
        rng: &mut Rng,
    ) {
        let d = tree.embed_dim();
        // batch-mean query, accumulated in f64 (row order independent of
        // the fan-out: this is a serial pass)
        cap_and_clear(&mut pool.hacc, d);
        pool.hacc.resize(d, 0.0);
        for row in h_all.chunks_exact(d) {
            for (acc, &x) in pool.hacc.iter_mut().zip(row) {
                *acc += x as f64;
            }
        }
        cap_and_clear(&mut pool.hbar, d);
        let inv_n = 1.0 / n_rows as f64;
        pool.hbar.extend(pool.hacc.iter().map(|&s| (s * inv_n) as f32));

        // P coarse descents from h̄; each slot records the tree's exact,
        // guarded q̄ (strictly positive — the pass-2 reweighting divides
        // by it)
        tree.begin_example(&pool.hbar, &mut pool.draw);
        cap_and_clear(&mut pool.slots, p);
        for _ in 0..p {
            let (class, qbar) = tree.draw(&pool.hbar, &mut pool.draw, rng);
            pool.slots.push((class, qbar));
        }

        // sort → duplicates adjacent → run table (q̄ is a deterministic
        // function of the class under a fixed scratch generation, so any
        // duplicate's q̄ equals the first)
        pool.slots.sort_unstable_by_key(|&(class, _)| class);
        cap_and_clear(&mut pool.run_class, p);
        cap_and_clear(&mut pool.run_count, p);
        cap_and_clear(&mut pool.run_qbar, p);
        for &(class, qbar) in pool.slots.iter() {
            if pool.run_class.last() == Some(&class) {
                *pool.run_count.last_mut().expect("non-empty runs") += 1;
            } else {
                pool.run_class.push(class);
                pool.run_count.push(1);
                pool.run_qbar.push(qbar);
            }
        }

        // gather the unique-class embeddings into one contiguous panel —
        // pass 2's kernel_many sweep streams this like a tree leaf
        let runs = pool.run_class.len();
        cap_and_clear(&mut pool.panel, runs * d);
        for &class in pool.run_class.iter() {
            pool.panel.extend_from_slice(tree.emb_row(class as usize));
        }
    }

    /// Pass 2 for one row: rescore the pool panel, resample m negatives
    /// from the composed CDF, or redraw the whole row through the per-row
    /// tree descent when the pool mass degenerates.
    fn sample_row<M: FeatureMap>(
        &self,
        tree: &TreeView<'_, M>,
        pool: &PoolScratch,
        h: &[f32],
        m: usize,
        rng: &mut Rng,
        slot: &mut Sample,
        rs: &mut RowScratch,
    ) {
        slot.clear();
        let runs = pool.run_class.len();
        let t0 = rs.obs_on.then(Instant::now);
        let ks = &mut rs.k[..runs];
        tree.feature_map().kernel_many(h, &pool.panel, ks);
        // composed second-stage weights w(c) = n_c · K(h, c) / q̄(c): the
        // q̄ division is the SIR reweighting that keeps the marginal near
        // the per-row kernel distribution (module docs); sanitize_mass
        // coerces NaN/negative to 0 and +inf to f64::MAX so one bad score
        // degrades to the counted fallback instead of poisoning the CDF
        let cum = &mut rs.cum[..runs];
        for j in 0..runs {
            let ratio = super::tree::sanitize_mass(ks[j]) / pool.run_qbar[j].max(f64::MIN_POSITIVE);
            ks[j] = pool.run_count[j] as f64 * super::tree::sanitize_mass(ratio);
        }
        let acc = crate::ops::fill_cum_into(ks, cum);
        if let Some(t0) = t0 {
            rs.obs_rescore_s += t0.elapsed().as_secs_f64();
        }
        let Some(pool_mass) = positive_pool_mass(acc) else {
            // degenerate pool for this row: every pooled class scored ≈ 0
            // (or the reweighting blew up). Redraw the whole row through
            // the per-row descent — exact full-support q, strictly
            // positive by the tree's own guards — and count it.
            if rs.obs_on {
                rs.obs_fallback_rows += 1;
                rs.obs_misses += m as u64;
            }
            tree.begin_example(h, &mut rs.draw);
            for _ in 0..m {
                let (class, q) = tree.draw(h, &mut rs.draw, rng);
                slot.push(class, q);
            }
            return;
        };
        for _ in 0..m {
            let u = rng.f64() * pool_mass;
            let j = cum.partition_point(|&c| c <= u).min(runs - 1);
            let j = step_down_to_positive(cum, j);
            let w = if j == 0 { cum[0] } else { cum[j] - cum[j - 1] };
            // composed q (module docs): exact conditional-on-pool draw
            // probability; pool_mass came from positive_pool_mass, and the
            // selected CDF increment is strictly positive, so q ∈ (0, 1]
            let q = w / pool_mass;
            slot.push(pool.run_class[j], q);
        }
        if rs.obs_on {
            rs.obs_hits += m as u64;
        }
    }

    /// Return a worker scratch to the freelist, draining its telemetry
    /// locals in one blocked flush (the pass-2 loop never touches an
    /// atomic — same discipline as the tree's scratch flush).
    fn put_row_scratch(&self, mut rs: RowScratch) {
        if rs.obs_on {
            if rs.obs_hits > 0 {
                self.obs.hits.add(rs.obs_hits);
                rs.obs_hits = 0;
            }
            if rs.obs_misses > 0 {
                self.obs.misses.add(rs.obs_misses);
                rs.obs_misses = 0;
            }
            if rs.obs_fallback_rows > 0 {
                self.obs.fallback_rows.add(rs.obs_fallback_rows);
                rs.obs_fallback_rows = 0;
            }
            if rs.obs_rescore_s > 0.0 {
                self.obs.rescore.record(rs.obs_rescore_s);
                rs.obs_rescore_s = 0.0;
            }
        }
        self.row_scratch.put(rs);
    }

    /// The batched two-pass engine over any tree view (see module docs).
    pub(crate) fn sample_batch_view<M: FeatureMap>(
        &self,
        tree: TreeView<'_, M>,
        name: &str,
        inputs: &BatchSampleInput,
        m: usize,
        step_seed: u64,
        out: &mut [Sample],
    ) -> Result<()> {
        anyhow::ensure!(
            out.len() == inputs.n,
            "out has {} slots, batch has {} rows",
            out.len(),
            inputs.n
        );
        inputs.validate(name, Needs { h: true, ..Needs::default() })?;
        let d = tree.embed_dim();
        anyhow::ensure!(inputs.d == d, "batch h dim {} != sampler d {}", inputs.d, d);
        if inputs.n == 0 || m == 0 {
            for slot in out.iter_mut() {
                slot.clear();
            }
            return Ok(());
        }
        let h_all = inputs.h.expect("validated: two-pass needs h");

        // pass 1 — calling thread, dedicated RNG stream
        let p = self.pool_size(inputs.n, m);
        let mut pool = self.pool_scratch.take(|| PoolScratch::new(&tree));
        let mut pool_rng = Rng::new(step_seed ^ POOL_SALT);
        self.build_pool(&tree, h_all, inputs.n, p, &mut pool, &mut pool_rng);
        let runs = pool.run_class.len();
        if self.obs.enabled {
            self.obs.pool_size.set(p as f64);
            self.obs.pool_unique.set(runs as f64);
        }

        // pass 2 — per-row resample, fanned out; the pool is read-only
        let pool_ref = &pool;
        par_chunks_mut(out, inputs.threads, |base, chunk| {
            let mut rs = self.row_scratch.take(|| RowScratch::new(&tree));
            rs.obs_on = self.obs.enabled;
            rs.prepare(runs);
            for (k, slot) in chunk.iter_mut().enumerate() {
                let i = base + k;
                let h = &h_all[i * d..(i + 1) * d];
                let mut rng = row_rng(step_seed, i);
                self.sample_row(&tree, pool_ref, h, m, &mut rng, slot, &mut rs);
            }
            self.put_row_scratch(rs);
        });
        self.pool_scratch.put(pool);
        Ok(())
    }

    /// Per-example two-pass draw: a B = 1 batch whose pool and resample
    /// both consume the caller's RNG stream (the documented batch-API
    /// exception — two-pass `sample` is not the row stream of
    /// `sample_batch`).
    pub(crate) fn sample_view<M: FeatureMap>(
        &self,
        tree: TreeView<'_, M>,
        input: &SampleInput,
        m: usize,
        rng: &mut Rng,
        out: &mut Sample,
    ) -> Result<()> {
        let h = input.h.ok_or_else(|| anyhow::anyhow!("two-pass sampler needs h"))?;
        let d = tree.embed_dim();
        anyhow::ensure!(h.len() == d, "h len {} != d {}", h.len(), d);
        if m == 0 {
            out.clear();
            return Ok(());
        }
        let p = self.pool_size(1, m);
        let mut pool = self.pool_scratch.take(|| PoolScratch::new(&tree));
        self.build_pool(&tree, h, 1, p, &mut pool, rng);
        let runs = pool.run_class.len();
        if self.obs.enabled {
            self.obs.pool_size.set(p as f64);
            self.obs.pool_unique.set(runs as f64);
        }
        let mut rs = self.row_scratch.take(|| RowScratch::new(&tree));
        rs.obs_on = self.obs.enabled;
        rs.prepare(runs);
        self.sample_row(&tree, &pool, h, m, rng, out, &mut rs);
        self.put_row_scratch(rs);
        self.pool_scratch.put(pool);
        Ok(())
    }
}

/// The owning two-pass sampler: a [`KernelTreeSampler`] (maintained through
/// the normal Fig. 1(b) update paths) plus a [`TwoPassCore`] that batches
/// its draws. Registered as `"quadratic-2pass"` / `"rff-2pass"`; the
/// snapshot-backed trainer path instead runs the same core over pinned
/// generations (`crate::serve::SnapshotSampler::with_two_pass`).
pub struct TwoPassKernelSampler<M: FeatureMap> {
    inner: KernelTreeSampler<M>,
    name: String,
    core: TwoPassCore,
}

impl<M: FeatureMap> TwoPassKernelSampler<M> {
    /// Build over `map` with the tree's default leaf policy (`leaf_size =
    /// None`) and the given pool divisor α.
    pub fn new(
        map: M,
        n_classes: usize,
        leaf_size: Option<usize>,
        pool_factor: f64,
    ) -> TwoPassKernelSampler<M> {
        let name = format!("{}-2pass", map.name());
        TwoPassKernelSampler {
            inner: KernelTreeSampler::new(map, n_classes, leaf_size),
            name,
            core: TwoPassCore::new(pool_factor),
        }
    }

    /// The configured pool divisor α.
    pub fn pool_factor(&self) -> f64 {
        self.core.pool_factor()
    }

    /// Two-pass telemetry cells (`kss_sampler_pool_*`).
    pub fn obs(&self) -> &TwoPassObs {
        self.core.obs()
    }

    /// The hosted tree's telemetry cells (`kss_sampler_*` descent series —
    /// pool descents and fallback redraws report here too).
    pub fn tree_obs(&self) -> &TreeObs {
        self.inner.obs()
    }

    /// Toggle telemetry on both the engine and the hosted tree.
    pub fn set_obs_enabled(&mut self, on: bool) {
        self.core.set_obs_enabled(on);
        self.inner.set_obs_enabled(on);
    }

    /// The hosted tree (tests and benches compare against its per-row
    /// engine directly).
    pub fn inner(&self) -> &KernelTreeSampler<M> {
        &self.inner
    }
}

impl<M: FeatureMap> Sampler for TwoPassKernelSampler<M> {
    fn name(&self) -> &str {
        &self.name
    }

    fn needs(&self) -> Needs {
        Needs { h: true, ..Needs::default() }
    }

    fn sample(&self, input: &SampleInput, m: usize, rng: &mut Rng, out: &mut Sample) -> Result<()> {
        self.core.sample_view(self.inner.view(), input, m, rng, out)
    }

    fn sample_batch(
        &self,
        inputs: &BatchSampleInput,
        m: usize,
        step_seed: u64,
        out: &mut [Sample],
    ) -> Result<()> {
        self.core.sample_batch_view(self.inner.view(), &self.name, inputs, m, step_seed, out)
    }

    /// Closed-form per-class probability — the infinite-pool limit of the
    /// composed marginal (the TV tests bound the finite-pool gap).
    fn prob(&self, input: &SampleInput, class: u32) -> Option<f64> {
        input.h.map(|h| self.inner.class_prob(h, class))
    }

    fn update_many(&mut self, classes: &[usize], rows: &[f32]) {
        KernelTreeSampler::update_many(&mut self.inner, classes, rows);
    }

    fn update(&mut self, class: usize, w_new: &[f32]) {
        Sampler::update(&mut self.inner, class, w_new);
    }

    fn reset_embeddings(&mut self, w: &[f32], n: usize, d: usize) {
        Sampler::reset_embeddings(&mut self.inner, w, n, d);
    }

    /// The hosted tree is a real kernel tree maintained through
    /// [`Sampler::update_many`] (the trainer's single-sweep accounting).
    fn owns_kernel_tree(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::kernel::QuadraticMap;
    use crate::util::stats::{chi_square_stat, tv_from_counts};

    fn random_emb(rng: &mut Rng, n: usize, d: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; n * d];
        rng.fill_normal(&mut v, 0.6);
        v
    }

    fn batch(
        s: &dyn Sampler,
        hs: &[f32],
        rows: usize,
        d: usize,
        n: usize,
        m: usize,
        seed: u64,
        threads: usize,
    ) -> Vec<Sample> {
        let inputs = BatchSampleInput {
            n: rows,
            d,
            n_classes: n,
            h: Some(hs),
            threads,
            ..Default::default()
        };
        let mut out: Vec<Sample> = (0..rows).map(|_| Sample::default()).collect();
        s.sample_batch(&inputs, m, seed, &mut out).unwrap();
        out
    }

    /// Exact closed-form kernel distribution of one query over all classes.
    fn exact_dist(map: &QuadraticMap, emb: &[f32], n: usize, d: usize, h: &[f32]) -> Vec<f64> {
        let ks: Vec<f64> = (0..n).map(|c| map.kernel(h, &emb[c * d..(c + 1) * d])).collect();
        let total: f64 = ks.iter().sum();
        ks.iter().map(|&k| k / total).collect()
    }

    #[test]
    fn composed_q_is_exact_for_the_realized_pool() {
        // reconstruct pass 1 independently (same salt, same stream), then
        // check every reported q equals n_c·K/q̄ / S bit-for-bit and that
        // q sums to 1 over the pool support
        let (n, d, rows, m, seed) = (96usize, 4usize, 12usize, 24usize, 0xC0FE_u64);
        let mut rng = Rng::new(5);
        let emb = random_emb(&mut rng, n, d);
        let mut s = TwoPassKernelSampler::new(QuadraticMap::new(d, 100.0), n, None, 4.0);
        Sampler::reset_embeddings(&mut s, &emb, n, d);
        let mut hs = vec![0.0f32; rows * d];
        rng.fill_normal(&mut hs, 1.0);
        let out = batch(&s, &hs, rows, d, n, m, seed, 3);

        // independent pass-1 replay over the same tree
        let tree = s.inner().view();
        let mut pool = PoolScratch::new(&tree);
        let p = s.core.pool_size(rows, m);
        let mut pool_rng = Rng::new(seed ^ POOL_SALT);
        s.core.build_pool(&tree, &hs, rows, p, &mut pool, &mut pool_rng);
        let runs = pool.run_class.len();
        assert!(runs > 1, "degenerate test setup: pool collapsed to {runs} runs");

        let map = s.inner().feature_map().clone();
        for (i, row) in out.iter().enumerate() {
            // recompute the row's composed weights exactly as pass 2 does
            let h = &hs[i * d..(i + 1) * d];
            let mut ks = vec![0.0f64; runs];
            map.kernel_many(h, &pool.panel, &mut ks);
            let mut cum = vec![0.0f64; runs];
            let mut acc = 0.0f64;
            for j in 0..runs {
                let ratio = super::super::tree::sanitize_mass(ks[j])
                    / pool.run_qbar[j].max(f64::MIN_POSITIVE);
                acc += pool.run_count[j] as f64 * super::super::tree::sanitize_mass(ratio);
                cum[j] = acc;
            }
            let total = acc;
            assert!(total > 0.0 && total.is_finite(), "row {i} pool mass degenerate");
            // q over the pool support is a probability distribution
            let sum_q: f64 = (0..runs)
                .map(|j| (if j == 0 { cum[0] } else { cum[j] - cum[j - 1] }) / total)
                .sum();
            assert!((sum_q - 1.0).abs() < 1e-9, "row {i}: Σq = {sum_q}");
            for (k, (&class, &q)) in row.classes.iter().zip(&row.q).enumerate() {
                let j = pool.run_class.binary_search(&class).unwrap_or_else(|_| {
                    panic!("row {i} draw {k}: class {class} not in the pool")
                });
                let w = if j == 0 { cum[0] } else { cum[j] - cum[j - 1] };
                let want = w / total;
                assert_eq!(q.to_bits(), want.to_bits(), "row {i} draw {k}: q {q} != {want}");
                assert!(q > 0.0 && q.is_finite());
            }
        }
    }

    #[test]
    fn two_pass_batch_is_thread_count_invariant() {
        let (n, d, rows, m) = (64usize, 3usize, 10usize, 16usize);
        let mut rng = Rng::new(9);
        let emb = random_emb(&mut rng, n, d);
        let mut s = TwoPassKernelSampler::new(QuadraticMap::new(d, 100.0), n, None, 3.0);
        Sampler::reset_embeddings(&mut s, &emb, n, d);
        let mut hs = vec![0.0f32; rows * d];
        rng.fill_normal(&mut hs, 1.0);
        let run = |threads: usize| {
            batch(&s, &hs, rows, d, n, m, 0xAB, threads)
                .into_iter()
                .map(|r| (r.classes, r.q))
                .collect::<Vec<_>>()
        };
        let serial = run(0);
        for threads in [1usize, 2, 5] {
            assert_eq!(run(threads), serial, "threads={threads}");
        }
    }

    #[test]
    fn marginal_tv_and_partition_bias_parity_with_per_row_descent() {
        // all rows share one query, so the exact per-row distribution is a
        // single closed-form vector; the two-pass marginal (over fresh
        // pools each step) must land close to it, and the q-corrected
        // partition estimator (the eq. (2) gradient-bias proxy) must stay
        // near the truth for BOTH samplers
        let (n, d, rows, m) = (48usize, 3usize, 32usize, 32usize);
        let mut rng = Rng::new(17);
        let emb = random_emb(&mut rng, n, d);
        let map = QuadraticMap::new(d, 100.0);
        let mut two = TwoPassKernelSampler::new(map.clone(), n, None, 2.0);
        Sampler::reset_embeddings(&mut two, &emb, n, d);
        let mut tree = KernelTreeSampler::new(map.clone(), n, None);
        Sampler::reset_embeddings(&mut tree, &emb, n, d);
        let h: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let hs: Vec<f32> = (0..rows).flat_map(|_| h.iter().copied()).collect();
        let expected = exact_dist(&map, &emb, n, d, &h);
        // exact softmax-numerator partition Σ exp(o) for the bias proxy
        let logits: Vec<f64> =
            (0..n).map(|c| crate::ops::dot_f32(&h, &emb[c * d..(c + 1) * d])).collect();
        let true_part: f64 = logits.iter().map(|&o| o.exp()).sum();

        let mut run = |s: &dyn Sampler| {
            let mut counts = vec![0usize; n];
            let (mut est_sum, mut est_n) = (0.0f64, 0usize);
            for step in 0..40u64 {
                for row in batch(s, &hs, rows, d, n, m, 0x7000 + step, 2) {
                    for (&c, &q) in row.classes.iter().zip(&row.q) {
                        counts[c as usize] += 1;
                        est_sum += logits[c as usize].exp() / q;
                        est_n += 1;
                    }
                }
            }
            (tv_from_counts(&counts, est_n, &expected), est_sum / est_n as f64)
        };
        let (tv_two, part_two) = run(&two);
        let (tv_tree, part_tree) = run(&tree);
        assert!(tv_tree < 0.05, "per-row descent TV {tv_tree} (baseline broken?)");
        assert!(tv_two < 0.08, "two-pass marginal TV {tv_two} too far from exact");
        assert!((tv_two - tv_tree).abs() < 0.06, "TV parity: {tv_two} vs {tv_tree}");
        let rel = |est: f64| (est - true_part).abs() / true_part;
        assert!(rel(part_tree) < 0.10, "tree partition bias {} ({part_tree} vs {true_part})", rel(part_tree));
        assert!(rel(part_two) < 0.12, "two-pass partition bias {} ({part_two} vs {true_part})", rel(part_two));
    }

    #[test]
    fn chi_square_gof_on_the_composed_proposal() {
        // one fixed pool (one step_seed), many rows with the same query:
        // every draw comes from the same conditional distribution
        // n_c·K/q̄ / S, so the counts must pass a χ² goodness-of-fit test
        // against the composed probabilities
        let (n, d, rows, m, seed) = (80usize, 3usize, 400usize, 8usize, 0xD1CE_u64);
        let mut rng = Rng::new(23);
        let emb = random_emb(&mut rng, n, d);
        let mut s = TwoPassKernelSampler::new(QuadraticMap::new(d, 100.0), n, None, 4.0);
        Sampler::reset_embeddings(&mut s, &emb, n, d);
        let h: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let hs: Vec<f32> = (0..rows).flat_map(|_| h.iter().copied()).collect();
        let out = batch(&s, &hs, rows, d, n, m, seed, 2);

        // composed probabilities from an independent pass-1 replay
        let tree = s.inner().view();
        let mut pool = PoolScratch::new(&tree);
        let p = s.core.pool_size(rows, m);
        let mut pool_rng = Rng::new(seed ^ POOL_SALT);
        s.core.build_pool(&tree, &hs, rows, p, &mut pool, &mut pool_rng);
        let runs = pool.run_class.len();
        let map = s.inner().feature_map();
        let mut ks = vec![0.0f64; runs];
        map.kernel_many(&h, &pool.panel, &mut ks);
        let ws: Vec<f64> = (0..runs)
            .map(|j| pool.run_count[j] as f64 * ks[j] / pool.run_qbar[j].max(f64::MIN_POSITIVE))
            .collect();
        let total_w: f64 = ws.iter().sum();
        let probs: Vec<f64> = ws.iter().map(|&w| w / total_w).collect();

        let mut counts = vec![0u64; runs];
        let mut total = 0u64;
        for row in &out {
            for &c in &row.classes {
                let j = pool.run_class.binary_search(&c).expect("draw outside pool");
                counts[j] += 1;
                total += 1;
            }
        }
        let stat = chi_square_stat(&counts, &probs, total as f64);
        let dof = (runs - 1) as f64;
        // mean dof, variance 2·dof: a 6σ bound is astronomically unlikely
        // to trip on a correct sampler, and catches systematic q errors
        let bound = dof + 6.0 * (2.0 * dof).sqrt();
        assert!(stat < bound, "χ² = {stat} over dof = {dof} (bound {bound})");
    }

    /// Kernel that is identically zero — no class can be scored, so every
    /// per-row pool mass degenerates and the fallback path must carry the
    /// whole batch with strictly positive q.
    #[derive(Clone)]
    struct ZeroMap {
        d: usize,
    }

    impl FeatureMap for ZeroMap {
        fn d(&self) -> usize {
            self.d
        }

        fn dim(&self) -> usize {
            self.d
        }

        fn name(&self) -> &'static str {
            "zero"
        }

        fn phi(&self, _a: &[f32], out: &mut [f64]) {
            out.fill(0.0);
        }

        fn kernel(&self, _a: &[f32], _b: &[f32]) -> f64 {
            0.0
        }
    }

    #[test]
    fn degenerate_pool_falls_back_with_positive_q() {
        let (n, d, rows, m) = (32usize, 3usize, 6usize, 8usize);
        let mut rng = Rng::new(41);
        let emb = random_emb(&mut rng, n, d);
        let mut s = TwoPassKernelSampler::new(ZeroMap { d }, n, None, 4.0);
        Sampler::reset_embeddings(&mut s, &emb, n, d);
        let mut hs = vec![0.0f32; rows * d];
        rng.fill_normal(&mut hs, 1.0);
        let out = batch(&s, &hs, rows, d, n, m, 0xFA11, 2);
        for (i, row) in out.iter().enumerate() {
            assert_eq!(row.classes.len(), m, "row {i}");
            for (&c, &q) in row.classes.iter().zip(&row.q) {
                assert!((c as usize) < n, "row {i} class {c} out of range");
                assert!(q > 0.0 && q.is_finite(), "row {i}: fallback q = {q}");
            }
        }
        // every row redrew through the counted fallback; nothing was
        // served from the pool
        assert_eq!(s.obs().fallback_total(), rows as u64);
        assert_eq!(s.obs().miss_total(), (rows * m) as u64);
        assert_eq!(s.obs().hit_total(), 0);
    }

    #[test]
    fn pool_hit_telemetry_accounts_every_draw() {
        let (n, d, rows, m) = (64usize, 3usize, 8usize, 12usize);
        let mut rng = Rng::new(47);
        let emb = random_emb(&mut rng, n, d);
        let mut s = TwoPassKernelSampler::new(QuadraticMap::new(d, 100.0), n, None, 4.0);
        Sampler::reset_embeddings(&mut s, &emb, n, d);
        let mut hs = vec![0.0f32; rows * d];
        rng.fill_normal(&mut hs, 1.0);
        let _ = batch(&s, &hs, rows, d, n, m, 0x0B5, 2);
        let obs = s.obs();
        assert_eq!(obs.hit_total() + obs.miss_total(), (rows * m) as u64);
        assert!(obs.pool_size() >= m as f64);
        assert!(obs.pool_unique() >= 1.0);
        assert!(obs.rescore_count() >= 1, "rescore sweep latency not recorded");
    }

    #[test]
    fn scratch_freelists_reuse_and_cap_capacity() {
        // satellite: pool buffers must round-trip through the freelist
        // (pointer reuse) and a large pool must not pin capacity after
        // smaller batches (cap-and-reuse, no monotone Vec growth)
        let (n, d) = (64usize, 3usize);
        let mut rng = Rng::new(53);
        let emb = random_emb(&mut rng, n, d);
        let mut s = TwoPassKernelSampler::new(QuadraticMap::new(d, 100.0), n, None, 2.0);
        Sampler::reset_embeddings(&mut s, &emb, n, d);
        let step = |s: &TwoPassKernelSampler<QuadraticMap>, rows: usize, m: usize, seed: u64| {
            let mut hs = vec![0.0f32; rows * d];
            Rng::new(seed).fill_normal(&mut hs, 1.0);
            let _ = batch(s, &hs, rows, d, n, m, seed, 0);
        };
        // big batch warms the buffers up
        step(&s, 64, 64, 1);
        let big_p = s.core.pool_size(64, 64);
        {
            let pool = s.core.pool_scratch.take(|| unreachable!("freelist must be warm"));
            assert!(pool.slots.capacity() >= big_p, "pool buffers were not kept");
            s.core.pool_scratch.put(pool);
        }
        // many small batches: capacity must come back down (≤ 4× need)
        for seed in 2..12u64 {
            step(&s, 2, 4, seed);
        }
        let small_p = s.core.pool_size(2, 4);
        let pool = s.core.pool_scratch.take(|| unreachable!("freelist must be warm"));
        assert!(
            pool.slots.capacity() <= 4 * small_p.max(1),
            "pool slots capacity {} not capped (need {})",
            pool.slots.capacity(),
            small_p
        );
        assert!(
            pool.panel.capacity() <= 4 * (small_p * d).max(1),
            "panel capacity {} not capped",
            pool.panel.capacity()
        );
        s.core.pool_scratch.put(pool);
        let rs = s.core.row_scratch.take(|| unreachable!("row freelist must be warm"));
        assert!(rs.k.capacity() <= 4 * small_p.max(1), "row k capacity not capped");
        s.core.row_scratch.put(rs);
    }

    #[test]
    fn cap_and_clear_bounds_capacity() {
        let mut v: Vec<u64> = Vec::with_capacity(1000);
        v.extend(0..1000);
        cap_and_clear(&mut v, 10);
        assert!(v.is_empty());
        assert!(v.capacity() <= 1000);
        assert!(v.capacity() >= 10, "shrink_to must keep the needed capacity");
        cap_and_clear(&mut v, 10);
        assert!(v.capacity() <= 40, "capacity {} not capped to 4× need", v.capacity());
        // growing again is fine
        v.extend(0..500);
        assert_eq!(v.len(), 500);
    }

    #[test]
    fn sample_is_a_b1_batch_with_positive_q() {
        let (n, d, m) = (48usize, 3usize, 16usize);
        let mut rng = Rng::new(61);
        let emb = random_emb(&mut rng, n, d);
        let mut s = TwoPassKernelSampler::new(QuadraticMap::new(d, 100.0), n, None, 4.0);
        Sampler::reset_embeddings(&mut s, &emb, n, d);
        let h: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let input = SampleInput { h: Some(&h), ..Default::default() };
        let mut out = Sample::default();
        let mut draw_rng = Rng::new(71);
        s.sample(&input, m, &mut draw_rng, &mut out).unwrap();
        assert_eq!(out.classes.len(), m);
        assert!(out.q.iter().all(|&q| q > 0.0 && q.is_finite()));
        // deterministic in the caller's stream
        let mut again = Sample::default();
        let mut draw_rng = Rng::new(71);
        s.sample(&input, m, &mut draw_rng, &mut again).unwrap();
        assert_eq!(out.classes, again.classes);
        assert_eq!(out.q, again.q);
    }
}
