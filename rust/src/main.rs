//! `kss` — launcher for the kernel-sampled-softmax system.
//!
//! Subcommands:
//!
//! * `kss info` — list the models/artifacts in the manifest.
//! * `kss train` — one training run (model × sampler × m), metrics to JSONL.
//! * `kss experiment` — a (samplers × m) grid, the engine behind the paper's
//!   figures; writes per-run JSONL + summary.json and prints the Figure-2
//!   style bias table.
//! * `kss demo` — 30-second tiny-model walkthrough of the whole stack.
//!
//! Artifacts must exist (`make artifacts`). Logging level: `KSS_LOG`.

use anyhow::Result;
use kss::coordinator::{run_grid, GridSpec, MetricsSink, TrainConfig, Trainer};
use kss::runtime::Engine;
use kss::util::cli::{Args, OptSpec};
use kss::{error, info};
use std::path::{Path, PathBuf};

fn main() {
    kss::util::logging::init_from_env();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(argv) {
        Ok(()) => 0,
        Err(e) => {
            error!("{e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn specs() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "artifacts", help: "artifacts directory", default: Some("artifacts".into()) },
        OptSpec { name: "model", help: "manifest model name", default: Some("tiny".into()) },
        OptSpec { name: "sampler", help: "sampler name or 'full'", default: Some("quadratic".into()) },
        OptSpec { name: "samplers", help: "comma list (experiment)", default: None },
        OptSpec { name: "m", help: "sample size(s), comma list", default: Some("8".into()) },
        OptSpec { name: "lr", help: "SGD learning rate (0 = model default)", default: Some("0".into()) },
        OptSpec { name: "epochs", help: "training epochs", default: Some("1".into()) },
        OptSpec { name: "train-size", help: "train tokens/events", default: Some("8000".into()) },
        OptSpec { name: "valid-size", help: "validation tokens/events", default: Some("1000".into()) },
        OptSpec { name: "max-steps", help: "cap steps per epoch (0 = all)", default: Some("0".into()) },
        OptSpec { name: "eval-every", help: "eval every k steps (0 = per epoch)", default: Some("0".into()) },
        OptSpec { name: "eval-batches", help: "eval batch cap (0 = all)", default: Some("20".into()) },
        OptSpec { name: "threads", help: "sampling threads (0 = auto)", default: Some("0".into()) },
        OptSpec { name: "seed", help: "master seed", default: Some("42".into()) },
        OptSpec { name: "out", help: "metrics output directory", default: Some("runs".into()) },
        OptSpec { name: "full", help: "include full-softmax reference (experiment)", default: Some("true".into()) },
    ]
}

fn parse_config(args: &Args) -> Result<TrainConfig> {
    Ok(TrainConfig {
        model: args.get_string_or("model", "tiny"),
        sampler: args.get_string_or("sampler", "quadratic"),
        m: args.get_usize_list("m", &[8])?[0],
        lr: args.get_f64("lr", 0.0)? as f32,
        epochs: args.get_usize("epochs", 1)?,
        train_size: args.get_usize("train-size", 8_000)?,
        valid_size: args.get_usize("valid-size", 1_000)?,
        max_steps_per_epoch: args.get_usize("max-steps", 0)?,
        eval_every: args.get_usize("eval-every", 0)?,
        eval_batches: args.get_usize("eval-batches", 20)?,
        threads: args.get_usize("threads", 0)?,
        seed: args.get_u64("seed", 42)?,
    })
}

fn run(argv: Vec<String>) -> Result<()> {
    let (cmd, rest) = match argv.split_first() {
        Some((c, rest)) if !c.starts_with("--") => (c.clone(), rest.to_vec()),
        _ => ("help".to_string(), argv),
    };
    let args = Args::parse("kss <info|train|experiment|demo>", &rest, &specs(), &["help"])?;
    if args.wants_help() || cmd == "help" {
        println!("{}", args.usage());
        println!("subcommands: info, train, experiment, demo");
        return Ok(());
    }
    let artifacts = PathBuf::from(args.get_string_or("artifacts", "artifacts"));
    match cmd.as_str() {
        "info" => info_cmd(&artifacts),
        "train" => train_cmd(&artifacts, &args),
        "experiment" => experiment_cmd(&artifacts, &args),
        "demo" => demo_cmd(&artifacts),
        other => anyhow::bail!("unknown subcommand '{other}' (info, train, experiment, demo)"),
    }
}

fn info_cmd(artifacts: &Path) -> Result<()> {
    let engine = Engine::new(artifacts)?;
    println!("platform: {}", engine.platform());
    println!(
        "{:<12} {:>8} {:>5} {:>6} {:>5} {:>8}  m values",
        "model", "classes", "d", "batch", "abs", "kind"
    );
    for (name, spec) in &engine.manifest().models {
        println!(
            "{:<12} {:>8} {:>5} {:>6} {:>5} {:>8}  {:?}",
            name,
            spec.n_classes,
            spec.d,
            spec.batch,
            spec.abs_logits,
            format!("{:?}", spec.kind).to_lowercase(),
            spec.available_m()
        );
    }
    Ok(())
}

fn train_cmd(artifacts: &Path, args: &Args) -> Result<()> {
    let engine = Engine::new(artifacts)?;
    let cfg = parse_config(args)?;
    let out = PathBuf::from(args.get_string_or("out", "runs"));
    let run_id = cfg.run_id();
    info!("training {run_id}");
    let mut sink = MetricsSink::to_dir(&out, &run_id)?;
    let mut trainer = Trainer::new(&engine, cfg)?;
    let res = trainer.train(&mut sink)?;
    println!("run {run_id}");
    println!("  final eval loss {:.4} (ppl {:.2})", res.final_loss, res.final_loss.exp());
    println!("  best  eval loss {:.4}", res.best_loss);
    println!("  steps {}", res.steps);
    println!("phase breakdown:\n{}", trainer.phases.report());
    Ok(())
}

fn experiment_cmd(artifacts: &Path, args: &Args) -> Result<()> {
    let engine = Engine::new(artifacts)?;
    let base = parse_config(args)?;
    let samplers = match args.get_str("samplers") {
        Some(_) => args.get_str_list("samplers", &[]),
        None => vec![base.sampler.clone()],
    };
    let ms = args.get_usize_list("m", &[8])?;
    let include_full = args.get_bool("full", true)?;
    let out = PathBuf::from(args.get_string_or("out", "runs"));
    let grid = GridSpec { base, samplers, ms: ms.clone(), include_full };
    let summaries = run_grid(&engine, &grid, Some(&out))?;
    println!("\nfinal full-softmax eval loss (bias table, Figure-2 style):");
    print!("{}", kss::coordinator::experiment::bias_table(&summaries, &ms));
    Ok(())
}

fn demo_cmd(artifacts: &Path) -> Result<()> {
    let engine = Engine::new(artifacts)?;
    println!("kernel-sampled-softmax demo (tiny model, ~30s)\n");
    let grid = GridSpec {
        base: TrainConfig {
            model: "tiny".into(),
            epochs: 2,
            train_size: 640,
            valid_size: 160,
            eval_batches: 5,
            ..Default::default()
        },
        samplers: vec!["uniform".into(), "quadratic".into(), "softmax".into()],
        ms: vec![8],
        include_full: true,
    };
    let summaries = run_grid(&engine, &grid, None)?;
    println!("\nfinal eval loss after 2 epochs (m = 8 of 128 classes):");
    for s in &summaries {
        println!("  {:<16} {:.4}", s.label(), s.final_loss);
    }
    println!("\nExpected shape (paper Fig. 2): softmax ≈ full < quadratic << uniform.");
    Ok(())
}
