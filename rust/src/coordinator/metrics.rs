//! Metric sink: JSONL on disk + in-memory curves for the figure benches.
//!
//! Every record is one JSON object per line with a `kind` field:
//! `config` (run header), `eval` (the full-softmax loss curve the paper
//! plots), `epoch` (timing summary). Files live under `runs/<run_id>.jsonl`.

use crate::util::json::Value;
use anyhow::{Context, Result};
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

/// One evaluation point on a loss curve.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EvalPoint {
    /// Fractional epoch (step / steps_per_epoch).
    pub epoch: f64,
    pub step: usize,
    /// Mean full-softmax cross entropy on held-out data.
    pub loss: f64,
}

impl EvalPoint {
    /// Perplexity (the paper's PTB metric).
    pub fn ppl(&self) -> f64 {
        self.loss.exp()
    }
}

/// Collects eval points; optionally streams them to a JSONL file.
pub struct MetricsSink {
    run_id: String,
    writer: Option<BufWriter<File>>,
    points: Vec<EvalPoint>,
}

impl MetricsSink {
    /// In-memory only (benches that aggregate themselves).
    pub fn memory(run_id: &str) -> MetricsSink {
        MetricsSink { run_id: run_id.to_string(), writer: None, points: Vec::new() }
    }

    /// Stream to `<dir>/<run_id>.jsonl` as well.
    pub fn to_dir(dir: &Path, run_id: &str) -> Result<MetricsSink> {
        std::fs::create_dir_all(dir).with_context(|| format!("creating {dir:?}"))?;
        let path = dir.join(format!("{run_id}.jsonl"));
        let file = File::create(&path).with_context(|| format!("creating {path:?}"))?;
        Ok(MetricsSink {
            run_id: run_id.to_string(),
            writer: Some(BufWriter::new(file)),
            points: Vec::new(),
        })
    }

    pub fn run_id(&self) -> &str {
        &self.run_id
    }

    fn write(&mut self, v: &Value) {
        if let Some(w) = self.writer.as_mut() {
            let _ = writeln!(w, "{}", v.to_string_compact());
            let _ = w.flush();
        }
    }

    /// Run header (config dump).
    pub fn log_config(&mut self, cfg: &Value) {
        let rec = Value::object(vec![
            ("kind", Value::str("config")),
            ("run_id", Value::str(&self.run_id)),
            ("config", cfg.clone()),
        ]);
        self.write(&rec);
    }

    /// One eval point on the loss curve.
    pub fn log_eval(&mut self, p: EvalPoint) {
        self.points.push(p);
        let rec = Value::object(vec![
            ("kind", Value::str("eval")),
            ("run_id", Value::str(&self.run_id)),
            ("epoch", Value::num(p.epoch)),
            ("step", Value::num(p.step as f64)),
            ("loss", Value::num(p.loss)),
            ("ppl", Value::num(p.ppl())),
        ]);
        self.write(&rec);
    }

    /// Free-form structured record (phase timings, sampler stats, ...).
    pub fn log_record(&mut self, kind: &str, fields: Vec<(&str, Value)>) {
        let mut all = vec![("kind", Value::str(kind)), ("run_id", Value::str(&self.run_id))];
        all.extend(fields);
        let rec = Value::object(all);
        self.write(&rec);
    }

    /// The collected loss curve.
    pub fn curve(&self) -> &[EvalPoint] {
        &self.points
    }

    /// Final (last) eval loss.
    pub fn final_loss(&self) -> Option<f64> {
        self.points.last().map(|p| p.loss)
    }

    /// Best eval loss over the run.
    pub fn best_loss(&self) -> Option<f64> {
        self.points.iter().map(|p| p.loss).min_by(|a, b| a.partial_cmp(b).unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn memory_sink_collects_curve() {
        let mut sink = MetricsSink::memory("test");
        sink.log_eval(EvalPoint { epoch: 0.5, step: 10, loss: 5.0 });
        sink.log_eval(EvalPoint { epoch: 1.0, step: 20, loss: 4.0 });
        assert_eq!(sink.curve().len(), 2);
        assert_eq!(sink.final_loss(), Some(4.0));
        assert_eq!(sink.best_loss(), Some(4.0));
        assert!((sink.curve()[0].ppl() - 5.0f64.exp()).abs() < 1e-9);
    }

    #[test]
    fn file_sink_writes_jsonl() {
        let dir = std::env::temp_dir().join(format!("kss-metrics-{}", std::process::id()));
        let mut sink = MetricsSink::to_dir(&dir, "run1").unwrap();
        sink.log_config(&Value::object(vec![("m", Value::num(8.0))]));
        sink.log_eval(EvalPoint { epoch: 1.0, step: 5, loss: 3.0 });
        sink.log_record("phase", vec![("encode_s", Value::num(0.1))]);
        drop(sink);
        let text = std::fs::read_to_string(dir.join("run1.jsonl")).unwrap();
        let recs = json::parse_jsonl(&text).unwrap();
        assert_eq!(recs.len(), 3);
        assert_eq!(recs[0].get("kind").unwrap().as_str(), Some("config"));
        assert_eq!(recs[1].get("loss").unwrap().as_f64(), Some(3.0));
        assert_eq!(recs[2].get("encode_s").unwrap().as_f64(), Some(0.1));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
