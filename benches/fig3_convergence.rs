//! Figure 3 — **convergence speed for varying sample size m** per sampler.
//!
//! Loss-vs-epoch curves: once m is large enough to remove the bias, adding
//! more samples should not change convergence speed noticeably (the paper's
//! second finding: batch-gradient noise dominates sampling noise).
//!
//! `cargo bench --bench fig3_convergence` (quick) /
//! `KSS_BENCH_SCALE=full ...` (ptb + yt10k, full m sweep).

use kss::bench_harness::{engine_or_exit, print_series, scale, Scale};
use kss::coordinator::experiment::{run_grid, GridSpec};
use kss::coordinator::TrainConfig;

fn main() -> anyhow::Result<()> {
    kss::util::logging::init_from_env();
    let engine = engine_or_exit();
    let (models, ms): (Vec<(&str, TrainConfig)>, Vec<usize>) = match scale() {
        Scale::Quick => (
            vec![(
                "tiny",
                TrainConfig {
                    model: "tiny".into(),
                    epochs: 4,
                    train_size: 960,
                    valid_size: 320,
                    eval_batches: 10,
                    eval_every: 40,
                    ..Default::default()
                },
            )],
            vec![4, 8],
        ),
        Scale::Full => (
            vec![
                (
                    "ptb",
                    TrainConfig {
                        model: "ptb".into(),
                        epochs: 3,
                        train_size: 120_000,
                        valid_size: 24_000,
                        eval_batches: 8,
                        eval_every: 100,
                        ..Default::default()
                    },
                ),
                (
                    "yt10k",
                    TrainConfig {
                        model: "yt10k".into(),
                        epochs: 3,
                        train_size: 40_000,
                        valid_size: 6_400,
                        eval_batches: 8,
                        eval_every: 150,
                        ..Default::default()
                    },
                ),
            ],
            vec![8, 32, 128],
        ),
    };

    for sampler in ["uniform", "quadratic", "softmax"] {
        for (label, base) in &models {
            println!("\n==== Figure 3 — {label}, sampler = {sampler}, m sweep ====");
            let grid = GridSpec {
                base: base.clone(),
                samplers: vec![sampler.to_string()],
                ms: ms.clone(),
                include_full: false,
            };
            let summaries = run_grid(&engine, &grid, Some(std::path::Path::new("runs/fig3")))?;
            for s in &summaries {
                let pts: Vec<(f64, f64)> =
                    s.curve.iter().map(|p| (p.epoch, p.loss)).collect();
                print_series(&format!("{label}/{sampler}/m={}", s.m), &pts);
            }
        }
    }
    println!("\nshape to check: for softmax all m-curves coincide; for uniform/");
    println!("quadratic small-m curves plateau higher (bias), but above the");
    println!("bias threshold extra samples do not speed up convergence.");
    Ok(())
}
