//! The sampling service: shard snapshot stores + micro-batcher + worker
//! pool behind one façade, and the [`ShardSet`] writer that feeds it.
//!
//! Data flow:
//!
//! ```text
//!          trainer / writer thread                     clients
//!                   │                                     │ submit(h, m)
//!        ShardSet::update_and_publish              MicroBatcher (bounded,
//!          │ per-shard update_many                   deadline-coalesced)
//!          ▼                                              │ next_batch
//!   TreePublisher ×S ──publish──► SnapshotStore ×S ──► workers ×W
//!   (double-buffered arenas)      (atomic swap)     SnapshotReader per shard
//!                                                    draw_from_shards / topk
//! ```
//!
//! Workers refresh their per-shard [`SnapshotReader`]s once per batch, so
//! every request in a batch samples one consistent generation set; a
//! publish lands between batches, never inside one. Request `seq` draws
//! from `row_rng(service_seed, seq)` regardless of how it was batched.

use crate::obs::{Counter, MetricsRegistry};
use crate::sampler::kernel::midx::{MidxCore, MidxObs};
use crate::sampler::kernel::tree::TreeView;
use crate::sampler::kernel::FeatureMap;
use crate::sampler::{row_rng, Sample};
use crate::serve::batcher::{BatcherConfig, MicroBatcher, SampleResponse, ServeError};
use crate::serve::shard::{
    draw_from_shards, scratch_for, split_updates_by_shard, ShardedKernelSampler,
};
use crate::serve::snapshot::{
    PublishReport, PublishStats, SnapshotReader, SnapshotStore, TreePublisher, TreeSnapshot,
};
use crate::serve::topk::{topk_over_snapshots, Hit, TopKConfig};
use crate::util::rng::Rng;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::Instant;

/// Writer-side bundle: one [`TreePublisher`] per shard, with global-class
/// routing — the serving counterpart of [`ShardedKernelSampler`], updates
/// routed and published per shard so a hot shard never stalls the rest.
pub struct ShardSet<M: FeatureMap + Clone> {
    publishers: Vec<TreePublisher<M>>,
    offsets: Vec<u32>,
    d: usize,
}

impl<M: FeatureMap + Clone> ShardSet<M> {
    /// Build S shard trees over `n` classes (optionally seeded with the
    /// embedding table `w`, flat n×d) and publish each as generation 0.
    pub fn new(
        map: M,
        n: usize,
        shards: usize,
        leaf_size: Option<usize>,
        w: Option<&[f32]>,
    ) -> Self {
        let d = map.d();
        let mut sampler = ShardedKernelSampler::new(map, n, shards, leaf_size);
        if let Some(w) = w {
            sampler.reset_embeddings(w, n, d);
        }
        let (trees, offsets) = sampler.into_shards();
        ShardSet {
            publishers: trees.into_iter().map(TreePublisher::new).collect(),
            offsets,
            d,
        }
    }

    pub fn shard_count(&self) -> usize {
        self.publishers.len()
    }

    pub fn offsets(&self) -> &[u32] {
        &self.offsets
    }

    /// The publish points, one per shard, to hand to
    /// [`SamplingService::start`].
    pub fn stores(&self) -> Vec<Arc<SnapshotStore<TreeSnapshot<M>>>> {
        self.publishers.iter().map(|p| p.store()).collect()
    }

    /// A snapshot-backed training [`crate::sampler::Sampler`] over this
    /// set's publish points, reporting the hosted kernel family's registry
    /// name (`<kernel>` unsharded, `<kernel>-sharded` otherwise). The
    /// trainer's one-tree path: draws read published generations of the
    /// very trees this set updates and publishes.
    pub fn snapshot_sampler(&self) -> crate::serve::SnapshotSampler<M> {
        let base = self.publishers[0].shadow().feature_map().name();
        let name = if self.publishers.len() == 1 {
            base.to_string()
        } else {
            format!("{base}-sharded")
        };
        crate::serve::SnapshotSampler::new(self.stores(), self.offsets.clone(), name)
    }

    /// Route a global-class update batch (`classes` sorted + dedup, `rows`
    /// flat len×d) to the owning shards and publish each touched shard's
    /// next generation. Untouched shards keep their current generation —
    /// the per-shard publish this layout exists for.
    pub fn update_and_publish(&mut self, classes: &[usize], rows: &[f32]) -> Vec<PublishReport> {
        let parts = split_updates_by_shard(&self.offsets, self.d, classes, rows);
        let mut reports = Vec::new();
        for (publisher, (cl, rw)) in self.publishers.iter_mut().zip(&parts) {
            if !cl.is_empty() {
                reports.push(publisher.update_and_publish(cl, rw));
            }
        }
        reports
    }

    /// One synthetic writer iteration, shared by the load generator and
    /// the serve bench: draw `k` random classes (sorted + dedup), generate
    /// fresh N(0, 0.3) rows, and publish the touched shards.
    pub fn publish_random_batch(&mut self, rng: &mut Rng, k: usize) -> Vec<PublishReport> {
        let n = *self.offsets.last().expect("offsets non-empty") as usize;
        let mut classes: Vec<usize> = (0..k.max(1)).map(|_| rng.range(0, n)).collect();
        classes.sort_unstable();
        classes.dedup();
        let mut rows = vec![0.0f32; classes.len() * self.d];
        rng.fill_normal(&mut rows, 0.3);
        self.update_and_publish(&classes, &rows)
    }

    /// Register every publish-path and sampler metric this set owns into
    /// `reg`. Per-shard cells bind under the same canonical names; the
    /// registry snapshot aggregates them into one series per name
    /// (counters sum, histograms merge), so a dashboard sees fleet totals
    /// without a per-shard label explosion.
    pub fn register_metrics(&self, reg: &MetricsRegistry) {
        for p in &self.publishers {
            p.obs().register_into(reg);
            p.shadow().obs().register_into(reg);
        }
    }

    /// Publish-path counters summed over all shards.
    pub fn stats(&self) -> PublishStats {
        let mut total = PublishStats::default();
        for p in &self.publishers {
            total.publishes += p.stats.publishes;
            total.reclaimed += p.stats.reclaimed;
            total.copied += p.stats.copied;
            total.replayed_batches += p.stats.replayed_batches;
        }
        total
    }
}

/// Kernel-erased writer surface of a [`ShardSet`]: exactly the two calls
/// the trainer's publish hook makes per step. Boxing this (instead of a
/// concrete `ShardSet<QuadraticMap>`) is what lets `Trainer` publish
/// whichever kernel family its sampler trains — quadratic and rff shard
/// sets behind the same hook.
pub trait ShardPublisher: Send {
    /// Route a global-class update batch to the owning shards and publish
    /// each touched shard's next generation (see
    /// [`ShardSet::update_and_publish`]).
    fn update_and_publish_rows(&mut self, classes: &[usize], rows: &[f32]) -> Vec<PublishReport>;

    /// Publish-path counters summed over all shards.
    fn publish_stats(&self) -> PublishStats;

    /// Bind every publish-path and sampler metric behind this publisher
    /// into `reg` — the kernel-erased face of
    /// [`ShardSet::register_metrics`], so the trainer can export serve
    /// telemetry without naming the concrete kernel family.
    fn register_metrics(&self, reg: &MetricsRegistry);

    /// Number of shards behind this publisher.
    fn shard_count(&self) -> usize;

    /// Downcast hook: when the trainer already routes its sampler through
    /// a publisher, `enable_serving_with::<M>` recovers the concrete
    /// [`ShardSet<M>`] to hand its typed snapshot stores to the serving
    /// stack — the same tree serves both, no second mirror is built.
    fn as_any(&self) -> &dyn std::any::Any;
}

impl<M: FeatureMap + Clone + 'static> ShardPublisher for ShardSet<M> {
    fn update_and_publish_rows(&mut self, classes: &[usize], rows: &[f32]) -> Vec<PublishReport> {
        self.update_and_publish(classes, rows)
    }

    fn publish_stats(&self) -> PublishStats {
        self.stats()
    }

    fn register_metrics(&self, reg: &MetricsRegistry) {
        ShardSet::register_metrics(self, reg)
    }

    fn shard_count(&self) -> usize {
        ShardSet::shard_count(self)
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

/// Service tuning.
#[derive(Clone, Copy, Debug)]
pub struct ServiceConfig {
    /// Worker threads executing batches.
    pub workers: usize,
    pub batcher: BatcherConfig,
    /// Seed of the per-request RNG streams (`row_rng(seed, seq)`).
    pub seed: u64,
    pub topk: TopKConfig,
    /// Largest accepted per-request sample count (submit-time guard: a
    /// pathological `m` must fail fast, not abort a worker's allocation).
    pub max_m: usize,
    /// Liveness backstop for blocking callers: `sample_blocking` gives up
    /// with [`ServeError::Timeout`] after this long, so a dead worker pool
    /// wedges no client forever. Generous by default — it is a backstop,
    /// not the latency SLA (that is the batcher deadline + load budget).
    pub request_timeout: std::time::Duration,
    /// Route worker draws through the inverted multi-index
    /// ([`MidxCore`], K clusters) instead of per-row tree descents.
    /// 0 = off; requires a single-shard publish point (the coarse CDF
    /// needs one index over the full class range).
    pub midx_clusters: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            workers: 2,
            batcher: BatcherConfig::default(),
            seed: 0x5E17E,
            topk: TopKConfig::default(),
            max_m: 4096,
            request_timeout: std::time::Duration::from_secs(30),
            midx_clusters: 0,
        }
    }
}

/// Service-level telemetry cells shared between the façade and the worker
/// pool. A reply whose receiver is gone (client timed out or hung up) is
/// not a worker error — the worker keeps running — but it *is* work served
/// for nothing, so it must land in a counter rather than vanish into a
/// `let _ =`.
#[derive(Clone, Default)]
pub struct ServiceObs {
    dropped_replies: Arc<Counter>,
}

impl ServiceObs {
    /// Bind this service's cells into `reg` under their canonical names.
    pub fn register_into(&self, reg: &MetricsRegistry) {
        reg.register_counter(
            "kss_service_dropped_reply_total",
            "replies",
            "serve",
            "responses computed but dropped because the client receiver was gone",
            self.dropped_replies.clone(),
        );
    }

    /// Replies computed and then dropped (receiver hung up) so far.
    pub fn dropped_replies_total(&self) -> u64 {
        self.dropped_replies.get()
    }
}

/// Concurrent sampling service over a shard set's snapshot stores.
pub struct SamplingService<M: FeatureMap + 'static> {
    stores: Vec<Arc<SnapshotStore<TreeSnapshot<M>>>>,
    offsets: Arc<Vec<u32>>,
    batcher: Arc<MicroBatcher>,
    workers: Vec<JoinHandle<()>>,
    topk_cfg: TopKConfig,
    /// Expected query-embedding length; requests are validated at submit
    /// so a malformed `h` can never panic a worker.
    d: usize,
    /// Per-request sample-count cap (see [`ServiceConfig::max_m`]).
    max_m: usize,
    request_timeout: std::time::Duration,
    obs: ServiceObs,
    /// Shared inverted multi-index engine (see [`ServiceConfig::midx_clusters`]);
    /// one index build per published generation, shared by every worker.
    midx: Option<Arc<MidxCore>>,
}

impl<M: FeatureMap + 'static> SamplingService<M> {
    /// Spawn the worker pool over the given per-shard publish points.
    pub fn start(
        stores: Vec<Arc<SnapshotStore<TreeSnapshot<M>>>>,
        offsets: Vec<u32>,
        cfg: ServiceConfig,
    ) -> SamplingService<M> {
        assert_eq!(offsets.len(), stores.len() + 1, "offsets must bracket every shard");
        let d = stores[0].load().1.tree.embed_dim();
        let batcher = MicroBatcher::new(cfg.batcher);
        let offsets = Arc::new(offsets);
        let obs = ServiceObs::default();
        let midx = (cfg.midx_clusters > 0).then(|| {
            assert_eq!(
                stores.len(),
                1,
                "midx serving needs a single-shard publish point (got {} shards)",
                stores.len()
            );
            Arc::new(MidxCore::new(Some(cfg.midx_clusters)))
        });
        let workers = (0..cfg.workers.max(1))
            .map(|w| {
                let batcher = batcher.clone();
                let stores = stores.clone();
                let offsets = offsets.clone();
                let obs = obs.clone();
                let midx = midx.clone();
                std::thread::Builder::new()
                    .name(format!("kss-serve-{w}"))
                    .spawn(move || {
                        worker_loop(&batcher, &stores, &offsets, cfg.seed, &obs, midx.as_deref())
                    })
                    .expect("spawn serve worker")
            })
            .collect();
        SamplingService {
            stores,
            offsets,
            batcher,
            workers,
            topk_cfg: cfg.topk,
            d,
            max_m: cfg.max_m.max(1),
            request_timeout: cfg.request_timeout,
            obs,
            midx,
        }
    }

    /// Midx telemetry cells (`kss_sampler_midx_*`), when in midx mode.
    pub fn midx_obs(&self) -> Option<&MidxObs> {
        self.midx.as_deref().map(|core| core.obs())
    }

    /// Service-level telemetry cells (shared with the worker pool).
    pub fn obs(&self) -> &ServiceObs {
        &self.obs
    }

    /// Register every metric this service owns — its own cells plus the
    /// micro-batcher's — into `reg`. One call wires the whole request path.
    pub fn register_metrics(&self, reg: &MetricsRegistry) {
        self.obs.register_into(reg);
        self.batcher.obs().register_into(reg);
        if let Some(core) = &self.midx {
            core.obs().register_into(reg);
        }
    }

    /// Enqueue a sampling request; returns its sequence number and the
    /// response receiver. Fails fast under overload (bounded queue) and on
    /// malformed requests (wrong `h` length).
    pub fn submit(
        &self,
        h: Vec<f32>,
        m: usize,
    ) -> Result<(u64, mpsc::Receiver<SampleResponse>), ServeError> {
        if h.len() != self.d {
            return Err(ServeError::BadRequest { got: h.len(), want: self.d });
        }
        if m == 0 || m > self.max_m {
            return Err(ServeError::BadSampleCount { got: m, max: self.max_m });
        }
        self.batcher.submit(h, m)
    }

    /// Submit and block for the response (the closed-loop client path).
    /// Bounded wait: a wedged or dead worker pool surfaces as
    /// [`ServeError::Timeout`] instead of hanging the caller forever.
    pub fn sample_blocking(&self, h: Vec<f32>, m: usize) -> Result<SampleResponse, ServeError> {
        let (_, rx) = self.submit(h, m)?;
        rx.recv_timeout(self.request_timeout).map_err(|e| match e {
            mpsc::RecvTimeoutError::Timeout => ServeError::Timeout,
            mpsc::RecvTimeoutError::Disconnected => ServeError::ShuttingDown,
        })
    }

    /// Top-k retrieval against the freshest published generation of every
    /// shard. Served inline (not through the batcher): retrieval reads one
    /// consistent pinned snapshot set and needs no RNG stream bookkeeping.
    ///
    /// This path takes each store's short swap lock (one `Arc` clone per
    /// shard) instead of a wait-free cached reader — a deliberate trade:
    /// the beam search dominates a retrieval call by orders of magnitude,
    /// `&self` here would force a shared mutable cache (its own lock), and
    /// the high-QPS sample path already goes through the workers' wait-free
    /// [`SnapshotReader`]s. Revisit if retrieval ever becomes the dominant
    /// traffic class.
    pub fn topk(&self, h: &[f32]) -> Result<Vec<Hit>, ServeError> {
        if h.len() != self.d {
            return Err(ServeError::BadRequest { got: h.len(), want: self.d });
        }
        let snaps: Vec<Arc<TreeSnapshot<M>>> =
            self.stores.iter().map(|s| s.load().1).collect();
        Ok(topk_over_snapshots(&snaps, &self.offsets, h, self.topk_cfg))
    }

    /// Requests shed for overload so far.
    pub fn rejected(&self) -> u64 {
        self.batcher.rejected.load(Ordering::Relaxed)
    }

    /// Queued rows right now.
    pub fn queue_depth(&self) -> usize {
        self.batcher.depth()
    }

    /// Drain the queue, stop the workers, and propagate any worker panic.
    pub fn shutdown(mut self) {
        self.batcher.shutdown();
        for w in self.workers.drain(..) {
            if let Err(payload) = w.join() {
                std::panic::resume_unwind(payload);
            }
        }
    }
}

impl<M: FeatureMap + 'static> Drop for SamplingService<M> {
    fn drop(&mut self) {
        // unblock workers if the service is dropped without shutdown();
        // they drain and exit on their own (drop does not join)
        self.batcher.shutdown();
    }
}

/// One worker: pull closed batches, refresh shard snapshots once per
/// batch, draw every request from its own `row_rng(seed, seq)` stream.
fn worker_loop<M: FeatureMap>(
    batcher: &MicroBatcher,
    stores: &[Arc<SnapshotStore<TreeSnapshot<M>>>],
    offsets: &[u32],
    seed: u64,
    obs: &ServiceObs,
    midx: Option<&MidxCore>,
) {
    let mut readers: Vec<SnapshotReader<TreeSnapshot<M>>> =
        stores.iter().map(|s| SnapshotReader::new(s.clone())).collect();
    // scratch geometry (node counts, φ dim) is fixed across generations,
    // so one state serves the worker for its whole life
    let mut state = {
        let views: Vec<TreeView<'_, M>> =
            readers.iter().map(|r| r.pinned().tree.view()).collect();
        scratch_for(&views)
    };
    while let Some(batch) = batcher.next_batch() {
        let picked = Instant::now();
        for r in readers.iter_mut() {
            r.current();
        }
        // pin this batch's generation set (Arc clones) so a concurrent
        // publish cannot swap trees out from under the views below
        let snaps: Vec<Arc<TreeSnapshot<M>>> =
            readers.iter().map(|r| r.pinned().clone()).collect();
        let generation = snaps.iter().map(|s| s.generation).min().unwrap_or(0);
        // read-only views: workers cannot reach an update path by type
        let trees: Vec<TreeView<'_, M>> = snaps.iter().map(|s| s.tree.view()).collect();
        let batch_rows = batch.len();
        for req in batch {
            let mut rng = row_rng(seed, req.seq as usize);
            let mut sample = Sample::with_capacity(req.m);
            // midx needs exactly one shard (SamplingService::start
            // asserts it); sample_view is infallible in that shape
            // (index_for recovers a poisoned cache by rebuilding), so
            // any residual Err falls back to the tree descent — workers
            // never panic
            let midx_drawn = match (midx, trees.split_first()) {
                (Some(core), Some((view, []))) => core
                    .sample_view(view, generation, &req.h, req.m, &mut rng, &mut sample)
                    .is_ok(),
                _ => false,
            };
            if !midx_drawn {
                draw_from_shards(&trees, offsets, &req.h, req.m, &mut state, &mut rng, &mut sample);
            }
            // a dropped receiver (client gave up) is not a worker error,
            // but the wasted work must be visible: count it
            let reply = SampleResponse {
                sample,
                generation,
                queued: picked.duration_since(req.enqueued),
                batch_rows,
            };
            if req.tx.send(reply).is_err() {
                obs.dropped_replies.inc();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::kernel::QuadraticMap;
    use crate::sampler::{SampleInput, Sampler};
    use crate::util::rng::Rng;
    use std::time::Duration;

    fn shard_set(
        n: usize,
        d: usize,
        shards: usize,
        seed: u64,
    ) -> (ShardSet<QuadraticMap>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let mut emb = vec![0.0f32; n * d];
        rng.fill_normal(&mut emb, 0.5);
        let set = ShardSet::new(QuadraticMap::new(d, 100.0), n, shards, Some(4), Some(&emb));
        (set, emb)
    }

    fn quick_cfg(workers: usize) -> ServiceConfig {
        ServiceConfig {
            workers,
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
                queue_cap: 1024,
            },
            seed: 0xFACE,
            topk: TopKConfig { k: 5, beam_width: 64 },
            max_m: 64,
            request_timeout: Duration::from_secs(30),
            midx_clusters: 0,
        }
    }

    #[test]
    fn end_to_end_requests_get_valid_samples() {
        let (n, d) = (60, 3);
        let (set, emb) = shard_set(n, d, 4, 1);
        let service = SamplingService::start(set.stores(), set.offsets().to_vec(), quick_cfg(3));
        let mut rng = Rng::new(2);
        // oracle distribution for q checks
        let map = QuadraticMap::new(d, 100.0);
        std::thread::scope(|scope| {
            for client in 0..4u64 {
                let service = &service;
                let emb = &emb;
                let map = &map;
                scope.spawn(move || {
                    let mut crng = Rng::new(50 + client);
                    for _ in 0..40 {
                        let h: Vec<f32> = (0..d).map(|_| crng.normal_f32(0.0, 1.0)).collect();
                        let resp = service.sample_blocking(h.clone(), 6).unwrap();
                        assert_eq!(resp.sample.classes.len(), 6);
                        let weights: Vec<f64> = (0..n)
                            .map(|j| map.kernel(&h, &emb[j * d..(j + 1) * d]))
                            .collect();
                        let z: f64 = weights.iter().sum();
                        for (&c, &q) in resp.sample.classes.iter().zip(&resp.sample.q) {
                            assert!((c as usize) < n);
                            let want = weights[c as usize] / z;
                            assert!((q - want).abs() < 1e-9, "q {q} vs {want}");
                        }
                        assert!(resp.batch_rows >= 1);
                    }
                });
            }
        });
        // retrieval against the same snapshots
        let h: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let hits = service.topk(&h).unwrap();
        assert_eq!(hits.len(), 5);
        let best = (0..n)
            .max_by(|&a, &b| {
                let ka = map.kernel(&h, &emb[a * d..(a + 1) * d]);
                let kb = map.kernel(&h, &emb[b * d..(b + 1) * d]);
                ka.total_cmp(&kb)
            })
            .unwrap();
        assert_eq!(hits[0].class as usize, best, "wide beam must find the argmax");
        service.shutdown();
    }

    #[test]
    fn midx_mode_serves_composed_q_matching_the_flat_oracle() {
        // single-shard service in midx mode: every (class, q) must agree
        // with the flat eq. (8) oracle (composed q — coarse × refine —
        // collapses to the flat form by linearity), and the index
        // telemetry must flow through the service registry
        let (n, d) = (60, 3);
        let (mut set, mut emb) = shard_set(n, d, 1, 7);
        let mut cfg = quick_cfg(2);
        cfg.midx_clusters = 6;
        let service = SamplingService::start(set.stores(), set.offsets().to_vec(), cfg);
        let reg = MetricsRegistry::new();
        service.register_metrics(&reg);
        let map = QuadraticMap::new(d, 100.0);
        let mut crng = Rng::new(77);
        for round in 0..3 {
            for _ in 0..20 {
                let h: Vec<f32> = (0..d).map(|_| crng.normal_f32(0.0, 1.0)).collect();
                let resp = service.sample_blocking(h.clone(), 6).unwrap();
                assert_eq!(resp.sample.classes.len(), 6);
                let weights: Vec<f64> =
                    (0..n).map(|j| map.kernel(&h, &emb[j * d..(j + 1) * d])).collect();
                let z: f64 = weights.iter().sum();
                for (&c, &q) in resp.sample.classes.iter().zip(&resp.sample.q) {
                    assert!((c as usize) < n);
                    let want = weights[c as usize] / z;
                    assert!((q - want).abs() < 1e-9, "round {round}: q {q} vs {want}");
                }
            }
            // publish a fresh generation: the shared core must rebuild
            // (warm) and keep serving exact q against the new panel
            let classes = [round, 20 + round, 40 + round];
            let mut rows = vec![0.0f32; classes.len() * d];
            crng.fill_normal(&mut rows, 0.4);
            for (i, &c) in classes.iter().enumerate() {
                emb[c * d..(c + 1) * d].copy_from_slice(&rows[i * d..(i + 1) * d]);
            }
            set.update_and_publish(&classes, &rows);
        }
        let obs = service.midx_obs().expect("midx mode has obs");
        assert!(obs.coarse_draw_total() > 0);
        assert_eq!(obs.reassign_total(), 2, "one warm rebuild per consumed publish");
        let snap = reg.snapshot();
        assert_eq!(snap.gauge("kss_sampler_midx_clusters"), Some(6.0));
        assert!(snap.counter("kss_sampler_midx_refine_total").unwrap_or(0) > 0);
        service.shutdown();
    }

    #[test]
    fn malformed_requests_are_rejected_at_submit() {
        // a wrong-length h must fail fast, not panic a worker and wedge
        // every later request
        let (set, _) = shard_set(20, 3, 2, 11);
        let service = SamplingService::start(set.stores(), set.offsets().to_vec(), quick_cfg(1));
        let err = service.submit(vec![0.0; 5], 4).unwrap_err();
        assert_eq!(err, crate::serve::ServeError::BadRequest { got: 5, want: 3 });
        let err = service.topk(&[0.0; 2]).unwrap_err();
        assert_eq!(err, crate::serve::ServeError::BadRequest { got: 2, want: 3 });
        // so must a pathological sample count (would abort the worker's
        // allocation otherwise)
        let err = service.submit(vec![0.0; 3], usize::MAX).unwrap_err();
        assert_eq!(err, crate::serve::ServeError::BadSampleCount { got: usize::MAX, max: 64 });
        let err = service.submit(vec![0.0; 3], 0).unwrap_err();
        assert_eq!(err, crate::serve::ServeError::BadSampleCount { got: 0, max: 64 });
        // the pool is still healthy afterwards
        let resp = service.sample_blocking(vec![0.1, -0.2, 0.3], 4).unwrap();
        assert_eq!(resp.sample.classes.len(), 4);
        service.shutdown();
    }

    #[test]
    fn dropped_receivers_are_counted_not_ignored() {
        let (set, _) = shard_set(20, 3, 2, 9);
        let service = SamplingService::start(set.stores(), set.offsets().to_vec(), quick_cfg(1));
        // submit and immediately hang up: the worker still computes the
        // reply, and the failed send must land in the counter
        {
            let (_seq, rx) = service.submit(vec![0.0; 3], 4).unwrap();
            drop(rx);
        }
        // with one worker and a FIFO queue, this blocking reply can only
        // arrive after the hung-up request's send already failed
        let resp = service.sample_blocking(vec![0.1, 0.2, 0.3], 4).unwrap();
        assert_eq!(resp.sample.classes.len(), 4);
        assert_eq!(service.obs().dropped_replies_total(), 1);
        // the same cell is visible through the registry under its
        // canonical name, alongside the batcher's request-path series
        let reg = MetricsRegistry::new();
        service.register_metrics(&reg);
        let snap = reg.snapshot();
        assert_eq!(snap.counter("kss_service_dropped_reply_total"), Some(1));
        assert_eq!(snap.counter("kss_batcher_submitted_total"), Some(2));
        service.shutdown();
    }

    #[test]
    fn request_results_depend_on_seq_not_batching() {
        // the same request stream must produce identical samples whether
        // requests arrive one by one (batches of 1) or all at once
        let (set, _) = shard_set(32, 2, 2, 3);
        let hs: Vec<Vec<f32>> = {
            let mut rng = Rng::new(4);
            (0..12).map(|_| (0..2).map(|_| rng.normal_f32(0.0, 1.0)).collect()).collect()
        };
        let run = |trickle: bool| -> Vec<(Vec<u32>, Vec<f64>)> {
            let cfg = ServiceConfig {
                batcher: BatcherConfig {
                    max_batch: if trickle { 1 } else { 64 },
                    max_wait: Duration::from_millis(if trickle { 0 } else { 20 }),
                    queue_cap: 256,
                },
                workers: if trickle { 1 } else { 2 },
                ..quick_cfg(1)
            };
            let service = SamplingService::start(set.stores(), set.offsets().to_vec(), cfg);
            let mut rxs = Vec::new();
            for h in &hs {
                rxs.push(service.submit(h.clone(), 4).unwrap().1);
            }
            let out = rxs
                .into_iter()
                .map(|rx| {
                    let r = rx.recv().unwrap();
                    (r.sample.classes, r.sample.q)
                })
                .collect();
            service.shutdown();
            out
        };
        let coalesced = run(false);
        let trickled = run(true);
        assert_eq!(coalesced, trickled, "batch composition changed results");
    }

    #[test]
    fn published_updates_become_visible_to_new_requests() {
        let (n, d) = (24, 2);
        let (mut set, _) = shard_set(n, d, 3, 5);
        let service = SamplingService::start(set.stores(), set.offsets().to_vec(), quick_cfg(2));
        // blow up one class's alignment and publish only its shard
        let target = 13usize;
        let w_new = vec![6.0f32, -6.0];
        let reports = set.update_and_publish(&[target], &w_new);
        assert_eq!(reports.len(), 1, "only the owning shard publishes");
        let h = vec![1.0f32, -1.0];
        // the updated class now dominates retrieval
        let hits = service.topk(&h).unwrap();
        assert_eq!(hits[0].class as usize, target, "{hits:?}");
        // and sampling mass concentrates on it
        let resp = service.sample_blocking(h.clone(), 64).unwrap();
        let hit_count = resp.sample.classes.iter().filter(|&&c| c as usize == target).count();
        assert!(hit_count > 16, "updated class undersampled: {hit_count}/64");
        assert!(resp.sample.q.iter().all(|&q| q > 0.0 && q.is_finite()));
        service.shutdown();
    }

    #[test]
    fn shard_set_matches_sharded_sampler_distribution() {
        // the writer bundle must produce the same distribution as the
        // training-side ShardedKernelSampler it was built from
        let (n, d, shards) = (40, 3, 4);
        let mut rng = Rng::new(7);
        let mut emb = vec![0.0f32; n * d];
        rng.fill_normal(&mut emb, 0.5);
        let mut sampler =
            ShardedKernelSampler::new(QuadraticMap::new(d, 100.0), n, shards, Some(4));
        sampler.reset_embeddings(&emb, n, d);
        let mut set =
            ShardSet::new(QuadraticMap::new(d, 100.0), n, shards, Some(4), Some(&emb));
        // a couple of update rounds through both paths
        for _round in 0..3 {
            let mut classes: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut classes);
            classes.truncate(5);
            classes.sort_unstable();
            let mut rows = vec![0.0f32; classes.len() * d];
            rng.fill_normal(&mut rows, 0.6);
            sampler.update_many(&classes, &rows);
            set.update_and_publish(&classes, &rows);
        }
        let h: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let input = SampleInput { h: Some(&h), ..Default::default() };
        let stores = set.stores();
        for c in 0..n as u32 {
            let want = sampler.prob(&input, c).unwrap();
            // closed form over the published snapshots
            let sid = crate::serve::shard::shard_of_class(set.offsets(), c as usize);
            let local = (c - set.offsets()[sid]) as usize;
            let snaps: Vec<_> = stores.iter().map(|s| s.load().1).collect();
            let phi = snaps[0].tree.phi_query(&h);
            let total: f64 = snaps.iter().map(|s| s.tree.partition(&phi).max(0.0)).sum();
            let k = snaps[sid].tree.feature_map().kernel(&h, snaps[sid].tree.emb_row(local));
            let got = k / total;
            assert!((got - want).abs() < 1e-9, "class {c}: {got} vs {want}");
        }
        assert!(set.stats().publishes >= 3);
    }
}
