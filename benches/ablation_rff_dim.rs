//! Ablation — **RFF feature dimension D vs sampling bias** (the knob the
//! random-feature subsystem adds, swept the way the paper sweeps m).
//!
//! For a fixed synthetic catalog, compare each proposal distribution to the
//! exact softmax target `p ∝ exp(o)` by closed-form total-variation
//! distance (no Monte-Carlo noise: every sampler's q is available in
//! closed form), averaged over query embeddings:
//!
//! * `rff D ∈ {d, 2d, 4d, d²}`, iid and structured-orthogonal ω;
//! * `quadratic` (α = 100, D = d² + 1) — the paper's kernel;
//! * timing: tree draw cost per D (the bias/throughput trade-off).
//!
//! Pure L3 — needs no artifacts. Emits `BENCH_bias.json`
//! (`KSS_BENCH_JSON_DIR` overrides the destination) so the bias trajectory
//! is diffable across PRs; CI uploads it as an artifact.
//!
//! `cargo bench --bench ablation_rff_dim` (quick) or
//! `KSS_BENCH_SCALE=full cargo bench --bench ablation_rff_dim`.

use kss::bench_harness::{print_table, scale, write_json_value, BenchRow, Bencher, Scale};
use kss::sampler::kernel::{FeatureMap, QuadraticMap};
use kss::sampler::{
    KernelTreeSampler, PositiveRffMap, RffConfig, Sample, SampleInput, Sampler,
};
use kss::util::json::Value;
use kss::util::rng::Rng;
use kss::util::stats::tv_from_scores;

/// The exact softmax target `p ∝ exp(o)` for one query — map-independent,
/// so it is computed once per query and shared across every proposal (one
/// ops-layer panel sweep + the max-shift softmax primitive).
fn softmax_target(h: &[f32], emb: &[f32], n: usize, d: usize) -> Vec<f64> {
    debug_assert_eq!(emb.len(), n * d);
    let mut logits = vec![0.0f64; n];
    kss::ops::dot_many_f32(h, emb, &mut logits);
    let mut ws = vec![0.0f64; n];
    let (_, wz) = kss::ops::max_shift_exp(&logits, &mut ws);
    ws.into_iter().map(|w| w / wz).collect()
}

/// Closed-form TV distance between a kernel proposal `q ∝ K(h, ·)` and a
/// precomputed target distribution, for one query (the TV itself is the
/// shared `util::stats::tv_from_scores`).
fn tv_to_target(map: &dyn FeatureMap, h: &[f32], emb: &[f32], d: usize, target: &[f64]) -> f64 {
    let ks: Vec<f64> =
        (0..target.len()).map(|j| map.kernel(h, &emb[j * d..(j + 1) * d])).collect();
    tv_from_scores(&ks, target)
}

struct BiasPoint {
    label: String,
    kernel: &'static str,
    dim: usize,
    variant: &'static str,
    avg_tv: f64,
}

fn main() {
    kss::util::logging::init_from_env();
    let (n, d, queries) = match scale() {
        Scale::Quick => (512usize, 8usize, 16usize),
        Scale::Full => (4096, 16, 32),
    };
    let mut rng = Rng::new(0xAB1A5);
    let mut emb = vec![0.0f32; n * d];
    rng.fill_normal(&mut emb, 0.5);
    let hs: Vec<Vec<f32>> =
        (0..queries).map(|_| (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect()).collect();
    let targets: Vec<Vec<f64>> = hs.iter().map(|h| softmax_target(h, &emb, n, d)).collect();
    let avg_tv = |map: &dyn FeatureMap| -> f64 {
        hs.iter()
            .zip(&targets)
            .map(|(h, p)| tv_to_target(map, h, &emb, d, p))
            .sum::<f64>()
            / queries as f64
    };

    println!("RFF dimension ablation: n={n} classes, d={d}, {queries} queries");
    println!("bias = closed-form TV(q, softmax), lower is better\n");

    let mut points: Vec<BiasPoint> = Vec::new();
    let quad = QuadraticMap::new(d, 100.0);
    points.push(BiasPoint {
        label: format!("quadratic α=100 (D={})", d * d + 1),
        kernel: "quadratic",
        dim: d * d + 1,
        variant: "exact",
        avg_tv: avg_tv(&quad),
    });
    let dims = [d, 2 * d, 4 * d, d * d];
    // rff rows go through the prepared-query path: one ω pass per class
    // instead of kernel()'s two (h is fixed per sweep)
    let avg_tv_rff = |map: &PositiveRffMap| -> f64 {
        hs.iter()
            .zip(&targets)
            .map(|(h, p)| {
                let prepared = map.prepare_query(h);
                let ks: Vec<f64> = (0..n)
                    .map(|j| map.kernel_prepared(&prepared, &emb[j * d..(j + 1) * d]))
                    .collect();
                tv_from_scores(&ks, p)
            })
            .sum::<f64>()
            / queries as f64
    };
    for &dim in &dims {
        for (orth, variant) in [(false, "iid"), (true, "orthogonal")] {
            let cfg = RffConfig::new(d, 0x2FF + dim as u64).with_dim(dim).with_orthogonal(orth);
            let map = PositiveRffMap::new(cfg);
            points.push(BiasPoint {
                label: format!("rff {variant} D={dim}"),
                kernel: "rff",
                dim,
                variant,
                avg_tv: avg_tv_rff(&map),
            });
        }
    }
    println!("{:<28} {:>8} {:>14}", "proposal", "D", "avg TV vs p");
    for p in &points {
        println!("{:<28} {:>8} {:>14.4}", p.label, p.dim, p.avg_tv);
    }

    // timing: tree draw cost as D grows (the other side of the trade-off)
    let bencher = Bencher::default();
    let m = 32;
    let mut rows: Vec<BenchRow> = Vec::new();
    let mut time_tree = |label: String, sampler: &dyn Sampler, h: &[f32]| {
        let input = SampleInput { h: Some(h), ..Default::default() };
        let mut out = Sample::with_capacity(m);
        let mut r = Rng::new(7);
        rows.push(bencher.run_with_items(&label, Some(m as f64), || {
            sampler.sample(&input, m, &mut r, &mut out).unwrap();
        }));
    };
    let mut quad_tree = KernelTreeSampler::new(quad.clone(), n, None);
    quad_tree.reset_embeddings(&emb, n, d);
    time_tree(format!("quadratic tree draw (D={})", d * d + 1), &quad_tree, &hs[0]);
    for &dim in &dims {
        let cfg = RffConfig::new(d, 0x2FF + dim as u64).with_dim(dim);
        let mut tree = KernelTreeSampler::new(PositiveRffMap::new(cfg), n, None);
        tree.reset_embeddings(&emb, n, d);
        time_tree(format!("rff tree draw D={dim}"), &tree, &hs[0]);
    }
    print_table("tree draw cost vs D", &rows);

    // machine-readable dump: bias series + timing rows
    let doc = Value::object(vec![
        ("bench", Value::str("bias")),
        (
            "scale",
            Value::str(match scale() {
                Scale::Quick => "quick",
                Scale::Full => "full",
            }),
        ),
        ("n_classes", Value::num(n as f64)),
        ("d", Value::num(d as f64)),
        ("queries", Value::num(queries as f64)),
        (
            "series",
            Value::Array(
                points
                    .iter()
                    .map(|p| {
                        Value::object(vec![
                            ("label", Value::str(&p.label)),
                            ("kernel", Value::str(p.kernel)),
                            ("dim", Value::num(p.dim as f64)),
                            ("variant", Value::str(p.variant)),
                            ("avg_tv_vs_softmax", Value::num(p.avg_tv)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "draw_cost",
            Value::Array(
                rows.iter()
                    .map(|r| {
                        Value::object(vec![
                            ("name", Value::str(&r.name)),
                            ("mean_s", Value::num(r.mean_s)),
                            ("p95_s", Value::num(r.p95_s)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    write_json_value("bias", &doc);

    println!("\nshape to check: rff TV falls monotonically-ish in D and undercuts");
    println!("quadratic well before D reaches the quadratic map's d²+1.");
}
