//! In-tree substrates.
//!
//! The build environment is fully offline with a fixed crate cache that does
//! not include the usual ecosystem crates (`rand`, `serde`, `clap`,
//! `criterion`, `rayon`, `proptest`), so this module provides the pieces the
//! rest of the system needs:
//!
//! * [`rng`] — deterministic PRNG (xoshiro256\*\*) and the distributions the
//!   paper's experiments require (uniform, normal, Zipf, categorical, and
//!   Walker's alias method — the paper cites Walker 1977 in §6).
//! * [`json`] — a small JSON parser/serializer for the artifact manifest,
//!   config files and metric sinks.
//! * [`cli`] — a typed command-line flag parser for the launcher.
//! * [`threadpool`] — scoped data-parallel map used to sample negatives for
//!   all rows of a batch concurrently.
//! * [`stats`] — online statistics and wall-clock timers shared by the
//!   trainer and the bench harness.
//! * [`testing`] — a miniature property-testing harness (seeded case
//!   generation with failure seeds reported) used across the test suite.
//! * [`logging`] — leveled stderr logger for the coordinator.

pub mod cli;
pub mod json;
pub mod logging;
pub mod rng;
pub mod stats;
pub mod testing;
pub mod threadpool;
