"""L1 correctness: Pallas kernels vs the pure-jnp oracles in ref.py.

This is the core correctness signal for the compute stack: everything the
rust coordinator executes was lowered from these kernels, so agreement here
(values *and* gradients, standard *and* absolute softmax) pins the whole
numeric path. Hypothesis sweeps shapes, seeds and block sizes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.full_softmax import full_softmax_loss, pick_chunk
from compile.kernels.sampled_softmax import pick_block, sampled_softmax_loss

jax.config.update("jax_platform_name", "cpu")


def make_inputs(seed, n_rows, s, d, scale=1.0):
    rng = np.random.default_rng(seed)
    h = jnp.asarray(scale * rng.normal(size=(n_rows, d)), jnp.float32)
    ws = jnp.asarray(scale * rng.normal(size=(n_rows, s, d)), jnp.float32)
    sub = np.zeros((n_rows, s), np.float32)
    sub[:, 1:] = rng.uniform(0.0, 4.0, size=(n_rows, s - 1))
    return h, ws, jnp.asarray(sub)


# ---------------------------------------------------------------------------
# sampled softmax kernel
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n_rows=st.integers(1, 48),
    s=st.integers(2, 33),
    d=st.sampled_from([1, 3, 8, 16, 64]),
    abs_logits=st.booleans(),
)
def test_sampled_loss_matches_ref(seed, n_rows, s, d, abs_logits):
    h, ws, sub = make_inputs(seed, n_rows, s, d)
    got = sampled_softmax_loss(h, ws, sub, abs_logits)
    want = ref.sampled_softmax_loss_ref(h, ws, sub, abs_logits)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=12, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n_rows=st.integers(1, 24),
    s=st.integers(2, 17),
    d=st.sampled_from([2, 8, 32]),
    abs_logits=st.booleans(),
)
def test_sampled_grads_match_ref(seed, n_rows, s, d, abs_logits):
    h, ws, sub = make_inputs(seed, n_rows, s, d)

    def f(h, ws, sub):
        return jnp.mean(sampled_softmax_loss(h, ws, sub, abs_logits))

    def fr(h, ws, sub):
        return jnp.mean(ref.sampled_softmax_loss_ref(h, ws, sub, abs_logits))

    got = jax.grad(f, argnums=(0, 1, 2))(h, ws, sub)
    want = jax.grad(fr, argnums=(0, 1, 2))(h, ws, sub)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=1e-4, atol=1e-5)


def test_sampled_block_sizes_agree():
    """Different row blockings must not change the numerics."""
    h, ws, sub = make_inputs(7, 24, 9, 16)
    base = sampled_softmax_loss(h, ws, sub, False, 24)
    for bn in [1, 2, 3, 4, 6, 8, 12]:
        np.testing.assert_allclose(
            sampled_softmax_loss(h, ws, sub, False, bn), base, rtol=1e-6, atol=1e-6
        )


def test_sampled_grad_logits_identity():
    """The kernel's gradient seed is (p' - y') — eq. (5) of the paper —
    checked through the ws cotangent: dL/dws[n,s] = g[n,s] * h[n]."""
    h, ws, sub = make_inputs(3, 6, 5, 8)
    g_ref = ref.sampled_softmax_grad_logits_ref(h, ws, sub, False)
    dws = jax.grad(lambda ws: jnp.sum(sampled_softmax_loss(h, ws, sub, False)))(ws)
    want = g_ref[:, :, None] * np.asarray(h)[:, None, :]
    np.testing.assert_allclose(dws, want, rtol=1e-4, atol=1e-6)


def test_sampled_extreme_logits_stable():
    """Large-magnitude logits must not overflow (stable log-softmax)."""
    h, ws, sub = make_inputs(11, 8, 7, 16, scale=20.0)
    loss = sampled_softmax_loss(h, ws, sub, False)
    assert np.all(np.isfinite(loss))
    want = ref.sampled_softmax_loss_ref(h, ws, sub, False)
    np.testing.assert_allclose(loss, want, rtol=1e-4, atol=1e-4)


def test_sampled_zero_correction_is_plain_softmax_ce():
    """With sub == 0 the loss is ordinary softmax CE over the sample."""
    h, ws, _ = make_inputs(5, 10, 6, 8)
    sub = jnp.zeros((10, 6), jnp.float32)
    got = sampled_softmax_loss(h, ws, sub, False)
    logits = jnp.einsum("nsd,nd->ns", ws, h)
    want = -jax.nn.log_softmax(logits, axis=-1)[:, 0]
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_pick_block_divides():
    for n in [1, 7, 30, 128, 400, 1000, 997]:
        b = pick_block(n)
        assert n % b == 0 and 1 <= b <= max(n, 1)


# ---------------------------------------------------------------------------
# full softmax kernel
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n_rows=st.integers(1, 24),
    n_classes=st.sampled_from([2, 10, 40, 100, 256]),
    d=st.sampled_from([1, 4, 16]),
    abs_logits=st.booleans(),
)
def test_full_loss_matches_ref(seed, n_rows, n_classes, d, abs_logits):
    rng = np.random.default_rng(seed)
    h = jnp.asarray(rng.normal(size=(n_rows, d)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(n_classes, d)), jnp.float32)
    pos = jnp.asarray(rng.integers(0, n_classes, n_rows), jnp.int32)
    got = full_softmax_loss(h, w, pos, abs_logits)
    want = ref.full_softmax_loss_ref(h, w, pos, abs_logits)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n_rows=st.integers(1, 12),
    n_classes=st.sampled_from([6, 30, 128]),
    d=st.sampled_from([2, 8]),
    abs_logits=st.booleans(),
)
def test_full_grads_match_ref(seed, n_rows, n_classes, d, abs_logits):
    rng = np.random.default_rng(seed)
    h = jnp.asarray(rng.normal(size=(n_rows, d)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(n_classes, d)), jnp.float32)
    pos = jnp.asarray(rng.integers(0, n_classes, n_rows), jnp.int32)

    def f(h, w):
        return jnp.mean(full_softmax_loss(h, w, pos, abs_logits))

    def fr(h, w):
        return jnp.mean(ref.full_softmax_loss_ref(h, w, pos, abs_logits))

    got = jax.grad(f, argnums=(0, 1))(h, w)
    want = jax.grad(fr, argnums=(0, 1))(h, w)
    for g, ww in zip(got, want):
        np.testing.assert_allclose(g, ww, rtol=1e-4, atol=1e-5)


def test_full_streaming_chunks_agree():
    """Online-logsumexp chunking must not change the numerics."""
    rng = np.random.default_rng(0)
    h = jnp.asarray(rng.normal(size=(6, 8)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(60, 8)), jnp.float32)
    pos = jnp.asarray(rng.integers(0, 60, 6), jnp.int32)
    want = ref.full_softmax_loss_ref(h, w, pos, False)
    # chunk sizes that divide 60
    from compile.kernels.full_softmax import _fwd_pallas

    for cc in [1, 2, 5, 12, 30, 60]:
        got, _ = _fwd_pallas(h, w, w[pos], False, None, cc)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_full_repeated_positives_grad():
    """Several rows sharing the same positive class: the scatter-add into dW
    must accumulate (a classic scatter bug catcher)."""
    rng = np.random.default_rng(1)
    h = jnp.asarray(rng.normal(size=(5, 4)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(12, 4)), jnp.float32)
    pos = jnp.asarray([3, 3, 3, 7, 3], jnp.int32)
    got = jax.grad(lambda w: jnp.sum(full_softmax_loss(h, w, pos, False)))(w)
    want = jax.grad(lambda w: jnp.sum(ref.full_softmax_loss_ref(h, w, pos, False)))(w)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_pick_chunk_divides():
    for n in [10, 512, 10_000, 100_000, 99_991]:
        c = pick_chunk(n)
        assert n % c == 0


# ---------------------------------------------------------------------------
# feature-map oracle (layout contract with the rust tree)
# ---------------------------------------------------------------------------


def test_phi_quadratic_reproduces_kernel():
    """⟨φ(a), φ(b)⟩ must equal α⟨a,b⟩² + 1 — eq. (10)."""
    rng = np.random.default_rng(2)
    for d in [1, 2, 5, 16]:
        a = jnp.asarray(rng.normal(size=d), jnp.float32)
        b = jnp.asarray(rng.normal(size=d), jnp.float32)
        phi_a = ref.phi_quadratic_ref(a, 100.0)
        phi_b = ref.phi_quadratic_ref(b, 100.0)
        assert phi_a.shape == (d * d + 1,)
        got = float(jnp.dot(phi_a, phi_b))
        want = float(100.0 * jnp.dot(a, b) ** 2 + 1.0)
        assert got == pytest.approx(want, rel=1e-4)


def test_kernels_are_positive():
    rng = np.random.default_rng(3)
    h = jnp.asarray(rng.normal(size=(10, 8)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(50, 8)), jnp.float32)
    assert np.all(np.asarray(ref.quadratic_kernel_ref(h, w)) >= 1.0)
    assert np.all(np.asarray(ref.quartic_kernel_ref(h, w)) >= 1.0)
