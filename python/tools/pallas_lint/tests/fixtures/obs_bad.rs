//! OBS fixture — every way to swallow an error invisibly.

pub struct Worker {
    tx: std::sync::mpsc::Sender<u32>,
}

impl Worker {
    pub fn reply(&self, v: u32) {
        // 1. silently dropped send: the response was computed, the client
        //    hung up, and nothing records it
        let _ = self.tx.send(v);
    }

    pub fn drain(&self, r: Result<u32, String>) -> u32 {
        // 2. empty error arm
        match r {
            Ok(v) => v,
            Err(_) => {}
        }
        0
    }

    pub fn flush(&self, r: Result<(), String>) {
        // 3. statement-position .ok() discards the Result wholesale
        r.ok();
    }
}

#[cfg(test)]
mod tests {
    // discards inside tests are fine — no operator is watching a test
    #[test]
    fn drops_allowed_here() {
        let (tx, rx) = std::sync::mpsc::channel::<u32>();
        drop(rx);
        let _ = tx.send(1);
    }
}
