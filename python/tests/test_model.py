"""L2 correctness: model entry points, shapes, training dynamics, and the
input/output contract the rust coordinator relies on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs as C
from compile import model as M
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

TINY = C.CONFIGS["tiny"]
TINY_LM = C.CONFIGS["tiny-lm"]
TINY_ABS = C.CONFIGS["tiny-abs"]


def make_batch(cfg, seed=0):
    """Random data inputs matching cfg.data_specs order (without lr)."""
    rng = np.random.default_rng(seed)
    if cfg.model == "lm":
        tokens = rng.integers(0, cfg.n_classes, (cfg.batch, cfg.seq_len))
        targets = rng.integers(0, cfg.n_classes, (cfg.batch, cfg.seq_len))
        return [jnp.asarray(tokens, jnp.int32)], jnp.asarray(targets, jnp.int32)
    user = rng.normal(size=(cfg.batch, cfg.n_user_features))
    prev = rng.integers(0, cfg.n_classes, (cfg.batch, cfg.n_prev))
    pos = rng.integers(0, cfg.n_classes, (cfg.batch,))
    return [jnp.asarray(user, jnp.float32), jnp.asarray(prev, jnp.int32)], jnp.asarray(pos, jnp.int32)


def make_sample(cfg, m, seed=1):
    rng = np.random.default_rng(seed)
    n = cfg.n_examples
    neg = jnp.asarray(rng.integers(0, cfg.n_classes, (n, m)), jnp.int32)
    sub = np.zeros((n, m + 1), np.float32)
    sub[:, 1:] = np.log(m / cfg.n_classes)  # uniform q correction
    return neg, jnp.asarray(sub)


@pytest.mark.parametrize("cfg", [TINY, TINY_LM, TINY_ABS], ids=lambda c: c.name)
def test_encode_shape(cfg):
    params = cfg.init_params(jax.random.PRNGKey(0))
    data, _ = make_batch(cfg)
    h = M.encode(cfg, params, *data)
    assert h.shape == (cfg.n_examples, cfg.d)
    assert np.all(np.isfinite(h))


@pytest.mark.parametrize("cfg", [TINY, TINY_LM], ids=lambda c: c.name)
def test_score_all_is_h_dot_w(cfg):
    params = cfg.init_params(jax.random.PRNGKey(1))
    data, _ = make_batch(cfg)
    logits = M.score_all(cfg, params, *data)
    h = M.encode(cfg, params, *data)
    np.testing.assert_allclose(logits, h @ params[-1].T, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("cfg", [TINY, TINY_LM, TINY_ABS], ids=lambda c: c.name)
def test_eval_full_matches_ref(cfg):
    params = cfg.init_params(jax.random.PRNGKey(2))
    data, pos = make_batch(cfg)
    got = M.eval_full(cfg, params, *data, pos)
    h = M.encode(cfg, params, *data)
    want = jnp.sum(ref.full_softmax_loss_ref(h, params[-1], pos.reshape(-1), cfg.abs_logits))
    assert float(got) == pytest.approx(float(want), rel=1e-5)


@pytest.mark.parametrize("cfg", [TINY, TINY_LM], ids=lambda c: c.name)
def test_train_sampled_step_contract(cfg):
    """Output order/shapes and the 'rows' gather contract used by rust."""
    m = 4
    params = cfg.init_params(jax.random.PRNGKey(3))
    data, pos = make_batch(cfg)
    neg, sub = make_sample(cfg, m)
    out = M.train_sampled(cfg, params, *data, pos, neg, sub, jnp.float32(0.1))
    n_p = len(cfg.param_specs())
    new_params, loss, rows = list(out[:n_p]), out[n_p], out[n_p + 1]
    for p_new, (name, shape, _) in zip(new_params, cfg.param_specs()):
        assert p_new.shape == shape, name
    assert loss.shape == ()
    assert rows.shape == (cfg.n_examples, m + 1, cfg.d)
    # rows must equal the *updated* out_w gathered at s = [pos, neg]
    s = np.concatenate([np.asarray(pos).reshape(-1, 1), np.asarray(neg)], axis=1)
    np.testing.assert_allclose(rows, np.asarray(new_params[-1])[s], rtol=1e-6, atol=1e-7)
    # parameters actually moved
    assert not np.allclose(new_params[-1], params[-1])


@pytest.mark.parametrize("cfg", [TINY, TINY_LM], ids=lambda c: c.name)
def test_train_sampled_only_sampled_rows_change(cfg):
    """Sampled softmax touches only the sampled classes' output embeddings —
    the sparsity the rust tree-update path depends on."""
    m = 4
    params = cfg.init_params(jax.random.PRNGKey(4))
    data, pos = make_batch(cfg)
    neg, sub = make_sample(cfg, m)
    out = M.train_sampled(cfg, params, *data, pos, neg, sub, jnp.float32(0.5))
    new_out_w = np.asarray(out[len(cfg.param_specs()) - 1])
    old_out_w = np.asarray(params[-1])
    s = set(np.asarray(pos).reshape(-1).tolist()) | set(np.asarray(neg).reshape(-1).tolist())
    changed = set(np.nonzero(np.abs(new_out_w - old_out_w).max(axis=1) > 0)[0].tolist())
    assert changed <= s, f"classes outside the sample changed: {sorted(changed - s)[:5]}"


def test_train_full_decreases_loss():
    cfg = TINY
    params = cfg.init_params(jax.random.PRNGKey(5))
    data, pos = make_batch(cfg)
    losses = []
    for _ in range(8):
        out = M.train_full(cfg, params, *data, pos, jnp.float32(0.5))
        params = list(out[:-1])
        losses.append(float(out[-1]))
    assert losses[-1] < losses[0], losses


def test_train_sampled_decreases_full_loss():
    """Training with sampled softmax (exact-softmax q would be unbiased; we
    use uniform q with enough samples) reduces the *full* softmax loss."""
    cfg = TINY
    m = 32
    params = cfg.init_params(jax.random.PRNGKey(6))
    data, pos = make_batch(cfg)
    rng = np.random.default_rng(0)
    before = float(M.eval_full(cfg, params, *data, pos)) / cfg.n_examples
    for step in range(12):
        neg = jnp.asarray(rng.integers(0, cfg.n_classes, (cfg.n_examples, m)), jnp.int32)
        sub = np.zeros((cfg.n_examples, m + 1), np.float32)
        sub[:, 1:] = np.log(m / cfg.n_classes)
        out = M.train_sampled(cfg, params, *data, pos, neg, jnp.asarray(sub), jnp.float32(0.3))
        params = list(out[: len(cfg.param_specs())])
    after = float(M.eval_full(cfg, params, *data, pos)) / cfg.n_examples
    assert after < before - 0.3, (before, after)


def test_abs_variant_differs_and_is_finite():
    data, pos = make_batch(TINY)
    params = TINY.init_params(jax.random.PRNGKey(7))
    std = float(M.eval_full(TINY, params, *data, pos))
    ab = float(M.eval_full(TINY_ABS, params, *data, pos))
    assert np.isfinite(std) and np.isfinite(ab)
    assert std != pytest.approx(ab, rel=1e-6)  # |o| changes the loss


def test_example_args_match_specs():
    for cfg in [TINY, TINY_LM]:
        for op in ["encode", "score_all", "eval_full", "train_full"]:
            args = M.example_args(cfg, op)
            assert len(args) == len(cfg.param_specs()) + len(cfg.data_specs(op))
        args = M.example_args(cfg, "train_sampled", 4)
        assert len(args) == len(cfg.param_specs()) + len(cfg.data_specs("train_sampled", 4))


def test_lower_to_hlo_text_smoke():
    text = M.lower_to_hlo_text(TINY, "encode")
    assert text.startswith("HloModule")
    assert "ENTRY" in text
