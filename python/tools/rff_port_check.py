#!/usr/bin/env python3
"""Line-for-line Python port of the RFF-subsystem algorithms, run against
the same property checks as the Rust tests (the build container has no
rust toolchain — see .claude/skills/verify/SKILL.md; serve_port_check.py
is the PR-2 precedent and supplies the shared Tree/shard ports).

Ported and checked here:

  1. PositiveRffMap (rust/src/sampler/rff/map.rs): factored positive
     feature map + closed-form realized kernel — ⟨φ(a),φ(b)⟩ == K̂(a,b),
     positivity, exp-kernel unbiasedness for iid AND orthogonal ω
     (tolerance margin of the Rust test measured empirically)
  2. draw_orthogonal_omega (rff/orthogonal.rs): blockwise Gram–Schmidt +
     χ_d radius — within-block orthogonality, N(0, I_d) marginal scale
  3. tree integration: the PR-1/2 Tree port instantiated with the RFF map
     — reported q == realized-kernel closed form; sharded == unsharded
  4. flat sampler rework (kernel/flat.rs): scratch-CDF sample_into vs the
     old Cdf::sample semantics on a shared uniform stream (bit-identical
     draw indices), Exp max-shift invariance, chi-square GOF of exp
     sampling against softmax
  5. the acceptance property: rff at D = 4d beats quadratic TV-to-softmax
     on dominant-tail rows (the exact construction of
     rff/tests.rs::rff_4d_beats_quadratic_tv_to_softmax_on_dominant_tail),
     swept over many seeds incl. simulated empirical-TV noise
  6. the SAME acceptance property on the exact five case realizations the
     Rust test will run: a faithful port of util/rng.rs (xoshiro256** +
     splitmix64 + Box-Muller spare, f32 arithmetic where the test uses it)
     reproduces each case's (h, emb, omega) bit-faithfully and pins its
     closed-form margin well above the asserted 0.1 + multinomial noise —
     so the statistical assert cannot flake on first real `cargo test`

Run: python3 python/tools/rff_port_check.py
"""
import math
import os
import random
import sys

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from serve_port_check import Tree, QuadraticMap, draw_from_shards, exact_dist  # noqa: E402

MAX_EXP = 700.0


def draw_orthogonal_omega(rng, rows, d):
    """Port of rff/orthogonal.rs::draw_orthogonal_omega."""
    omega = np.zeros((rows, d))
    block = []
    for r in range(rows):
        if r % d == 0:
            block = []
        while True:
            v = np.array([rng.gauss(0, 1) for _ in range(d)])
            for prev in block:
                v = v - np.dot(v, prev) * prev
            n2 = float(np.dot(v, v))
            if n2 > 1e-24:
                v = v / math.sqrt(n2)
                break
        radius = math.sqrt(sum(rng.gauss(0, 1) ** 2 for _ in range(d)))
        omega[r] = radius * v
        block.append(v)
    return omega


class RffMap:
    """Port of rff/map.rs::PositiveRffMap."""

    def __init__(self, d, omega):
        self.d = d
        self.omega = np.asarray(omega, dtype=np.float64).reshape(-1, d)

    @classmethod
    def draw(cls, d, dim, seed, orthogonal=False):
        rng = random.Random(seed)
        if orthogonal:
            return cls(d, draw_orthogonal_omega(rng, dim, d))
        return cls(d, np.array([[rng.gauss(0, 1) for _ in range(d)] for _ in range(dim)]))

    def dim(self):
        return self.omega.shape[0]

    def phi(self, a):
        a = np.asarray(a, dtype=np.float64)
        log_pref = -0.5 * float(a @ a) - 0.5 * math.log(self.dim())
        e = self.omega @ a + log_pref
        return np.exp(np.minimum(e, MAX_EXP))

    def kernel(self, a, b):
        a = np.asarray(a, dtype=np.float64)
        b = np.asarray(b, dtype=np.float64)
        log_pref = -0.5 * float(a @ a) - 0.5 * float(b @ b) - math.log(self.dim())
        e = self.omega @ a + self.omega @ b + log_pref
        return float(np.exp(np.minimum(e, MAX_EXP)).sum())


# --- 1/2: map + orthogonal draws ----------------------------------------
def check_phi_kernel_consistency(trials=60):
    rng = random.Random(11)
    for case in range(trials):
        d = rng.randint(1, 10)
        dim = rng.randint(1, 40)
        m = RffMap.draw(d, dim, 100 + case, orthogonal=case % 2 == 0)
        npr = np.random.default_rng(case)
        a = npr.uniform(-1.5, 1.5, d)
        b = npr.uniform(-1.5, 1.5, d)
        ip = float(m.phi(a) @ m.phi(b))
        k = m.kernel(a, b)
        assert abs(ip - k) < 1e-9 * max(abs(k), 1e-9), (case, ip, k)
        assert np.all(m.phi(a) > 0)
    print("  phi inner product == realized kernel, phi > 0: OK")


def check_unbiasedness(seeds=400):
    a = np.array([0.4, -0.3, 0.5])
    b = np.array([-0.2, 0.6, 0.35])
    want = math.exp(float(a @ b))
    for orth in (False, True):
        vals = [RffMap.draw(3, 12, 7000 + s, orth).kernel(a, b) for s in range(seeds)]
        mean = float(np.mean(vals))
        rel = abs(mean - want) / want
        # the Rust test allows 12% — measure the actual spread to confirm
        # that bound is comfortably > 4 sigma of the mean estimator
        sigma_rel = float(np.std(vals)) / math.sqrt(seeds) / want
        assert rel < 0.12, (orth, mean, want)
        assert 4 * sigma_rel < 0.12, f"tolerance too tight: 4σ={4*sigma_rel:.4f}"
    print("  exp-kernel unbiasedness (iid + orthogonal), 12% tol > 4σ: OK")


def check_orthogonal_structure():
    rng = random.Random(5)
    d, rows = 6, 15
    om = draw_orthogonal_omega(rng, rows, d)
    for blk in range((rows + d - 1) // d):
        lo, hi = blk * d, min(blk * d + d, rows)
        for i in range(lo, hi):
            for j in range(i + 1, hi):
                assert abs(float(om[i] @ om[j])) < 1e-9, (i, j)
    rng = random.Random(6)
    big = draw_orthogonal_omega(rng, 4000, 8)
    mean_sq = float((big ** 2).sum(axis=1).mean())
    assert abs(mean_sq - 8.0) < 0.3, mean_sq
    print("  orthogonal blocks + chi_d marginal scale: OK")


# --- 3: tree/shard integration ------------------------------------------
def check_rff_tree(trials=25):
    rng = random.Random(21)
    for case in range(trials):
        n = rng.randint(4, 48)
        d = rng.randint(1, 6)
        leaf = rng.randint(1, 8)
        fmap = RffMap.draw(d, rng.randint(2, 24), 500 + case, orthogonal=case % 2 == 0)
        emb = np.random.default_rng(case).normal(0, 0.5, (n, d)).astype(np.float32)
        t = Tree(fmap, n, leaf)
        t.reset(emb)
        h = np.random.default_rng(case + 777).normal(0, 1, d).astype(np.float32)
        expected = exact_dist(fmap, h, emb)
        s = t.begin_example(h)
        for _ in range(48):
            c, q = t.draw(h, s, rng)
            assert abs(q - expected[c]) < 1e-9 * max(expected[c], 1e-12), (case, c, q, expected[c])
    print("  rff tree q == realized-kernel closed form: OK")


def check_rff_sharded(trials=10):
    rng = random.Random(31)
    for case in range(trials):
        n = rng.randint(6, 60)
        d = rng.randint(1, 5)
        shards = rng.randint(2, min(6, n))
        fmap = RffMap.draw(d, rng.randint(2, 16), 900 + case)
        emb = np.random.default_rng(case).normal(0, 0.5, (n, d)).astype(np.float32)
        offsets = [s * n // shards for s in range(shards + 1)]
        trees = []
        for s in range(shards):
            lo, hi = offsets[s], offsets[s + 1]
            t = Tree(fmap, hi - lo, 4)  # clone semantics: same fmap object
            t.reset(emb[lo:hi])
            trees.append(t)
        h = np.random.default_rng(case + 333).normal(0, 1, d).astype(np.float32)
        expected = exact_dist(fmap, h, emb)
        for c, q in draw_from_shards(trees, offsets, h, 32, rng):
            assert abs(q - expected[c]) < 1e-9 * max(expected[c], 1e-12), (case, c, q)
    print("  rff sharded q == unsharded realized-kernel distribution: OK")


# --- 4: flat sampler rework ---------------------------------------------
def kind_shift(kind, logits):
    return float(np.max(logits)) if kind == "exp" else 0.0


def kind_weight(kind, o, shift):
    o = float(o)
    if kind == "quadratic":
        return 100.0 * o * o + 1.0
    if kind == "quartic":
        return o ** 4 + 1.0
    return math.exp(o - shift)


def old_cdf_sample(cum, total, u):
    """Port of util/rng.rs::Cdf::sample (the pre-PR flat draw)."""
    idx = sum(1 for c in cum if c <= u * total)
    if idx < len(cum):
        return idx
    for i in reversed(range(len(cum))):
        lo = 0.0 if i == 0 else cum[i - 1]
        if cum[i] - lo > 0.0:
            return i
    raise AssertionError("zero mass")


def new_sample_into(kind, logits, us):
    """Port of kernel/flat.rs::sample_into over a given uniform stream."""
    shift = kind_shift(kind, logits)
    w = [np.float32(kind_weight(kind, o, shift)) for o in logits]
    cum, acc = [], 0.0
    for x in w:
        acc += float(x)
        cum.append(acc)
    total = acc
    assert total > 0.0 and math.isfinite(total)
    out = []
    for u in us:
        idx = sum(1 for c in cum if c <= u * total)
        if idx >= len(cum):
            idx = next(
                i
                for i in reversed(range(len(cum)))
                if cum[i] - (0.0 if i == 0 else cum[i - 1]) > 0.0
            )
        lo = 0.0 if idx == 0 else cum[idx - 1]
        q = max((cum[idx] - lo) / total, 5e-324)
        out.append((idx, q))
    return out


def check_flat_rework(trials=40):
    rng = random.Random(51)
    for case in range(trials):
        n = rng.randint(2, 60)
        logits = np.random.default_rng(case).normal(0, 1.5, n).astype(np.float32)
        kind = ("quadratic", "quartic", "exp")[case % 3]
        us = [rng.random() for _ in range(32)]
        got = new_sample_into(kind, logits, us)
        # reference: the old Cdf path over the same (shifted) weights
        shift = kind_shift(kind, logits)
        w = [np.float32(kind_weight(kind, o, shift)) for o in logits]
        cum, acc = [], 0.0
        for x in w:
            acc += float(x)
            cum.append(acc)
        for u, (idx, q) in zip(us, got):
            ref = old_cdf_sample(cum, acc, u)
            assert idx == ref, (case, kind, idx, ref)
            assert q > 0.0
    # exp shift invariance: +400 on every logit leaves all q unchanged
    logits = np.array([0.4, -1.2, 2.0, 0.0], dtype=np.float32)
    us = [random.Random(3).random() for _ in range(64)]
    a = new_sample_into("exp", logits, us)
    b = new_sample_into("exp", logits + np.float32(400.0), us)
    assert [i for i, _ in a] == [i for i, _ in b]
    for (_, qa), (_, qb) in zip(a, b):
        # f32 rounding of o + 400 perturbs exponents by ~3e-5
        assert abs(qa - qb) < 1e-3 * qa
    print("  flat scratch-CDF == old Cdf semantics; exp shift invariant: OK")


def check_exp_chi_square():
    npr = np.random.default_rng(43)
    logits = npr.normal(0, 1.2, 30)
    p = np.exp(logits - logits.max())
    p /= p.sum()
    draws = 200_000
    counts = npr.multinomial(draws, p)  # flat exp sampling IS multinomial(p)
    expect = p * draws
    keep = expect >= 1.0
    stat = float(((counts[keep] - expect[keep]) ** 2 / expect[keep]).sum())
    df = int(keep.sum()) - 1
    assert stat < df + 5 * math.sqrt(2 * df), (stat, df)
    print("  exp-flat chi-square GOF vs softmax: OK")


# --- 5: acceptance property ---------------------------------------------
def dominant_tail_case(seed, d=4, n=24):
    """The construction of rff/tests.rs::rff_4d_beats_quadratic_tv…"""
    npr = np.random.default_rng(seed)
    h = npr.normal(0, 1, d)
    h = h / np.linalg.norm(h) * 1.2
    h2 = float(h @ h)
    emb = np.zeros((n, d))
    emb[0] = h * 2.2 / h2
    for j in range(1, 7):
        emb[j] = -emb[0]
    emb[7:] = npr.normal(0, 0.25, (n - 7, d))
    o = emb @ h
    p = np.exp(o - o.max())
    p /= p.sum()
    return h.astype(np.float32), emb.astype(np.float32), p


def tv(a, b):
    return 0.5 * float(np.abs(np.asarray(a) - np.asarray(b)).sum())


def check_acceptance_property(seeds=200, draws=120_000):
    npr = np.random.default_rng(99)
    worst = math.inf
    for s in range(seeds):
        h, emb, p = dominant_tail_case(s)
        quad = QuadraticMap(4, 100.0)
        q_quad = np.array(exact_dist(quad, h, emb))
        rff = RffMap.draw(4, 16, 5000 + s, orthogonal=False)  # D = 4d
        q_rff = np.array(exact_dist(rff, h, emb))
        # simulate the empirical-TV estimator of the Rust test: the tree is
        # exact for its realized kernel (checked above), so empirical
        # counts are multinomial around the closed-form distribution
        emp_quad = npr.multinomial(draws, q_quad) / draws
        emp_rff = npr.multinomial(draws, q_rff) / draws
        margin = tv(emp_quad, p) - tv(emp_rff, p)
        worst = min(worst, margin)
        assert margin > 0.1, f"seed {s}: margin {margin:.3f}"
    print(f"  rff(D=4d) beats quadratic TV-to-softmax, {seeds} seeds, "
          f"worst margin {worst:.3f} (> 0.1): OK")


# --- 6: the exact Rust realizations of the acceptance test ---------------
MASK = (1 << 64) - 1
GOLDEN = 0x9E3779B97F4A7C15


def _splitmix64(state):
    state = (state + GOLDEN) & MASK
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
    return state, z ^ (z >> 31)


def _rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & MASK


class RustRng:
    """Faithful port of util/rng.rs: xoshiro256** seeded via splitmix64,
    Box-Muller normals with the cached-spare discipline."""

    def __init__(self, seed):
        s, sm = [], seed & MASK
        for _ in range(4):
            sm, v = _splitmix64(sm)
            s.append(v)
        self.s, self.spare = s, None

    def next_u64(self):
        s = self.s
        result = (_rotl((s[1] * 5) & MASK, 7) * 9) & MASK
        t = (s[1] << 17) & MASK
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return result

    def f64(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def normal(self):
        if self.spare is not None:
            z, self.spare = self.spare, None
            return z
        while True:
            u1 = self.f64()
            if u1 > 1e-300:
                break
        u2 = self.f64()
        r = math.sqrt(-2.0 * math.log(u1))
        a = 2.0 * math.pi * u2
        self.spare = r * math.sin(a)
        return r * math.cos(a)

    def normal_f32(self, mean, std):
        # rust: mean + std * (self.normal() as f32), all f32 arithmetic
        return np.float32(np.float32(mean) + np.float32(std) * np.float32(self.normal()))


def check_exact_rust_acceptance_cases(cases=5, min_margin=0.15):
    """Reproduce rff/tests.rs::rff_4d_beats_quadratic_tv… bit-faithfully:
    util/testing.rs case seeds (base 0xC0FFEE), the test's f32 construction
    (h via fill_normal, the ±2.2 logit plants, N(0, 0.25) tail), and
    RffConfig::draw_omega's exact Rng stream. The Rust assert is margin >
    0.1 on *empirical* TVs (120k draws ⇒ multinomial noise ≲ 0.01); pinning
    the closed-form margins ≥ min_margin proves the assert cannot flake."""
    d, n = 4, 24
    worst = math.inf
    for case in range(cases):
        cs = ((0xC0FFEE + case) * GOLDEN) & MASK
        rng = RustRng(cs ^ 0xD0)
        h = np.array([rng.normal_f32(0.0, 1.0) for _ in range(d)], dtype=np.float32)
        norm = np.float32(math.sqrt(float(np.float64(h) @ np.float64(h))))
        h = (h * np.float32(np.float32(1.2) / max(norm, np.float32(1e-6)))).astype(np.float32)
        h2 = np.float32(float(np.float64(h) @ np.float64(h)))
        emb = np.zeros((n, d), dtype=np.float32)
        emb[0] = (h * np.float32(2.2) / h2).astype(np.float32)
        for j in range(1, 7):
            emb[j] = -emb[0]
        for j in range(7, n):
            for k in range(d):
                emb[j, k] = rng.normal_f32(0.0, 0.25)
        # omega: RffConfig::new(d, cs ^ 0xB2).draw_omega(), D = 4d iid
        orng = RustRng(((cs ^ 0xB2) ^ ((0x52FF0 * GOLDEN) & MASK)) & MASK)
        omega = np.array([[orng.normal() for _ in range(d)] for _ in range(4 * d)])
        o = np.float64(emb) @ np.float64(h)
        p = np.exp(o - o.max())
        p /= p.sum()
        qq = 100.0 * o ** 2 + 1.0
        qq /= qq.sum()
        qr = np.array([RffMap(d, omega).kernel(h, w) for w in emb])
        qr /= qr.sum()
        margin = tv(qq, p) - tv(qr, p)
        worst = min(worst, margin)
        assert margin > min_margin, f"rust case {case}: margin {margin:.3f}"
    print(f"  exact Rust-Rng acceptance cases ({cases}): worst closed-form "
          f"margin {worst:.3f} (> {min_margin} + noise headroom): OK")


if __name__ == "__main__":
    print("rff-subsystem port checks:")
    check_phi_kernel_consistency()
    check_unbiasedness()
    check_orthogonal_structure()
    check_rff_tree()
    check_rff_sharded()
    check_flat_rework()
    check_exp_chi_square()
    check_acceptance_property()
    check_exact_rust_acceptance_cases()
    print("all rff-subsystem port checks passed")
