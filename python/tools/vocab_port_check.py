#!/usr/bin/env python3
"""Line-for-line Python port of the streaming-vocabulary subsystem
(vocab/memtable.rs + vocab/streaming.rs + vocab/publisher.rs +
serve/snapshot.rs's compaction barrier), validated against the same
properties the Rust tests pin.

No rust toolchain exists in the build container (see
.claude/skills/verify/SKILL.md), so — as in PRs 1-7 — the algorithmic core
of the change is ported faithfully (same data layout, same guards, same
arithmetic order where it matters) and property-checked here. The kernel
tree is imported from serve_port_check.py (the line-for-line port of
tree.rs); this file adds the vocab-specific pieces:

  1. memtable: explicit slot <-> global-id mapping survives insert /
     swap-remove / update churn over a holey id space; the flat-CDF draw
     returns member ids whose weight is the kernel score, bitwise
  2. tier router q algebra: at EVERY point of an interleaved insert /
     retire / update / compact schedule, the composite
     q = (M_tier/SumM) * q_tier of each draw matches the closed-form
     K(h,w_c)/SumM over the live union to <= 1e-12 relative, and prob()
     agrees on every live class
  3. tombstone masking: retired classes are never drawn (mass exclusion +
     rejection), their prob is None, and the composite partition total
     equals the sum of live kernel masses
  4. replay-log compaction: the publisher's Compact barrier record folds
     the memtable into an arena BITWISE equal to a from-scratch rebuild
     over the live set; pre-barrier pinned arenas stay untouched,
     pre-barrier free arenas are discarded (never replayed across the
     barrier), and post-barrier update replay stays exact

Run: python3 python/tools/vocab_port_check.py
"""
import math
import os
import random
import sys

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from serve_port_check import (  # noqa: E402
    QuadraticMap,
    Tree,
    exact_dist,
    sanitize_mass,
    step_down_to_positive,
)

TIER_ARENA, TIER_MEM = 0, 1
REJECT_CAP = 64
F64_MIN_POSITIVE = 2.2250738585072014e-308


def fill_cum(weights):
    """Port of ops::fill_cum_into — prefix sums, returns the total."""
    acc, cum = 0.0, []
    for w in weights:
        assert not (w < 0.0), "negative weight in CDF"
        acc += w
        cum.append(acc)
    return cum, acc


def sample_cum(cum, total, rng):
    """Port of util::rng::sample_cum (partition_point + last-positive)."""
    assert total > 0.0 and math.isfinite(total)
    u = rng.random() * total
    idx = sum(1 for c in cum if c <= u)
    if idx < len(cum):
        return idx
    for i in reversed(range(len(cum))):
        lo = 0.0 if i == 0 else cum[i - 1]
        if cum[i] - lo > 0.0:
            return i
    raise AssertionError("CDF invariant: total mass > 0")


def clamp_q(q):
    return min(max(q, F64_MIN_POSITIVE), 1.7976931348623157e308)


class Memtable:
    """Port of vocab/memtable.rs Memtable."""

    def __init__(self, d):
        self.d = d
        self.ids = []  # slot -> global id
        self.rows = []  # slot-major flat f32 rows
        self.index = {}  # global id -> slot

    def __len__(self):
        return len(self.ids)

    def contains(self, gid):
        return gid in self.index

    def row(self, slot):
        return self.rows[slot * self.d:(slot + 1) * self.d]

    def insert(self, gid, row):
        assert len(row) == self.d and not self.contains(gid)
        self.index[gid] = len(self.ids)
        self.ids.append(gid)
        self.rows.extend(np.float32(v) for v in row)

    def remove(self, gid):
        if gid not in self.index:
            return False
        slot = self.index.pop(gid)
        last = len(self.ids) - 1
        if slot != last:
            self.ids[slot] = self.ids[last]
            self.rows[slot * self.d:(slot + 1) * self.d] = self.rows[last * self.d:]
            self.index[self.ids[slot]] = slot
        self.ids.pop()
        del self.rows[last * self.d:]
        return True

    def update_row(self, gid, row):
        if gid not in self.index:
            return False
        slot = self.index[gid]
        self.rows[slot * self.d:(slot + 1) * self.d] = [np.float32(v) for v in row]
        return True

    def clear(self):
        self.ids, self.rows, self.index = [], [], {}

    def weights(self, fmap, h):
        return [fmap.kernel(h, self.row(s)) for s in range(len(self.ids))]

    def draw_prepared(self, cum, total, rng):
        slot = sample_cum(cum, total, rng)
        return slot, self.ids[slot]


class TombstoneSet:
    """Port of vocab/memtable.rs TombstoneSet (sorted slots + frozen rows)."""

    def __init__(self, d):
        self.d = d
        self.slots = []
        self.rows = []

    def __len__(self):
        return len(self.slots)

    def contains(self, slot):
        import bisect
        i = bisect.bisect_left(self.slots, slot)
        return i < len(self.slots) and self.slots[i] == slot

    def insert(self, slot, row):
        import bisect
        pos = bisect.bisect_left(self.slots, slot)
        if pos < len(self.slots) and self.slots[pos] == slot:
            return False
        self.slots.insert(pos, slot)
        self.rows[pos * self.d:pos * self.d] = [np.float32(v) for v in row]
        return True

    def clear(self):
        self.slots, self.rows = [], []

    def mass(self, fmap, h):
        if not self.slots:
            return 0.0
        ks = [
            sanitize_mass(fmap.kernel(h, self.rows[i * self.d:(i + 1) * self.d]))
            for i in range(len(self.slots))
        ]
        _, total = fill_cum(ks)
        return total


def draw_from_tiers(tree, arena_ids, memtable, tombs, h, m, rng):
    """Port of vocab/streaming.rs draw_from_tiers. Returns [(gid, q)]."""
    fmap = tree.map
    arena_n = len(arena_ids)
    arena_live_n = arena_n - len(tombs)
    live_n = arena_live_n + len(memtable)
    assert live_n > 0, "streaming sampler has no live classes"

    phi = fmap.phi(h)
    arena_raw = tree.partition(phi)
    tomb_mass = tombs.mass(fmap, h)
    mem_w = memtable.weights(fmap, h)
    mem_cum, mem_mass = fill_cum(mem_w)
    masses = [
        0.0 if arena_live_n == 0 else sanitize_mass(arena_raw - tomb_mass),
        0.0 if not len(memtable) else sanitize_mass(mem_mass),
    ]
    cum, total = fill_cum(masses)

    tree_scratch = None
    out = []
    for _ in range(m):
        if total > 0.0 and math.isfinite(total):
            u = rng.random() * total
            idx = min(sum(1 for c in cum if c <= u), 1)
            idx = step_down_to_positive(cum, idx)
            tier, p_tier, clean = idx, masses[idx] / total, True
        elif arena_live_n > 0 and len(memtable) > 0:
            tier, p_tier, clean = rng.randrange(2), 0.5, False
        elif arena_live_n > 0:
            tier, p_tier, clean = TIER_ARENA, 1.0, False
        else:
            tier, p_tier, clean = TIER_MEM, 1.0, False

        if tier == TIER_MEM:
            if mem_mass > 0.0 and math.isfinite(mem_mass):
                slot, gid = memtable.draw_prepared(mem_cum, mem_mass, rng)
                if clean:
                    q = clamp_q(mem_w[slot] / total)
                else:
                    lo = 0.0 if slot == 0 else mem_cum[slot - 1]
                    q = clamp_q(p_tier * ((mem_cum[slot] - lo) / mem_mass))
            else:
                slot = rng.randrange(len(memtable))
                gid = memtable.ids[slot]
                q = clamp_q(p_tier / len(memtable))
            out.append((gid, q))
            continue

        if tree_scratch is None:
            tree_scratch = tree.begin_example_prepared(phi, arena_raw)
        chosen = None
        for _ in range(REJECT_CAP):
            slot, q_tree = tree.draw(h, tree_scratch, rng)
            if not tombs.contains(slot):
                chosen = (slot, q_tree)
                break
        if chosen is not None:
            slot, q_tree = chosen
            if clean:
                k = sanitize_mass(fmap.kernel(h, tree.emb[slot]))
                q = clamp_q(k / total)
            else:
                q = clamp_q(p_tier * q_tree)
        else:
            pick = rng.randrange(arena_live_n)
            seen, slot = 0, 0
            for cand in range(arena_n):
                if tombs.contains(cand):
                    continue
                if seen == pick:
                    slot = cand
                    break
                seen += 1
            q = clamp_q(p_tier / arena_live_n)
        out.append((arena_ids[slot], q))
    return out


def prob_from_tiers(tree, arena_index, memtable, tombs, h, gid):
    """Port of vocab/streaming.rs prob_from_tiers."""
    fmap = tree.map
    if memtable.contains(gid):
        k = fmap.kernel(h, memtable.row(memtable.index[gid]))
    elif gid in arena_index:
        slot = arena_index[gid]
        if tombs.contains(slot):
            return None
        k = fmap.kernel(h, tree.emb[slot])
    else:
        return None
    phi = fmap.phi(h)
    arena_raw = tree.partition(phi)
    tomb_mass = tombs.mass(fmap, h)
    _, mem_mass = fill_cum(memtable.weights(fmap, h))
    arena_live_n = len(arena_index) - len(tombs)
    m_arena = 0.0 if arena_live_n == 0 else sanitize_mass(arena_raw - tomb_mass)
    m_mem = 0.0 if not len(memtable) else sanitize_mass(mem_mass)
    total = m_arena + m_mem
    if not (total > 0.0 and math.isfinite(total)):
        return None
    return k / total


class StreamingSampler:
    """Port of vocab/streaming.rs StreamingKernelSampler (manual policy)."""

    def __init__(self, fmap, n, leaf):
        self.fmap, self.leaf = fmap, leaf
        self.tree = Tree(fmap, n, leaf)
        self.arena_ids = list(range(n))
        self.arena_index = {i: i for i in range(n)}
        self.memtable = Memtable(fmap.d)
        self.tombs = TombstoneSet(fmap.d)
        self.next_id = n

    def reset(self, emb):
        self.tree.reset(emb)

    def live_len(self):
        return len(self.arena_ids) - len(self.tombs) + len(self.memtable)

    def is_live(self, gid):
        if self.memtable.contains(gid):
            return True
        slot = self.arena_index.get(gid)
        return slot is not None and not self.tombs.contains(slot)

    def insert_class(self, row):
        gid = self.next_id
        assert not self.is_live(gid)
        self.memtable.insert(gid, row)
        self.next_id = max(self.next_id, gid + 1)
        return gid

    def retire_class(self, gid):
        if self.live_len() <= 1:
            return False
        if self.memtable.remove(gid):
            return True
        slot = self.arena_index.get(gid)
        if slot is None or self.tombs.contains(slot):
            return False
        self.tombs.insert(slot, self.tree.emb[slot].copy())
        return True

    def update_many(self, gids, rows):
        arena, dropped = [], 0
        for gid, row in zip(gids, rows):
            if self.memtable.update_row(gid, row):
                continue
            slot = self.arena_index.get(gid)
            if slot is not None and not self.tombs.contains(slot):
                arena.append((slot, row))
            else:
                dropped += 1
        if arena:
            arena.sort(key=lambda t: t[0])
            self.tree.update_many([s for s, _ in arena], [r for _, r in arena])
        return dropped

    def live_classes(self):
        """Canonical compaction order: arena slots ascending minus
        tombstones, then memtable slots."""
        ids, rows = [], []
        for slot in range(len(self.arena_ids)):
            if self.tombs.contains(slot):
                continue
            ids.append(self.arena_ids[slot])
            rows.append(self.tree.emb[slot].copy())
        for slot in range(len(self.memtable)):
            ids.append(self.memtable.ids[slot])
            rows.append(np.array(self.memtable.row(slot), dtype=np.float32))
        return ids, rows

    def compact(self):
        ids, rows = self.live_classes()
        tree = Tree(self.fmap, len(ids), self.leaf)
        tree.reset(np.array(rows, dtype=np.float32))
        self.tree = tree
        self.arena_ids = ids
        self.arena_index = {gid: slot for slot, gid in enumerate(ids)}
        self.memtable.clear()
        self.tombs.clear()

    def sample(self, h, m, rng):
        return draw_from_tiers(
            self.tree, self.arena_ids, self.memtable, self.tombs, h, m, rng
        )

    def prob(self, h, gid):
        return prob_from_tiers(
            self.tree, self.arena_index, self.memtable, self.tombs, h, gid
        )


class VocabPublisher:
    """Port of the arena replay log with the Compact barrier
    (serve/snapshot.rs TreePublisher: Update/Compact records, stale-arena
    discard, reclaim + fast-forward replay) driving the composite fold of
    vocab/publisher.rs compact()."""

    MAX_RETIRED = 6

    def __init__(self, tree):
        self.shadow = tree
        self.gen = 0
        snap = {"gen": 0, "tree": tree.clone(), "pins": 0}
        self.current = snap
        self.retired = [snap]
        self.log = []  # ('update', gen, classes, rows) | ('compact', gen)
        self.last_compact_gen = 0
        self.stats = {
            "publishes": 0, "reclaimed": 0, "copied": 0,
            "replayed": 0, "compactions": 0, "discarded_stale": 0,
        }

    def _discard_stale_retired(self):
        if self.last_compact_gen == 0:
            return
        keep = [s for s in self.retired if s["gen"] >= self.last_compact_gen]
        self.stats["discarded_stale"] += len(self.retired) - len(keep)
        self.retired = keep

    def _publish_next(self, snap):
        self.retired.append(snap)
        self.current = snap
        self.stats["publishes"] += 1
        while len(self.retired) > self.MAX_RETIRED:
            self.retired.pop(0)
        min_gen = self.retired[0]["gen"] if self.retired else self.gen
        self.log = [r for r in self.log if r[1] > min_gen]
        return snap

    def update_and_publish(self, classes, rows):
        self.shadow.update_many(classes, rows)
        self.gen += 1
        self.log.append(("update", self.gen, list(classes), [list(r) for r in rows]))
        self._discard_stale_retired()
        reclaimed = None
        i = 0
        while i < len(self.retired):
            cand = self.retired[i]
            if cand is self.current or cand["pins"] > 0:
                i += 1
                continue
            reclaimed = self.retired.pop(i)
        if reclaimed is not None:
            for rec in self.log:
                if rec[0] == "update" and rec[1] > reclaimed["gen"]:
                    reclaimed["tree"].update_many(rec[2], rec[3])
                    self.stats["replayed"] += 1
                elif rec[0] == "compact":
                    assert rec[1] <= reclaimed["gen"], (
                        "replay crossed a compaction barrier"
                    )
            reclaimed["gen"] = self.gen
            self.stats["reclaimed"] += 1
            nxt = reclaimed
        else:
            self.stats["copied"] += 1
            nxt = {"gen": self.gen, "tree": self.shadow.clone(), "pins": 0}
        return self._publish_next(nxt)

    def compact_and_publish(self, tree):
        self.shadow = tree
        self.gen += 1
        self.last_compact_gen = self.gen
        self.log.append(("compact", self.gen))
        self._discard_stale_retired()
        self.stats["compactions"] += 1
        nxt = {"gen": self.gen, "tree": self.shadow.clone(), "pins": 0}
        return self._publish_next(nxt)


# --- checks -------------------------------------------------------------
def check_memtable(trials=30):
    rng = random.Random(1)
    fmap = QuadraticMap(3, 50.0)
    for case in range(trials):
        mt = Memtable(3)
        npr = np.random.default_rng(case)
        live = {}
        next_id = 1000 * (case + 1)  # deliberately holey, non-dense ids
        for _ in range(60):
            op = rng.random()
            if op < 0.5 or not live:
                row = npr.normal(0, 0.8, 3).astype(np.float32)
                mt.insert(next_id, row)
                live[next_id] = row
                next_id += rng.randint(1, 97)
            elif op < 0.75:
                gid = rng.choice(list(live))
                assert mt.remove(gid)
                assert not mt.remove(gid), "double remove"
                del live[gid]
            else:
                gid = rng.choice(list(live))
                row = npr.normal(0, 0.8, 3).astype(np.float32)
                assert mt.update_row(gid, row)
                live[gid] = row
            # the slot <-> id mapping is exactly inverse after every op
            assert len(mt) == len(live)
            for gid, row in live.items():
                slot = mt.index[gid]
                assert mt.ids[slot] == gid
                assert np.array_equal(np.float32(mt.row(slot)), row)
        if not live:
            continue
        h = npr.normal(0, 1, 3).astype(np.float32)
        w = mt.weights(fmap, h)
        cum, total = fill_cum(w)
        for _ in range(50):
            slot, gid = mt.draw_prepared(cum, total, rng)
            assert gid in live, f"alien id {gid}"
            # the slot's weight is the kernel recomputed from its row, bitwise
            assert w[slot] == fmap.kernel(h, mt.row(slot))
    print("  memtable slot<->id mapping + flat-CDF draw over holey ids: OK")


def live_union_dist(s, h):
    """The reference: exact kernel distribution over the live class set,
    built from scratch (the q every draw must report to <= 1e-12 rel)."""
    ids, rows = s.live_classes()
    probs = exact_dist(s.fmap, h, np.array(rows, dtype=np.float32))
    return {gid: p for gid, p in zip(ids, probs)}


def check_tier_algebra(trials=8):
    rng = random.Random(2)
    for case in range(trials):
        n0 = rng.randint(8, 20)
        d = rng.randint(2, 4)
        fmap = QuadraticMap(d, rng.uniform(20.0, 150.0))
        npr = np.random.default_rng(100 + case)
        s = StreamingSampler(fmap, n0, 4)
        s.reset(npr.normal(0, 0.6, (n0, d)).astype(np.float32))
        retired = []
        for step in range(30):
            kind = step % 8
            if kind in (0, 3, 6):
                s.insert_class(npr.normal(0, 0.6, d).astype(np.float32))
            elif kind in (1, 5):
                if s.live_len() > 3:
                    ids, _ = s.live_classes()
                    gid = rng.choice(ids)
                    assert s.retire_class(gid)
                    retired.append(gid)
            elif kind == 7:
                s.compact()
                assert len(s.memtable) == 0 and len(s.tombs) == 0
            else:
                ids, _ = s.live_classes()
                picks = sorted(rng.sample(ids, min(3, len(ids))))
                rows = npr.normal(0, 0.6, (len(picks), d)).astype(np.float32)
                assert s.update_many(picks, rows) == 0
            h = npr.normal(0, 1, d).astype(np.float32)
            want = live_union_dist(s, h)
            for gid, q in s.sample(h, 8, rng):
                assert s.is_live(gid), f"step {step}: drew non-live class {gid}"
                assert gid not in retired or s.is_live(gid)
                ref = want[gid]
                assert abs(q - ref) <= 1e-12 * max(abs(q), abs(ref)), (
                    case, step, gid, q, ref,
                )
            for gid, ref in want.items():
                got = s.prob(h, gid)
                assert abs(got - ref) <= 1e-12 * max(abs(got), abs(ref))
            for gid in retired[:3]:
                if not s.is_live(gid):
                    assert s.prob(h, gid) is None
    print("  tier router q == from-scratch union tree (<=1e-12 rel), all steps: OK")


def check_tombstone_masking():
    rng = random.Random(3)
    n, d = 32, 3
    fmap = QuadraticMap(d, 100.0)
    npr = np.random.default_rng(7)
    s = StreamingSampler(fmap, n, 4)
    s.reset(npr.normal(0, 0.7, (n, d)).astype(np.float32))
    dead = list(range(0, 30, 2))[:15]
    for gid in dead:
        assert s.retire_class(gid)
    assert len(s.tombs) == 15
    h = npr.normal(0, 1, d).astype(np.float32)
    # mass exclusion: the composite total equals the sum of live kernels
    phi = fmap.phi(h)
    composite = s.tree.partition(phi) - s.tombs.mass(fmap, h)
    live_sum = sum(
        fmap.kernel(h, s.tree.emb[slot])
        for slot in range(n)
        if not s.tombs.contains(slot)
    )
    assert abs(composite - live_sum) <= 1e-9 * live_sum, (composite, live_sum)
    # rejection: tombstoned classes never appear, q stays positive finite,
    # and the empirical conditional distribution matches the live union
    counts = {}
    draws = 40_000
    want = live_union_dist(s, h)
    for _ in range(draws // 25):
        for gid, q in s.sample(h, 25, rng):
            assert gid not in dead, f"drew tombstoned class {gid}"
            assert q > 0.0 and math.isfinite(q)
            counts[gid] = counts.get(gid, 0) + 1
    stat = sum(
        (counts.get(g, 0) - p * draws) ** 2 / (p * draws)
        for g, p in want.items()
        if p * draws >= 1.0
    )
    df = len(want) - 1
    assert stat < df + 5 * math.sqrt(2 * df), (stat, df)
    # updates to tombstoned and unknown ids are dropped, countably
    assert s.update_many([0, 1], [np.zeros(d, np.float32)] * 2) == 1
    assert s.update_many([99999], [np.zeros(d, np.float32)]) == 1
    print(f"  tombstone masking (mass exclusion + rejection, chi2 {stat:.1f}, df {df}): OK")


def check_compaction_replay(trials=10):
    rng = random.Random(4)
    for case in range(trials):
        n0 = rng.randint(8, 16)
        d = rng.randint(2, 3)
        fmap = QuadraticMap(d, 100.0)
        npr = np.random.default_rng(500 + case)
        emb = npr.normal(0, 0.5, (n0, d)).astype(np.float32)
        base = Tree(fmap, n0, 4)
        base.reset(emb)
        # composite writer: streaming state + arena replay-log publisher
        s = StreamingSampler(fmap, n0, 4)
        s.reset(emb)
        pub = VocabPublisher(base)
        pinned = pub.current
        pinned["pins"] += 1
        pinned_z = pinned["tree"].z.copy()
        for step in range(18):
            kind = step % 6
            if kind in (0, 3):
                s.insert_class(npr.normal(0, 0.5, d).astype(np.float32))
            elif kind == 1:
                ids, _ = s.live_classes()
                s.retire_class(rng.choice(ids))
            elif kind == 4:
                # the vocab/publisher.rs compact(): gather the live set,
                # build a fresh tree, push it through the barrier
                s.compact()
                _, rows = s.live_classes()
                tree = Tree(fmap, len(rows), 4)
                tree.reset(np.array(rows, dtype=np.float32))
                snap = pub.compact_and_publish(tree)
                # bitwise equal to a from-scratch rebuild over the live set
                rebuild = Tree(fmap, len(rows), 4)
                rebuild.reset(np.array(rows, dtype=np.float32))
                assert np.array_equal(snap["tree"].z, rebuild.z), (case, step)
                assert np.array_equal(snap["tree"].emb, rebuild.emb)
                # pre-barrier arenas left the reclaim queue
                assert all(r["gen"] >= pub.last_compact_gen for r in pub.retired)
            else:
                ids, _ = s.live_classes()
                # arena-resident live classes route through the publisher
                arena = sorted(
                    s.arena_index[g] for g in ids
                    if g in s.arena_index and not s.tombs.contains(s.arena_index[g])
                )[:3]
                if arena:
                    rows = npr.normal(0, 0.5, (len(arena), d)).astype(np.float32)
                    s.tree.update_many(arena, rows)
                    snap = pub.update_and_publish(arena, rows)
                    # replay/reclaim == the straight-line shadow, bitwise
                    assert np.array_equal(snap["tree"].z, pub.shadow.z), (case, step)
                    assert np.array_equal(snap["tree"].emb, pub.shadow.emb)
            # the streaming arena and the published arena never diverge
            assert np.array_equal(pub.current["tree"].z, s.tree.z), (case, step)
        assert pub.stats["compactions"] >= 3, pub.stats
        assert pub.stats["discarded_stale"] >= 1, pub.stats
        # the pinned pre-barrier generation was never mutated
        assert np.array_equal(pinned["tree"].z, pinned_z), "pinned generation mutated"
    print("  replay-log compaction: barrier fold == from-scratch rebuild (bitwise): OK")


if __name__ == "__main__":
    print("streaming-vocabulary port checks:")
    check_memtable()
    check_tier_algebra()
    check_tombstone_masking()
    check_compaction_replay()
    print("all streaming-vocabulary port checks passed")
