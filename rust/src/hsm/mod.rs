//! Hierarchical softmax baseline (paper §5.2, Goodman 2001).
//!
//! The main alternative family to sampled softmax: factor
//! `p(y|x) = p(c_y|x) · p(y | c_y, x)` over `√n`-sized clusters so one
//! training step costs `O(d·√n)` instead of `O(d·n)`. The paper's related
//! work quotes Chen et al. (2015): HSM trains fast but converges to a
//! *worse* model than full softmax (>10% perplexity gap), while sampled
//! softmax with a good q approaches full softmax — that comparison is
//! exactly what `benches/hsm_baseline.rs` measures on a synthetic task.
//!
//! Self-contained: its own two-level head, exact gradients (both softmaxes
//! are small), SGD — no XLA involvement, so the comparison isolates the
//! output-layer method.

use crate::util::rng::Rng;

/// Cluster assignment: contiguous frequency bins (Mikolov et al. 2011 style
/// "frequency binning": sort classes by frequency, cut into equal-mass
/// bins). Returns (assignment per class, members per cluster).
pub fn frequency_binning(counts: &[u64], n_clusters: usize) -> (Vec<u32>, Vec<Vec<u32>>) {
    let n = counts.len();
    let n_clusters = n_clusters.clamp(1, n);
    let mut order: Vec<u32> = (0..n as u32).collect();
    order.sort_by_key(|&c| std::cmp::Reverse(counts[c as usize]));
    let total: u64 = counts.iter().sum::<u64>() + n as u64; // +1 smoothing
    let per_bin = total as f64 / n_clusters as f64;
    let mut assign = vec![0u32; n];
    let mut members: Vec<Vec<u32>> = vec![Vec::new(); n_clusters];
    let mut acc = 0.0f64;
    let mut bin = 0usize;
    for &class in &order {
        if acc >= per_bin * (bin + 1) as f64 && bin + 1 < n_clusters {
            bin += 1;
        }
        assign[class as usize] = bin as u32;
        members[bin].push(class);
        acc += (counts[class as usize] + 1) as f64;
    }
    // make sure no cluster is empty (move one member if needed)
    for b in 0..n_clusters {
        if members[b].is_empty() {
            let donor = (0..n_clusters).max_by_key(|&i| members[i].len()).unwrap();
            let class = members[donor].pop().unwrap();
            assign[class as usize] = b as u32;
            members[b].push(class);
        }
    }
    (assign, members)
}

/// Two-level hierarchical softmax output head with SGD training.
pub struct HsmHead {
    d: usize,
    assign: Vec<u32>,
    members: Vec<Vec<u32>>,
    /// (n_clusters, d) cluster logit vectors.
    cluster_w: Vec<f32>,
    /// (n, d) within-cluster class vectors.
    class_w: Vec<f32>,
}

impl HsmHead {
    pub fn new(counts: &[u64], d: usize, n_clusters: usize, rng: &mut Rng) -> HsmHead {
        let n = counts.len();
        let (assign, members) = frequency_binning(counts, n_clusters);
        let mut cluster_w = vec![0.0f32; members.len() * d];
        let mut class_w = vec![0.0f32; n * d];
        rng.fill_normal(&mut cluster_w, 0.1);
        rng.fill_normal(&mut class_w, 0.1);
        HsmHead { d, assign, members, cluster_w, class_w }
    }

    pub fn n_clusters(&self) -> usize {
        self.members.len()
    }

    /// -log p(y|h) under the factorization; O(d(√n + |cluster|)).
    pub fn loss(&self, h: &[f32], y: u32) -> f64 {
        let c = self.assign[y as usize] as usize;
        let (lc, _) = self.softmax_over(h, None, c, y);
        lc
    }

    /// One SGD step on example (h, y); returns the loss. Updates both levels
    /// and returns d loss / d h in `dh` (so an encoder could backprop).
    pub fn step(&mut self, h: &[f32], y: u32, lr: f32, dh: &mut [f32]) -> f64 {
        let d = self.d;
        let c = self.assign[y as usize] as usize;
        dh.iter_mut().for_each(|x| *x = 0.0);

        // level 1: cluster softmax over all clusters
        let k = self.members.len();
        let mut logits = vec![0.0f32; k];
        for (j, slot) in logits.iter_mut().enumerate() {
            *slot = dotf(&self.cluster_w[j * d..(j + 1) * d], h);
        }
        let p1 = softmax(&logits);
        let loss1 = -(p1[c].max(1e-30)).ln();
        for j in 0..k {
            let g = (p1[j] - f64::from(j == c) as f64) as f32;
            for t in 0..d {
                dh[t] += g * self.cluster_w[j * d + t];
                self.cluster_w[j * d + t] -= lr * g * h[t];
            }
        }

        // level 2: class softmax within y's cluster
        let members = self.members[c].clone();
        let mut logits = vec![0.0f32; members.len()];
        let mut y_pos = 0;
        for (j, &class) in members.iter().enumerate() {
            logits[j] = dotf(&self.class_w[class as usize * d..(class as usize + 1) * d], h);
            if class == y {
                y_pos = j;
            }
        }
        let p2 = softmax(&logits);
        let loss2 = -(p2[y_pos].max(1e-30)).ln();
        for (j, &class) in members.iter().enumerate() {
            let g = (p2[j] - f64::from(j == y_pos) as f64) as f32;
            let row = &mut self.class_w[class as usize * d..(class as usize + 1) * d];
            for t in 0..d {
                dh[t] += g * row[t];
                row[t] -= lr * g * h[t];
            }
        }
        loss1 + loss2
    }

    /// Exact p(y|h) for evaluation (sums to 1 over all classes by
    /// construction — verified in tests).
    pub fn prob(&self, h: &[f32], y: u32) -> f64 {
        let c = self.assign[y as usize] as usize;
        let k = self.members.len();
        let d = self.d;
        let mut logits = vec![0.0f32; k];
        for (j, slot) in logits.iter_mut().enumerate() {
            *slot = dotf(&self.cluster_w[j * d..(j + 1) * d], h);
        }
        let p1 = softmax(&logits)[c];
        let members = &self.members[c];
        let mut logits = vec![0.0f32; members.len()];
        let mut y_pos = 0;
        for (j, &class) in members.iter().enumerate() {
            logits[j] = dotf(&self.class_w[class as usize * d..(class as usize + 1) * d], h);
            if class == y {
                y_pos = j;
            }
        }
        p1 * softmax(&logits)[y_pos]
    }

    fn softmax_over(&self, h: &[f32], _unused: Option<()>, c: usize, y: u32) -> (f64, usize) {
        (-(self.prob(h, y).max(1e-300)).ln(), c)
    }
}

/// Plain full-softmax head with SGD — the comparison baseline.
pub struct FullHead {
    d: usize,
    w: Vec<f32>,
}

impl FullHead {
    pub fn new(n: usize, d: usize, rng: &mut Rng) -> FullHead {
        let mut w = vec![0.0f32; n * d];
        rng.fill_normal(&mut w, 0.1);
        FullHead { d, w }
    }

    pub fn loss(&self, h: &[f32], y: u32) -> f64 {
        let n = self.w.len() / self.d;
        let logits: Vec<f32> =
            (0..n).map(|j| dotf(&self.w[j * self.d..(j + 1) * self.d], h)).collect();
        -(softmax(&logits)[y as usize].max(1e-30)).ln()
    }

    pub fn step(&mut self, h: &[f32], y: u32, lr: f32) -> f64 {
        let d = self.d;
        let n = self.w.len() / d;
        let logits: Vec<f32> = (0..n).map(|j| dotf(&self.w[j * d..(j + 1) * d], h)).collect();
        let p = softmax(&logits);
        let loss = -(p[y as usize].max(1e-30)).ln();
        for j in 0..n {
            let g = (p[j] - f64::from(j == y as usize) as f64) as f32;
            let row = &mut self.w[j * d..(j + 1) * d];
            for t in 0..d {
                row[t] -= lr * g * h[t];
            }
        }
        loss
    }
}

fn dotf(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(&x, &y)| x * y).sum()
}

fn softmax(o: &[f32]) -> Vec<f64> {
    let mx = o.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let e: Vec<f64> = o.iter().map(|&x| (x as f64 - mx).exp()).collect();
    let z: f64 = e.iter().sum();
    e.into_iter().map(|x| x / z).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frequency_binning_partitions_classes() {
        let counts: Vec<u64> = (0..100).map(|i| (100 - i) * 10).collect();
        let (assign, members) = frequency_binning(&counts, 10);
        assert_eq!(members.len(), 10);
        let total: usize = members.iter().map(|m| m.len()).sum();
        assert_eq!(total, 100);
        for (b, m) in members.iter().enumerate() {
            assert!(!m.is_empty(), "cluster {b} empty");
            for &class in m {
                assert_eq!(assign[class as usize], b as u32);
            }
        }
        // frequent classes land in earlier (smaller) bins: bin 0 should have
        // far fewer members than the last bin
        assert!(members[0].len() < members[9].len());
    }

    #[test]
    fn hsm_probabilities_sum_to_one() {
        let mut rng = Rng::new(3);
        let counts = vec![5u64; 30];
        let head = HsmHead::new(&counts, 8, 6, &mut rng);
        let h: Vec<f32> = (0..8).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let total: f64 = (0..30).map(|y| head.prob(&h, y)).sum();
        assert!((total - 1.0).abs() < 1e-9, "{total}");
    }

    #[test]
    fn hsm_learns_a_simple_mapping() {
        // h is a noisy one-hot of the target's "concept"; HSM should learn it
        let mut rng = Rng::new(7);
        let (n, d) = (40usize, 16usize);
        let counts = vec![1u64; n];
        let mut head = HsmHead::new(&counts, d, 6, &mut rng);
        let mut proto = vec![0.0f32; n * d];
        rng.fill_normal(&mut proto, 1.0);
        let mut dh = vec![0.0f32; d];
        let mut first = 0.0;
        let mut last = 0.0;
        for it in 0..4000 {
            let y = rng.below(n as u64) as u32;
            let h: Vec<f32> = proto[y as usize * d..(y as usize + 1) * d]
                .iter()
                .map(|&x| x + rng.normal_f32(0.0, 0.2))
                .collect();
            let loss = head.step(&h, y, 0.1, &mut dh);
            if it < 100 {
                first += loss / 100.0;
            }
            if it >= 3900 {
                last += loss / 100.0;
            }
        }
        assert!(last < first * 0.5, "HSM failed to learn: {first} -> {last}");
    }

    #[test]
    fn full_head_learns_better_than_hsm_on_hard_task() {
        // the §5.2 claim (Chen et al.): same budget, HSM converges worse.
        // "hard" = class identity cuts across the frequency-binned clusters.
        let mut rng = Rng::new(11);
        let (n, d) = (60usize, 12usize);
        let counts: Vec<u64> = (0..n as u64).map(|i| i * 3 + 1).collect();
        let mut hsm = HsmHead::new(&counts, d, 8, &mut rng);
        let mut full = FullHead::new(n, d, &mut rng);
        let mut proto = vec![0.0f32; n * d];
        rng.fill_normal(&mut proto, 0.7);
        let mut dh = vec![0.0f32; d];
        let gen = |rng: &mut Rng, proto: &[f32]| {
            let y = rng.below(n as u64) as u32;
            let h: Vec<f32> = proto[y as usize * d..(y as usize + 1) * d]
                .iter()
                .map(|&x| x + rng.normal_f32(0.0, 0.5))
                .collect();
            (y, h)
        };
        for _ in 0..6000 {
            let (y, h) = gen(&mut rng, &proto);
            hsm.step(&h, y, 0.08, &mut dh);
            full.step(&h, y, 0.08);
        }
        // evaluate both with the *true* model-agnostic CE
        let mut l_hsm = 0.0;
        let mut l_full = 0.0;
        for _ in 0..500 {
            let (y, h) = gen(&mut rng, &proto);
            l_hsm += -(hsm.prob(&h, y).max(1e-30)).ln();
            l_full += full.loss(&h, y);
        }
        l_hsm /= 500.0;
        l_full /= 500.0;
        assert!(
            l_full < l_hsm,
            "full softmax should converge below HSM: full {l_full} vs hsm {l_hsm}"
        );
    }
}
