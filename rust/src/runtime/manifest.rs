//! Typed view of `artifacts/manifest.json` (written by python/compile/aot.py).
//!
//! The manifest is the single source of truth for the rust side: parameter
//! order/shape/init, per-op artifact files, and input/output specs. The
//! contract is documented in python/compile/model.py — params first, `lr`
//! last for train ops; train ops return new params, loss, and (sampled only)
//! the updated output-embedding rows.

use crate::util::json::{self, Value};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Which model family an entry describes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelKind {
    Lm,
    Recsys,
}

/// One parameter: name, shape and the initializer the ParamStore applies.
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: String,
}

/// One input or output of an op.
#[derive(Clone, Debug)]
pub struct IoSpec {
    pub name: String,
    pub dtype: String,
    pub shape: Vec<usize>,
}

/// One lowered entry point.
#[derive(Clone, Debug)]
pub struct OpSpec {
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

/// One model configuration with all its artifacts.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: String,
    pub kind: ModelKind,
    pub n_classes: usize,
    pub d: usize,
    pub batch: usize,
    pub seq_len: Option<usize>,
    pub n_user_features: Option<usize>,
    pub n_prev: usize,
    pub hidden: usize,
    pub n_examples: usize,
    pub abs_logits: bool,
    /// Quadratic-kernel α recorded at lowering time (sampler must match).
    pub alpha: f32,
    pub params: Vec<ParamSpec>,
    /// encode / score_all / eval_full / train_full.
    pub ops: BTreeMap<String, OpSpec>,
    /// train_sampled keyed by sample size m.
    pub train_sampled: BTreeMap<usize, OpSpec>,
}

impl ModelSpec {
    /// Available m values (sorted).
    pub fn available_m(&self) -> Vec<usize> {
        self.train_sampled.keys().copied().collect()
    }

    pub fn op(&self, name: &str) -> Result<&OpSpec> {
        self.ops.get(name).ok_or_else(|| anyhow!("model {} has no op '{name}'", self.name))
    }

    pub fn train_sampled_op(&self, m: usize) -> Result<&OpSpec> {
        self.train_sampled.get(&m).ok_or_else(|| {
            anyhow!(
                "model {} has no train_sampled artifact for m={m} (available: {:?}); \
                 re-run `make artifacts` or `python -m compile.aot --configs {} --m {m}`",
                self.name,
                self.available_m(),
                self.name
            )
        })
    }
}

/// The whole manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelSpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}; run `make artifacts` first"))?;
        Self::parse(dir, &text)
    }

    /// Parse manifest text (separated for testing).
    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let root = json::parse(text).context("parsing manifest.json")?;
        let version = root.req("version")?.as_usize().unwrap_or(0);
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let mut models = BTreeMap::new();
        for (name, entry) in root.req("models")?.as_object().unwrap_or(&[]) {
            models.insert(name.clone(), parse_model(name, entry)?);
        }
        if models.is_empty() {
            bail!("manifest has no models");
        }
        Ok(Manifest { dir: dir.to_path_buf(), models })
    }

    pub fn model(&self, name: &str) -> Result<&ModelSpec> {
        self.models.get(name).ok_or_else(|| {
            anyhow!("no model '{name}' in manifest (available: {:?})", self.models.keys().collect::<Vec<_>>())
        })
    }

    /// Absolute path of an artifact file.
    pub fn artifact_path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }
}

fn parse_model(name: &str, v: &Value) -> Result<ModelSpec> {
    let kind = match v.req("model")?.as_str() {
        Some("lm") => ModelKind::Lm,
        Some("recsys") => ModelKind::Recsys,
        other => bail!("model {name}: unknown kind {other:?}"),
    };
    let usize_of = |key: &str| -> Result<usize> {
        v.req(key)?.as_usize().ok_or_else(|| anyhow!("model {name}: bad {key}"))
    };
    let opt_usize = |key: &str| v.get(key).and_then(|x| x.as_usize());

    let params = v
        .req("params")?
        .as_array()
        .ok_or_else(|| anyhow!("model {name}: params not a list"))?
        .iter()
        .map(|p| parse_param(name, p))
        .collect::<Result<Vec<_>>>()?;

    let mut ops = BTreeMap::new();
    for (op_name, op) in v.req("ops")?.as_object().unwrap_or(&[]) {
        ops.insert(op_name.clone(), parse_op(name, op)?);
    }
    let mut train_sampled = BTreeMap::new();
    for (m_str, op) in v.req("train_sampled")?.as_object().unwrap_or(&[]) {
        let m: usize = m_str.parse().map_err(|_| anyhow!("model {name}: bad m '{m_str}'"))?;
        train_sampled.insert(m, parse_op(name, op)?);
    }

    Ok(ModelSpec {
        name: name.to_string(),
        kind,
        n_classes: usize_of("n_classes")?,
        d: usize_of("d")?,
        batch: usize_of("batch")?,
        seq_len: opt_usize("seq_len"),
        n_user_features: opt_usize("n_user_features"),
        n_prev: opt_usize("n_prev").unwrap_or(3),
        hidden: opt_usize("hidden").unwrap_or(0),
        n_examples: usize_of("n_examples")?,
        abs_logits: v.req("abs_logits")?.as_bool().unwrap_or(false),
        alpha: v.get("alpha").and_then(|x| x.as_f64()).unwrap_or(100.0) as f32,
        params,
        ops,
        train_sampled,
    })
}

fn parse_param(model: &str, v: &Value) -> Result<ParamSpec> {
    Ok(ParamSpec {
        name: v.req("name")?.as_str().ok_or_else(|| anyhow!("{model}: param name"))?.to_string(),
        shape: parse_shape(v.req("shape")?)?,
        init: v.req("init")?.as_str().unwrap_or("zeros").to_string(),
    })
}

fn parse_op(model: &str, v: &Value) -> Result<OpSpec> {
    let io = |key: &str| -> Result<Vec<IoSpec>> {
        v.req(key)?
            .as_array()
            .ok_or_else(|| anyhow!("{model}: {key} not a list"))?
            .iter()
            .map(|x| {
                Ok(IoSpec {
                    name: x.req("name")?.as_str().unwrap_or("").to_string(),
                    dtype: x.req("dtype")?.as_str().unwrap_or("f32").to_string(),
                    shape: parse_shape(x.req("shape")?)?,
                })
            })
            .collect()
    };
    Ok(OpSpec {
        file: v.req("file")?.as_str().ok_or_else(|| anyhow!("{model}: op file"))?.to_string(),
        inputs: io("inputs")?,
        outputs: io("outputs")?,
    })
}

fn parse_shape(v: &Value) -> Result<Vec<usize>> {
    v.as_array()
        .ok_or_else(|| anyhow!("shape not a list"))?
        .iter()
        .map(|x| x.as_usize().ok_or_else(|| anyhow!("bad shape dim")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "models": {
        "tiny": {
          "model": "recsys", "n_classes": 128, "d": 16, "batch": 8,
          "seq_len": null, "n_user_features": 4, "n_prev": 3, "hidden": 32,
          "n_examples": 8, "abs_logits": false, "alpha": 100.0,
          "params": [
            {"name": "item_emb", "shape": [128, 16], "init": "normal:0.1"},
            {"name": "out_w", "shape": [128, 16], "init": "normal:0.1"}
          ],
          "ops": {
            "encode": {"file": "tiny_encode.hlo.txt",
              "inputs": [{"name": "user", "dtype": "f32", "shape": [8, 4]}],
              "outputs": [{"name": "h", "dtype": "f32", "shape": [8, 16]}]}
          },
          "train_sampled": {
            "4": {"file": "tiny_train_sampled_m4.hlo.txt",
              "inputs": [{"name": "neg", "dtype": "i32", "shape": [8, 4]}],
              "outputs": [{"name": "loss", "dtype": "f32", "shape": []}]}
          }
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let man = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        let m = man.model("tiny").unwrap();
        assert_eq!(m.kind, ModelKind::Recsys);
        assert_eq!(m.n_classes, 128);
        assert_eq!(m.params[0].name, "item_emb");
        assert_eq!(m.params[0].shape, vec![128, 16]);
        assert_eq!(m.op("encode").unwrap().file, "tiny_encode.hlo.txt");
        assert_eq!(m.available_m(), vec![4]);
        assert_eq!(m.train_sampled_op(4).unwrap().outputs[0].name, "loss");
        assert!(m.train_sampled_op(8).is_err());
        assert!(man.model("nope").is_err());
        assert_eq!(man.artifact_path("x.hlo.txt"), PathBuf::from("/tmp/a/x.hlo.txt"));
    }

    #[test]
    fn rejects_bad_version() {
        assert!(Manifest::parse(Path::new("."), r#"{"version": 2, "models": {}}"#).is_err());
        assert!(Manifest::parse(Path::new("."), r#"{"version": 1, "models": {}}"#).is_err());
    }

    #[test]
    fn loads_real_manifest_if_built() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("artifacts not built; skipping");
            return;
        }
        let man = Manifest::load(&dir).unwrap();
        let tiny = man.model("tiny").unwrap();
        assert_eq!(tiny.params.last().unwrap().name, "out_w");
        for (_, op) in &tiny.ops {
            assert!(man.artifact_path(&op.file).exists(), "{}", op.file);
        }
    }
}
