#!/usr/bin/env python3
"""Delimiter-balance lexer for Rust sources (offline compile sanity).

Thin shim over the shared pallas-lint frontend (python/tools/pallas_lint/
frontend.py), which owns the string/char/lifetime/comment-aware Rust
lexer this script used to carry inline. Same CLI as before:

    python3 python/tools/lexcheck.py $(git ls-files '*.rs')

prints one `path:line: message` per balance error and
`lexcheck: N files, M errors`, exiting 1 if any error was found.
"""
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from pallas_lint.frontend import tokenize


def lex(path: str) -> list[str]:
    with open(path, encoding="utf-8") as f:
        src = f.read()
    _, errs = tokenize(src, path)
    return errs


def main() -> int:
    bad = 0
    for path in sys.argv[1:]:
        for e in lex(path):
            print(e)
            bad += 1
    print(f"lexcheck: {len(sys.argv) - 1} files, {bad} errors")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
