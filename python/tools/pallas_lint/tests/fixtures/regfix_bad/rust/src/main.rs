// pallas-lint REG fixture: hand-kept help footer — the drift REG flags.

fn main() {
    println!("samplers: uniform");
}
