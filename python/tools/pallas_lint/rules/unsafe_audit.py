"""UNSAFE — every `unsafe` block carries an adjacent `// SAFETY:` proof.

The repo's only `unsafe` is the byte-level reinterpretation handing
tensors to the XLA boundary (`runtime/tensor.rs`). Unsafe without a
written obligation is how those sites rot: the next edit changes an
element type or a length computation and the invariant that made the
cast sound silently stops holding. Rule: an `unsafe` keyword (block or
fn) must have a `// SAFETY:` comment on the same line or within the few
lines directly above it, stating the invariant being relied on. The
waiver file for this rule is expected to stay empty.
"""

from __future__ import annotations

from pallas_lint.frontend import IDENT, SourceFile, snippet
from pallas_lint.rules import Finding, Rule

_LOOKBACK = 5  # lines above the `unsafe` token searched for // SAFETY:


class UnsafeAudit(Rule):
    id = "UNSAFE"
    name = "unsafe-audit"
    summary = "`unsafe` without an adjacent // SAFETY: justification"
    contract = (
        "XLA boundary soundness (runtime/tensor.rs): each unsafe "
        "reinterpretation documents the pointer/length/alignment invariant "
        "it relies on, so edits that break the invariant are visible in review"
    )

    def applies(self, relpath: str) -> bool:
        # audit everything we lex, including benches/examples
        return relpath.endswith(".rs")

    def check(self, sf: SourceFile) -> list[Finding]:
        findings: list[Finding] = []
        for tok in sf.tokens:
            if not (tok.kind == IDENT and tok.text == "unsafe"):
                continue
            if sf.in_test(tok.line):
                continue
            lo = max(1, tok.line - _LOOKBACK)
            window = "\n".join(sf.lines[lo - 1 : tok.line])
            if "SAFETY:" in window:
                continue
            findings.append(
                Finding(
                    rule=self.id,
                    file=sf.path,
                    line=tok.line,
                    message=(
                        "`unsafe` without a `// SAFETY:` comment — state the "
                        "invariant (pointer validity, length, alignment, bit "
                        "validity) on the line(s) directly above"
                    ),
                    snippet=snippet(sf, tok.line),
                )
            )
        return findings
