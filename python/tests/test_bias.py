"""Statistical validation of the paper's core theory (Theorem 2.1 / §2.3):

  * sampling with q = softmax(o) gives an **unbiased** estimator of the full
    softmax gradient,
  * any other q (uniform here) is biased, and the bias shrinks as m grows.

These are Monte-Carlo tests over the *reference* implementation (ref.py), so
they validate the equations the kernels and the rust samplers implement, not
any particular kernel."""

import numpy as np
import pytest

from compile.kernels import ref


def estimator_expectation(o, q, m, trials, rng):
    """Monte-Carlo E[sum_j I(s_j = i) p'_j] (lhs of eq. 7) for positive
    class 0, sampling m negatives from q with replacement. Vectorized over
    trials.

    Follows the setting of the paper's appendix proof: negatives are drawn
    from q restricted to the negative classes (the positive enters the
    sample with probability 1, eq. 12/13 sum over j >= 2)."""
    n = o.shape[0]
    q = q.copy()
    q[0] = 0.0
    q /= q.sum()
    neg = rng.choice(n, size=(trials, m), p=q)
    o_neg = o[neg] - np.log(m * q[neg])  # adjusted logits, eq. (2)
    o_pos = np.full((trials, 1), o[0])  # positive uncorrected
    adj = np.concatenate([o_pos, o_neg], axis=1)  # (trials, m+1)
    adj = adj - adj.max(axis=1, keepdims=True)
    e = np.exp(adj)
    p = e / e.sum(axis=1, keepdims=True)  # p', eq. (3)
    # accumulate per-class expectation of sum_j I(s_j = i) p'_j
    acc = np.zeros(n)
    np.add.at(acc, neg.reshape(-1), p[:, 1:].reshape(-1))
    acc /= trials
    acc[0] += p[:, 0].mean()
    return acc


def softmax(o):
    e = np.exp(o - o.max())
    return e / e.sum()


@pytest.mark.parametrize("m", [2, 8])
def test_softmax_sampling_is_unbiased(m):
    rng = np.random.default_rng(0)
    n = 25
    o = rng.normal(size=n)
    p = softmax(o)
    est = estimator_expectation(o, p, m=m, trials=250_000, rng=rng)
    np.testing.assert_allclose(est, p, atol=5e-3)


def test_uniform_sampling_is_biased_and_bias_shrinks():
    rng = np.random.default_rng(1)
    n = 25
    o = rng.normal(size=n) * 2.0
    p = softmax(o)
    q = np.full(n, 1.0 / n)
    bias = {}
    for m in [2, 8, 32]:
        est = estimator_expectation(o, q, m=m, trials=120_000, rng=rng)
        bias[m] = np.abs(est - p).sum()
    # clearly biased at small m...
    assert bias[2] > 0.05, bias
    # ...and monotonically shrinking toward unbiasedness as m grows (eq. 2's
    # correction makes the limit exact)
    assert bias[2] > bias[8] > bias[32], bias


def test_absolute_softmax_equivalence_claim():
    """§3.3: softmax is shift invariant, so any softmax solution has an
    absolute-softmax counterpart: shifting logits to be nonnegative leaves
    the absolute-softmax distribution equal to the softmax one."""
    rng = np.random.default_rng(2)
    o = rng.normal(size=40)
    shift = -o.min() + 1.0
    p_soft = softmax(o)
    p_abs = softmax(np.abs(o + shift))  # all logits nonneg -> |.| is identity
    np.testing.assert_allclose(p_soft, softmax(o + shift), atol=1e-12)
    np.testing.assert_allclose(p_abs, p_soft, atol=1e-12)


def test_quadratic_kernel_tracks_abs_softmax_better_than_uniform():
    """The design rationale of §3.3: q ∝ 100·o² + 1 is closer (in total
    variation) to the absolute-softmax distribution than uniform is, once
    the model has learned logits with meaningful spread (std ≈ 1-2, the
    regime of a trained model; near the origin with std << 1 the softmax
    itself is nearly uniform and uniform sampling is trivially fine)."""
    rng = np.random.default_rng(3)
    n = 1000
    o = rng.normal(size=n) * 1.5
    p_abs = softmax(np.abs(o))
    q_quad = 100.0 * o**2 + 1.0
    q_quad /= q_quad.sum()
    q_unif = np.full(n, 1.0 / n)
    tv_quad = 0.5 * np.abs(q_quad - p_abs).sum()
    tv_unif = 0.5 * np.abs(q_unif - p_abs).sum()
    assert tv_quad < tv_unif, (tv_quad, tv_unif)
