//! [`PositiveRffMap`] — positive random features for the exponential
//! kernel, as a drop-in [`FeatureMap`] for the §3.2 tree machinery.
//!
//! ```text
//! φ(a)_i = exp(ω_iᵀa − ‖a‖²/2) / √D          (every component > 0)
//! K̂(a,b) = ⟨φ(a), φ(b)⟩ = 1/D Σ_i exp(ω_iᵀ(a+b) − (‖a‖²+‖b‖²)/2)
//! E_ω[K̂(a,b)] = exp(aᵀb)                     (ω_i ~ N(0, I_d))
//! ```
//!
//! The identity is `E exp(ωᵀx) = exp(‖x‖²/2)` for `ω ~ N(0, I)` applied to
//! `x = a + b`: the prefactors turn `‖a+b‖²/2 − ‖a‖²/2 − ‖b‖²/2` into
//! `aᵀb`. Positivity is what lets the whole subset-summary tree work: node
//! masses `⟨φ(h), z(C)⟩` are sums of positive terms, so eq. (9) descent
//! probabilities are honest probabilities and the zero-mass guards only
//! ever fire on true underflow.
//!
//! All inner loops run on the [`crate::ops`] layer: the `ω` projections
//! are panel sweeps ([`crate::ops::dot_many_mixed`] streams the D×d
//! frequency matrix once with the query cache-resident), and the
//! exponentiation is the clamped [`crate::ops::exp_shifted`] row
//! primitive.

use super::config::RffConfig;
use crate::ops;
use crate::sampler::kernel::FeatureMap;
use std::cell::RefCell;

thread_local! {
    /// Per-thread projection buffer for [`FeatureMap::kernel_many`]: the
    /// tree's leaf step runs there once per (example, leaf), which is too
    /// fine-grained for a `Pool` (two Mutex round-trips per leaf would
    /// serialize batch workers) and has no scratch parameter to thread a
    /// per-worker buffer through — so the buffer is thread-local: zero
    /// allocation after each worker's first leaf, zero contention.
    /// Contents never affect results (fully overwritten per call).
    static PROJ_SCRATCH: RefCell<Vec<f64>> = RefCell::new(Vec::new());
}

/// Exponents are clamped here before `exp` so φ components and kernel
/// values stay finite f64s (`exp(709.8)` overflows); the tree additionally
/// sanitizes masses, so the clamp only matters for pathological inputs.
const MAX_EXP: f64 = 700.0;

/// Positive random feature map of the exponential kernel (see module docs).
/// `ω` is frozen at construction from the config seed; `Clone` shares the
/// realized kernel, which is what keeps shards and snapshots consistent.
#[derive(Clone, Debug)]
pub struct PositiveRffMap {
    cfg: RffConfig,
    /// Frequency matrix, `dim × d` row-major.
    omega: Vec<f64>,
}

/// One query's precomputed kernel state (see
/// [`PositiveRffMap::prepare_query`]).
pub struct PreparedQuery {
    /// `ω_iᵀa` per feature row.
    proj: Vec<f64>,
    /// `−‖a‖²/2 − ln D` (the query side's share of the exponent).
    log_pref: f64,
}

impl PositiveRffMap {
    /// Build the map this config describes (draws `ω` deterministically).
    pub fn new(cfg: RffConfig) -> PositiveRffMap {
        assert!(cfg.d > 0 && cfg.dim > 0);
        let omega = cfg.draw_omega();
        PositiveRffMap { cfg, omega }
    }

    /// Build from an explicit frequency matrix (`omega.len()` must be a
    /// multiple of `d`). Used by the layout-pinning tests against the
    /// Python oracle (`phi_rff_ref`) and by variance experiments.
    ///
    /// **Outside the config-identity contract:** the fabricated config
    /// (`seed = u64::MAX` sentinel) does *not* determine this map's `ω` —
    /// re-deriving via `PositiveRffMap::new(map.config()…)` or comparing
    /// configs for kernel equality is only valid for maps built from
    /// [`Self::new`]. Share a `with_omega` map by `Clone`, never by
    /// config.
    pub fn with_omega(d: usize, omega: Vec<f64>) -> PositiveRffMap {
        assert!(d > 0 && !omega.is_empty() && omega.len() % d == 0);
        let dim = omega.len() / d;
        let cfg = RffConfig { d, dim, seed: u64::MAX, orthogonal: false };
        PositiveRffMap { cfg, omega }
    }

    /// The config this map was built from. For [`Self::new`] maps this is
    /// the kernel identity (equal config ⇒ identical `ω`); for
    /// [`Self::with_omega`] maps it is descriptive only (see there).
    pub fn config(&self) -> &RffConfig {
        &self.cfg
    }

    /// The realized frequency matrix (`dim × d` row-major).
    pub fn omega(&self) -> &[f64] {
        &self.omega
    }

    /// Precompute the query-side state for scoring one fixed `a` against
    /// many classes: the D projections `ω_iᵀa` plus that side's prefactor
    /// exponent. [`Self::kernel_prepared`] then costs one `ω` pass per
    /// class instead of two — the dominant pattern of closed-form
    /// distribution sweeps (benches, tests) over a fixed query;
    /// [`Self::kernel_many`] uses the same factoring for leaf panels.
    pub fn prepare_query(&self, a: &[f32]) -> PreparedQuery {
        debug_assert_eq!(a.len(), self.cfg.d);
        let mut proj = vec![0.0f64; self.cfg.dim];
        ops::dot_many_mixed(&self.omega, a, &mut proj);
        PreparedQuery { proj, log_pref: Self::half_neg_sq_norm(a) - (self.cfg.dim as f64).ln() }
    }

    /// `K̂(a, b)` against a query prepared by [`Self::prepare_query`] —
    /// same factored exponents as [`FeatureMap::kernel`] up to f64
    /// addition order (tests bound the difference).
    pub fn kernel_prepared(&self, q: &PreparedQuery, b: &[f32]) -> f64 {
        debug_assert_eq!(b.len(), self.cfg.d);
        self.sum_prepared_exponents(&q.proj, q.log_pref + Self::half_neg_sq_norm(b), b)
    }

    /// `Σ_i exp(min(proj_i + ω_iᵀb + lp, MAX_EXP))` — the ONE accumulation
    /// body behind every prepared-query kernel evaluation
    /// ([`Self::kernel_prepared`] and [`FeatureMap::kernel_many`]); the
    /// clamp/factoring must never diverge between them (the tree's 1e-9
    /// closed-form q tolerance depends on their agreement).
    fn sum_prepared_exponents(&self, proj: &[f64], lp: f64, b: &[f32]) -> f64 {
        let d = self.cfg.d;
        let mut acc = 0.0f64;
        for (i, &pa) in proj.iter().enumerate() {
            let row = &self.omega[i * d..(i + 1) * d];
            acc += (pa + ops::dot_mixed(row, b) + lp).min(MAX_EXP).exp();
        }
        acc
    }

    /// `−‖a‖²/2` — the Gaussian-kernel prefactor exponent of one side.
    #[inline]
    fn half_neg_sq_norm(a: &[f32]) -> f64 {
        -0.5 * ops::dot_f32(a, a)
    }
}

impl FeatureMap for PositiveRffMap {
    fn d(&self) -> usize {
        self.cfg.d
    }

    fn dim(&self) -> usize {
        self.cfg.dim
    }

    fn name(&self) -> &'static str {
        "rff"
    }

    fn phi(&self, a: &[f32], out: &mut [f64]) {
        debug_assert_eq!(a.len(), self.cfg.d);
        debug_assert_eq!(out.len(), self.cfg.dim);
        // one panel sweep for all D projections (ω streamed once), then
        // the scalar prefactor exp(−‖a‖²/2)/√D folded into each exponent —
        // one clamped exp per component, no second pass
        ops::dot_many_mixed(&self.omega, a, out);
        let log_pref = Self::half_neg_sq_norm(a) - 0.5 * (self.cfg.dim as f64).ln();
        ops::exp_shifted(out, log_pref, MAX_EXP);
    }

    /// `⟨φ(a), φ(b)⟩` in closed form: the factored exponent
    /// `ω_iᵀa + ω_iᵀb + log_pref(a) + log_pref(b)` sums the same quantities
    /// `phi` exponentiates per side, so leaf scores agree with the arena's
    /// `z` sums to f64 rounding (the same contract the quadratic map
    /// satisfies — the tree's closed-form q depends on it).
    fn kernel(&self, a: &[f32], b: &[f32]) -> f64 {
        debug_assert_eq!(a.len(), self.cfg.d);
        debug_assert_eq!(b.len(), self.cfg.d);
        let log_pref = Self::half_neg_sq_norm(a) + Self::half_neg_sq_norm(b)
            - (self.cfg.dim as f64).ln();
        let d = self.cfg.d;
        let mut acc = 0.0f64;
        for i in 0..self.cfg.dim {
            let row = &self.omega[i * d..(i + 1) * d];
            acc += (ops::dot_mixed(row, a) + ops::dot_mixed(row, b) + log_pref)
                .min(MAX_EXP)
                .exp();
        }
        acc
    }

    /// Leaf-panel scoring with the query side factored out: one ω pass for
    /// the shared projections (`prepare_query`-style, but into the
    /// thread-local buffer — the tree's leaf step runs here and
    /// steady-state sampling must neither allocate nor take a lock), then
    /// one ω pass per class — instead of the default loop's two. Same
    /// factored exponents as [`Self::kernel`] up to f64 addition order
    /// (within the tree's 1e-9 closed-form tolerance; the rff tests bound
    /// it).
    fn kernel_many(&self, a: &[f32], panel: &[f32], out: &mut [f64]) {
        let d = self.cfg.d;
        debug_assert_eq!(panel.len(), d * out.len());
        PROJ_SCRATCH.with(|cell| {
            let mut proj = cell.borrow_mut();
            proj.clear();
            proj.resize(self.cfg.dim, 0.0);
            ops::dot_many_mixed(&self.omega, a, &mut proj);
            let lp_query = Self::half_neg_sq_norm(a) - (self.cfg.dim as f64).ln();
            for (slot, row) in out.iter_mut().zip(panel.chunks_exact(d.max(1))) {
                *slot =
                    self.sum_prepared_exponents(&proj, lp_query + Self::half_neg_sq_norm(row), row);
            }
        });
    }
}
