//! Experiment grid runner — the machinery behind every figure.
//!
//! A [`GridSpec`] names a model, a list of samplers and a list of sample
//! sizes m; [`run_grid`] trains every (sampler, m) cell from the same seed
//! (identical init + data) and collects the eval-loss curves. The figure
//! benches and the `kss experiment` subcommand are thin layers over this.

use crate::coordinator::config::TrainConfig;
use crate::coordinator::metrics::{EvalPoint, MetricsSink};
use crate::coordinator::trainer::Trainer;
use crate::runtime::Engine;
use crate::util::json::Value;
use anyhow::Result;
use std::path::Path;

/// A (sampler × m) sweep over one model.
#[derive(Clone, Debug)]
pub struct GridSpec {
    /// Base config: model, lr, schedule, seed (sampler/m are overridden).
    pub base: TrainConfig,
    pub samplers: Vec<String>,
    pub ms: Vec<usize>,
    /// Also run the full-softmax reference line.
    pub include_full: bool,
}

/// One cell's outcome.
#[derive(Clone, Debug)]
pub struct RunSummary {
    pub sampler: String,
    /// 0 for the full-softmax baseline.
    pub m: usize,
    pub final_loss: f64,
    pub best_loss: f64,
    pub curve: Vec<EvalPoint>,
    pub wall_s: f64,
}

impl RunSummary {
    pub fn label(&self) -> String {
        if self.sampler == "full" {
            "full".to_string()
        } else {
            format!("{} m={}", self.sampler, self.m)
        }
    }
}

/// Run every cell of the grid. `out_dir` (if given) receives one JSONL per
/// run plus a `summary.json`.
pub fn run_grid(engine: &Engine, grid: &GridSpec, out_dir: Option<&Path>) -> Result<Vec<RunSummary>> {
    let mut summaries = Vec::new();
    let mut cells: Vec<(String, usize)> = Vec::new();
    if grid.include_full {
        cells.push(("full".to_string(), 0));
    }
    for s in &grid.samplers {
        for &m in &grid.ms {
            cells.push((s.clone(), m));
        }
    }
    for (sampler, m) in cells {
        let mut cfg = grid.base.clone();
        cfg.sampler = sampler.clone();
        cfg.m = m;
        let run_id = cfg.run_id();
        let mut sink = match out_dir {
            Some(dir) => MetricsSink::to_dir(dir, &run_id)?,
            None => MetricsSink::memory(&run_id),
        };
        let t0 = std::time::Instant::now();
        let mut trainer = Trainer::new(engine, cfg)?;
        let res = trainer.train(&mut sink)?;
        let wall_s = t0.elapsed().as_secs_f64();
        crate::info!(
            "grid cell {:<28} final {:.4} best {:.4} ({:.1}s)",
            format!("{sampler} m={m}"),
            res.final_loss,
            res.best_loss,
            wall_s
        );
        summaries.push(RunSummary {
            sampler,
            m,
            final_loss: res.final_loss,
            best_loss: res.best_loss,
            curve: res.curve,
            wall_s,
        });
    }
    if let Some(dir) = out_dir {
        let summary = summaries_to_json(&summaries);
        std::fs::write(dir.join("summary.json"), summary.to_string_pretty())?;
    }
    Ok(summaries)
}

/// JSON dump of grid results (consumed by plotting / EXPERIMENTS.md).
pub fn summaries_to_json(summaries: &[RunSummary]) -> Value {
    Value::Array(
        summaries
            .iter()
            .map(|s| {
                Value::object(vec![
                    ("sampler", Value::str(&s.sampler)),
                    ("m", Value::num(s.m as f64)),
                    ("final_loss", Value::num(s.final_loss)),
                    ("best_loss", Value::num(s.best_loss)),
                    ("wall_s", Value::num(s.wall_s)),
                    (
                        "curve",
                        Value::Array(
                            s.curve
                                .iter()
                                .map(|p| {
                                    Value::object(vec![
                                        ("epoch", Value::num(p.epoch)),
                                        ("loss", Value::num(p.loss)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect(),
    )
}

/// Render a "final loss vs m" table (the paper's Figure-2 content) as text.
pub fn bias_table(summaries: &[RunSummary], ms: &[usize]) -> String {
    let mut out = String::new();
    out.push_str(&format!("{:<16}", "sampler"));
    for &m in ms {
        out.push_str(&format!(" {:>10}", format!("m={m}")));
    }
    out.push('\n');
    let mut samplers: Vec<&str> = Vec::new();
    for s in summaries {
        if s.sampler != "full" && !samplers.contains(&s.sampler.as_str()) {
            samplers.push(&s.sampler);
        }
    }
    for sampler in samplers {
        out.push_str(&format!("{sampler:<16}"));
        for &m in ms {
            match summaries.iter().find(|s| s.sampler == sampler && s.m == m) {
                Some(s) => out.push_str(&format!(" {:>10.4}", s.final_loss)),
                None => out.push_str(&format!(" {:>10}", "-")),
            }
        }
        out.push('\n');
    }
    if let Some(full) = summaries.iter().find(|s| s.sampler == "full") {
        out.push_str(&format!("{:<16} {:>10.4} (reference)\n", "full softmax", full.final_loss));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_summary(sampler: &str, m: usize, loss: f64) -> RunSummary {
        RunSummary {
            sampler: sampler.into(),
            m,
            final_loss: loss,
            best_loss: loss,
            curve: vec![EvalPoint { epoch: 1.0, step: 1, loss }],
            wall_s: 0.1,
        }
    }

    #[test]
    fn bias_table_renders_rows_and_reference() {
        let summaries = vec![
            fake_summary("uniform", 8, 5.0),
            fake_summary("uniform", 32, 4.5),
            fake_summary("quadratic", 8, 4.2),
            fake_summary("full", 0, 4.0),
        ];
        let table = bias_table(&summaries, &[8, 32]);
        assert!(table.contains("uniform") && table.contains("quadratic"));
        assert!(table.contains("5.0000") && table.contains("4.5000"));
        assert!(table.contains("(reference)"));
        assert!(table.contains('-'), "missing cells rendered as '-'");
    }

    #[test]
    fn summaries_json_shape() {
        let v = summaries_to_json(&[fake_summary("uniform", 8, 5.0)]);
        let arr = v.as_array().unwrap();
        assert_eq!(arr[0].get("sampler").unwrap().as_str(), Some("uniform"));
        assert_eq!(arr[0].get("curve").unwrap().as_array().unwrap().len(), 1);
    }

    #[test]
    fn labels() {
        assert_eq!(fake_summary("full", 0, 1.0).label(), "full");
        assert_eq!(fake_summary("uniform", 8, 1.0).label(), "uniform m=8");
    }
}
