//! Figure 5 (appendix) — **Penn-Tree-Bank, all six samplers × m sweep**:
//! uniform, unigram, bigram, quadratic, quartic, softmax.
//!
//! The full set of samplers the paper evaluates on its NLP task, including
//! the static language-model baselines (unigram/bigram) and the quartic
//! kernel (flat sampling: D = O(d⁴) has no tractable feature map).
//!
//! `cargo bench --bench fig5_ptb_all` / `KSS_BENCH_SCALE=full ...`

use kss::bench_harness::{engine_or_exit, print_series, scale, Scale};
use kss::coordinator::experiment::{bias_table, run_grid, GridSpec};
use kss::coordinator::TrainConfig;

fn main() -> anyhow::Result<()> {
    kss::util::logging::init_from_env();
    let engine = engine_or_exit();
    let (base, ms) = match scale() {
        Scale::Quick => (
            TrainConfig {
                model: "tiny-lm".into(),
                epochs: 2,
                train_size: 6_000,
                valid_size: 1_200,
                eval_batches: 8,
                eval_every: 100,
                ..Default::default()
            },
            vec![4usize],
        ),
        Scale::Full => (
            TrainConfig {
                model: "ptb".into(),
                epochs: 2,
                train_size: 120_000,
                valid_size: 24_000,
                eval_batches: 8,
                eval_every: 100,
                ..Default::default()
            },
            vec![8usize, 32, 128],
        ),
    };

    println!("==== Figure 5 — LM dataset, all samplers × m ====");
    let grid = GridSpec {
        base,
        samplers: kss::sampler::LM_SAMPLERS.iter().map(|s| s.to_string()).collect(),
        ms: ms.clone(),
        include_full: true,
    };
    let summaries = run_grid(&engine, &grid, Some(std::path::Path::new("runs/fig5")))?;
    for s in &summaries {
        let pts: Vec<(f64, f64)> = s.curve.iter().map(|p| (p.epoch, p.loss)).collect();
        print_series(&s.label(), &pts);
    }
    println!("\nfinal-loss table:");
    print!("{}", bias_table(&summaries, &ms));
    println!("\nshape to check (paper Fig. 5): softmax best ≈ full; quadratic and");
    println!("quartic close; bigram < unigram < uniform among the static samplers.");
    Ok(())
}
