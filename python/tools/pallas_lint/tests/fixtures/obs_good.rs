//! OBS fixture — the same discard sites, made visible to the registry.

use std::sync::atomic::{AtomicU64, Ordering};

pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }
}

pub struct Worker {
    tx: std::sync::mpsc::Sender<u32>,
    dropped_replies: Counter,
    write_failures: Counter,
}

impl Worker {
    pub fn reply(&self, v: u32) {
        // drop counted: the registry sees every hung-up receiver
        if self.tx.send(v).is_err() {
            self.dropped_replies.inc();
        }
    }

    pub fn drain(&self, r: Result<u32, String>) -> u32 {
        match r {
            Ok(v) => v,
            Err(_) => {
                self.write_failures.inc();
                0
            }
        }
    }

    pub fn flush(&self, r: Result<(), String>) -> Option<()> {
        // value-position .ok() is a conversion, not a discard
        r.ok()
    }
}
