//! Figure 4 — **convergence at a fixed m across sampling distributions**.
//!
//! All distributions converge at a similar *speed*; only the final loss
//! (the bias) differs. Uniform plateaus high; quadratic tracks softmax with
//! a small offset.
//!
//! `cargo bench --bench fig4_distributions` / `KSS_BENCH_SCALE=full ...`

use kss::bench_harness::{engine_or_exit, print_series, scale, Scale};
use kss::coordinator::experiment::{run_grid, GridSpec};
use kss::coordinator::TrainConfig;

fn main() -> anyhow::Result<()> {
    kss::util::logging::init_from_env();
    let engine = engine_or_exit();
    let (label, base, m) = match scale() {
        Scale::Quick => (
            "tiny",
            TrainConfig {
                model: "tiny".into(),
                epochs: 4,
                train_size: 960,
                valid_size: 320,
                eval_batches: 10,
                eval_every: 40,
                ..Default::default()
            },
            8usize,
        ),
        Scale::Full => (
            "ptb",
            TrainConfig {
                model: "ptb".into(),
                epochs: 3,
                train_size: 120_000,
                valid_size: 24_000,
                eval_batches: 8,
                eval_every: 100,
                ..Default::default()
            },
            32usize, // scaled stand-in for the paper's m = 40
        ),
    };

    println!("==== Figure 4 — {label}, fixed m = {m}, distribution comparison ====");
    let grid = GridSpec {
        base,
        samplers: vec!["uniform".into(), "quadratic".into(), "softmax".into()],
        ms: vec![m],
        include_full: true,
    };
    let summaries = run_grid(&engine, &grid, Some(std::path::Path::new("runs/fig4")))?;
    for s in &summaries {
        let pts: Vec<(f64, f64)> = s.curve.iter().map(|p| (p.epoch, p.loss)).collect();
        print_series(&s.label(), &pts);
    }
    println!("\nshape to check: similar convergence *speed* everywhere; uniform's");
    println!("curve flattens at a visibly higher loss (its bias floor).");
    Ok(())
}
