//! Parameter store: host-side model parameters + the output-embedding mirror.
//!
//! Parameters are initialized in rust (deterministically, from the manifest's
//! init specs — matching `ModelConfig.init_params` in spirit; exact RNG
//! parity with jax is not required, only distributional parity) and round-trip
//! through every train step: fed in as literals, replaced by the returned
//! updated params.
//!
//! The samplers need host access to the *output* embedding table `out_w`
//! (the kernel tree computes φ(w_i), the exact samplers compute logits): the
//! store exposes it and applies the sparse row updates `train_sampled`
//! returns, reporting which classes changed so the tree can update its
//! `z(C)` path statistics (paper Fig. 1(b)).

use crate::runtime::manifest::ParamSpec;
use crate::runtime::tensor::Tensor;
use crate::util::rng::Rng;
use anyhow::{bail, Result};

/// Host-side parameters in manifest order.
pub struct ParamStore {
    specs: Vec<ParamSpec>,
    values: Vec<Tensor>,
}

impl ParamStore {
    /// Initialize from specs with a seeded RNG.
    pub fn init(specs: &[ParamSpec], seed: u64) -> Result<ParamStore> {
        let mut rng = Rng::new(seed);
        let mut values = Vec::with_capacity(specs.len());
        for spec in specs {
            let len: usize = spec.shape.iter().product();
            let mut data = vec![0.0f32; len];
            if spec.init == "zeros" {
                // leave zeros
            } else if let Some(std) = spec.init.strip_prefix("normal:") {
                let std: f32 = std.parse().map_err(|_| {
                    anyhow::anyhow!("param {}: bad init '{}'", spec.name, spec.init)
                })?;
                rng.fill_normal(&mut data, std);
            } else if spec.init == "glorot" {
                let fan_in = *spec.shape.first().unwrap_or(&1) as f32;
                let fan_out = *spec.shape.last().unwrap_or(&1) as f32;
                let std = (2.0 / (fan_in + fan_out)).sqrt();
                rng.fill_normal(&mut data, std);
            } else {
                bail!("param {}: unknown init '{}'", spec.name, spec.init);
            }
            values.push(Tensor::f32s(&spec.shape, data));
        }
        Ok(ParamStore { specs: specs.to_vec(), values })
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    pub fn specs(&self) -> &[ParamSpec] {
        &self.specs
    }

    pub fn values(&self) -> &[Tensor] {
        &self.values
    }

    /// Total number of scalar parameters (model size).
    pub fn n_scalars(&self) -> usize {
        self.values.iter().map(|t| t.len()).sum()
    }

    /// Replace all parameters (the leading outputs of a train step).
    pub fn set_all(&mut self, new_values: &[Tensor]) -> Result<()> {
        if new_values.len() != self.values.len() {
            bail!("expected {} params, got {}", self.values.len(), new_values.len());
        }
        for (cur, new) in self.values.iter_mut().zip(new_values) {
            if cur.shape() != new.shape() {
                bail!("param shape changed: {:?} -> {:?}", cur.shape(), new.shape());
            }
            *cur = new.clone();
        }
        Ok(())
    }

    /// The output-embedding table (last param by convention), as (n, d) rows.
    pub fn out_w(&self) -> &Tensor {
        self.values.last().expect("no params")
    }

    /// One row of the output embedding table.
    pub fn out_row(&self, class: usize) -> &[f32] {
        let t = self.out_w();
        let d = t.shape()[1];
        &t.as_f32().unwrap()[class * d..(class + 1) * d]
    }

    /// Apply the `rows` output of train_sampled: for each example the
    /// (S = m+1) sampled classes' *post-update* embeddings. Writes them into
    /// the host mirror and returns the sorted, deduplicated list of classes
    /// that changed (the tree-update work list).
    ///
    /// `s` is (N, S) class indices (positive at column 0), `rows` is
    /// (N, S, d) — both exactly as the artifact produced them.
    pub fn apply_sampled_rows(&mut self, s: &[i32], rows: &Tensor) -> Result<Vec<usize>> {
        let dims = rows.shape().to_vec();
        if dims.len() != 3 {
            bail!("rows must be (N, S, d), got {dims:?}");
        }
        let (n, sdim, d) = (dims[0], dims[1], dims[2]);
        if s.len() != n * sdim {
            bail!("s has {} entries, expected {}", s.len(), n * sdim);
        }
        let out_t = self.values.last_mut().expect("no params");
        let out_shape = out_t.shape().to_vec();
        if out_shape[1] != d {
            bail!("row width {} != out_w width {}", d, out_shape[1]);
        }
        // borrow the artifact's row buffer directly — this runs on every
        // sampled step, and the old `.to_vec()` cloned the whole (N, S, d)
        // tensor before the row patch
        let data = rows.as_f32()?;
        let out = out_t.as_f32_mut()?;
        let mut changed: Vec<usize> = Vec::with_capacity(s.len());
        for i in 0..n * sdim {
            let class = s[i] as usize;
            if class >= out_shape[0] {
                bail!("class index {class} out of range {}", out_shape[0]);
            }
            out[class * d..(class + 1) * d].copy_from_slice(&data[i * d..(i + 1) * d]);
            changed.push(class);
        }
        changed.sort_unstable();
        changed.dedup();
        Ok(changed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<ParamSpec> {
        vec![
            ParamSpec { name: "emb".into(), shape: vec![10, 4], init: "normal:0.1".into() },
            ParamSpec { name: "w".into(), shape: vec![4, 8], init: "glorot".into() },
            ParamSpec { name: "b".into(), shape: vec![8], init: "zeros".into() },
            ParamSpec { name: "out_w".into(), shape: vec![10, 4], init: "normal:0.1".into() },
        ]
    }

    #[test]
    fn init_respects_specs() {
        let store = ParamStore::init(&specs(), 1).unwrap();
        assert_eq!(store.len(), 4);
        assert_eq!(store.n_scalars(), 40 + 32 + 8 + 40);
        assert!(store.values()[2].as_f32().unwrap().iter().all(|&x| x == 0.0));
        let emb = store.values()[0].as_f32().unwrap();
        assert!(emb.iter().any(|&x| x != 0.0));
        // std ≈ 0.1
        let var: f32 = emb.iter().map(|x| x * x).sum::<f32>() / emb.len() as f32;
        assert!(var.sqrt() < 0.2, "std {}", var.sqrt());
    }

    #[test]
    fn init_is_deterministic_and_seed_sensitive() {
        let a = ParamStore::init(&specs(), 7).unwrap();
        let b = ParamStore::init(&specs(), 7).unwrap();
        let c = ParamStore::init(&specs(), 8).unwrap();
        assert_eq!(a.values()[0], b.values()[0]);
        assert_ne!(a.values()[0], c.values()[0]);
    }

    #[test]
    fn apply_sampled_rows_updates_mirror() {
        let mut store = ParamStore::init(&specs(), 3).unwrap();
        let before = store.out_row(5).to_vec();
        // N=2 examples, S=2 (pos + 1 neg), d=4
        let s = vec![5i32, 2, 7, 2];
        let rows = Tensor::f32s(&[2, 2, 4], (0..16).map(|x| x as f32).collect());
        let changed = store.apply_sampled_rows(&s, &rows).unwrap();
        assert_eq!(changed, vec![2, 5, 7]);
        assert_eq!(store.out_row(5), &[0.0, 1.0, 2.0, 3.0]);
        // class 2 appears twice; the LAST write wins (values identical in
        // real steps since both gathers read the same updated table)
        assert_eq!(store.out_row(2), &[12.0, 13.0, 14.0, 15.0]);
        assert_eq!(store.out_row(7), &[8.0, 9.0, 10.0, 11.0]);
        assert_ne!(store.out_row(5), before.as_slice());
    }

    #[test]
    fn apply_sampled_rows_validates() {
        let mut store = ParamStore::init(&specs(), 3).unwrap();
        let rows = Tensor::f32s(&[1, 1, 4], vec![0.0; 4]);
        assert!(store.apply_sampled_rows(&[99], &rows).is_err()); // class oob
        assert!(store.apply_sampled_rows(&[0, 1], &rows).is_err()); // s len
        let bad = Tensor::f32s(&[1, 4], vec![0.0; 4]);
        assert!(store.apply_sampled_rows(&[0], &bad).is_err()); // rank
    }

    #[test]
    fn set_all_validates_shapes() {
        let mut store = ParamStore::init(&specs(), 1).unwrap();
        let mut vals: Vec<Tensor> = store.values().to_vec();
        vals[0] = Tensor::zeros_f32(&[10, 4]);
        store.set_all(&vals).unwrap();
        assert!(store.values()[0].as_f32().unwrap().iter().all(|&x| x == 0.0));
        assert!(store.set_all(&vals[..2]).is_err());
        vals[1] = Tensor::zeros_f32(&[1]);
        assert!(store.set_all(&vals).is_err());
    }
}
