//! The coordinator: everything between the datasets and the PJRT runtime.
//!
//! * [`config`] — experiment configuration (model, sampler, m, schedule) and
//!   dataset construction.
//! * [`trainer`] — the training loop implementing the paper's procedure:
//!   encode → batch negative sampling → sampled-softmax step → one
//!   kernel-tree update + publish; plus the full-softmax baseline and the
//!   full-softmax evaluation the figures report.
//! * [`pipeline`] — the stage-overlapped engine under the trainer: the
//!   sample/step/publish schedule (depth 1 sequential, depth 2 overlapped
//!   with one-step-stale q), the pipeline worker, pooled step scratch and
//!   the resolved-op cache.
//! * [`metrics`] — JSONL metric sink + in-memory loss curves.
//! * [`experiment`] — the (sampler × m) grid runner behind every figure.

pub mod config;
pub mod experiment;
pub mod metrics;
pub mod pipeline;
pub mod trainer;

pub use config::TrainConfig;
pub use experiment::{run_grid, GridSpec, RunSummary};
pub use metrics::MetricsSink;
pub use pipeline::{PipelineDriver, SampleOutcome, SampleTask, StepScratch};
pub use trainer::{TrainResult, Trainer};
