//! Global-free metrics registry.
//!
//! There are no statics: the owner of a subsystem (Trainer, the serve
//! load generator, a bench) constructs a [`MetricsRegistry`], and each
//! component *binds* its already-live atomic cells to it under a stable
//! name (`register_*`) or asks the registry to mint one (`counter` /
//! `gauge` / `histogram`). Components therefore work instrumented even
//! with no registry in sight — their cells are plain `Arc`s — and a
//! registry is only the naming/export layer on top.
//!
//! Registration takes a mutex (cold, startup-only). The hot path —
//! `Counter::inc`, `Gauge::set`, `Histogram::record` — never locks.
//!
//! Duplicate names are legal and meaningful: the four shards of a
//! [`crate::serve::ShardSet`] each register their publisher cells under
//! the same names, and [`MetricsRegistry::snapshot`] aggregates by name
//! (counters sum, histograms merge, gauges take the max), so exports see
//! one fleet-wide series per metric.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::histogram::{atomic_f64_add, Histogram, HistogramSnapshot};

/// Monotone event counter (`AtomicU64`, relaxed).
#[derive(Default)]
pub struct Counter {
    v: AtomicU64,
}

impl std::fmt::Debug for Counter {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Counter({})", self.get())
    }
}

impl Counter {
    pub fn new() -> Self {
        Counter { v: AtomicU64::new(0) }
    }

    #[inline]
    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Last-write-wins f64 cell with monotone helpers (`set_max` keeps a
/// high-watermark, `add` accumulates — both lock-free).
pub struct Gauge {
    bits: AtomicU64,
}

impl Default for Gauge {
    fn default() -> Self {
        Self::new()
    }
}

impl Gauge {
    pub fn new() -> Self {
        Gauge { bits: AtomicU64::new(0.0f64.to_bits()) }
    }

    #[inline]
    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Raise the gauge to `v` if larger (high-watermark). Positive-f64
    /// bit patterns order like the floats, so this is one `fetch_max`;
    /// non-positive values are ignored (the watermark starts at 0).
    #[inline]
    pub fn set_max(&self, v: f64) {
        if v > 0.0 {
            self.bits.fetch_max(v.to_bits(), Ordering::Relaxed);
        }
    }

    /// Accumulate into the gauge (CAS-add; for rarely-written cells).
    #[inline]
    pub fn add(&self, v: f64) {
        atomic_f64_add(&self.bits, v);
    }

    /// Lower the gauge to `v` if smaller, treating the initial 0.0 as
    /// "no observation yet" (so a min-watermark like min-q-observed works
    /// without a NaN/inf sentinel that the JSON export couldn't carry).
    /// Only positive finite values are accepted.
    #[inline]
    pub fn set_min(&self, v: f64) {
        if !(v > 0.0 && v.is_finite()) {
            return;
        }
        let mut cur = self.bits.load(Ordering::Relaxed);
        loop {
            let cf = f64::from_bits(cur);
            if cf != 0.0 && cf <= v {
                return;
            }
            match self.bits.compare_exchange_weak(
                cur,
                v.to_bits(),
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

/// What a registered cell is — drives exposition rendering.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MetricKind {
    Counter,
    Gauge,
    Histogram,
}

/// Name + documentation of one registered metric. `unit` and `layer` are
/// free-form short strings surfaced in the README metric catalog and the
/// JSONL export (`layer` is the subsystem: sampler / serve / pipeline /
/// trainer).
#[derive(Clone, Debug)]
pub struct MetricMeta {
    pub name: String,
    pub kind: MetricKind,
    pub unit: &'static str,
    pub layer: &'static str,
    pub help: &'static str,
}

enum Cell {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Hist(Arc<Histogram>),
}

struct Entry {
    meta: MetricMeta,
    cell: Cell,
}

/// The registry: an insertion-ordered list of named cells.
#[derive(Default)]
pub struct MetricsRegistry {
    inner: Mutex<Vec<Entry>>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        MetricsRegistry { inner: Mutex::new(Vec::new()) }
    }

    fn push(&self, meta: MetricMeta, cell: Cell) {
        // registration is cold; recover a poisoned registry rather than
        // propagate (a panicked registrant must not take telemetry down)
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        g.push(Entry { meta, cell });
    }

    /// Mint + register a counter.
    pub fn counter(
        &self,
        name: &str,
        unit: &'static str,
        layer: &'static str,
        help: &'static str,
    ) -> Arc<Counter> {
        let c = Arc::new(Counter::new());
        self.register_counter(name, unit, layer, help, Arc::clone(&c));
        c
    }

    /// Mint + register a gauge.
    pub fn gauge(
        &self,
        name: &str,
        unit: &'static str,
        layer: &'static str,
        help: &'static str,
    ) -> Arc<Gauge> {
        let g = Arc::new(Gauge::new());
        self.register_gauge(name, unit, layer, help, Arc::clone(&g));
        g
    }

    /// Mint + register a histogram.
    pub fn histogram(
        &self,
        name: &str,
        unit: &'static str,
        layer: &'static str,
        help: &'static str,
    ) -> Arc<Histogram> {
        let h = Arc::new(Histogram::new());
        self.register_histogram(name, unit, layer, help, Arc::clone(&h));
        h
    }

    /// Bind an existing counter cell under `name`.
    pub fn register_counter(
        &self,
        name: &str,
        unit: &'static str,
        layer: &'static str,
        help: &'static str,
        cell: Arc<Counter>,
    ) {
        self.push(
            MetricMeta { name: name.to_string(), kind: MetricKind::Counter, unit, layer, help },
            Cell::Counter(cell),
        );
    }

    /// Bind an existing gauge cell under `name`.
    pub fn register_gauge(
        &self,
        name: &str,
        unit: &'static str,
        layer: &'static str,
        help: &'static str,
        cell: Arc<Gauge>,
    ) {
        self.push(
            MetricMeta { name: name.to_string(), kind: MetricKind::Gauge, unit, layer, help },
            Cell::Gauge(cell),
        );
    }

    /// Bind an existing histogram cell under `name`.
    pub fn register_histogram(
        &self,
        name: &str,
        unit: &'static str,
        layer: &'static str,
        help: &'static str,
        cell: Arc<Histogram>,
    ) {
        self.push(
            MetricMeta { name: name.to_string(), kind: MetricKind::Histogram, unit, layer, help },
            Cell::Hist(cell),
        );
    }

    /// Point-in-time readout, aggregated by name (first-registration
    /// order): duplicate counters sum, duplicate histograms merge,
    /// duplicate gauges keep the max (shards report the worst case).
    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let mut snap = MetricsSnapshot {
            counters: Vec::new(),
            gauges: Vec::new(),
            hists: Vec::new(),
        };
        for e in g.iter() {
            match &e.cell {
                Cell::Counter(c) => {
                    let v = c.get();
                    match snap.counters.iter_mut().find(|(m, _)| m.name == e.meta.name) {
                        Some((_, acc)) => *acc += v,
                        None => snap.counters.push((e.meta.clone(), v)),
                    }
                }
                Cell::Gauge(c) => {
                    let v = c.get();
                    match snap.gauges.iter_mut().find(|(m, _)| m.name == e.meta.name) {
                        Some((_, acc)) => *acc = acc.max(v),
                        None => snap.gauges.push((e.meta.clone(), v)),
                    }
                }
                Cell::Hist(h) => {
                    let v = h.snapshot();
                    match snap.hists.iter_mut().find(|(m, _)| m.name == e.meta.name) {
                        Some((_, acc)) => acc.merge(&v),
                        None => snap.hists.push((e.meta.clone(), v)),
                    }
                }
            }
        }
        snap
    }
}

/// Aggregated point-in-time view of a registry — the input to both export
/// formats (see `obs::export`) and to test assertions.
pub struct MetricsSnapshot {
    pub counters: Vec<(MetricMeta, u64)>,
    pub gauges: Vec<(MetricMeta, f64)>,
    pub hists: Vec<(MetricMeta, HistogramSnapshot)>,
}

impl MetricsSnapshot {
    /// Counter value by name (tests / assertions).
    pub fn counter(&self, name: &str) -> Option<u64> {
        self.counters.iter().find(|(m, _)| m.name == name).map(|(_, v)| *v)
    }

    /// Gauge value by name.
    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.iter().find(|(m, _)| m.name == name).map(|(_, v)| *v)
    }

    /// Histogram snapshot by name.
    pub fn hist(&self, name: &str) -> Option<&HistogramSnapshot> {
        self.hists.iter().find(|(m, _)| m.name == name).map(|(_, v)| v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mint_register_and_read_back() {
        let reg = MetricsRegistry::new();
        let c = reg.counter("kss_test_total", "events", "test", "a counter");
        let g = reg.gauge("kss_test_depth", "items", "test", "a gauge");
        let h = reg.histogram("kss_test_latency_seconds", "seconds", "test", "a histogram");
        c.add(3);
        c.inc();
        g.set(2.5);
        h.record(0.25);
        h.record(0.25);
        let s = reg.snapshot();
        assert_eq!(s.counter("kss_test_total"), Some(4));
        assert_eq!(s.gauge("kss_test_depth"), Some(2.5));
        let hs = s.hist("kss_test_latency_seconds").unwrap();
        assert_eq!(hs.count(), 2);
        assert_eq!(hs.p50(), 0.25);
        assert_eq!(s.counter("missing"), None);
    }

    #[test]
    fn duplicate_names_aggregate() {
        let reg = MetricsRegistry::new();
        // two shards binding the same series names
        let c0 = Arc::new(Counter::new());
        let c1 = Arc::new(Counter::new());
        reg.register_counter("kss_shard_total", "events", "serve", "per-shard", Arc::clone(&c0));
        reg.register_counter("kss_shard_total", "events", "serve", "per-shard", Arc::clone(&c1));
        let g0 = Arc::new(Gauge::new());
        let g1 = Arc::new(Gauge::new());
        reg.register_gauge("kss_shard_peak", "items", "serve", "per-shard", Arc::clone(&g0));
        reg.register_gauge("kss_shard_peak", "items", "serve", "per-shard", Arc::clone(&g1));
        let h0 = Arc::new(Histogram::new());
        let h1 = Arc::new(Histogram::new());
        reg.register_histogram("kss_shard_lat", "seconds", "serve", "per-shard", Arc::clone(&h0));
        reg.register_histogram("kss_shard_lat", "seconds", "serve", "per-shard", Arc::clone(&h1));
        c0.add(2);
        c1.add(5);
        g0.set(1.0);
        g1.set(3.0);
        h0.record(0.5);
        h1.record(0.5);
        let s = reg.snapshot();
        assert_eq!(s.counter("kss_shard_total"), Some(7));
        assert_eq!(s.gauge("kss_shard_peak"), Some(3.0));
        assert_eq!(s.hist("kss_shard_lat").unwrap().count(), 2);
        // aggregation by name: one row per series
        assert_eq!(s.counters.len(), 1);
        assert_eq!(s.gauges.len(), 1);
        assert_eq!(s.hists.len(), 1);
    }

    #[test]
    fn gauge_watermark_and_add() {
        let g = Gauge::new();
        g.set_max(2.0);
        g.set_max(1.0);
        assert_eq!(g.get(), 2.0);
        g.set_max(-5.0); // ignored
        assert_eq!(g.get(), 2.0);
        let g2 = Gauge::new();
        g2.add(0.5);
        g2.add(0.25);
        assert!((g2.get() - 0.75).abs() < 1e-15);
    }
}
