"""Rule registry: every invariant pallas-lint enforces, in display order.

A `Rule` checks one SourceFile at a time; a `ProjectRule` sees the whole
scanned file set at once (cross-file invariants like registry
consistency or the global lock-acquisition graph). Each rule names the
contract it protects — the same text lands in ANALYSIS.json and the
README invariant catalog.
"""

from __future__ import annotations

from dataclasses import dataclass

from pallas_lint.frontend import SourceFile


@dataclass
class Finding:
    rule: str
    file: str
    line: int
    message: str
    snippet: str


class Rule:
    id = "RULE"
    name = "rule"
    summary = ""
    contract = ""

    def applies(self, relpath: str) -> bool:
        return relpath.startswith("rust/src/")

    def check(self, sf: SourceFile) -> list[Finding]:
        raise NotImplementedError


class ProjectRule(Rule):
    """Cross-file rule: `check_project` runs once over the scanned set.
    `extra_files` lists non-Rust paths (relative to the repo root) the
    rule wants the engine to read for it (e.g. README.md)."""

    extra_files: tuple = ()

    def check(self, sf: SourceFile) -> list[Finding]:
        return []

    def check_project(
        self, files: dict, extra: dict
    ) -> list[Finding]:  # files: relpath -> SourceFile; extra: relpath -> str
        raise NotImplementedError


def all_rules() -> list[Rule]:
    from pallas_lint.rules.accumulation import AccumulationContract
    from pallas_lint.rules.lock_discipline import LockDiscipline
    from pallas_lint.rules.obs_drop import ObsVisibleDrops
    from pallas_lint.rules.panic_free import PanicFreeWorkers
    from pallas_lint.rules.q_positivity import QPositivity
    from pallas_lint.rules.registry_consistency import RegistryConsistency
    from pallas_lint.rules.unsafe_audit import UnsafeAudit

    return [
        AccumulationContract(),
        QPositivity(),
        PanicFreeWorkers(),
        LockDiscipline(),
        ObsVisibleDrops(),
        UnsafeAudit(),
        RegistryConsistency(),
    ]
