//! Online sampler-quality monitors over eq. (2) importance weights.
//!
//! The paper's eq. (2) trains on adjusted logits `o'_i = o_i − ln(m·q_i)`;
//! the quality of a proposal `q` is exactly how well the induced
//! importance weights behave. Two streaming estimators, both cheap enough
//! to run on a stride inside the hot sampler:
//!
//! * **TV-to-exact** — for a class `c` drawn from the proposal
//!   (which is precisely what the sampler emits), the identity
//!   `TV(p, q) = ½·E_{c∼q} |p_c/q_c − 1|` turns total-variation distance
//!   into a per-draw statistic. `p_c = exp(o_c)/Z` needs the unknown
//!   softmax partition `Z`, which the *same* draws estimate unbiasedly as
//!   `Ẑ = mean(exp(o_c)/q_c)`. [`QualityMonitor`] keeps a bounded
//!   reservoir (Algorithm R with a deterministic splitmix64 coin, so the
//!   Python port reproduces it bit-for-bit) of recent `(o, q)` pairs and
//!   reads the plug-in estimate out of it.
//! * **ESS** — per strided example, the effective sample size of the
//!   eq. (2) weights: `u = softmax(o − ln(m·q))`, `ESS = 1/Σu²  ∈ [1, m]`.
//!   [`ess_fraction`] reports `ESS/m`: 1.0 means the m draws carry full
//!   information (q ∝ p), → 1/m means one draw dominates (bad proposal or
//!   collapsed q).
//!
//! Both estimators are validated against the exact `util::stats`
//! implementations in the unit tests below and re-validated by the Python
//! port (`python/tools/obs_port_check.py`).

use crate::util::rng::splitmix64;

/// Effective-sample-size fraction `ESS/m ∈ (0, 1]` of one example's
/// eq. (2) importance weights. `scored` holds `(o_i, q_i)` per drawn
/// class: raw logit and proposal probability. Pairs with non-positive or
/// non-finite `q` are skipped (they indicate an upstream q-positivity
/// breach, counted separately by the sampler's own guards); returns
/// `None` when nothing valid remains.
pub fn ess_fraction(scored: &[(f64, f64)]) -> Option<f64> {
    let m = scored.len();
    if m == 0 {
        return None;
    }
    // adjusted logits a_i = o_i − ln(m·q_i), max-shifted before exp
    let mut adj = Vec::with_capacity(m);
    for &(o, q) in scored {
        if q > 0.0 && q.is_finite() && o.is_finite() {
            adj.push(o - (m as f64 * q).ln());
        }
    }
    if adj.is_empty() {
        return None;
    }
    let max_a = adj.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let mut z = 0.0f64;
    for a in adj.iter_mut() {
        *a = (*a - max_a).exp();
        z += *a;
    }
    if !(z > 0.0 && z.is_finite()) {
        return None;
    }
    let sum_sq: f64 = adj.iter().map(|&u| (u / z) * (u / z)).sum();
    Some(1.0 / sum_sq / adj.len() as f64)
}

/// Plug-in streaming TV-to-exact estimate from `(o, q)` pairs whose
/// classes were drawn from `q`: `Ẑ = mean(exp(o − M)/q)`,
/// `TV ≈ ½·mean(|w/Ẑ − 1|)`. Exact in expectation (see module docs);
/// `None` when no valid pairs or a degenerate `Ẑ`.
pub fn tv_from_pairs(pairs: &[(f64, f64)]) -> Option<f64> {
    let mut max_o = f64::NEG_INFINITY;
    for &(o, q) in pairs {
        if q > 0.0 && q.is_finite() && o.is_finite() {
            max_o = max_o.max(o);
        }
    }
    if !max_o.is_finite() {
        return None;
    }
    let mut weights = Vec::with_capacity(pairs.len());
    let mut zhat = 0.0f64;
    for &(o, q) in pairs {
        if q > 0.0 && q.is_finite() && o.is_finite() {
            let w = (o - max_o).exp() / q;
            weights.push(w);
            zhat += w;
        }
    }
    if weights.is_empty() {
        return None;
    }
    zhat /= weights.len() as f64;
    if !(zhat > 0.0 && zhat.is_finite()) {
        return None;
    }
    let dev: f64 = weights.iter().map(|&w| (w / zhat - 1.0).abs()).sum();
    Some(0.5 * dev / weights.len() as f64)
}

/// Default reservoir capacity (pairs kept for the TV estimate).
pub const DEFAULT_RESERVOIR: usize = 512;
/// Default example stride between monitor observations: one in 1024
/// examples pays the O(m·d) exact-scoring cost, keeping steady-state
/// overhead under the 3% contract (`benches/obs_overhead.rs`).
pub const DEFAULT_STRIDE: u64 = 1024;

/// Bounded reservoir of `(o, q)` pairs (Algorithm R). The replacement
/// coin is splitmix64 of the pair ordinal — deterministic given the
/// ingestion sequence, so runs and the Python port are reproducible
/// without threading an `Rng` through the sampler hot path.
pub struct QualityMonitor {
    cap: usize,
    seen_pairs: u64,
    reservoir: Vec<(f64, f64)>,
}

impl Default for QualityMonitor {
    fn default() -> Self {
        Self::new(DEFAULT_RESERVOIR)
    }
}

impl QualityMonitor {
    pub fn new(cap: usize) -> Self {
        QualityMonitor { cap: cap.max(1), seen_pairs: 0, reservoir: Vec::new() }
    }

    /// Ingest one example's scored draws into the reservoir.
    pub fn observe(&mut self, scored: &[(f64, f64)]) {
        for &(o, q) in scored {
            if !(q > 0.0 && q.is_finite() && o.is_finite()) {
                continue;
            }
            self.seen_pairs += 1;
            if self.reservoir.len() < self.cap {
                self.reservoir.push((o, q));
            } else {
                let mut s = self.seen_pairs;
                let j = splitmix64(&mut s) % self.seen_pairs;
                if let Some(slot) = self.reservoir.get_mut(j as usize) {
                    *slot = (o, q);
                }
            }
        }
    }

    /// Current TV-to-exact estimate over the reservoir.
    pub fn tv_estimate(&self) -> Option<f64> {
        tv_from_pairs(&self.reservoir)
    }

    /// Total valid pairs ever ingested.
    pub fn seen_pairs(&self) -> u64 {
        self.seen_pairs
    }

    /// Pairs currently held (≤ cap).
    pub fn len(&self) -> usize {
        self.reservoir.len()
    }

    pub fn is_empty(&self) -> bool {
        self.reservoir.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::stats::tv_distance;

    fn softmax(o: &[f64]) -> Vec<f64> {
        let m = o.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let e: Vec<f64> = o.iter().map(|&x| (x - m).exp()).collect();
        let z: f64 = e.iter().sum();
        e.iter().map(|&x| x / z).collect()
    }

    #[test]
    fn ess_full_when_q_matches_p() {
        // o_i = ln(m·q_i) ⇒ adjusted logits all zero ⇒ uniform weights
        let m = 16;
        let scored: Vec<(f64, f64)> = (0..m)
            .map(|i| {
                let q = (i + 1) as f64 / ((m * (m + 1) / 2) as f64);
                ((m as f64 * q).ln(), q)
            })
            .collect();
        let f = ess_fraction(&scored).unwrap();
        assert!((f - 1.0).abs() < 1e-12, "ess fraction {f}");
    }

    #[test]
    fn ess_collapses_under_dominant_weight() {
        let m = 32usize;
        let mut scored = vec![(0.0, 1.0 / m as f64); m];
        scored[0].0 = 50.0; // one draw dominates
        let f = ess_fraction(&scored).unwrap();
        assert!(f < 1.5 / m as f64, "ess fraction {f} should collapse toward 1/m");
    }

    #[test]
    fn ess_guards_degenerate_input() {
        assert_eq!(ess_fraction(&[]), None);
        assert_eq!(ess_fraction(&[(1.0, 0.0), (f64::NAN, 0.5)]), None);
        // invalid pairs are skipped, not fatal
        let f = ess_fraction(&[(0.0, 0.5), (0.0, 0.0)]).unwrap();
        assert!((f - 1.0).abs() < 1e-12);
    }

    #[test]
    fn tv_exact_under_uniform_proposal() {
        // q uniform ⇒ the unweighted mean over all classes IS E_{c~q},
        // so the plug-in estimate equals TV(softmax(o), uniform) exactly
        let o = [1.0, -0.5, 2.0, 0.0, -1.5, 0.25];
        let n = o.len();
        let q = vec![1.0 / n as f64; n];
        let pairs: Vec<(f64, f64)> = o.iter().map(|&oi| (oi, 1.0 / n as f64)).collect();
        let got = tv_from_pairs(&pairs).unwrap();
        let exact = tv_distance(&softmax(&o), &q);
        assert!((got - exact).abs() < 1e-12, "{got} vs {exact}");
    }

    #[test]
    fn tv_near_zero_when_proposal_is_exact() {
        let o = [1.0, -0.5, 2.0, 0.0];
        let p = softmax(&o);
        let pairs: Vec<(f64, f64)> = o.iter().zip(&p).map(|(&oi, &pi)| (oi, pi)).collect();
        let got = tv_from_pairs(&pairs).unwrap();
        assert!(got < 1e-12, "{got}");
    }

    #[test]
    fn reservoir_statistical_tv_close_to_exact() {
        // draw classes from q, stream through the monitor, compare the
        // reservoir estimate against the exact TV(p, q)
        let n = 64;
        let mut rng = Rng::new(42);
        let o: Vec<f64> = (0..n).map(|_| rng.f64() * 3.0 - 1.5).collect();
        let mut q: Vec<f64> = (0..n).map(|_| rng.f64() + 0.05).collect();
        let zq: f64 = q.iter().sum();
        q.iter_mut().for_each(|x| *x /= zq);
        let mut cum = vec![0.0f64; n];
        let mut acc = 0.0;
        for i in 0..n {
            acc += q[i];
            cum[i] = acc;
        }
        let mut mon = QualityMonitor::new(4096);
        for _ in 0..20_000 {
            let u = rng.f64() * acc;
            let c = cum.partition_point(|&x| x < u).min(n - 1);
            mon.observe(&[(o[c], q[c])]);
        }
        let est = mon.tv_estimate().unwrap();
        let exact = tv_distance(&softmax(&o), &q);
        assert!(
            (est - exact).abs() < 0.05 + 0.15 * exact,
            "reservoir TV {est} vs exact {exact}"
        );
    }

    #[test]
    fn reservoir_bounded_and_deterministic() {
        let mut a = QualityMonitor::new(8);
        let mut b = QualityMonitor::new(8);
        for i in 0..1000 {
            let pair = [(i as f64 * 0.01, 1.0 / (1.0 + i as f64))];
            a.observe(&pair);
            b.observe(&pair);
        }
        assert_eq!(a.len(), 8);
        assert_eq!(a.seen_pairs(), 1000);
        assert_eq!(a.reservoir, b.reservoir);
    }
}
