// pallas-lint REG fixture: the help footer iterates the registry.

fn main() {
    for info in sampler::SAMPLER_REGISTRY {
        println!("  {:<18} {}", info.name, info.summary);
    }
}
