//! Unigram (global popularity) sampling, `q_i ∝ count(i)` — the common NLP
//! baseline included in the paper's Penn-Tree-Bank figures.
//!
//! Static: built once from corpus counts, O(1) per draw via Walker's alias
//! method. Add-one smoothing keeps every class reachable (a class with
//! q_i = 0 could never be corrected by eq. (2) and would make the estimator
//! blow up if it appeared as a negative elsewhere) — it is also what makes
//! the sampler layer's q-positivity invariant hold unconditionally here:
//! every reported q is at least 1/(Σ counts + n). Batch draws go through
//! the default [`Sampler::sample_batch`] fan-out.

use super::{Needs, Sample, SampleInput, Sampler};
use crate::util::rng::{AliasTable, Rng};
use anyhow::{Context, Result};

/// `q_i ∝ count_i + 1`, sampled through an alias table.
pub struct UnigramSampler {
    alias: AliasTable,
}

impl UnigramSampler {
    pub fn new(class_counts: &[u64]) -> Result<UnigramSampler> {
        let weights: Vec<f64> = class_counts.iter().map(|&c| c as f64 + 1.0).collect();
        let alias = AliasTable::new(&weights).context("degenerate unigram counts")?;
        Ok(UnigramSampler { alias })
    }
}

impl Sampler for UnigramSampler {
    fn name(&self) -> &str {
        "unigram"
    }

    fn needs(&self) -> Needs {
        Needs::default()
    }

    fn sample(&self, _input: &SampleInput, m: usize, rng: &mut Rng, out: &mut Sample) -> Result<()> {
        out.clear();
        for _ in 0..m {
            let c = self.alias.sample(rng);
            out.push(c as u32, self.alias.prob_of(c));
        }
        Ok(())
    }

    fn prob(&self, _input: &SampleInput, class: u32) -> Option<f64> {
        ((class as usize) < self.alias.len()).then(|| self.alias.prob_of(class as usize))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::test_util::empirical_tv;

    #[test]
    fn matches_count_distribution() {
        let counts = vec![99u64, 9, 49, 0, 19]; // +1 smoothing => 100,10,50,1,20
        let s = UnigramSampler::new(&counts).unwrap();
        let total = 181.0;
        let expected: Vec<f64> = [100.0, 10.0, 50.0, 1.0, 20.0].iter().map(|w| w / total).collect();
        for (i, &e) in expected.iter().enumerate() {
            assert!((s.prob(&SampleInput::default(), i as u32).unwrap() - e).abs() < 1e-12);
        }
        let tv = empirical_tv(&s, &SampleInput::default(), &expected, 200_000, 3);
        assert!(tv < 0.02, "tv {tv}");
    }

    #[test]
    fn zero_count_class_still_reachable() {
        let s = UnigramSampler::new(&[1000, 0]).unwrap();
        let q1 = s.prob(&SampleInput::default(), 1).unwrap();
        assert!(q1 > 0.0, "smoothing must keep q positive");
    }
}
