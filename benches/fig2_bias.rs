//! Figure 2 — **final model quality vs sample size m per sampling
//! distribution** (the paper's headline result).
//!
//! For each dataset, train every (sampler, m) cell to the epoch budget and
//! report the final full-softmax eval loss, plus the full-softmax reference
//! line. The paper's claim to reproduce: the quadratic kernel reaches
//! full-softmax quality with one to two orders of magnitude fewer samples
//! than uniform, and softmax sampling's quality is independent of m. The
//! `rff` rows add the random-feature exp-kernel family (D = 4d), expected
//! to land between quadratic and the exact-softmax line; see
//! `ablation_rff_dim` for the D sweep.
//!
//! `cargo bench --bench fig2_bias` (quick: tiny models) or
//! `KSS_BENCH_SCALE=full cargo bench --bench fig2_bias` (paper scale:
//! synthetic-PTB 10k + YouTube 10k/100k; hours).

use kss::bench_harness::{engine_or_exit, scale, Scale};
use kss::coordinator::experiment::{bias_table, run_grid, summaries_to_json, GridSpec};
use kss::coordinator::TrainConfig;

fn main() -> anyhow::Result<()> {
    kss::util::logging::init_from_env();
    let engine = engine_or_exit();
    // (dataset label, model, samplers, ms, base config)
    let cells: Vec<(&str, GridSpec)> = match scale() {
        Scale::Quick => vec![
            (
                "tiny recsys (128 classes)",
                GridSpec {
                    base: TrainConfig {
                        model: "tiny".into(),
                        epochs: 3,
                        train_size: 1_280,
                        valid_size: 320,
                        eval_batches: 10,
                        ..Default::default()
                    },
                    samplers: vec![
                        "uniform".into(),
                        "quadratic".into(),
                        "rff".into(),
                        "softmax".into(),
                    ],
                    ms: vec![4, 8],
                    include_full: true,
                },
            ),
            (
                "tiny LM (120 classes)",
                GridSpec {
                    base: TrainConfig {
                        model: "tiny-lm".into(),
                        epochs: 2,
                        train_size: 6_000,
                        valid_size: 1_200,
                        eval_batches: 8,
                        ..Default::default()
                    },
                    samplers: vec![
                        "uniform".into(),
                        "unigram".into(),
                        "bigram".into(),
                        "quadratic".into(),
                        "quartic".into(),
                        "rff".into(),
                        "softmax".into(),
                    ],
                    ms: vec![4],
                    include_full: true,
                },
            ),
        ],
        Scale::Full => {
            let ms = vec![8, 16, 32, 64, 128, 256];
            vec![
                (
                    "synthetic PTB (10k vocab)",
                    GridSpec {
                        base: TrainConfig {
                            model: "ptb".into(),
                            epochs: 2,
                            train_size: 160_000,
                            valid_size: 30_000,
                            eval_batches: 10,
                            ..Default::default()
                        },
                        samplers: vec![
                            "uniform".into(),
                            "unigram".into(),
                            "bigram".into(),
                            "quadratic".into(),
                            "quartic".into(),
                            "rff".into(),
                            "softmax".into(),
                        ],
                        ms: ms.clone(),
                        include_full: true,
                    },
                ),
                (
                    "YouTube10k",
                    GridSpec {
                        base: TrainConfig {
                            model: "yt10k".into(),
                            epochs: 2,
                            train_size: 50_000,
                            valid_size: 6_400,
                            eval_batches: 10,
                            ..Default::default()
                        },
                        samplers: vec![
                            "uniform".into(),
                            "quadratic".into(),
                            "rff".into(),
                            "softmax".into(),
                        ],
                        ms: ms.clone(),
                        include_full: true,
                    },
                ),
                (
                    "YouTube100k",
                    GridSpec {
                        base: TrainConfig {
                            model: "yt100k".into(),
                            epochs: 1,
                            train_size: 50_000,
                            valid_size: 6_400,
                            eval_batches: 10,
                            ..Default::default()
                        },
                        samplers: vec!["uniform".into(), "quadratic".into(), "softmax".into()],
                        ms: ms.clone(),
                        include_full: true,
                    },
                ),
            ]
        }
    };

    for (label, grid) in cells {
        println!("\n==== Figure 2 — {label} ====");
        let out = std::path::PathBuf::from("runs/fig2");
        let summaries = run_grid(&engine, &grid, Some(&out))?;
        println!("\nfinal full-softmax eval loss vs m:");
        print!("{}", bias_table(&summaries, &grid.ms));
        // machine-readable dump for EXPERIMENTS.md
        std::fs::create_dir_all("runs/fig2")?;
        let fname = format!("runs/fig2/{}.json", grid.base.model);
        std::fs::write(&fname, summaries_to_json(&summaries).to_string_pretty())?;
        println!("(wrote {fname})");
    }
    println!("\nshape to check: quadratic reaches the full-softmax line at much");
    println!("smaller m than uniform; softmax row is flat in m.");
    Ok(())
}
