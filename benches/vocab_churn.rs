//! Streaming-vocabulary acceptance bench: what does class churn cost?
//!
//! Measures, on a [`StreamingKernelSampler`] (quadratic kernel):
//!
//! * **insert / retire throughput** — the memtable/tombstone fast path,
//!   with compaction left on its default policy so the folds it triggers
//!   are charged to the ops that caused them (the production amortization);
//! * **draw latency vs memtable fill** — the two-tier router's overhead as
//!   the mutable tier grows (compaction disabled so the fill level holds);
//! * **compaction vs per-op rebuild at 1% churn** — the LSM claim: absorb
//!   `n/100` interleaved insert/retire ops through the memtable and fold
//!   once, vs rebuilding the kernel tree after every op (the only way a
//!   snapshot-only sampler could stay exact). Acceptance: ≥5x cheaper.
//!
//! No artifacts needed (pure L3). `cargo bench --bench vocab_churn`.

use kss::bench_harness::{print_table, scale, write_json, BenchRow, Scale};
use kss::sampler::kernel::QuadraticMap;
use kss::sampler::{Sample, SampleInput, Sampler};
use kss::util::rng::Rng;
use kss::util::stats::Samples;
use kss::vocab::{CompactionPolicy, StreamingKernelSampler};
use std::time::Instant;

fn seeded_sampler(n: usize, d: usize, rng: &mut Rng) -> StreamingKernelSampler<QuadraticMap> {
    let mut s = StreamingKernelSampler::new(QuadraticMap::new(d, 100.0), n, None);
    let mut emb = vec![0.0f32; n * d];
    rng.fill_normal(&mut emb, 0.3);
    s.reset_embeddings(&emb, n, d);
    s
}

/// Time `ops` inserts (and optionally interleaved retires) under the
/// default compaction policy, so the amortized fold cost is included.
fn churn_throughput(n: usize, d: usize, ops: usize, retire: bool) -> (f64, usize) {
    let mut rng = Rng::new(0xC0DE);
    let mut sampler = seeded_sampler(n, d, &mut rng);
    let mut row = vec![0.0f32; d];
    let mut live: Vec<u32> = (0..n as u32).collect();
    let before = sampler.obs().compactions();
    let t0 = Instant::now();
    for i in 0..ops {
        if retire && i % 2 == 1 {
            let idx = rng.below(live.len() as u64) as usize;
            let id = live.swap_remove(idx);
            assert!(sampler.retire_class(id), "retire of live id {id} refused");
        } else {
            rng.fill_normal(&mut row, 0.3);
            live.push(sampler.insert_class(&row));
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    (wall, (sampler.obs().compactions() - before) as usize)
}

/// Per-draw latency with the memtable pinned at `fill` entries
/// (compaction disabled so the fill level cannot collapse mid-run).
fn draw_latency_at_fill(n: usize, d: usize, m: usize, fill: usize, draws: usize) -> Samples {
    let mut rng = Rng::new(0xF111 ^ fill as u64);
    let mut sampler =
        seeded_sampler(n, d, &mut rng).with_policy(CompactionPolicy::manual());
    let mut row = vec![0.0f32; d];
    for _ in 0..fill {
        rng.fill_normal(&mut row, 0.3);
        sampler.insert_class(&row);
    }
    assert_eq!(sampler.memtable_len(), fill, "manual policy must hold the fill level");
    let mut h = vec![0.0f32; d];
    let mut out = Sample::with_capacity(m);
    let mut lat = Samples::new();
    for _ in 0..draws {
        rng.fill_normal(&mut h, 1.0);
        let input = SampleInput { h: Some(&h), ..Default::default() };
        let t0 = Instant::now();
        out.clear();
        sampler.sample(&input, m, &mut rng, &mut out).expect("draw failed");
        lat.push(t0.elapsed().as_secs_f64());
        std::hint::black_box(&out);
    }
    lat
}

/// The LSM comparison at ~1% churn: streaming (memtable absorbs every op,
/// one fold at the end) vs rebuilding the tree after every op.
struct LsmResult {
    churn_ops: usize,
    streaming_s: f64,
    compact_s: f64,
    rebuild_per_op_s: f64,
    speedup: f64,
}

fn lsm_vs_rebuild(n: usize, d: usize) -> LsmResult {
    let churn_ops = (n / 100).max(8);
    let mut rng = Rng::new(0x15A4);
    let mut sampler =
        seeded_sampler(n, d, &mut rng).with_policy(CompactionPolicy::manual());
    let mut row = vec![0.0f32; d];
    let mut live: Vec<u32> = (0..n as u32).collect();
    let t0 = Instant::now();
    for i in 0..churn_ops {
        if i % 2 == 1 {
            let idx = rng.below(live.len() as u64) as usize;
            let id = live.swap_remove(idx);
            assert!(sampler.retire_class(id));
        } else {
            rng.fill_normal(&mut row, 0.3);
            live.push(sampler.insert_class(&row));
        }
    }
    let t_compact = Instant::now();
    sampler.compact();
    let compact_s = t_compact.elapsed().as_secs_f64();
    let streaming_s = t0.elapsed().as_secs_f64();

    // Rebuild baseline: a from-scratch tree over the live set, which is
    // what each churn op would cost without the memtable. Median of 3.
    let (ids, rows) = sampler.live_classes();
    let mut builds = Samples::new();
    for _ in 0..3 {
        let t = Instant::now();
        let mut fresh =
            StreamingKernelSampler::new(QuadraticMap::new(d, 100.0), ids.len(), None);
        fresh.reset_embeddings(&rows, ids.len(), d);
        std::hint::black_box(&fresh);
        builds.push(t.elapsed().as_secs_f64());
    }
    let rebuild_per_op_s = builds.p50();
    LsmResult {
        churn_ops,
        streaming_s,
        compact_s,
        rebuild_per_op_s,
        speedup: rebuild_per_op_s * churn_ops as f64 / streaming_s,
    }
}

fn main() {
    let (n, d, m, ops, draws) = match scale() {
        Scale::Quick => (20_000usize, 16usize, 8usize, 4_000usize, 2_000usize),
        Scale::Full => (100_000, 32, 16, 20_000, 10_000),
    };
    println!("vocab churn bench: {n} classes × d={d}, m={m}");

    let mut churn_rows: Vec<BenchRow> = Vec::new();
    let (wall, folds) = churn_throughput(n, d, ops, false);
    println!("insert-only: {ops} ops in {wall:.3}s ({folds} compactions amortized in)");
    churn_rows.push(BenchRow {
        name: format!("insert x{ops} (default policy)"),
        mean_s: wall / ops as f64,
        p50_s: wall / ops as f64,
        p95_s: wall / ops as f64,
        iters: ops,
        items_per_iter: Some(1.0),
    });
    let (wall, folds) = churn_throughput(n, d, ops, true);
    println!("insert+retire: {ops} ops in {wall:.3}s ({folds} compactions amortized in)");
    churn_rows.push(BenchRow {
        name: format!("insert/retire x{ops} (default policy)"),
        mean_s: wall / ops as f64,
        p50_s: wall / ops as f64,
        p95_s: wall / ops as f64,
        iters: ops,
        items_per_iter: Some(1.0),
    });

    let mut draw_rows: Vec<BenchRow> = Vec::new();
    for &fill in &[0usize, 64, 256, 1024] {
        let lat = draw_latency_at_fill(n, d, m, fill, draws);
        draw_rows.push(BenchRow {
            name: format!("draw m={m} (memtable fill={fill})"),
            mean_s: lat.mean(),
            p50_s: lat.p50(),
            p95_s: lat.p95(),
            iters: draws,
            items_per_iter: Some(m as f64),
        });
    }

    let lsm = lsm_vs_rebuild(n, d);
    let lsm_rows = vec![
        BenchRow {
            name: format!("streaming: {} churn ops + 1 fold", lsm.churn_ops),
            mean_s: lsm.streaming_s,
            p50_s: lsm.streaming_s,
            p95_s: lsm.streaming_s,
            iters: 1,
            items_per_iter: Some(lsm.churn_ops as f64),
        },
        BenchRow {
            name: "  of which: the single compaction".to_string(),
            mean_s: lsm.compact_s,
            p50_s: lsm.compact_s,
            p95_s: lsm.compact_s,
            iters: 1,
            items_per_iter: None,
        },
        BenchRow {
            name: format!("rebuild-per-op: {} x tree build", lsm.churn_ops),
            mean_s: lsm.rebuild_per_op_s * lsm.churn_ops as f64,
            p50_s: lsm.rebuild_per_op_s * lsm.churn_ops as f64,
            p95_s: lsm.rebuild_per_op_s * lsm.churn_ops as f64,
            iters: 1,
            items_per_iter: Some(lsm.churn_ops as f64),
        },
    ];

    print_table("churn op throughput (amortized, default compaction policy)", &churn_rows);
    print_table("draw latency vs memtable fill", &draw_rows);
    print_table("1% churn: LSM streaming vs rebuild-per-op", &lsm_rows);

    println!(
        "\nLSM speedup at 1% churn: {:.1}x (streaming {:.4}s vs {:.4}s rebuilding per op; \
         one tree build = {:.4}s)",
        lsm.speedup,
        lsm.streaming_s,
        lsm.rebuild_per_op_s * lsm.churn_ops as f64,
        lsm.rebuild_per_op_s
    );
    assert!(
        lsm.speedup >= 5.0,
        "LSM amortization regressed: only {:.1}x cheaper than rebuild-per-op (need >= 5x)",
        lsm.speedup
    );
    println!("(acceptance: >= 5x — passed)");

    write_json(
        "vocab",
        &[
            ("churn throughput", &churn_rows),
            ("draw latency vs fill", &draw_rows),
            ("lsm vs rebuild", &lsm_rows),
        ],
    );
}
