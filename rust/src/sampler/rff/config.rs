//! Configuration and deterministic feature draws for [`PositiveRffMap`].
//!
//! The config *is* the kernel identity: two maps built from equal configs
//! realize bit-identical `ω` matrices and therefore the same random kernel
//! `K̂`. That is the shard-consistency contract — `build_sampler`,
//! `ShardSet`, and snapshot replay never serialize `ω`, they re-derive or
//! clone it — so the seed must never be taken from ambient entropy.
//!
//! [`PositiveRffMap`]: super::PositiveRffMap

use crate::util::rng::Rng;

/// Seed used by `build_sampler` for the registered `"rff"` family, fixed so
/// a sampler named in a config reproduces from `(config, seed)` alone on
/// any machine — the same rule that pins the shard count there.
pub const RFF_BUILD_SEED: u64 = 0x52FF_5EED_0001;

/// Configuration of a positive random feature map (dimension, seed,
/// variant). Equal configs ⇒ identical `ω` ⇒ identical kernel.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RffConfig {
    /// Input (embedding) dimension d.
    pub d: usize,
    /// Feature dimension D — the bias/variance knob. Typical sweet spot:
    /// `4·d` (see `benches/ablation_rff_dim.rs`); `d²` matches the
    /// quadratic map's memory footprint.
    pub dim: usize,
    /// Seed of the `ω` draw. All randomness of the map flows from here.
    pub seed: u64,
    /// Blockwise-orthogonalized `ω` (structured orthogonal random
    /// features) instead of iid Gaussian rows: same marginal distribution,
    /// lower kernel-estimate variance at equal D.
    pub orthogonal: bool,
}

impl RffConfig {
    /// Config with the default `D = 4d` and iid rows.
    pub fn new(d: usize, seed: u64) -> RffConfig {
        RffConfig { d, dim: Self::default_dim(d), seed, orthogonal: false }
    }

    /// The registry default `D = 4d`: comfortably below the quadratic
    /// map's `d² + 1` once `d > 4`, with empirical bias already well under
    /// quadratic's on peaked rows (the acceptance property in
    /// `rff/tests.rs` pins this).
    pub fn default_dim(d: usize) -> usize {
        4 * d.max(1)
    }

    /// Override the feature dimension D.
    pub fn with_dim(mut self, dim: usize) -> RffConfig {
        assert!(dim > 0, "RFF feature dimension must be positive");
        self.dim = dim;
        self
    }

    /// Select the structured-orthogonal `ω` variant.
    pub fn with_orthogonal(mut self, orthogonal: bool) -> RffConfig {
        self.orthogonal = orthogonal;
        self
    }

    /// Draw the frequency matrix `ω` (D × d, row-major, f64) this config
    /// describes. Pure function of the config — the determinism contract.
    pub fn draw_omega(&self) -> Vec<f64> {
        assert!(self.d > 0 && self.dim > 0);
        let mut rng = Rng::new(self.seed ^ 0x52FF_0_u64.wrapping_mul(0x9E3779B97F4A7C15));
        if self.orthogonal {
            super::orthogonal::draw_orthogonal_omega(&mut rng, self.dim, self.d)
        } else {
            (0..self.dim * self.d).map(|_| rng.normal()).collect()
        }
    }
}
