//! §2.3 / Theorem 2.1 — **gradient bias of the sampled-softmax estimator**,
//! measured by Monte Carlo against the exact full-softmax gradient.
//!
//! The quantitative backbone of Figure 2: softmax sampling is unbiased at
//! every m (only MC noise remains); uniform/quadratic/quartic are biased
//! with bias ↓ as m ↑; the quadratic kernel's bias sits well below
//! uniform's at equal m.
//!
//! No artifacts needed. `cargo bench --bench gradient_bias`.

use kss::bench_harness::{scale, Scale};
use kss::sampler::{
    FlatKernelSampler, KernelKind, KernelTreeSampler, QuadraticMap, Sample, SampleInput, Sampler,
    SoftmaxSampler, UniformSampler,
};
use kss::util::rng::Rng;

fn main() {
    let (n, d, trials) = match scale() {
        Scale::Quick => (200usize, 16usize, 20_000usize),
        Scale::Full => (2_000, 32, 100_000),
    };
    let ms = [2usize, 8, 32, 128];
    let mut rng = Rng::new(11);
    let mut w = vec![0.0f32; n * d];
    rng.fill_normal(&mut w, 0.5);
    let h: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let logits: Vec<f32> = (0..n)
        .map(|j| w[j * d..(j + 1) * d].iter().zip(&h).map(|(&a, &b)| a * b).sum())
        .collect();
    let positive = 3u32;
    let p = softmax(&logits);
    let mut full_grad = p.clone();
    full_grad[positive as usize] -= 1.0;

    let mut tree = KernelTreeSampler::new(QuadraticMap::new(d, 100.0), n, None);
    tree.reset_embeddings(&w, n, d);
    let samplers: Vec<Box<dyn Sampler>> = vec![
        Box::new(UniformSampler::new(n)),
        Box::new(FlatKernelSampler::new(KernelKind::Quadratic { alpha: 100.0 })),
        Box::new(tree),
        Box::new(FlatKernelSampler::new(KernelKind::Quartic)),
        Box::new(SoftmaxSampler::new(n, false)),
    ];

    println!("gradient bias ‖E[ĝ] − (p − y)‖₁  ({n} classes, {trials} trials/cell)\n");
    print!("{:<18}", "sampler");
    for m in ms {
        print!(" {:>9}", format!("m={m}"));
    }
    println!();
    let mut table: Vec<(String, Vec<f64>)> = Vec::new();
    for sampler in &samplers {
        print!("{:<18}", sampler.name());
        let mut row = Vec::new();
        for m in ms {
            let bias = measure_bias(sampler.as_ref(), &h, &logits, positive, &full_grad, m, trials, &mut rng);
            print!(" {:>9.4}", bias);
            row.push(bias);
        }
        println!();
        table.push((sampler.name().to_string(), row));
    }

    // assertions on the paper's shape (soft: print PASS/FAIL, don't panic)
    let find = |name: &str| table.iter().find(|(n, _)| n == name).map(|(_, r)| r.clone()).unwrap();
    let uni = find("uniform");
    let quad = find("quadratic");
    let soft = find("softmax");
    let check = |label: &str, ok: bool| println!("  [{}] {label}", if ok { "PASS" } else { "FAIL" });
    println!("\nshape checks:");
    check("softmax bias ≈ MC noise (< uniform at every m)", soft.iter().zip(&uni).all(|(s, u)| s < u));
    check("quadratic < uniform at every m", quad.iter().zip(&uni).all(|(q, u)| q < u));
    check("uniform bias decreases with m", uni.windows(2).all(|w| w[1] < w[0]));
    check("quadratic bias decreases with m", quad.windows(2).all(|w| w[1] < w[0]));
}

fn softmax(o: &[f32]) -> Vec<f64> {
    let mx = o.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let e: Vec<f64> = o.iter().map(|&x| (x as f64 - mx).exp()).collect();
    let z: f64 = e.iter().sum();
    e.into_iter().map(|x| x / z).collect()
}

#[allow(clippy::too_many_arguments)]
fn measure_bias(
    sampler: &dyn Sampler,
    h: &[f32],
    logits: &[f32],
    positive: u32,
    full_grad: &[f64],
    m: usize,
    trials: usize,
    rng: &mut Rng,
) -> f64 {
    let n = logits.len();
    let input = SampleInput { h: Some(h), logits: Some(logits), prev: None };
    let mut acc = vec![0.0f64; n];
    let mut out = Sample::default();
    for _ in 0..trials {
        sampler.sample(&input, m, rng, &mut out).expect("sample");
        let mut adj = Vec::with_capacity(m + 1);
        adj.push(logits[positive as usize] as f64);
        for (&c, &q) in out.classes.iter().zip(&out.q) {
            adj.push(logits[c as usize] as f64 - (m as f64 * q).ln());
        }
        let mx = adj.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let e: Vec<f64> = adj.iter().map(|&x| (x - mx).exp()).collect();
        let z: f64 = e.iter().sum();
        acc[positive as usize] += e[0] / z - 1.0;
        for (k, &c) in out.classes.iter().enumerate() {
            acc[c as usize] += e[k + 1] / z;
        }
    }
    acc.iter().zip(full_grad).map(|(a, g)| (a / trials as f64 - g).abs()).sum()
}
