"""Scan driver: file discovery, rule execution, baseline, ANALYSIS.json.

The baseline (waiver) workflow: `baseline.json` holds fingerprints of
accepted findings with a written reason each. A finding whose
fingerprint appears there is *waived* — reported in ANALYSIS.json but
not counted against the build; anything else is *new* and fails CI.
Fingerprints hash rule + file + the whitespace-normalized source line +
an ordinal (for repeated identical lines), so they survive unrelated
edits that shift line numbers, and die with the code they describe —
a stale waiver is reported so it can be pruned.
"""

from __future__ import annotations

import hashlib
import json
import os
from typing import Optional

from pallas_lint import __version__
from pallas_lint.frontend import SourceFile, normalize
from pallas_lint.rules import Finding, ProjectRule, all_rules

# directories searched for .rs sources, relative to the repo root
SCAN_ROOTS = ("rust", "benches", "examples", "vendor")

LEX_RULE = {
    "id": "LEX",
    "name": "lexical-balance",
    "summary": "delimiter balance / unterminated literals (ex-lexcheck)",
    "contract": "every tracked .rs file lexes cleanly (tier-0 sanity)",
}


def discover(root: str) -> list:
    """Repo-relative forward-slash paths of every .rs file under
    SCAN_ROOTS, sorted."""
    out = []
    for top in SCAN_ROOTS:
        base = os.path.join(root, top)
        if not os.path.isdir(base):
            continue
        for dirpath, dirnames, filenames in os.walk(base):
            dirnames[:] = [d for d in dirnames if d not in (".git", "target")]
            for fn in filenames:
                if fn.endswith(".rs"):
                    rel = os.path.relpath(os.path.join(dirpath, fn), root)
                    out.append(rel.replace(os.sep, "/"))
    return sorted(out)


def load_files(root: str, relpaths: list) -> dict:
    files = {}
    for rel in relpaths:
        with open(os.path.join(root, rel), "r", encoding="utf-8") as f:
            files[rel] = SourceFile(rel, f.read())
    return files


def fingerprint(f: Finding, ordinal: int) -> str:
    key = f"{f.rule}|{f.file}|{normalize(f.snippet)}|{ordinal}"
    return hashlib.sha1(key.encode("utf-8")).hexdigest()[:16]


def assign_fingerprints(findings: list) -> list:
    """Stable fingerprints: ordinal disambiguates identical (rule, file,
    normalized-line) triples in source order."""
    counts: dict = {}
    out = []
    for f in sorted(findings, key=lambda f: (f.file, f.line, f.rule, f.message)):
        key = (f.rule, f.file, normalize(f.snippet))
        ordinal = counts.get(key, 0)
        counts[key] = ordinal + 1
        out.append((f, fingerprint(f, ordinal)))
    return out


def load_baseline(path: str) -> dict:
    """fingerprint -> waiver entry. Missing file = empty baseline."""
    if not os.path.exists(path):
        return {}
    with open(path, "r", encoding="utf-8") as f:
        data = json.load(f)
    return {w["fingerprint"]: w for w in data.get("waivers", [])}


def write_baseline(path: str, fingerprinted: list) -> None:
    waivers = [
        {
            "fingerprint": fp,
            "rule": f.rule,
            "file": f.file,
            "line": f.line,
            "snippet": f.snippet,
            "reason": "TODO: justify or fix",
        }
        for f, fp in fingerprinted
    ]
    with open(path, "w", encoding="utf-8") as out:
        json.dump({"version": 1, "waivers": waivers}, out, indent=2)
        out.write("\n")


def run(
    root: str,
    baseline_path: Optional[str] = None,
    rule_filter: Optional[set] = None,
) -> dict:
    """Run every rule; return the ANALYSIS report dict.

    Report keys: files, rules, findings (each with fingerprint + waived
    flag + reason), new_count, waived_count, stale_waivers.
    """
    relpaths = discover(root)
    files = load_files(root, relpaths)
    rules = all_rules()
    if rule_filter:
        rules = [r for r in rules if r.id in rule_filter]

    findings: list = []

    # LEX pseudo-rule: balance errors from the shared tokenizer
    if rule_filter is None or "LEX" in rule_filter:
        for sf in files.values():
            for err in sf.balance:
                # "path:line: message"
                try:
                    _, line_s, msg = err.split(":", 2)
                    line = int(line_s)
                except ValueError:
                    line, msg = 1, err
                findings.append(
                    Finding(
                        rule="LEX",
                        file=sf.path,
                        line=line,
                        message=msg.strip(),
                        snippet=sf.line_text(line).strip()[:160],
                    )
                )

    extra: dict = {}
    for r in rules:
        if isinstance(r, ProjectRule):
            for rel in r.extra_files:
                p = os.path.join(root, rel)
                if rel not in extra and os.path.exists(p):
                    with open(p, "r", encoding="utf-8") as f:
                        extra[rel] = f.read()

    for r in rules:
        if isinstance(r, ProjectRule):
            findings.extend(r.check_project(files, extra))
        else:
            for sf in files.values():
                if r.applies(sf.path):
                    findings.extend(r.check(sf))

    fingerprinted = assign_fingerprints(findings)
    baseline = (
        load_baseline(baseline_path) if baseline_path else {}
    )

    seen_fps = set()
    items = []
    for f, fp in fingerprinted:
        seen_fps.add(fp)
        waiver = baseline.get(fp)
        items.append(
            {
                "rule": f.rule,
                "file": f.file,
                "line": f.line,
                "message": f.message,
                "snippet": f.snippet,
                "fingerprint": fp,
                "waived": waiver is not None,
                "reason": waiver.get("reason") if waiver else None,
            }
        )
    stale = [w for fp, w in sorted(baseline.items()) if fp not in seen_fps]

    rule_meta = [LEX_RULE] + [
        {"id": r.id, "name": r.name, "summary": r.summary, "contract": r.contract}
        for r in all_rules()
    ]
    report = {
        "tool": "pallas-lint",
        "version": __version__,
        "files_scanned": len(files),
        "rules": rule_meta,
        "findings": items,
        "new_count": sum(1 for it in items if not it["waived"]),
        "waived_count": sum(1 for it in items if it["waived"]),
        "stale_waivers": stale,
    }
    report["_fingerprinted"] = fingerprinted  # internal, stripped before dump
    return report
