"""Pallas kernel: fused sampled-softmax loss and gradient (the hot spot).

This is Layer 1 of the stack. The sampled-softmax step (eqs. 2-3 of the
paper) evaluates, for each of N training positions, the ``S = m + 1`` logits
of the positive + sampled negative classes, corrects them by ``ln(m q)``,
and takes a cross-entropy over the sample. The kernel fuses:

  gather-free contraction  o[n,s] = ⟨h[n], ws[n,s]⟩        (MXU-friendly)
  correction               o'     = |o|? - sub              (eq. 2 / eq. 11)
  stable log-softmax CE    loss   = lse(o') - o'[:,0]       (eq. 3)
  gradient seed            g      = (p' - y') * d|o|/do     (eq. 5)

in one VMEM-resident pass per block of rows, and a second kernel applies the
chain rule to produce dh and dws without materializing anything but the
(bn, S) gradient block.

TPU adaptation (DESIGN.md §6): rows are tiled by ``block_n``; one grid step
holds ``(bn, S, d)`` class embeddings + ``(bn, d)`` queries in VMEM
(≈ bn·S·d·4 bytes; 8.4 KB/row-block at the default S=33, d=64 config) and
feeds the ``(S,d)×(d,)`` contractions to the MXU. ``interpret=True`` is
required on this CPU-PJRT testbed — the kernel then lowers to plain HLO with
identical numerics (validated against ``ref.py`` by pytest).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def pick_block(n: int, target: int = 128) -> int:
    """Largest divisor of ``n`` that is <= target (grid must tile N exactly)."""
    if n <= target:
        return max(n, 1)
    for b in range(target, 0, -1):
        if n % b == 0:
            return b
    return 1


# ---------------------------------------------------------------------------
# forward kernel
# ---------------------------------------------------------------------------


def _fwd_kernel(h_ref, ws_ref, sub_ref, loss_ref, g_ref, sign_ref, *, abs_logits):
    h = h_ref[...]  # (bn, d)
    ws = ws_ref[...]  # (bn, S, d)
    sub = sub_ref[...]  # (bn, S)
    # One fused contraction: o[n, s] = <h[n], ws[n, s]>.
    o = jnp.einsum("nsd,nd->ns", ws, h, preferred_element_type=jnp.float32)
    if abs_logits:
        sign = jnp.sign(o)
        o = jnp.abs(o)
    else:
        sign = jnp.ones_like(o)
    adj = o - sub  # eq. (2)
    m = jnp.max(adj, axis=-1, keepdims=True)
    e = jnp.exp(adj - m)
    z = jnp.sum(e, axis=-1, keepdims=True)
    # loss = lse - adj[:, 0]  (cross entropy against the positive at col 0)
    loss_ref[...] = (m[:, 0] + jnp.log(z[:, 0]) - adj[:, 0]).astype(loss_ref.dtype)
    p = e / z
    # g = p' - y', the eq. (5) gradient seed w.r.t. the *adjusted* logits;
    # sign folds the |o| chain-rule factor for the raw logits.
    g = p.at[:, 0].add(-1.0)
    g_ref[...] = g.astype(g_ref.dtype)
    sign_ref[...] = sign.astype(sign_ref.dtype)


def _fwd_pallas(h, ws, sub, abs_logits, block_n):
    n, d = h.shape
    s = ws.shape[1]
    bn = block_n or pick_block(n)
    assert n % bn == 0, f"N={n} not divisible by block_n={bn}"
    kernel = functools.partial(_fwd_kernel, abs_logits=abs_logits)
    return pl.pallas_call(
        kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((bn, s, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((bn, s), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn, s), lambda i: (i, 0)),
            pl.BlockSpec((bn, s), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), h.dtype),
            jax.ShapeDtypeStruct((n, s), h.dtype),
            jax.ShapeDtypeStruct((n, s), h.dtype),
        ],
        interpret=True,  # CPU-PJRT target; Mosaic lowering is TPU-only
    )(h, ws, sub)


# ---------------------------------------------------------------------------
# backward kernel
# ---------------------------------------------------------------------------


def _bwd_kernel(tg_ref, h_ref, ws_ref, dh_ref, dws_ref):
    # tg = t[:, None] * g * sign — the cotangent w.r.t. the raw logits.
    tg = tg_ref[...]  # (bn, S)
    h = h_ref[...]  # (bn, d)
    ws = ws_ref[...]  # (bn, S, d)
    # dh[n] = sum_s tg[n, s] * ws[n, s];  dws[n, s] = tg[n, s] * h[n]
    dh_ref[...] = jnp.einsum("ns,nsd->nd", tg, ws, preferred_element_type=jnp.float32).astype(
        dh_ref.dtype
    )
    dws_ref[...] = (tg[..., None] * h[:, None, :]).astype(dws_ref.dtype)


def _bwd_pallas(tg, h, ws, block_n):
    n, d = h.shape
    s = ws.shape[1]
    bn = block_n or pick_block(n)
    return pl.pallas_call(
        _bwd_kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, s), lambda i: (i, 0)),
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((bn, s, d), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((bn, s, d), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, d), h.dtype),
            jax.ShapeDtypeStruct((n, s, d), ws.dtype),
        ],
        interpret=True,
    )(tg, h, ws)


# ---------------------------------------------------------------------------
# public custom-vjp entry point
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def sampled_softmax_loss(h, ws, sub, abs_logits=False, block_n=None):
    """Per-example sampled-softmax CE loss (eqs. 2-3). See module docstring.

    Args:
      h: (N, d) query embeddings.
      ws: (N, S, d) sampled-class embeddings, positive at column 0.
      sub: (N, S) ``ln(m q)`` corrections (column 0 must be 0).
      abs_logits: eq. (11) absolute-softmax prediction distribution.
      block_n: row-block override (None = auto).

    Returns: (N,) losses. Differentiable in h, ws and sub.
    """
    loss, _, _ = _fwd_pallas(h, ws, sub, abs_logits, block_n)
    return loss


def _vjp_fwd(h, ws, sub, abs_logits, block_n):
    loss, g, sign = _fwd_pallas(h, ws, sub, abs_logits, block_n)
    return loss, (g, sign, h, ws)


def _vjp_bwd(abs_logits, block_n, res, t):
    g, sign, h, ws = res
    # Cotangent w.r.t. raw logits; t is the (N,) cotangent of the loss.
    tg = (t[:, None] * g * sign).astype(h.dtype)
    dh, dws = _bwd_pallas(tg, h, ws, block_n)
    dsub = (-(t[:, None] * g)).astype(ws.dtype)  # d loss / d sub = -g
    return dh, dws, dsub


sampled_softmax_loss.defvjp(_vjp_fwd, _vjp_bwd)
