//! PJRT engine: compile HLO-text artifacts (lazily, cached) and execute.
//!
//! One [`Engine`] owns the CPU PJRT client and a cache of compiled
//! executables keyed by artifact file. Executables are compiled the first
//! time an op is needed — figure sweeps only pay for the m values they use.
//!
//! The interchange is HLO *text* (`HloModuleProto::from_text_file`): jax's
//! serialized protos carry 64-bit instruction ids that this XLA build
//! rejects, while the text parser reassigns ids (see DESIGN.md §2).

use crate::runtime::manifest::{Manifest, OpSpec};
use crate::runtime::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

/// Compiled-executable cache + PJRT client. Not `Sync`: the coordinator owns
/// it on one thread (sampling, not execution, is what we parallelize).
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    /// Cumulative number of execute() calls (metrics).
    executions: RefCell<u64>,
}

impl Engine {
    /// Create an engine over an artifacts directory (loads the manifest).
    pub fn new(artifacts_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
            executions: RefCell::new(0),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn executions(&self) -> u64 {
        *self.executions.borrow()
    }

    /// Compile (or fetch from cache) the executable for an artifact file.
    pub fn executable(&self, file: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(file) {
            return Ok(exe.clone());
        }
        let path = self.manifest.artifact_path(file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client.compile(&comp).with_context(|| format!("compiling {file}"))?,
        );
        self.cache.borrow_mut().insert(file.to_string(), exe.clone());
        Ok(exe)
    }

    /// Number of executables currently compiled.
    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }

    /// Execute an op with host tensors; returns the output tuple as host
    /// tensors. Validates input arity against the op spec (params + data).
    /// Takes references so callers can mix the param store's tensors with
    /// batch tensors without cloning either.
    pub fn execute(&self, op: &OpSpec, n_params: usize, args: &[&Tensor]) -> Result<Vec<Tensor>> {
        let expect = n_params + op.inputs.len();
        if args.len() != expect {
            bail!(
                "op {}: expected {} inputs ({} params + {} data), got {}",
                op.file,
                expect,
                n_params,
                op.inputs.len(),
                args.len()
            );
        }
        let exe = self.executable(&op.file)?;
        let literals: Vec<xla::Literal> =
            args.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        self.execute_literals(&exe, &literals)
    }

    /// Low-level execute on literals (used by tests and the perf path).
    pub fn execute_literals(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        args: &[xla::Literal],
    ) -> Result<Vec<Tensor>> {
        *self.executions.borrow_mut() += 1;
        let result = exe.execute::<xla::Literal>(args).context("PJRT execute")?;
        let buffer = &result[0][0];
        let tuple = buffer.to_literal_sync().context("fetching result")?;
        // aot.py lowers with return_tuple=True: decompose into elements.
        let parts = tuple.to_tuple().context("decomposing result tuple")?;
        parts.iter().map(Tensor::from_literal).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn engine_compiles_and_executes_tiny_encode() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("artifacts not built; skipping");
            return;
        };
        let engine = Engine::new(&dir).unwrap();
        let model = engine.manifest().model("tiny").unwrap().clone();
        let op = model.op("encode").unwrap().clone();

        // zero params + zero inputs => h must be b2 broadcast (all zeros here)
        let mut owned: Vec<Tensor> =
            model.params.iter().map(|p| Tensor::zeros_f32(&p.shape)).collect();
        owned.push(Tensor::zeros_f32(&[model.batch, model.n_user_features.unwrap()]));
        owned.push(Tensor::i32s(
            &[model.batch, model.n_prev],
            vec![0; model.batch * model.n_prev],
        ));
        let args: Vec<&Tensor> = owned.iter().collect();
        let out = engine.execute(&op, model.params.len(), &args).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].shape(), &[model.n_examples, model.d]);
        assert!(out[0].as_f32().unwrap().iter().all(|&x| x == 0.0));
        assert_eq!(engine.compiled_count(), 1);
        assert_eq!(engine.executions(), 1);
    }

    #[test]
    fn engine_rejects_wrong_arity() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("artifacts not built; skipping");
            return;
        };
        let engine = Engine::new(&dir).unwrap();
        let model = engine.manifest().model("tiny").unwrap().clone();
        let op = model.op("encode").unwrap().clone();
        let err = engine.execute(&op, model.params.len(), &[]).unwrap_err();
        assert!(err.to_string().contains("expected"));
    }
}
