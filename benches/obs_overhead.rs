//! Telemetry overhead contract — **instrumented sampling within 3% of
//! uninstrumented**.
//!
//! The obs subsystem promises a zero-atomic draw path: sampler telemetry
//! accumulates in plain scratch-local fields and drains into the shared
//! atomic cells once per scratch checkout (`put_scratch`), with the
//! quality monitor gated on its stride. This bench holds that promise to
//! a number: `sample_batch` throughput with telemetry on (default stride)
//! vs `set_obs_enabled(false)`, alternated round-robin so machine drift
//! hits both sides equally, best-of-rounds per side.
//!
//! No artifacts needed (pure L3). `cargo bench --bench obs_overhead`
//! writes `BENCH_obs.json` with `overhead_pct` for the CI trajectory.

use kss::bench_harness::{print_table, scale, write_json_value, Bencher, BenchRow, Scale};
use kss::obs::MetricsRegistry;
use kss::sampler::{BatchSampleInput, KernelTreeSampler, QuadraticMap, Sample, Sampler};
use kss::util::json::Value;
use kss::util::rng::Rng;
use kss::util::threadpool::default_threads;

/// The contract this bench exists to hold (percent).
const CONTRACT_PCT: f64 = 3.0;

fn row_json(r: &BenchRow) -> Value {
    let mut pairs = vec![
        ("name", Value::str(&r.name)),
        ("mean_s", Value::num(r.mean_s)),
        ("p50_s", Value::num(r.p50_s)),
        ("p95_s", Value::num(r.p95_s)),
        ("iters", Value::num(r.iters as f64)),
    ];
    if let Some(t) = r.throughput() {
        pairs.push(("throughput_per_s", Value::num(t)));
    }
    Value::object(pairs)
}

fn main() {
    let (n, batch) = match scale() {
        Scale::Quick => (50_000usize, 64usize),
        Scale::Full => (200_000, 64),
    };
    let (d, m) = (16usize, 32usize);
    let threads = default_threads();
    let mut rng = Rng::new(0x0B5);
    let mut w = vec![0.0f32; n * d];
    rng.fill_normal(&mut w, 0.3);
    let mut tree = KernelTreeSampler::new(QuadraticMap::new(d, 100.0), n, None);
    tree.reset_embeddings(&w, n, d);
    let mut hs = vec![0.0f32; batch * d];
    rng.fill_normal(&mut hs, 1.0);
    let input = BatchSampleInput {
        n: batch,
        d,
        n_classes: n,
        h: Some(&hs),
        threads,
        ..Default::default()
    };
    let mut outs: Vec<Sample> = (0..batch).map(|_| Sample::with_capacity(m)).collect();
    let bencher = Bencher { warmup_iters: 2, min_iters: 10, max_iters: 400, budget_s: 1.2 };

    println!(
        "obs overhead: n={n}, d={d}, batch={batch} × m={m}, {threads} threads, \
         monitor stride {} (default)",
        kss::obs::monitor::DEFAULT_STRIDE
    );

    // best-of-rounds per side, sides alternated within each round
    let rounds = 3usize;
    let mut best_on: Option<BenchRow> = None;
    let mut best_off: Option<BenchRow> = None;
    let mut all_rows: Vec<BenchRow> = Vec::new();
    for round in 0..rounds {
        for on in [true, false] {
            tree.set_obs_enabled(on);
            let label = if on {
                format!("obs on  (round {round})")
            } else {
                format!("obs off (round {round})")
            };
            let mut step = (round as u64) * 100_000;
            let row = bencher.run_with_items(&label, Some((batch * m) as f64), || {
                step += 1;
                tree.sample_batch(&input, m, step, &mut outs).unwrap();
            });
            all_rows.push(row.clone());
            let slot = if on { &mut best_on } else { &mut best_off };
            let better = match slot {
                Some(prev) => row.mean_s < prev.mean_s,
                None => true,
            };
            if better {
                *slot = Some(row);
            }
        }
    }
    let on = best_on.expect("rounds > 0");
    let off = best_off.expect("rounds > 0");
    let overhead_pct = (on.mean_s - off.mean_s) / off.mean_s * 100.0;

    print_table("instrumented vs baseline sample_batch (all rounds)", &all_rows);
    print_table("best of rounds", &[on.clone(), off.clone()]);
    println!(
        "\ntelemetry overhead: {overhead_pct:+.2}% (contract: < {CONTRACT_PCT}%){}",
        if overhead_pct < CONTRACT_PCT { "  OK" } else { "  ** OVER CONTRACT **" }
    );

    // sanity: the instrumented rounds actually exercised the counters —
    // a 0% overhead against dead instrumentation proves nothing
    let reg = MetricsRegistry::new();
    tree.obs().register_into(&reg);
    let snap = reg.snapshot();
    let draws = snap.counter("kss_sampler_draws_total").unwrap_or(0);
    println!("draws counted while instrumented: {draws}");
    assert!(draws > 0, "telemetry never recorded — the bench measured nothing");

    let doc = Value::object(vec![
        ("bench", Value::str("obs")),
        (
            "scale",
            Value::str(match scale() {
                Scale::Quick => "quick",
                Scale::Full => "full",
            }),
        ),
        ("overhead_pct", Value::num(overhead_pct)),
        ("contract_pct", Value::num(CONTRACT_PCT)),
        ("within_contract", Value::Bool(overhead_pct < CONTRACT_PCT)),
        ("draws_counted", Value::num(draws as f64)),
        (
            "tables",
            Value::Array(vec![Value::object(vec![
                ("title", Value::str("instrumented vs baseline sample_batch (best of rounds)")),
                ("rows", Value::Array(vec![row_json(&on), row_json(&off)])),
            ])]),
        ),
    ]);
    write_json_value("obs", &doc);
}
