//! Vectorized compute core — the one set of inner-loop primitives under
//! every hot path in the system.
//!
//! The paper's cost argument is that one kernel draw is O(D log n) against
//! the full softmax's O(nd); once that asymptotic is in place, the constant
//! factor on the D-dimensional inner products and row sweeps *is* the
//! product. Before this module those loops were hand-rolled in five places
//! (tree descent, tree update sweep, flat CDF fill, RFF φ, HSM head) with
//! mixed f32/f64 accumulation and per-site layouts that blocked
//! autovectorization. Now every layer calls here:
//!
//! ```text
//! sampler/kernel/tree.rs   descent node masses ──► dot2_32 / dot32 (f32 shadow)
//!                          q / partition / beam ─► dot            (f64 master)
//!                          leaf scoring ────────► FeatureMap::kernel_many
//!                                                  └► dot_many_f32 (class panel)
//!                          update sweeps ───────► add_assign / sub_assign
//! sampler/kernel/flat.rs   weight shift ────────► row_max
//!                          CDF fill ────────────► fill_cum
//! sampler/rff/map.rs       φ(a), K̂(a,b) ───────► dot_many_mixed / dot_mixed
//!                                                  └► exp_shifted
//! sampler/rff/orthogonal   Gram–Schmidt ────────► dot
//! hsm/mod.rs               head logits ─────────► dot_many_f32 (cluster panel)
//!                          softmax ─────────────► max_shift_exp
//!                          SGD row updates ─────► axpy32
//! util/rng.rs              Cdf construction ────► fill_cum
//! serve/shard.rs           router CDF ──────────► fill_cum_into
//! serve/topk.rs            beam / leaf scores ──► dot, kernel_many (via tree)
//! ```
//!
//! # Accumulation-order contract
//!
//! Every reduction here has a **pinned, input-only accumulation order**:
//! the result is a pure function of the input values and length — never of
//! thread count, call site, or previous calls. Concretely:
//!
//! * `dot`-family reductions split the input into a fixed number of lanes
//!   (4 for f64, 8 for f32), accumulate each lane sequentially over its
//!   strided elements, combine lanes pairwise (`(s0+s1)+(s2+s3)` for 4
//!   lanes; left-fold of the 8-lane array for f32), then fold the `len %
//!   lanes` remainder sequentially. This is both the SIMD-friendly shape
//!   (independent dependence chains) and a *pairwise-style* summation whose
//!   worst-case rounding error is strictly smaller than the scalar
//!   sequential fold's for long inputs.
//! * Long sums that feed probabilities accumulate in **f64** even when the
//!   inputs are f32 (`dot_f32`, `dot_many_f32`, `fill_cum`): the only f32
//!   accumulation in the system is the tree's descent shadow (`dot32` /
//!   `dot2_32`), whose exactness the sampler never relies on — q values are
//!   recomputed in closed form from f64 state.
//! * Prefix sums (`fill_cum`, `fill_cum_into`) are defined **strictly
//!   sequentially** in both implementations: each cumulative value is
//!   observable by the CDF draw, so there is exactly one legal order.
//! * Element-wise ops (`axpy`, `add_assign`, `exp_shifted`, …) have no
//!   reduction at all; blocked and scalar versions are bit-identical.
//!
//! # Build-time selection
//!
//! The public dot/axpy/row_max families dispatch to the blocked
//! implementations by default; building with `--features ops-scalar`
//! swaps in the scalar reference bodies (`ops::reference`) instead — a
//! debugging/bisection aid, and the baseline `benches/ops_throughput.rs`
//! measures against. Exceptions with a **single** implementation in both
//! builds (a bisection cannot swap these out): the prefix sums
//! (`fill_cum`, `fill_cum_into` — sequential is the only legal order),
//! the element-wise `exp_shifted`, and `max_shift_exp` (element-wise exp
//! plus one pinned 4-lane normalizer). Property tests pin blocked ==
//! reference across every remainder-lane length and assert bitwise
//! determinism (same input ⇒ same bits, on any thread).

/// Scalar reference implementations: the semantic ground truth the blocked
/// kernels are property-tested against, and the baseline the throughput
/// bench measures. Plain sequential loops — one accumulator, one pass.
pub mod reference {
    /// Sequential f64 dot product.
    pub fn dot(a: &[f64], b: &[f64]) -> f64 {
        debug_assert_eq!(a.len(), b.len());
        a.iter().zip(b).map(|(&x, &y)| x * y).sum()
    }

    /// Sequential f32 dot product with f32 accumulation.
    pub fn dot32(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        a.iter().zip(b).map(|(&x, &y)| x * y).sum()
    }

    /// Sequential f32-input dot with f64 accumulation.
    pub fn dot_f32(a: &[f32], b: &[f32]) -> f64 {
        debug_assert_eq!(a.len(), b.len());
        a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
    }

    /// Sequential mixed f64×f32 dot with f64 accumulation.
    pub fn dot_mixed(w: &[f64], x: &[f32]) -> f64 {
        debug_assert_eq!(w.len(), x.len());
        w.iter().zip(x).map(|(&a, &b)| a * b as f64).sum()
    }

    /// Row-at-a-time panel dot (see [`super::dot_many`]).
    pub fn dot_many(q: &[f64], panel: &[f64], out: &mut [f64]) {
        debug_assert_eq!(panel.len(), q.len() * out.len());
        for (slot, row) in out.iter_mut().zip(panel.chunks_exact(q.len().max(1))) {
            *slot = dot(q, row);
        }
    }

    /// Row-at-a-time f32 panel dot with f64 accumulation.
    pub fn dot_many_f32(q: &[f32], panel: &[f32], out: &mut [f64]) {
        debug_assert_eq!(panel.len(), q.len() * out.len());
        for (slot, row) in out.iter_mut().zip(panel.chunks_exact(q.len().max(1))) {
            *slot = dot_f32(q, row);
        }
    }

    /// Row-at-a-time mixed panel dot: `out[i] = ⟨panel_row_i, x⟩`.
    pub fn dot_many_mixed(panel: &[f64], x: &[f32], out: &mut [f64]) {
        debug_assert_eq!(panel.len(), x.len() * out.len());
        for (slot, row) in out.iter_mut().zip(panel.chunks_exact(x.len().max(1))) {
            *slot = dot_mixed(row, x);
        }
    }

    /// `y += a·x`, element-wise.
    pub fn axpy(y: &mut [f64], a: f64, x: &[f64]) {
        debug_assert_eq!(y.len(), x.len());
        for (yi, &xi) in y.iter_mut().zip(x) {
            *yi += a * xi;
        }
    }

    /// `y += a·x`, element-wise, f32.
    pub fn axpy32(y: &mut [f32], a: f32, x: &[f32]) {
        debug_assert_eq!(y.len(), x.len());
        for (yi, &xi) in y.iter_mut().zip(x) {
            *yi += a * xi;
        }
    }

    /// `y += x`, element-wise.
    pub fn add_assign(y: &mut [f64], x: &[f64]) {
        debug_assert_eq!(y.len(), x.len());
        for (yi, &xi) in y.iter_mut().zip(x) {
            *yi += xi;
        }
    }

    /// `y -= x`, element-wise.
    pub fn sub_assign(y: &mut [f64], x: &[f64]) {
        debug_assert_eq!(y.len(), x.len());
        for (yi, &xi) in y.iter_mut().zip(x) {
            *yi -= xi;
        }
    }

    /// Row max of f32 values as f64 (NaNs ignored, `-inf` when empty).
    pub fn row_max(xs: &[f32]) -> f64 {
        xs.iter().fold(f64::NEG_INFINITY, |m, &o| m.max(o as f64))
    }
}

// ---------------------------------------------------------------------------
// Blocked implementations. Lane counts are fixed constants of the contract
// (4 f64 lanes / 8 f32 lanes), chosen to saturate the FP pipelines of any
// recent x86/aarch64 core without spilling accumulators.
// ---------------------------------------------------------------------------

mod blocked {
    /// 4-lane f64 dot: lanes combined pairwise, then the remainder
    /// sequentially — the pinned accumulation order of the contract.
    #[inline]
    pub fn dot(a: &[f64], b: &[f64]) -> f64 {
        debug_assert_eq!(a.len(), b.len());
        let n4 = a.len() / 4 * 4;
        let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0, 0.0, 0.0);
        let mut i = 0;
        while i < n4 {
            s0 += a[i] * b[i];
            s1 += a[i + 1] * b[i + 1];
            s2 += a[i + 2] * b[i + 2];
            s3 += a[i + 3] * b[i + 3];
            i += 4;
        }
        let mut acc = (s0 + s1) + (s2 + s3);
        for j in n4..a.len() {
            acc += a[j] * b[j];
        }
        acc
    }

    /// 8-lane f32 dot with f32 accumulation (the descent shadow's dot:
    /// twice the SIMD width of f64, half the memory traffic).
    #[inline]
    pub fn dot32(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let mut acc = [0.0f32; 8];
        let chunks = a.len() / 8;
        for c in 0..chunks {
            let base = c * 8;
            for k in 0..8 {
                acc[k] += a[base + k] * b[base + k];
            }
        }
        let mut total = acc.iter().sum::<f32>();
        for j in chunks * 8..a.len() {
            total += a[j] * b[j];
        }
        total
    }

    /// 4-lane f32-input dot with **f64 accumulation** — the long-sum-safe
    /// form every probability-feeding reduction over f32 data uses.
    #[inline]
    pub fn dot_f32(a: &[f32], b: &[f32]) -> f64 {
        debug_assert_eq!(a.len(), b.len());
        let n4 = a.len() / 4 * 4;
        let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0, 0.0, 0.0);
        let mut i = 0;
        while i < n4 {
            s0 += a[i] as f64 * b[i] as f64;
            s1 += a[i + 1] as f64 * b[i + 1] as f64;
            s2 += a[i + 2] as f64 * b[i + 2] as f64;
            s3 += a[i + 3] as f64 * b[i + 3] as f64;
            i += 4;
        }
        let mut acc = (s0 + s1) + (s2 + s3);
        for j in n4..a.len() {
            acc += a[j] as f64 * b[j] as f64;
        }
        acc
    }

    /// 4-lane mixed f64×f32 dot, f64 accumulation.
    #[inline]
    pub fn dot_mixed(w: &[f64], x: &[f32]) -> f64 {
        debug_assert_eq!(w.len(), x.len());
        let n4 = w.len() / 4 * 4;
        let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0, 0.0, 0.0);
        let mut i = 0;
        while i < n4 {
            s0 += w[i] * x[i] as f64;
            s1 += w[i + 1] * x[i + 1] as f64;
            s2 += w[i + 2] * x[i + 2] as f64;
            s3 += w[i + 3] * x[i + 3] as f64;
            i += 4;
        }
        let mut acc = (s0 + s1) + (s2 + s3);
        for j in n4..w.len() {
            acc += w[j] * x[j] as f64;
        }
        acc
    }

    /// Fused two-row f32 panel dot: one pass over `q` against two
    /// *contiguous* rows (`rows.len() == 2·q.len()`), each accumulated in
    /// exactly [`dot32`]'s order — the results are bit-identical to two
    /// separate `dot32` calls, but every `q` load is reused for both rows.
    /// This is the tree-descent shape: sibling `z32` slices are adjacent in
    /// the arena by construction.
    #[inline]
    pub fn dot2_32(q: &[f32], rows: &[f32]) -> (f32, f32) {
        let n = q.len();
        debug_assert_eq!(rows.len(), 2 * n);
        let (l, r) = rows.split_at(n);
        let mut al = [0.0f32; 8];
        let mut ar = [0.0f32; 8];
        let chunks = n / 8;
        for c in 0..chunks {
            let base = c * 8;
            for k in 0..8 {
                al[k] += q[base + k] * l[base + k];
                ar[k] += q[base + k] * r[base + k];
            }
        }
        let mut tl = al.iter().sum::<f32>();
        let mut tr = ar.iter().sum::<f32>();
        for j in chunks * 8..n {
            tl += q[j] * l[j];
            tr += q[j] * r[j];
        }
        (tl, tr)
    }

    /// Fused two-row f64 dot (same pinned per-row order as [`dot`]).
    #[inline]
    fn dot2(q: &[f64], a: &[f64], b: &[f64]) -> (f64, f64) {
        let n4 = q.len() / 4 * 4;
        let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0, 0.0, 0.0);
        let (mut b0, mut b1, mut b2, mut b3) = (0.0f64, 0.0, 0.0, 0.0);
        let mut i = 0;
        while i < n4 {
            a0 += q[i] * a[i];
            a1 += q[i + 1] * a[i + 1];
            a2 += q[i + 2] * a[i + 2];
            a3 += q[i + 3] * a[i + 3];
            b0 += q[i] * b[i];
            b1 += q[i + 1] * b[i + 1];
            b2 += q[i + 2] * b[i + 2];
            b3 += q[i + 3] * b[i + 3];
            i += 4;
        }
        let mut ta = (a0 + a1) + (a2 + a3);
        let mut tb = (b0 + b1) + (b2 + b3);
        for j in n4..q.len() {
            ta += q[j] * a[j];
            tb += q[j] * b[j];
        }
        (ta, tb)
    }

    /// Fused two-row f32 dot with f64 accumulation (per-row order pinned
    /// to [`dot_f32`]'s).
    #[inline]
    fn dot2_f32(q: &[f32], a: &[f32], b: &[f32]) -> (f64, f64) {
        let n4 = q.len() / 4 * 4;
        let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0, 0.0, 0.0);
        let (mut b0, mut b1, mut b2, mut b3) = (0.0f64, 0.0, 0.0, 0.0);
        let mut i = 0;
        while i < n4 {
            a0 += q[i] as f64 * a[i] as f64;
            a1 += q[i + 1] as f64 * a[i + 1] as f64;
            a2 += q[i + 2] as f64 * a[i + 2] as f64;
            a3 += q[i + 3] as f64 * a[i + 3] as f64;
            b0 += q[i] as f64 * b[i] as f64;
            b1 += q[i + 1] as f64 * b[i + 1] as f64;
            b2 += q[i + 2] as f64 * b[i + 2] as f64;
            b3 += q[i + 3] as f64 * b[i + 3] as f64;
            i += 4;
        }
        let mut ta = (a0 + a1) + (a2 + a3);
        let mut tb = (b0 + b1) + (b2 + b3);
        for j in n4..q.len() {
            ta += q[j] as f64 * a[j] as f64;
            tb += q[j] as f64 * b[j] as f64;
        }
        (ta, tb)
    }

    /// Fused panel dot: `out[i] = ⟨q, panel[i·d..(i+1)·d]⟩` with `q`
    /// cache-resident and the panel streamed once, two rows per pass (each
    /// row still accumulates in [`dot`]'s pinned order, so the result is
    /// bit-identical to row-at-a-time calls).
    #[inline]
    pub fn dot_many(q: &[f64], panel: &[f64], out: &mut [f64]) {
        let d = q.len();
        debug_assert_eq!(panel.len(), d * out.len());
        let pairs = out.len() / 2;
        for p in 0..pairs {
            let base = 2 * p * d;
            let (x, y) = dot2(q, &panel[base..base + d], &panel[base + d..base + 2 * d]);
            out[2 * p] = x;
            out[2 * p + 1] = y;
        }
        if out.len() % 2 == 1 {
            let i = out.len() - 1;
            out[i] = dot(q, &panel[i * d..(i + 1) * d]);
        }
    }

    /// [`dot_many`] over f32 data with f64 accumulation — leaf class
    /// panels, HSM head panels, logits rows.
    #[inline]
    pub fn dot_many_f32(q: &[f32], panel: &[f32], out: &mut [f64]) {
        let d = q.len();
        debug_assert_eq!(panel.len(), d * out.len());
        let pairs = out.len() / 2;
        for p in 0..pairs {
            let base = 2 * p * d;
            let (x, y) = dot2_f32(q, &panel[base..base + d], &panel[base + d..base + 2 * d]);
            out[2 * p] = x;
            out[2 * p + 1] = y;
        }
        if out.len() % 2 == 1 {
            let i = out.len() - 1;
            out[i] = dot_f32(q, &panel[i * d..(i + 1) * d]);
        }
    }

    /// Mixed panel dot: `out[i] = ⟨panel_row_i (f64), x (f32)⟩` — the RFF
    /// `ω` projection, streaming the D×d frequency panel once.
    #[inline]
    pub fn dot_many_mixed(panel: &[f64], x: &[f32], out: &mut [f64]) {
        let d = x.len();
        debug_assert_eq!(panel.len(), d * out.len());
        for (slot, row) in out.iter_mut().zip(panel.chunks_exact(d.max(1))) {
            *slot = dot_mixed(row, x);
        }
    }

    /// `y += a·x` (element-wise; 4-lane unrolled, bit-identical to the
    /// scalar loop — there is no reduction).
    #[inline]
    pub fn axpy(y: &mut [f64], a: f64, x: &[f64]) {
        debug_assert_eq!(y.len(), x.len());
        let n4 = y.len() / 4 * 4;
        let mut i = 0;
        while i < n4 {
            y[i] += a * x[i];
            y[i + 1] += a * x[i + 1];
            y[i + 2] += a * x[i + 2];
            y[i + 3] += a * x[i + 3];
            i += 4;
        }
        for j in n4..y.len() {
            y[j] += a * x[j];
        }
    }

    /// `y += a·x`, f32 (HSM SGD row updates).
    #[inline]
    pub fn axpy32(y: &mut [f32], a: f32, x: &[f32]) {
        debug_assert_eq!(y.len(), x.len());
        let n8 = y.len() / 8 * 8;
        let mut i = 0;
        while i < n8 {
            for k in 0..8 {
                y[i + k] += a * x[i + k];
            }
            i += 8;
        }
        for j in n8..y.len() {
            y[j] += a * x[j];
        }
    }

    /// `y += x` (the update sweep's Δz merge).
    #[inline]
    pub fn add_assign(y: &mut [f64], x: &[f64]) {
        debug_assert_eq!(y.len(), x.len());
        let n4 = y.len() / 4 * 4;
        let mut i = 0;
        while i < n4 {
            y[i] += x[i];
            y[i + 1] += x[i + 1];
            y[i + 2] += x[i + 2];
            y[i + 3] += x[i + 3];
            i += 4;
        }
        for j in n4..y.len() {
            y[j] += x[j];
        }
    }

    /// `y -= x` (Δφ = φ_new − φ_old in place).
    #[inline]
    pub fn sub_assign(y: &mut [f64], x: &[f64]) {
        debug_assert_eq!(y.len(), x.len());
        let n4 = y.len() / 4 * 4;
        let mut i = 0;
        while i < n4 {
            y[i] -= x[i];
            y[i + 1] -= x[i + 1];
            y[i + 2] -= x[i + 2];
            y[i + 3] -= x[i + 3];
            i += 4;
        }
        for j in n4..y.len() {
            y[j] -= x[j];
        }
    }

    /// Row max of f32 values as f64. `max` is associative and commutative
    /// and NaNs are ignored per `f64::max`, so the blocked lane order
    /// returns exactly the scalar fold's value (`-inf` on empty input).
    #[inline]
    pub fn row_max(xs: &[f32]) -> f64 {
        let mut lanes = [f64::NEG_INFINITY; 8];
        let chunks = xs.len() / 8;
        for c in 0..chunks {
            let base = c * 8;
            for k in 0..8 {
                lanes[k] = lanes[k].max(xs[base + k] as f64);
            }
        }
        let mut m = lanes.iter().fold(f64::NEG_INFINITY, |a, &b| a.max(b));
        for &x in &xs[chunks * 8..] {
            m = m.max(x as f64);
        }
        m
    }
}

// ---------------------------------------------------------------------------
// Public API: blocked by default, scalar reference under `ops-scalar`.
// ---------------------------------------------------------------------------

#[cfg(not(feature = "ops-scalar"))]
use blocked as imp;
#[cfg(feature = "ops-scalar")]
use reference as imp;

/// `⟨a, b⟩`, f64 (4-lane blocked; see the module contract).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    imp::dot(a, b)
}

/// `⟨a, b⟩`, f32 inputs, **f32 accumulation** (8-lane) — the tree's descent
/// shadow dot only. Every probability-feeding sum uses [`dot_f32`] instead.
#[inline]
pub fn dot32(a: &[f32], b: &[f32]) -> f32 {
    imp::dot32(a, b)
}

/// `⟨a, b⟩`, f32 inputs, f64 accumulation (4-lane).
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f64 {
    imp::dot_f32(a, b)
}

/// `⟨w, x⟩` for f64 `w` against f32 `x`, f64 accumulation (4-lane).
#[inline]
pub fn dot_mixed(w: &[f64], x: &[f32]) -> f64 {
    imp::dot_mixed(w, x)
}

/// Fused dot of `q` against two contiguous f32 rows (`rows.len() ==
/// 2·q.len()`); returns both, bit-identical to two [`dot32`] calls. The
/// descent reads sibling `z32` slices, which are adjacent by arena
/// construction — one streamed panel, `q` loaded once.
#[inline]
pub fn dot2_32(q: &[f32], rows: &[f32]) -> (f32, f32) {
    #[cfg(not(feature = "ops-scalar"))]
    {
        blocked::dot2_32(q, rows)
    }
    #[cfg(feature = "ops-scalar")]
    {
        let n = q.len();
        (reference::dot32(q, &rows[..n]), reference::dot32(q, &rows[n..]))
    }
}

/// `out[i] = ⟨q, panel[i·d..(i+1)·d]⟩` over a row-major class-blocked
/// panel: the panel streams through cache once while `q` stays resident —
/// the shape every leaf/HSM/logits sweep now has.
#[inline]
pub fn dot_many(q: &[f64], panel: &[f64], out: &mut [f64]) {
    imp::dot_many(q, panel, out)
}

/// [`dot_many`] over f32 data with f64 accumulation.
#[inline]
pub fn dot_many_f32(q: &[f32], panel: &[f32], out: &mut [f64]) {
    imp::dot_many_f32(q, panel, out)
}

/// `out[i] = ⟨panel_row_i, x⟩` for an f64 panel against an f32 query (the
/// RFF `ω` projection).
#[inline]
pub fn dot_many_mixed(panel: &[f64], x: &[f32], out: &mut [f64]) {
    imp::dot_many_mixed(panel, x, out)
}

/// `y += a·x`, element-wise f64.
#[inline]
pub fn axpy(y: &mut [f64], a: f64, x: &[f64]) {
    imp::axpy(y, a, x)
}

/// `y += a·x`, element-wise f32.
#[inline]
pub fn axpy32(y: &mut [f32], a: f32, x: &[f32]) {
    imp::axpy32(y, a, x)
}

/// `y += x`, element-wise f64.
#[inline]
pub fn add_assign(y: &mut [f64], x: &[f64]) {
    imp::add_assign(y, x)
}

/// `y -= x`, element-wise f64.
#[inline]
pub fn sub_assign(y: &mut [f64], x: &[f64]) {
    imp::sub_assign(y, x)
}

/// Row max of f32 values as f64 (NaNs ignored, `-inf` when empty) — the
/// `Exp` kernel's overflow shift.
#[inline]
pub fn row_max(xs: &[f32]) -> f64 {
    imp::row_max(xs)
}

/// Fill `cum` with the inclusive prefix sums of `weights` (`cum[i] =
/// Σ_{j<=i} w_j`, f64) and return the total mass. **Strictly sequential in
/// both implementations** — every partial sum is observable by the CDF
/// draw, so there is exactly one legal accumulation order (the contract's
/// prefix-sum clause). Negative weights are a programming error; NaN/inf
/// flow through to the caller's total check as a recoverable degenerate
/// row. The allocation-free core behind `util::rng::Cdf` and the flat
/// sampler's pooled scratch.
pub fn fill_cum(weights: &[f32], cum: &mut Vec<f64>) -> f64 {
    cum.clear();
    cum.reserve(weights.len());
    let mut acc = 0.0f64;
    for &w in weights {
        debug_assert!(!(w < 0.0), "negative weight in CDF");
        acc += w as f64;
        cum.push(acc);
    }
    acc
}

/// [`fill_cum`] over f64 weights into a preallocated slice (`cum.len() ==
/// weights.len()`); returns the total. The serve-layer shard router builds
/// its per-request root-mass CDF with this.
pub fn fill_cum_into(weights: &[f64], cum: &mut [f64]) -> f64 {
    debug_assert_eq!(weights.len(), cum.len());
    let mut acc = 0.0f64;
    for (slot, &w) in cum.iter_mut().zip(weights) {
        debug_assert!(!(w < 0.0), "negative weight in CDF");
        acc += w;
        *slot = acc;
    }
    acc
}

/// Max-shift + exp row primitive: `out[i] = exp(xs[i] − max(xs))`; returns
/// `(max, Σ out)`. The numerically safe softmax numerator every head loss
/// shares (the shift cancels in all probability ratios). Element-wise exp
/// plus the pinned 4-lane sum for the total.
pub fn max_shift_exp(xs: &[f64], out: &mut [f64]) -> (f64, f64) {
    debug_assert_eq!(xs.len(), out.len());
    let mx = xs.iter().fold(f64::NEG_INFINITY, |a, &b| a.max(b));
    for (slot, &x) in out.iter_mut().zip(xs) {
        *slot = (x - mx).exp();
    }
    // pinned 4-lane reduction for the normalizer (same order as `dot` with
    // an all-ones query)
    let n4 = out.len() / 4 * 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0, 0.0, 0.0);
    let mut i = 0;
    while i < n4 {
        s0 += out[i];
        s1 += out[i + 1];
        s2 += out[i + 2];
        s3 += out[i + 3];
        i += 4;
    }
    let mut z = (s0 + s1) + (s2 + s3);
    for j in n4..out.len() {
        z += out[j];
    }
    (mx, z)
}

/// `xs[i] = exp(min(xs[i] + shift, max_exp))` in place — the RFF φ/kernel
/// exponentiation with its overflow clamp folded in. Element-wise.
pub fn exp_shifted(xs: &mut [f64], shift: f64, max_exp: f64) {
    for x in xs.iter_mut() {
        *x = (*x + shift).min(max_exp).exp();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Lengths exercising every remainder lane for both block sizes
    /// (len % 4 ∈ {0..3} and len % 8 ∈ {0..7}), plus empty and length-1.
    fn lens() -> Vec<usize> {
        let mut v: Vec<usize> = (0..=17).collect();
        v.extend([24, 31, 32, 33, 63, 64, 65, 100]);
        v
    }

    fn vec64(rng: &mut Rng, n: usize) -> Vec<f64> {
        (0..n).map(|_| rng.normal()).collect()
    }

    fn vec32(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
    }

    #[test]
    fn blocked_dot_matches_reference_across_remainder_lanes() {
        let mut rng = Rng::new(0x0505);
        for n in lens() {
            let a = vec64(&mut rng, n);
            let b = vec64(&mut rng, n);
            let got = blocked::dot(&a, &b);
            let want = reference::dot(&a, &b);
            assert!(
                (got - want).abs() <= 1e-12 * want.abs().max(1.0),
                "len {n}: {got} vs {want}"
            );
            let a32 = vec32(&mut rng, n);
            let b32 = vec32(&mut rng, n);
            let g32 = blocked::dot32(&a32, &b32);
            let w32 = reference::dot32(&a32, &b32);
            assert!(
                (g32 - w32).abs() <= 1e-4 * w32.abs().max(1.0),
                "len {n}: {g32} vs {w32}"
            );
            let gf = blocked::dot_f32(&a32, &b32);
            let wf = reference::dot_f32(&a32, &b32);
            assert!((gf - wf).abs() <= 1e-12 * wf.abs().max(1.0), "len {n}");
            let gm = blocked::dot_mixed(&a, &b32);
            let wm = reference::dot_mixed(&a, &b32);
            assert!((gm - wm).abs() <= 1e-12 * wm.abs().max(1.0), "len {n}");
        }
    }

    #[test]
    fn fused_pair_dot_is_bitwise_two_singles() {
        // dot2_32 must equal (dot32(q, left), dot32(q, right)) *bitwise*:
        // the tree memo caches per-node values, so fused and single paths
        // must be indistinguishable
        let mut rng = Rng::new(0x0707);
        for n in lens() {
            let q = vec32(&mut rng, n);
            let rows = vec32(&mut rng, 2 * n);
            let (l, r) = dot2_32(&q, &rows);
            assert_eq!(l.to_bits(), dot32(&q, &rows[..n]).to_bits(), "len {n} left");
            assert_eq!(r.to_bits(), dot32(&q, &rows[n..]).to_bits(), "len {n} right");
        }
    }

    #[test]
    fn dot_many_is_bitwise_row_at_a_time() {
        let mut rng = Rng::new(0x0909);
        for d in [1usize, 3, 4, 7, 8, 16, 65] {
            for rows in [0usize, 1, 2, 3, 5, 8] {
                let q = vec64(&mut rng, d);
                let panel = vec64(&mut rng, d * rows);
                let mut out = vec![0.0f64; rows];
                dot_many(&q, &panel, &mut out);
                for (i, &o) in out.iter().enumerate() {
                    let want = dot(&q, &panel[i * d..(i + 1) * d]);
                    assert_eq!(o.to_bits(), want.to_bits(), "d {d} row {i}");
                }
                let q32 = vec32(&mut rng, d);
                let p32 = vec32(&mut rng, d * rows);
                let mut out = vec![0.0f64; rows];
                dot_many_f32(&q32, &p32, &mut out);
                for (i, &o) in out.iter().enumerate() {
                    let want = dot_f32(&q32, &p32[i * d..(i + 1) * d]);
                    assert_eq!(o.to_bits(), want.to_bits(), "d {d} row {i} (f32)");
                }
                let pw = vec64(&mut rng, d * rows);
                let mut out = vec![0.0f64; rows];
                dot_many_mixed(&pw, &q32, &mut out);
                for (i, &o) in out.iter().enumerate() {
                    let want = dot_mixed(&pw[i * d..(i + 1) * d], &q32);
                    assert_eq!(o.to_bits(), want.to_bits(), "d {d} row {i} (mixed)");
                }
            }
        }
    }

    #[test]
    fn elementwise_ops_match_reference_bitwise() {
        let mut rng = Rng::new(0x0B0B);
        for n in lens() {
            let x = vec64(&mut rng, n);
            let x32 = vec32(&mut rng, n);
            let a = rng.normal();
            let base = vec64(&mut rng, n);
            let base32 = vec32(&mut rng, n);

            let mut got = base.clone();
            let mut want = base.clone();
            blocked::axpy(&mut got, a, &x);
            reference::axpy(&mut want, a, &x);
            assert_eq!(got, want, "axpy len {n}");

            let mut g32 = base32.clone();
            let mut w32 = base32.clone();
            blocked::axpy32(&mut g32, a as f32, &x32);
            reference::axpy32(&mut w32, a as f32, &x32);
            assert_eq!(g32, w32, "axpy32 len {n}");

            let mut got = base.clone();
            let mut want = base.clone();
            blocked::add_assign(&mut got, &x);
            reference::add_assign(&mut want, &x);
            assert_eq!(got, want, "add_assign len {n}");

            let mut got = base.clone();
            let mut want = base;
            blocked::sub_assign(&mut got, &x);
            reference::sub_assign(&mut want, &x);
            assert_eq!(got, want, "sub_assign len {n}");

            assert_eq!(
                blocked::row_max(&x32).to_bits(),
                reference::row_max(&x32).to_bits(),
                "row_max len {n}"
            );
        }
        // row_max edge cases: empty, NaN-ignoring
        assert_eq!(row_max(&[]), f64::NEG_INFINITY);
        assert_eq!(row_max(&[f32::NAN, 2.0, 1.0]), 2.0);
    }

    #[test]
    fn fill_cum_is_sequential_and_total_matches() {
        let mut rng = Rng::new(0x0D0D);
        for n in lens() {
            let w: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
            let mut cum = Vec::new();
            let total = fill_cum(&w, &mut cum);
            assert_eq!(cum.len(), n);
            let mut acc = 0.0f64;
            for (i, &c) in cum.iter().enumerate() {
                acc += w[i] as f64;
                assert_eq!(c.to_bits(), acc.to_bits(), "prefix {i} must be sequential");
            }
            assert_eq!(total.to_bits(), acc.to_bits());
            // f64 slice variant: same sequential order
            let w64: Vec<f64> = w.iter().map(|&x| x as f64).collect();
            let mut cum2 = vec![0.0f64; n];
            let t2 = fill_cum_into(&w64, &mut cum2);
            assert_eq!(t2.to_bits(), total.to_bits());
            assert_eq!(cum, cum2);
        }
    }

    #[test]
    fn max_shift_exp_is_safe_and_normalizing() {
        let xs = vec![700.0f64, 710.0, 5.0, -3000.0];
        let mut out = vec![0.0; 4];
        let (mx, z) = max_shift_exp(&xs, &mut out);
        assert_eq!(mx, 710.0);
        assert!(out.iter().all(|&e| e.is_finite() && e >= 0.0));
        assert_eq!(out[1], 1.0);
        assert!(z.is_finite() && z >= 1.0);
        // probabilities from the shifted exps sum to 1
        let p: f64 = out.iter().map(|&e| e / z).sum();
        assert!((p - 1.0).abs() < 1e-12);
        // exp_shifted clamps its exponent
        let mut ys = vec![1e6f64, 0.0];
        exp_shifted(&mut ys, 0.0, 700.0);
        assert!(ys[0].is_finite());
        assert_eq!(ys[1], 1.0);
    }

    #[test]
    fn results_are_bitwise_deterministic_across_threads() {
        // the contract: a reduction's bits depend only on the input values
        // and length — same input must produce the same bits on the main
        // thread and on any number of worker threads
        let mut rng = Rng::new(0x0F0F);
        let a = vec64(&mut rng, 257);
        let b = vec64(&mut rng, 257);
        let a32 = vec32(&mut rng, 257);
        let b32 = vec32(&mut rng, 257);
        let panel = vec64(&mut rng, 257 * 6);
        let want = (
            dot(&a, &b).to_bits(),
            dot32(&a32, &b32).to_bits(),
            dot_f32(&a32, &b32).to_bits(),
            {
                let mut out = vec![0.0; 6];
                dot_many(&a, &panel, &mut out);
                out.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
            },
        );
        std::thread::scope(|scope| {
            for _ in 0..4 {
                let (a, b, a32, b32, panel) = (&a, &b, &a32, &b32, &panel);
                let want = &want;
                scope.spawn(move || {
                    for _ in 0..8 {
                        assert_eq!(dot(a, b).to_bits(), want.0);
                        assert_eq!(dot32(a32, b32).to_bits(), want.1);
                        assert_eq!(dot_f32(a32, b32).to_bits(), want.2);
                        let mut out = vec![0.0; 6];
                        dot_many(a, panel, &mut out);
                        assert_eq!(out.iter().map(|x| x.to_bits()).collect::<Vec<_>>(), want.3);
                    }
                });
            }
        });
    }

    #[test]
    fn blocked_f32_long_sum_drift_is_bounded_by_reference() {
        // the pairwise-style lane split must not be *worse* than the scalar
        // fold against an f64 ground truth on a long, same-sign sum — the
        // rounding-drift clause of the bugfix audit
        let mut rng = Rng::new(0x1111);
        let n = 4097; // the quadratic map's D at d = 64
        let a: Vec<f32> = (0..n).map(|_| rng.f32() + 0.5).collect();
        let b: Vec<f32> = (0..n).map(|_| rng.f32() + 0.5).collect();
        let truth = reference::dot_f32(&a, &b); // f64 accumulation
        let blocked_err = (blocked::dot32(&a, &b) as f64 - truth).abs();
        let scalar_err = (reference::dot32(&a, &b) as f64 - truth).abs();
        assert!(
            blocked_err <= scalar_err.max(1e-3 * truth.abs()),
            "blocked f32 drift {blocked_err} worse than scalar {scalar_err}"
        );
    }
}
