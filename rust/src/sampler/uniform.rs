//! Uniform sampling, `q_i ∝ 1` — the paper's baseline (§4.1.2).
//!
//! Neither example- nor model-dependent; the paper shows it needs one to two
//! orders of magnitude more samples than the quadratic kernel to reach
//! full-softmax quality. `q = 1/n > 0` trivially satisfies the sampler
//! layer's q-positivity invariant, and the default [`Sampler::sample_batch`]
//! fan-out is already optimal here (no per-example setup to amortize).

use super::{Needs, Sample, SampleInput, Sampler};
use crate::util::rng::Rng;
use anyhow::Result;

/// `q_i = 1/n` for every class.
pub struct UniformSampler {
    n: usize,
    q: f64,
}

impl UniformSampler {
    pub fn new(n: usize) -> UniformSampler {
        assert!(n > 0);
        UniformSampler { n, q: 1.0 / n as f64 }
    }
}

impl Sampler for UniformSampler {
    fn name(&self) -> &str {
        "uniform"
    }

    fn needs(&self) -> Needs {
        Needs::default()
    }

    fn sample(&self, _input: &SampleInput, m: usize, rng: &mut Rng, out: &mut Sample) -> Result<()> {
        out.clear();
        for _ in 0..m {
            out.push(rng.below(self.n as u64) as u32, self.q);
        }
        Ok(())
    }

    fn prob(&self, _input: &SampleInput, class: u32) -> Option<f64> {
        ((class as usize) < self.n).then_some(self.q)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::test_util::empirical_tv;

    #[test]
    fn uniform_q_and_distribution() {
        let s = UniformSampler::new(64);
        let mut rng = Rng::new(1);
        let mut out = Sample::default();
        s.sample(&SampleInput::default(), 32, &mut rng, &mut out).unwrap();
        assert_eq!(out.classes.len(), 32);
        assert!(out.q.iter().all(|&q| (q - 1.0 / 64.0).abs() < 1e-15));
        assert!(out.classes.iter().all(|&c| c < 64));
        let expected = vec![1.0 / 64.0; 64];
        let tv = empirical_tv(&s, &SampleInput::default(), &expected, 200_000, 7);
        assert!(tv < 0.02, "tv {tv}");
    }

    #[test]
    fn prob_bounds() {
        let s = UniformSampler::new(10);
        assert_eq!(s.prob(&SampleInput::default(), 9), Some(0.1));
        assert_eq!(s.prob(&SampleInput::default(), 10), None);
    }
}
