//! Negative samplers — the paper's subject matter.
//!
//! A [`Sampler`] draws, for one training example, `m` negative classes *with
//! replacement* from its distribution `q` and reports the probability of
//! each draw (the trainer turns those into the eq. (2) corrections
//! `ln(m q_i)`). The paper's taxonomy (§2.4) orders samplers by how much of
//! the model they see:
//!
//! | sampler        | example-dep. | model-dep. | cost/draw        |
//! |----------------|--------------|------------|------------------|
//! | uniform        | no           | no         | O(1)             |
//! | unigram        | no           | no         | O(1) (alias)     |
//! | bigram         | context only | no         | O(1) (alias)     |
//! | quadratic tree | yes          | yes        | O(D log n) §3.2  |
//! | quadratic flat | yes          | yes        | O(n) (oracle)    |
//! | quartic flat   | yes          | yes        | O(n)             |
//! | softmax exact  | yes          | yes        | O(n) (Thm 2.1)   |
//!
//! All samplers are deterministic functions of the seeded [`Rng`] stream
//! passed in, so experiments replay exactly.

pub mod bigram;
pub mod kernel;
pub mod softmax_exact;
pub mod uniform;
pub mod unigram;

use crate::util::rng::Rng;
use anyhow::Result;

pub use bigram::BigramSampler;
pub use kernel::flat::FlatKernelSampler;
pub use kernel::tree::KernelTreeSampler;
pub use kernel::{KernelKind, QuadraticMap};
pub use softmax_exact::SoftmaxSampler;
pub use uniform::UniformSampler;
pub use unigram::UnigramSampler;

/// Per-example inputs a sampler may consume. The trainer fills only what the
/// chosen sampler [`Needs`]; the rest stays `None`.
#[derive(Clone, Copy, Debug, Default)]
pub struct SampleInput<'a> {
    /// Query embedding h (the model's last hidden layer) for this example.
    pub h: Option<&'a [f32]>,
    /// Full logits row o = W h (from the score_all artifact) — only the
    /// exact/oracle samplers ask for this.
    pub logits: Option<&'a [f32]>,
    /// Previous token (LM context) for the bigram sampler.
    pub prev: Option<u32>,
}

/// What a sampler requires per batch; the trainer uses this to decide which
/// artifacts to run (encode for `h`, score_all for `logits`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Needs {
    pub h: bool,
    pub logits: bool,
    pub prev: bool,
}

/// One example's sample: m class indices (with replacement) and the
/// probability q of each draw under the sampler's distribution.
#[derive(Clone, Debug, Default)]
pub struct Sample {
    pub classes: Vec<u32>,
    pub q: Vec<f64>,
}

impl Sample {
    pub fn with_capacity(m: usize) -> Sample {
        Sample { classes: Vec::with_capacity(m), q: Vec::with_capacity(m) }
    }

    pub fn clear(&mut self) {
        self.classes.clear();
        self.q.clear();
    }

    pub fn push(&mut self, class: u32, q: f64) {
        self.classes.push(class);
        self.q.push(q);
    }
}

/// A negative-sampling distribution (immutable during a batch; `update` is
/// called between steps with the classes whose embeddings changed).
pub trait Sampler: Send + Sync {
    /// Short name used in configs, logs and figures.
    fn name(&self) -> &str;

    /// What per-example inputs `sample` consumes.
    fn needs(&self) -> Needs {
        Needs::default()
    }

    /// Draw `m` negatives with replacement into `out` (cleared first).
    fn sample(&self, input: &SampleInput, m: usize, rng: &mut Rng, out: &mut Sample) -> Result<()>;

    /// Probability of a single class under the current distribution for the
    /// given input (used by tests and the gradient-bias bench). Default:
    /// unsupported.
    fn prob(&self, _input: &SampleInput, _class: u32) -> Option<f64> {
        None
    }

    /// Notify the sampler that a class embedding changed (paper Fig. 1(b)).
    /// Static samplers ignore this.
    fn update(&mut self, _class: usize, _w_new: &[f32]) {}

    /// Batched update: `classes` sorted + deduplicated, `rows` the flat
    /// (len·d) buffer of new embeddings in the same order. Default loops
    /// over [`Sampler::update`]; the kernel tree overrides it with a single
    /// aggregated bottom-up sweep (much cheaper per step).
    fn update_many(&mut self, classes: &[usize], rows: &[f32]) {
        if classes.is_empty() {
            return;
        }
        let d = rows.len() / classes.len();
        for (i, &class) in classes.iter().enumerate() {
            self.update(class, &rows[i * d..(i + 1) * d]);
        }
    }

    /// Adaptive samplers that mirror W need the full table at (re)start.
    fn reset_embeddings(&mut self, _w: &[f32], _n: usize, _d: usize) {}
}

/// Corpus statistics the frequency-based samplers are built from.
pub struct CorpusStats {
    /// Class occurrence counts (unigram).
    pub class_counts: Vec<u64>,
    /// (prev, next) pair counts for the bigram sampler, sparse.
    pub bigram_counts: Option<Vec<Vec<(u32, u64)>>>,
}

/// Build a sampler by name. `stats` feeds unigram/bigram; `w`/`d` seed the
/// adaptive samplers' embedding mirror; `abs_logits` tells the softmax
/// oracle to use the |o| prediction distribution (§3.3).
pub fn build_sampler(
    name: &str,
    n_classes: usize,
    d: usize,
    alpha: f32,
    abs_logits: bool,
    stats: Option<&CorpusStats>,
    w: Option<&[f32]>,
) -> Result<Box<dyn Sampler>> {
    let mut s: Box<dyn Sampler> = match name {
        "uniform" => Box::new(UniformSampler::new(n_classes)),
        "unigram" => {
            let stats = stats.ok_or_else(|| anyhow::anyhow!("unigram needs corpus stats"))?;
            Box::new(UnigramSampler::new(&stats.class_counts)?)
        }
        "bigram" => {
            let stats = stats.ok_or_else(|| anyhow::anyhow!("bigram needs corpus stats"))?;
            let pairs = stats
                .bigram_counts
                .as_ref()
                .ok_or_else(|| anyhow::anyhow!("bigram needs pair counts (LM datasets only)"))?;
            Box::new(BigramSampler::new(&stats.class_counts, pairs, 0.75)?)
        }
        "softmax" => Box::new(SoftmaxSampler::new(n_classes, abs_logits)),
        "quadratic" => Box::new(KernelTreeSampler::new(
            QuadraticMap::new(d, alpha as f64),
            n_classes,
            None,
        )),
        "quadratic-flat" => {
            Box::new(FlatKernelSampler::new(KernelKind::Quadratic { alpha: alpha as f64 }))
        }
        "quartic" => Box::new(FlatKernelSampler::new(KernelKind::Quartic)),
        other => anyhow::bail!(
            "unknown sampler '{other}' (known: uniform, unigram, bigram, softmax, \
             quadratic, quadratic-flat, quartic)"
        ),
    };
    if let Some(w) = w {
        s.reset_embeddings(w, n_classes, d);
    }
    Ok(s)
}

/// All sampler names usable on every dataset (bigram is LM-only).
pub const GENERIC_SAMPLERS: &[&str] = &["uniform", "softmax", "quadratic"];

/// Sampler set for the Penn-Tree-Bank-style figures (paper Fig. 2 left).
pub const LM_SAMPLERS: &[&str] =
    &["uniform", "unigram", "bigram", "quadratic", "quartic", "softmax"];

#[cfg(test)]
pub(crate) mod test_util {
    use super::*;

    /// Empirical total-variation distance between a sampler and an expected
    /// distribution, over `draws` samples.
    pub fn empirical_tv(
        sampler: &dyn Sampler,
        input: &SampleInput,
        expected: &[f64],
        draws: usize,
        seed: u64,
    ) -> f64 {
        let mut rng = Rng::new(seed);
        let mut counts = vec![0usize; expected.len()];
        let mut out = Sample::default();
        let m = 16;
        let mut total = 0usize;
        while total < draws {
            out.clear();
            sampler.sample(input, m, &mut rng, &mut out).unwrap();
            for &c in &out.classes {
                counts[c as usize] += 1;
            }
            total += m;
        }
        0.5 * counts
            .iter()
            .zip(expected)
            .map(|(&c, &p)| (c as f64 / total as f64 - p).abs())
            .sum::<f64>()
    }
}
