//! Kernel based sampling (§3 of the paper).
//!
//! A kernel `K(h, w_i) = ⟨φ(h), φ(w_i)⟩ ≥ 0` induces the sampling
//! distribution `q_i = K(h, w_i) / ⟨φ(h), Σ_j φ(w_j)⟩` (eq. 8): the
//! partition function collapses to a dot product against a precomputable
//! summary `z = Σ_j φ(w_j)`, which is what makes adaptive sampling cheap.
//!
//! * [`QuadraticMap`] — the paper's suggested kernel `α⟨h,w⟩² + 1` with the
//!   explicit feature map `φ(a) = [√α vec(a ⊗ a), 1]`, `D = d² + 1`
//!   (eq. 10). The layout matches `phi_quadratic_ref` in
//!   python/compile/kernels/ref.py (row-major outer product, constant last).
//! * [`flat`] — exact O(n·d) sampling directly from kernel scores; the
//!   correctness oracle for the tree and the only option for kernels with
//!   intractable feature maps (quartic: D = d⁴; exact exp: D = ∞).
//! * [`tree`] — the paper's divide-and-conquer sampler (§3.2): O(D log n)
//!   draws and updates via per-subset summaries `z(C)`.
//! * [`two_pass`] — TAPAS-style batch-shared sampling: one coarse pool
//!   from the batch-mean query, then per-row exact rescoring/resampling
//!   restricted to the pool (amortizes the descents across the batch).
//! * [`midx`] — inverted multi-index: k-means clusters with per-cluster
//!   φ-aggregates, one kernel-dim op per *cluster* (K ≈ √n of them)
//!   instead of per tree level, exact within-cluster refine — the
//!   10M-class scaling path.
//!
//! The random-feature approximation of the *exponential* kernel
//! (`crate::sampler::rff`) plugs into the same [`FeatureMap`] machinery
//! with a tunable D; [`KernelKind::Exp`] is its closed-form flat oracle.

pub mod flat;
pub mod midx;
pub mod multi;
pub mod tree;
pub mod two_pass;

use crate::ops;

/// Explicit feature map of a kernel: `K(a,b) = ⟨φ(a), φ(b)⟩`.
pub trait FeatureMap: Send + Sync {
    /// Input dimension d.
    fn d(&self) -> usize;
    /// Feature dimension D.
    fn dim(&self) -> usize;
    /// Kernel-family name; doubles as the tree sampler's registry name
    /// (`"quadratic"`, `"rff"`) — the sharded variant appends `-sharded`.
    fn name(&self) -> &'static str;
    /// Write φ(a) into `out` (len = D). f64: the tree's z statistics are
    /// updated incrementally and must not drift.
    fn phi(&self, a: &[f32], out: &mut [f64]);
    /// Closed-form kernel value (cheaper than materializing φ: the paper's
    /// §3.2.2 leaf-step trick relies on K being O(d) to evaluate).
    fn kernel(&self, a: &[f32], b: &[f32]) -> f64;
    /// `out[i] = K(a, panel[i·d..(i+1)·d])` over a contiguous row-major
    /// class panel — the shape of the tree's leaf step and beam scoring
    /// (leaf classes are contiguous in the embedding mirror). The default
    /// is the row-at-a-time loop; maps with a cheaper fused form override
    /// it (quadratic → one [`ops::dot_many_f32`] sweep; rff → one shared
    /// query-projection pass). Implementations must agree with
    /// [`Self::kernel`] to f64 rounding — the tree's closed-form q
    /// tolerance (1e-9) depends on it.
    fn kernel_many(&self, a: &[f32], panel: &[f32], out: &mut [f64]) {
        let d = self.d();
        debug_assert_eq!(panel.len(), d * out.len());
        for (slot, row) in out.iter_mut().zip(panel.chunks_exact(d.max(1))) {
            *slot = self.kernel(a, row);
        }
    }
}

/// The paper's quadratic kernel, eq. (10): `K(a,b) = α⟨a,b⟩² + 1`.
#[derive(Clone, Debug)]
pub struct QuadraticMap {
    d: usize,
    alpha: f64,
}

impl QuadraticMap {
    pub fn new(d: usize, alpha: f64) -> QuadraticMap {
        assert!(d > 0 && alpha >= 0.0);
        QuadraticMap { d, alpha }
    }

    pub fn alpha(&self) -> f64 {
        self.alpha
    }
}

impl FeatureMap for QuadraticMap {
    fn d(&self) -> usize {
        self.d
    }

    fn dim(&self) -> usize {
        self.d * self.d + 1
    }

    fn name(&self) -> &'static str {
        "quadratic"
    }

    fn phi(&self, a: &[f32], out: &mut [f64]) {
        debug_assert_eq!(a.len(), self.d);
        debug_assert_eq!(out.len(), self.dim());
        let sqrt_alpha = self.alpha.sqrt();
        for i in 0..self.d {
            let ai = sqrt_alpha * a[i] as f64;
            let row = &mut out[i * self.d..(i + 1) * self.d];
            for (j, slot) in row.iter_mut().enumerate() {
                *slot = ai * a[j] as f64;
            }
        }
        out[self.d * self.d] = 1.0;
    }

    fn kernel(&self, a: &[f32], b: &[f32]) -> f64 {
        let dot = ops::dot_f32(a, b);
        self.alpha * dot * dot + 1.0
    }

    /// Fused leaf/beam scoring: one [`ops::dot_many_f32`] sweep over the
    /// class panel, then the α·o²+1 polynomial element-wise. Each row's dot
    /// is bit-identical to [`Self::kernel`]'s, so the two paths agree
    /// exactly.
    fn kernel_many(&self, a: &[f32], panel: &[f32], out: &mut [f64]) {
        debug_assert_eq!(panel.len(), a.len() * out.len());
        ops::dot_many_f32(a, panel, out);
        for o in out.iter_mut() {
            *o = self.alpha * *o * *o + 1.0;
        }
    }
}

/// Kernels usable by the flat sampler (weight as a function of the logit
/// `o = ⟨h, w⟩`, the `K(a,b) = f(⟨a,b⟩)` family of §3.2.2).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum KernelKind {
    /// `α o² + 1` — the paper's main proposal.
    Quadratic { alpha: f64 },
    /// `o⁴ + 1` — the 4th-degree polynomial extra from Figure 2 (no
    /// tractable feature map: D = O(d⁴), so flat sampling only).
    Quartic,
    /// `exp(o)` — the exponential kernel itself, i.e. the softmax
    /// distribution (Theorem 2.1's unbiased case). The closed-form oracle
    /// the `"rff"` random-feature tree approximates; registered as
    /// `"rff-flat"`. Weights are computed relative to the row's max logit
    /// (a per-row shift that cancels in every probability), so the flat
    /// sampler never overflows on large logits.
    Exp,
}

impl KernelKind {
    /// Per-row weight shift, subtracted from the logit before
    /// [`Self::weight_shifted`]. Zero for the polynomial kernels; the row
    /// max for `Exp`, where `exp(o − max)` keeps every weight in (0, 1] —
    /// the shift cancels in `q = w_i / Σ w_j`, so the distribution (and
    /// `prob`) is unchanged.
    #[inline]
    pub fn shift(&self, logits: &[f32]) -> f64 {
        match self {
            KernelKind::Exp => ops::row_max(logits),
            _ => 0.0,
        }
    }

    /// Kernel weight of one logit under a precomputed per-row
    /// [`Self::shift`].
    #[inline]
    pub fn weight_shifted(&self, o: f32, shift: f64) -> f64 {
        let o = o as f64;
        match self {
            KernelKind::Quadratic { alpha } => alpha * o * o + 1.0,
            KernelKind::Quartic => {
                let o2 = o * o;
                o2 * o2 + 1.0
            }
            KernelKind::Exp => (o - shift).exp(),
        }
    }

    /// Unshifted kernel value from a precomputed logit (polynomial kernels
    /// and tests; row-aware callers use [`Self::shift`] +
    /// [`Self::weight_shifted`]).
    #[inline]
    pub fn weight(&self, o: f32) -> f64 {
        self.weight_shifted(o, 0.0)
    }

    pub fn name(&self) -> &'static str {
        match self {
            KernelKind::Quadratic { .. } => "quadratic-flat",
            KernelKind::Quartic => "quartic",
            KernelKind::Exp => "rff-flat",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::testing::check;

    #[test]
    fn phi_inner_product_equals_kernel() {
        check("⟨φ(a),φ(b)⟩ == α⟨a,b⟩²+1", 100, |g| {
            let d = g.usize_in(1, 12);
            let alpha = g.f64_in(0.0, 200.0);
            let map = QuadraticMap::new(d, alpha);
            let a = g.vec_f32(d, -2.0, 2.0);
            let b = g.vec_f32(d, -2.0, 2.0);
            let mut pa = vec![0.0; map.dim()];
            let mut pb = vec![0.0; map.dim()];
            map.phi(&a, &mut pa);
            map.phi(&b, &mut pb);
            let ip: f64 = pa.iter().zip(&pb).map(|(x, y)| x * y).sum();
            let k = map.kernel(&a, &b);
            assert!((ip - k).abs() < 1e-6 * k.abs().max(1.0), "ip={ip} k={k}");
        });
    }

    #[test]
    fn quadratic_kernel_is_positive() {
        let map = QuadraticMap::new(4, 100.0);
        let mut rng = Rng::new(3);
        for _ in 0..100 {
            let a: Vec<f32> = (0..4).map(|_| rng.normal_f32(0.0, 2.0)).collect();
            let b: Vec<f32> = (0..4).map(|_| rng.normal_f32(0.0, 2.0)).collect();
            assert!(map.kernel(&a, &b) >= 1.0);
        }
    }

    #[test]
    fn kernel_kind_weights() {
        let q = KernelKind::Quadratic { alpha: 100.0 };
        assert_eq!(q.weight(0.0), 1.0);
        assert_eq!(q.weight(2.0), 401.0);
        assert_eq!(q.weight(-2.0), 401.0); // symmetric
        let f = KernelKind::Quartic;
        assert_eq!(f.weight(0.0), 1.0);
        assert_eq!(f.weight(2.0), 17.0);
        assert_eq!(f.weight(-2.0), 17.0);
        let e = KernelKind::Exp;
        assert_eq!(e.weight(0.0), 1.0);
        assert!((e.weight(2.0) - (2.0f64).exp()).abs() < 1e-12);
        assert!(e.weight(-2.0) < e.weight(0.0), "exp is monotone, not symmetric");
    }

    #[test]
    fn exp_shift_cancels_in_ratios() {
        // the max-logit shift must not change relative weights: w_i/w_j is
        // exp(o_i - o_j) either way, and huge logits no longer overflow
        let e = KernelKind::Exp;
        let logits = vec![500.0f32, 498.0, 300.0];
        let shift = e.shift(&logits);
        assert_eq!(shift, 500.0);
        let w: Vec<f64> = logits.iter().map(|&o| e.weight_shifted(o, shift)).collect();
        assert!(w.iter().all(|x| x.is_finite() && *x > 0.0), "{w:?}");
        assert!((w[0] / w[1] - (2.0f64).exp()).abs() < 1e-9);
        // polynomial kernels ignore the shift entirely
        let q = KernelKind::Quadratic { alpha: 2.0 };
        assert_eq!(q.shift(&logits), 0.0);
        assert_eq!(q.weight_shifted(3.0, 123.0), q.weight(3.0));
    }

    #[test]
    fn kernel_many_matches_kernel_rows_bitwise() {
        // the fused panel sweep must agree with the row-at-a-time closed
        // form exactly — the tree's leaf CDF and beam scores rely on it
        check("kernel_many == per-row kernel", 40, |g| {
            let d = g.usize_in(1, 9);
            let rows = g.usize_in(0, 12);
            let map = QuadraticMap::new(d, g.f64_in(0.0, 150.0));
            let a = g.vec_f32(d, -2.0, 2.0);
            let panel = g.vec_f32(d * rows, -2.0, 2.0);
            let mut out = vec![0.0f64; rows];
            map.kernel_many(&a, &panel, &mut out);
            for (i, &o) in out.iter().enumerate() {
                let want = map.kernel(&a, &panel[i * d..(i + 1) * d]);
                assert_eq!(o.to_bits(), want.to_bits(), "row {i}");
            }
        });
    }

    #[test]
    fn phi_layout_matches_python_oracle() {
        // pins the layout contract with ref.phi_quadratic_ref: row-major
        // outer product scaled by √α, then the constant 1.
        let map = QuadraticMap::new(2, 4.0);
        let mut out = vec![0.0; 5];
        map.phi(&[1.0, 2.0], &mut out);
        assert_eq!(out, vec![2.0, 4.0, 4.0, 8.0, 1.0]);
    }
}
