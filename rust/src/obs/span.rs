//! RAII phase spans: `span(&hist)` starts a timer whose elapsed seconds
//! land in the histogram when the guard drops (or explicitly via
//! [`Span::stop`], which also returns the elapsed time so callers that
//! already thread timings — the pipeline driver, `PhaseTimes` — don't
//! measure twice). One `Instant::now()` on entry, one `record` on exit;
//! no allocation, no locks.

use std::time::Instant;

use super::histogram::Histogram;

/// Live span guard. Records on drop unless [`Span::stop`] was called.
pub struct Span<'a> {
    hist: &'a Histogram,
    t0: Instant,
    armed: bool,
}

/// Open a span over `hist`.
#[inline]
pub fn span(hist: &Histogram) -> Span<'_> {
    Span { hist, t0: Instant::now(), armed: true }
}

impl Span<'_> {
    /// Close the span now, record, and return the elapsed seconds.
    #[inline]
    pub fn stop(mut self) -> f64 {
        self.armed = false;
        let secs = self.t0.elapsed().as_secs_f64();
        self.hist.record(secs);
        secs
    }

    /// Abandon the span without recording (error paths whose partial
    /// timing would pollute the distribution).
    #[inline]
    pub fn cancel(mut self) {
        self.armed = false;
    }
}

impl Drop for Span<'_> {
    fn drop(&mut self) {
        if self.armed {
            self.hist.record(self.t0.elapsed().as_secs_f64());
        }
    }
}

/// Time a closure into `hist`, passing its return value through.
#[inline]
pub fn time<R>(hist: &Histogram, f: impl FnOnce() -> R) -> R {
    let _s = span(hist);
    f()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_records_on_drop() {
        let h = Histogram::new();
        {
            let _s = span(&h);
            std::hint::black_box(2 + 2);
        }
        assert_eq!(h.count(), 1);
    }

    #[test]
    fn stop_records_once_and_returns_elapsed() {
        let h = Histogram::new();
        let s = span(&h);
        let secs = s.stop();
        assert!(secs >= 0.0);
        assert_eq!(h.count(), 1);
    }

    #[test]
    fn cancel_discards() {
        let h = Histogram::new();
        span(&h).cancel();
        assert_eq!(h.count(), 0);
    }

    #[test]
    fn time_passes_value_through() {
        let h = Histogram::new();
        let v = time(&h, || 42);
        assert_eq!(v, 42);
        assert_eq!(h.count(), 1);
    }
}
