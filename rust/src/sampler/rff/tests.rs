//! Tests of the random-feature subsystem: feature/kernel consistency,
//! unbiasedness, determinism + shard consistency, tree integration, and
//! the acceptance property (lower bias than quadratic at `D = 4d` on
//! dominant-tail rows).

use super::config::RffConfig;
use super::map::PositiveRffMap;
use crate::sampler::kernel::tree::KernelTreeSampler;
use crate::sampler::kernel::{FeatureMap, QuadraticMap};
use crate::sampler::test_util::empirical_tv;
use crate::sampler::{Sample, SampleInput, Sampler};
use crate::serve::shard::ShardedKernelSampler;
use crate::serve::{ShardPublisher, ShardSet};
use crate::ops::dot_f32 as dot;
use crate::util::rng::Rng;
use crate::util::testing::check;

/// Closed-form distribution of the *realized* random kernel — what the
/// tree must sample exactly.
fn realized_dist(map: &PositiveRffMap, h: &[f32], emb: &[f32], n: usize, d: usize) -> Vec<f64> {
    let w: Vec<f64> = (0..n).map(|j| map.kernel(h, &emb[j * d..(j + 1) * d])).collect();
    let z: f64 = w.iter().sum();
    w.into_iter().map(|x| x / z).collect()
}

#[test]
fn phi_inner_product_equals_kernel() {
    // the FeatureMap contract the whole tree stands on, for both variants
    check("⟨φ(a),φ(b)⟩ == K̂(a,b)", 60, |g| {
        let d = g.usize_in(1, 10);
        let dim = g.usize_in(1, 40);
        let cfg = RffConfig::new(d, g.case_seed).with_dim(dim).with_orthogonal(g.bool());
        let map = PositiveRffMap::new(cfg);
        let a = g.vec_f32(d, -1.5, 1.5);
        let b = g.vec_f32(d, -1.5, 1.5);
        let mut pa = vec![0.0; dim];
        let mut pb = vec![0.0; dim];
        map.phi(&a, &mut pa);
        map.phi(&b, &mut pb);
        let ip: f64 = pa.iter().zip(&pb).map(|(x, y)| x * y).sum();
        let k = map.kernel(&a, &b);
        assert!((ip - k).abs() < 1e-9 * k.abs().max(1e-9), "ip={ip} k={k}");
    });
}

#[test]
fn kernel_many_matches_kernel_within_f64_order() {
    // the fused panel sweep factors the query projections out once; it
    // must agree with the stateless kernel to f64 addition-order tolerance
    // (the tree's leaf CDF runs on it)
    check("rff kernel_many ≈ per-row kernel", 30, |g| {
        let d = g.usize_in(1, 8);
        let rows = g.usize_in(1, 10);
        let cfg = RffConfig::new(d, g.case_seed ^ 9)
            .with_dim(g.usize_in(1, 32))
            .with_orthogonal(g.bool());
        let map = PositiveRffMap::new(cfg);
        let a = g.vec_f32(d, -1.5, 1.5);
        let panel = g.vec_f32(d * rows, -1.5, 1.5);
        let mut out = vec![0.0f64; rows];
        map.kernel_many(&a, &panel, &mut out);
        for (i, &o) in out.iter().enumerate() {
            let want = map.kernel(&a, &panel[i * d..(i + 1) * d]);
            assert!((o - want).abs() < 1e-9 * want.abs().max(1e-12), "row {i}: {o} vs {want}");
        }
    });
}

#[test]
fn prepared_query_matches_kernel() {
    // the one-pass prepared path must agree with the stateless kernel to
    // f64 addition-order tolerance
    check("kernel_prepared == kernel", 30, |g| {
        let d = g.usize_in(1, 8);
        let cfg = RffConfig::new(d, g.case_seed ^ 3)
            .with_dim(g.usize_in(1, 32))
            .with_orthogonal(g.bool());
        let map = PositiveRffMap::new(cfg);
        let a = g.vec_f32(d, -1.5, 1.5);
        let prepared = map.prepare_query(&a);
        for _ in 0..4 {
            let b = g.vec_f32(d, -1.5, 1.5);
            let fast = map.kernel_prepared(&prepared, &b);
            let slow = map.kernel(&a, &b);
            assert!((fast - slow).abs() < 1e-9 * slow.max(1e-12), "{fast} vs {slow}");
        }
    });
}

#[test]
fn phi_components_are_positive() {
    // positivity is what keeps node masses ≥ 0 through the tree
    check("φ > 0 componentwise", 30, |g| {
        let d = g.usize_in(1, 8);
        let cfg = RffConfig::new(d, g.case_seed ^ 1).with_orthogonal(g.bool());
        let map = PositiveRffMap::new(cfg);
        let a = g.vec_f32(d, -2.0, 2.0);
        let mut phi = vec![0.0; map.dim()];
        map.phi(&a, &mut phi);
        assert!(phi.iter().all(|&x| x > 0.0 && x.is_finite()), "{phi:?}");
        let b = g.vec_f32(d, -2.0, 2.0);
        assert!(map.kernel(&a, &b) > 0.0);
    });
}

#[test]
fn kernel_estimate_is_unbiased_for_exp() {
    // E_ω[K̂(a,b)] = exp(aᵀb): average the realized kernel over many
    // independent feature draws (both variants — orthogonalization changes
    // variance, not expectation)
    for orthogonal in [false, true] {
        let d = 3;
        let a = vec![0.4f32, -0.3, 0.5];
        let b = vec![-0.2f32, 0.6, 0.35];
        let want = dot(&a, &b).exp();
        let seeds = 400usize;
        let mean: f64 = (0..seeds)
            .map(|s| {
                let cfg = RffConfig::new(d, 0xBEEF + s as u64)
                    .with_dim(12)
                    .with_orthogonal(orthogonal);
                PositiveRffMap::new(cfg).kernel(&a, &b)
            })
            .sum::<f64>()
            / seeds as f64;
        // 4800 effective features; per-feature rel-std ≈ √(e^‖a+b‖² − 1)
        assert!(
            (mean - want).abs() < 0.12 * want,
            "orthogonal={orthogonal}: mean {mean} vs exp(ab) {want}"
        );
    }
}

#[test]
fn same_config_draws_identical_features() {
    // the determinism / shard-consistency contract: config == identity
    let cfg = RffConfig::new(5, 99).with_dim(20).with_orthogonal(true);
    let a = PositiveRffMap::new(cfg);
    let b = PositiveRffMap::new(cfg);
    assert_eq!(a.omega(), b.omega());
    let c = a.clone();
    assert_eq!(a.omega(), c.omega());
    // and a different seed realizes a different kernel
    let other = PositiveRffMap::new(RffConfig::new(5, 100).with_dim(20).with_orthogonal(true));
    assert_ne!(a.omega(), other.omega());
}

#[test]
fn phi_layout_matches_python_oracle() {
    // pins the layout contract with ref.phi_rff_ref: out[i] is ω row i
    // (row-major D × d), each component exp(ω_iᵀa − ‖a‖²/2)/√D
    let omega = vec![1.0, 0.0, 0.0, 1.0]; // rows e_1, e_2
    let map = PositiveRffMap::with_omega(2, omega);
    let a = [0.6f32, -0.8];
    let mut out = vec![0.0; 2];
    map.phi(&a, &mut out);
    let pref = (-0.5f64).exp() / (2.0f64).sqrt(); // ‖a‖² = 1
    let want = [pref * (0.6f64).exp(), pref * (-0.8f64).exp()];
    for (i, (&got, &w)) in out.iter().zip(&want).enumerate() {
        assert!((got - w).abs() < 1e-12, "slot {i}: {got} vs {w}");
    }
}

#[test]
fn tree_q_matches_realized_kernel_closed_form() {
    // the §3.2 machinery must be *exact* for the realized kernel: reported
    // q == K̂/Σ K̂ (relative tolerance: f64 summation order)
    check("rff tree q == K̂ closed form", 12, |g| {
        let n = g.usize_in(4, 48);
        let d = g.usize_in(1, 6);
        let leaf = g.usize_in(1, 8);
        let mut rng = Rng::new(g.case_seed ^ 0x2FF);
        let cfg = RffConfig::new(d, g.case_seed ^ 7)
            .with_dim(g.usize_in(2, 24))
            .with_orthogonal(g.bool());
        let map = PositiveRffMap::new(cfg);
        let mut emb = vec![0.0f32; n * d];
        rng.fill_normal(&mut emb, 0.5);
        let mut tree = KernelTreeSampler::new(map.clone(), n, Some(leaf));
        tree.reset_embeddings(&emb, n, d);
        let h: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let expected = realized_dist(&map, &h, &emb, n, d);
        let input = SampleInput { h: Some(&h), ..Default::default() };
        let mut out = Sample::default();
        tree.sample(&input, 48, &mut rng, &mut out).unwrap();
        for (&c, &q) in out.classes.iter().zip(&out.q) {
            let want = expected[c as usize];
            assert!(
                (q - want).abs() < 1e-9 * want.max(1e-12),
                "class {c}: q {q} vs closed form {want}"
            );
        }
    });
}

#[test]
fn tree_samples_match_realized_kernel_distribution() {
    // tree-vs-flat-oracle TV for the RFF map (the crate-wide tree == flat
    // contract, instantiated for PositiveRffMap)
    let (n, d) = (64, 4);
    let mut rng = Rng::new(2026);
    let map = PositiveRffMap::new(RffConfig::new(d, 0x51).with_dim(16));
    let mut emb = vec![0.0f32; n * d];
    rng.fill_normal(&mut emb, 0.5);
    let mut tree = KernelTreeSampler::new(map.clone(), n, None);
    tree.reset_embeddings(&emb, n, d);
    let h: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let expected = realized_dist(&map, &h, &emb, n, d);
    let input = SampleInput { h: Some(&h), ..Default::default() };
    let tv = empirical_tv(&tree, &input, &expected, 300_000, 17);
    assert!(tv < 0.02, "tv {tv}");
}

#[test]
fn sharded_rff_matches_unsharded_distribution() {
    // shard consistency: clones share ω, so the router's merged q equals
    // the unsharded realized-kernel distribution
    check("rff sharded q == unsharded q", 8, |g| {
        let n = g.usize_in(6, 80);
        let d = g.usize_in(1, 5);
        let shards = g.usize_in(2, 6.min(n));
        let mut rng = Rng::new(g.case_seed ^ 0x5F);
        let map = PositiveRffMap::new(
            RffConfig::new(d, g.case_seed ^ 0x11).with_dim(g.usize_in(2, 16)),
        );
        let mut emb = vec![0.0f32; n * d];
        rng.fill_normal(&mut emb, 0.5);
        let mut sharded = ShardedKernelSampler::new(map.clone(), n, shards, Some(4));
        sharded.reset_embeddings(&emb, n, d);
        assert_eq!(sharded.name(), "rff-sharded");
        let h: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let expected = realized_dist(&map, &h, &emb, n, d);
        let input = SampleInput { h: Some(&h), ..Default::default() };
        let mut out = Sample::default();
        sharded.sample(&input, 32, &mut rng, &mut out).unwrap();
        for (&c, &q) in out.classes.iter().zip(&out.q) {
            let want = expected[c as usize];
            assert!(
                (q - want).abs() < 1e-9 * want.max(1e-12),
                "class {c}: sharded q {q} vs unsharded {want}"
            );
        }
    });
}

#[test]
fn kernel_erased_publisher_serves_the_realized_rff_kernel() {
    // the trainer's publish path for a non-quadratic kernel: stores/offsets
    // taken first (as enable_serving_with does), the set then driven
    // kernel-erased through Box<dyn ShardPublisher> across several rounds —
    // deep enough that publishes go through the reclaim+replay path — and
    // the published snapshots must still score with the *same realized
    // kernel* (cloned ω, not re-derived) as the training-side mirror.
    let (n, d, shards) = (30usize, 3usize, 3usize);
    let mut rng = Rng::new(0xE2A);
    let map = PositiveRffMap::new(RffConfig::new(d, 0x77).with_dim(8));
    let mut emb = vec![0.0f32; n * d];
    rng.fill_normal(&mut emb, 0.5);
    let set = ShardSet::new(map.clone(), n, shards, Some(4), Some(&emb));
    let stores = set.stores();
    let offsets = set.offsets().to_vec();
    let mut publisher: Box<dyn ShardPublisher> = Box::new(set);
    for _round in 0..6 {
        let mut classes: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut classes);
        classes.truncate(5);
        classes.sort_unstable();
        let mut rows = vec![0.0f32; classes.len() * d];
        rng.fill_normal(&mut rows, 0.6);
        publisher.update_and_publish_rows(&classes, &rows);
        for (i, &c) in classes.iter().enumerate() {
            emb[c * d..(c + 1) * d].copy_from_slice(&rows[i * d..(i + 1) * d]);
        }
    }
    assert!(publisher.publish_stats().publishes >= 6);
    // closed form over the published snapshots == realized kernel over the
    // mirrored table (any ω re-derivation or replay defect would skew it)
    let h: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let expected = realized_dist(&map, &h, &emb, n, d);
    let snaps: Vec<_> = stores.iter().map(|s| s.load().1).collect();
    let phi = snaps[0].tree.phi_query(&h);
    let total: f64 = snaps.iter().map(|s| s.tree.partition(&phi).max(0.0)).sum();
    for c in 0..n {
        let sid = crate::serve::shard::shard_of_class(&offsets, c);
        let local = c - offsets[sid] as usize;
        let k = snaps[sid].tree.feature_map().kernel(&h, snaps[sid].tree.emb_row(local));
        let got = k / total;
        let want = expected[c];
        assert!(
            (got - want).abs() < 1e-9 * want.max(1e-12),
            "class {c}: served {got} vs realized kernel {want}"
        );
    }
}

/// The acceptance property: on logit rows with a *dominant tail class*
/// (one class far above the bulk, mirror classes far below — where the
/// quadratic kernel's sign-blindness hurts most), the rff tree at `D = 4d`
/// lands measurably closer to the exact softmax distribution than the
/// quadratic tree. The construction plants `o = +2.2` for one class,
/// `o = −2.2` for six mirrors, and small logits for the rest: softmax
/// concentrates on the positive class, quadratic weights ±2.2 identically.
#[test]
fn rff_4d_beats_quadratic_tv_to_softmax_on_dominant_tail() {
    check("rff(4d) TV < quadratic TV to softmax", 5, |g| {
        let d = 4usize;
        let n = 24usize;
        let mut rng = Rng::new(g.case_seed ^ 0xD0);
        // h with a controlled norm
        let mut h = vec![0.0f32; d];
        rng.fill_normal(&mut h, 1.0);
        let norm = dot(&h, &h).sqrt() as f32;
        for x in h.iter_mut() {
            *x *= 1.2 / norm.max(1e-6);
        }
        let h2 = dot(&h, &h) as f32; // ≈ 1.44
        // class 0: o = +2.2; classes 1..=6: o = −2.2; rest: small
        let mut emb = vec![0.0f32; n * d];
        for k in 0..d {
            emb[k] = h[k] * 2.2 / h2;
        }
        for j in 1..=6 {
            for k in 0..d {
                emb[j * d + k] = -emb[k];
            }
        }
        for j in 7..n {
            for k in 0..d {
                emb[j * d + k] = rng.normal_f32(0.0, 0.25);
            }
        }
        // exact softmax target p ∝ exp(o)
        let logits: Vec<f64> = (0..n).map(|j| dot(&h, &emb[j * d..(j + 1) * d])).collect();
        let mx = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let ws: Vec<f64> = logits.iter().map(|&o| (o - mx).exp()).collect();
        let z: f64 = ws.iter().sum();
        let softmax: Vec<f64> = ws.iter().map(|w| w / z).collect();

        let input = SampleInput { h: Some(&h), ..Default::default() };
        let draws = 120_000;

        let mut quad = KernelTreeSampler::new(QuadraticMap::new(d, 100.0), n, None);
        quad.reset_embeddings(&emb, n, d);
        let tv_quad = empirical_tv(&quad, &input, &softmax, draws, g.case_seed ^ 0xA1);

        let cfg = RffConfig::new(d, g.case_seed ^ 0xB2); // D = 4d
        assert_eq!(cfg.dim, 4 * d);
        let mut rff = KernelTreeSampler::new(PositiveRffMap::new(cfg), n, None);
        rff.reset_embeddings(&emb, n, d);
        let tv_rff = empirical_tv(&rff, &input, &softmax, draws, g.case_seed ^ 0xA2);

        assert!(
            tv_rff < tv_quad - 0.1,
            "rff at D=4d should beat quadratic decisively: tv_rff {tv_rff} vs tv_quad {tv_quad}"
        );
    });
}
