//! Flat (exact, O(n)) kernel sampling — the oracle the tree is tested
//! against, and the only implementation for kernels whose feature map is
//! intractable (quartic: D = O(d⁴)).
//!
//! Consumes the logits row `o = W h` (from the score_all artifact, the same
//! input the exact-softmax sampler uses) since both of the paper's kernels
//! are functions of the dot product: `K = f(⟨h, w_i⟩)`.

use super::KernelKind;
use crate::sampler::{Needs, Sample, SampleInput, Sampler};
use crate::util::rng::{Cdf, Rng};
use anyhow::Result;

/// Exact sampler for `q_i ∝ f(o_i)`.
pub struct FlatKernelSampler {
    kind: KernelKind,
}

impl FlatKernelSampler {
    pub fn new(kind: KernelKind) -> FlatKernelSampler {
        FlatKernelSampler { kind }
    }

    fn weights(&self, logits: &[f32]) -> Vec<f32> {
        logits.iter().map(|&o| self.kind.weight(o) as f32).collect()
    }
}

impl Sampler for FlatKernelSampler {
    fn name(&self) -> &str {
        self.kind.name()
    }

    fn needs(&self) -> Needs {
        Needs { logits: true, ..Needs::default() }
    }

    fn sample(&self, input: &SampleInput, m: usize, rng: &mut Rng, out: &mut Sample) -> Result<()> {
        let logits =
            input.logits.ok_or_else(|| anyhow::anyhow!("flat kernel sampler needs logits"))?;
        out.clear();
        let w = self.weights(logits);
        let cdf = Cdf::new(&w).ok_or_else(|| anyhow::anyhow!("degenerate kernel weights"))?;
        for _ in 0..m {
            let c = cdf.sample(rng);
            // Cdf::sample only returns positive-weight indices; the clamp
            // keeps q > 0 even if the ratio to a huge total underflows.
            out.push(c as u32, cdf.prob(c).max(f64::MIN_POSITIVE));
        }
        Ok(())
    }

    fn prob(&self, input: &SampleInput, class: u32) -> Option<f64> {
        let logits = input.logits?;
        let total: f64 = logits.iter().map(|&o| self.kind.weight(o)).sum();
        Some(self.kind.weight(logits[class as usize]) / total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::test_util::empirical_tv;

    #[test]
    fn quadratic_flat_matches_kernel_distribution() {
        let logits = vec![0.0f32, 1.0, -1.0, 2.0, 0.5];
        let s = FlatKernelSampler::new(KernelKind::Quadratic { alpha: 100.0 });
        let input = SampleInput { logits: Some(&logits), ..Default::default() };
        let w: Vec<f64> = logits.iter().map(|&o| 100.0 * (o as f64).powi(2) + 1.0).collect();
        let z: f64 = w.iter().sum();
        let expected: Vec<f64> = w.iter().map(|x| x / z).collect();
        for c in 0..5u32 {
            assert!((s.prob(&input, c).unwrap() - expected[c as usize]).abs() < 1e-9);
        }
        let tv = empirical_tv(&s, &input, &expected, 200_000, 13);
        assert!(tv < 0.02, "tv {tv}");
        // symmetry: o = ±1 get the same probability
        assert!((s.prob(&input, 1).unwrap() - s.prob(&input, 2).unwrap()).abs() < 1e-12);
    }

    #[test]
    fn quartic_sharper_than_quadratic() {
        // quartic upweights large logits more aggressively
        let logits = vec![0.1f32, 3.0];
        let quad = FlatKernelSampler::new(KernelKind::Quadratic { alpha: 1.0 });
        let quart = FlatKernelSampler::new(KernelKind::Quartic);
        let input = SampleInput { logits: Some(&logits), ..Default::default() };
        assert!(quart.prob(&input, 1).unwrap() > quad.prob(&input, 1).unwrap());
    }

    #[test]
    fn zero_logits_fall_back_to_uniform() {
        let logits = vec![0.0f32; 8];
        let s = FlatKernelSampler::new(KernelKind::Quadratic { alpha: 100.0 });
        let input = SampleInput { logits: Some(&logits), ..Default::default() };
        for c in 0..8u32 {
            assert!((s.prob(&input, c).unwrap() - 0.125).abs() < 1e-12);
        }
    }
}
