//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! This is the bridge between the rust coordinator (L3) and the JAX/Pallas
//! compute graphs (L2/L1). `python/compile/aot.py` lowers every entry point
//! to `artifacts/*.hlo.txt` plus `artifacts/manifest.json`; at startup the
//! coordinator builds an [`Engine`] which compiles artifacts lazily on a
//! `PjRtClient::cpu()` and keeps them cached. Python never runs here.
//!
//! * [`manifest`] — typed view of manifest.json (models, params, op specs).
//! * [`tensor`] — host-side tensors and Literal conversion.
//! * [`engine`] — executable cache + execute.
//! * [`params`] — parameter initialization (per manifest init specs) and the
//!   host mirror of the output-embedding table the samplers read.

pub mod engine;
pub mod manifest;
pub mod params;
pub mod tensor;

pub use engine::Engine;
pub use manifest::{IoSpec, Manifest, ModelKind, ModelSpec, OpSpec, ParamSpec};
pub use params::ParamStore;
pub use tensor::Tensor;
