//! Hierarchical softmax baseline (paper §5.2, Goodman 2001).
//!
//! The main alternative family to sampled softmax: factor
//! `p(y|x) = p(c_y|x) · p(y | c_y, x)` over `√n`-sized clusters so one
//! training step costs `O(d·√n)` instead of `O(d·n)`. The paper's related
//! work quotes Chen et al. (2015): HSM trains fast but converges to a
//! *worse* model than full softmax (>10% perplexity gap), while sampled
//! softmax with a good q approaches full softmax — that comparison is
//! exactly what `benches/hsm_baseline.rs` measures on a synthetic task.
//!
//! Self-contained: its own two-level head, exact gradients (both softmaxes
//! are small), SGD — no XLA involvement, so the comparison isolates the
//! output-layer method.
//!
//! # Panel layout (ops-layer integration)
//!
//! Both levels run on [`crate::ops`]: logits are one
//! [`crate::ops::dot_many_f32`] sweep over a contiguous row panel, the
//! softmax is the [`crate::ops::max_shift_exp`] row primitive (f64
//! accumulation — the head's long sums are never f32), and SGD row
//! updates are [`crate::ops::axpy32`]. To make level 2 a panel sweep, the
//! class vectors are stored **cluster-blocked**: `class_w` is permuted so
//! cluster `c`'s member rows occupy the contiguous range
//! `[panel_lo[c], panel_lo[c] + members[c].len())` — the same
//! class-blocked-panel idea as the kernel tree's leaf step, replacing the
//! old per-member strided gather.

use crate::ops;
use crate::util::rng::Rng;

/// Cluster assignment: contiguous frequency bins (Mikolov et al. 2011 style
/// "frequency binning": sort classes by frequency, cut into equal-mass
/// bins). Returns (assignment per class, members per cluster).
pub fn frequency_binning(counts: &[u64], n_clusters: usize) -> (Vec<u32>, Vec<Vec<u32>>) {
    let n = counts.len();
    let n_clusters = n_clusters.clamp(1, n);
    let mut order: Vec<u32> = (0..n as u32).collect();
    order.sort_by_key(|&c| std::cmp::Reverse(counts[c as usize]));
    let total: u64 = counts.iter().sum::<u64>() + n as u64; // +1 smoothing
    let per_bin = total as f64 / n_clusters as f64;
    let mut assign = vec![0u32; n];
    let mut members: Vec<Vec<u32>> = vec![Vec::new(); n_clusters];
    let mut acc = 0.0f64;
    let mut bin = 0usize;
    for &class in &order {
        if acc >= per_bin * (bin + 1) as f64 && bin + 1 < n_clusters {
            bin += 1;
        }
        assign[class as usize] = bin as u32;
        members[bin].push(class);
        acc += (counts[class as usize] + 1) as f64;
    }
    // make sure no cluster is empty (move one member if needed)
    for b in 0..n_clusters {
        if members[b].is_empty() {
            let donor = (0..n_clusters).max_by_key(|&i| members[i].len()).unwrap();
            let class = members[donor].pop().unwrap();
            assign[class as usize] = b as u32;
            members[b].push(class);
        }
    }
    (assign, members)
}

/// Two-level hierarchical softmax output head with SGD training.
pub struct HsmHead {
    d: usize,
    assign: Vec<u32>,
    members: Vec<Vec<u32>>,
    /// (n_clusters, d) cluster logit vectors (contiguous panel).
    cluster_w: Vec<f32>,
    /// (n, d) within-cluster class vectors in **cluster-blocked panel
    /// order**: cluster c owns rows `panel_lo[c] ..` (see module docs).
    class_w: Vec<f32>,
    /// First panel row of each cluster (`panel_lo[c+1] − panel_lo[c] ==
    /// members[c].len()`; one extra terminal entry).
    panel_lo: Vec<usize>,
    /// Class id → its panel row in `class_w`.
    row_of_class: Vec<u32>,
    /// Reusable logits/softmax buffers (avoid per-step allocation; sized
    /// to max(n_clusters, largest cluster)).
    scratch_logits: Vec<f64>,
    scratch_p: Vec<f64>,
}

impl HsmHead {
    pub fn new(counts: &[u64], d: usize, n_clusters: usize, rng: &mut Rng) -> HsmHead {
        let n = counts.len();
        let (assign, members) = frequency_binning(counts, n_clusters);
        let mut cluster_w = vec![0.0f32; members.len() * d];
        let mut class_w = vec![0.0f32; n * d];
        rng.fill_normal(&mut cluster_w, 0.1);
        rng.fill_normal(&mut class_w, 0.1);
        // cluster-blocked panel: cluster c's members are contiguous rows
        let mut panel_lo = Vec::with_capacity(members.len() + 1);
        let mut row_of_class = vec![0u32; n];
        let mut row = 0usize;
        for m in &members {
            panel_lo.push(row);
            for &class in m {
                row_of_class[class as usize] = row as u32;
                row += 1;
            }
        }
        panel_lo.push(row);
        debug_assert_eq!(row, n);
        let widest = members.iter().map(|m| m.len()).max().unwrap_or(1).max(members.len());
        HsmHead {
            d,
            assign,
            members,
            cluster_w,
            class_w,
            panel_lo,
            row_of_class,
            scratch_logits: vec![0.0; widest],
            scratch_p: vec![0.0; widest],
        }
    }

    pub fn n_clusters(&self) -> usize {
        self.members.len()
    }

    /// Cluster c's contiguous class-vector panel.
    #[inline]
    fn panel(&self, c: usize) -> &[f32] {
        &self.class_w[self.panel_lo[c] * self.d..self.panel_lo[c + 1] * self.d]
    }

    /// -log p(y|h) under the factorization; O(d(√n + |cluster|)).
    pub fn loss(&self, h: &[f32], y: u32) -> f64 {
        -(self.prob(h, y).max(1e-300)).ln()
    }

    /// One SGD step on example (h, y); returns the loss. Updates both levels
    /// and returns d loss / d h in `dh` (so an encoder could backprop).
    pub fn step(&mut self, h: &[f32], y: u32, lr: f32, dh: &mut [f32]) -> f64 {
        let d = self.d;
        let c = self.assign[y as usize] as usize;
        dh.iter_mut().for_each(|x| *x = 0.0);

        // level 1: cluster softmax over all clusters — one panel sweep
        let k = self.members.len();
        let logits = &mut self.scratch_logits[..k];
        ops::dot_many_f32(h, &self.cluster_w, logits);
        let p1 = &mut self.scratch_p[..k];
        let (_, z1) = ops::max_shift_exp(logits, p1);
        let loss1 = -((p1[c] / z1).max(1e-30)).ln();
        for j in 0..k {
            let g = ((p1[j] / z1) - f64::from(j == c) as f64) as f32;
            let row = &self.cluster_w[j * d..(j + 1) * d];
            ops::axpy32(dh, g, row);
            let row = &mut self.cluster_w[j * d..(j + 1) * d];
            ops::axpy32(row, -lr * g, h);
        }

        // level 2: class softmax within y's cluster — the cluster-blocked
        // panel makes this one contiguous sweep, no strided gather
        let (lo, hi) = (self.panel_lo[c], self.panel_lo[c + 1]);
        let len = hi - lo;
        let y_pos = self.row_of_class[y as usize] as usize - lo;
        let logits = &mut self.scratch_logits[..len];
        ops::dot_many_f32(h, &self.class_w[lo * d..hi * d], logits);
        let p2 = &mut self.scratch_p[..len];
        let (_, z2) = ops::max_shift_exp(logits, p2);
        let loss2 = -((p2[y_pos] / z2).max(1e-30)).ln();
        for j in 0..len {
            let g = ((p2[j] / z2) - f64::from(j == y_pos) as f64) as f32;
            let row = &self.class_w[(lo + j) * d..(lo + j + 1) * d];
            ops::axpy32(dh, g, row);
            let row = &mut self.class_w[(lo + j) * d..(lo + j + 1) * d];
            ops::axpy32(row, -lr * g, h);
        }
        loss1 + loss2
    }

    /// Exact p(y|h) for evaluation (sums to 1 over all classes by
    /// construction — verified in tests).
    pub fn prob(&self, h: &[f32], y: u32) -> f64 {
        let c = self.assign[y as usize] as usize;
        let d = self.d;
        let k = self.members.len();
        let mut logits = vec![0.0f64; k];
        ops::dot_many_f32(h, &self.cluster_w, &mut logits);
        let mut e = vec![0.0f64; k];
        let (_, z1) = ops::max_shift_exp(&logits, &mut e);
        let p1 = e[c] / z1;
        let (lo, hi) = (self.panel_lo[c], self.panel_lo[c + 1]);
        let y_pos = self.row_of_class[y as usize] as usize - lo;
        let mut logits = vec![0.0f64; hi - lo];
        ops::dot_many_f32(h, self.panel(c), &mut logits);
        let mut e = vec![0.0f64; hi - lo];
        let (_, z2) = ops::max_shift_exp(&logits, &mut e);
        p1 * (e[y_pos] / z2)
    }
}

/// Plain full-softmax head with SGD — the comparison baseline.
pub struct FullHead {
    d: usize,
    w: Vec<f32>,
    /// Reusable logits/softmax buffers.
    scratch_logits: Vec<f64>,
    scratch_p: Vec<f64>,
}

impl FullHead {
    pub fn new(n: usize, d: usize, rng: &mut Rng) -> FullHead {
        let mut w = vec![0.0f32; n * d];
        rng.fill_normal(&mut w, 0.1);
        FullHead { d, w, scratch_logits: vec![0.0; n], scratch_p: vec![0.0; n] }
    }

    pub fn loss(&self, h: &[f32], y: u32) -> f64 {
        let n = self.w.len() / self.d;
        let mut logits = vec![0.0f64; n];
        ops::dot_many_f32(h, &self.w, &mut logits);
        let mut e = vec![0.0f64; n];
        let (_, z) = ops::max_shift_exp(&logits, &mut e);
        -((e[y as usize] / z).max(1e-30)).ln()
    }

    pub fn step(&mut self, h: &[f32], y: u32, lr: f32) -> f64 {
        let d = self.d;
        let n = self.w.len() / d;
        let logits = &mut self.scratch_logits[..n];
        ops::dot_many_f32(h, &self.w, logits);
        let p = &mut self.scratch_p[..n];
        let (_, z) = ops::max_shift_exp(logits, p);
        let loss = -((p[y as usize] / z).max(1e-30)).ln();
        for j in 0..n {
            let g = ((p[j] / z) - f64::from(j == y as usize) as f64) as f32;
            let row = &mut self.w[j * d..(j + 1) * d];
            ops::axpy32(row, -lr * g, h);
        }
        loss
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frequency_binning_partitions_classes() {
        let counts: Vec<u64> = (0..100).map(|i| (100 - i) * 10).collect();
        let (assign, members) = frequency_binning(&counts, 10);
        assert_eq!(members.len(), 10);
        let total: usize = members.iter().map(|m| m.len()).sum();
        assert_eq!(total, 100);
        for (b, m) in members.iter().enumerate() {
            assert!(!m.is_empty(), "cluster {b} empty");
            for &class in m {
                assert_eq!(assign[class as usize], b as u32);
            }
        }
        // frequent classes land in earlier (smaller) bins: bin 0 should have
        // far fewer members than the last bin
        assert!(members[0].len() < members[9].len());
    }

    #[test]
    fn cluster_panel_layout_is_a_permutation() {
        // every class owns exactly one panel row inside its cluster's
        // contiguous block — the invariant the level-2 sweep depends on
        let mut rng = Rng::new(13);
        let counts: Vec<u64> = (0..57u64).map(|i| i * 7 % 23).collect();
        let head = HsmHead::new(&counts, 5, 8, &mut rng);
        let n = counts.len();
        let mut seen = vec![false; n];
        for (c, m) in head.members.iter().enumerate() {
            let (lo, hi) = (head.panel_lo[c], head.panel_lo[c + 1]);
            assert_eq!(hi - lo, m.len(), "cluster {c} panel size");
            for &class in m {
                let row = head.row_of_class[class as usize] as usize;
                assert!((lo..hi).contains(&row), "class {class} outside its panel");
                assert!(!seen[row], "panel row {row} assigned twice");
                seen[row] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "panel rows must cover all classes");
        assert_eq!(*head.panel_lo.last().unwrap(), n);
    }

    #[test]
    fn hsm_probabilities_sum_to_one() {
        let mut rng = Rng::new(3);
        let counts = vec![5u64; 30];
        let head = HsmHead::new(&counts, 8, 6, &mut rng);
        let h: Vec<f32> = (0..8).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let total: f64 = (0..30).map(|y| head.prob(&h, y)).sum();
        assert!((total - 1.0).abs() < 1e-9, "{total}");
    }

    #[test]
    fn step_loss_matches_prob_before_update() {
        // the step's reported loss must equal -ln p(y|h) of the pre-update
        // head (same max-shift softmax both ways)
        let mut rng = Rng::new(17);
        let counts: Vec<u64> = (0..40u64).map(|i| i + 1).collect();
        let mut head = HsmHead::new(&counts, 6, 7, &mut rng);
        let mut dh = vec![0.0f32; 6];
        for y in [0u32, 13, 39] {
            let h: Vec<f32> = (0..6).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let want = head.loss(&h, y);
            let got = head.step(&h, y, 0.05, &mut dh);
            assert!((got - want).abs() < 1e-9 * want.max(1.0), "y {y}: {got} vs {want}");
        }
    }

    #[test]
    fn hsm_learns_a_simple_mapping() {
        // h is a noisy one-hot of the target's "concept"; HSM should learn it
        let mut rng = Rng::new(7);
        let (n, d) = (40usize, 16usize);
        let counts = vec![1u64; n];
        let mut head = HsmHead::new(&counts, d, 6, &mut rng);
        let mut proto = vec![0.0f32; n * d];
        rng.fill_normal(&mut proto, 1.0);
        let mut dh = vec![0.0f32; d];
        let mut first = 0.0;
        let mut last = 0.0;
        for it in 0..4000 {
            let y = rng.below(n as u64) as u32;
            let h: Vec<f32> = proto[y as usize * d..(y as usize + 1) * d]
                .iter()
                .map(|&x| x + rng.normal_f32(0.0, 0.2))
                .collect();
            let loss = head.step(&h, y, 0.1, &mut dh);
            if it < 100 {
                first += loss / 100.0;
            }
            if it >= 3900 {
                last += loss / 100.0;
            }
        }
        assert!(last < first * 0.5, "HSM failed to learn: {first} -> {last}");
    }

    #[test]
    fn full_head_learns_better_than_hsm_on_hard_task() {
        // the §5.2 claim (Chen et al.): same budget, HSM converges worse.
        // "hard" = class identity cuts across the frequency-binned clusters.
        let mut rng = Rng::new(11);
        let (n, d) = (60usize, 12usize);
        let counts: Vec<u64> = (0..n as u64).map(|i| i * 3 + 1).collect();
        let mut hsm = HsmHead::new(&counts, d, 8, &mut rng);
        let mut full = FullHead::new(n, d, &mut rng);
        let mut proto = vec![0.0f32; n * d];
        rng.fill_normal(&mut proto, 0.7);
        let mut dh = vec![0.0f32; d];
        let gen = |rng: &mut Rng, proto: &[f32]| {
            let y = rng.below(n as u64) as u32;
            let h: Vec<f32> = proto[y as usize * d..(y as usize + 1) * d]
                .iter()
                .map(|&x| x + rng.normal_f32(0.0, 0.5))
                .collect();
            (y, h)
        };
        for _ in 0..6000 {
            let (y, h) = gen(&mut rng, &proto);
            hsm.step(&h, y, 0.08, &mut dh);
            full.step(&h, y, 0.08);
        }
        // evaluate both with the *true* model-agnostic CE
        let mut l_hsm = 0.0;
        let mut l_full = 0.0;
        for _ in 0..500 {
            let (y, h) = gen(&mut rng, &proto);
            l_hsm += -(hsm.prob(&h, y).max(1e-30)).ln();
            l_full += full.loss(&h, y);
        }
        l_hsm /= 500.0;
        l_full /= 500.0;
        assert!(
            l_full < l_hsm,
            "full softmax should converge below HSM: full {l_full} vs hsm {l_hsm}"
        );
    }
}
