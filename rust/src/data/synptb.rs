//! Synthetic Penn-Tree-Bank stand-in (DESIGN.md §3).
//!
//! A ground-truth first-order Markov language over `n` word types:
//!
//! * marginals follow Zipf(1.05) — natural-language-like skew (this is what
//!   makes the unigram sampler meaningful and uniform sampling bad);
//! * each word type has a sparse successor table (`succ_k` successors with
//!   geometric weights) blended with the global Zipf unigram:
//!   `P(next | prev) = λ · sparse(prev) + (1 − λ) · zipf` — context carries
//!   real signal (what the bigram sampler and the LSTM can exploit), with
//!   enough entropy that sampling distributions matter.
//!
//! The corpus is one long walk of this chain, split into train/valid, and
//! batched Zaremba-style: B parallel streams, length-T windows, targets
//! shifted by one.

use super::{Batch, Dataset};
use crate::runtime::Tensor;
use crate::sampler::CorpusStats;
use crate::util::rng::{AliasTable, Rng, Zipf};
use std::collections::BTreeMap;

/// Generated corpus + ground truth.
pub struct SynPtb {
    n_vocab: usize,
    batch: usize,
    seq_len: usize,
    train: Vec<u32>,
    valid: Vec<u32>,
}

impl SynPtb {
    /// Generate a corpus. `train_tokens`/`valid_tokens` are stream lengths.
    ///
    /// The default experiment scale (see coordinator::config) is 10k vocab /
    /// ~200k train tokens — the paper's PTB has 10k / ~1M; the ratio of
    /// steps to classes is preserved well enough for the bias phenomena.
    pub fn generate(
        n_vocab: usize,
        batch: usize,
        seq_len: usize,
        train_tokens: usize,
        valid_tokens: usize,
        seed: u64,
    ) -> SynPtb {
        assert!(n_vocab >= 4);
        let mut rng = Rng::new(seed ^ 0x5955_7eb1);
        let zipf = Zipf::new(n_vocab, 1.05);
        // map Zipf ranks to word ids with a fixed permutation so frequent
        // ids are scattered (catches id-vs-rank confusions downstream)
        let mut perm: Vec<u32> = (0..n_vocab as u32).collect();
        rng.shuffle(&mut perm);

        // sparse successor tables: succ_k successors, geometric weights
        let succ_k = 24.min(n_vocab);
        let lambda = 0.6;
        let mut succ: Vec<(Vec<u32>, AliasTable)> = Vec::with_capacity(n_vocab);
        for _ in 0..n_vocab {
            let mut set: Vec<u32> = Vec::with_capacity(succ_k);
            let mut weights: Vec<f64> = Vec::with_capacity(succ_k);
            let mut w = 1.0f64;
            for _ in 0..succ_k {
                set.push(perm[zipf.sample(&mut rng)]);
                weights.push(w);
                w *= 0.8;
            }
            let alias = AliasTable::new(&weights).expect("geometric weights valid");
            succ.push((set, alias));
        }

        let mut gen_stream = |len: usize, rng: &mut Rng| -> Vec<u32> {
            let mut out = Vec::with_capacity(len);
            let mut prev = perm[zipf.sample(rng)];
            for _ in 0..len {
                let next = if rng.bool(lambda) {
                    let (set, alias) = &succ[prev as usize];
                    set[alias.sample(rng)]
                } else {
                    perm[zipf.sample(rng)]
                };
                out.push(next);
                prev = next;
            }
            out
        };

        let train = gen_stream(train_tokens, &mut rng);
        let valid = gen_stream(valid_tokens, &mut rng);
        SynPtb { n_vocab, batch, seq_len, train, valid }
    }

    /// Zaremba-style batching of a stream: B parallel substreams, windows of
    /// T tokens, targets shifted by one.
    fn batches_of(&self, stream: &[u32]) -> Vec<Batch> {
        let (b, t) = (self.batch, self.seq_len);
        let per_stream = stream.len() / b;
        let windows = per_stream.saturating_sub(1) / t;
        let mut out = Vec::with_capacity(windows);
        for w in 0..windows {
            let mut tokens = Vec::with_capacity(b * t);
            let mut targets = Vec::with_capacity(b * t);
            let mut prev = Vec::with_capacity(b * t);
            for stream_i in 0..b {
                let base = stream_i * per_stream + w * t;
                for k in 0..t {
                    let tok = stream[base + k];
                    tokens.push(tok as i32);
                    targets.push(stream[base + k + 1] as i32);
                    // context preceding the *target* = current token
                    prev.push(tok);
                }
            }
            out.push(Batch {
                data: vec![Tensor::i32s(&[b, t], tokens), Tensor::i32s(&[b, t], targets.clone())],
                pos: targets,
                prev: Some(prev),
            });
        }
        out
    }

    pub fn train_tokens(&self) -> &[u32] {
        &self.train
    }
}

impl Dataset for SynPtb {
    fn name(&self) -> &str {
        "synptb"
    }

    fn n_classes(&self) -> usize {
        self.n_vocab
    }

    fn train_batches(&self, _epoch: usize) -> Vec<Batch> {
        // the stream is fixed; epochs revisit it (classic LM training)
        self.batches_of(&self.train)
    }

    fn eval_batches(&self) -> Vec<Batch> {
        self.batches_of(&self.valid)
    }

    fn stats(&self) -> CorpusStats {
        let mut counts = vec![0u64; self.n_vocab];
        for &t in &self.train {
            counts[t as usize] += 1;
        }
        // sparse bigram pair counts
        let mut maps: Vec<BTreeMap<u32, u64>> = vec![BTreeMap::new(); self.n_vocab];
        for pair in self.train.windows(2) {
            *maps[pair[0] as usize].entry(pair[1]).or_insert(0) += 1;
        }
        let bigram = maps
            .into_iter()
            .map(|m| m.into_iter().collect::<Vec<(u32, u64)>>())
            .collect();
        CorpusStats { class_counts: counts, bigram_counts: Some(bigram) }
    }

    fn is_lm(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> SynPtb {
        SynPtb::generate(500, 4, 10, 20_000, 2_000, 42)
    }

    #[test]
    fn deterministic_generation() {
        let a = SynPtb::generate(100, 2, 5, 1000, 100, 1);
        let b = SynPtb::generate(100, 2, 5, 1000, 100, 1);
        let c = SynPtb::generate(100, 2, 5, 1000, 100, 2);
        assert_eq!(a.train, b.train);
        assert_ne!(a.train, c.train);
    }

    #[test]
    fn zipf_skew_in_counts() {
        let ds = small();
        let stats = ds.stats();
        let mut counts = stats.class_counts.clone();
        counts.sort_unstable_by(|a, b| b.cmp(a));
        let top10: u64 = counts.iter().take(10).sum();
        let total: u64 = counts.iter().sum();
        assert_eq!(total as usize, ds.train.len());
        assert!(
            top10 as f64 > 0.15 * total as f64,
            "top-10 words should carry substantial mass: {top10}/{total}"
        );
    }

    #[test]
    fn bigram_structure_present() {
        // context must be predictive: average max successor prob >> unigram max
        let ds = small();
        let stats = ds.stats();
        let bigram = stats.bigram_counts.as_ref().unwrap();
        let mut predictive = 0.0;
        let mut rows = 0.0;
        for row in bigram.iter().filter(|r| r.iter().map(|&(_, c)| c).sum::<u64>() >= 20) {
            let total: u64 = row.iter().map(|&(_, c)| c).sum();
            let max: u64 = row.iter().map(|&(_, c)| c).max().unwrap();
            predictive += max as f64 / total as f64;
            rows += 1.0;
        }
        assert!(rows > 10.0, "need enough frequent contexts");
        let avg = predictive / rows;
        assert!(avg > 0.15, "successors should be predictable, got {avg}");
    }

    #[test]
    fn batch_targets_are_shifted_tokens() {
        let ds = small();
        let batches = ds.train_batches(0);
        assert!(!batches.is_empty());
        let b0 = &batches[0];
        let tokens = b0.data[0].as_i32().unwrap();
        let targets = b0.data[1].as_i32().unwrap();
        assert_eq!(tokens.len(), 40);
        // stream 0, window 0: tokens are train[0..10], targets train[1..11]
        for k in 0..10 {
            assert_eq!(tokens[k], ds.train[k] as i32);
            assert_eq!(targets[k], ds.train[k + 1] as i32);
        }
        // prev context equals the input token at each position
        assert_eq!(b0.prev.as_ref().unwrap()[3], ds.train[3]);
        // pos == flattened targets
        assert_eq!(b0.pos, targets.to_vec());
    }

    #[test]
    fn windows_cover_stream_without_overlap() {
        let ds = SynPtb::generate(50, 2, 5, 200, 50, 3);
        let batches = ds.train_batches(0);
        // per_stream = 100, windows = 99/5 = 19
        assert_eq!(batches.len(), 19);
        let t1 = batches[1].data[0].as_i32().unwrap()[0];
        assert_eq!(t1, ds.train[5] as i32, "window 1 starts at offset 5");
    }
}
