//! Random-feature kernel sampling — low-bias exp-kernel proposals through
//! the whole tree/shard/serve stack.
//!
//! The paper's quadratic kernel (eq. 10) is a fixed-shape surrogate for
//! `exp(o)`: its bias floor is set by how badly `αo² + 1` tracks the
//! exponential tails, and its explicit feature map costs `D = d² + 1`.
//! *Sampled Softmax with Random Fourier Features* (Rawat et al., 2019)
//! approximates the exponential kernel directly with **positive random
//! features**:
//!
//! ```text
//! φ(a) = exp(−‖a‖²/2) / √D · [exp(ω_1ᵀa), …, exp(ω_Dᵀa)],   ω_i ~ N(0, I_d)
//! ```
//!
//! so `E_ω[⟨φ(h), φ(w)⟩] = exp(hᵀw)` (the softmax kernel itself), every
//! feature is **strictly positive** (node masses stay ≥ 0 — the tree's
//! zero-mass guards compose unchanged), and `D` is a *tunable knob*: larger
//! `D` means lower variance around the exp kernel, smaller `D` means
//! cheaper nodes (`D ≪ d²` beats the quadratic map's footprint). The
//! `benches/ablation_rff_dim.rs` sweep ablates `D ∈ {d, 2d, 4d, d²}` the
//! way the paper ablates `m`.
//!
//! Within one draw the sampler is *exact* for the realized random kernel
//! `K̂(a,b) = ⟨φ(a), φ(b)⟩` — reported q values equal `K̂/Σ K̂` in closed
//! form, like every other [`FeatureMap`] in the tree — and `K̂` is an
//! unbiased, concentrating estimate of `exp(aᵀb)`. All randomness is
//! **frozen at construction** from [`RffConfig::seed`]: the same config
//! always draws the same `ω`, and cloning shares it, so shards, snapshot
//! replicas, and replay all score with the identical kernel (see the
//! shard-consistency tests).
//!
//! * [`RffConfig`] — dimension/seed/variant; the determinism contract.
//! * [`PositiveRffMap`] — the [`FeatureMap`] implementation.
//! * [`orthogonal`] — blockwise-orthogonalized `ω` draws (structured
//!   orthogonal random features: same marginals, lower kernel-estimate
//!   variance).
//!
//! [`FeatureMap`]: crate::sampler::kernel::FeatureMap

pub mod config;
pub mod map;
pub mod orthogonal;

pub use config::{RffConfig, RFF_BUILD_SEED};
pub use map::PositiveRffMap;

#[cfg(test)]
mod tests;
