#!/usr/bin/env python3
"""Port of the pipelined training engine (coordinator/pipeline.rs +
trainer.rs + serve/reader_sampler.rs), validated against the same
properties the Rust tests pin.

No rust toolchain exists in the build container (see
.claude/skills/verify/SKILL.md), so — as in PRs 1-4 — the algorithmic core
of the change is ported faithfully and property-checked here. The kernel
tree and snapshot publisher are imported from serve_port_check.py (the
line-for-line ports of tree.rs / snapshot.rs); this file adds the
pipeline-specific pieces:

  1. one-tree unification: a trainer whose sampler reads *published*
     snapshot generations (SnapshotSampler) reproduces the legacy
     private-tree sequential trainer BITWISE — identical draws, identical
     q, identical parameter trajectory — while running exactly ONE tree
     update sweep per step (legacy ran two when serving was on)
  2. depth-2 FIFO schedule: sample(t+1) is enqueued before publish(t), so
     step t samples generation t-1 (depth 1 samples generation t) — the
     staleness is exactly one generation, deterministic, and every
     reported q equals the exact eq. (8) probability under the generation
     actually sampled (the generation-tagging property that keeps eq. (2)
     an exact estimator)
  3. pinned snapshots: the publisher's reclaim/replay never mutates a
     generation the in-flight sampling stage still holds
  4. staleness regression: depth-2 quadratic sampling still beats uniform
     on the tiny ordering task (stale adaptivity >> no adaptivity)

Run: python3 python/tools/pipeline_port_check.py
"""
import math
import os
import random
import sys

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from serve_port_check import Publisher, QuadraticMap, Tree  # noqa: E402

GOLDEN = 0x9E3779B97F4A7C15
MASK = 0xFFFFFFFFFFFFFFFF


def row_rng(step_seed, row):
    """Port of sampler::row_rng's per-row stream derivation."""
    return random.Random((step_seed ^ ((row * GOLDEN) & MASK)) & MASK)


# --- the toy model -----------------------------------------------------
# Output-embedding-only classifier: example = (h, y); logits o_j = <h, w_j>.
# The "device step" is the sampled-softmax SGD step of the fused artifact:
# softmax over the sampled set with eq. (2) corrections ln(m q) on the
# negatives, gradient only on the sampled rows. Deterministic, shared by
# every trainer variant below — so trajectory differences can only come
# from the sampling/publish schedule under test.


def make_task(n, d, n_train, n_eval, seed):
    rng = np.random.default_rng(seed)
    W0 = (0.1 * rng.standard_normal((n, d))).astype(np.float32)
    centers = (rng.standard_normal((n, d))).astype(np.float32)
    def gen(count):
        ys = rng.integers(0, n, count)
        hs = (centers[ys] + 0.3 * rng.standard_normal((count, d))).astype(np.float32)
        return list(zip(hs, ys))
    return W0, gen(n_train), gen(n_eval)


def device_step(W, batch, draws, m, lr):
    """One fused sampled-softmax SGD step; returns (loss, changed classes)."""
    grad = {}
    loss = 0.0
    for (h, y), row in zip(batch, draws):
        s_classes = [int(y)] + [int(c) for c, _ in row]
        corr = [0.0] + [math.log(m * q) for _, q in row]
        logits = np.array(
            [float(np.dot(h, W[c])) - corr[k] for k, c in enumerate(s_classes)]
        )
        logits -= logits.max()
        p = np.exp(logits)
        p /= p.sum()
        loss += -math.log(max(p[0], 1e-300))
        for k, c in enumerate(s_classes):
            g = (p[k] - (1.0 if k == 0 else 0.0)) * h
            grad[c] = grad.get(c, 0.0) + g
    for c, g in grad.items():
        W[c] = (W[c] - (lr / len(batch)) * g).astype(np.float32)
    changed = sorted(grad.keys())
    return loss / len(batch), changed


def full_ce(W, examples):
    total = 0.0
    for h, y in examples:
        logits = W @ h
        logits = logits - logits.max()
        p = np.exp(logits)
        p /= p.sum()
        total += -math.log(max(p[int(y)], 1e-300))
    return total / len(examples)


def draw_batch(tree, batch, m, step_seed):
    """Port of the tree sampler's batch engine over row_rng streams."""
    out = []
    for i, (h, _y) in enumerate(batch):
        rng = row_rng(step_seed, i)
        s = tree.begin_example(h)
        row = [tree.draw(h, s, rng) for _ in range(m)]
        out.append(row)
    return out


def draw_batch_uniform(n, batch, m, step_seed):
    out = []
    for i in range(len(batch)):
        rng = row_rng(step_seed, i)
        out.append([(rng.randrange(n), 1.0 / n) for _ in range(m)])
    return out


def batches_of(train, bs):
    return [train[i : i + bs] for i in range(0, len(train) - bs + 1, bs)]


# --- trainer variants --------------------------------------------------
def train_legacy(W0, train, m, lr, bs, steps, alpha, with_serving, trace):
    """The pre-pipeline sequential loop: PRIVATE sampler tree, plus (when
    serving is on) a second publisher mirror receiving the same rows —
    the duplicated per-step tree work this PR deletes."""
    n, d = W0.shape
    W = W0.copy()
    sampler = Tree(QuadraticMap(d, alpha), n, 4)
    sampler.reset(W)
    publisher = Publisher(Tree(QuadraticMap(d, alpha), n, 4)) if with_serving else None
    if publisher:
        publisher.shadow.reset(W)
        publisher.current["tree"].reset(W)
    seed_rng = random.Random(0xC0FFEE)
    batches = batches_of(train, bs)
    sweeps_per_step = []
    for t in range(steps):
        batch = batches[t % len(batches)]
        step_seed = seed_rng.getrandbits(64)
        draws = draw_batch(sampler, batch, m, step_seed)
        trace.append([(c, q) for row in draws for c, q in row])
        _loss, changed = device_step(W, batch, draws, m, lr)
        rows = [list(W[c]) for c in changed]
        sweeps = 0
        sampler.update_many(changed, rows)  # sweep 1: the private tree
        sweeps += 1
        if publisher:
            publisher.publish(changed, rows)  # sweep 2: the serve mirror
            sweeps += 1
        sweeps_per_step.append(sweeps)
    return W, sweeps_per_step


def train_unified(W0, train, m, lr, bs, steps, alpha, depth, trace, gens=None,
                  q_exact_check=False):
    """The pipelined engine: ONE tree inside the publisher; the sampler
    reads pinned published generations. depth 1 = sequential; depth 2 =
    the FIFO schedule (sample t+1 enqueued before publish t)."""
    n, d = W0.shape
    W = W0.copy()
    publisher = Publisher(Tree(QuadraticMap(d, alpha), n, 4))
    publisher.shadow.reset(W)
    publisher.current["tree"].reset(W)
    seed_rng = random.Random(0xC0FFEE)
    batches = batches_of(train, bs)

    def schedule(t):
        # refresh_snapshots: pin the freshest published generation; FIFO
        # places this call after every publish enqueued before it
        snap = publisher.current
        snap["pins"] += 1
        batch = batches[t % len(batches)]
        step_seed = seed_rng.getrandbits(64)
        draws = draw_batch(snap["tree"], batch, m, step_seed)
        if q_exact_check:
            # generation tagging: every reported q must be the exact
            # eq. (8) probability under the PINNED generation — checked at
            # draw time, against the tree the draws actually used
            fmap = snap["tree"].map
            for (h, _y), row in zip(batch, draws):
                z = sum(fmap.kernel(h, snap["tree"].emb[j]) for j in range(n))
                for c, q in row:
                    want = fmap.kernel(h, snap["tree"].emb[c]) / z
                    assert abs(q - want) <= 1e-9 * max(want, 1e-12), (t, c, q, want)
        return {"step": t, "batch": batch, "draws": draws, "snap": snap}

    sweeps_per_step = []
    pending = None
    for t in range(steps):
        if pending is None:
            pending = schedule(t)
        outcome = pending
        pending = None
        assert outcome["step"] == t
        if gens is not None:
            gens.append(outcome["snap"]["gen"])
        if depth >= 2 and t + 1 < steps:
            # enqueued BEFORE publish(t): sees generations <= t-1 only
            pending = schedule(t + 1)
        draws = outcome["draws"]
        trace.append([(c, q) for row in draws for c, q in row])
        _loss, changed = device_step(W, outcome["batch"], draws, m, lr)
        rows = [list(W[c]) for c in changed]
        publisher.publish(changed, rows)  # the single tree sweep + publish
        sweeps_per_step.append(1)
        outcome["snap"]["pins"] -= 1
    if pending is not None:
        pending["snap"]["pins"] -= 1
    assert publisher.stats["publishes"] == steps
    return W, sweeps_per_step


def train_uniform(W0, train, m, lr, bs, steps):
    n, _d = W0.shape
    W = W0.copy()
    seed_rng = random.Random(0xC0FFEE)
    batches = batches_of(train, bs)
    for t in range(steps):
        batch = batches[t % len(batches)]
        draws = draw_batch_uniform(n, batch, m, seed_rng.getrandbits(64))
        device_step(W, batch, draws, m, lr)
    return W


# --- checks ------------------------------------------------------------
def check_depth1_equivalence():
    n, d, m, bs, steps, alpha = 40, 5, 4, 8, 30, 60.0
    W0, train, _ = make_task(n, d, 64, 0, seed=3)
    tr_legacy, tr_unified = [], []
    W_legacy, sweeps_legacy = train_legacy(
        W0, train, m, 0.3, bs, steps, alpha, with_serving=True, trace=tr_legacy
    )
    W_unified, sweeps_unified = train_unified(
        W0, train, m, 0.3, bs, steps, alpha, depth=1, trace=tr_unified
    )
    assert tr_legacy == tr_unified, "draw streams diverged (classes or q)"
    assert np.array_equal(W_legacy, W_unified), "parameter trajectories diverged"
    # the satellite: legacy-with-serving swept two trees per step, the
    # unified engine exactly one
    assert all(s == 2 for s in sweeps_legacy)
    assert all(s == 1 for s in sweeps_unified)
    print("  depth-1 unified == legacy sequential (bitwise draws + params); "
          "1 sweep/step vs legacy 2: OK")


def check_depth2_staleness_and_tagging():
    n, d, m, bs, steps, alpha = 48, 5, 4, 8, 40, 60.0
    W0, train, _ = make_task(n, d, 64, 0, seed=5)
    gens1, gens2 = [], []
    tr1, tr2a, tr2b = [], [], []
    train_unified(W0, train, m, 0.3, bs, steps, alpha, depth=1, trace=tr1,
                  gens=gens1, q_exact_check=True)
    W2a, _ = train_unified(W0, train, m, 0.3, bs, steps, alpha, depth=2,
                           trace=tr2a, gens=gens2, q_exact_check=True)
    W2b, _ = train_unified(W0, train, m, 0.3, bs, steps, alpha, depth=2,
                           trace=tr2b)
    # determinism: the schedule is FIFO, not timing — reruns are identical
    assert tr2a == tr2b and np.array_equal(W2a, W2b), "depth-2 not deterministic"
    # staleness exactly one generation: depth 1 samples gen t, depth 2
    # samples gen max(t-1, 0)
    assert gens1 == list(range(steps)), gens1[:6]
    assert gens2 == [0] + list(range(steps - 1)), gens2[:6]
    # stale q, not wrong q: the very first step (both pin gen 0) agrees,
    # later steps differ because adaptivity lags
    assert tr1[0] == tr2a[0], "step 0 should be identical across depths"
    assert tr1[5] != tr2a[5], "depth 2 should sample a stale distribution"
    print("  depth-2 FIFO: deterministic, staleness exactly 1 generation, "
          "q exact under the pinned generation: OK")


def check_staleness_regression():
    # the ordering task: adaptive quadratic sampling (even one step stale)
    # must beat uniform proposals at small m (2 of 96 classes; margin
    # ~0.30 nats at these settings, asserted at a third of that)
    n, d, m, bs, steps, alpha = 96, 6, 2, 10, 300, 60.0
    W0, train, evalset = make_task(n, d, 120, 200, seed=11)
    tr = []
    W_d2, _ = train_unified(W0, train, m, 0.5, bs, steps, alpha, depth=2, trace=tr)
    tr2 = []
    W_d1, _ = train_unified(W0, train, m, 0.5, bs, steps, alpha, depth=1, trace=tr2)
    W_uni = train_uniform(W0, train, m, 0.5, bs, steps)
    ce0 = full_ce(W0, evalset)
    ce_d1 = full_ce(W_d1, evalset)
    ce_d2 = full_ce(W_d2, evalset)
    ce_uni = full_ce(W_uni, evalset)
    assert ce_d2 < ce0 - 0.5, f"depth-2 quadratic failed to learn: {ce0} -> {ce_d2}"
    assert ce_d2 < ce_uni - 0.1, f"stale quadratic {ce_d2} vs uniform {ce_uni}"
    assert abs(ce_d2 - ce_d1) < 0.25, f"depth-2 diverged from depth-1: {ce_d2} vs {ce_d1}"
    print(f"  staleness regression: depth-2 quadratic CE {ce_d2:.4f} < "
          f"uniform {ce_uni:.4f} (depth-1 {ce_d1:.4f}, init {ce0:.4f}): OK")


def check_pinned_generation_safety():
    # while a sampling stage holds a pinned generation, publishes must not
    # mutate it (the reclaim path skips pinned arenas)
    n, d, alpha = 24, 4, 60.0
    rng = np.random.default_rng(7)
    W = (0.2 * rng.standard_normal((n, d))).astype(np.float32)
    publisher = Publisher(Tree(QuadraticMap(d, alpha), n, 4))
    publisher.shadow.reset(W)
    publisher.current["tree"].reset(W)
    h = rng.standard_normal(d).astype(np.float32)
    snap = publisher.current
    snap["pins"] += 1
    before_z = snap["tree"].z.copy()
    before_q = [
        snap["tree"].map.kernel(h, snap["tree"].emb[c]) / snap["tree"].partition(
            snap["tree"].begin_example(h)["phi"]
        )
        for c in range(n)
    ]
    for t in range(8):
        classes = sorted({(3 * t + k) % n for k in range(3)})
        rows = [list(rng.standard_normal(d).astype(np.float32)) for _ in classes]
        publisher.publish(classes, rows)
    assert np.array_equal(snap["tree"].z, before_z), "pinned generation mutated"
    after_q = [
        snap["tree"].map.kernel(h, snap["tree"].emb[c]) / snap["tree"].partition(
            snap["tree"].begin_example(h)["phi"]
        )
        for c in range(n)
    ]
    assert before_q == after_q
    snap["pins"] -= 1
    assert publisher.stats["publishes"] == 8
    print("  pinned generations survive 8 publishes bit-identical: OK")


if __name__ == "__main__":
    print("pipeline port checks:")
    check_depth1_equivalence()
    check_depth2_staleness_and_tagging()
    check_pinned_generation_safety()
    check_staleness_regression()
    print("all pipeline port checks passed")
