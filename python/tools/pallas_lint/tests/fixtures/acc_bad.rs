// pallas-lint fixture — MUST trip ACC (raw float reduction outside ops::).
// Scanned by the self-tests under a rust/src/sampler/ logical path.

pub fn dot_by_hand(a: &[f32], b: &[f32]) -> f64 {
    let mut acc = 0.0f64;
    for i in 0..a.len() {
        acc += (a[i] * b[i]) as f64;
    }
    acc
}
