//! YouTube-style next-watch generator (DESIGN.md §3).
//!
//! The paper's YouTube datasets are private; what the experiments need from
//! them is (a) 10k/100k classes with skewed popularity and (b) an
//! input-dependent output distribution a model can learn. This generator
//! provides both with a known ground truth:
//!
//! * items get Zipf(1.1) global popularity;
//! * users belong to one of `n_clusters` taste clusters; each cluster has
//!   its own alias table over a cluster-specific Zipf reordering of a
//!   catalog slice;
//! * a user's next watch mixes taste (with their cluster's table) and
//!   global popularity: `P(i | u) = μ · cluster_u(i) + (1 − μ) · pop(i)`;
//! * the observable features are a noisy cluster one-hot (so the MLP can
//!   infer the cluster) and the previous three watches.
//!
//! Watches are generated as short per-user sessions so `prev` is a real
//! history, like the paper's "three previously watched videos".

use super::{Batch, Dataset};
use crate::runtime::Tensor;
use crate::sampler::CorpusStats;
use crate::util::rng::{AliasTable, Rng, Zipf};

/// One training example.
#[derive(Clone, Debug)]
struct Event {
    user_feat: Vec<f32>,
    prev: [u32; 3],
    pos: u32,
}

/// Generated dataset.
pub struct YouTube {
    n_items: usize,
    n_features: usize,
    batch: usize,
    train: Vec<Event>,
    valid: Vec<Event>,
}

impl YouTube {
    /// Generate `train_events` + `train_events/10` validation events over an
    /// `n_items` catalog. `n_features` is the user-feature width (must match
    /// the model config's `n_user_features`).
    pub fn generate(
        n_items: usize,
        n_features: usize,
        train_events: usize,
        valid_events: usize,
        batch: usize,
        seed: u64,
    ) -> YouTube {
        assert!(n_items >= 8 && n_features >= 2);
        let mut rng = Rng::new(seed ^ 0x07be_11aa);
        let n_clusters = n_features; // one taste dimension per feature
        let pop = Zipf::new(n_items, 1.1);
        let mut perm: Vec<u32> = (0..n_items as u32).collect();
        rng.shuffle(&mut perm);

        // per-cluster taste: a Zipf over a rotated slice of the catalog
        let slice = (n_items / n_clusters).max(4);
        let taste_zipf = Zipf::new(slice, 1.2);
        let cluster_base: Vec<usize> = (0..n_clusters).map(|c| c * slice % n_items).collect();

        let mu = 0.65;
        let gen_events = |count: usize, rng: &mut Rng| -> Vec<Event> {
            let mut events = Vec::with_capacity(count);
            'outer: loop {
                // one user session of 8 watches
                let cluster = rng.range(0, n_clusters);
                let mut feat = vec![0.0f32; n_features];
                for (i, f) in feat.iter_mut().enumerate() {
                    *f = if i == cluster { 1.0 } else { 0.0 } + rng.normal_f32(0.0, 0.25);
                }
                let mut draw = |rng: &mut Rng| -> u32 {
                    if rng.bool(mu) {
                        let off = taste_zipf.sample(rng);
                        perm[(cluster_base[cluster] + off) % n_items]
                    } else {
                        perm[pop.sample(rng)]
                    }
                };
                let mut hist = [draw(rng), draw(rng), draw(rng)];
                for _ in 0..8 {
                    let next = draw(rng);
                    events.push(Event { user_feat: feat.clone(), prev: hist, pos: next });
                    hist = [hist[1], hist[2], next];
                    if events.len() >= count {
                        break 'outer;
                    }
                }
            }
            events
        };

        let train = gen_events(train_events, &mut rng);
        let valid = gen_events(valid_events, &mut rng);
        YouTube { n_items, n_features, batch, train, valid }
    }

    fn batches_of(&self, events: &[Event]) -> Vec<Batch> {
        let b = self.batch;
        let n_batches = events.len() / b;
        let mut out = Vec::with_capacity(n_batches);
        for i in 0..n_batches {
            let chunk = &events[i * b..(i + 1) * b];
            let mut user = Vec::with_capacity(b * self.n_features);
            let mut prev = Vec::with_capacity(b * 3);
            let mut pos = Vec::with_capacity(b);
            for e in chunk {
                user.extend_from_slice(&e.user_feat);
                prev.extend(e.prev.iter().map(|&x| x as i32));
                pos.push(e.pos as i32);
            }
            out.push(Batch {
                data: vec![
                    Tensor::f32s(&[b, self.n_features], user),
                    Tensor::i32s(&[b, 3], prev),
                    Tensor::i32s(&[b], pos.clone()),
                ],
                pos,
                prev: None,
            });
        }
        out
    }
}

impl Dataset for YouTube {
    fn name(&self) -> &str {
        "youtube"
    }

    fn n_classes(&self) -> usize {
        self.n_items
    }

    fn train_batches(&self, _epoch: usize) -> Vec<Batch> {
        self.batches_of(&self.train)
    }

    fn eval_batches(&self) -> Vec<Batch> {
        self.batches_of(&self.valid)
    }

    fn stats(&self) -> CorpusStats {
        let mut counts = vec![0u64; self.n_items];
        for e in &self.train {
            counts[e.pos as usize] += 1;
        }
        CorpusStats { class_counts: counts, bigram_counts: None }
    }

    fn is_lm(&self) -> bool {
        false
    }
}

/// Expose an alias-table check used by tests & the quickstart example:
/// popularity sampling must roughly match empirical watch counts.
pub fn popularity_alias(stats: &CorpusStats) -> Option<AliasTable> {
    let w: Vec<f64> = stats.class_counts.iter().map(|&c| c as f64 + 1.0).collect();
    AliasTable::new(&w)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> YouTube {
        YouTube::generate(512, 8, 8_000, 800, 16, 5)
    }

    #[test]
    fn deterministic_and_sized() {
        let a = small();
        let b = small();
        assert_eq!(a.train.len(), 8_000);
        assert_eq!(a.valid.len(), 800);
        assert_eq!(a.train[17].pos, b.train[17].pos);
        assert_eq!(a.train[17].user_feat, b.train[17].user_feat);
    }

    #[test]
    fn popularity_is_skewed() {
        let ds = small();
        let stats = ds.stats();
        let mut counts = stats.class_counts.clone();
        counts.sort_unstable_by(|a, b| b.cmp(a));
        let top20: u64 = counts.iter().take(20).sum();
        let total: u64 = counts.iter().sum();
        assert!(top20 as f64 > 0.1 * total as f64, "top items should dominate: {top20}/{total}");
        assert!(stats.bigram_counts.is_none());
    }

    #[test]
    fn features_identify_clusters() {
        // the argmax of the user features must correlate with which slice of
        // the catalog the user watches (i.e. features carry signal)
        let ds = small();
        let mut agree = 0usize;
        let mut total = 0usize;
        for e in ds.train.iter().take(2000) {
            let cluster = e
                .user_feat
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            // crude: count it as agreement if another user with the same
            // argmax watches the same item more often than chance would
            total += 1;
            agree += usize::from(cluster < 8); // placeholder always true
        }
        assert_eq!(agree, total); // structural sanity (features exist, bounded)
        // real signal check: events from the same cluster share items more
        // than events from different clusters
        let mut same = 0.0;
        let mut diff = 0.0;
        let (mut same_n, mut diff_n) = (0.0f64, 0.0f64);
        let events: Vec<_> = ds.train.iter().take(1500).collect();
        for pair in events.chunks(2) {
            if pair.len() < 2 {
                break;
            }
            let c0 = argmax(&pair[0].user_feat);
            let c1 = argmax(&pair[1].user_feat);
            let overlap = f64::from(pair[0].pos == pair[1].pos);
            if c0 == c1 {
                same += overlap;
                same_n += 1.0;
            } else {
                diff += overlap;
                diff_n += 1.0;
            }
        }
        let p_same = same / same_n.max(1.0);
        let p_diff = diff / diff_n.max(1.0);
        assert!(
            p_same > p_diff,
            "same-cluster users should collide on items more: {p_same} vs {p_diff}"
        );
    }

    fn argmax(xs: &[f32]) -> usize {
        xs.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0
    }

    #[test]
    fn history_rolls_forward() {
        let ds = small();
        // within a session, the previous event's pos enters the next prev
        let mut found = false;
        for w in ds.train.windows(2).take(500) {
            if w[0].user_feat == w[1].user_feat {
                assert_eq!(w[1].prev[2], w[0].pos, "history must roll");
                found = true;
            }
        }
        assert!(found, "sessions should span consecutive events");
    }

    #[test]
    fn batch_layout() {
        let ds = small();
        let batches = ds.train_batches(0);
        assert_eq!(batches.len(), 8_000 / 16);
        let b0 = &batches[0];
        assert_eq!(b0.data[0].shape(), &[16, 8]);
        assert_eq!(b0.data[1].shape(), &[16, 3]);
        assert_eq!(b0.data[2].shape(), &[16]);
        assert_eq!(b0.data[2].as_i32().unwrap(), b0.pos.as_slice());
    }
}
