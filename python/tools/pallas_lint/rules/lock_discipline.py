"""LOCK — static lock-acquisition graph + pinned-snapshot discipline.

Eight files hold Mutexes (batcher queue, snapshot stores, the pinned
reader set, scratch pools, the shared publisher). The serving design
stays deadlock-free by construction: every guard is scoped to one short
critical section and no lock is taken while another is held. This rule
keeps it that way mechanically:

* every `.lock()` site is collected, with the receiver chain as the lock
  identity (`self.queue` -> `queue`), and guard lifetimes are tracked
  lexically (`let g = x.lock()...` lives to end of scope or `drop(g)`;
  an un-bound guard lives to the end of its statement);
* acquiring lock B while lock A is held adds edge A -> B to a global
  acquisition graph; a cycle in that graph is a potential deadlock and
  fails the pass. Acquiring A while A is held is reported directly
  (std::sync::Mutex self-deadlock);
* acquiring any lock while a pinned `SnapshotReader` generation binding
  (`let s = reader.pinned()/current()...`) is live is flagged: holding a
  pinned generation across a lock acquisition lets one slow/blocked
  reader degrade every publish to a clone (the PR 2 head-of-line
  regression) and inverts the wait-free-reader design.

The analysis is lexical (per function body); cross-function acquisition
chains are out of scope and covered by the module docs' ownership rules.
"""

from __future__ import annotations

from pallas_lint.frontend import IDENT, PUNCT, SourceFile, snippet
from pallas_lint.rules import Finding, ProjectRule


def _receiver_chain(code, j: int) -> list:
    """Receiver segments of the call at code[j] (j = method ident whose
    preceding token is `.`), walked backwards over idents, `.`/`::` and
    balanced `()`/`[]` groups."""
    k = j - 2
    parts: list = []
    while k >= 0:
        t = code[k]
        if t.kind == PUNCT and t.text == ")":
            depth = 0
            while k >= 0:
                if code[k].kind == PUNCT and code[k].text == ")":
                    depth += 1
                elif code[k].kind == PUNCT and code[k].text == "(":
                    depth -= 1
                    if depth == 0:
                        break
                k -= 1
            k -= 1
            if k >= 0 and code[k].kind == IDENT:
                parts.append(code[k].text + "()")
                k -= 1
            else:
                break
        elif t.kind == PUNCT and t.text == "]":
            depth = 0
            while k >= 0:
                if code[k].kind == PUNCT and code[k].text == "]":
                    depth += 1
                elif code[k].kind == PUNCT and code[k].text == "[":
                    depth -= 1
                    if depth == 0:
                        break
                k -= 1
            k -= 1
            continue
        elif t.kind == IDENT:
            parts.append(t.text)
            k -= 1
        else:
            break
        if k >= 0 and code[k].kind == PUNCT and code[k].text == ".":
            k -= 1
            continue
        if k >= 1 and code[k].text == ":" and code[k - 1].text == ":":
            k -= 2
            continue
        break
    parts.reverse()
    return parts


def _lock_id(parts: list) -> str:
    parts = [p for p in parts if p != "self"]
    return ".".join(parts) if parts else "<anon>"


def _stmt_end(code, j: int) -> int:
    """Index of the `;` ending the statement containing code[j] (or the
    index where the enclosing block closes)."""
    depth = 0
    k = j
    while k < len(code):
        t = code[k]
        if t.kind == PUNCT:
            if t.text in "([{":
                depth += 1
            elif t.text in ")]":
                depth -= 1
            elif t.text == "}":
                depth -= 1
                if depth < 0:
                    return k
            elif t.text == ";" and depth <= 0:
                return k
        k += 1
    return len(code) - 1


def _let_binding(code, recv_start: int):
    """If the statement holding the expression starting at recv_start is
    `let [mut] NAME = ...`, return NAME."""
    k = recv_start - 1
    if k >= 0 and code[k].kind == PUNCT and code[k].text == "=":
        k -= 1
        if k >= 0 and code[k].kind == IDENT:
            name = code[k].text
            k -= 1
            if k >= 0 and code[k].kind == IDENT and code[k].text == "mut":
                k -= 1
            if k >= 0 and code[k].kind == IDENT and code[k].text == "let":
                return name
    return None


class LockDiscipline(ProjectRule):
    id = "LOCK"
    name = "lock-discipline"
    summary = "lock-order cycles, double-locks, locks under pinned snapshots"
    contract = (
        "serving concurrency design (README 'Online serving'): one short "
        "critical section per guard, no nested lock acquisition, and never "
        "a lock while a pinned SnapshotReader generation is held (wait-free "
        "readers; publisher reclaim must not block on readers)"
    )

    def applies(self, relpath: str) -> bool:
        return relpath.startswith("rust/src/")

    def check_project(self, files: dict, extra: dict) -> list[Finding]:
        findings: list[Finding] = []
        edges: dict = {}  # (held, acquired) -> (file, line, snippet)

        for sf in files.values():
            if not self.applies(sf.path):
                continue
            for fn in sf.functions():
                if sf.in_test(fn.start_line):
                    continue
                self._walk_function(sf, fn, edges, findings)

        # cycle detection over the acquisition graph
        graph: dict = {}
        for held, acquired in edges:
            graph.setdefault(held, set()).add(acquired)
        for cycle in _find_cycles(graph):
            first = min(
                (e for e in edges if e[0] in cycle and e[1] in cycle),
                key=lambda e: edges[e][:2],
            )
            f, line, snip = edges[first]
            findings.append(
                Finding(
                    rule=self.id,
                    file=f,
                    line=line,
                    message=(
                        "lock-acquisition cycle "
                        + " -> ".join(cycle + [cycle[0]])
                        + " — a deadlock is reachable if these sections run "
                        "concurrently; impose one global acquisition order"
                    ),
                    snippet=snip,
                )
            )
        return findings

    def _walk_function(self, sf: SourceFile, fn, edges: dict, findings: list) -> None:
        code = sf.code
        depth = 0
        # each guard: [kind, lock_id/var, var, declared_depth, until_idx]
        guards: list = []
        j = fn.body_open
        while j <= fn.body_close:
            t = code[j]
            if t.kind == PUNCT and t.text == "{":
                depth += 1
            elif t.kind == PUNCT and t.text == "}":
                depth -= 1
                guards = [g for g in guards if g["depth"] <= depth]
            # expire temporary guards at their statement end
            guards = [g for g in guards if g["until"] is None or j <= g["until"]]
            # drop(var) releases a guard early
            if (
                t.kind == IDENT
                and t.text == "drop"
                and j + 2 < len(code)
                and code[j + 1].text == "("
                and code[j + 2].kind == IDENT
            ):
                victim = code[j + 2].text
                guards = [g for g in guards if g["var"] != victim]
            # a method call token preceded by `.`
            if (
                t.kind == IDENT
                and j > 0
                and code[j - 1].kind == PUNCT
                and code[j - 1].text == "."
                and j + 1 < len(code)
                and code[j + 1].kind == PUNCT
                and code[j + 1].text == "("
            ):
                if t.text == "lock":
                    parts = _receiver_chain(code, j)
                    lock = _lock_id(parts)
                    recv_start = j - 1 - _chain_token_len(code, j)
                    var = _let_binding(code, recv_start)
                    line, snip = t.line, snippet(sf, t.line)
                    for g in guards:
                        if g["kind"] == "pinned":
                            findings.append(
                                Finding(
                                    rule=self.id,
                                    file=sf.path,
                                    line=line,
                                    message=(
                                        f"`{lock}` locked while the pinned snapshot "
                                        f"binding `{g['var']}` is live — release the "
                                        "pinned generation before taking locks "
                                        "(wait-free reader contract)"
                                    ),
                                    snippet=snip,
                                )
                            )
                        elif g["lock"] == lock:
                            findings.append(
                                Finding(
                                    rule=self.id,
                                    file=sf.path,
                                    line=line,
                                    message=(
                                        f"`{lock}` locked while already held "
                                        f"(guard `{g['var'] or '<temp>'}` from line "
                                        f"{g['line']}) — std::sync::Mutex "
                                        "self-deadlocks on re-acquisition"
                                    ),
                                    snippet=snip,
                                )
                            )
                        else:
                            edges.setdefault(
                                (g["lock"], lock), (sf.path, line, snip)
                            )
                    guards.append(
                        {
                            "kind": "lock",
                            "lock": lock,
                            "var": var,
                            "depth": depth,
                            "line": line,
                            "until": None if var else _stmt_end(code, j),
                        }
                    )
                elif t.text in ("pinned", "current"):
                    recv = _receiver_chain(code, j)
                    # only track let-bound pinned generations; a bare
                    # `r.current();` refresh releases at statement end
                    stmt_let = _enclosing_let(code, j, fn.body_open)
                    if stmt_let is not None and recv:
                        guards.append(
                            {
                                "kind": "pinned",
                                "lock": None,
                                "var": stmt_let,
                                "depth": depth,
                                "line": t.line,
                                "until": None,
                            }
                        )
            j += 1


def _chain_token_len(code, j: int) -> int:
    """Token count of the receiver chain before `.lock` at j (approximate:
    walk back over idents, dots, `::` and balanced groups)."""
    k = j - 2
    start = k
    while k >= 0:
        t = code[k]
        if t.kind == PUNCT and t.text in ")]":
            close = t.text
            open_ = "(" if close == ")" else "["
            depth = 0
            while k >= 0:
                if code[k].kind == PUNCT and code[k].text == close:
                    depth += 1
                elif code[k].kind == PUNCT and code[k].text == open_:
                    depth -= 1
                    if depth == 0:
                        break
                k -= 1
            k -= 1
            continue
        if t.kind == IDENT:
            k -= 1
            if k >= 0 and code[k].kind == PUNCT and code[k].text == ".":
                k -= 1
                continue
            if k >= 1 and code[k].text == ":" and code[k - 1].text == ":":
                k -= 2
                continue
            break
        break
    return start - k


def _enclosing_let(code, j: int, floor: int):
    """Name bound by the `let` statement containing code[j], or None."""
    k = j
    while k > floor:
        t = code[k]
        if t.kind == PUNCT and t.text in (";", "{", "}"):
            break
        k -= 1
    k += 1
    if k < len(code) and code[k].kind == IDENT and code[k].text == "let":
        k += 1
        if k < len(code) and code[k].kind == IDENT and code[k].text == "mut":
            k += 1
        if k < len(code) and code[k].kind == IDENT:
            return code[k].text
    return None


def _find_cycles(graph: dict) -> list:
    """Simple cycles (as node lists) via Tarjan SCCs; self-loops excluded
    (reported directly at the acquisition site)."""
    index: dict = {}
    low: dict = {}
    on_stack: set = set()
    stack: list = []
    sccs: list = []
    counter = [0]

    def strongconnect(v):
        index[v] = low[v] = counter[0]
        counter[0] += 1
        stack.append(v)
        on_stack.add(v)
        for w in graph.get(v, ()):
            if w not in index:
                strongconnect(w)
                low[v] = min(low[v], low[w])
            elif w in on_stack:
                low[v] = min(low[v], index[w])
        if low[v] == index[v]:
            comp = []
            while True:
                w = stack.pop()
                on_stack.discard(w)
                comp.append(w)
                if w == v:
                    break
            if len(comp) > 1:
                sccs.append(sorted(comp))

    for v in list(graph):
        if v not in index:
            strongconnect(v)
    return sccs
