"""REG — one sampler registry, four mechanically-agreeing views.

`SAMPLER_REGISTRY` in `rust/src/sampler/mod.rs` is the single source of
truth for sampler names. Three other surfaces must agree with it:

* the `build_sampler` match arms (a registry entry with no arm is an
  advertised name that errors at runtime; an arm with no entry is an
  undiscoverable sampler that skips the round-trip test);
* the `kss --help` footer in `rust/src/main.rs`, which must iterate
  `SAMPLER_REGISTRY` rather than hand-list names;
* the hand-kept mirror table under README "### Sampler registry".

PR 4 and PR 5 each added sampler families; this rule is the mechanical
replacement for the "remember to update the table" review comment.
"""

from __future__ import annotations

import re

from pallas_lint.frontend import IDENT, PUNCT, STR, SourceFile
from pallas_lint.rules import Finding, ProjectRule

_MOD = "rust/src/sampler/mod.rs"
_MAIN = "rust/src/main.rs"
_README = "README.md"


def _str_value(text: str) -> str:
    """Literal value of a STR token (strip quotes / b / r#)."""
    m = re.match(r'^b?r?#*"(.*)"#*$', text, re.S)
    return m.group(1) if m else text.strip('"')


def _registry_names(sf: SourceFile) -> list:
    """`name: "..."` entries inside the SAMPLER_REGISTRY const."""
    code = sf.code
    names = []
    for i, t in enumerate(code):
        if not (t.kind == IDENT and t.text == "SAMPLER_REGISTRY"):
            continue
        if not (i > 0 and code[i - 1].kind == IDENT and code[i - 1].text == "const"):
            continue
        # skip past the `=` so we land on the initializer `&[...]`, not
        # the `&[SamplerInfo]` type annotation
        j = i
        while j < len(code) and not (code[j].kind == PUNCT and code[j].text == "="):
            j += 1
        while j < len(code) and not (code[j].kind == PUNCT and code[j].text == "["):
            j += 1
        depth = 0
        while j < len(code):
            c = code[j]
            if c.kind == PUNCT and c.text == "[":
                depth += 1
            elif c.kind == PUNCT and c.text == "]":
                depth -= 1
                if depth == 0:
                    break
            elif (
                c.kind == IDENT
                and c.text == "name"
                and j + 2 < len(code)
                and code[j + 1].kind == PUNCT
                and code[j + 1].text == ":"
                and code[j + 2].kind == STR
            ):
                names.append((_str_value(code[j + 2].text), code[j + 2].line))
            j += 1
        break
    return names


def _match_arm_names(sf: SourceFile) -> list:
    """String-literal match arms (`"name" =>`) inside build_sampler."""
    arms = []
    for fn in sf.functions():
        if fn.name != "build_sampler":
            continue
        code = sf.code
        for j in range(fn.body_open, fn.body_close):
            t = code[j]
            if (
                t.kind == STR
                and j + 2 < len(code)
                and code[j + 1].kind == PUNCT
                and code[j + 1].text == "="
                and code[j + 2].kind == PUNCT
                and code[j + 2].text == ">"
            ):
                arms.append((_str_value(t.text), t.line))
    return arms


def _readme_names(readme: str) -> list:
    """Backticked names in the table under '### Sampler registry'."""
    lines = readme.split("\n")
    names = []
    in_section = False
    for lineno, raw in enumerate(lines, start=1):
        if raw.startswith("### Sampler registry"):
            in_section = True
            continue
        if in_section and (raw.startswith("## ") or raw.startswith("### ")):
            break
        if in_section and raw.lstrip().startswith("|"):
            m = re.match(r"\s*\|\s*`([^`]+)`\s*\|", raw)
            if m:
                names.append((m.group(1), lineno))
    return names


class RegistryConsistency(ProjectRule):
    id = "REG"
    name = "registry-consistency"
    summary = "SAMPLER_REGISTRY vs build_sampler vs --help vs README table"
    contract = (
        "single-source-of-truth registry (sampler/mod.rs docs): every "
        "surface that lists sampler names derives from or mirrors "
        "SAMPLER_REGISTRY, and the mirrors are checked, not remembered"
    )
    extra_files = (_README,)

    def applies(self, relpath: str) -> bool:
        return relpath in (_MOD, _MAIN)

    def check_project(self, files: dict, extra: dict) -> list[Finding]:
        findings: list[Finding] = []
        mod = files.get(_MOD)
        if mod is None:
            return findings

        reg = _registry_names(mod)
        reg_names = [n for n, _ in reg]
        reg_set = set(reg_names)
        reg_line = reg[0][1] if reg else 1

        if not reg:
            findings.append(
                Finding(
                    rule=self.id,
                    file=_MOD,
                    line=1,
                    message="SAMPLER_REGISTRY not found (const renamed or removed?)",
                    snippet="",
                )
            )
            return findings

        dupes = {n for n in reg_names if reg_names.count(n) > 1}
        for n in sorted(dupes):
            findings.append(
                Finding(
                    rule=self.id,
                    file=_MOD,
                    line=reg_line,
                    message=f"duplicate registry name `{n}`",
                    snippet=f'name: "{n}"',
                )
            )

        arms = _match_arm_names(mod)
        arm_set = {n for n, _ in arms}
        for n in sorted(reg_set - arm_set):
            findings.append(
                Finding(
                    rule=self.id,
                    file=_MOD,
                    line=next(l for name, l in reg if name == n),
                    message=(
                        f"registry name `{n}` has no build_sampler match arm — "
                        "it is advertised but errors at runtime"
                    ),
                    snippet=f'name: "{n}"',
                )
            )
        for n, line in arms:
            if n not in reg_set:
                findings.append(
                    Finding(
                        rule=self.id,
                        file=_MOD,
                        line=line,
                        message=(
                            f"build_sampler arm `{n}` missing from "
                            "SAMPLER_REGISTRY — undiscoverable and skips the "
                            "registry round-trip test"
                        ),
                        snippet=f'"{n}" =>',
                    )
                )

        main = files.get(_MAIN)
        if main is not None and "SAMPLER_REGISTRY" not in main.src:
            findings.append(
                Finding(
                    rule=self.id,
                    file=_MAIN,
                    line=1,
                    message=(
                        "kss --help no longer iterates SAMPLER_REGISTRY — the "
                        "help footer must derive from the registry, not a "
                        "hand-kept list"
                    ),
                    snippet="",
                )
            )

        readme = extra.get(_README)
        if readme is not None:
            table = _readme_names(readme)
            table_set = {n for n, _ in table}
            if not table:
                findings.append(
                    Finding(
                        rule=self.id,
                        file=_README,
                        line=1,
                        message=(
                            "README '### Sampler registry' table not found — "
                            "the mirror table must exist (and agree with the "
                            "registry)"
                        ),
                        snippet="",
                    )
                )
            else:
                first_line = table[0][1]
                for n in sorted(reg_set - table_set):
                    findings.append(
                        Finding(
                            rule=self.id,
                            file=_README,
                            line=first_line,
                            message=(
                                f"registry name `{n}` missing from the README "
                                "sampler table (hand-kept mirror is stale)"
                            ),
                            snippet=f"`{n}`",
                        )
                    )
                for n, line in table:
                    if n not in reg_set:
                        findings.append(
                            Finding(
                                rule=self.id,
                                file=_README,
                                line=line,
                                message=(
                                    f"README sampler table lists `{n}` which is "
                                    "not in SAMPLER_REGISTRY"
                                ),
                                snippet=f"`{n}`",
                            )
                        )
        return findings
