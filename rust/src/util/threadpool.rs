//! Scoped data-parallel helpers (no `rayon` offline).
//!
//! The trainer samples negatives for every row of a batch independently;
//! [`par_map_mut`] fans those rows out over `std::thread::scope` workers with
//! static chunking. Each worker gets a forked, independent RNG stream from
//! the caller, so results are deterministic for a fixed seed *and* thread
//! count (thread count is part of the experiment config, defaulting to the
//! machine's parallelism).

/// Number of worker threads to use by default (capped: the batch rows we
/// parallelize over are small work items).
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(16)
}

/// Apply `f(index, &mut item)` to every element, in parallel chunks across
/// `threads` workers. Deterministic partitioning: element order and
/// chunk->worker assignment do not depend on scheduling.
pub fn par_for_each_mut<T: Send>(
    items: &mut [T],
    threads: usize,
    f: impl Fn(usize, &mut T) + Sync,
) {
    let threads = threads.max(1);
    if threads == 1 || items.len() <= 1 {
        for (i, item) in items.iter_mut().enumerate() {
            f(i, item);
        }
        return;
    }
    let n = items.len();
    let chunk = n.div_ceil(threads);
    std::thread::scope(|scope| {
        let mut rest = items;
        let mut base = 0usize;
        while !rest.is_empty() {
            let take = chunk.min(rest.len());
            let (head, tail) = rest.split_at_mut(take);
            let fref = &f;
            scope.spawn(move || {
                for (i, item) in head.iter_mut().enumerate() {
                    fref(base + i, item);
                }
            });
            rest = tail;
            base += take;
        }
    });
}

/// Parallel map producing a `Vec` in input order.
pub fn par_map<T: Send + Sync, R: Send>(
    items: &[T],
    threads: usize,
    f: impl Fn(usize, &T) -> R + Sync,
) -> Vec<R> {
    let mut out: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
    {
        let slots = &mut out[..];
        par_for_each_mut(slots, threads, |i, slot| {
            *slot = Some(f(i, &items[i]));
        });
    }
    out.into_iter().map(|r| r.expect("par_map slot unfilled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn maps_in_order() {
        let xs: Vec<usize> = (0..1000).collect();
        let ys = par_map(&xs, 4, |_, &x| x * 2);
        assert_eq!(ys, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn for_each_visits_every_index_once() {
        let mut xs = vec![0usize; 517];
        let visits = AtomicUsize::new(0);
        par_for_each_mut(&mut xs, 3, |i, x| {
            *x = i + 1;
            visits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(visits.load(Ordering::Relaxed), 517);
        for (i, &x) in xs.iter().enumerate() {
            assert_eq!(x, i + 1);
        }
    }

    #[test]
    fn single_thread_path() {
        let mut xs = vec![1u32; 8];
        par_for_each_mut(&mut xs, 1, |i, x| *x += i as u32);
        assert_eq!(xs, vec![1, 2, 3, 4, 5, 6, 7, 8]);
    }

    #[test]
    fn empty_and_tiny_inputs() {
        let mut empty: Vec<u8> = vec![];
        par_for_each_mut(&mut empty, 8, |_, _| panic!("must not be called"));
        let ys = par_map::<u8, u8>(&[], 8, |_, &x| x);
        assert!(ys.is_empty());
        let one = par_map(&[41], 8, |_, &x| x + 1);
        assert_eq!(one, vec![42]);
    }

    #[test]
    fn more_threads_than_items() {
        let xs: Vec<usize> = (0..3).collect();
        let ys = par_map(&xs, 64, |i, &x| x + i);
        assert_eq!(ys, vec![0, 2, 4]);
    }
}
