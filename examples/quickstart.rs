//! Quickstart: the kernel sampling tree standalone, then one training run.
//!
//! Run with artifacts built (`make artifacts`):
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//!
//! Part 1 uses the public sampler API directly — no model, no runtime — to
//! show what "adaptive" means: the distribution follows the query h and the
//! embeddings W as they change. Part 2 runs a real (tiny) sampled-softmax
//! training loop through the full three-layer stack.

use kss::coordinator::{MetricsSink, TrainConfig, Trainer};
use kss::runtime::Engine;
use kss::sampler::{KernelTreeSampler, QuadraticMap, Sample, SampleInput, Sampler};
use kss::util::rng::Rng;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    // ---------------------------------------------------------------- part 1
    println!("== Part 1: the O(D log n) kernel sampling tree (paper §3.2) ==\n");
    let (n, d) = (1_000, 16);
    let mut rng = Rng::new(7);
    let mut w = vec![0.0f32; n * d];
    rng.fill_normal(&mut w, 0.4);

    // q_i ∝ 100·⟨h, w_i⟩² + 1  (the paper's quadratic kernel, eq. 10)
    let mut tree = KernelTreeSampler::new(QuadraticMap::new(d, 100.0), n, None);
    tree.reset_embeddings(&w, n, d);
    println!(
        "tree over {n} classes: {} nodes, depth {}, leaf size {} (= D/d)",
        tree.node_count(),
        tree.depth(),
        tree.leaf_size()
    );

    let h: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let input = SampleInput { h: Some(&h), ..Default::default() };
    let mut out = Sample::default();
    tree.sample(&input, 8, &mut rng, &mut out)?;
    println!("\n8 draws for a random query h (class: probability q):");
    for (c, q) in out.classes.iter().zip(&out.q) {
        println!("  class {c:<4}  q = {q:.5}");
    }

    // adaptivity: align class 123 with h and update the tree (Fig. 1(b))
    let aligned: Vec<f32> = h.iter().map(|&x| 2.0 * x).collect();
    let before = tree.prob(&input, 123).unwrap();
    tree.update(123, &aligned);
    let after = tree.prob(&input, 123).unwrap();
    println!("\nafter aligning class 123's embedding with h (one O(D log n) update):");
    println!("  q(123): {before:.6} -> {after:.4}  (the sampler followed the model)");

    // ---------------------------------------------------------------- part 2
    println!("\n== Part 2: sampled-softmax training through the full stack ==\n");
    let engine = Engine::new(Path::new("artifacts"))?;
    let cfg = TrainConfig {
        model: "tiny".into(),
        sampler: "quadratic".into(),
        m: 8,
        epochs: 2,
        train_size: 640,
        valid_size: 160,
        eval_batches: 5,
        ..Default::default()
    };
    let mut trainer = Trainer::new(&engine, cfg)?;
    let mut sink = MetricsSink::memory("quickstart");
    let res = trainer.train(&mut sink)?;
    println!("\neval loss curve (full softmax CE on held-out data):");
    for p in &res.curve {
        println!("  epoch {:>4.1}  loss {:.4}", p.epoch, p.loss);
    }
    println!("\nDone. Try `kss demo` for a sampler comparison, or the");
    println!("lm_language_model / recsys_youtube examples for the paper's workloads.");
    Ok(())
}
