//! The divide-and-conquer kernel sampler — the paper's §3.2 algorithm and
//! the system's core data structure.
//!
//! A balanced binary tree over the class-id range `[0, n)`; splitting stops
//! once a subset is no larger than `leaf_size` (Fig. 1(c): a branching
//! factor of O(D/d) at the leaves cuts memory from O(nD) to O(nd)). Every
//! node stores `z(C) = Σ_{j∈C} φ(w_j)`.
//!
//! * **draw** (Fig. 1(a)): descend from the root; at each internal node go
//!   left with probability `⟨φ(h), z(left)⟩ / ⟨φ(h), z(left)⟩+⟨φ(h), z(right)⟩`
//!   (eq. 9); inside the leaf, score its ≤ leaf_size classes directly with
//!   the closed-form kernel (O(d) each — the §3.2.2 trick) and draw one.
//!   Cost: O(D log(n·d/D) + D) = O(D log n). The reported probability is
//!   computed in closed form, `q_i = K(h, w_i) / ⟨φ(h), z(root)⟩` (eq. 8),
//!   which the descent provably equals (§3.2.1).
//! * **update** (Fig. 1(b)): when class i's embedding changes, add
//!   `Δφ = φ(w_new) − φ(w_old)` to every node on the root→leaf path:
//!   O(D log n).
//!
//! `z` is kept in f64: it is maintained *incrementally* over millions of
//! updates and must not drift (tests bound the drift against a from-scratch
//! rebuild).

use super::FeatureMap;
use crate::sampler::{Needs, Sample, SampleInput, Sampler};
use crate::util::rng::Rng;
use anyhow::Result;

const NO_CHILD: u32 = u32::MAX;

struct Node {
    /// Class range [lo, hi) this node covers.
    lo: u32,
    hi: u32,
    left: u32,
    right: u32,
    /// z(C) = Σ_{j ∈ [lo, hi)} φ(w_j). f64 master copy: maintained
    /// incrementally across millions of updates, must not drift.
    z: Vec<f64>,
    /// f32 shadow of `z` used by the descent dot products (twice the SIMD
    /// width, half the memory traffic; q values are still computed in
    /// closed form so sampling corrections stay exact).
    z32: Vec<f32>,
}

impl Node {
    #[inline]
    fn is_leaf(&self) -> bool {
        self.left == NO_CHILD
    }
}

/// §3.2 divide-and-conquer sampler over a feature map.
pub struct KernelTreeSampler<M: FeatureMap> {
    map: M,
    n: usize,
    d: usize,
    leaf_size: usize,
    nodes: Vec<Node>,
    /// Host mirror of the output-embedding table (n × d).
    emb: Vec<f32>,
    /// Scratch buffers for updates (avoid per-update allocation).
    scratch_old: Vec<f64>,
    scratch_new: Vec<f64>,
    /// Draws + updates performed (ops accounting for the benches).
    pub stats: TreeStats,
}

/// Operation counters (exposed so benches can report per-op costs).
#[derive(Clone, Copy, Debug, Default)]
pub struct TreeStats {
    pub draws: u64,
    pub updates: u64,
    pub node_visits: u64,
}

impl<M: FeatureMap> KernelTreeSampler<M> {
    /// Create a tree over `n` classes with all-zero embeddings (call
    /// `reset_embeddings` or `update` to populate). `leaf_size = None`
    /// selects the paper's O(D/d) leaf branching factor.
    pub fn new(map: M, n: usize, leaf_size: Option<usize>) -> KernelTreeSampler<M> {
        assert!(n > 0);
        let d = map.d();
        let dim = map.dim();
        let leaf_size = leaf_size.unwrap_or_else(|| (dim / d).max(1)).clamp(1, n);
        let mut sampler = KernelTreeSampler {
            map,
            n,
            d,
            leaf_size,
            nodes: Vec::new(),
            emb: vec![0.0; n * d],
            scratch_old: vec![0.0; dim],
            scratch_new: vec![0.0; dim],
            stats: TreeStats::default(),
        };
        sampler.build();
        sampler
    }

    /// Number of tree nodes.
    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    /// Depth of the tree (root = 1).
    pub fn depth(&self) -> usize {
        fn go(nodes: &[Node], i: u32) -> usize {
            let n = &nodes[i as usize];
            if n.is_leaf() {
                1
            } else {
                1 + go(nodes, n.left).max(go(nodes, n.right))
            }
        }
        go(&self.nodes, 0)
    }

    pub fn leaf_size(&self) -> usize {
        self.leaf_size
    }

    /// Total kernel mass `⟨φ(h), z(root)⟩ = Σ_j K(h, w_j)` — the eq. (8)
    /// partition function, computed in O(D).
    pub fn partition(&self, phi_h: &[f64]) -> f64 {
        dot(phi_h, &self.nodes[0].z)
    }

    /// Materialize φ(h) (callers that draw many samples per example should
    /// reuse this across draws — the trainer does).
    pub fn phi_query(&self, h: &[f32]) -> Vec<f64> {
        let mut phi = vec![0.0; self.map.dim()];
        self.map.phi(h, &mut phi);
        phi
    }

    /// Fresh per-example draw cache (see [`DrawCache`]).
    pub fn new_cache(&self, phi_h: &[f64]) -> DrawCache {
        DrawCache {
            phi32: phi_h.iter().map(|&x| x as f32).collect(),
            // eq. (8) partition function in f64: q values stay exact even
            // though the descent decisions use the f32 shadow.
            total: self.partition(phi_h),
            node_dot: vec![f64::NAN; self.nodes.len()],
            leaf_cdf: std::collections::HashMap::new(),
        }
    }

    #[inline]
    fn node_dot(&self, cache: &mut DrawCache, idx: u32) -> f64 {
        let slot = &mut cache.node_dot[idx as usize];
        if slot.is_nan() {
            *slot = (dot32(&cache.phi32, &self.nodes[idx as usize].z32) as f64).max(0.0);
        }
        *slot
    }

    fn leaf_cdf<'c>(&self, cache: &'c mut DrawCache, h: &[f32], idx: u32) -> &'c LeafCdf {
        let node = &self.nodes[idx as usize];
        cache.leaf_cdf.entry(idx).or_insert_with(|| {
            let lo = node.lo as usize;
            let hi = node.hi as usize;
            let mut cum = Vec::with_capacity(hi - lo);
            let mut acc = 0.0;
            for j in lo..hi {
                acc += self.map.kernel(h, &self.emb[j * self.d..(j + 1) * self.d]);
                cum.push(acc);
            }
            LeafCdf { lo: node.lo, cum }
        })
    }

    /// One draw given a precomputed φ(h) and a per-example [`DrawCache`].
    /// Returns (class, q). The m draws of one example share the cache, so
    /// each tree node's `⟨φ(h), z⟩` and each leaf's CDF is computed at most
    /// once per example regardless of m.
    pub fn draw(&self, h: &[f32], cache: &mut DrawCache, rng: &mut Rng) -> (u32, f64) {
        let total = cache.total;
        let mut idx = 0u32;
        loop {
            let node = &self.nodes[idx as usize];
            if node.is_leaf() {
                // §3.2.2: score the O(D/d) leaf classes in the original
                // space — O(d) per class with the closed-form kernel
                // (memoized per example).
                let leaf = self.leaf_cdf(cache, h, idx);
                let mass = *leaf.cum.last().expect("leaf not empty");
                let u = rng.f64() * mass;
                let off = leaf.cum.partition_point(|&c| c <= u).min(leaf.cum.len() - 1);
                let chosen = leaf.lo as usize + off;
                // closed-form q (provably equals the descent product,
                // §3.2.1); the kernel value is the CDF increment.
                let k = if off == 0 { leaf.cum[0] } else { leaf.cum[off] - leaf.cum[off - 1] };
                return (chosen as u32, k / total);
            }
            // eq. (9): branch proportionally to the subset masses.
            let (left, right) = (node.left, node.right);
            let sl = self.node_dot(cache, left);
            let sr = self.node_dot(cache, right);
            let u = rng.f64() * (sl + sr);
            idx = if u < sl { left } else { right };
        }
    }

    /// §3.2.2 "multiple partial samples": one descent, return the whole leaf.
    /// Each returned class carries `q = P(reaching its leaf)`; correcting
    /// with `ln(runs · q)` keeps `E[Σ exp(o')] = Σ exp(o)` (the classes of a
    /// leaf are returned with weight 1/P(leaf) in expectation).
    pub fn draw_leaf(&self, phi_h: &[f64], rng: &mut Rng) -> (std::ops::Range<u32>, f64) {
        let mut idx = 0u32;
        let mut p_leaf = 1.0f64;
        loop {
            let node = &self.nodes[idx as usize];
            if node.is_leaf() {
                return (node.lo..node.hi, p_leaf);
            }
            let sl = dot(phi_h, &self.nodes[node.left as usize].z).max(0.0);
            let sr = dot(phi_h, &self.nodes[node.right as usize].z).max(0.0);
            let u = rng.f64() * (sl + sr);
            let denom = (sl + sr).max(f64::MIN_POSITIVE);
            if u < sl {
                p_leaf *= sl / denom;
                idx = node.left;
            } else {
                p_leaf *= sr / denom;
                idx = node.right;
            }
        }
    }

    /// Probability that one descent reaches the leaf containing `class`
    /// (= `⟨φ(h), z(leaf)⟩ / ⟨φ(h), z(root)⟩` by the eq. (9) chain).
    pub fn leaf_prob_of_class(&self, phi_h: &[f64], class: u32) -> f64 {
        let mut idx = 0u32;
        loop {
            let node = &self.nodes[idx as usize];
            if node.is_leaf() {
                return dot(phi_h, &node.z).max(0.0) / self.partition(phi_h);
            }
            let mid = self.nodes[node.left as usize].hi;
            idx = if class < mid { node.left } else { node.right };
        }
    }

    /// Exact probability of one class (closed form; O(d + D)).
    pub fn class_prob(&self, h: &[f32], class: u32) -> f64 {
        let phi_h = self.phi_query(h);
        let k = self.map.kernel(h, &self.emb[class as usize * self.d..(class as usize + 1) * self.d]);
        k / self.partition(&phi_h)
    }

    /// Batched Fig. 1(b): apply many embedding updates in one bottom-up
    /// sweep. Each touched node receives its *aggregated* Δz once, so the
    /// path-add cost drops from O(#updates · D · log n) to
    /// O(#updates · d² + #touched_nodes · D) — the dominant term becomes the
    /// unavoidable φ evaluations. Equivalent to calling `update` per class
    /// (up to f64 summation order).
    ///
    /// `updates` must be sorted by class id with at most one entry per class
    /// (the trainer's dedup guarantees this); `rows` is the flat (len·d)
    /// buffer of new embeddings in the same order.
    pub fn update_many(&mut self, classes: &[usize], rows: &[f32]) {
        debug_assert_eq!(rows.len(), classes.len() * self.d);
        debug_assert!(classes.windows(2).all(|w| w[0] < w[1]), "classes must be sorted+dedup");
        if classes.is_empty() {
            return;
        }
        let delta = self.apply_updates_rec(0, classes, rows);
        // root already applied inside the recursion; delta returned for parent
        let _ = delta;
        self.stats.updates += classes.len() as u64;
    }

    /// Recursive helper: applies all updates under `node`, adds the
    /// aggregated Δz to the node, and returns that Δz for the parent.
    fn apply_updates_rec(&mut self, idx: u32, classes: &[usize], rows: &[f32]) -> Vec<f64> {
        let dim = self.map.dim();
        let (lo, hi, left, right) = {
            let n = &self.nodes[idx as usize];
            (n.lo, n.hi, n.left, n.right)
        };
        debug_assert!(classes.iter().all(|&c| (c as u32) >= lo && (c as u32) < hi));
        let mut delta = vec![0.0f64; dim];
        if left == NO_CHILD {
            // leaf: Δφ per class, accumulated; mirror updated here
            for (i, &class) in classes.iter().enumerate() {
                let w_new = &rows[i * self.d..(i + 1) * self.d];
                let row = &self.emb[class * self.d..(class + 1) * self.d];
                let (old_buf, new_buf) = (&mut self.scratch_old, &mut self.scratch_new);
                self.map.phi(row, old_buf);
                self.map.phi(w_new, new_buf);
                for k in 0..dim {
                    delta[k] += new_buf[k] - old_buf[k];
                }
                self.emb[class * self.d..(class + 1) * self.d].copy_from_slice(w_new);
            }
        } else {
            let mid = self.nodes[left as usize].hi as usize;
            let split = classes.partition_point(|&c| c < mid);
            if split > 0 {
                let dl = self.apply_updates_rec(left, &classes[..split], &rows[..split * self.d]);
                for (a, b) in delta.iter_mut().zip(&dl) {
                    *a += *b;
                }
            }
            if split < classes.len() {
                let dr =
                    self.apply_updates_rec(right, &classes[split..], &rows[split * self.d..]);
                for (a, b) in delta.iter_mut().zip(&dr) {
                    *a += *b;
                }
            }
        }
        let node = &mut self.nodes[idx as usize];
        for ((zi, z32i), di) in node.z.iter_mut().zip(node.z32.iter_mut()).zip(delta.iter()) {
            *zi += *di;
            *z32i = *zi as f32;
        }
        self.stats.node_visits += 1;
        delta
    }

    /// Rebuild every z from the embedding mirror (O(n·D)).
    fn build(&mut self) {
        self.nodes.clear();
        self.build_range(0, self.n as u32);
        self.recompute_node(0);
    }

    /// Allocate nodes for [lo, hi); returns node index.
    fn build_range(&mut self, lo: u32, hi: u32) -> u32 {
        let idx = self.nodes.len() as u32;
        self.nodes.push(Node { lo, hi, left: NO_CHILD, right: NO_CHILD, z: Vec::new(), z32: Vec::new() });
        if (hi - lo) as usize > self.leaf_size {
            let mid = lo + (hi - lo) / 2;
            let left = self.build_range(lo, mid);
            let right = self.build_range(mid, hi);
            self.nodes[idx as usize].left = left;
            self.nodes[idx as usize].right = right;
        }
        idx
    }

    /// Recompute z for node `idx` (post-order) from the embedding mirror.
    fn recompute_node(&mut self, idx: u32) {
        let (lo, hi, left, right) = {
            let n = &self.nodes[idx as usize];
            (n.lo, n.hi, n.left, n.right)
        };
        let dim = self.map.dim();
        if left == NO_CHILD {
            let mut z = vec![0.0f64; dim];
            let mut phi = vec![0.0f64; dim];
            for j in lo..hi {
                let j = j as usize;
                self.map.phi(&self.emb[j * self.d..(j + 1) * self.d], &mut phi);
                for (zi, pi) in z.iter_mut().zip(&phi) {
                    *zi += *pi;
                }
            }
            self.nodes[idx as usize].z32 = z.iter().map(|&x| x as f32).collect();
            self.nodes[idx as usize].z = z;
            return;
        }
        self.recompute_node(left);
        self.recompute_node(right);
        let mut z = vec![0.0f64; dim];
        for &child in [left, right].iter() {
            for (zi, ci) in z.iter_mut().zip(&self.nodes[child as usize].z) {
                *zi += *ci;
            }
        }
        self.nodes[idx as usize].z32 = z.iter().map(|&x| x as f32).collect();
        self.nodes[idx as usize].z = z;
    }

    /// Max |z − z_rebuilt| over all nodes/components: drift diagnostic.
    pub fn max_drift(&self) -> f64 {
        let mut clone_z: Vec<Vec<f64>> = self.nodes.iter().map(|n| n.z.clone()).collect();
        // rebuild into a scratch copy
        let mut fresh = KernelTreeSamplerRebuild {
            map: &self.map,
            d: self.d,
            emb: &self.emb,
            nodes: &self.nodes,
            out: &mut clone_z,
        };
        fresh.recompute(0);
        let mut worst = 0.0f64;
        for (node, fresh_z) in self.nodes.iter().zip(clone_z.iter()) {
            for (a, b) in node.z.iter().zip(fresh_z) {
                worst = worst.max((a - b).abs());
            }
        }
        worst
    }
}

/// Helper to rebuild z values without mutating the sampler (drift check).
struct KernelTreeSamplerRebuild<'a, M: FeatureMap> {
    map: &'a M,
    d: usize,
    emb: &'a [f32],
    nodes: &'a [Node],
    out: &'a mut Vec<Vec<f64>>,
}

impl<'a, M: FeatureMap> KernelTreeSamplerRebuild<'a, M> {
    fn recompute(&mut self, idx: u32) {
        let n = &self.nodes[idx as usize];
        let dim = self.map.dim();
        let mut z = vec![0.0f64; dim];
        if n.is_leaf() {
            let mut phi = vec![0.0f64; dim];
            for j in n.lo..n.hi {
                let j = j as usize;
                self.map.phi(&self.emb[j * self.d..(j + 1) * self.d], &mut phi);
                for (zi, pi) in z.iter_mut().zip(&phi) {
                    *zi += *pi;
                }
            }
        } else {
            self.recompute(n.left);
            self.recompute(n.right);
            for &child in [n.left, n.right].iter() {
                for (zi, ci) in z.iter_mut().zip(&self.out[child as usize]) {
                    *zi += *ci;
                }
            }
        }
        self.out[idx as usize] = z;
    }
}

/// Per-example memo shared by the m draws of one example: lazily computed
/// `⟨φ(h), z(node)⟩` values and leaf CDFs. Reduces the per-example cost from
/// O(m · D · log n) to O(min(m·log n, #nodes) · D + m · log n).
pub struct DrawCache {
    /// f32 copy of φ(h) for the vectorized descent dots.
    phi32: Vec<f32>,
    /// f64 partition function ⟨φ(h), z(root)⟩ for exact q reporting.
    total: f64,
    node_dot: Vec<f64>,
    leaf_cdf: std::collections::HashMap<u32, LeafCdf>,
}

struct LeafCdf {
    lo: u32,
    /// Inclusive prefix sums of the leaf's kernel scores.
    cum: Vec<f64>,
}

/// f32 dot with 8-way accumulation — the hot descent dot (z32 shadow path).
#[inline]
fn dot32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 8];
    let chunks = a.len() / 8;
    for c in 0..chunks {
        let base = c * 8;
        for k in 0..8 {
            acc[k] += a[base + k] * b[base + k];
        }
    }
    let mut total = acc.iter().sum::<f32>();
    for j in chunks * 8..a.len() {
        total += a[j] * b[j];
    }
    total
}

/// f64 dot with 4-way accumulation (keeps LLVM auto-vectorizing the
/// non-hot f64 paths: partition(), draw_leaf()).
#[inline]
fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n4 = a.len() / 4 * 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0, 0.0, 0.0);
    let mut i = 0;
    while i < n4 {
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
        i += 4;
    }
    let mut acc = (s0 + s1) + (s2 + s3);
    for j in n4..a.len() {
        acc += a[j] * b[j];
    }
    acc
}

impl<M: FeatureMap> Sampler for KernelTreeSampler<M> {
    fn name(&self) -> &str {
        "quadratic"
    }

    fn needs(&self) -> Needs {
        Needs { h: true, ..Needs::default() }
    }

    fn sample(&self, input: &SampleInput, m: usize, rng: &mut Rng, out: &mut Sample) -> Result<()> {
        let h = input.h.ok_or_else(|| anyhow::anyhow!("kernel tree sampler needs h"))?;
        anyhow::ensure!(h.len() == self.d, "h len {} != d {}", h.len(), self.d);
        out.clear();
        // φ(h) once per example, shared by the m draws (O(d²) amortized);
        // node dots and leaf CDFs are memoized across the draws too.
        let phi_h = self.phi_query(h);
        let mut cache = self.new_cache(&phi_h);
        for _ in 0..m {
            let (class, q) = self.draw(h, &mut cache, rng);
            out.push(class, q);
        }
        Ok(())
    }

    fn prob(&self, input: &SampleInput, class: u32) -> Option<f64> {
        input.h.map(|h| self.class_prob(h, class))
    }

    /// Batched Fig. 1(b): one aggregated bottom-up sweep (see the inherent
    /// `update_many` — this trait hook just forwards).
    fn update_many(&mut self, classes: &[usize], rows: &[f32]) {
        KernelTreeSampler::update_many(self, classes, rows);
    }

    /// Fig. 1(b): update z along the root→leaf path of the changed class.
    fn update(&mut self, class: usize, w_new: &[f32]) {
        debug_assert!(class < self.n);
        debug_assert_eq!(w_new.len(), self.d);
        let row = &self.emb[class * self.d..(class + 1) * self.d];
        // Δφ = φ(new) − φ(old)
        // (scratch buffers are reused; this is the hot update path)
        let dim = self.map.dim();
        let (old_buf, new_buf) = (&mut self.scratch_old, &mut self.scratch_new);
        self.map.phi(row, old_buf);
        self.map.phi(w_new, new_buf);
        for i in 0..dim {
            new_buf[i] -= old_buf[i];
        }
        // walk the path by range descent
        let mut idx = 0u32;
        loop {
            let node = &mut self.nodes[idx as usize];
            for ((zi, z32i), di) in node.z.iter_mut().zip(node.z32.iter_mut()).zip(new_buf.iter()) {
                *zi += *di;
                *z32i = *zi as f32; // refresh the f32 shadow from the master
            }
            self.stats.node_visits += 1;
            if node.is_leaf() {
                break;
            }
            let mid = self.nodes[self.nodes[idx as usize].left as usize].hi;
            idx = if (class as u32) < mid {
                self.nodes[idx as usize].left
            } else {
                self.nodes[idx as usize].right
            };
        }
        self.emb[class * self.d..(class + 1) * self.d].copy_from_slice(w_new);
        self.stats.updates += 1;
    }

    fn reset_embeddings(&mut self, w: &[f32], n: usize, d: usize) {
        assert_eq!(n, self.n, "class count changed");
        assert_eq!(d, self.d, "embedding dim changed");
        assert_eq!(w.len(), n * d);
        self.emb.copy_from_slice(w);
        self.build();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::kernel::QuadraticMap;
    use crate::sampler::test_util::empirical_tv;
    use crate::util::testing::check;

    fn random_emb(rng: &mut Rng, n: usize, d: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; n * d];
        rng.fill_normal(&mut v, 0.5);
        v
    }

    fn exact_dist(map: &QuadraticMap, h: &[f32], emb: &[f32], n: usize, d: usize) -> Vec<f64> {
        let w: Vec<f64> = (0..n).map(|j| map.kernel(h, &emb[j * d..(j + 1) * d])).collect();
        let z: f64 = w.iter().sum();
        w.into_iter().map(|x| x / z).collect()
    }

    #[test]
    fn tree_q_matches_closed_form() {
        let (n, d) = (37, 4);
        let mut rng = Rng::new(1);
        let emb = random_emb(&mut rng, n, d);
        let map = QuadraticMap::new(d, 100.0);
        let mut tree = KernelTreeSampler::new(map.clone(), n, Some(3));
        tree.reset_embeddings(&emb, n, d);
        let h: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let expected = exact_dist(&map, &h, &emb, n, d);
        let input = SampleInput { h: Some(&h), ..Default::default() };
        let mut out = Sample::default();
        tree.sample(&input, 64, &mut rng, &mut out).unwrap();
        for (&c, &q) in out.classes.iter().zip(&out.q) {
            assert!((q - expected[c as usize]).abs() < 1e-9, "class {c}: {q} vs {}", expected[c as usize]);
        }
    }

    #[test]
    fn tree_samples_match_kernel_distribution() {
        let (n, d) = (64, 4);
        let mut rng = Rng::new(2);
        let emb = random_emb(&mut rng, n, d);
        let map = QuadraticMap::new(d, 100.0);
        let mut tree = KernelTreeSampler::new(map.clone(), n, None);
        tree.reset_embeddings(&emb, n, d);
        let h: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let expected = exact_dist(&map, &h, &emb, n, d);
        let input = SampleInput { h: Some(&h), ..Default::default() };
        let tv = empirical_tv(&tree, &input, &expected, 300_000, 17);
        assert!(tv < 0.02, "tv {tv}");
    }

    #[test]
    fn leaf_size_does_not_change_distribution() {
        check("any leaf size gives the kernel distribution", 12, |g| {
            let n = g.usize_in(2, 40);
            let d = g.usize_in(1, 5);
            let leaf = g.usize_in(1, n);
            let mut rng = Rng::new(g.case_seed ^ 1);
            let emb = random_emb(&mut rng, n, d);
            let map = QuadraticMap::new(d, g.f64_in(1.0, 150.0));
            let mut tree = KernelTreeSampler::new(map.clone(), n, Some(leaf));
            tree.reset_embeddings(&emb, n, d);
            let h: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let expected = exact_dist(&map, &h, &emb, n, d);
            // q values must be exact for every draw
            let input = SampleInput { h: Some(&h), ..Default::default() };
            let mut out = Sample::default();
            tree.sample(&input, 32, &mut rng, &mut out).unwrap();
            for (&c, &q) in out.classes.iter().zip(&out.q) {
                assert!((q - expected[c as usize]).abs() < 1e-9);
            }
        });
    }

    #[test]
    fn update_keeps_tree_consistent() {
        check("incremental updates equal a rebuild", 10, |g| {
            let n = g.usize_in(3, 32);
            let d = g.usize_in(1, 4);
            let mut rng = Rng::new(g.case_seed ^ 2);
            let emb = random_emb(&mut rng, n, d);
            let map = QuadraticMap::new(d, 100.0);
            let mut tree = KernelTreeSampler::new(map, n, Some(g.usize_in(1, n)));
            tree.reset_embeddings(&emb, n, d);
            // apply a bunch of random row updates
            for _ in 0..g.usize_in(1, 50) {
                let class = rng.range(0, n);
                let mut w: Vec<f32> = vec![0.0; d];
                rng.fill_normal(&mut w, 0.8);
                tree.update(class, &w);
            }
            let drift = tree.max_drift();
            assert!(drift < 1e-9, "drift {drift}");
        });
    }

    #[test]
    fn update_changes_distribution_correctly() {
        let (n, d) = (16, 3);
        let mut rng = Rng::new(5);
        let emb = random_emb(&mut rng, n, d);
        let map = QuadraticMap::new(d, 100.0);
        let mut tree = KernelTreeSampler::new(map.clone(), n, Some(2));
        tree.reset_embeddings(&emb, n, d);
        let h = vec![1.0f32, 0.0, 0.0];
        // blow up class 9's alignment with h
        let w_new = vec![5.0f32, 0.0, 0.0];
        tree.update(9, &w_new);
        let input = SampleInput { h: Some(&h), ..Default::default() };
        let q9 = tree.prob(&input, 9).unwrap();
        assert!(q9 > 0.5, "updated class should dominate: q9 = {q9}");
        // and q must equal the closed form over the *updated* table
        let mut emb2 = emb.clone();
        emb2[9 * d..10 * d].copy_from_slice(&w_new);
        let expected = exact_dist(&map, &h, &emb2, n, d);
        assert!((q9 - expected[9]).abs() < 1e-9);
    }

    #[test]
    fn default_leaf_size_is_d_over_d() {
        let map = QuadraticMap::new(8, 100.0);
        let tree = KernelTreeSampler::new(map, 1000, None);
        // D = 65, d = 8 -> leaf_size = 8
        assert_eq!(tree.leaf_size(), 8);
        assert!(tree.depth() <= 9, "depth {}", tree.depth());
    }

    #[test]
    fn single_class_and_tiny_trees() {
        let map = QuadraticMap::new(2, 100.0);
        let mut tree = KernelTreeSampler::new(map, 1, None);
        tree.reset_embeddings(&[0.3, -0.7], 1, 2);
        let h = vec![1.0f32, 1.0];
        let input = SampleInput { h: Some(&h), ..Default::default() };
        let mut rng = Rng::new(9);
        let mut out = Sample::default();
        tree.sample(&input, 8, &mut rng, &mut out).unwrap();
        assert!(out.classes.iter().all(|&c| c == 0));
        assert!(out.q.iter().all(|&q| (q - 1.0).abs() < 1e-12));
    }

    #[test]
    fn zero_embeddings_give_uniform() {
        // all-zero W: K(h, w) = 1 for all classes -> uniform q
        let map = QuadraticMap::new(4, 100.0);
        let tree = KernelTreeSampler::new(map, 10, Some(2));
        let h = vec![1.0f32; 4];
        let input = SampleInput { h: Some(&h), ..Default::default() };
        for c in 0..10u32 {
            assert!((tree.prob(&input, c).unwrap() - 0.1).abs() < 1e-12);
        }
    }

    #[test]
    fn draw_leaf_probabilities_sum_to_one() {
        let (n, d) = (24, 3);
        let mut rng = Rng::new(7);
        let emb = random_emb(&mut rng, n, d);
        let map = QuadraticMap::new(d, 100.0);
        let mut tree = KernelTreeSampler::new(map, n, Some(4));
        tree.reset_embeddings(&emb, n, d);
        let h: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let phi_h = tree.phi_query(&h);
        // Monte-Carlo: E[1/P(leaf) * |leaf|]-ish sanity + leaf probs valid
        let mut seen = std::collections::HashMap::new();
        for _ in 0..2000 {
            let (range, p) = tree.draw_leaf(&phi_h, &mut rng);
            assert!(p > 0.0 && p <= 1.0 + 1e-12);
            *seen.entry(range.start).or_insert(0usize) += 1;
        }
        // every leaf's empirical frequency ≈ its p
        for (&lo, &count) in &seen {
            // find the leaf's p by a fresh descent probability computation:
            // p = ⟨φ(h), z(leaf)⟩ / ⟨φ(h), z(root)⟩ by eq. (9) chain
            let leaf = tree.nodes.iter().find(|nd| nd.is_leaf() && nd.lo == lo).unwrap();
            let p = super::dot(&phi_h, &leaf.z) / tree.partition(&phi_h);
            let freq = count as f64 / 2000.0;
            assert!((freq - p).abs() < 0.05, "leaf {lo}: freq {freq} vs p {p}");
        }
    }
}
