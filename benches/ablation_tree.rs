//! §3.2.2 ablations — the tree's design knobs:
//!
//! * **leaf branching factor**: the paper suggests O(D/d)-sized leaves to
//!   cut memory from O(nD) to O(nd); this sweeps leaf sizes and reports
//!   draw cost, update cost and memory — showing D/d is a sane default.
//! * **multiple partial samples**: one descent returning a whole leaf
//!   (importance-weighted) vs m independent draws — faster per returned
//!   class, but correlated; we measure both the speed and the estimator
//!   quality (partition-function estimate variance).
//!
//! No artifacts needed. `cargo bench --bench ablation_tree`.

use kss::bench_harness::{print_speedup, print_table, scale, Bencher, BenchRow, Scale};
use kss::sampler::kernel::multi::PartialLeafSampler;
use kss::sampler::{
    row_rng, BatchSampleInput, KernelTreeSampler, QuadraticMap, Sample, SampleInput, Sampler,
};
use kss::util::rng::Rng;
use kss::util::threadpool::default_threads;

fn main() {
    let (n, d) = match scale() {
        Scale::Quick => (10_000usize, 32usize),
        Scale::Full => (100_000, 64),
    };
    let m = 32usize;
    let dim = d * d + 1;
    let bencher = Bencher { warmup_iters: 1, min_iters: 5, max_iters: 60, budget_s: 1.0 };
    let mut rng = Rng::new(3);
    let mut w = vec![0.0f32; n * d];
    rng.fill_normal(&mut w, 0.3);
    let h: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let input = SampleInput { h: Some(&h), ..Default::default() };

    // ---- leaf-size sweep ---------------------------------------------------
    println!("==== leaf branching factor sweep (n = {n}, d = {d}, D = {dim}) ====");
    println!("paper default: leaf = D/d = {}\n", dim / d);
    let mut rows: Vec<BenchRow> = Vec::new();
    for leaf in [1usize, d / 4, d, dim / d, 4 * dim / d, 16 * dim / d] {
        let leaf = leaf.max(1);
        let mut tree = KernelTreeSampler::new(QuadraticMap::new(d, 100.0), n, Some(leaf));
        tree.reset_embeddings(&w, n, d);
        let mem_mb = tree.node_count() as f64 * dim as f64 * 12.0 / 1e6; // f64 z + f32 shadow
        let mut out = Sample::default();
        let mut r = Rng::new(9);
        rows.push(bencher.run_with_items(
            &format!("leaf={leaf:>5} nodes={:>6} mem={mem_mb:>7.1}MB", tree.node_count()),
            Some(m as f64),
            || tree.sample(&input, m, &mut r, &mut out).unwrap(),
        ));
        let mut r = Rng::new(10);
        let mut w_new = vec![0.0f32; d];
        rows.push(bencher.run_with_items(
            &format!("  update leaf={leaf:>5}"),
            Some(1.0),
            || {
                r.fill_normal(&mut w_new, 0.3);
                let c = r.range(0, n);
                tree.update(c, &w_new);
            },
        ));
    }
    print_table("draw (m per example) and update costs by leaf size", &rows);

    // ---- multiple partial samples vs independent draws ---------------------
    println!("\n==== §3.2.2 multiple partial samples ====");
    let mut tree = KernelTreeSampler::new(QuadraticMap::new(d, 100.0), n, None);
    tree.reset_embeddings(&w, n, d);
    let leaf_size = tree.leaf_size();
    let partial = PartialLeafSampler::new(tree);
    let mut tree2 = KernelTreeSampler::new(QuadraticMap::new(d, 100.0), n, None);
    tree2.reset_embeddings(&w, n, d);

    let mut out = Sample::default();
    let mut r = Rng::new(21);
    let runs = (m / leaf_size).max(1); // same total classes as m draws
    let row_part = bencher.run_with_items(
        &format!("partial: {runs} descents x leaf {leaf_size}"),
        Some((runs * leaf_size) as f64),
        || partial.sample(&input, runs, &mut r, &mut out).unwrap(),
    );
    let mut r = Rng::new(21);
    let row_indep = bencher.run_with_items(
        &format!("independent: {m} draws"),
        Some(m as f64),
        || tree2.sample(&input, m, &mut r, &mut out).unwrap(),
    );
    print_table("classes returned per second", &[row_part, row_indep]);

    // estimator quality: Monte-Carlo variance of the importance-weighted
    // estimate of S = Σ_j f(o_j) (the quantity eq. 12 needs) under both
    // schemes, normalized per returned class. Partial sampling's classes
    // are correlated (whole leaves), so its per-class variance is higher —
    // exactly the trade the paper describes in §3.2.2.
    let score = |c: u32| -> f64 {
        let row = &w[c as usize * d..(c as usize + 1) * d];
        (row.iter().zip(&h).map(|(&a, &b)| (a * b) as f64).sum::<f64>()).exp()
    };
    let truth: f64 = (0..n as u32).map(score).sum();
    let trials = 1_000;
    let var_of = |use_partial: bool| -> f64 {
        let mut r = Rng::new(77);
        let mut s = Sample::default();
        let mut acc = 0.0;
        for _ in 0..trials {
            if use_partial {
                partial.sample(&input, runs, &mut r, &mut s).unwrap();
            } else {
                tree2.sample(&input, m, &mut r, &mut s).unwrap();
            }
            let draws = if use_partial { runs } else { m } as f64;
            let est: f64 =
                s.classes.iter().zip(&s.q).map(|(&c, &q)| score(c) / (draws * q)).sum();
            let rel = est / truth - 1.0;
            acc += rel * rel;
        }
        (acc / trials as f64).sqrt()
    };
    let v_ind = var_of(false);
    let v_part = var_of(true);
    println!("\npartition-estimate relative std over {trials} trials:");
    println!("  independent draws (m={m}):        {v_ind:.4}");
    println!("  partial leaves ({runs}x{leaf_size} classes):   {v_part:.4}");
    println!("\nboth are unbiased (eq. 12); partial sampling is cheaper per class");
    println!("but correlated, so it needs more classes for the same variance —");
    println!("the §3.2.2 trade-off. The trainer defaults to independent draws.");

    // ---- batched engine vs per-example loop --------------------------------
    println!("\n==== batch engine: sample_batch vs per-example loop ====");
    let batch_examples = 32usize;
    let threads = default_threads();
    let mut hs = vec![0.0f32; batch_examples * d];
    rng.fill_normal(&mut hs, 1.0);
    let base_input = BatchSampleInput {
        n: batch_examples,
        d,
        n_classes: n,
        h: Some(&hs),
        ..Default::default()
    };
    let batched_input = BatchSampleInput { threads, ..base_input };
    let mut outs: Vec<Sample> = (0..batch_examples).map(|_| Sample::with_capacity(m)).collect();
    let mut step = 0u64;
    let row_batched = bencher.run_with_items(
        &format!("batched ({batch_examples} ex × m={m}, {threads} thr)"),
        Some((batch_examples * m) as f64),
        || {
            step += 1;
            tree2.sample_batch(&batched_input, m, step, &mut outs).unwrap();
        },
    );
    let mut step = 0u64;
    let row_per_ex = bencher.run_with_items(
        &format!("per-example ({batch_examples} ex × m={m}, 1 thr)"),
        Some((batch_examples * m) as f64),
        || {
            step += 1;
            for (i, slot) in outs.iter_mut().enumerate() {
                let input = base_input.row(i);
                let mut r = row_rng(step, i);
                tree2.sample(&input, m, &mut r, slot).unwrap();
            }
        },
    );
    print_table(
        "batch engine (same per-row RNG streams, identical output)",
        &[row_batched.clone(), row_per_ex.clone()],
    );
    print_speedup("batched vs per-example", &row_per_ex, &row_batched);
}
