//! §5.2 comparison — **hierarchical softmax vs full softmax vs sampled
//! softmax** on a synthetic classification task.
//!
//! The paper's related work cites Chen et al. (2015): HSM is ~O(d√n)/step
//! and fast, but converges >10% worse than full softmax; sampled softmax
//! with a good q keeps full-softmax quality at sampled-softmax cost. This
//! bench reproduces the cost and the quality ordering with self-contained
//! rust heads (no XLA — isolates the output-layer method).
//!
//! `cargo bench --bench hsm_baseline`

use kss::bench_harness::{print_table, scale, Bencher, Scale};
use kss::hsm::{FullHead, HsmHead};
use kss::util::rng::Rng;

fn main() {
    let (n, d, steps) = match scale() {
        Scale::Quick => (400usize, 16usize, 6_000usize),
        Scale::Full => (5_000, 32, 40_000),
    };
    let n_clusters = (n as f64).sqrt().round() as usize;
    let mut rng = Rng::new(5);
    let counts: Vec<u64> = (0..n as u64).map(|i| i + 1).collect();
    let mut proto = vec![0.0f32; n * d];
    rng.fill_normal(&mut proto, 0.7);
    let gen = |rng: &mut Rng| -> (u32, Vec<f32>) {
        let y = rng.below(n as u64) as u32;
        let h: Vec<f32> = proto[y as usize * d..(y as usize + 1) * d]
            .iter()
            .map(|&x| x + rng.normal_f32(0.0, 0.5))
            .collect();
        (y, h)
    };

    // ---- per-step cost ------------------------------------------------------
    let bencher = Bencher { warmup_iters: 5, min_iters: 50, max_iters: 3000, budget_s: 1.0 };
    let mut hsm = HsmHead::new(&counts, d, n_clusters, &mut rng);
    let mut full = FullHead::new(n, d, &mut rng);
    let mut dh = vec![0.0f32; d];
    let mut r = Rng::new(1);
    let row_hsm = bencher.run(&format!("HSM step (n={n}, {n_clusters} clusters)"), || {
        let (y, h) = gen(&mut r);
        hsm.step(&h, y, 0.05, &mut dh);
    });
    let mut r = Rng::new(1);
    let row_full = bencher.run(&format!("full softmax step (n={n})"), || {
        let (y, h) = gen(&mut r);
        full.step(&h, y, 0.05);
    });
    print_table("per-example train-step cost", &[row_hsm, row_full]);

    // ---- converged quality --------------------------------------------------
    let mut hsm = HsmHead::new(&counts, d, n_clusters, &mut rng);
    let mut full = FullHead::new(n, d, &mut rng);
    let mut r = Rng::new(2);
    for _ in 0..steps {
        let (y, h) = gen(&mut r);
        hsm.step(&h, y, 0.08, &mut dh);
        full.step(&h, y, 0.08);
    }
    let evals = 1_000;
    let (mut l_hsm, mut l_full) = (0.0, 0.0);
    for _ in 0..evals {
        let (y, h) = gen(&mut r);
        l_hsm += -(hsm.prob(&h, y).max(1e-30)).ln();
        l_full += full.loss(&h, y);
    }
    l_hsm /= evals as f64;
    l_full /= evals as f64;
    println!("\nconverged CE after {steps} steps:");
    println!("  HSM          {l_hsm:.4}  (ppl {:.1})", l_hsm.exp());
    println!("  full softmax {l_full:.4}  (ppl {:.1})", l_full.exp());
    let gap = (l_hsm.exp() / l_full.exp() - 1.0) * 100.0;
    println!("  perplexity gap: {gap:.1}% (Chen et al. 2015 report >10% on PTB)");
    println!("\nshape: HSM is much cheaper per step but converges worse — the gap");
    println!("sampled softmax with a good q avoids (figs. 2/4 benches).");
}
