//! Serving-layer acceptance bench: reader scaling under concurrent
//! publishing, and the cost of a publish as seen from both sides.
//!
//! Measures, per reader thread count:
//!
//! * aggregate draw throughput with an **idle** writer (baseline);
//! * the same with a writer continuously applying `update_many` batches
//!   and publishing snapshot generations (the production shape);
//!
//! and reports the publish path's build time (replay/clone, off the reader
//! path) vs swap time (the only interval a refreshing reader can contend
//! with). Readers are wait-free in steady state, so throughput with a
//! publishing writer should track the idle baseline and scale with thread
//! count; the swap max is the worst stall any reader could observe.
//!
//! No artifacts needed (pure L3). `cargo bench --bench serve_throughput`.

use kss::bench_harness::{print_table, scale, write_json, BenchRow, Scale};
use kss::sampler::Sample;
use kss::serve::{draw_from_shards, shard::scratch_for, ShardSet, SnapshotReader};
use kss::sampler::kernel::QuadraticMap;
use kss::sampler::row_rng;
use kss::util::rng::Rng;
use kss::util::stats::Samples;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

struct RunResult {
    wall_s: f64,
    draws: u64,
    /// publish timings (empty when the writer was idle)
    build: Samples,
    swap: Samples,
    publishes: u64,
    reclaimed: u64,
}

/// Run `threads` readers drawing `requests_per_thread × m` samples each,
/// optionally against a continuously publishing writer.
fn run_readers(
    set: &mut ShardSet<QuadraticMap>,
    hs: &[f32],
    d: usize,
    m: usize,
    threads: usize,
    requests_per_thread: usize,
    writer_updates: usize,
) -> RunResult {
    let stores = set.stores();
    let offsets = set.offsets().to_vec();
    let n_h = hs.len() / d;
    let stop = AtomicBool::new(false);
    let mut build = Samples::new();
    let mut swap = Samples::new();
    let mut publishes = 0u64;
    let mut reclaimed = 0u64;
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        let mut readers = Vec::new();
        for worker in 0..threads {
            let stores = stores.clone();
            let offsets = &offsets;
            readers.push(scope.spawn(move || {
                let mut shard_readers: Vec<SnapshotReader<_>> =
                    stores.iter().map(|s| SnapshotReader::new(s.clone())).collect();
                let mut state = {
                    let views: Vec<_> =
                        shard_readers.iter().map(|r| r.pinned().tree.view()).collect();
                    scratch_for(&views)
                };
                let mut out = Sample::with_capacity(m);
                for req in 0..requests_per_thread {
                    for r in shard_readers.iter_mut() {
                        r.current();
                    }
                    let snaps: Vec<_> =
                        shard_readers.iter().map(|r| r.pinned().clone()).collect();
                    let trees: Vec<_> = snaps.iter().map(|s| s.tree.view()).collect();
                    let h = &hs[(req % n_h) * d..(req % n_h + 1) * d];
                    let mut rng = row_rng(worker as u64, req);
                    out.clear();
                    draw_from_shards(&trees, offsets, h, m, &mut state, &mut rng, &mut out);
                    std::hint::black_box(&out);
                }
            }));
        }
        let writer = (writer_updates > 0).then(|| {
            let stop = &stop;
            let set = &mut *set;
            scope.spawn(move || {
                let mut wrng = Rng::new(0xBEEF);
                let mut builds = Samples::new();
                let mut swaps = Samples::new();
                let (mut pubs, mut recl) = (0u64, 0u64);
                while !stop.load(Ordering::Relaxed) {
                    for report in set.publish_random_batch(&mut wrng, writer_updates) {
                        builds.push(report.build_s);
                        swaps.push(report.swap_s);
                        pubs += 1;
                        if report.reclaimed {
                            recl += 1;
                        }
                    }
                }
                (builds, swaps, pubs, recl)
            })
        });
        for r in readers {
            r.join().expect("reader panicked");
        }
        stop.store(true, Ordering::Relaxed);
        if let Some(w) = writer {
            let (builds, swaps, pubs, recl) = w.join().expect("writer panicked");
            build = builds;
            swap = swaps;
            publishes = pubs;
            reclaimed = recl;
        }
    });
    RunResult {
        wall_s: t0.elapsed().as_secs_f64(),
        draws: (threads * requests_per_thread * m) as u64,
        build,
        swap,
        publishes,
        reclaimed,
    }
}

fn row(name: &str, r: &RunResult) -> BenchRow {
    BenchRow {
        name: name.to_string(),
        mean_s: r.wall_s,
        p50_s: r.wall_s,
        p95_s: r.wall_s,
        iters: 1,
        items_per_iter: Some(r.draws as f64),
    }
}

fn main() {
    let (n, d, m) = match scale() {
        Scale::Quick => (20_000usize, 16usize, 8usize),
        Scale::Full => (200_000, 32, 16),
    };
    let shards = 4;
    let requests = match scale() {
        Scale::Quick => 2_000usize,
        Scale::Full => 10_000,
    };
    let mut rng = Rng::new(7);
    let mut emb = vec![0.0f32; n * d];
    rng.fill_normal(&mut emb, 0.3);
    let mut hs = vec![0.0f32; 256 * d];
    rng.fill_normal(&mut hs, 1.0);
    let mut set = ShardSet::new(QuadraticMap::new(d, 100.0), n, shards, None, Some(&emb));
    println!("serve bench: {n} classes × d={d} in {shards} shards, m={m}, {requests} req/reader");

    let thread_counts = [1usize, 2, 4, 8];
    let mut reader_rows: Vec<BenchRow> = Vec::new();
    let mut publish_rows: Vec<BenchRow> = Vec::new();
    let mut idle_tput = Vec::new();
    let mut busy_tput = Vec::new();
    for &threads in &thread_counts {
        let idle = run_readers(&mut set, &hs, d, m, threads, requests, 0);
        idle_tput.push(idle.draws as f64 / idle.wall_s);
        reader_rows.push(row(&format!("readers={threads} writer=idle"), &idle));
        let busy = run_readers(&mut set, &hs, d, m, threads, requests, 64);
        busy_tput.push(busy.draws as f64 / busy.wall_s);
        reader_rows.push(row(&format!("readers={threads} writer=publishing"), &busy));
        if !busy.swap.is_empty() {
            publish_rows.push(BenchRow {
                name: format!("publish build (readers={threads})"),
                mean_s: busy.build.mean(),
                p50_s: busy.build.p50(),
                p95_s: busy.build.p95(),
                iters: busy.publishes as usize,
                items_per_iter: None,
            });
            publish_rows.push(BenchRow {
                name: format!("publish swap  (readers={threads})"),
                mean_s: busy.swap.mean(),
                p50_s: busy.swap.p50(),
                p95_s: busy.swap.percentile(100.0),
                iters: busy.publishes as usize,
                items_per_iter: None,
            });
            println!(
                "readers={threads}: {} publishes ({} reclaimed), swap max {:.3} µs — publish \
                 never blocks readers beyond the swap",
                busy.publishes,
                busy.reclaimed,
                busy.swap.percentile(100.0) * 1e6
            );
        }
    }

    print_table("reader draw throughput (wall-clock per full run)", &reader_rows);
    print_table("publish cost: build (off reader path) vs swap (p95 column = max)", &publish_rows);

    println!("\nreader scaling (draws/s):");
    for (i, &threads) in thread_counts.iter().enumerate() {
        println!(
            "  {threads:>2} readers: idle {:>12.0}/s  publishing {:>12.0}/s  \
             ({:.1}% of idle, {:.2}x vs 1 reader)",
            idle_tput[i],
            busy_tput[i],
            100.0 * busy_tput[i] / idle_tput[i],
            busy_tput[i] / busy_tput[0]
        );
    }
    let last = thread_counts.len() - 1;
    println!(
        "(acceptance: throughput grows with readers — {:.2}x at {} threads — while the writer \
         publishes concurrently)",
        busy_tput[last] / busy_tput[0],
        thread_counts[last]
    );

    write_json(
        "serve",
        &[
            ("reader throughput", &reader_rows),
            ("publish cost", &publish_rows),
        ],
    );
}
