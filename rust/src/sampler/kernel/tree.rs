//! The divide-and-conquer kernel sampler — the paper's §3.2 algorithm and
//! the system's core data structure.
//!
//! A balanced binary tree over the class-id range `[0, n)`; splitting stops
//! once a subset is no larger than `leaf_size` (Fig. 1(c): a branching
//! factor of O(D/d) at the leaves cuts memory from O(nD) to O(nd)). Every
//! node stores `z(C) = Σ_{j∈C} φ(w_j)`.
//!
//! # Arena layout
//!
//! The tree is a struct-of-arrays arena, not a pointer structure:
//!
//! ```text
//! meta : [NodeMeta; #nodes]   breadth-first (level) order; children of
//!                             node i are meta[i].left and meta[i].left+1,
//!                             so sibling subsets are always adjacent
//! z    : [f64; #nodes × D]    node i owns z[i·D .. (i+1)·D]  (master)
//! z32  : [f32; #nodes × D]    same layout, f32 shadow for descent dots
//! ```
//!
//! A descent therefore reads two *adjacent* D-sized slices per level
//! (`left`, `left+1`) from one flat allocation — no pointer chasing and no
//! per-node `Vec` headers — and `update_many` sweeps contiguous arena
//! slices bottom-up. Per-example memo state lives in a reusable
//! [`DrawScratch`] pool (generation counters, no hashing, no allocation
//! after warm-up), which the batched [`Sampler::sample_batch`] engine keeps
//! per worker across the whole batch.
//!
//! # Panel layout and the ops layer
//!
//! Every inner loop here runs on [`crate::ops`], and the arena is laid out
//! as the **class-blocked panels** those primitives stream:
//!
//! * sibling `z32` slices are adjacent (`left`, `left+1`), so one descent
//!   level is a single contiguous 2×D panel — [`crate::ops::dot2_32`]
//!   scores both children in one pass with φ(h) cache-resident (loaded
//!   once, not twice), falling back to the exact f64 [`crate::ops::dot`]
//!   per side on overflow;
//! * a leaf covers the contiguous class range `[lo, hi)` and the embedding
//!   mirror is row-major by class id, so the §3.2.2 leaf step is one
//!   [`FeatureMap::kernel_many`] sweep over the contiguous
//!   `emb[lo·d..hi·d]` panel (→ [`crate::ops::dot_many_f32`] for the
//!   quadratic kernel) instead of strided row-at-a-time kernel calls;
//! * `update_many`'s Δz merges and `update`'s Δφ are
//!   [`crate::ops::add_assign`]/[`crate::ops::sub_assign`] over arena
//!   slices.
//!
//! * **draw** (Fig. 1(a)): descend from the root; at each internal node go
//!   left with probability `⟨φ(h), z(left)⟩ / ⟨φ(h), z(left)⟩+⟨φ(h), z(right)⟩`
//!   (eq. 9); inside the leaf, score its ≤ leaf_size classes directly with
//!   the closed-form kernel (O(d) each — the §3.2.2 trick) and draw one.
//!   Cost: O(D log(n·d/D) + D) = O(D log n). The reported probability is
//!   computed in closed form, `q_i = K(h, w_i) / ⟨φ(h), z(root)⟩` (eq. 8),
//!   which the descent provably equals (§3.2.1). Zero/denormal subset
//!   masses fall back to uniform choices with a guarded descent
//!   probability, so the reported q is always strictly positive.
//! * **update** (Fig. 1(b)): when class i's embedding changes, add
//!   `Δφ = φ(w_new) − φ(w_old)` to every node on the root→leaf path:
//!   O(D log n).
//!
//! `z` is kept in f64: it is maintained *incrementally* over millions of
//! updates and must not drift (tests bound the drift against a from-scratch
//! rebuild). The f32 shadow is refreshed from the master and clamped to
//! finite values, so overflow at large α degrades to an exact f64 fallback
//! instead of poisoning descent probabilities.

use super::FeatureMap;
use crate::obs::monitor::DEFAULT_STRIDE;
use crate::obs::{ess_fraction, Counter, Gauge, Histogram, MetricsRegistry, QualityMonitor};
use crate::ops;
use crate::sampler::{row_rng, BatchSampleInput, Needs, Sample, SampleInput, Sampler};
use crate::util::rng::Rng;
use crate::util::threadpool::{par_chunks_mut, Pool};
use anyhow::Result;
use std::sync::{Arc, Mutex};

const NO_CHILD: u32 = u32::MAX;

/// Node metadata (struct-of-arrays arena; the z summaries live in the flat
/// `z`/`z32` arenas indexed by node id).
#[derive(Clone, Copy, Debug)]
struct NodeMeta {
    /// Class range [lo, hi) this node covers.
    lo: u32,
    hi: u32,
    /// Left child node id, or `NO_CHILD` for leaves. Nodes are allocated in
    /// breadth-first order, so the right child is always `left + 1`.
    left: u32,
}

impl NodeMeta {
    #[inline]
    fn is_leaf(&self) -> bool {
        self.left == NO_CHILD
    }
}

/// §3.2 divide-and-conquer sampler over a feature map.
pub struct KernelTreeSampler<M: FeatureMap> {
    map: M,
    n: usize,
    d: usize,
    /// Feature dimension D (cached `map.dim()`).
    dim: usize,
    leaf_size: usize,
    /// Tree depth (root = 1), fixed at build time — update_many sizes its
    /// delta pool from this without re-walking the tree.
    tree_depth: usize,
    /// Node metadata in breadth-first (level) order; node 0 is the root.
    meta: Vec<NodeMeta>,
    /// Flat z(C) arena: node i owns `z[i·D .. (i+1)·D]`. f64 master copy:
    /// maintained incrementally across millions of updates, must not drift.
    z: Vec<f64>,
    /// f32 shadow of `z` (same layout) used by the descent dot products
    /// (twice the SIMD width, half the memory traffic; q values are still
    /// computed in closed form so sampling corrections stay exact).
    /// Refreshed from the master on every update, clamped to finite values.
    z32: Vec<f32>,
    /// Host mirror of the output-embedding table (n × d).
    emb: Vec<f32>,
    /// Scratch buffers for updates (avoid per-update allocation).
    scratch_old: Vec<f64>,
    scratch_new: Vec<f64>,
    /// Depth-indexed Δz buffers for `update_many`'s bottom-up sweep
    /// (allocated lazily to the tree depth, then reused forever).
    delta_pool: Vec<Vec<f64>>,
    /// Freelist of [`DrawScratch`] pools: `sample`/`sample_batch` check one
    /// out per example-sequence and return it, so the O(#nodes + n) scratch
    /// is allocated a bounded number of times (≈ max concurrent workers)
    /// per sampler lifetime instead of per call. Scratch contents never
    /// affect results (generation counters invalidate them per example),
    /// so pooling preserves stream determinism.
    scratch_pool: Pool<DrawScratch>,
    /// Draws + updates performed (ops accounting for the benches).
    pub stats: TreeStats,
    /// Telemetry cells (Arc-shared with clones; see [`TreeObs`]).
    obs: TreeObs,
}

/// Operation counters (exposed so benches can report per-op costs).
#[derive(Clone, Copy, Debug, Default)]
pub struct TreeStats {
    pub draws: u64,
    pub updates: u64,
    pub node_visits: u64,
}

/// Shared telemetry cells for one tree and every clone of it.
///
/// The draw hot path never touches these atomics directly: each
/// [`DrawScratch`] accumulates plain-integer locals and
/// [`KernelTreeSampler::put_scratch`] drains them in one blocked flush per
/// checkout (the same accumulate-then-merge discipline as `ops/`). The
/// cells are `Arc`-shared so the serve layer's snapshot clones report into
/// the same series as the tree they were published from, and
/// [`TreeObs::register_into`] binds them to any number of registries.
///
/// The quality monitor runs on `monitor_stride` (examples): one strided
/// example pays the O(m·d) exact re-scoring of its drawn classes, feeding
/// the reservoir TV estimator and the eq. (2) ESS gauge. The stride is
/// per-scratch, so with worker pooling it is approximate — a sampling
/// cadence, not an exact decimation.
#[derive(Clone)]
pub struct TreeObs {
    /// Master switch: when false, draws skip all scratch-local
    /// bookkeeping (the `obs_overhead` bench compares the two states).
    pub enabled: bool,
    /// Examples between quality-monitor observations (0 disables the
    /// monitor; counters and depth accounting still run).
    pub monitor_stride: u64,
    draws: Arc<Counter>,
    zero_mass: Arc<Counter>,
    degenerate_branches: Arc<Counter>,
    exact_fallbacks: Arc<Counter>,
    depth: Arc<Histogram>,
    min_q: Arc<Gauge>,
    tv: Arc<Gauge>,
    ess: Arc<Gauge>,
    monitor: Arc<Mutex<QualityMonitor>>,
}

impl Default for TreeObs {
    fn default() -> Self {
        TreeObs {
            enabled: true,
            monitor_stride: DEFAULT_STRIDE,
            draws: Arc::new(Counter::new()),
            zero_mass: Arc::new(Counter::new()),
            degenerate_branches: Arc::new(Counter::new()),
            exact_fallbacks: Arc::new(Counter::new()),
            depth: Arc::new(Histogram::new()),
            min_q: Arc::new(Gauge::new()),
            tv: Arc::new(Gauge::new()),
            ess: Arc::new(Gauge::new()),
            monitor: Arc::new(Mutex::new(QualityMonitor::default())),
        }
    }
}

impl TreeObs {
    /// Bind every cell to `reg` under the stable `kss_sampler_*` names
    /// (see the README metric catalog).
    pub fn register_into(&self, reg: &MetricsRegistry) {
        reg.register_counter(
            "kss_sampler_draws_total",
            "draws",
            "sampler",
            "classes drawn by tree descent",
            Arc::clone(&self.draws),
        );
        reg.register_counter(
            "kss_sampler_zero_mass_fallback_total",
            "draws",
            "sampler",
            "leaf draws where every kernel mass underflowed (uniform fallback)",
            Arc::clone(&self.zero_mass),
        );
        reg.register_counter(
            "kss_sampler_degenerate_branch_total",
            "branches",
            "sampler",
            "eq. (9) branch steps that fell back to a fair coin",
            Arc::clone(&self.degenerate_branches),
        );
        reg.register_counter(
            "kss_sampler_exact_fallback_total",
            "dots",
            "sampler",
            "f32 descent dots that overflowed into the exact f64 path",
            Arc::clone(&self.exact_fallbacks),
        );
        reg.register_histogram(
            "kss_sampler_descent_depth",
            "levels",
            "sampler",
            "internal-node levels traversed per draw",
            Arc::clone(&self.depth),
        );
        reg.register_gauge(
            "kss_sampler_min_q",
            "probability",
            "sampler",
            "smallest proposal probability reported (q-positivity headroom)",
            Arc::clone(&self.min_q),
        );
        reg.register_gauge(
            "kss_sampler_tv_estimate",
            "distance",
            "sampler",
            "streaming TV(softmax, proposal) over the monitor reservoir",
            Arc::clone(&self.tv),
        );
        reg.register_gauge(
            "kss_sampler_ess_fraction",
            "fraction",
            "sampler",
            "eq. (2) effective-sample-size fraction of the last monitored example",
            Arc::clone(&self.ess),
        );
    }

    /// Classes drawn (counted on the scratch flush, so a just-finished
    /// call is visible once its scratch returns to the pool).
    pub fn draws_total(&self) -> u64 {
        self.draws.get()
    }

    pub fn zero_mass_total(&self) -> u64 {
        self.zero_mass.get()
    }

    pub fn degenerate_branch_total(&self) -> u64 {
        self.degenerate_branches.get()
    }

    pub fn exact_fallback_total(&self) -> u64 {
        self.exact_fallbacks.get()
    }

    /// Smallest q reported so far (0.0 until the first flush).
    pub fn min_q(&self) -> f64 {
        self.min_q.get()
    }

    /// Latest reservoir TV estimate (0.0 until the monitor first runs).
    pub fn tv_estimate(&self) -> f64 {
        self.tv.get()
    }

    /// Latest eq. (2) ESS fraction (0.0 until the monitor first runs).
    pub fn ess_fraction(&self) -> f64 {
        self.ess.get()
    }
}

/// Clamp an f64 to a finite f32 (overflow saturates instead of producing
/// inf/NaN in the shadow arena — a NaN there used to defeat the draw memo).
#[inline]
fn to_f32_clamped(v: f64) -> f32 {
    let x = v as f32;
    if x.is_finite() {
        x
    } else if x.is_nan() {
        0.0
    } else {
        f32::MAX.copysign(x)
    }
}

/// Coerce a kernel/subset mass to a usable value: NaN → 0, negative → 0,
/// +inf → f64::MAX. Shared with the serve layer (shard router masses and
/// beam scores go through the same guard).
#[inline]
pub(crate) fn sanitize_mass(x: f64) -> f64 {
    if x.is_nan() {
        0.0
    } else {
        x.clamp(0.0, f64::MAX)
    }
}

/// Guarded eq. (9) branch step, shared by `draw` and `draw_leaf`: go left
/// with probability `sl / (sl + sr)`. When the combined subset mass
/// underflows to zero (or is non-finite) it falls back to a fair coin —
/// the unguarded version always descended right on zero mass, a
/// deterministic bias, and could report q = 0. Returns the side taken,
/// its probability (always strictly positive), and whether the fair-coin
/// fallback fired (the telemetry layer counts those; fallback draws are
/// correct but signal a degenerate mass upstream).
#[inline]
fn choose_branch(sl: f64, sr: f64, rng: &mut Rng) -> (bool, f64, bool) {
    let sum = sl + sr;
    if sum > 0.0 && sum.is_finite() {
        let u = rng.f64() * sum;
        if u < sl {
            (true, sl / sum, false)
        } else {
            (false, sr / sum, false)
        }
    } else {
        (rng.bool(0.5), 0.5, true)
    }
}

/// `partition_point`'s floating-point slack can clamp a draw onto a
/// zero-mass tail slot of the CDF; walk down to the nearest strictly
/// positive increment (one exists whenever the total mass is positive).
/// Shared with the serve-layer shard router, which draws shards from the
/// same kind of inclusive-prefix-sum CDF.
#[inline]
pub(crate) fn step_down_to_positive(cum: &[f64], mut off: usize) -> usize {
    while off > 0 && cum[off] - cum[off - 1] <= 0.0 {
        off -= 1;
    }
    off
}

impl<M: FeatureMap> KernelTreeSampler<M> {
    /// Create a tree over `n` classes with all-zero embeddings (call
    /// `reset_embeddings` or `update` to populate). `leaf_size = None`
    /// selects the paper's O(D/d) leaf branching factor.
    pub fn new(map: M, n: usize, leaf_size: Option<usize>) -> KernelTreeSampler<M> {
        assert!(n > 0);
        let d = map.d();
        let dim = map.dim();
        let leaf_size = leaf_size.unwrap_or_else(|| (dim / d).max(1)).clamp(1, n);
        let mut sampler = KernelTreeSampler {
            map,
            n,
            d,
            dim,
            leaf_size,
            tree_depth: 1,
            meta: Vec::new(),
            z: Vec::new(),
            z32: Vec::new(),
            emb: vec![0.0; n * d],
            scratch_old: vec![0.0; dim],
            scratch_new: vec![0.0; dim],
            delta_pool: Vec::new(),
            scratch_pool: Pool::new(),
            stats: TreeStats::default(),
            obs: TreeObs::default(),
        };
        sampler.build();
        sampler
    }

    /// Telemetry cells (register them into a [`MetricsRegistry`] via
    /// [`TreeObs::register_into`]; shared with every clone of this tree).
    pub fn obs(&self) -> &TreeObs {
        &self.obs
    }

    /// Toggle per-draw telemetry accounting (the `obs_overhead` bench
    /// measures both states; the monitor only runs while enabled).
    pub fn set_obs_enabled(&mut self, on: bool) {
        self.obs.enabled = on;
    }

    /// Examples between sampler-quality observations (0 disables the
    /// monitor entirely).
    pub fn set_monitor_stride(&mut self, stride: u64) {
        self.obs.monitor_stride = stride;
    }

    /// Number of tree nodes.
    pub fn node_count(&self) -> usize {
        self.meta.len()
    }

    /// Depth of the tree (root = 1). Cached at build time.
    pub fn depth(&self) -> usize {
        self.tree_depth
    }

    pub fn leaf_size(&self) -> usize {
        self.leaf_size
    }

    /// Number of classes the tree covers.
    pub fn num_classes(&self) -> usize {
        self.n
    }

    /// Embedding dimension d.
    pub fn embed_dim(&self) -> usize {
        self.d
    }

    /// The kernel's feature map (the serve router needs `K(h, ·)` in closed
    /// form to report merged q values).
    pub fn feature_map(&self) -> &M {
        &self.map
    }

    /// Row `class` of the host embedding mirror.
    #[inline]
    pub fn emb_row(&self, class: usize) -> &[f32] {
        &self.emb[class * self.d..(class + 1) * self.d]
    }

    /// The full class-major (n × d) embedding mirror. The serve-side
    /// midx engine rebuilds its inverted index from the published tree's
    /// panel, and the bias ablation scores whole generations against it.
    #[inline]
    pub fn emb_panel(&self) -> &[f32] {
        &self.emb
    }

    /// The leaf class range `[lo, hi)` containing `class`: descend the
    /// breadth-first arena from the root by the split midpoints. Used by
    /// the bench layer to account the tree's exact per-draw kernel-eval
    /// cost (path nodes × 2 + leaf span) without duplicating the split
    /// rule.
    pub fn leaf_range_of(&self, class: u32) -> std::ops::Range<u32> {
        debug_assert!((class as usize) < self.n);
        let mut idx = 0u32;
        loop {
            let m = self.meta[idx as usize];
            if m.is_leaf() {
                return m.lo..m.hi;
            }
            let mid = self.meta[m.left as usize].hi;
            idx = if class < mid { m.left } else { m.left + 1 };
        }
    }

    /// Node i's z(C) slice in the arena.
    #[inline]
    fn z_of(&self, idx: u32) -> &[f64] {
        &self.z[idx as usize * self.dim..(idx as usize + 1) * self.dim]
    }

    /// Node i's f32 shadow slice in the arena.
    #[inline]
    fn z32_of(&self, idx: u32) -> &[f32] {
        &self.z32[idx as usize * self.dim..(idx as usize + 1) * self.dim]
    }

    /// Total kernel mass `⟨φ(h), z(root)⟩ = Σ_j K(h, w_j)` — the eq. (8)
    /// partition function, computed in O(D).
    pub fn partition(&self, phi_h: &[f64]) -> f64 {
        ops::dot(phi_h, self.z_of(0))
    }

    /// Materialize φ(h) (callers that draw many samples per example should
    /// reuse this across draws — the trainer does, via [`DrawScratch`]).
    pub fn phi_query(&self, h: &[f32]) -> Vec<f64> {
        let mut phi = vec![0.0; self.dim];
        self.map.phi(h, &mut phi);
        phi
    }

    /// Allocate a reusable draw scratch pool sized for this tree (see
    /// [`DrawScratch`]). One pool serves any number of examples in
    /// sequence; the batched engine keeps one per worker thread.
    pub fn new_scratch(&self) -> DrawScratch {
        DrawScratch {
            phi_h: vec![0.0; self.dim],
            phi32: vec![0.0; self.dim],
            total: 0.0,
            node_dot: vec![0.0; self.meta.len()],
            node_gen: vec![0; self.meta.len()],
            leaf_cum: vec![0.0; self.n],
            leaf_k: vec![0.0; self.leaf_size],
            leaf_gen: vec![0; self.meta.len()],
            gen: 0,
            obs_on: false,
            obs_draws: 0,
            obs_zero_mass: 0,
            obs_degenerate: 0,
            obs_exact_fallback: 0,
            obs_min_q: f64::INFINITY,
            obs_depth_counts: vec![0; self.tree_depth + 1],
            obs_examples: 0,
        }
    }

    /// Check a scratch pool out of the freelist, allocating only when the
    /// freelist is empty — so steady-state `sample`/`sample_batch` traffic
    /// allocates nothing, and total allocations are bounded by the maximum
    /// number of concurrent users rather than the call count.
    pub fn take_scratch(&self) -> DrawScratch {
        let mut s = self.scratch_pool.take(|| self.new_scratch());
        s.obs_on = self.obs.enabled;
        s
    }

    /// Return a scratch pool to the freelist for reuse by later calls,
    /// draining its telemetry locals into the shared [`TreeObs`] cells
    /// first (one blocked flush per checkout — the draw loop itself never
    /// touches an atomic).
    pub fn put_scratch(&self, mut scratch: DrawScratch) {
        self.flush_scratch_obs(&mut scratch);
        self.scratch_pool.put(scratch);
    }

    /// Drain a scratch's telemetry locals into the shared cells and reset
    /// them (the stride counter survives: it is a cadence, not a stat).
    fn flush_scratch_obs(&self, s: &mut DrawScratch) {
        if !s.obs_on {
            return;
        }
        if s.obs_draws > 0 {
            self.obs.draws.add(s.obs_draws);
            s.obs_draws = 0;
        }
        if s.obs_zero_mass > 0 {
            self.obs.zero_mass.add(s.obs_zero_mass);
            s.obs_zero_mass = 0;
        }
        if s.obs_degenerate > 0 {
            self.obs.degenerate_branches.add(s.obs_degenerate);
            s.obs_degenerate = 0;
        }
        if s.obs_exact_fallback > 0 {
            self.obs.exact_fallbacks.add(s.obs_exact_fallback);
            s.obs_exact_fallback = 0;
        }
        for (depth, c) in s.obs_depth_counts.iter_mut().enumerate() {
            if *c > 0 {
                self.obs.depth.record_n(depth as f64, *c);
                *c = 0;
            }
        }
        // set_min ignores the +inf "nothing observed" sentinel
        self.obs.min_q.set_min(s.obs_min_q);
        s.obs_min_q = f64::INFINITY;
    }

    /// Start a new example: materialize φ(h), compute the eq. (8) partition
    /// function in f64 (q values stay exact even though descent decisions
    /// use the f32 shadow), and invalidate all memos by bumping the
    /// generation counter — O(#nodes) state is reused, not reallocated.
    pub fn begin_example(&self, h: &[f32], s: &mut DrawScratch) {
        debug_assert_eq!(h.len(), self.d);
        self.map.phi(h, &mut s.phi_h);
        for (dst, &x) in s.phi32.iter_mut().zip(s.phi_h.iter()) {
            *dst = to_f32_clamped(x);
        }
        s.total = self.partition(&s.phi_h);
        s.advance_gen();
    }

    /// [`Self::begin_example`] with a caller-materialized φ(h) and root
    /// partition `total = ⟨φ(h), z(root)⟩`. The serve layer's shard router
    /// computes φ(h) once per request and scores every shard's root to
    /// build its CDF; priming the shard a draw lands on then reuses both —
    /// no repeated O(d²) feature map, no repeated O(D) root dot.
    pub fn begin_example_prepared(&self, phi_h: &[f64], total: f64, s: &mut DrawScratch) {
        debug_assert_eq!(phi_h.len(), self.dim);
        debug_assert_eq!(total.to_bits(), self.partition(phi_h).to_bits());
        s.phi_h.copy_from_slice(phi_h);
        for (dst, &x) in s.phi32.iter_mut().zip(s.phi_h.iter()) {
            *dst = to_f32_clamped(x);
        }
        s.total = total;
        s.advance_gen();
    }

    /// Memoized `⟨φ(h), z(node)⟩`. Validity is a generation counter, *not*
    /// a NaN sentinel: a legitimately-NaN f32 dot (z32 overflow at large α)
    /// used to defeat the memo — recomputing forever and poisoning descent
    /// probabilities. Now a non-finite fast dot triggers one exact f64
    /// fallback, and the sanitized value is cached like any other.
    #[inline]
    fn node_mass(&self, s: &mut DrawScratch, idx: u32) -> f64 {
        let i = idx as usize;
        if s.node_gen[i] == s.gen {
            return s.node_dot[i];
        }
        let fast = ops::dot32(&s.phi32, self.z32_of(idx));
        let v = self.sanitized_mass_of(s, idx, fast);
        s.node_dot[i] = v;
        s.node_gen[i] = s.gen;
        v
    }

    /// Sanitize one fast f32 descent dot into a usable mass, falling back
    /// to the exact f64 arena on overflow (shared by the single and fused
    /// memo paths — identical values by construction). The fallback is
    /// counted into the scratch's telemetry locals when accounting is on.
    #[inline]
    fn sanitized_mass_of(&self, s: &mut DrawScratch, idx: u32, fast: f32) -> f64 {
        let fast = fast as f64;
        if fast.is_finite() {
            fast.max(0.0)
        } else {
            if s.obs_on {
                s.obs_exact_fallback += 1;
            }
            sanitize_mass(ops::dot(&s.phi_h, self.z_of(idx)))
        }
    }

    /// Memoized masses of a sibling pair (`left`, `left+1`). The two `z32`
    /// slices are adjacent in the arena, so when neither is memoized yet
    /// the pair is one fused [`ops::dot2_32`] over the contiguous 2×D
    /// panel — φ(h) streams through cache once per level instead of twice.
    /// Values are bit-identical to two [`Self::node_mass`] calls (the
    /// fused kernel pins each row's accumulation order), so memo state
    /// composes transparently with the single-node path.
    #[inline]
    fn node_mass_pair(&self, s: &mut DrawScratch, left: u32) -> (f64, f64) {
        let li = left as usize;
        let lv = s.node_gen[li] == s.gen;
        let rv = s.node_gen[li + 1] == s.gen;
        if lv && rv {
            return (s.node_dot[li], s.node_dot[li + 1]);
        }
        if lv || rv {
            // one side already memoized: compute only the other
            return (self.node_mass(s, left), self.node_mass(s, left + 1));
        }
        let base = li * self.dim;
        let (fl, fr) = ops::dot2_32(&s.phi32, &self.z32[base..base + 2 * self.dim]);
        let sl = self.sanitized_mass_of(s, left, fl);
        let sr = self.sanitized_mass_of(s, left + 1, fr);
        s.node_dot[li] = sl;
        s.node_dot[li + 1] = sr;
        s.node_gen[li] = s.gen;
        s.node_gen[li + 1] = s.gen;
        (sl, sr)
    }

    /// Fill (at most once per example per leaf) and return the leaf's
    /// inclusive kernel-mass prefix sums plus its first class id. The CDF
    /// arena is indexed by class id, so leaf `[lo, hi)` owns
    /// `leaf_cum[lo..hi]` — flat, no hashing.
    fn leaf_cdf<'s>(&self, s: &'s mut DrawScratch, h: &[f32], idx: u32) -> (&'s [f64], u32) {
        let m = self.meta[idx as usize];
        let (lo, hi) = (m.lo as usize, m.hi as usize);
        if s.leaf_gen[idx as usize] != s.gen {
            // §3.2.2: score the O(D/d) leaf classes in the original space —
            // O(d) per class with the closed-form kernel, fused over the
            // contiguous class-blocked embedding panel (the mirror is
            // row-major by class id and a leaf covers [lo, hi), so this is
            // one ops::dot_many-shaped sweep, not strided row gathers).
            let ks = &mut s.leaf_k[..hi - lo];
            self.map.kernel_many(h, &self.emb[lo * self.d..hi * self.d], ks);
            let mut acc = 0.0f64;
            for (j, &k) in ks.iter().enumerate() {
                acc += sanitize_mass(k);
                s.leaf_cum[lo + j] = acc;
            }
            s.leaf_gen[idx as usize] = s.gen;
        }
        (&s.leaf_cum[lo..hi], m.lo)
    }

    /// One draw given a [`DrawScratch`] primed by [`Self::begin_example`].
    /// Returns (class, q). The m draws of one example share the scratch, so
    /// each tree node's `⟨φ(h), z⟩` and each leaf's CDF is computed at most
    /// once per example regardless of m.
    ///
    /// q is strictly positive in every case: zero-mass subsets fall back to
    /// uniform choices whose probability is the guarded descent product.
    pub fn draw(&self, h: &[f32], s: &mut DrawScratch, rng: &mut Rng) -> (u32, f64) {
        let total = s.total;
        let mut idx = 0u32;
        // Guarded descent product — the draw's actual probability when the
        // closed form degenerates.
        let mut p_path = 1.0f64;
        // internal levels traversed, for the descent-depth histogram
        let mut depth = 0usize;
        loop {
            let meta = self.meta[idx as usize];
            if meta.is_leaf() {
                let len = (meta.hi - meta.lo) as usize;
                let (cum, lo) = self.leaf_cdf(s, h, idx);
                let mass = *cum.last().expect("leaf not empty");
                if !(mass > 0.0) {
                    // Every kernel mass in the subset underflowed to zero
                    // (or was non-finite): uniform within the subset, with
                    // the descent probability as q — never ≤ 0. Unguarded,
                    // this clamped to the last class and reported q = 0,
                    // sending ln(m·q) = -inf into the training kernel.
                    let off = rng.below(len as u64) as usize;
                    let q = (p_path / len as f64).max(f64::MIN_POSITIVE);
                    if s.obs_on {
                        s.obs_zero_mass += 1;
                        s.note_draw(depth, q);
                    }
                    return (lo + off as u32, q);
                }
                let u = rng.f64() * mass;
                let off = cum.partition_point(|&c| c <= u).min(len - 1);
                let off = step_down_to_positive(cum, off);
                // closed-form q (provably equals the descent product,
                // §3.2.1); the kernel value is the CDF increment.
                let k = if off == 0 { cum[0] } else { cum[off] - cum[off - 1] };
                let q = k / total;
                let q = if q > 0.0 && q.is_finite() {
                    q
                } else {
                    // degenerate partition function: report the actual draw
                    // probability under the guarded descent instead
                    (p_path * k / mass).max(f64::MIN_POSITIVE)
                };
                if s.obs_on {
                    s.note_draw(depth, q);
                }
                return (lo + off as u32, q);
            }
            // eq. (9): branch proportionally to the subset masses (guarded;
            // one fused pass over the adjacent sibling panel).
            let (sl, sr) = self.node_mass_pair(s, meta.left);
            let (go_left, p, degenerate) = choose_branch(sl, sr, rng);
            if degenerate && s.obs_on {
                s.obs_degenerate += 1;
            }
            p_path *= p;
            depth += 1;
            idx = if go_left { meta.left } else { meta.left + 1 };
        }
    }

    /// [`Self::draw_leaf`] through a [`DrawScratch`] primed by
    /// [`Self::begin_example`]: uses the same memoized f32-shadow node
    /// masses (with the exact f64 fallback) as [`Self::draw`], so the
    /// partial-leaf batch engine reuses one scratch per worker instead of
    /// re-deriving every node dot per descent. The returned `p` is the
    /// actual probability of reaching the leaf under the guarded descent
    /// (always strictly positive), which keeps the §3.2.2 importance
    /// weights unbiased regardless of which precision produced the masses.
    pub fn draw_leaf_scratch(
        &self,
        s: &mut DrawScratch,
        rng: &mut Rng,
    ) -> (std::ops::Range<u32>, f64) {
        let mut idx = 0u32;
        let mut p_leaf = 1.0f64;
        loop {
            let meta = self.meta[idx as usize];
            if meta.is_leaf() {
                return (meta.lo..meta.hi, p_leaf.max(f64::MIN_POSITIVE));
            }
            let (sl, sr) = self.node_mass_pair(s, meta.left);
            let (go_left, p, degenerate) = choose_branch(sl, sr, rng);
            if degenerate && s.obs_on {
                s.obs_degenerate += 1;
            }
            p_leaf *= p;
            idx = if go_left { meta.left } else { meta.left + 1 };
        }
    }

    /// §3.2.2 "multiple partial samples": one descent, return the whole leaf.
    /// Each returned class carries `q = P(reaching its leaf)`; correcting
    /// with `ln(runs · q)` keeps `E[Σ exp(o')] = Σ exp(o)` (the classes of a
    /// leaf are returned with weight 1/P(leaf) in expectation). Shares the
    /// guarded branch step with [`Self::draw`], so P(leaf) > 0 always.
    pub fn draw_leaf(&self, phi_h: &[f64], rng: &mut Rng) -> (std::ops::Range<u32>, f64) {
        let mut idx = 0u32;
        let mut p_leaf = 1.0f64;
        loop {
            let meta = self.meta[idx as usize];
            if meta.is_leaf() {
                return (meta.lo..meta.hi, p_leaf.max(f64::MIN_POSITIVE));
            }
            let sl = sanitize_mass(ops::dot(phi_h, self.z_of(meta.left)));
            let sr = sanitize_mass(ops::dot(phi_h, self.z_of(meta.left + 1)));
            // no scratch here: the scratchless path drops the telemetry flag
            let (go_left, p, _degenerate) = choose_branch(sl, sr, rng);
            p_leaf *= p;
            idx = if go_left { meta.left } else { meta.left + 1 };
        }
    }

    /// Probability that one descent reaches the leaf containing `class`
    /// (= `⟨φ(h), z(leaf)⟩ / ⟨φ(h), z(root)⟩` by the eq. (9) chain).
    pub fn leaf_prob_of_class(&self, phi_h: &[f64], class: u32) -> f64 {
        let mut idx = 0u32;
        loop {
            let meta = self.meta[idx as usize];
            if meta.is_leaf() {
                // clamped denominator keeps the quotient finite if the
                // root mass underflows (eq. (2) q-positivity)
                return ops::dot(phi_h, self.z_of(idx)).max(0.0)
                    / self.partition(phi_h).max(f64::MIN_POSITIVE);
            }
            let mid = self.meta[meta.left as usize].hi;
            idx = if class < mid { meta.left } else { meta.left + 1 };
        }
    }

    /// Exact probability of one class (closed form; O(d + D)).
    pub fn class_prob(&self, h: &[f32], class: u32) -> f64 {
        let phi_h = self.phi_query(h);
        let k = self
            .map
            .kernel(h, &self.emb[class as usize * self.d..(class as usize + 1) * self.d]);
        k / self.partition(&phi_h).max(f64::MIN_POSITIVE)
    }

    /// Approximate top-k retrieval by kernel score `K(h, w_j) = ⟨φ(h), φ(w_j)⟩`
    /// via a level-synchronous beam descent over the arena.
    ///
    /// At each level every surviving internal node is expanded into its two
    /// children; leaves carry forward; the frontier is then cut to the
    /// `beam_width` nodes with the largest subset mass `⟨φ(h), z(C)⟩`
    /// (sanitized through the same zero-mass guard as the draw path, so
    /// degenerate masses sort as 0 instead of poisoning the ordering). The
    /// ≤ `beam_width · leaf_size` classes of the surviving leaves are then
    /// scored exactly with the closed-form kernel — O(d) each, the §3.2.2
    /// trick — and the best `k` are returned, sorted by descending score
    /// with class id as the deterministic tie-break.
    ///
    /// Approximate because a subset's *mass* (a sum) can understate a lone
    /// high-scoring class inside a low-mass subset; `beam_width ≥ #leaves`
    /// makes the result exact (tests pin this), and recall degrades
    /// gracefully as the beam narrows.
    pub fn topk_beam(&self, h: &[f32], k: usize, beam_width: usize) -> Vec<(u32, f64)> {
        let beam_width = beam_width.max(1);
        let phi_h = self.phi_query(h);
        let mass = |idx: u32| sanitize_mass(ops::dot(&phi_h, self.z_of(idx)));
        let mut frontier: Vec<(u32, f64)> = vec![(0, mass(0))];
        loop {
            let mut next: Vec<(u32, f64)> = Vec::with_capacity(2 * frontier.len());
            let mut expanded = false;
            for &(idx, m) in &frontier {
                let meta = self.meta[idx as usize];
                if meta.is_leaf() {
                    next.push((idx, m));
                } else {
                    expanded = true;
                    next.push((meta.left, mass(meta.left)));
                    next.push((meta.left + 1, mass(meta.left + 1)));
                }
            }
            if !expanded {
                break;
            }
            // keep the beam_width heaviest subsets; ties resolve by node id
            // so the result is deterministic across runs and platforms
            next.sort_unstable_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
            next.truncate(beam_width);
            frontier = next;
        }
        // exact closed-form scores inside the surviving leaves: one fused
        // kernel_many sweep per leaf over its contiguous class panel
        let mut scored: Vec<(u32, f64)> = Vec::with_capacity(frontier.len() * self.leaf_size);
        let mut ks = vec![0.0f64; self.leaf_size];
        for &(idx, _) in &frontier {
            let meta = self.meta[idx as usize];
            let (lo, hi) = (meta.lo as usize, meta.hi as usize);
            let ks = &mut ks[..hi - lo];
            self.map.kernel_many(h, &self.emb[lo * self.d..hi * self.d], ks);
            for (j, &k) in ks.iter().enumerate() {
                scored.push(((lo + j) as u32, sanitize_mass(k)));
            }
        }
        scored.sort_unstable_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
        scored.truncate(k);
        scored
    }

    /// Strided sampler-quality observation: every `monitor_stride`-th
    /// example (per scratch) re-scores its m drawn classes exactly —
    /// `o_c = ⟨h, w_c⟩`, O(m·d) — and feeds the `(o, q)` pairs to the
    /// reservoir TV estimator and the eq. (2) ESS gauge. `try_lock` on the
    /// shared reservoir: a contended observation is dropped, never waited
    /// for (telemetry must not serialize the sampling workers).
    fn maybe_observe_quality(&self, h: &[f32], s: &DrawScratch, classes: &[u32], q: &[f64]) {
        let stride = self.obs.monitor_stride;
        if !s.obs_on || stride == 0 || s.obs_examples % stride != 0 {
            return;
        }
        let m = classes.len().min(q.len());
        if m == 0 {
            return;
        }
        let mut pairs = Vec::with_capacity(m);
        for i in 0..m {
            let o = ops::dot32(h, self.emb_row(classes[i] as usize)) as f64;
            pairs.push((o, q[i]));
        }
        if let Some(f) = ess_fraction(&pairs) {
            self.obs.ess.set(f);
        }
        if let Ok(mut mon) = self.obs.monitor.try_lock() {
            mon.observe(&pairs);
            if let Some(tv) = mon.tv_estimate() {
                self.obs.tv.set(tv);
            }
        }
    }

    /// Read-only sampling/retrieval view (see [`TreeView`]).
    pub fn view(&self) -> TreeView<'_, M> {
        TreeView { tree: self }
    }

    /// Batched Fig. 1(b): apply many embedding updates in one bottom-up
    /// sweep over arena slices. Each touched node receives its *aggregated*
    /// Δz once, so the path-add cost drops from O(#updates · D · log n) to
    /// O(#updates · d² + #touched_nodes · D) — the dominant term becomes the
    /// unavoidable φ evaluations. Equivalent to calling `update` per class
    /// (up to f64 summation order; the property tests bound the difference).
    ///
    /// `classes` must be sorted with at most one entry per class (the
    /// trainer's dedup guarantees this); `rows` is the flat (len·d) buffer
    /// of new embeddings in the same order.
    pub fn update_many(&mut self, classes: &[usize], rows: &[f32]) {
        debug_assert_eq!(rows.len(), classes.len() * self.d);
        debug_assert!(classes.windows(2).all(|w| w[0] < w[1]), "classes must be sorted+dedup");
        if classes.is_empty() {
            return;
        }
        let depth = self.depth();
        while self.delta_pool.len() < depth {
            self.delta_pool.push(vec![0.0; self.dim]);
        }
        self.apply_updates_rec(0, classes, rows, 0);
        self.stats.updates += classes.len() as u64;
    }

    /// Recursive helper: aggregates the Δφ of every update under `idx` into
    /// `delta_pool[level]`, applies it to the node's arena slice, and
    /// leaves it in the pool for the parent to accumulate — one O(D) add
    /// per touched node, no allocation after the pool is warm.
    fn apply_updates_rec(&mut self, idx: u32, classes: &[usize], rows: &[f32], level: usize) {
        let meta = self.meta[idx as usize];
        debug_assert!(classes.iter().all(|&c| (c as u32) >= meta.lo && (c as u32) < meta.hi));
        self.delta_pool[level].fill(0.0);
        if meta.is_leaf() {
            // leaf: Δφ per class, accumulated; embedding mirror updated here
            for (i, &class) in classes.iter().enumerate() {
                let w_new = &rows[i * self.d..(i + 1) * self.d];
                self.map
                    .phi(&self.emb[class * self.d..(class + 1) * self.d], &mut self.scratch_old);
                self.map.phi(w_new, &mut self.scratch_new);
                ops::sub_assign(&mut self.scratch_new, &self.scratch_old);
                ops::add_assign(&mut self.delta_pool[level], &self.scratch_new);
                self.emb[class * self.d..(class + 1) * self.d].copy_from_slice(w_new);
            }
        } else {
            let mid = self.meta[meta.left as usize].hi as usize;
            let split = classes.partition_point(|&c| c < mid);
            if split > 0 {
                self.apply_updates_rec(meta.left, &classes[..split], &rows[..split * self.d], level + 1);
                let (head, tail) = self.delta_pool.split_at_mut(level + 1);
                ops::add_assign(&mut head[level], &tail[0]);
            }
            if split < classes.len() {
                self.apply_updates_rec(
                    meta.left + 1,
                    &classes[split..],
                    &rows[split * self.d..],
                    level + 1,
                );
                let (head, tail) = self.delta_pool.split_at_mut(level + 1);
                ops::add_assign(&mut head[level], &tail[0]);
            }
        }
        // apply the aggregated Δz to this node's arena slices
        let base = idx as usize * self.dim;
        let zs = &mut self.z[base..base + self.dim];
        let z32s = &mut self.z32[base..base + self.dim];
        let delta = &self.delta_pool[level];
        for ((zi, z32i), di) in zs.iter_mut().zip(z32s.iter_mut()).zip(delta.iter()) {
            *zi += *di;
            *z32i = to_f32_clamped(*zi);
        }
        self.stats.node_visits += 1;
    }

    /// (Re)build the arena: breadth-first node layout, then every z from
    /// the embedding mirror (O(n·D)).
    fn build(&mut self) {
        self.meta.clear();
        self.meta.push(NodeMeta { lo: 0, hi: self.n as u32, left: NO_CHILD });
        let mut head = 0usize;
        while head < self.meta.len() {
            let m = self.meta[head];
            if (m.hi - m.lo) as usize > self.leaf_size {
                let mid = m.lo + (m.hi - m.lo) / 2;
                self.meta[head].left = self.meta.len() as u32;
                self.meta.push(NodeMeta { lo: m.lo, hi: mid, left: NO_CHILD });
                self.meta.push(NodeMeta { lo: mid, hi: m.hi, left: NO_CHILD });
            }
            head += 1;
        }
        self.tree_depth = {
            fn go(meta: &[NodeMeta], i: u32) -> usize {
                let m = meta[i as usize];
                if m.is_leaf() {
                    1
                } else {
                    1 + go(meta, m.left).max(go(meta, m.left + 1))
                }
            }
            go(&self.meta, 0)
        };
        self.z = vec![0.0; self.meta.len() * self.dim];
        self.z32 = vec![0.0; self.meta.len() * self.dim];
        self.delta_pool.clear();
        self.recompute_all();
    }

    /// Recompute every z from the embedding mirror. Children always have
    /// larger ids than their parent (breadth-first layout), so one reverse
    /// sweep visits children before parents — no recursion.
    fn recompute_all(&mut self) {
        let dim = self.dim;
        let mut phi = vec![0.0f64; dim];
        for idx in (0..self.meta.len()).rev() {
            let m = self.meta[idx];
            if m.is_leaf() {
                let target = &mut self.z[idx * dim..(idx + 1) * dim];
                target.fill(0.0);
                for j in m.lo..m.hi {
                    let j = j as usize;
                    self.map.phi(&self.emb[j * self.d..(j + 1) * self.d], &mut phi);
                    ops::add_assign(target, &phi);
                }
            } else {
                let l = m.left as usize;
                let (head, tail) = self.z.split_at_mut(l * dim);
                let target = &mut head[idx * dim..(idx + 1) * dim];
                let (zl, zr) = (&tail[..dim], &tail[dim..2 * dim]);
                for ((t, a), b) in target.iter_mut().zip(zl).zip(zr) {
                    *t = *a + *b;
                }
            }
        }
        for (s, &v) in self.z32.iter_mut().zip(self.z.iter()) {
            *s = to_f32_clamped(v);
        }
    }

    /// Max |z − z_rebuilt| over all nodes/components: drift diagnostic.
    pub fn max_drift(&self) -> f64 {
        let dim = self.dim;
        let mut fresh = vec![0.0f64; self.z.len()];
        let mut phi = vec![0.0f64; dim];
        for idx in (0..self.meta.len()).rev() {
            let m = self.meta[idx];
            if m.is_leaf() {
                let target = &mut fresh[idx * dim..(idx + 1) * dim];
                for j in m.lo..m.hi {
                    let j = j as usize;
                    self.map.phi(&self.emb[j * self.d..(j + 1) * self.d], &mut phi);
                    ops::add_assign(target, &phi);
                }
            } else {
                let l = m.left as usize;
                let (head, tail) = fresh.split_at_mut(l * dim);
                let target = &mut head[idx * dim..(idx + 1) * dim];
                for ((t, a), b) in target.iter_mut().zip(&tail[..dim]).zip(&tail[dim..2 * dim]) {
                    *t = *a + *b;
                }
            }
        }
        self.z
            .iter()
            .zip(&fresh)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max)
    }
}

/// Cloning duplicates the *arena state* (meta, z master + f32 shadow,
/// embedding mirror) — the primitive the serve layer's double-buffered
/// snapshot publisher is built on. Transient state is deliberately not
/// shared: the clone gets fresh update scratch, an empty delta pool, and an
/// empty [`DrawScratch`] freelist (scratches are sized per tree and refill
/// on first use), while `stats` carries over as a plain copy. Telemetry is
/// the one shared piece: the [`TreeObs`] cells stay Arc-linked so serve
/// snapshots report into the series of the tree they were published from.
impl<M: FeatureMap + Clone> Clone for KernelTreeSampler<M> {
    fn clone(&self) -> Self {
        KernelTreeSampler {
            map: self.map.clone(),
            n: self.n,
            d: self.d,
            dim: self.dim,
            leaf_size: self.leaf_size,
            tree_depth: self.tree_depth,
            meta: self.meta.clone(),
            z: self.z.clone(),
            z32: self.z32.clone(),
            emb: self.emb.clone(),
            scratch_old: vec![0.0; self.dim],
            scratch_new: vec![0.0; self.dim],
            delta_pool: Vec::new(),
            scratch_pool: Pool::new(),
            stats: self.stats,
            // telemetry cells are Arc-shared: a published snapshot clone
            // reports into the same series as its source tree
            obs: self.obs.clone(),
        }
    }
}

/// Read-only view over a [`KernelTreeSampler`]: exposes exactly the `&self`
/// surface the serve layer's read paths consume (router scoring, scratch
/// draws, top-k retrieval, closed-form probabilities) and nothing that can
/// mutate the arena. `draw_from_shards`, the serve workers, and snapshot
/// top-k all take `TreeView`s — the type system, not convention, keeps the
/// update paths off the read side.
pub struct TreeView<'a, M: FeatureMap> {
    tree: &'a KernelTreeSampler<M>,
}

// manual impls: a view is a reference, copyable regardless of whether M is
impl<M: FeatureMap> Clone for TreeView<'_, M> {
    fn clone(&self) -> Self {
        *self
    }
}

impl<M: FeatureMap> Copy for TreeView<'_, M> {}

impl<'a, M: FeatureMap> TreeView<'a, M> {
    pub fn num_classes(&self) -> usize {
        self.tree.num_classes()
    }

    pub fn embed_dim(&self) -> usize {
        self.tree.embed_dim()
    }

    pub fn feature_map(&self) -> &'a M {
        self.tree.feature_map()
    }

    pub fn emb_row(&self, class: usize) -> &'a [f32] {
        self.tree.emb_row(class)
    }

    pub fn emb_panel(&self) -> &'a [f32] {
        self.tree.emb_panel()
    }

    pub fn leaf_range_of(&self, class: u32) -> std::ops::Range<u32> {
        self.tree.leaf_range_of(class)
    }

    pub fn new_scratch(&self) -> DrawScratch {
        self.tree.new_scratch()
    }

    pub fn partition(&self, phi_h: &[f64]) -> f64 {
        self.tree.partition(phi_h)
    }

    pub fn begin_example(&self, h: &[f32], s: &mut DrawScratch) {
        self.tree.begin_example(h, s)
    }

    pub fn begin_example_prepared(&self, phi_h: &[f64], total: f64, s: &mut DrawScratch) {
        self.tree.begin_example_prepared(phi_h, total, s)
    }

    pub fn draw(&self, h: &[f32], s: &mut DrawScratch, rng: &mut Rng) -> (u32, f64) {
        self.tree.draw(h, s, rng)
    }

    pub fn draw_leaf_scratch(
        &self,
        s: &mut DrawScratch,
        rng: &mut Rng,
    ) -> (std::ops::Range<u32>, f64) {
        self.tree.draw_leaf_scratch(s, rng)
    }

    pub fn class_prob(&self, h: &[f32], class: u32) -> f64 {
        self.tree.class_prob(h, class)
    }

    pub fn topk_beam(&self, h: &[f32], k: usize, beam_width: usize) -> Vec<(u32, f64)> {
        self.tree.topk_beam(h, k, beam_width)
    }
}

/// Reusable per-example memo pool for the m draws of one example: lazily
/// computed `⟨φ(h), z(node)⟩` values and leaf CDFs, validated by a
/// generation counter that [`KernelTreeSampler::begin_example`] bumps.
/// Replaces the old per-call NaN-sentinel vector + `HashMap` cache: flat
/// arrays indexed by node/class id, zero allocation after construction, and
/// NaN is a representable value rather than "unset".
pub struct DrawScratch {
    /// φ(h) of the current example (f64 master).
    phi_h: Vec<f64>,
    /// f32 copy of φ(h) for the vectorized descent dots (clamped finite).
    phi32: Vec<f32>,
    /// f64 partition function ⟨φ(h), z(root)⟩ for exact q reporting.
    total: f64,
    /// Memoized node masses, valid where `node_gen[i] == gen`.
    node_dot: Vec<f64>,
    node_gen: Vec<u32>,
    /// Leaf CDF arena indexed by class id (leaf [lo, hi) owns [lo..hi]),
    /// valid where `leaf_gen[node] == gen`.
    leaf_cum: Vec<f64>,
    /// Raw kernel scores of one leaf's class panel (`kernel_many` output
    /// before sanitize+cumsum; sized to the tree's `leaf_size`).
    leaf_k: Vec<f64>,
    leaf_gen: Vec<u32>,
    gen: u32,
    /// Telemetry locals (plain fields — no atomics on the draw path).
    /// Accumulated while `obs_on` and drained by
    /// [`KernelTreeSampler::put_scratch`]; never read by the draw logic,
    /// so pooling them preserves stream determinism like the memos do.
    obs_on: bool,
    obs_draws: u64,
    obs_zero_mass: u64,
    obs_degenerate: u64,
    obs_exact_fallback: u64,
    obs_min_q: f64,
    /// Draw count per descent depth (index = internal levels traversed);
    /// flushed into the shared histogram via `record_n`.
    obs_depth_counts: Vec<u64>,
    /// Examples begun on this scratch — the quality-monitor stride clock
    /// (monotone; deliberately not reset by the flush).
    obs_examples: u64,
}

impl DrawScratch {
    /// Invalidate all memos for a new example (O(1) amortized; the marker
    /// arrays are only rewritten on generation-counter wrap).
    fn advance_gen(&mut self) {
        if self.gen == u32::MAX {
            self.node_gen.fill(0);
            self.leaf_gen.fill(0);
            self.gen = 0;
        }
        self.gen += 1;
        self.obs_examples += 1;
    }

    /// Account one finished draw into the telemetry locals (callers gate
    /// on `obs_on`; kept out of line so the hot loop stays branch-lean).
    #[inline]
    fn note_draw(&mut self, depth: usize, q: f64) {
        self.obs_draws += 1;
        if q < self.obs_min_q {
            self.obs_min_q = q;
        }
        if let Some(c) = self.obs_depth_counts.get_mut(depth) {
            *c += 1;
        }
    }

    /// eq. (8) partition function of the current example.
    pub fn total(&self) -> f64 {
        self.total
    }

    /// φ(h) of the current example.
    pub fn phi_h(&self) -> &[f64] {
        &self.phi_h
    }
}

impl<M: FeatureMap> Sampler for KernelTreeSampler<M> {
    /// The kernel family's registry name (`"quadratic"`, `"rff"`): the tree
    /// is the canonical sampler of whichever kernel it hosts.
    fn name(&self) -> &str {
        self.map.name()
    }

    fn needs(&self) -> Needs {
        Needs { h: true, ..Needs::default() }
    }

    fn sample(&self, input: &SampleInput, m: usize, rng: &mut Rng, out: &mut Sample) -> Result<()> {
        let h = input.h.ok_or_else(|| anyhow::anyhow!("kernel tree sampler needs h"))?;
        anyhow::ensure!(h.len() == self.d, "h len {} != d {}", h.len(), self.d);
        out.clear();
        // φ(h) once per example, shared by the m draws (O(d²) amortized);
        // node dots and leaf CDFs are memoized across the draws too. The
        // scratch comes from the freelist, so repeated per-example calls
        // don't pay the O(#nodes + n) allocation either.
        let mut scratch = self.take_scratch();
        self.begin_example(h, &mut scratch);
        for _ in 0..m {
            let (class, q) = self.draw(h, &mut scratch, rng);
            out.push(class, q);
        }
        self.maybe_observe_quality(h, &scratch, &out.classes, &out.q);
        self.put_scratch(scratch);
        Ok(())
    }

    /// Batched descent engine: each worker checks one [`DrawScratch`] out
    /// of the freelist and reuses it across all of that worker's rows, so a
    /// steady-state batch performs zero allocations and walks only the flat
    /// arena. Row `i` draws from [`row_rng`]`(step_seed, i)`, bit-identical
    /// to the per-example loop.
    fn sample_batch(
        &self,
        inputs: &BatchSampleInput,
        m: usize,
        step_seed: u64,
        out: &mut [Sample],
    ) -> Result<()> {
        anyhow::ensure!(
            out.len() == inputs.n,
            "out has {} slots, batch has {} rows",
            out.len(),
            inputs.n
        );
        inputs.validate(self.name(), self.needs())?;
        anyhow::ensure!(inputs.d == self.d, "batch h dim {} != sampler d {}", inputs.d, self.d);
        let h_all = inputs.h.expect("validated: kernel tree needs h");
        par_chunks_mut(out, inputs.threads, |base, chunk| {
            let mut scratch = self.take_scratch();
            for (k, slot) in chunk.iter_mut().enumerate() {
                let i = base + k;
                let h = &h_all[i * self.d..(i + 1) * self.d];
                let mut rng = row_rng(step_seed, i);
                self.begin_example(h, &mut scratch);
                slot.clear();
                for _ in 0..m {
                    let (class, q) = self.draw(h, &mut scratch, &mut rng);
                    slot.push(class, q);
                }
                self.maybe_observe_quality(h, &scratch, &slot.classes, &slot.q);
            }
            self.put_scratch(scratch);
        });
        Ok(())
    }

    fn prob(&self, input: &SampleInput, class: u32) -> Option<f64> {
        input.h.map(|h| self.class_prob(h, class))
    }

    /// Batched Fig. 1(b): one aggregated bottom-up sweep (see the inherent
    /// `update_many` — this trait hook just forwards).
    fn update_many(&mut self, classes: &[usize], rows: &[f32]) {
        KernelTreeSampler::update_many(self, classes, rows);
    }

    /// Fig. 1(b): update z along the root→leaf path of the changed class.
    fn update(&mut self, class: usize, w_new: &[f32]) {
        debug_assert!(class < self.n);
        debug_assert_eq!(w_new.len(), self.d);
        // Δφ = φ(new) − φ(old)
        // (scratch buffers are reused; this is the hot update path)
        let dim = self.dim;
        self.map.phi(&self.emb[class * self.d..(class + 1) * self.d], &mut self.scratch_old);
        self.map.phi(w_new, &mut self.scratch_new);
        ops::sub_assign(&mut self.scratch_new, &self.scratch_old);
        // walk the path by range descent, patching arena slices
        let mut idx = 0u32;
        loop {
            let meta = self.meta[idx as usize];
            let base = idx as usize * dim;
            let zs = &mut self.z[base..base + dim];
            let z32s = &mut self.z32[base..base + dim];
            for ((zi, z32i), di) in zs.iter_mut().zip(z32s.iter_mut()).zip(self.scratch_new.iter())
            {
                *zi += *di;
                *z32i = to_f32_clamped(*zi);
            }
            self.stats.node_visits += 1;
            if meta.is_leaf() {
                break;
            }
            let mid = self.meta[meta.left as usize].hi;
            idx = if (class as u32) < mid { meta.left } else { meta.left + 1 };
        }
        self.emb[class * self.d..(class + 1) * self.d].copy_from_slice(w_new);
        self.stats.updates += 1;
    }

    fn reset_embeddings(&mut self, w: &[f32], n: usize, d: usize) {
        assert_eq!(n, self.n, "class count changed");
        assert_eq!(d, self.d, "embedding dim changed");
        assert_eq!(w.len(), n * d);
        self.emb.copy_from_slice(w);
        self.build();
    }

    /// The tree IS the kernel tree — its `update_many` is a real arena
    /// sweep (the trainer's single-sweep accounting counts it).
    fn owns_kernel_tree(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::kernel::QuadraticMap;
    use crate::sampler::test_util::empirical_tv;
    use crate::util::testing::check;

    fn random_emb(rng: &mut Rng, n: usize, d: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; n * d];
        rng.fill_normal(&mut v, 0.5);
        v
    }

    fn exact_dist(map: &QuadraticMap, h: &[f32], emb: &[f32], n: usize, d: usize) -> Vec<f64> {
        let w: Vec<f64> = (0..n).map(|j| map.kernel(h, &emb[j * d..(j + 1) * d])).collect();
        let z: f64 = w.iter().sum();
        w.into_iter().map(|x| x / z).collect()
    }

    #[test]
    fn tree_q_matches_closed_form() {
        let (n, d) = (37, 4);
        let mut rng = Rng::new(1);
        let emb = random_emb(&mut rng, n, d);
        let map = QuadraticMap::new(d, 100.0);
        let mut tree = KernelTreeSampler::new(map.clone(), n, Some(3));
        tree.reset_embeddings(&emb, n, d);
        let h: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let expected = exact_dist(&map, &h, &emb, n, d);
        let input = SampleInput { h: Some(&h), ..Default::default() };
        let mut out = Sample::default();
        tree.sample(&input, 64, &mut rng, &mut out).unwrap();
        for (&c, &q) in out.classes.iter().zip(&out.q) {
            assert!((q - expected[c as usize]).abs() < 1e-9, "class {c}: {q} vs {}", expected[c as usize]);
        }
    }

    #[test]
    fn tree_samples_match_kernel_distribution() {
        let (n, d) = (64, 4);
        let mut rng = Rng::new(2);
        let emb = random_emb(&mut rng, n, d);
        let map = QuadraticMap::new(d, 100.0);
        let mut tree = KernelTreeSampler::new(map.clone(), n, None);
        tree.reset_embeddings(&emb, n, d);
        let h: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let expected = exact_dist(&map, &h, &emb, n, d);
        let input = SampleInput { h: Some(&h), ..Default::default() };
        let tv = empirical_tv(&tree, &input, &expected, 300_000, 17);
        assert!(tv < 0.02, "tv {tv}");
    }

    #[test]
    fn leaf_size_does_not_change_distribution() {
        check("any leaf size gives the kernel distribution", 12, |g| {
            let n = g.usize_in(2, 40);
            let d = g.usize_in(1, 5);
            let leaf = g.usize_in(1, n);
            let mut rng = Rng::new(g.case_seed ^ 1);
            let emb = random_emb(&mut rng, n, d);
            let map = QuadraticMap::new(d, g.f64_in(1.0, 150.0));
            let mut tree = KernelTreeSampler::new(map.clone(), n, Some(leaf));
            tree.reset_embeddings(&emb, n, d);
            let h: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let expected = exact_dist(&map, &h, &emb, n, d);
            // q values must be exact for every draw
            let input = SampleInput { h: Some(&h), ..Default::default() };
            let mut out = Sample::default();
            tree.sample(&input, 32, &mut rng, &mut out).unwrap();
            for (&c, &q) in out.classes.iter().zip(&out.q) {
                assert!((q - expected[c as usize]).abs() < 1e-9);
            }
        });
    }

    #[test]
    fn update_keeps_tree_consistent() {
        check("incremental updates equal a rebuild", 10, |g| {
            let n = g.usize_in(3, 32);
            let d = g.usize_in(1, 4);
            let mut rng = Rng::new(g.case_seed ^ 2);
            let emb = random_emb(&mut rng, n, d);
            let map = QuadraticMap::new(d, 100.0);
            let mut tree = KernelTreeSampler::new(map, n, Some(g.usize_in(1, n)));
            tree.reset_embeddings(&emb, n, d);
            // apply a bunch of random row updates
            for _ in 0..g.usize_in(1, 50) {
                let class = rng.range(0, n);
                let mut w: Vec<f32> = vec![0.0; d];
                rng.fill_normal(&mut w, 0.8);
                tree.update(class, &w);
            }
            let drift = tree.max_drift();
            assert!(drift < 1e-9, "drift {drift}");
        });
    }

    #[test]
    fn update_many_matches_single_updates_and_rebuild() {
        // the batched aggregated sweep must leave the arena within 1e-9 of
        // (a) the equivalent sequence of single updates and (b) a rebuild
        check("update_many == singles == rebuild", 12, |g| {
            let n = g.usize_in(3, 48);
            let d = g.usize_in(1, 4);
            let leaf = g.usize_in(1, n);
            let mut rng = Rng::new(g.case_seed ^ 3);
            let emb = random_emb(&mut rng, n, d);
            let map = QuadraticMap::new(d, 100.0);
            let mut batched = KernelTreeSampler::new(map.clone(), n, Some(leaf));
            batched.reset_embeddings(&emb, n, d);
            let mut singles = KernelTreeSampler::new(map, n, Some(leaf));
            singles.reset_embeddings(&emb, n, d);
            // random class subset, sorted + dedup, with fresh rows
            let k = g.usize_in(1, n);
            let mut classes: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut classes);
            classes.truncate(k);
            classes.sort_unstable();
            let mut rows = vec![0.0f32; k * d];
            rng.fill_normal(&mut rows, 0.8);

            batched.update_many(&classes, &rows);
            for (i, &class) in classes.iter().enumerate() {
                singles.update(class, &rows[i * d..(i + 1) * d]);
            }
            for (idx, (a, b)) in batched.z.iter().zip(&singles.z).enumerate() {
                assert!((a - b).abs() < 1e-9, "z[{idx}]: {a} vs {b}");
            }
            assert_eq!(batched.emb, singles.emb);
            assert!(batched.max_drift() < 1e-9, "drift {}", batched.max_drift());
            // a second sweep over a subset keeps everything consistent too
            let classes2: Vec<usize> = classes.iter().copied().step_by(2).collect();
            let mut rows2 = vec![0.0f32; classes2.len() * d];
            rng.fill_normal(&mut rows2, 0.8);
            batched.update_many(&classes2, &rows2);
            assert!(batched.max_drift() < 1e-9, "drift {}", batched.max_drift());
        });
    }

    #[test]
    fn update_changes_distribution_correctly() {
        let (n, d) = (16, 3);
        let mut rng = Rng::new(5);
        let emb = random_emb(&mut rng, n, d);
        let map = QuadraticMap::new(d, 100.0);
        let mut tree = KernelTreeSampler::new(map.clone(), n, Some(2));
        tree.reset_embeddings(&emb, n, d);
        let h = vec![1.0f32, 0.0, 0.0];
        // blow up class 9's alignment with h
        let w_new = vec![5.0f32, 0.0, 0.0];
        tree.update(9, &w_new);
        let input = SampleInput { h: Some(&h), ..Default::default() };
        let q9 = tree.prob(&input, 9).unwrap();
        assert!(q9 > 0.5, "updated class should dominate: q9 = {q9}");
        // and q must equal the closed form over the *updated* table
        let mut emb2 = emb.clone();
        emb2[9 * d..10 * d].copy_from_slice(&w_new);
        let expected = exact_dist(&map, &h, &emb2, n, d);
        assert!((q9 - expected[9]).abs() < 1e-9);
    }

    #[test]
    fn default_leaf_size_is_d_over_d() {
        let map = QuadraticMap::new(8, 100.0);
        let tree = KernelTreeSampler::new(map, 1000, None);
        // D = 65, d = 8 -> leaf_size = 8
        assert_eq!(tree.leaf_size(), 8);
        assert!(tree.depth() <= 9, "depth {}", tree.depth());
    }

    #[test]
    fn bfs_arena_children_are_adjacent() {
        let tree = KernelTreeSampler::new(QuadraticMap::new(4, 100.0), 100, Some(4));
        for m in &tree.meta {
            if !m.is_leaf() {
                let l = &tree.meta[m.left as usize];
                let r = &tree.meta[m.left as usize + 1];
                assert_eq!(l.lo, m.lo);
                assert_eq!(l.hi, r.lo, "siblings must split the parent range");
                assert_eq!(r.hi, m.hi);
            }
        }
        // root covers everything; arena sized to the node count
        assert_eq!((tree.meta[0].lo, tree.meta[0].hi), (0, 100));
        assert_eq!(tree.z.len(), tree.node_count() * tree.dim);
        assert_eq!(tree.z32.len(), tree.node_count() * tree.dim);
    }

    #[test]
    fn single_class_and_tiny_trees() {
        let map = QuadraticMap::new(2, 100.0);
        let mut tree = KernelTreeSampler::new(map, 1, None);
        tree.reset_embeddings(&[0.3, -0.7], 1, 2);
        let h = vec![1.0f32, 1.0];
        let input = SampleInput { h: Some(&h), ..Default::default() };
        let mut rng = Rng::new(9);
        let mut out = Sample::default();
        tree.sample(&input, 8, &mut rng, &mut out).unwrap();
        assert!(out.classes.iter().all(|&c| c == 0));
        assert!(out.q.iter().all(|&q| (q - 1.0).abs() < 1e-12));
    }

    #[test]
    fn zero_embeddings_give_uniform() {
        // all-zero W: K(h, w) = 1 for all classes -> uniform q
        let map = QuadraticMap::new(4, 100.0);
        let tree = KernelTreeSampler::new(map, 10, Some(2));
        let h = vec![1.0f32; 4];
        let input = SampleInput { h: Some(&h), ..Default::default() };
        for c in 0..10u32 {
            assert!((tree.prob(&input, c).unwrap() - 0.1).abs() < 1e-12);
        }
    }

    #[test]
    fn draw_leaf_probabilities_sum_to_one() {
        let (n, d) = (24, 3);
        let mut rng = Rng::new(7);
        let emb = random_emb(&mut rng, n, d);
        let map = QuadraticMap::new(d, 100.0);
        let mut tree = KernelTreeSampler::new(map, n, Some(4));
        tree.reset_embeddings(&emb, n, d);
        let h: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let phi_h = tree.phi_query(&h);
        // Monte-Carlo: E[1/P(leaf) * |leaf|]-ish sanity + leaf probs valid
        let mut seen = std::collections::HashMap::new();
        for _ in 0..2000 {
            let (range, p) = tree.draw_leaf(&phi_h, &mut rng);
            assert!(p > 0.0 && p <= 1.0 + 1e-12);
            *seen.entry(range.start).or_insert(0usize) += 1;
        }
        // every leaf's empirical frequency ≈ its p
        for (&lo, &count) in &seen {
            // find the leaf's p by a fresh descent probability computation:
            // p = ⟨φ(h), z(leaf)⟩ / ⟨φ(h), z(root)⟩ by eq. (9) chain
            let leaf = (0..tree.meta.len() as u32)
                .find(|&i| tree.meta[i as usize].is_leaf() && tree.meta[i as usize].lo == lo)
                .unwrap();
            let p = ops::dot(&phi_h, tree.z_of(leaf)) / tree.partition(&phi_h);
            let freq = count as f64 / 2000.0;
            assert!((freq - p).abs() < 0.05, "leaf {lo}: freq {freq} vs p {p}");
        }
    }

    /// A feature map whose masses all vanish — the degenerate regime the
    /// zero-mass guards exist for.
    #[derive(Clone)]
    struct ZeroMap {
        d: usize,
    }

    impl FeatureMap for ZeroMap {
        fn d(&self) -> usize {
            self.d
        }
        fn dim(&self) -> usize {
            2
        }
        fn name(&self) -> &'static str {
            "zero"
        }
        fn phi(&self, _a: &[f32], out: &mut [f64]) {
            out.fill(0.0);
        }
        fn kernel(&self, _a: &[f32], _b: &[f32]) -> f64 {
            0.0
        }
    }

    #[test]
    fn zero_mass_tree_reports_positive_q_and_no_descent_bias() {
        // regression (zero-mass leaf + zero-mass branch): an all-zero
        // kernel used to clamp every draw to the last class of the
        // rightmost leaf and report q = 0 (-> ln(m·q) = -inf downstream).
        let n = 16;
        let tree = KernelTreeSampler::new(ZeroMap { d: 3 }, n, Some(2));
        let h = vec![1.0f32, 2.0, 3.0];
        let input = SampleInput { h: Some(&h), ..Default::default() };
        let mut rng = Rng::new(11);
        let mut out = Sample::default();
        let m = 512;
        tree.sample(&input, m, &mut rng, &mut out).unwrap();
        assert_eq!(out.classes.len(), m);
        let mut counts = vec![0usize; n];
        for (&c, &q) in out.classes.iter().zip(&out.q) {
            assert!((c as usize) < n);
            assert!(q > 0.0 && q.is_finite(), "q = {q}");
            assert!((m as f64 * q).ln().is_finite(), "eq. 2 correction blew up");
            counts[c as usize] += 1;
        }
        // guarded descent = fair coin per level + uniform leaf: both halves
        // must be hit, and no single class may absorb the draws
        let left: usize = counts[..n / 2].iter().sum();
        let right: usize = counts[n / 2..].iter().sum();
        assert!(left > m / 8 && right > m / 8, "biased halves: {left} vs {right}");
        assert!(counts.iter().all(|&c| c < m / 2), "one class absorbed the draws: {counts:?}");
        // draw_leaf shares the guard
        let phi_h = tree.phi_query(&h);
        let (_, p) = tree.draw_leaf(&phi_h, &mut rng);
        assert!(p > 0.0, "leaf probability must stay positive");
    }

    #[test]
    fn f32_shadow_overflow_keeps_q_exact() {
        // regression (NaN memo sentinel): at extreme α the z32 shadow
        // overflows f32 and the descent dots go inf/NaN; the generation
        // memo + f64 fallback must keep draws working and q exact.
        let (n, d) = (12, 2);
        let mut rng = Rng::new(13);
        let emb = random_emb(&mut rng, n, d);
        let map = QuadraticMap::new(d, 1e80);
        let mut tree = KernelTreeSampler::new(map.clone(), n, Some(2));
        tree.reset_embeddings(&emb, n, d);
        // shadow must be clamped finite even though the master overflows f32
        assert!(tree.z32.iter().all(|x| x.is_finite()));
        let h: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let expected = exact_dist(&map, &h, &emb, n, d);
        let input = SampleInput { h: Some(&h), ..Default::default() };
        let mut out = Sample::default();
        tree.sample(&input, 64, &mut rng, &mut out).unwrap();
        for (&c, &q) in out.classes.iter().zip(&out.q) {
            let want = expected[c as usize];
            assert!(q > 0.0 && q.is_finite());
            assert!((q - want).abs() < 1e-9 * want.max(1e-12), "q {q} vs {want}");
        }
    }

    #[test]
    fn batched_sampling_reproduces_per_example_streams() {
        // the sample_batch override must be bit-identical to the default
        // per-row loop, for any thread count
        let (n_classes, d, rows, m) = (40, 3, 17, 9);
        let mut rng = Rng::new(21);
        let emb = random_emb(&mut rng, n_classes, d);
        let mut tree = KernelTreeSampler::new(QuadraticMap::new(d, 100.0), n_classes, Some(3));
        tree.reset_embeddings(&emb, n_classes, d);
        let mut hs = vec![0.0f32; rows * d];
        rng.fill_normal(&mut hs, 1.0);
        let step_seed = 0xBA7C4;
        let mut per_example: Vec<Sample> = (0..rows).map(|_| Sample::default()).collect();
        for (i, slot) in per_example.iter_mut().enumerate() {
            let input = SampleInput { h: Some(&hs[i * d..(i + 1) * d]), ..Default::default() };
            let mut r = row_rng(step_seed, i);
            tree.sample(&input, m, &mut r, slot).unwrap();
        }
        for threads in [0usize, 1, 3, 8] {
            let inputs = BatchSampleInput {
                n: rows,
                d,
                n_classes,
                h: Some(&hs),
                threads,
                ..Default::default()
            };
            let mut batched: Vec<Sample> = (0..rows).map(|_| Sample::default()).collect();
            tree.sample_batch(&inputs, m, step_seed, &mut batched).unwrap();
            for (i, (a, b)) in batched.iter().zip(&per_example).enumerate() {
                assert_eq!(a.classes, b.classes, "threads {threads} row {i}");
                assert_eq!(a.q, b.q, "threads {threads} row {i}");
            }
        }
    }

    #[test]
    fn clone_duplicates_arena_and_diverges_independently() {
        let (n, d) = (24, 3);
        let mut rng = Rng::new(31);
        let emb = random_emb(&mut rng, n, d);
        let mut a = KernelTreeSampler::new(QuadraticMap::new(d, 100.0), n, Some(3));
        a.reset_embeddings(&emb, n, d);
        let b = a.clone();
        assert_eq!(a.z, b.z);
        assert_eq!(a.emb, b.emb);
        // mutate the original; the clone's arena must be untouched
        let w = vec![2.0f32; d];
        a.update(5, &w);
        assert_ne!(a.z, b.z);
        assert_eq!(b.emb[5 * d..6 * d], emb[5 * d..6 * d]);
        assert!(b.max_drift() < 1e-9);
    }

    #[test]
    fn draw_leaf_scratch_matches_descent_probabilities() {
        // the scratch-based leaf draw must report the probability it
        // actually used, leaf frequencies ≈ reported p (same contract as
        // draw_leaf, now over the memoized f32-shadow masses)
        let (n, d) = (32, 3);
        let mut rng = Rng::new(37);
        let emb = random_emb(&mut rng, n, d);
        let mut tree = KernelTreeSampler::new(QuadraticMap::new(d, 100.0), n, Some(4));
        tree.reset_embeddings(&emb, n, d);
        let h: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut scratch = tree.take_scratch();
        tree.begin_example(&h, &mut scratch);
        let mut seen: std::collections::HashMap<u32, (usize, f64)> = Default::default();
        let trials = 4000;
        for _ in 0..trials {
            let (range, p) = tree.draw_leaf_scratch(&mut scratch, &mut rng);
            assert!(p > 0.0 && p <= 1.0 + 1e-12);
            let e = seen.entry(range.start).or_insert((0, p));
            e.0 += 1;
            assert!((e.1 - p).abs() < 1e-12, "same leaf must report the same p");
        }
        tree.put_scratch(scratch);
        for (_, &(count, p)) in &seen {
            let freq = count as f64 / trials as f64;
            assert!((freq - p).abs() < 0.04, "freq {freq} vs p {p}");
        }
    }

    #[test]
    fn topk_beam_full_width_is_exact() {
        check("full-width beam == exact top-k", 10, |g| {
            let n = g.usize_in(4, 60);
            let d = g.usize_in(1, 5);
            let k = g.usize_in(1, n);
            let mut rng = Rng::new(g.case_seed ^ 7);
            let emb = random_emb(&mut rng, n, d);
            let map = QuadraticMap::new(d, g.f64_in(1.0, 150.0));
            let mut tree = KernelTreeSampler::new(map.clone(), n, Some(g.usize_in(1, n)));
            tree.reset_embeddings(&emb, n, d);
            let h: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            // oracle: score every class, sort desc with id tie-break
            let mut exact: Vec<(u32, f64)> = (0..n as u32)
                .map(|c| (c, map.kernel(&h, &emb[c as usize * d..(c as usize + 1) * d])))
                .collect();
            exact.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
            exact.truncate(k);
            let got = tree.topk_beam(&h, k, tree.node_count());
            assert_eq!(got.len(), k.min(n));
            for (i, ((gc, gs), (ec, es))) in got.iter().zip(&exact).enumerate() {
                assert!((gs - es).abs() < 1e-9 * es.max(1.0), "rank {i}: {gs} vs {es}");
                assert_eq!(gc, ec, "rank {i}");
            }
        });
    }

    #[test]
    fn tree_q_stays_exact_at_1e5_classes() {
        // bugfix-audit regression for the ops-layer migration: on a
        // catalog-scale model the blocked-dot tree must report q within
        // 1e-9 of a closed form accumulated in a *different* (sequential
        // f64) order, and an update sweep must not widen drift against a
        // from-scratch rebuild — i.e. the refactor cannot have silently
        // changed where long sums accumulate.
        let (n, d) = (100_000usize, 4usize);
        let mut rng = Rng::new(0x1E5);
        let mut emb = vec![0.0f32; n * d];
        rng.fill_normal(&mut emb, 0.4);
        let map = QuadraticMap::new(d, 100.0);
        let mut tree = KernelTreeSampler::new(map.clone(), n, None);
        tree.reset_embeddings(&emb, n, d);
        let h: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        // independent partition function: sequential f64 accumulation, a
        // deliberately different order than the tree's blocked dots
        let total: f64 = (0..n).map(|j| map.kernel(&h, &emb[j * d..(j + 1) * d])).sum();
        let input = SampleInput { h: Some(&h), ..Default::default() };
        let mut out = Sample::default();
        tree.sample(&input, 64, &mut rng, &mut out).unwrap();
        for (&c, &q) in out.classes.iter().zip(&out.q) {
            let c = c as usize;
            let want = map.kernel(&h, &emb[c * d..(c + 1) * d]) / total;
            assert!((q - want).abs() < 1e-9 * want.max(1e-12), "class {c}: {q} vs {want}");
        }
        // a batched Fig. 1(b) sweep over 1000 classes stays within rebuild
        // tolerance (f64 master must not drift)
        let classes: Vec<usize> = (0..1000).map(|i| i * 100).collect();
        let mut rows = vec![0.0f32; classes.len() * d];
        rng.fill_normal(&mut rows, 0.4);
        tree.update_many(&classes, &rows);
        let drift = tree.max_drift();
        assert!(drift < 1e-6, "drift {drift} after sweep at n=1e5");
        assert!(tree.z32.iter().all(|x| x.is_finite()), "shadow must stay finite");
    }

    #[test]
    fn topk_beam_narrow_finds_dominant_class() {
        // one class dwarfs the rest: even a width-1 beam must find it,
        // because its leaf's mass dominates every level of the descent
        let (n, d) = (64, 3);
        let mut rng = Rng::new(41);
        let mut emb = vec![0.0f32; n * d];
        rng.fill_normal(&mut emb, 0.05);
        emb[17 * d..18 * d].copy_from_slice(&[4.0, -4.0, 4.0]);
        let mut tree = KernelTreeSampler::new(QuadraticMap::new(d, 100.0), n, Some(4));
        tree.reset_embeddings(&emb, n, d);
        let h = vec![1.0f32, -1.0, 1.0];
        let top = tree.topk_beam(&h, 1, 1);
        assert_eq!(top[0].0, 17, "beam missed the dominant class: {top:?}");
        // zero-mass guard: an all-zero map still returns k distinct classes
        let ztree = KernelTreeSampler::new(ZeroMap { d: 3 }, 16, Some(2));
        let zt = ztree.topk_beam(&[1.0, 2.0, 3.0], 4, 2);
        assert_eq!(zt.len(), 4);
        let mut ids: Vec<u32> = zt.iter().map(|&(c, _)| c).collect();
        ids.dedup();
        assert_eq!(ids.len(), 4, "duplicate classes in top-k: {zt:?}");
    }

    #[test]
    fn obs_counts_draws_depths_and_min_q() {
        let (n, d, m) = (37, 4, 8usize);
        let mut rng = Rng::new(7);
        let emb = random_emb(&mut rng, n, d);
        let mut tree = KernelTreeSampler::new(QuadraticMap::new(d, 100.0), n, Some(3));
        tree.reset_embeddings(&emb, n, d);
        let reg = crate::obs::MetricsRegistry::new();
        tree.obs().register_into(&reg);
        let h: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let input = SampleInput { h: Some(&h), ..Default::default() };
        let mut out = Sample::default();
        tree.sample(&input, m, &mut rng, &mut out).unwrap();
        let s = reg.snapshot();
        assert_eq!(s.counter("kss_sampler_draws_total"), Some(m as u64));
        assert_eq!(s.counter("kss_sampler_zero_mass_fallback_total"), Some(0));
        assert_eq!(s.counter("kss_sampler_degenerate_branch_total"), Some(0));
        let depth = s.hist("kss_sampler_descent_depth").unwrap();
        assert_eq!(depth.count(), m as u64);
        assert!(depth.min() >= 1.0, "37 classes can't live in one leaf of 3");
        // the min-q gauge is the exact smallest reported proposal prob
        let want = out.q.iter().cloned().fold(f64::INFINITY, f64::min);
        assert_eq!(s.gauge("kss_sampler_min_q"), Some(want));
        assert!(want > 0.0);
    }

    #[test]
    fn obs_counts_zero_mass_and_degenerate_branches() {
        // all-zero kernel: every leaf draw is a uniform fallback and every
        // branch step a fair coin — the counters must say exactly that
        let n = 16; // leaf 2 ⇒ balanced, 3 internal levels per descent
        let tree = KernelTreeSampler::new(ZeroMap { d: 3 }, n, Some(2));
        let h = vec![1.0f32, 2.0, 3.0];
        let input = SampleInput { h: Some(&h), ..Default::default() };
        let mut rng = Rng::new(11);
        let mut out = Sample::default();
        let m = 64;
        tree.sample(&input, m, &mut rng, &mut out).unwrap();
        let obs = tree.obs();
        assert_eq!(obs.draws_total(), m as u64);
        assert_eq!(obs.zero_mass_total(), m as u64);
        assert_eq!(obs.degenerate_branch_total(), 3 * m as u64);
        assert!(obs.min_q() > 0.0, "q-positivity holds even under fallback");
    }

    #[test]
    fn obs_counts_exact_fallbacks_under_f32_overflow() {
        // same extreme-α setup as f32_shadow_overflow_keeps_q_exact: the
        // f32 descent dots overflow, so every first-touch node mass must
        // route through (and count) the exact f64 fallback
        let (n, d) = (12, 2);
        let mut rng = Rng::new(13);
        let emb = random_emb(&mut rng, n, d);
        let mut tree = KernelTreeSampler::new(QuadraticMap::new(d, 1e80), n, Some(2));
        tree.reset_embeddings(&emb, n, d);
        let h: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let input = SampleInput { h: Some(&h), ..Default::default() };
        let mut out = Sample::default();
        tree.sample(&input, 16, &mut rng, &mut out).unwrap();
        assert!(tree.obs().exact_fallback_total() > 0, "overflow never hit the f64 path");
        assert!(out.q.iter().all(|&q| q > 0.0 && q.is_finite()));
    }

    #[test]
    fn obs_quality_monitor_updates_on_stride() {
        let (n, d, m) = (48, 4, 16usize);
        let mut rng = Rng::new(23);
        let emb = random_emb(&mut rng, n, d);
        let mut tree = KernelTreeSampler::new(QuadraticMap::new(d, 100.0), n, Some(4));
        tree.reset_embeddings(&emb, n, d);
        tree.set_monitor_stride(1); // observe every example
        let mut out = Sample::default();
        for _ in 0..4 {
            let h: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let input = SampleInput { h: Some(&h), ..Default::default() };
            tree.sample(&input, m, &mut rng, &mut out).unwrap();
        }
        let obs = tree.obs();
        let ess = obs.ess_fraction();
        assert!(ess > 0.0 && ess <= 1.0 + 1e-12, "ess fraction {ess}");
        assert!(obs.tv_estimate() > 0.0, "reservoir TV should be set and nonzero");
    }

    #[test]
    fn obs_disabled_skips_accounting() {
        let (n, d) = (24, 3);
        let mut rng = Rng::new(29);
        let emb = random_emb(&mut rng, n, d);
        let mut tree = KernelTreeSampler::new(QuadraticMap::new(d, 100.0), n, Some(3));
        tree.reset_embeddings(&emb, n, d);
        tree.set_obs_enabled(false);
        tree.set_monitor_stride(1);
        let h: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let input = SampleInput { h: Some(&h), ..Default::default() };
        let mut out = Sample::default();
        tree.sample(&input, 32, &mut rng, &mut out).unwrap();
        let obs = tree.obs();
        assert_eq!(obs.draws_total(), 0);
        assert_eq!(obs.ess_fraction(), 0.0);
        assert_eq!(obs.min_q(), 0.0);
    }

    #[test]
    fn obs_cells_shared_with_clones() {
        // the snapshot publisher clones trees; telemetry must aggregate
        // into the source tree's series, not vanish into the clone
        let (n, d) = (24, 3);
        let mut rng = Rng::new(31);
        let emb = random_emb(&mut rng, n, d);
        let mut a = KernelTreeSampler::new(QuadraticMap::new(d, 100.0), n, Some(3));
        a.reset_embeddings(&emb, n, d);
        let b = a.clone();
        let h: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let input = SampleInput { h: Some(&h), ..Default::default() };
        let mut out = Sample::default();
        b.sample(&input, 8, &mut rng, &mut out).unwrap();
        assert_eq!(a.obs().draws_total(), 8, "clone draws must land in the shared cells");
    }
}
