// pallas-lint fixture — must NOT trip ACC.

/// Hot-path reduction through the ops layer: the pinned accumulation order.
pub fn dot_ok(a: &[f32], b: &[f32]) -> f64 {
    crate::ops::dot_mixed(a, b)
}

/// Integer counter bumps are not float reductions.
pub fn count_positive(xs: &[i32]) -> usize {
    let mut n = 0;
    for x in xs {
        if *x > 0 {
            n += 1;
        }
    }
    n
}

/// A float accumulator that never reads data (constant stride) is not a
/// reduction over a slice.
pub fn ramp(steps: usize) -> f64 {
    let mut t = 0.0f64;
    for _ in 0..steps {
        t += 1.0;
    }
    t
}

#[cfg(test)]
mod tests {
    /// Test oracles may sum however they like — the contract binds
    /// production paths only.
    #[test]
    fn oracle_sum_is_fine() {
        let xs = [0.25f64, 0.5, 0.125];
        let mut acc = 0.0f64;
        for i in 0..xs.len() {
            acc += xs[i];
        }
        assert!(acc > 0.0);
    }
}
