//! Negative samplers — the paper's subject matter.
//!
//! A [`Sampler`] draws, for one training example, `m` negative classes *with
//! replacement* from its distribution `q` and reports the probability of
//! each draw (the trainer turns those into the eq. (2) corrections
//! `ln(m q_i)`). The paper's taxonomy (§2.4) orders samplers by how much of
//! the model they see:
//!
//! | sampler        | example-dep. | model-dep. | cost/draw        | batched draw        |
//! |----------------|--------------|------------|------------------|---------------------|
//! | uniform        | no           | no         | O(1)             | default fan-out     |
//! | unigram        | no           | no         | O(1) (alias)     | default fan-out     |
//! | bigram         | context only | no         | O(1) (alias)     | default fan-out     |
//! | quadratic tree | yes          | yes        | O(D log n) §3.2  | native (arena+pool) |
//! | quadratic shard| yes          | yes        | O(D log n) + S   | native (router+pool)|
//! | quadratic flat | yes          | yes        | O(n) (oracle)    | native (pooled CDF) |
//! | quartic flat   | yes          | yes        | O(n)             | native (pooled CDF) |
//! | rff tree       | yes          | yes        | O(D log n), D=4d | native (arena+pool) |
//! | rff shard      | yes          | yes        | O(D log n) + S   | native (router+pool)|
//! | rff flat (exp) | yes          | yes        | O(n) (oracle)    | native (pooled CDF) |
//! | softmax exact  | yes          | yes        | O(n) (Thm 2.1)   | default fan-out     |
//! | 2pass tree     | yes          | yes        | O(P/B·D log n) amortized | native (shared pool) |
//! | midx (quad/rff)| yes          | yes        | O(D·√n)/example + O(√n) refine | native (arena+pool) |
//!
//! The canonical name list (with one-line summaries for the CLI and the
//! unknown-name error) is [`SAMPLER_REGISTRY`] — one table, so new kernels
//! cannot drift out of the help text or the error message.
//!
//! All samplers are deterministic functions of the seeded [`Rng`] stream
//! passed in, so experiments replay exactly.
//!
//! # Batch API contract
//!
//! [`Sampler::sample_batch`] draws every example of a training step in one
//! call; the sampler layer (not the trainer) owns the parallel fan-out.
//! The contract is **stream determinism**: row `i` of the batch must be
//! sampled from the RNG stream [`row_rng`]`(step_seed, i)`, so
//! `sample_batch` produces bit-identical `(class, q)` sequences to calling
//! [`Sampler::sample`] per row with those streams — for any thread count,
//! including 1. The default implementation does exactly that per-row loop
//! (fanned out over [`par_chunks_mut`] workers); `KernelTreeSampler`
//! overrides it with a batched descent engine that reuses one arena scratch
//! pool per worker instead of allocating per example.
//!
//! **One documented exception**: the two-pass samplers
//! (`kernel::two_pass`, names `*-2pass`) are deliberately
//! *batch-coupled* — pass 1 draws one candidate pool shared by all rows
//! of the call, so a per-example [`Sampler::sample`] loop (each call its
//! own B = 1 batch with its own pool) is **not** bit-identical to
//! `sample_batch`. Stream determinism still holds where it matters:
//! `sample_batch` is a pure function of `(inputs, m, step_seed)` for any
//! thread count — the pool consumes a dedicated salted stream on the
//! calling thread and row `i` still resamples from [`row_rng`].
//!
//! Invariant (eq. 2): no sampler may ever report `q ≤ 0` — the trainer
//! feeds `ln(m·q)` to the training kernel, and a zero would poison the
//! logits with `-inf`. [`Sample::push`] debug-asserts this at the source.

pub mod bigram;
pub mod kernel;
pub mod rff;
pub mod softmax_exact;
pub mod uniform;
pub mod unigram;

use crate::util::rng::Rng;
use crate::util::threadpool::par_chunks_mut;
use anyhow::Result;

pub use bigram::BigramSampler;
pub use kernel::flat::FlatKernelSampler;
pub use kernel::midx::{MidxCore, MidxIndex, MidxKernelSampler, MidxObs};
pub use kernel::tree::{KernelTreeSampler, TreeObs};
pub use kernel::two_pass::{TwoPassKernelSampler, TwoPassObs, DEFAULT_POOL_FACTOR};
pub use kernel::{KernelKind, QuadraticMap};
pub use rff::{PositiveRffMap, RffConfig};
pub use softmax_exact::SoftmaxSampler;
pub use uniform::UniformSampler;
pub use unigram::UnigramSampler;

/// The deterministic per-row RNG stream of the batch API: row `i` of a step
/// seeded with `step_seed` always samples from this stream, whether drawn
/// through [`Sampler::sample_batch`] or a per-example [`Sampler::sample`]
/// loop, and regardless of the fan-out thread count. The stream definition
/// lives in [`crate::util::rng`] (so `AliasTable::sample_many` can share
/// it); this re-export is the sampler-layer name every sampler uses.
pub use crate::util::rng::row_rng;

/// Batch-level inputs for [`Sampler::sample_batch`]: the whole step's
/// model-dependent tensors in flat row-major form, plus the fan-out width.
/// The trainer fills only what the chosen sampler [`Needs`].
#[derive(Clone, Copy, Debug, Default)]
pub struct BatchSampleInput<'a> {
    /// Number of examples (rows) in the batch.
    pub n: usize,
    /// Embedding dimension of `h` rows.
    pub d: usize,
    /// Number of classes (width of `logits` rows).
    pub n_classes: usize,
    /// Query embeddings, (n × d) row-major.
    pub h: Option<&'a [f32]>,
    /// Full logit rows, (n × n_classes) row-major.
    pub logits: Option<&'a [f32]>,
    /// Previous token per example (LM context).
    pub prev: Option<&'a [u32]>,
    /// Worker threads for the fan-out (0 = serial). Results never depend on
    /// this — it is part of the batch input only so the sampler layer owns
    /// the parallelism decision, not the trainer.
    pub threads: usize,
}

impl<'a> BatchSampleInput<'a> {
    /// The per-example view of row `i` (what [`Sampler::sample`] consumes).
    #[inline]
    pub fn row(&self, i: usize) -> SampleInput<'a> {
        SampleInput {
            h: self.h.map(|h| &h[i * self.d..(i + 1) * self.d]),
            logits: self.logits.map(|l| &l[i * self.n_classes..(i + 1) * self.n_classes]),
            prev: self.prev.map(|p| p[i]),
        }
    }

    /// Validate that everything `needs` asks for is present and correctly
    /// sized for `n` rows, so per-row sampling cannot fail midway through a
    /// parallel section.
    pub fn validate(&self, name: &str, needs: Needs) -> Result<()> {
        if needs.h {
            let h = self
                .h
                .ok_or_else(|| anyhow::anyhow!("sampler '{name}' needs h for sample_batch"))?;
            anyhow::ensure!(
                h.len() == self.n * self.d,
                "h is {} floats, batch ({} × d={}) needs {}",
                h.len(),
                self.n,
                self.d,
                self.n * self.d
            );
        }
        if needs.logits {
            let l = self
                .logits
                .ok_or_else(|| anyhow::anyhow!("sampler '{name}' needs logits for sample_batch"))?;
            anyhow::ensure!(
                l.len() == self.n * self.n_classes,
                "logits is {} floats, batch ({} × n={}) needs {}",
                l.len(),
                self.n,
                self.n_classes,
                self.n * self.n_classes
            );
        }
        if needs.prev {
            let p = self
                .prev
                .ok_or_else(|| anyhow::anyhow!("sampler '{name}' needs prev for sample_batch"))?;
            anyhow::ensure!(
                p.len() == self.n,
                "prev has {} entries, batch has {}",
                p.len(),
                self.n
            );
        }
        Ok(())
    }
}

/// Per-example inputs a sampler may consume. The trainer fills only what the
/// chosen sampler [`Needs`]; the rest stays `None`.
#[derive(Clone, Copy, Debug, Default)]
pub struct SampleInput<'a> {
    /// Query embedding h (the model's last hidden layer) for this example.
    pub h: Option<&'a [f32]>,
    /// Full logits row o = W h (from the score_all artifact) — only the
    /// exact/oracle samplers ask for this.
    pub logits: Option<&'a [f32]>,
    /// Previous token (LM context) for the bigram sampler.
    pub prev: Option<u32>,
}

/// What a sampler requires per batch; the trainer uses this to decide which
/// artifacts to run (encode for `h`, score_all for `logits`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Needs {
    pub h: bool,
    pub logits: bool,
    pub prev: bool,
}

/// One example's sample: m class indices (with replacement) and the
/// probability q of each draw under the sampler's distribution.
#[derive(Clone, Debug, Default)]
pub struct Sample {
    pub classes: Vec<u32>,
    pub q: Vec<f64>,
}

impl Sample {
    pub fn with_capacity(m: usize) -> Sample {
        Sample { classes: Vec::with_capacity(m), q: Vec::with_capacity(m) }
    }

    pub fn clear(&mut self) {
        self.classes.clear();
        self.q.clear();
    }

    pub fn push(&mut self, class: u32, q: f64) {
        // eq. (2) feeds ln(m·q) to the training kernel: q = 0 would inject
        // -inf, q = NaN poisons the loss. Every sampler must guard its own
        // degenerate cases (see the zero-mass fallbacks in kernel/tree.rs).
        debug_assert!(
            q > 0.0 && q.is_finite(),
            "sampler reported q = {q} for class {class} (must be finite and > 0)"
        );
        self.classes.push(class);
        self.q.push(q);
    }
}

/// A negative-sampling distribution (immutable during a batch; `update` is
/// called between steps with the classes whose embeddings changed).
pub trait Sampler: Send + Sync {
    /// Short name used in configs, logs and figures.
    fn name(&self) -> &str;

    /// What per-example inputs `sample` consumes.
    fn needs(&self) -> Needs {
        Needs::default()
    }

    /// Draw `m` negatives with replacement into `out` (cleared first).
    fn sample(&self, input: &SampleInput, m: usize, rng: &mut Rng, out: &mut Sample) -> Result<()>;

    /// Draw `m` negatives for every row of a batch into `out` (one slot per
    /// row, each cleared first). Row `i` samples from the deterministic
    /// stream [`row_rng`]`(step_seed, i)`, so the result is bit-identical
    /// to a per-example [`Sampler::sample`] loop over those streams — for
    /// any `inputs.threads`, including 0/1 (serial).
    ///
    /// The default implementation is exactly that loop, fanned out over
    /// static contiguous chunks; adaptive samplers override it to amortize
    /// per-example setup (see `KernelTreeSampler`, which reuses one arena
    /// scratch pool per worker).
    fn sample_batch(
        &self,
        inputs: &BatchSampleInput,
        m: usize,
        step_seed: u64,
        out: &mut [Sample],
    ) -> Result<()> {
        anyhow::ensure!(
            out.len() == inputs.n,
            "out has {} slots, batch has {} rows",
            out.len(),
            inputs.n
        );
        inputs.validate(self.name(), self.needs())?;
        par_chunks_mut(out, inputs.threads, |base, chunk| {
            for (k, slot) in chunk.iter_mut().enumerate() {
                let i = base + k;
                let input = inputs.row(i);
                let mut rng = row_rng(step_seed, i);
                self.sample(&input, m, &mut rng, slot)
                    .expect("sampler failed (batch inputs were validated)");
            }
        });
        Ok(())
    }

    /// Probability of a single class under the current distribution for the
    /// given input (used by tests and the gradient-bias bench). Default:
    /// unsupported.
    fn prob(&self, _input: &SampleInput, _class: u32) -> Option<f64> {
        None
    }

    /// Notify the sampler that a class embedding changed (paper Fig. 1(b)).
    /// Static samplers ignore this.
    fn update(&mut self, _class: usize, _w_new: &[f32]) {}

    /// Batched update: `classes` sorted + deduplicated, `rows` the flat
    /// (len·d) buffer of new embeddings in the same order. Default loops
    /// over [`Sampler::update`]; the kernel tree overrides it with a single
    /// aggregated bottom-up sweep (much cheaper per step).
    fn update_many(&mut self, classes: &[usize], rows: &[f32]) {
        if classes.is_empty() {
            return;
        }
        let d = rows.len() / classes.len();
        for (i, &class) in classes.iter().enumerate() {
            self.update(class, &rows[i * d..(i + 1) * d]);
        }
    }

    /// Adaptive samplers that mirror W need the full table at (re)start.
    fn reset_embeddings(&mut self, _w: &[f32], _n: usize, _d: usize) {}

    /// True for read-only adapters that draw from *published* kernel-tree
    /// snapshots (see `crate::serve::SnapshotSampler`): their tree
    /// maintenance happens in the owning publisher, never through
    /// [`Sampler::update_many`]. The training pipeline uses this to (a)
    /// skip the sampler-side tree sweep (the single-sweep invariant) and
    /// (b) allow a step's sampling to overlap the previous step's device
    /// execute — a pinned snapshot generation cannot change underneath the
    /// draw.
    fn snapshot_backed(&self) -> bool {
        false
    }

    /// Re-pin a snapshot-backed sampler to the freshest published
    /// generation set. The pipeline calls this at a deterministic point in
    /// the stage schedule (immediately before a step's draws begin, on the
    /// thread running them — never concurrently with `sample_batch`), so
    /// the generation a step samples from is a pure function of the
    /// schedule, not of thread timing. No-op for samplers that own their
    /// state.
    fn refresh_snapshots(&self) {}

    /// Minimum generation across the currently pinned snapshot set; `None`
    /// for samplers that own their state. The pipeline tags each step's
    /// draws with this so the eq. (2) corrections are provably taken from
    /// the generation actually sampled.
    fn pinned_generation(&self) -> Option<u64> {
        None
    }

    /// Whether this sampler owns and maintains a kernel tree through
    /// [`Sampler::update_many`] — the trainer's per-step sweep accounting
    /// (at most one kernel-tree update sweep may run per sampled step).
    fn owns_kernel_tree(&self) -> bool {
        false
    }
}

/// Corpus statistics the frequency-based samplers are built from.
pub struct CorpusStats {
    /// Class occurrence counts (unigram).
    pub class_counts: Vec<u64>,
    /// (prev, next) pair counts for the bigram sampler, sparse.
    pub bigram_counts: Option<Vec<Vec<(u32, u64)>>>,
}

/// One registry entry: the canonical sampler name plus the one-line
/// summary shown by `kss --help` and the README table.
pub struct SamplerInfo {
    pub name: &'static str,
    pub summary: &'static str,
}

/// The single source of truth for sampler names: the unknown-name error
/// and the CLI help footer derive from this list mechanically, and the
/// registry round-trip test pins every entry to a building sampler that
/// reports exactly this name (the README table mirrors it by hand). Order
/// is display order.
pub const SAMPLER_REGISTRY: &[SamplerInfo] = &[
    SamplerInfo { name: "uniform", summary: "uniform over classes (static baseline)" },
    SamplerInfo { name: "unigram", summary: "corpus frequency, alias table (static)" },
    SamplerInfo { name: "bigram", summary: "previous-token conditional (LM datasets)" },
    SamplerInfo { name: "softmax", summary: "exact softmax oracle (Thm 2.1, O(n))" },
    SamplerInfo { name: "quadratic", summary: "αo²+1 kernel tree (§3.2, D = d²+1)" },
    SamplerInfo {
        name: "quadratic-sharded",
        summary: "quadratic tree split into S router-merged shards",
    },
    SamplerInfo { name: "quadratic-flat", summary: "αo²+1 exact O(n) oracle" },
    SamplerInfo { name: "quartic", summary: "o⁴+1 flat sampler (no tractable φ)" },
    SamplerInfo { name: "rff", summary: "positive random features ≈ exp kernel, D = 4d" },
    SamplerInfo { name: "rff-sharded", summary: "rff tree split into S router-merged shards" },
    SamplerInfo { name: "rff-flat", summary: "exact exp-kernel (softmax) flat oracle" },
    SamplerInfo {
        name: "quadratic-streaming",
        summary: "quadratic tree + memtable/tombstones (online class churn)",
    },
    SamplerInfo {
        name: "rff-streaming",
        summary: "rff tree + memtable/tombstones (online class churn)",
    },
    SamplerInfo {
        name: "quadratic-2pass",
        summary: "quadratic tree, batch-shared two-pass pool (TAPAS-style)",
    },
    SamplerInfo {
        name: "rff-2pass",
        summary: "rff tree, batch-shared two-pass pool (TAPAS-style)",
    },
    SamplerInfo {
        name: "quadratic-midx",
        summary: "quadratic inverted multi-index (k-means two-level, K ≈ √n)",
    },
    SamplerInfo {
        name: "rff-midx",
        summary: "rff inverted multi-index (k-means two-level, K ≈ √n)",
    },
];

/// Comma-separated registry names (error messages, CLI help).
pub fn sampler_names() -> String {
    SAMPLER_REGISTRY.iter().map(|s| s.name).collect::<Vec<_>>().join(", ")
}

/// Build a sampler by name (see [`SAMPLER_REGISTRY`] for the list).
/// `stats` feeds unigram/bigram; `w`/`d` seed the adaptive samplers'
/// embedding mirror; `abs_logits` tells the softmax oracle to use the |o|
/// prediction distribution (§3.3); `alpha` parameterizes the quadratic
/// family (the rff family instead reads its fixed build seed and `D = 4d`
/// from [`RffConfig`], so draws reproduce from `(config, seed)` alone).
pub fn build_sampler(
    name: &str,
    n_classes: usize,
    d: usize,
    alpha: f32,
    abs_logits: bool,
    stats: Option<&CorpusStats>,
    w: Option<&[f32]>,
) -> Result<Box<dyn Sampler>> {
    let mut s: Box<dyn Sampler> = match name {
        "uniform" => Box::new(UniformSampler::new(n_classes)),
        "unigram" => {
            let stats = stats.ok_or_else(|| anyhow::anyhow!("unigram needs corpus stats"))?;
            Box::new(UnigramSampler::new(&stats.class_counts)?)
        }
        "bigram" => {
            let stats = stats.ok_or_else(|| anyhow::anyhow!("bigram needs corpus stats"))?;
            let pairs = stats
                .bigram_counts
                .as_ref()
                .ok_or_else(|| anyhow::anyhow!("bigram needs pair counts (LM datasets only)"))?;
            Box::new(BigramSampler::new(&stats.class_counts, pairs, 0.75)?)
        }
        "softmax" => Box::new(SoftmaxSampler::new(n_classes, abs_logits)),
        "quadratic" => Box::new(KernelTreeSampler::new(
            QuadraticMap::new(d, alpha as f64),
            n_classes,
            None,
        )),
        // the serve layer's sharded tree as a drop-in training sampler:
        // identical distribution to "quadratic" (property-tested), with
        // per-shard parallel updates. S is pinned — NOT derived from the
        // host's core count — because shard topology shapes how the
        // row_rng streams are consumed, and results must stay
        // bit-reproducible from (config, seed) on any machine. The update
        // fan-out adapts to the machine instead (a cap, never affecting
        // results); code that needs a different S constructs the sampler
        // directly.
        "quadratic-sharded" => Box::new(crate::serve::shard::ShardedKernelSampler::new(
            QuadraticMap::new(d, alpha as f64),
            n_classes,
            4,
            None,
        )),
        "quadratic-flat" => {
            Box::new(FlatKernelSampler::new(KernelKind::Quadratic { alpha: alpha as f64 }))
        }
        "quartic" => Box::new(FlatKernelSampler::new(KernelKind::Quartic)),
        // exp-kernel family via positive random features: D = 4d, feature
        // draws pinned to RFF_BUILD_SEED (shard-consistent and
        // reproducible from the config alone — same rule as the pinned
        // shard count above)
        "rff" => Box::new(KernelTreeSampler::new(
            PositiveRffMap::new(RffConfig::new(d, rff::RFF_BUILD_SEED)),
            n_classes,
            None,
        )),
        "rff-sharded" => Box::new(crate::serve::shard::ShardedKernelSampler::new(
            PositiveRffMap::new(RffConfig::new(d, rff::RFF_BUILD_SEED)),
            n_classes,
            4,
            None,
        )),
        "rff-flat" => Box::new(FlatKernelSampler::new(KernelKind::Exp)),
        // the streaming-vocabulary samplers (crate::vocab): a dense
        // 0..n_classes catalog at build time, with insert_class /
        // retire_class available through the concrete type for churn
        // drivers; leaf_size None = the tree's default policy
        "quadratic-streaming" => Box::new(crate::vocab::StreamingKernelSampler::new(
            QuadraticMap::new(d, alpha as f64),
            n_classes,
            None,
        )),
        "rff-streaming" => Box::new(crate::vocab::StreamingKernelSampler::new(
            PositiveRffMap::new(RffConfig::new(d, rff::RFF_BUILD_SEED)),
            n_classes,
            None,
        )),
        // two-pass batch-shared pool over the owning trees (the trainer's
        // snapshot-backed path instead applies SnapshotSampler::
        // with_two_pass over the published generations); the default pool
        // divisor α here matches TrainConfig::default — callers that tune
        // α construct TwoPassKernelSampler directly
        "quadratic-2pass" => Box::new(kernel::two_pass::TwoPassKernelSampler::new(
            QuadraticMap::new(d, alpha as f64),
            n_classes,
            None,
            kernel::two_pass::DEFAULT_POOL_FACTOR,
        )),
        "rff-2pass" => Box::new(kernel::two_pass::TwoPassKernelSampler::new(
            PositiveRffMap::new(RffConfig::new(d, rff::RFF_BUILD_SEED)),
            n_classes,
            None,
            kernel::two_pass::DEFAULT_POOL_FACTOR,
        )),
        // inverted multi-index (kernel::midx): K = ⌈√n⌉ k-means clusters
        // with per-cluster φ-aggregates; coarse cluster CDF is one
        // kernel-dim op per cluster, within-cluster refine is exact.
        // K and the build seed are pinned by the same reproducibility
        // rule as the shard count above; callers that tune them construct
        // MidxKernelSampler::with_config directly
        "quadratic-midx" => Box::new(kernel::midx::MidxKernelSampler::new(
            QuadraticMap::new(d, alpha as f64),
            n_classes,
            None,
        )),
        "rff-midx" => Box::new(kernel::midx::MidxKernelSampler::new(
            PositiveRffMap::new(RffConfig::new(d, rff::RFF_BUILD_SEED)),
            n_classes,
            None,
        )),
        other => anyhow::bail!("unknown sampler '{other}' (known: {})", sampler_names()),
    };
    if let Some(w) = w {
        s.reset_embeddings(w, n_classes, d);
    }
    Ok(s)
}

/// All sampler names usable on every dataset (bigram is LM-only).
pub const GENERIC_SAMPLERS: &[&str] = &["uniform", "softmax", "quadratic"];

/// Sampler set for the Penn-Tree-Bank-style figures (paper Fig. 2 left).
pub const LM_SAMPLERS: &[&str] =
    &["uniform", "unigram", "bigram", "quadratic", "quartic", "softmax"];

#[cfg(test)]
pub(crate) mod test_util {
    use super::*;

    /// Empirical total-variation distance between a sampler and an expected
    /// distribution, over `draws` samples. The TV arithmetic itself lives
    /// in [`crate::util::stats::tv_from_counts`] — one implementation
    /// shared with the closed-form bias benches.
    pub fn empirical_tv(
        sampler: &dyn Sampler,
        input: &SampleInput,
        expected: &[f64],
        draws: usize,
        seed: u64,
    ) -> f64 {
        let mut rng = Rng::new(seed);
        let mut counts = vec![0usize; expected.len()];
        let mut out = Sample::default();
        let m = 16;
        let mut total = 0usize;
        while total < draws {
            out.clear();
            sampler.sample(input, m, &mut rng, &mut out).unwrap();
            for &c in &out.classes {
                counts[c as usize] += 1;
            }
            total += m;
        }
        crate::util::stats::tv_from_counts(&counts, total, expected)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_sample_batch_reproduces_per_row_streams() {
        let sampler = UniformSampler::new(50);
        let n = 13;
        let m = 7;
        let step_seed = 0xFEED_F00D;
        let inputs = BatchSampleInput { n, threads: 3, ..Default::default() };
        let mut batched: Vec<Sample> = (0..n).map(|_| Sample::with_capacity(m)).collect();
        sampler.sample_batch(&inputs, m, step_seed, &mut batched).unwrap();
        for (i, row) in batched.iter().enumerate() {
            let mut rng = row_rng(step_seed, i);
            let mut want = Sample::default();
            sampler.sample(&SampleInput::default(), m, &mut rng, &mut want).unwrap();
            assert_eq!(row.classes, want.classes, "row {i}");
            assert_eq!(row.q, want.q, "row {i}");
        }
    }

    #[test]
    fn sample_batch_is_thread_count_invariant() {
        let sampler = UniformSampler::new(31);
        let n = 9;
        let m = 4;
        let run = |threads: usize| {
            let inputs = BatchSampleInput { n, threads, ..Default::default() };
            let mut out: Vec<Sample> = (0..n).map(|_| Sample::with_capacity(m)).collect();
            sampler.sample_batch(&inputs, m, 42, &mut out).unwrap();
            out.iter().map(|s| s.classes.clone()).collect::<Vec<_>>()
        };
        let serial = run(0);
        for threads in [1, 2, 5, 16] {
            assert_eq!(run(threads), serial, "threads={threads}");
        }
    }

    #[test]
    fn registry_is_the_single_source_of_names() {
        // every registered name must build, and must report itself under
        // exactly its registry name (the round-trip that keeps configs,
        // logs and figures consistent)
        let n = 16;
        let stats = CorpusStats {
            class_counts: vec![1; n],
            bigram_counts: Some(vec![vec![(0, 1)]; n]),
        };
        let emb = vec![0.1f32; n * 3];
        for info in SAMPLER_REGISTRY {
            let s = build_sampler(info.name, n, 3, 100.0, false, Some(&stats), Some(&emb))
                .unwrap_or_else(|e| panic!("registry name '{}' failed to build: {e}", info.name));
            assert_eq!(s.name(), info.name, "name must round-trip through build_sampler");
            assert!(!info.summary.is_empty());
        }
        // the unknown-name error derives from the same table — no
        // hand-maintained list to drift
        let err = build_sampler("no-such-kernel", n, 3, 100.0, false, None, None).unwrap_err();
        let msg = err.to_string();
        for info in SAMPLER_REGISTRY {
            assert!(msg.contains(info.name), "error message misses '{}': {msg}", info.name);
        }
    }

    #[test]
    fn sample_batch_validates_missing_inputs() {
        // softmax needs logits; an unfilled batch input must error up front
        let sampler = SoftmaxSampler::new(8, false);
        let inputs = BatchSampleInput { n: 2, n_classes: 8, ..Default::default() };
        let mut out: Vec<Sample> = (0..2).map(|_| Sample::default()).collect();
        let err = sampler.sample_batch(&inputs, 3, 1, &mut out).unwrap_err();
        assert!(err.to_string().contains("logits"), "{err}");
        // wrong out length is also an error
        let mut short: Vec<Sample> = vec![Sample::default()];
        assert!(sampler.sample_batch(&inputs, 3, 1, &mut short).is_err());
    }
}
