#!/usr/bin/env python3
"""Python port of the two-pass batch-shared sampling engine
(rust/src/sampler/kernel/two_pass.rs), run against the same property
checks as the Rust tests.

The build container has no rust toolchain (see .claude/skills/verify/
SKILL.md), so the algorithmic core of the PR is ported faithfully — same
pool sizing, same run-table dedup, same SIR reweighting and guard order —
and validated here:

  1. pool sizing: P = ceil(B*m/alpha) clamped to [m, B*m]
  2. composed q is exact for the realized pool: every reported q equals
     n_c * K(h,c) / qbar(c) / S and sums to 1 over the pool support
  3. chi-square goodness of fit of resampled draws against the composed
     conditional distribution
  4. SIR marginal: averaged over fresh pools, the composed distribution
     approaches the exact per-row kernel distribution (TV), and beats the
     un-reweighted variant (which squares the kernel — the flaw the
     qbar division exists to prevent)
  5. q-corrected partition estimator stays near the truth (eq. (2)
     gradient-bias proxy), parity with per-row tree descent
  6. degenerate pool (zero kernel): counted fallback redraw through the
     per-row descent, q still strictly positive

The tree, feature maps and guard helpers are imported from
serve_port_check.py (the ported PR-1/PR-4 serve layer).

Run: python3 python/tools/two_pass_port_check.py
"""
import math
import os
import random
import sys

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from serve_port_check import (  # noqa: E402
    QuadraticMap,
    Tree,
    ZeroMap,
    exact_dist,
    sanitize_mass,
    step_down_to_positive,
)

F64_MIN_POSITIVE = 5e-324


def positive_pool_mass(total):
    """Port of two_pass::positive_pool_mass — the QPOS guard idiom."""
    if total > 0.0 and math.isfinite(total):
        return total
    return None


def pool_size(n_rows, m, pool_factor):
    """Port of TwoPassCore::pool_size: P = ceil(B*m/alpha), in [m, B*m]."""
    target = math.ceil((n_rows * m) / pool_factor)
    return min(max(target, max(m, 1)), max(n_rows * m, 1))


def build_pool(tree, hs, p, rng):
    """Port of TwoPassCore::build_pool.

    hs: (rows, d) f32 queries. Returns the run table
    (run_class, run_count, run_qbar) built from P coarse descents off the
    batch-mean query; qbar is the tree's exact guarded coarse q per slot.
    """
    hacc = hs.astype(np.float64).sum(axis=0)
    hbar = (hacc / len(hs)).astype(np.float32)
    scratch = tree.begin_example(hbar)
    slots = sorted(tree.draw(hbar, scratch, rng) for _ in range(p))
    run_class, run_count, run_qbar = [], [], []
    for cls, qbar in slots:
        if run_class and run_class[-1] == cls:
            run_count[-1] += 1
        else:
            run_class.append(cls)
            run_count.append(1)
            run_qbar.append(qbar)
    return run_class, run_count, run_qbar


def row_cdf(tree, pool, h, reweight=True):
    """Pass-2 composed weights for one row: w(c) = n_c * K(h,c) / qbar(c)
    as inclusive prefix sums (reweight=False drops the SIR division — the
    kernel-squared control in check 4)."""
    run_class, run_count, run_qbar = pool
    cum, acc = [], 0.0
    for cls, n_c, qbar in zip(run_class, run_count, run_qbar):
        k = sanitize_mass(tree.map.kernel(h, tree.emb[cls]))
        ratio = k / max(qbar, F64_MIN_POSITIVE) if reweight else k
        acc += n_c * sanitize_mass(ratio)
        cum.append(acc)
    return cum


def sample_row(tree, pool, h, m, rng):
    """Port of TwoPassCore::sample_row: resample m negatives from the
    composed CDF, or fall back to m per-row tree descents when the pool
    mass degenerates. Returns (draws, fell_back)."""
    run_class = pool[0]
    cum = row_cdf(tree, pool, h)
    mass = positive_pool_mass(cum[-1]) if cum else None
    if mass is None:
        scratch = tree.begin_example(h)
        return [tree.draw(h, scratch, rng) for _ in range(m)], True
    out = []
    for _ in range(m):
        u = rng.random() * mass
        j = min(sum(1 for c in cum if c <= u), len(cum) - 1)
        j = step_down_to_positive(cum, j)
        w = cum[0] if j == 0 else cum[j] - cum[j - 1]
        out.append((run_class[j], w / mass))
    return out, False


def make_case(seed, n, d, rows, alpha=100.0):
    rng = random.Random(seed)
    emb = np.random.default_rng(seed).normal(0, 0.5, (n, d)).astype(np.float32)
    tree = Tree(QuadraticMap(d, alpha), n, 4)
    tree.reset(emb)
    hs = np.random.default_rng(seed + 999).normal(0, 1, (rows, d)).astype(np.float32)
    return rng, tree, emb, hs


def check_pool_sizing():
    assert pool_size(48, 100, 4.0) == math.ceil(4800 / 4.0)
    assert pool_size(2, 100, 8.0) == 100  # clamped up to m
    assert pool_size(48, 100, 0.5) == 4800  # alpha < 1 still capped at B*m
    assert pool_size(1, 8, 4.0) == 8
    assert pool_size(4, 0, 4.0) == 1  # degenerate floor
    assert pool_size(48, 100, 1.0) == 4800  # never above B*m
    print("  pool sizing P = ceil(B*m/alpha) in [m, B*m]: OK")


def check_composed_q_exact(trials=10):
    for case in range(trials):
        rng, tree, emb, hs = make_case(100 + case, n=60, d=3, rows=10)
        m = 16
        p = pool_size(len(hs), m, 4.0)
        pool = build_pool(tree, hs, p, rng)
        for h in hs:
            draws, fell_back = sample_row(tree, pool, h, m, rng)
            if fell_back:
                continue
            cum = row_cdf(tree, pool, h)
            total = cum[-1]
            # q over the pool support is a probability distribution
            qs = [(cum[0] if j == 0 else cum[j] - cum[j - 1]) / total for j in range(len(cum))]
            assert abs(sum(qs) - 1.0) < 1e-9
            for cls, q in draws:
                j = pool[0].index(cls)
                assert q == qs[j], (case, cls, q, qs[j])
                assert q > 0.0 and math.isfinite(q)
    print("  composed q == n_c*K/qbar / S, sums to 1 over pool support: OK")


def check_chi_square_conditional():
    rng, tree, emb, hs = make_case(7, n=50, d=3, rows=8)
    p = pool_size(len(hs), 32, 2.0)
    pool = build_pool(tree, hs, p, rng)
    h = hs[0]
    cum = row_cdf(tree, pool, h)
    total = cum[-1]
    probs = [(cum[0] if j == 0 else cum[j] - cum[j - 1]) / total for j in range(len(cum))]
    counts = [0] * len(pool[0])
    draws = 60_000
    for _ in range(draws // 50):
        out, fell_back = sample_row(tree, pool, h, 50, rng)
        assert not fell_back
        for cls, _ in out:
            counts[pool[0].index(cls)] += 1
    stat = sum(
        (counts[j] - probs[j] * draws) ** 2 / (probs[j] * draws)
        for j in range(len(probs))
        if probs[j] * draws >= 1.0
    )
    dof = sum(1 for pj in probs if pj * draws >= 1.0) - 1
    bound = dof + 6 * math.sqrt(2 * dof)
    assert stat < bound, (stat, dof, bound)
    print(f"  chi-square GOF on the composed conditional (chi2 {stat:.1f}, dof {dof}): OK")


def tv(a, b):
    return 0.5 * sum(abs(x - y) for x, y in zip(a, b))


def check_sir_marginal():
    # shared query: the exact per-row target is one closed-form vector.
    # Fresh pool per step; the SIR-reweighted marginal must approach it,
    # and must beat the un-reweighted control (kernel-squared flaw).
    n, d, rows, m = 40, 3, 16, 32
    rng, tree, emb, _ = make_case(31, n=n, d=d, rows=rows)
    h = np.random.default_rng(32).normal(0, 1, d).astype(np.float32)
    hs = np.tile(h, (rows, 1))
    expected = exact_dist(tree.map, h, emb)
    ksq = [w * w for w in (tree.map.kernel(h, e) for e in emb)]
    ksq = [x / sum(ksq) for x in ksq]

    def run(reweight):
        counts, total = [0] * n, 0
        for _ in range(60):
            pool = build_pool(tree, hs, pool_size(rows, m, 2.0), rng)
            for hr in hs:
                cum = row_cdf(tree, pool, hr, reweight=reweight)
                mass = positive_pool_mass(cum[-1])
                assert mass is not None
                for _ in range(m):
                    u = rng.random() * mass
                    j = min(sum(1 for c in cum if c <= u), len(cum) - 1)
                    j = step_down_to_positive(cum, j)
                    counts[pool[0][j]] += 1
                    total += 1
        return [c / total for c in counts]

    emp_sir = run(True)
    emp_raw = run(False)
    tv_sir = tv(emp_sir, expected)
    tv_raw = tv(emp_raw, expected)
    tv_raw_vs_ksq = tv(emp_raw, ksq)
    assert tv_sir < 0.05, tv_sir
    # the control lands on the kernel-SQUARED distribution, not the target
    assert tv_raw > 2 * tv_sir, (tv_raw, tv_sir)
    assert tv_raw_vs_ksq < tv_raw, (tv_raw_vs_ksq, tv_raw)
    print(
        f"  SIR marginal -> kernel dist (TV {tv_sir:.3f}); un-reweighted control "
        f"-> kernel^2 (TV {tv_raw:.3f} vs target, {tv_raw_vs_ksq:.3f} vs K^2): OK"
    )


def check_partition_estimator():
    # eq. (2) proxy: E[exp(o_c)/q_c] over draws ~ q estimates the softmax
    # partition restricted support -> generous bands; parity with the
    # per-row tree descent
    n, d, rows, m = 40, 3, 24, 32
    rng, tree, emb, _ = make_case(57, n=n, d=d, rows=rows)
    h = np.random.default_rng(58).normal(0, 1, d).astype(np.float32)
    hs = np.tile(h, (rows, 1))
    logits = [float(np.dot(h.astype(np.float64), e.astype(np.float64))) for e in emb]
    truth = sum(math.exp(o) for o in logits)

    est_two, n_two = 0.0, 0
    for _ in range(50):
        pool = build_pool(tree, hs, pool_size(rows, m, 2.0), rng)
        for hr in hs:
            for cls, q in sample_row(tree, pool, hr, m, rng)[0]:
                est_two += math.exp(logits[cls]) / q
                n_two += 1
    est_tree, n_tree = 0.0, 0
    scratch = tree.begin_example(h)
    for _ in range(50 * rows * m):
        cls, q = tree.draw(h, scratch, rng)
        est_tree += math.exp(logits[cls]) / q
        n_tree += 1
    rel_two = abs(est_two / n_two - truth) / truth
    rel_tree = abs(est_tree / n_tree - truth) / truth
    assert rel_tree < 0.10, rel_tree
    assert rel_two < 0.12, rel_two
    print(
        f"  partition estimator bias: tree {rel_tree:.3f}, two-pass {rel_two:.3f} "
        f"(truth {truth:.1f}): OK"
    )


def check_degenerate_fallback():
    n, d, rows, m = 24, 3, 6, 8
    rng = random.Random(83)
    tree = Tree(ZeroMap(d), n, 4)
    hs = np.random.default_rng(84).normal(0, 1, (rows, d)).astype(np.float32)
    pool = build_pool(tree, hs, pool_size(rows, m, 4.0), rng)
    fallbacks = 0
    for h in hs:
        draws, fell_back = sample_row(tree, pool, h, m, rng)
        assert fell_back
        fallbacks += 1
        assert len(draws) == m
        for cls, q in draws:
            assert 0 <= cls < n
            assert q > 0.0 and math.isfinite(q), q
    assert fallbacks == rows
    # the guard itself
    assert positive_pool_mass(0.0) is None
    assert positive_pool_mass(-1.0) is None
    assert positive_pool_mass(float("inf")) is None
    assert positive_pool_mass(float("nan")) is None
    assert positive_pool_mass(2.5) == 2.5
    print("  degenerate pool -> counted per-row fallback, q > 0 always: OK")


if __name__ == "__main__":
    print("two-pass sampling port checks:")
    check_pool_sizing()
    check_composed_q_exact()
    check_chi_square_conditional()
    check_sir_marginal()
    check_partition_estimator()
    check_degenerate_fallback()
    print("all two-pass port checks passed")
