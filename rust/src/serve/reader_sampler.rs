//! Snapshot-backed training sampler: the [`Sampler`] face of the serve
//! layer's publish points.
//!
//! [`SnapshotSampler`] owns **no tree**. It holds one [`SnapshotReader`]
//! per shard over the same [`SnapshotStore`]s the online serving workers
//! read, and draws every negative from the *pinned* generation set. Tree
//! maintenance happens exactly once, in the [`TreePublisher`]s behind the
//! owning [`crate::serve::ShardSet`] — the trainer routes each step's
//! Fig. 1(b) rows through `update_and_publish_rows` and this adapter picks
//! the new generation up at its next [`Sampler::refresh_snapshots`]. One
//! tree, one update sweep, one publish point, shared by training and
//! serving.
//!
//! # Determinism contract
//!
//! The pinned generation changes **only** in [`Sampler::refresh_snapshots`]
//! — never inside a draw. The training pipeline calls refresh at a fixed
//! point of its stage schedule (immediately before a step's draws, on the
//! thread running them, FIFO-ordered after the publishes that must be
//! visible), so the generation a step samples from is a pure function of
//! the schedule: at pipeline depth 1 it is the generation the previous
//! step published (exactly the live tree of the pre-refactor private
//! sampler), at depth 2 it is one generation older (the documented
//! staleness). Draw streams are bit-identical to the samplers this adapter
//! replaces:
//!
//! * one shard — delegates to the snapshot tree's own
//!   [`KernelTreeSampler`] batch engine (same arena walk, same RNG
//!   consumption as the legacy `"quadratic"` / `"rff"` samplers);
//! * several shards — the router fan-out of
//!   [`crate::serve::ShardedKernelSampler`], reusing the same
//!   [`draw_from_shards`] body the serve workers run.

use crate::sampler::kernel::midx::{MidxCore, MidxObs};
use crate::sampler::kernel::tree::{sanitize_mass, TreeView};
use crate::sampler::kernel::two_pass::{TwoPassCore, TwoPassObs};
use crate::sampler::kernel::FeatureMap;
use crate::sampler::{row_rng, BatchSampleInput, Needs, Sample, SampleInput, Sampler};
use crate::serve::shard::{draw_from_shards, scratch_for, shard_of_class, ShardScratch};
use crate::serve::snapshot::{SnapshotReader, SnapshotStore, TreeSnapshot};
use crate::util::rng::Rng;
use crate::util::threadpool::{par_chunks_mut, Pool};
use anyhow::Result;
use std::sync::{Arc, Mutex, PoisonError};

/// The pinned state: per-shard readers plus the `Arc`'d snapshots they
/// currently pin. Guarded by one mutex that is locked only at refresh and
/// at the start of a batch (to clone the pinned `Arc`s out) — never held
/// across draws.
struct Pinned<M: FeatureMap> {
    readers: Vec<SnapshotReader<TreeSnapshot<M>>>,
    snaps: Vec<Arc<TreeSnapshot<M>>>,
}

/// Read-only [`Sampler`] over published kernel-tree snapshot generations
/// (see the module docs for the determinism contract).
pub struct SnapshotSampler<M: FeatureMap + Clone> {
    offsets: Vec<u32>,
    n: usize,
    d: usize,
    /// Registry name this adapter stands in for (`"quadratic"`,
    /// `"rff-sharded"`, ...): configs and logs keep reading the same names.
    name: String,
    pinned: Mutex<Pinned<M>>,
    /// Router scratch freelist (multi-shard draws only) — the same pooling
    /// discipline as [`crate::serve::ShardedKernelSampler`].
    scratch_pool: Pool<ShardScratch>,
    /// Batch-shared two-pass engine (single-shard only): when set, draws
    /// route through [`TwoPassCore`] over the pinned generation's tree
    /// view instead of per-row descents. See
    /// `crate::sampler::kernel::two_pass` for the composed-q contract.
    two_pass: Option<TwoPassCore>,
    /// Inverted multi-index engine (single-shard only): when set, draws
    /// route through [`MidxCore`], which rebuilds its k-means index
    /// behind each published generation (warm-restarted — the
    /// re-assignment sweep lives behind the publisher, like compaction).
    /// See `crate::sampler::kernel::midx` for the composed-q contract.
    midx: Option<MidxCore>,
}

impl<M: FeatureMap + Clone> SnapshotSampler<M> {
    /// Subscribe to the given per-shard publish points. `offsets` bracket
    /// every shard (`offsets.len() == stores.len() + 1`); `name` is the
    /// sampler-registry name this adapter reports.
    pub fn new(
        stores: Vec<Arc<SnapshotStore<TreeSnapshot<M>>>>,
        offsets: Vec<u32>,
        name: String,
    ) -> SnapshotSampler<M> {
        assert_eq!(offsets.len(), stores.len() + 1, "offsets must bracket every shard");
        let readers: Vec<SnapshotReader<TreeSnapshot<M>>> =
            stores.iter().map(|s| SnapshotReader::new(s.clone())).collect();
        let snaps: Vec<Arc<TreeSnapshot<M>>> =
            readers.iter().map(|r| r.pinned().clone()).collect();
        let n = *offsets.last().expect("offsets non-empty") as usize;
        let d = snaps[0].tree.embed_dim();
        SnapshotSampler {
            offsets,
            n,
            d,
            name,
            pinned: Mutex::new(Pinned { readers, snaps }),
            scratch_pool: Pool::new(),
            two_pass: None,
            midx: None,
        }
    }

    /// Switch this adapter into batch-shared two-pass mode (pool divisor
    /// `pool_factor` = the α of P = ⌈B·m/α⌉) and report the matching
    /// `*-2pass` registry name. Single-shard publish points only: the pool
    /// descent needs one tree over the full class range (the router merge
    /// would break the composed-q algebra).
    pub fn with_two_pass(mut self, pool_factor: f64) -> SnapshotSampler<M> {
        assert_eq!(
            self.offsets.len(),
            2,
            "two-pass mode needs a single-shard publish point (got {} shards)",
            self.offsets.len() - 1
        );
        if !self.name.ends_with("-2pass") {
            self.name = format!("{}-2pass", self.name);
        }
        self.two_pass = Some(TwoPassCore::new(pool_factor));
        self
    }

    /// Two-pass telemetry cells (`kss_sampler_pool_*`), when in two-pass
    /// mode.
    pub fn two_pass_obs(&self) -> Option<&TwoPassObs> {
        self.two_pass.as_ref().map(|core| core.obs())
    }

    /// Switch this adapter into inverted-multi-index mode (`clusters =
    /// None` → K = ⌈√n⌉) and report the matching `*-midx` registry name.
    /// Single-shard publish points only: the coarse CDF needs one index
    /// over the full class range. Mutually exclusive with two-pass mode.
    pub fn with_midx(mut self, clusters: Option<usize>) -> SnapshotSampler<M> {
        assert_eq!(
            self.offsets.len(),
            2,
            "midx mode needs a single-shard publish point (got {} shards)",
            self.offsets.len() - 1
        );
        assert!(self.two_pass.is_none(), "midx and two-pass modes are mutually exclusive");
        if !self.name.ends_with("-midx") {
            self.name = format!("{}-midx", self.name);
        }
        self.midx = Some(MidxCore::new(clusters));
        self
    }

    /// Midx telemetry cells (`kss_sampler_midx_*`), when in midx mode.
    pub fn midx_obs(&self) -> Option<&MidxObs> {
        self.midx.as_ref().map(|core| core.obs())
    }

    /// Generation of every pinned shard snapshot (test/debug surface).
    /// Reading generations is sound even if a draw thread panicked with
    /// the lock held, so poison is recovered rather than propagated.
    pub fn pinned_generations(&self) -> Vec<u64> {
        let guard = self.pinned.lock().unwrap_or_else(PoisonError::into_inner);
        guard.snaps.iter().map(|s| s.generation).collect()
    }

    /// Clone the pinned snapshot set out of the lock (one `Arc` clone per
    /// shard; the lock is never held while drawing). Errors instead of
    /// panicking on poison: the draw paths surface it to the caller, so a
    /// panic elsewhere cannot cascade through every sampling thread.
    fn pin(&self) -> Result<Vec<Arc<TreeSnapshot<M>>>> {
        let guard = self
            .pinned
            .lock()
            .map_err(|_| anyhow::anyhow!("snapshot sampler lock poisoned"))?;
        Ok(guard.snaps.clone())
    }
}

impl<M: FeatureMap + Clone> Sampler for SnapshotSampler<M> {
    fn name(&self) -> &str {
        &self.name
    }

    fn needs(&self) -> Needs {
        Needs { h: true, ..Needs::default() }
    }

    fn sample(&self, input: &SampleInput, m: usize, rng: &mut Rng, out: &mut Sample) -> Result<()> {
        let snaps = self.pin()?;
        if let (Some(core), Some(snap)) = (&self.two_pass, snaps.first()) {
            // B = 1 two-pass batch over the pinned generation (the
            // documented batch-coupled exception in sampler/mod.rs);
            // with_two_pass asserts a single shard, so first() is it
            return core.sample_view(snap.tree.view(), input, m, rng, out);
        }
        if let (Some(core), Some(snap)) = (&self.midx, snaps.first()) {
            // with_midx asserts a single shard, so first() is the whole
            // class range; the core caches its index per generation
            let h = input.h.ok_or_else(|| anyhow::anyhow!("midx sampler needs h"))?;
            anyhow::ensure!(h.len() == self.d, "h len {} != d {}", h.len(), self.d);
            return core.sample_view(&snap.tree.view(), snap.generation, h, m, rng, out);
        }
        if snaps.len() == 1 {
            // single tree: the snapshot's own engine (bit-identical stream
            // to the legacy private KernelTreeSampler)
            return snaps[0].tree.sample(input, m, rng, out);
        }
        let h = input.h.ok_or_else(|| anyhow::anyhow!("snapshot sampler needs h"))?;
        anyhow::ensure!(h.len() == self.d, "h len {} != d {}", h.len(), self.d);
        out.clear();
        let trees: Vec<TreeView<'_, M>> = snaps.iter().map(|s| s.tree.view()).collect();
        let mut state = self.scratch_pool.take(|| scratch_for(&trees));
        draw_from_shards(&trees, &self.offsets, h, m, &mut state, rng, out);
        self.scratch_pool.put(state);
        Ok(())
    }

    /// Batched engine over the pinned generation set — the same fan-out
    /// bodies as the samplers this adapter replaces, so the per-row
    /// [`row_rng`] streams are bit-identical for any thread count.
    fn sample_batch(
        &self,
        inputs: &BatchSampleInput,
        m: usize,
        step_seed: u64,
        out: &mut [Sample],
    ) -> Result<()> {
        let snaps = self.pin()?;
        if let (Some(core), Some(snap)) = (&self.two_pass, snaps.first()) {
            // single shard by with_two_pass's assert, see sample() above
            return core.sample_batch_view(snap.tree.view(), &self.name, inputs, m, step_seed, out);
        }
        if let (Some(core), Some(snap)) = (&self.midx, snaps.first()) {
            // single shard by with_midx's assert, see sample() above
            return core.sample_batch_view(&snap.tree.view(), snap.generation, inputs, m, step_seed, out);
        }
        if snaps.len() == 1 {
            return snaps[0].tree.sample_batch(inputs, m, step_seed, out);
        }
        anyhow::ensure!(
            out.len() == inputs.n,
            "out has {} slots, batch has {} rows",
            out.len(),
            inputs.n
        );
        inputs.validate(self.name(), self.needs())?;
        anyhow::ensure!(inputs.d == self.d, "batch h dim {} != sampler d {}", inputs.d, self.d);
        let h_all = inputs.h.ok_or_else(|| anyhow::anyhow!("snapshot sampler needs h"))?;
        let trees: Vec<TreeView<'_, M>> = snaps.iter().map(|s| s.tree.view()).collect();
        par_chunks_mut(out, inputs.threads, |base, chunk| {
            let mut state = self.scratch_pool.take(|| scratch_for(&trees));
            for (k, slot) in chunk.iter_mut().enumerate() {
                let i = base + k;
                let h = &h_all[i * self.d..(i + 1) * self.d];
                let mut rng = row_rng(step_seed, i);
                slot.clear();
                draw_from_shards(&trees, &self.offsets, h, m, &mut state, &mut rng, slot);
            }
            self.scratch_pool.put(state);
        });
        Ok(())
    }

    fn prob(&self, input: &SampleInput, class: u32) -> Option<f64> {
        let h = input.h?;
        if (class as usize) >= self.n {
            return None;
        }
        let snaps = self.pin().ok()?;
        let phi_h = snaps[0].tree.phi_query(h);
        let total: f64 = snaps.iter().map(|s| sanitize_mass(s.tree.partition(&phi_h))).sum();
        // eq. (2) q-positivity: a fully-degenerate mass (every shard
        // sanitized to zero) has no defined distribution — say so rather
        // than returning inf/NaN
        if !(total > 0.0) {
            return None;
        }
        let sid = shard_of_class(&self.offsets, class as usize);
        let local = (class - self.offsets[sid]) as usize;
        let k = snaps[sid].tree.feature_map().kernel(h, snaps[sid].tree.emb_row(local));
        Some(k / total)
    }

    /// Snapshot samplers are read-only: their tree lives in the publisher.
    /// Receiving an update here means a duplicated tree-maintenance path
    /// survived the refactor — fail loudly in debug builds.
    fn update(&mut self, _class: usize, _w_new: &[f32]) {
        debug_assert!(
            false,
            "snapshot-backed sampler is read-only; route updates through the publisher"
        );
    }

    fn update_many(&mut self, _classes: &[usize], _rows: &[f32]) {
        debug_assert!(
            false,
            "snapshot-backed sampler is read-only; route updates through the publisher"
        );
    }

    fn reset_embeddings(&mut self, _w: &[f32], _n: usize, _d: usize) {
        debug_assert!(
            false,
            "snapshot-backed sampler is read-only; seed the ShardSet with w instead"
        );
    }

    fn snapshot_backed(&self) -> bool {
        true
    }

    /// Advance every shard reader to the freshest published generation.
    /// The *only* place the pinned set changes — see the module docs.
    /// Recovers a poisoned lock: refresh rewrites the entire pinned set
    /// from the readers, so whatever partial state a panicking thread left
    /// behind is overwritten wholesale (the trait signature has no error
    /// channel, and the training driver must keep stepping).
    fn refresh_snapshots(&self) {
        let mut guard = self.pinned.lock().unwrap_or_else(PoisonError::into_inner);
        let Pinned { readers, snaps } = &mut *guard;
        for (reader, snap) in readers.iter_mut().zip(snaps.iter_mut()) {
            *snap = reader.current().clone();
        }
    }

    fn pinned_generation(&self) -> Option<u64> {
        // read-only aggregate over Arc'd snapshots — sound under poison
        let guard = self.pinned.lock().unwrap_or_else(PoisonError::into_inner);
        guard.snaps.iter().map(|s| s.generation).min()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::kernel::tree::KernelTreeSampler;
    use crate::sampler::kernel::QuadraticMap;
    use crate::serve::service::ShardSet;
    use crate::serve::shard::ShardedKernelSampler;

    fn random_emb(rng: &mut Rng, n: usize, d: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; n * d];
        rng.fill_normal(&mut v, 0.5);
        v
    }

    fn batch_draws(
        s: &dyn Sampler,
        hs: &[f32],
        n_rows: usize,
        d: usize,
        n_classes: usize,
        m: usize,
        seed: u64,
        threads: usize,
    ) -> Vec<Sample> {
        let inputs = BatchSampleInput {
            n: n_rows,
            d,
            n_classes,
            h: Some(hs),
            threads,
            ..Default::default()
        };
        let mut out: Vec<Sample> = (0..n_rows).map(|_| Sample::default()).collect();
        s.sample_batch(&inputs, m, seed, &mut out).unwrap();
        out
    }

    #[test]
    fn single_shard_streams_match_live_tree_across_updates() {
        // the bitwise contract behind depth-1 pipeline equivalence: with
        // identical update history, the snapshot adapter and the legacy
        // private tree draw identical (class, q) streams
        let (n, d, rows, m) = (48usize, 3usize, 9usize, 6usize);
        let mut rng = Rng::new(11);
        let emb = random_emb(&mut rng, n, d);
        let map = QuadraticMap::new(d, 100.0);
        let mut live = KernelTreeSampler::new(map.clone(), n, None);
        live.reset_embeddings(&emb, n, d);
        let mut set = ShardSet::new(map, n, 1, None, Some(&emb));
        let reader = SnapshotSampler::new(set.stores(), set.offsets().to_vec(), "quadratic".into());
        for step in 0..7u64 {
            let mut hs = vec![0.0f32; rows * d];
            rng.fill_normal(&mut hs, 1.0);
            reader.refresh_snapshots();
            let a = batch_draws(&live, &hs, rows, d, n, m, 0xA0 + step, 3);
            let b = batch_draws(&reader, &hs, rows, d, n, m, 0xA0 + step, 2);
            for (i, (x, y)) in a.iter().zip(&b).enumerate() {
                assert_eq!(x.classes, y.classes, "step {step} row {i}");
                assert_eq!(x.q, y.q, "step {step} row {i}");
            }
            // identical Fig. 1(b) rows through both maintenance paths
            let k = 1 + (step as usize % 4);
            let classes: Vec<usize> = (0..k).map(|j| (j * 11 + step as usize) % n).collect();
            let mut classes = classes;
            classes.sort_unstable();
            classes.dedup();
            let mut new_rows = vec![0.0f32; classes.len() * d];
            rng.fill_normal(&mut new_rows, 0.6);
            Sampler::update_many(&mut live, &classes, &new_rows);
            set.update_and_publish(&classes, &new_rows);
        }
    }

    #[test]
    fn sharded_streams_match_sharded_sampler() {
        let (n, d, shards, rows, m) = (40usize, 3usize, 4usize, 7usize, 5usize);
        let mut rng = Rng::new(21);
        let emb = random_emb(&mut rng, n, d);
        let map = QuadraticMap::new(d, 100.0);
        let mut live = ShardedKernelSampler::new(map.clone(), n, shards, None);
        live.reset_embeddings(&emb, n, d);
        let set = ShardSet::new(map, n, shards, None, Some(&emb));
        let reader =
            SnapshotSampler::new(set.stores(), set.offsets().to_vec(), "quadratic-sharded".into());
        reader.refresh_snapshots();
        let mut hs = vec![0.0f32; rows * d];
        rng.fill_normal(&mut hs, 1.0);
        for threads in [0usize, 1, 3] {
            let a = batch_draws(&live, &hs, rows, d, n, m, 0x51ED, threads);
            let b = batch_draws(&reader, &hs, rows, d, n, m, 0x51ED, threads);
            for (i, (x, y)) in a.iter().zip(&b).enumerate() {
                assert_eq!(x.classes, y.classes, "threads {threads} row {i}");
                assert_eq!(x.q, y.q, "threads {threads} row {i}");
            }
        }
        // prob() closed form agrees with the live sampler everywhere
        let input = SampleInput { h: Some(&hs[..d]), ..Default::default() };
        for c in 0..n as u32 {
            let a = live.prob(&input, c).unwrap();
            let b = reader.prob(&input, c).unwrap();
            assert!((a - b).abs() < 1e-12, "class {c}: {a} vs {b}");
        }
    }

    #[test]
    fn two_pass_streams_match_owning_two_pass_sampler() {
        // the snapshot adapter in two-pass mode and the owning
        // TwoPassKernelSampler run the same TwoPassCore over equal tree
        // arenas — (class, q) streams must be bit-identical, across
        // publishes
        use crate::sampler::kernel::two_pass::TwoPassKernelSampler;
        let (n, d, rows, m) = (48usize, 3usize, 9usize, 12usize);
        let mut rng = Rng::new(71);
        let emb = random_emb(&mut rng, n, d);
        let map = QuadraticMap::new(d, 100.0);
        let mut live = TwoPassKernelSampler::new(map.clone(), n, None, 3.0);
        Sampler::reset_embeddings(&mut live, &emb, n, d);
        let mut set = ShardSet::new(map, n, 1, None, Some(&emb));
        let reader =
            SnapshotSampler::new(set.stores(), set.offsets().to_vec(), "quadratic".into())
                .with_two_pass(3.0);
        assert_eq!(reader.name(), "quadratic-2pass");
        for step in 0..5u64 {
            let mut hs = vec![0.0f32; rows * d];
            rng.fill_normal(&mut hs, 1.0);
            reader.refresh_snapshots();
            let a = batch_draws(&live, &hs, rows, d, n, m, 0xB0 + step, 3);
            let b = batch_draws(&reader, &hs, rows, d, n, m, 0xB0 + step, 2);
            for (i, (x, y)) in a.iter().zip(&b).enumerate() {
                assert_eq!(x.classes, y.classes, "step {step} row {i}");
                assert_eq!(x.q, y.q, "step {step} row {i}");
            }
            let classes = vec![(step as usize * 7) % n, (step as usize * 13 + 1) % n];
            let mut classes = classes;
            classes.sort_unstable();
            classes.dedup();
            let mut new_rows = vec![0.0f32; classes.len() * d];
            rng.fill_normal(&mut new_rows, 0.6);
            Sampler::update_many(&mut live, &classes, &new_rows);
            set.update_and_publish(&classes, &new_rows);
        }
        // telemetry flows through the adapter's engine
        let obs = reader.two_pass_obs().expect("two-pass mode has obs");
        assert!(obs.hit_total() + obs.miss_total() > 0);
    }

    #[test]
    fn midx_streams_match_owning_midx_sampler_at_first_generation() {
        // cold-built from the same embedding panel with the pinned build
        // seed, the adapter's MidxCore and the owning MidxKernelSampler
        // hold identical indices — (class, q) streams are bit-identical.
        // After a publish the adapter warm-restarts its k-means (which may
        // re-assign members the owning sampler only sweeps periodically),
        // so later generations are held to the eq. (2) contract instead:
        // every drawn q must agree with the flat closed form.
        use crate::sampler::kernel::midx::MidxKernelSampler;
        let (n, d, rows, m) = (48usize, 3usize, 9usize, 12usize);
        let mut rng = Rng::new(81);
        let emb = random_emb(&mut rng, n, d);
        let map = QuadraticMap::new(d, 100.0);
        let mut live = MidxKernelSampler::new(map.clone(), n, None);
        Sampler::reset_embeddings(&mut live, &emb, n, d);
        let mut set = ShardSet::new(map, n, 1, None, Some(&emb));
        let reader =
            SnapshotSampler::new(set.stores(), set.offsets().to_vec(), "quadratic".into())
                .with_midx(None);
        assert_eq!(reader.name(), "quadratic-midx");
        reader.refresh_snapshots();
        let mut hs = vec![0.0f32; rows * d];
        rng.fill_normal(&mut hs, 1.0);
        for threads in [0usize, 1, 3] {
            let a = batch_draws(&live, &hs, rows, d, n, m, 0xC0, threads);
            let b = batch_draws(&reader, &hs, rows, d, n, m, 0xC0, threads);
            for (i, (x, y)) in a.iter().zip(&b).enumerate() {
                assert_eq!(x.classes, y.classes, "threads {threads} row {i}");
                assert_eq!(x.q, y.q, "threads {threads} row {i}");
            }
        }
        // publish a couple of generations; the adapter must keep serving
        // composed q that matches the flat eq. (8) distribution
        for step in 0..3u64 {
            let classes = {
                let mut c = vec![(step as usize * 7) % n, (step as usize * 13 + 1) % n];
                c.sort_unstable();
                c.dedup();
                c
            };
            let mut new_rows = vec![0.0f32; classes.len() * d];
            rng.fill_normal(&mut new_rows, 0.6);
            set.update_and_publish(&classes, &new_rows);
            reader.refresh_snapshots();
            let input = SampleInput { h: Some(&hs[..d]), ..Default::default() };
            let mut out = Sample::default();
            let mut draw_rng = Rng::new(0xD0 + step);
            reader.sample(&input, m, &mut draw_rng, &mut out).unwrap();
            for (&c, &q) in out.classes.iter().zip(&out.q) {
                assert!(q > 0.0, "step {step}: q must be positive");
                let flat = reader.prob(&input, c).expect("in-range class");
                let rel = (q - flat).abs() / flat.max(1e-300);
                assert!(rel <= 1e-9, "step {step} class {c}: composed q {q} vs flat {flat}");
            }
        }
        // telemetry flows through the adapter's engine: coarse draws
        // happened, and each post-publish rebuild warm-restarted
        let obs = reader.midx_obs().expect("midx mode has obs");
        assert!(obs.coarse_draw_total() > 0);
        assert!(obs.refine_total() > 0);
        assert_eq!(obs.reassign_total(), 3, "one warm rebuild per consumed publish");
        assert!(obs.clusters() >= 1.0);
    }

    #[test]
    fn generation_is_pinned_until_refresh() {
        let (n, d) = (24usize, 2usize);
        let mut rng = Rng::new(31);
        let emb = random_emb(&mut rng, n, d);
        let mut set = ShardSet::new(QuadraticMap::new(d, 100.0), n, 1, None, Some(&emb));
        let reader = SnapshotSampler::new(set.stores(), set.offsets().to_vec(), "quadratic".into());
        assert_eq!(reader.pinned_generation(), Some(0));
        let h = vec![0.7f32, -0.4];
        let draw = |r: &SnapshotSampler<QuadraticMap>| {
            let input = SampleInput { h: Some(&h), ..Default::default() };
            let mut out = Sample::default();
            let mut rng = Rng::new(99);
            r.sample(&input, 32, &mut rng, &mut out).unwrap();
            (out.classes, out.q)
        };
        let before = draw(&reader);
        // publishes land; the pinned set must not move until refresh
        let mut new_rows = vec![0.0f32; d];
        for _ in 0..3 {
            rng.fill_normal(&mut new_rows, 0.8);
            set.update_and_publish(&[5], &new_rows);
        }
        assert_eq!(reader.pinned_generation(), Some(0), "pinned set moved without refresh");
        assert_eq!(draw(&reader), before, "draw stream changed under a pinned generation");
        reader.refresh_snapshots();
        assert_eq!(reader.pinned_generation(), Some(3));
        assert_eq!(reader.pinned_generations(), vec![3]);
        assert_ne!(draw(&reader).1, before.1, "fresh generation should differ");
    }

    #[test]
    fn poisoned_lock_surfaces_errors_not_panics() {
        let (n, d) = (16usize, 2usize);
        let emb = vec![0.2f32; n * d];
        let set = ShardSet::new(QuadraticMap::new(d, 100.0), n, 2, None, Some(&emb));
        let reader = SnapshotSampler::new(
            set.stores(),
            set.offsets().to_vec(),
            "quadratic-sharded".into(),
        );
        // poison the pinned-set mutex: a scoped thread panics holding it
        // (join consumes the Err so the scope exits cleanly)
        std::thread::scope(|s| {
            let r = &reader;
            let _ = s
                .spawn(move || {
                    let _g = r.pinned.lock().unwrap();
                    panic!("poisoning the pinned-set mutex");
                })
                .join();
        });
        assert!(reader.pinned.is_poisoned(), "setup failed: lock not poisoned");
        let h = vec![0.3f32, -0.1];
        let input = SampleInput { h: Some(&h), ..Default::default() };
        let mut out = Sample::default();
        let mut rng = Rng::new(7);
        assert!(reader.sample(&input, 4, &mut rng, &mut out).is_err(), "sample must error");
        let inputs =
            BatchSampleInput { n: 1, d, n_classes: n, h: Some(&h), ..Default::default() };
        let mut slots = vec![Sample::default()];
        assert!(reader.sample_batch(&inputs, 4, 1, &mut slots).is_err(), "batch must error");
        assert_eq!(reader.prob(&input, 3), None, "prob must decline, not panic");
        // observability + refresh recover the lock rather than panicking
        reader.refresh_snapshots();
        assert_eq!(reader.pinned_generation(), Some(0));
        assert_eq!(reader.pinned_generations(), vec![0, 0]);
    }

    #[test]
    fn prob_out_of_range_class_is_none() {
        let (n, d) = (12usize, 2usize);
        let emb = vec![0.4f32; n * d];
        let set = ShardSet::new(QuadraticMap::new(d, 100.0), n, 2, None, Some(&emb));
        let reader = SnapshotSampler::new(
            set.stores(),
            set.offsets().to_vec(),
            "quadratic-sharded".into(),
        );
        let h = vec![0.5f32, 0.5];
        let input = SampleInput { h: Some(&h), ..Default::default() };
        assert!(reader.prob(&input, (n - 1) as u32).is_some());
        assert_eq!(reader.prob(&input, n as u32), None, "class past n must be None");
        assert_eq!(reader.prob(&input, u32::MAX), None);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "read-only")]
    fn updates_through_the_adapter_are_rejected() {
        let (n, d) = (8usize, 2usize);
        let emb = vec![0.1f32; n * d];
        let set = ShardSet::new(QuadraticMap::new(d, 100.0), n, 1, None, Some(&emb));
        let mut reader =
            SnapshotSampler::new(set.stores(), set.offsets().to_vec(), "quadratic".into());
        Sampler::update_many(&mut reader, &[1], &[0.5, 0.5]);
    }
}
