//! The coordinator: everything between the datasets and the PJRT runtime.
//!
//! * [`config`] — experiment configuration (model, sampler, m, schedule) and
//!   dataset construction.
//! * [`trainer`] — the training loop implementing the paper's procedure:
//!   encode → per-example negative sampling (threadpool) → sampled-softmax
//!   step → host-mirror/kernel-tree update; plus the full-softmax baseline
//!   and the full-softmax evaluation the figures report.
//! * [`metrics`] — JSONL metric sink + in-memory loss curves.
//! * [`experiment`] — the (sampler × m) grid runner behind every figure.

pub mod config;
pub mod experiment;
pub mod metrics;
pub mod trainer;

pub use config::TrainConfig;
pub use experiment::{run_grid, GridSpec, RunSummary};
pub use metrics::MetricsSink;
pub use trainer::{TrainResult, Trainer};
