"""CLI: `python3 -m pallas_lint [--root R] [--report P] [--baseline P]`.

Exit 0 when every finding is waived by the baseline, 1 when new
findings exist, 2 on usage errors. `--write-baseline` accepts the
current findings as the new baseline (reasons start as TODO and are
filled in by hand — a waiver without a reason should not survive
review).
"""

from __future__ import annotations

import argparse
import json
import os
import sys

if __package__ in (None, ""):
    # invoked as `python3 python/tools/pallas_lint` — put the parent dir
    # on sys.path so the package imports resolve
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from pallas_lint import __version__
from pallas_lint.engine import run, write_baseline


def _default_root() -> str:
    """Nearest ancestor of this file containing Cargo.toml (the repo
    root), falling back to the current directory."""
    d = os.path.dirname(os.path.abspath(__file__))
    while True:
        if os.path.exists(os.path.join(d, "Cargo.toml")):
            return d
        parent = os.path.dirname(d)
        if parent == d:
            return os.getcwd()
        d = parent


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="pallas-lint",
        description="static invariant analyzer for the kss repo",
    )
    ap.add_argument("--root", default=None, help="repo root (default: auto)")
    ap.add_argument(
        "--report",
        default="ANALYSIS.json",
        help="machine-readable report path, relative to root ('-' to skip)",
    )
    ap.add_argument(
        "--baseline",
        default="python/tools/pallas_lint/baseline.json",
        help="waiver file, relative to root",
    )
    ap.add_argument(
        "--no-baseline",
        action="store_true",
        help="ignore the baseline: report every finding as new",
    )
    ap.add_argument(
        "--write-baseline",
        action="store_true",
        help="accept current findings as the baseline and exit 0",
    )
    ap.add_argument(
        "--rule",
        action="append",
        default=None,
        metavar="ID",
        help="run only this rule id (repeatable; LEX ACC QPOS PANIC LOCK OBS UNSAFE REG)",
    )
    ap.add_argument("--version", action="version", version=f"pallas-lint {__version__}")
    args = ap.parse_args(argv)

    root = args.root or _default_root()
    if not os.path.isdir(root):
        print(f"pallas-lint: no such root: {root}", file=sys.stderr)
        return 2
    baseline_path = None if args.no_baseline else os.path.join(root, args.baseline)
    rule_filter = set(args.rule) if args.rule else None

    report = run(root, baseline_path=baseline_path, rule_filter=rule_filter)
    fingerprinted = report.pop("_fingerprinted")

    if args.write_baseline:
        out = os.path.join(root, args.baseline)
        write_baseline(out, fingerprinted)
        print(
            f"pallas-lint: wrote {len(fingerprinted)} waiver(s) to {args.baseline} "
            "(fill in the reasons)"
        )
        return 0

    for it in report["findings"]:
        tag = "waived" if it["waived"] else "NEW"
        print(f"{it['file']}:{it['line']}: [{it['rule']}/{tag}] {it['message']}")
        if it["snippet"]:
            print(f"    {it['snippet']}")
    for w in report["stale_waivers"]:
        print(
            f"stale waiver: {w['fingerprint']} ({w['rule']} {w['file']}) — "
            "finding no longer present; prune it from the baseline"
        )

    if args.report != "-":
        report_path = os.path.join(root, args.report)
        with open(report_path, "w", encoding="utf-8") as f:
            json.dump(report, f, indent=2)
            f.write("\n")

    print(
        f"pallas-lint: {report['files_scanned']} files, "
        f"{report['new_count']} new finding(s), "
        f"{report['waived_count']} waived, "
        f"{len(report['stale_waivers'])} stale waiver(s)"
    )
    return 1 if report["new_count"] else 0


if __name__ == "__main__":
    sys.exit(main())
