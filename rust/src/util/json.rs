//! Minimal JSON parser and serializer.
//!
//! Used for the artifact manifest written by `python/compile/aot.py`, the
//! experiment config files, and the JSONL metric sinks. Implements the full
//! JSON grammar (RFC 8259): nested containers, string escapes including
//! `\uXXXX` (with surrogate pairs), and the usual number forms. Object key
//! order is preserved (insertion order), which keeps written configs diffable.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Array(Vec<Value>),
    /// Insertion-ordered key/value pairs.
    Object(Vec<(String, Value)>),
}

/// Parse error with byte offset context.
#[derive(Debug)]
pub struct ParseError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Value {
    // ---- accessors -------------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Value::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Num(x) if x.fract() == 0.0 => Some(*x as i64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&[(String, Value)]> {
        match self {
            Value::Object(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Object(o) => o.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// `get` that errors with the key name — convenient for manifest loading.
    pub fn req(&self, key: &str) -> anyhow::Result<&Value> {
        self.get(key).ok_or_else(|| anyhow::anyhow!("missing json key '{key}'"))
    }

    /// Convert an object into a map (for membership-style queries).
    pub fn to_map(&self) -> Option<BTreeMap<&str, &Value>> {
        match self {
            Value::Object(o) => Some(o.iter().map(|(k, v)| (k.as_str(), v)).collect()),
            _ => None,
        }
    }

    // ---- construction helpers -------------------------------------------

    pub fn object(pairs: Vec<(&str, Value)>) -> Value {
        Value::Object(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(x: f64) -> Value {
        Value::Num(x)
    }

    pub fn str(s: &str) -> Value {
        Value::Str(s.to_string())
    }

    pub fn array_f64(xs: &[f64]) -> Value {
        Value::Array(xs.iter().map(|&x| Value::Num(x)).collect())
    }

    pub fn array_usize(xs: &[usize]) -> Value {
        Value::Array(xs.iter().map(|&x| Value::Num(x as f64)).collect())
    }

    // ---- serialization ----------------------------------------------------

    /// Compact single-line serialization (JSONL metric records).
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 2-space indent (configs, manifests).
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9.0e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Array(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Value::Object(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document. Trailing whitespace is allowed; trailing garbage
/// is an error.
pub fn parse(text: &str) -> Result<Value, ParseError> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

/// Parse a file of newline-delimited JSON records.
pub fn parse_jsonl(text: &str) -> Result<Vec<Value>, ParseError> {
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .map(parse)
        .collect()
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { offset: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a json value")),
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Object(pairs));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Array(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let hi = self.hex4()?;
                            let c = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair
                                if self.bytes[self.pos..].starts_with(b"\\u") {
                                    self.pos += 2;
                                    let lo = self.hex4()?;
                                    let cp = 0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(cp).ok_or_else(|| self.err("bad surrogate"))?
                                } else {
                                    return Err(self.err("lone high surrogate"));
                                }
                            } else {
                                char::from_u32(hi).ok_or_else(|| self.err("bad codepoint"))?
                            };
                            s.push(c);
                            continue; // hex4 already advanced
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let rest = &self.bytes[self.pos..];
                    let ch_len = utf8_len(rest[0]);
                    let chunk = rest
                        .get(..ch_len)
                        .and_then(|c| std::str::from_utf8(c).ok())
                        .ok_or_else(|| self.err("bad utf-8"))?;
                    s.push_str(chunk);
                    self.pos += ch_len;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let chunk = self
            .bytes
            .get(self.pos..self.pos + 4)
            .and_then(|c| std::str::from_utf8(c).ok())
            .ok_or_else(|| self.err("expected 4 hex digits"))?;
        let v = u32::from_str_radix(chunk, 16).map_err(|_| self.err("bad hex"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Value::Num).map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let a = v.get("a").unwrap().as_array().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b").unwrap(), &Value::Null);
    }

    #[test]
    fn parses_escapes() {
        let v = parse(r#""a\n\t\"\\ A 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ A 😀");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("'single'").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"name":"m","dims":[2,3,4],"lr":0.125,"flags":{"abs":true,"x":null}}"#;
        let v = parse(src).unwrap();
        let compact = v.to_string_compact();
        assert_eq!(parse(&compact).unwrap(), v);
        let pretty = v.to_string_pretty();
        assert_eq!(parse(&pretty).unwrap(), v);
        assert!(pretty.contains('\n'));
    }

    #[test]
    fn roundtrip_unicode_string() {
        let v = Value::Str("日本 \"q\" \\ \n".into());
        assert_eq!(parse(&v.to_string_compact()).unwrap(), v);
    }

    #[test]
    fn object_key_order_preserved() {
        let v = parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<_> = v.as_object().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, ["z", "a", "m"]);
    }

    #[test]
    fn jsonl_parsing() {
        let recs = parse_jsonl("{\"a\":1}\n\n{\"a\":2}\n").unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[1].get("a").unwrap().as_usize().unwrap(), 2);
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Value::Num(3.0).to_string_compact(), "3");
        assert_eq!(Value::Num(3.25).to_string_compact(), "3.25");
    }

    #[test]
    fn as_usize_guards() {
        assert_eq!(Value::Num(-1.0).as_usize(), None);
        assert_eq!(Value::Num(1.5).as_usize(), None);
        assert_eq!(Value::Num(7.0).as_usize(), Some(7));
    }
}
