//! Typed command-line flag parser for the launcher (no `clap` offline).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, repeated flags,
//! positional arguments, and generates a usage string from the declared
//! options. Unknown flags are an error (catches typos in experiment sweeps).

use std::collections::BTreeMap;

/// Declared option for usage output.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<String>,
}

/// Parsed arguments plus the declaration table.
pub struct Args {
    /// flag name -> values in order of appearance
    flags: BTreeMap<String, Vec<String>>,
    positional: Vec<String>,
    specs: Vec<OptSpec>,
    prog: String,
}

#[derive(Debug)]
pub enum CliError {
    Unknown(String),
    MissingValue(String),
    BadValue(String, String, &'static str),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::Unknown(flag) => write!(f, "unknown flag --{flag} (see --help)"),
            CliError::MissingValue(flag) => write!(f, "flag --{flag}: expected a value"),
            CliError::BadValue(flag, value, ty) => {
                write!(f, "flag --{flag}: cannot parse '{value}' as {ty}")
            }
        }
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse a raw argv (without the program name) against declared specs.
    /// `bool_flags` lists flags that take no value.
    pub fn parse(
        prog: &str,
        argv: &[String],
        specs: &[OptSpec],
        bool_flags: &[&str],
    ) -> Result<Args, CliError> {
        let known: Vec<&str> = specs.iter().map(|s| s.name).collect();
        let mut flags: BTreeMap<String, Vec<String>> = BTreeMap::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(stripped) = a.strip_prefix("--") {
                let (name, inline_val) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                if name != "help" && !known.contains(&name.as_str()) {
                    return Err(CliError::Unknown(name));
                }
                let val = if let Some(v) = inline_val {
                    v
                } else if bool_flags.contains(&name.as_str()) || name == "help" {
                    "true".to_string()
                } else {
                    i += 1;
                    argv.get(i).cloned().ok_or_else(|| CliError::MissingValue(name.clone()))?
                };
                flags.entry(name).or_default().push(val);
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok(Args { flags, positional, specs: specs.to_vec(), prog: prog.to_string() })
    }

    /// True when `--help` was passed.
    pub fn wants_help(&self) -> bool {
        self.flags.contains_key("help")
    }

    /// Usage text generated from the specs.
    pub fn usage(&self) -> String {
        let mut s = format!("usage: {} [options]\n\noptions:\n", self.prog);
        for spec in &self.specs {
            let def = spec
                .default
                .as_ref()
                .map(|d| format!(" (default: {d})"))
                .unwrap_or_default();
            s.push_str(&format!("  --{:<22} {}{}\n", spec.name, spec.help, def));
        }
        s
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Last occurrence of a string flag.
    pub fn get_str(&self, name: &str) -> Option<&str> {
        self.flags.get(name).and_then(|v| v.last()).map(|s| s.as_str())
    }

    /// All occurrences of a flag (repeated flags = sweeps).
    pub fn get_all(&self, name: &str) -> Vec<&str> {
        self.flags.get(name).map(|v| v.iter().map(|s| s.as_str()).collect()).unwrap_or_default()
    }

    pub fn get_string_or(&self, name: &str, default: &str) -> String {
        self.get_str(name).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, CliError> {
        match self.get_str(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError::BadValue(name.into(), v.into(), "usize")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, CliError> {
        match self.get_str(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| CliError::BadValue(name.into(), v.into(), "u64")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, CliError> {
        match self.get_str(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| CliError::BadValue(name.into(), v.into(), "f64")),
        }
    }

    pub fn get_bool(&self, name: &str, default: bool) -> Result<bool, CliError> {
        match self.get_str(name) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => Err(CliError::BadValue(name.into(), v.into(), "bool")),
        }
    }

    /// Comma-separated list of usizes, e.g. `--m 8,16,32`.
    pub fn get_usize_list(&self, name: &str, default: &[usize]) -> Result<Vec<usize>, CliError> {
        match self.get_str(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .filter(|p| !p.is_empty())
                .map(|p| {
                    p.trim()
                        .parse()
                        .map_err(|_| CliError::BadValue(name.into(), p.into(), "usize list"))
                })
                .collect(),
        }
    }

    /// Comma-separated list of strings.
    pub fn get_str_list(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.get_str(name) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(v) => v.split(',').filter(|p| !p.is_empty()).map(|p| p.trim().to_string()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<OptSpec> {
        vec![
            OptSpec { name: "m", help: "sample sizes", default: Some("32".into()) },
            OptSpec { name: "lr", help: "learning rate", default: None },
            OptSpec { name: "verbose", help: "chatty", default: None },
            OptSpec { name: "name", help: "run name", default: None },
        ]
    }

    fn parse(argv: &[&str]) -> Result<Args, CliError> {
        let v: Vec<String> = argv.iter().map(|s| s.to_string()).collect();
        Args::parse("kss test", &v, &specs(), &["verbose"])
    }

    #[test]
    fn parses_values_and_defaults() {
        let a = parse(&["--m", "8,16", "--lr=0.5", "pos1"]).unwrap();
        assert_eq!(a.get_usize_list("m", &[]).unwrap(), vec![8, 16]);
        assert_eq!(a.get_f64("lr", 1.0).unwrap(), 0.5);
        assert_eq!(a.positional(), &["pos1".to_string()]);
        // reading a list-valued flag as a scalar is a BadValue error
        assert!(matches!(a.get_usize("m", 7), Err(CliError::BadValue(..))));
        // defaults apply when the flag is absent
        let b = parse(&[]).unwrap();
        assert_eq!(b.get_usize_list("m", &[32]).unwrap(), vec![32]);
    }

    #[test]
    fn bool_flags_take_no_value() {
        let a = parse(&["--verbose", "--name", "x"]).unwrap();
        assert!(a.get_bool("verbose", false).unwrap());
        assert_eq!(a.get_str("name"), Some("x"));
    }

    #[test]
    fn unknown_flag_is_error() {
        assert!(matches!(parse(&["--nope", "1"]), Err(CliError::Unknown(_))));
    }

    #[test]
    fn missing_value_is_error() {
        assert!(matches!(parse(&["--lr"]), Err(CliError::MissingValue(_))));
    }

    #[test]
    fn bad_value_is_error() {
        let a = parse(&["--lr", "abc"]).unwrap();
        assert!(matches!(a.get_f64("lr", 0.0), Err(CliError::BadValue(..))));
    }

    #[test]
    fn repeated_flags_accumulate() {
        let a = parse(&["--name", "a", "--name", "b"]).unwrap();
        assert_eq!(a.get_all("name"), vec!["a", "b"]);
        assert_eq!(a.get_str("name"), Some("b"));
    }

    #[test]
    fn help_and_usage() {
        let a = parse(&["--help"]).unwrap();
        assert!(a.wants_help());
        let u = a.usage();
        assert!(u.contains("--m") && u.contains("default: 32"));
    }
}
