"""Pallas kernel: streaming full-softmax cross entropy over all n classes.

The paper's baseline (and its evaluation metric) is the *full* softmax loss,
which needs the partition function over every class. For large n the logits
matrix (N, n) should never hit HBM; this kernel streams the class-embedding
table through VMEM in chunks with an online (flash-style) logsumexp:

    running (m, z):  m' = max(m, max_c o_c),  z' = z·e^{m-m'} + Σ_c e^{o_c-m'}

The backward pass makes a second streaming sweep computing p = softmax(o)
chunk-by-chunk, accumulating dh on the fly and writing each chunk's dW tile
in place — the (N, n) probability matrix is never materialized either.

TPU adaptation (DESIGN.md §6): the class table is tiled (chunk_c, d); one
grid step holds a (bn, d) query block plus one class chunk in VMEM and runs
(bn,d)×(d,chunk_c) MXU contractions. On this CPU testbed the kernel runs
under interpret=True; pytest pins its numerics (values and grads) to ref.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .sampled_softmax import pick_block


def pick_chunk(n: int, target: int = 512) -> int:
    """Class-chunk size: largest divisor of n <= target."""
    return pick_block(n, target)


# ---------------------------------------------------------------------------
# forward: online logsumexp over class chunks
# ---------------------------------------------------------------------------


def _fwd_kernel(h_ref, w_ref, wpos_ref, loss_ref, lse_ref, *, abs_logits, chunk_c):
    h = h_ref[...]  # (bn, d)
    bn = h.shape[0]
    n_classes = w_ref.shape[0]
    steps = n_classes // chunk_c

    def body(c, carry):
        m, z = carry
        wblk = pl.load(w_ref, (pl.dslice(c * chunk_c, chunk_c), slice(None)))  # (cc, d)
        o = jax.lax.dot_general(
            h, wblk, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bn, cc)
        if abs_logits:
            o = jnp.abs(o)
        m_new = jnp.maximum(m, jnp.max(o, axis=-1))
        z = z * jnp.exp(m - m_new) + jnp.sum(jnp.exp(o - m_new[:, None]), axis=-1)
        return m_new, z

    m0 = jnp.full((bn,), -jnp.inf, dtype=jnp.float32)
    z0 = jnp.zeros((bn,), dtype=jnp.float32)
    m, z = jax.lax.fori_loop(0, steps, body, (m0, z0))
    lse = m + jnp.log(z)
    # positive logit from the pre-gathered rows (keeps the kernel gather-free)
    opos = jnp.sum(h * wpos_ref[...], axis=-1)
    if abs_logits:
        opos = jnp.abs(opos)
    loss_ref[...] = (lse - opos).astype(loss_ref.dtype)
    lse_ref[...] = lse.astype(lse_ref.dtype)


def _fwd_pallas(h, w, wpos, abs_logits, block_n=None, chunk_c=None):
    n, d = h.shape
    nc = w.shape[0]
    bn = block_n or pick_block(n)
    cc = chunk_c or pick_chunk(nc)
    kernel = functools.partial(_fwd_kernel, abs_logits=abs_logits, chunk_c=cc)
    return pl.pallas_call(
        kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((nc, d), lambda i: (0, 0)),  # full table, streamed inside
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), h.dtype),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,
    )(h, w, wpos)


# ---------------------------------------------------------------------------
# backward: second streaming sweep, p computed chunk-wise
# ---------------------------------------------------------------------------


def _bwd_kernel(t_ref, h_ref, w_ref, wpos_ref, lse_ref, dh_ref, dw_ref, *, abs_logits, chunk_c):
    i = pl.program_id(0)
    h = h_ref[...]  # (bn, d)
    t = t_ref[...]  # (bn,)
    lse = lse_ref[...]  # (bn,)
    n_classes = w_ref.shape[0]
    steps = n_classes // chunk_c

    # dW accumulates across row-blocks (grid steps): zero it once.
    @pl.when(i == 0)
    def _init():
        dw_ref[...] = jnp.zeros_like(dw_ref)

    def body(c, dh_acc):
        sl = (pl.dslice(c * chunk_c, chunk_c), slice(None))
        wblk = pl.load(w_ref, sl)  # (cc, d)
        o = jax.lax.dot_general(
            h, wblk, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        if abs_logits:
            sign = jnp.sign(o)
            o = jnp.abs(o)
        else:
            sign = jnp.ones_like(o)
        p = jnp.exp(o - lse[:, None])  # softmax probabilities of this chunk
        tp = t[:, None] * p * sign  # cotangent w.r.t. raw logits (lse part)
        dh_acc = dh_acc + jax.lax.dot_general(
            tp, wblk, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        dwblk = jax.lax.dot_general(
            tp, h, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )  # (cc, d)
        pl.store(dw_ref, sl, pl.load(dw_ref, sl) + dwblk.astype(dw_ref.dtype))
        return dh_acc

    dh = jax.lax.fori_loop(0, steps, body, jnp.zeros(h.shape, jnp.float32))
    # the -o_pos term: d/dh = -t * sign_pos * wpos (wpos cotangent handled
    # outside the kernel where the gather happened)
    opos_sign = jnp.sign(jnp.sum(h * wpos_ref[...], axis=-1)) if abs_logits else jnp.ones_like(t)
    dh = dh - (t * opos_sign)[:, None] * wpos_ref[...]
    dh_ref[...] = dh.astype(dh_ref.dtype)


def _bwd_pallas(t, h, w, wpos, lse, abs_logits, block_n=None, chunk_c=None):
    n, d = h.shape
    nc = w.shape[0]
    bn = block_n or pick_block(n)
    cc = chunk_c or pick_chunk(nc)
    kernel = functools.partial(_bwd_kernel, abs_logits=abs_logits, chunk_c=cc)
    return pl.pallas_call(
        kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((nc, d), lambda i: (0, 0)),
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((nc, d), lambda i: (0, 0)),  # accumulated across steps
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, d), h.dtype),
            jax.ShapeDtypeStruct((nc, d), w.dtype),
        ],
        interpret=True,
    )(t, h, w, wpos, lse)


# ---------------------------------------------------------------------------
# public custom-vjp entry point
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def full_softmax_loss(h, w, pos, abs_logits=False):
    """Per-example full-softmax CE loss over all classes (eq. 1 / eq. 11).

    Args:
      h: (N, d) query embeddings.
      w: (n, d) full class-embedding table.
      pos: (N,) int32 positive class indices.

    Returns: (N,) losses. Differentiable in h and w.
    """
    wpos = w[pos]
    loss, _ = _fwd_pallas(h, w, wpos, abs_logits)
    return loss


def _vjp_fwd(h, w, pos, abs_logits):
    wpos = w[pos]
    loss, lse = _fwd_pallas(h, w, wpos, abs_logits)
    return loss, (h, w, wpos, pos, lse)


def _vjp_bwd(abs_logits, res, t):
    h, w, wpos, pos, lse = res
    dh, dw = _bwd_pallas(t, h, w, wpos, lse, abs_logits)
    # -o_pos term's contribution to W: scatter -t*sign*h into the pos rows.
    if abs_logits:
        sign = jnp.sign(jnp.sum(h * wpos, axis=-1))
    else:
        sign = jnp.ones_like(t)
    dw = dw.at[pos].add(-(t * sign)[:, None] * h)
    return dh.astype(h.dtype), dw.astype(w.dtype), None


full_softmax_loss.defvjp(_vjp_fwd, _vjp_bwd)
