//! Scoped data-parallel helpers (no `rayon` offline).
//!
//! The sampler layer fans a batch's rows out over `std::thread::scope`
//! workers with [`par_chunks_mut`] — static contiguous chunking, so the
//! partition depends only on `(len, threads)`. Each row derives its own RNG
//! stream from its index (`sampler::row_rng`), which makes results
//! deterministic for a fixed seed and *any* thread count. [`par_for_each_mut`]
//! and [`par_map`] are the per-element conveniences built on top.

/// Number of worker threads to use by default (capped: the batch rows we
/// parallelize over are small work items).
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(16)
}

/// Mutex-guarded freelist of reusable scratch states: `take` pops a pooled
/// item (or builds a fresh one when empty), `put` returns it. Total
/// allocations are bounded by the peak number of concurrent users rather
/// than the call count — the discipline both the kernel tree's
/// `DrawScratch` pool and the shard router's `ShardScratch` pool share.
/// Contents must never affect results (kss scratches are invalidated by
/// generation counters on checkout).
pub struct Pool<T> {
    items: std::sync::Mutex<Vec<T>>,
}

impl<T> Default for Pool<T> {
    fn default() -> Self {
        Pool::new()
    }
}

impl<T> Pool<T> {
    pub fn new() -> Pool<T> {
        Pool { items: std::sync::Mutex::new(Vec::new()) }
    }

    /// Pop a pooled item, or build one with `make` when the pool is empty.
    pub fn take(&self, make: impl FnOnce() -> T) -> T {
        self.items.lock().expect("scratch pool poisoned").pop().unwrap_or_else(make)
    }

    /// Return an item for reuse by later `take`s.
    pub fn put(&self, item: T) {
        self.items.lock().expect("scratch pool poisoned").push(item);
    }
}

/// Apply `f(base_index, chunk)` to contiguous chunks of `items`, one chunk
/// per worker. The partition depends only on `items.len()` and `threads`
/// (static chunking), so callers that derive per-index state (per-row RNG
/// streams, per-worker scratch buffers) get identical results for any
/// thread count. This is the primitive the batch sampling engine fans out
/// on: workers allocate scratch once per chunk, not once per item.
pub fn par_chunks_mut<T: Send>(
    items: &mut [T],
    threads: usize,
    f: impl Fn(usize, &mut [T]) + Sync,
) {
    if items.is_empty() {
        return;
    }
    let threads = threads.max(1);
    if threads == 1 || items.len() == 1 {
        f(0, items);
        return;
    }
    let n = items.len();
    let chunk = n.div_ceil(threads);
    std::thread::scope(|scope| {
        let mut rest = items;
        let mut base = 0usize;
        while !rest.is_empty() {
            let take = chunk.min(rest.len());
            let (head, tail) = rest.split_at_mut(take);
            let fref = &f;
            scope.spawn(move || fref(base, head));
            rest = tail;
            base += take;
        }
    });
}

/// Apply `f(index, &mut item)` to every element, in parallel chunks across
/// `threads` workers. Deterministic partitioning: element order and
/// chunk->worker assignment do not depend on scheduling.
pub fn par_for_each_mut<T: Send>(
    items: &mut [T],
    threads: usize,
    f: impl Fn(usize, &mut T) + Sync,
) {
    par_chunks_mut(items, threads, |base, chunk| {
        for (i, item) in chunk.iter_mut().enumerate() {
            f(base + i, item);
        }
    });
}

/// Parallel map producing a `Vec` in input order.
pub fn par_map<T: Send + Sync, R: Send>(
    items: &[T],
    threads: usize,
    f: impl Fn(usize, &T) -> R + Sync,
) -> Vec<R> {
    let mut out: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
    {
        let slots = &mut out[..];
        par_for_each_mut(slots, threads, |i, slot| {
            *slot = Some(f(i, &items[i]));
        });
    }
    out.into_iter().map(|r| r.expect("par_map slot unfilled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn maps_in_order() {
        let xs: Vec<usize> = (0..1000).collect();
        let ys = par_map(&xs, 4, |_, &x| x * 2);
        assert_eq!(ys, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn for_each_visits_every_index_once() {
        let mut xs = vec![0usize; 517];
        let visits = AtomicUsize::new(0);
        par_for_each_mut(&mut xs, 3, |i, x| {
            *x = i + 1;
            visits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(visits.load(Ordering::Relaxed), 517);
        for (i, &x) in xs.iter().enumerate() {
            assert_eq!(x, i + 1);
        }
    }

    #[test]
    fn single_thread_path() {
        let mut xs = vec![1u32; 8];
        par_for_each_mut(&mut xs, 1, |i, x| *x += i as u32);
        assert_eq!(xs, vec![1, 2, 3, 4, 5, 6, 7, 8]);
    }

    #[test]
    fn empty_and_tiny_inputs() {
        let mut empty: Vec<u8> = vec![];
        par_for_each_mut(&mut empty, 8, |_, _| panic!("must not be called"));
        let ys = par_map::<u8, u8>(&[], 8, |_, &x| x);
        assert!(ys.is_empty());
        let one = par_map(&[41], 8, |_, &x| x + 1);
        assert_eq!(one, vec![42]);
    }

    #[test]
    fn more_threads_than_items() {
        let xs: Vec<usize> = (0..3).collect();
        let ys = par_map(&xs, 64, |i, &x| x + i);
        assert_eq!(ys, vec![0, 2, 4]);
    }

    #[test]
    fn chunks_cover_everything_with_correct_bases() {
        for threads in [1usize, 2, 3, 7, 64] {
            let mut xs = vec![0usize; 23];
            par_chunks_mut(&mut xs, threads, |base, chunk| {
                for (i, x) in chunk.iter_mut().enumerate() {
                    *x = base + i + 1;
                }
            });
            for (i, &x) in xs.iter().enumerate() {
                assert_eq!(x, i + 1, "threads={threads}");
            }
        }
        let mut empty: Vec<u8> = vec![];
        par_chunks_mut(&mut empty, 4, |_, _| panic!("must not be called"));
    }

    #[test]
    fn chunk_partition_is_static() {
        // same (len, threads) must always produce the same chunk bases
        let collect = |threads: usize| {
            let mut xs = vec![0usize; 17];
            let bases = std::sync::Mutex::new(Vec::new());
            par_chunks_mut(&mut xs, threads, |base, chunk| {
                bases.lock().unwrap().push((base, chunk.len()));
            });
            let mut b = bases.into_inner().unwrap();
            b.sort_unstable();
            b
        };
        assert_eq!(collect(4), collect(4));
    }
}
