#!/usr/bin/env python3
"""pallas-lint self-tests: every rule must trip on its known-bad fixture
and stay silent on its known-good twin, and the engine's baseline
workflow must fail the build on a seeded violation (the CI-fail
demonstration the static-analysis job relies on).

Plain asserts, stdlib only, Python 3.10: `python3 run_tests.py` exits 0
on success.
"""

import json
import os
import shutil
import sys
import tempfile

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__)))))

from pallas_lint import engine
from pallas_lint.frontend import SourceFile, normalize, tokenize
from pallas_lint.rules.accumulation import AccumulationContract
from pallas_lint.rules.lock_discipline import LockDiscipline
from pallas_lint.rules.obs_drop import ObsVisibleDrops
from pallas_lint.rules.panic_free import PanicFreeWorkers
from pallas_lint.rules.q_positivity import QPositivity
from pallas_lint.rules.registry_consistency import RegistryConsistency
from pallas_lint.rules.unsafe_audit import UnsafeAudit

FIXTURES = os.path.join(os.path.dirname(os.path.abspath(__file__)), "fixtures")


def fixture(name: str) -> str:
    with open(os.path.join(FIXTURES, name), "r", encoding="utf-8") as f:
        return f.read()


def sf(logical_path: str, fixture_name: str) -> SourceFile:
    """Lex a fixture under the repo-relative path the rule scopes on."""
    return SourceFile(logical_path, fixture(fixture_name))


# -- frontend ---------------------------------------------------------------


def test_tokenizer_balance():
    _, errs = tokenize("fn main() { let x = (1 + 2; }", "bad.rs")
    assert errs, "unbalanced source must produce balance errors"
    _, errs = tokenize('fn ok() { let s = r#"no { balance " here"#; }', "ok.rs")
    assert errs == [], f"raw string confused the lexer: {errs}"
    _, errs = tokenize("fn ok() { let c = '{'; let lt: &'static str = \"x\"; }", "ok.rs")
    assert errs == [], f"char literal / lifetime confused the lexer: {errs}"


def test_structure_helpers():
    s = SourceFile("rust/src/x.rs", fixture("panic_good.rs"))
    names = {f.name for f in s.functions()}
    assert {"submit", "next_batch", "shutdown", "depth"} <= names, names
    spans = s.test_spans()
    assert spans, "#[cfg(test)] mod must be detected"
    assert s.in_test(spans[0][0]) and s.in_test(spans[0][1])
    assert not s.in_test(1)


# -- per-rule fixtures ------------------------------------------------------


def test_acc_rule():
    rule = AccumulationContract()
    bad = rule.check(sf("rust/src/sampler/acc_bad.rs", "acc_bad.rs"))
    assert len(bad) == 1 and bad[0].rule == "ACC", bad
    assert "acc" in bad[0].message, bad[0].message
    good = rule.check(sf("rust/src/sampler/acc_good.rs", "acc_good.rs"))
    assert good == [], good
    # the same reduction inside rust/src/ops/ is the contract, not a breach
    assert not rule.applies("rust/src/ops/lanes.rs")


def test_qpos_rule():
    rule = QPositivity()
    bad = rule.check(sf("rust/src/sampler/qpos_bad.rs", "qpos_bad.rs"))
    assert len(bad) == 4, bad
    assert all(f.rule == "QPOS" for f in bad)
    # the un-minted pool_mass rebind is caught despite the guard-4 name
    assert any("pool_mass" in f.message for f in bad), [f.message for f in bad]
    # ... and so is the un-minted midx refine denominator
    assert any("cluster_mass" in f.message for f in bad), [f.message for f in bad]
    good = rule.check(sf("rust/src/sampler/qpos_good.rs", "qpos_good.rs"))
    assert good == [], good
    # the rule scopes to sampler/ + serve/ only
    assert not rule.applies("rust/src/util/stats.rs")


def test_panic_rule():
    rule = PanicFreeWorkers()
    bad = rule.check(sf("rust/src/serve/batcher.rs", "panic_bad.rs"))
    kinds = sorted(f.message.split(" ")[0] for f in bad)
    assert len(bad) == 4, (len(bad), kinds)  # unwrap, expect, panic!, items[0]
    assert any(".unwrap()" in f.message for f in bad)
    assert any(".expect()" in f.message for f in bad)
    assert any("panic!" in f.message for f in bad)
    assert any("indexing" in f.message for f in bad)
    good = rule.check(sf("rust/src/serve/batcher.rs", "panic_good.rs"))
    assert good == [], good


def test_obs_rule():
    rule = ObsVisibleDrops()
    bad = rule.check(sf("rust/src/serve/obs_bad.rs", "obs_bad.rs"))
    assert len(bad) == 3, [f.message for f in bad]
    assert all(f.rule == "OBS" for f in bad)
    assert any("`let _ =`" in f.message for f in bad)
    assert any("Err(_)" in f.message for f in bad)
    assert any(".ok();" in f.message for f in bad)
    good = rule.check(sf("rust/src/serve/obs_good.rs", "obs_good.rs"))
    assert good == [], [f.message for f in good]
    # scope: serve + coordinator trees only — sampler fallbacks have their
    # own dedicated counters wired in the scratch drain
    assert rule.applies("rust/src/coordinator/pipeline.rs")
    assert not rule.applies("rust/src/util/logging.rs")


def test_lock_rule():
    rule = LockDiscipline()
    bad_sf = sf("rust/src/serve/lock_bad.rs", "lock_bad.rs")
    bad = rule.check_project({bad_sf.path: bad_sf}, {})
    msgs = [f.message for f in bad]
    assert any("already held" in m for m in msgs), msgs
    assert any("pinned snapshot" in m for m in msgs), msgs
    assert any("lock-acquisition cycle" in m for m in msgs), msgs
    assert len(bad) == 3, msgs
    good_sf = sf("rust/src/serve/lock_good.rs", "lock_good.rs")
    good = rule.check_project({good_sf.path: good_sf}, {})
    assert good == [], [f.message for f in good]


def test_unsafe_rule():
    rule = UnsafeAudit()
    bad = rule.check(sf("rust/src/runtime/unsafe_bad.rs", "unsafe_bad.rs"))
    assert len(bad) == 1 and bad[0].rule == "UNSAFE", bad
    good = rule.check(sf("rust/src/runtime/unsafe_good.rs", "unsafe_good.rs"))
    assert good == [], good


def _reg_files(tree: str):
    root = os.path.join(FIXTURES, tree)
    files = {}
    for rel in ("rust/src/sampler/mod.rs", "rust/src/main.rs"):
        with open(os.path.join(root, rel), "r", encoding="utf-8") as f:
            files[rel] = SourceFile(rel, f.read())
    with open(os.path.join(root, "README.md"), "r", encoding="utf-8") as f:
        extra = {"README.md": f.read()}
    return files, extra


def test_reg_rule():
    rule = RegistryConsistency()
    files, extra = _reg_files("regfix_bad")
    bad = rule.check_project(files, extra)
    msgs = [f.message for f in bad]
    assert any("phantom" in m and "no build_sampler match arm" in m for m in msgs), msgs
    assert any("orphan" in m and "missing from" in m for m in msgs), msgs
    assert any("no longer iterates SAMPLER_REGISTRY" in m for m in msgs), msgs
    assert any("phantom" in m and "README" in m for m in msgs), msgs
    assert any("stale" in m and "not in SAMPLER_REGISTRY" in m for m in msgs), msgs
    assert len(bad) == 5, msgs
    files, extra = _reg_files("regfix_good")
    good = rule.check_project(files, extra)
    assert good == [], [f.message for f in good]


# -- engine + baseline workflow --------------------------------------------


def test_engine_clean_tree():
    report = engine.run(os.path.join(FIXTURES, "regfix_good"))
    report.pop("_fingerprinted")
    assert report["new_count"] == 0, report["findings"]
    assert report["files_scanned"] == 2


def test_engine_dirty_tree():
    report = engine.run(os.path.join(FIXTURES, "regfix_bad"))
    report.pop("_fingerprinted")
    assert report["new_count"] == 5, report["findings"]
    assert {f["rule"] for f in report["findings"]} == {"REG"}


def test_baseline_blocks_only_new_findings():
    """The acceptance demonstration: pre-existing findings are waived by
    the checked-in baseline; a seeded violation fails the run."""
    with tempfile.TemporaryDirectory() as tmp:
        root = os.path.join(tmp, "repo")
        shutil.copytree(os.path.join(FIXTURES, "regfix_bad"), root)
        baseline = os.path.join(root, "baseline.json")

        # 1. accept the 5 pre-existing findings
        report = engine.run(root)
        engine.write_baseline(baseline, report.pop("_fingerprinted"))
        report = engine.run(root, baseline_path=baseline)
        report.pop("_fingerprinted")
        assert report["new_count"] == 0 and report["waived_count"] == 5, report

        # 2. seed a violation: an unsafe block with no SAFETY comment
        main_rs = os.path.join(root, "rust", "src", "main.rs")
        with open(main_rs, "a", encoding="utf-8") as f:
            f.write(
                "\npub fn seeded(x: &[f32]) -> *const f32 {\n"
                "    unsafe { x.as_ptr().add(0) }\n"
                "}\n"
            )
        report = engine.run(root, baseline_path=baseline)
        report.pop("_fingerprinted")
        assert report["new_count"] == 1, report["findings"]
        seeded = [f for f in report["findings"] if not f["waived"]]
        assert seeded[0]["rule"] == "UNSAFE", seeded

        # 3. fix one waived finding -> its waiver is reported stale
        mod_rs = os.path.join(root, "rust", "src", "sampler", "mod.rs")
        with open(mod_rs, "r", encoding="utf-8") as f:
            src = f.read()
        with open(mod_rs, "w", encoding="utf-8") as f:
            f.write(src.replace('"orphan" => Ok(9),\n', ""))
        report = engine.run(root, baseline_path=baseline)
        report.pop("_fingerprinted")
        assert report["stale_waivers"], "fixed finding must surface its waiver"


def test_lex_findings_through_engine():
    with tempfile.TemporaryDirectory() as tmp:
        root = os.path.join(tmp, "repo")
        os.makedirs(os.path.join(root, "rust", "src"))
        with open(os.path.join(root, "rust", "src", "broken.rs"), "w") as f:
            f.write("fn main() { let x = (1 + 2; }\n")
        report = engine.run(root)
        report.pop("_fingerprinted")
        rules = {f["rule"] for f in report["findings"]}
        assert "LEX" in rules, report["findings"]


def test_fingerprints_survive_line_drift():
    f1 = engine.Finding("ACC", "a.rs", 10, "m", "    acc += x[i];")
    f2 = engine.Finding("ACC", "a.rs", 99, "m", "acc += x[i];")
    assert normalize(f1.snippet) == normalize(f2.snippet)
    assert engine.fingerprint(f1, 0) == engine.fingerprint(f2, 0)
    assert engine.fingerprint(f1, 0) != engine.fingerprint(f1, 1)


def test_repo_baseline_is_justified():
    """Every waiver in the checked-in baseline must carry a real reason."""
    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(here, "baseline.json")
    assert os.path.exists(path), "checked-in baseline missing"
    with open(path, "r", encoding="utf-8") as f:
        data = json.load(f)
    for w in data["waivers"]:
        reason = w.get("reason", "")
        assert reason and "TODO" not in reason, f"unjustified waiver: {w}"
        # the unsafe-audit waiver set must stay empty (satellite b)
        assert w["rule"] != "UNSAFE", f"unsafe finding must be fixed, not waived: {w}"


def main() -> int:
    tests = [(n, fn) for n, fn in sorted(globals().items()) if n.startswith("test_")]
    failed = 0
    for name, fn in tests:
        try:
            fn()
            print(f"  ok  {name}")
        except AssertionError as e:
            failed += 1
            print(f"FAIL  {name}: {e}")
    print(f"pallas-lint self-tests: {len(tests) - failed}/{len(tests)} passed")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
