//! §3.2.2 "Multiple Partial Samples" — the paper's faster-sampling variant.
//!
//! Instead of m independent O(D log n) descents, run `runs` descents and
//! return *every* class of each reached leaf. A run that reaches leaf C with
//! probability P(C) contributes each of C's classes once; weighting those
//! contributions by 1/P(C) keeps the importance-corrected partition-function
//! estimate unbiased:
//!
//!   E[ Σ_{j ∈ C} exp(o_j) / P(C) ] = Σ_leaves P(C) Σ_{j∈C} exp(o_j)/P(C)
//!                                  = Σ_j exp(o_j)
//!
//! so the trainer can use `q_j = P(leaf(j))` with `m = runs` in the eq. (2)
//! correction. The paper notes (and our ablation bench confirms) that the
//! samples are correlated, so more total classes are needed for the same
//! bias — the trade is descent count vs sample quality.

use super::tree::KernelTreeSampler;
use super::FeatureMap;
use crate::sampler::{Needs, Sample, SampleInput, Sampler};
use crate::util::rng::Rng;
use anyhow::Result;

/// Wraps a [`KernelTreeSampler`] to return whole leaves per descent.
/// `sample(.., m, ..)` interprets m as the number of *descents*; the output
/// contains up to `m × leaf_size` classes.
pub struct PartialLeafSampler<M: FeatureMap> {
    tree: KernelTreeSampler<M>,
}

impl<M: FeatureMap> PartialLeafSampler<M> {
    pub fn new(tree: KernelTreeSampler<M>) -> Self {
        PartialLeafSampler { tree }
    }

    pub fn tree(&self) -> &KernelTreeSampler<M> {
        &self.tree
    }
}

impl<M: FeatureMap> Sampler for PartialLeafSampler<M> {
    fn name(&self) -> &str {
        "quadratic-partial"
    }

    fn needs(&self) -> Needs {
        Needs { h: true, ..Needs::default() }
    }

    fn sample(&self, input: &SampleInput, runs: usize, rng: &mut Rng, out: &mut Sample) -> Result<()> {
        let h = input.h.ok_or_else(|| anyhow::anyhow!("partial-leaf sampler needs h"))?;
        out.clear();
        let phi_h = self.tree.phi_query(h);
        for _ in 0..runs {
            // draw_leaf shares the tree's guarded branch step, so p_leaf is
            // strictly positive even when subset masses underflow to zero
            // (the eq. 2 correction ln(runs·q) stays finite).
            let (range, p_leaf) = self.tree.draw_leaf(&phi_h, rng);
            for class in range {
                out.push(class, p_leaf);
            }
        }
        Ok(())
    }

    fn prob(&self, input: &SampleInput, class: u32) -> Option<f64> {
        // probability of *the class's leaf* being returned per run
        let h = input.h?;
        let phi_h = self.tree.phi_query(h);
        Some(self.tree.leaf_prob_of_class(&phi_h, class))
    }

    fn update(&mut self, class: usize, w_new: &[f32]) {
        self.tree.update(class, w_new);
    }

    fn update_many(&mut self, classes: &[usize], rows: &[f32]) {
        self.tree.update_many(classes, rows);
    }

    fn reset_embeddings(&mut self, w: &[f32], n: usize, d: usize) {
        self.tree.reset_embeddings(w, n, d);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::kernel::QuadraticMap;

    #[test]
    fn partial_sampler_importance_weights_are_unbiased() {
        // E[ Σ_{j∈leaf} f(j) / P(leaf) ] per run must equal Σ_j f(j).
        let (n, d) = (30, 3);
        let mut rng = Rng::new(3);
        let mut emb = vec![0.0f32; n * d];
        rng.fill_normal(&mut emb, 0.6);
        let mut tree = KernelTreeSampler::new(QuadraticMap::new(d, 100.0), n, Some(5));
        tree.reset_embeddings(&emb, n, d);
        let sampler = PartialLeafSampler::new(tree);
        let h: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let input = SampleInput { h: Some(&h), ..Default::default() };
        let f = |j: u32| 1.0 + (j as f64) * 0.1; // arbitrary positive payload
        let truth: f64 = (0..n as u32).map(f).sum();
        let runs = 40_000;
        let mut out = Sample::default();
        let mut acc = 0.0;
        sampler.sample(&input, runs, &mut rng, &mut out).unwrap();
        for (&c, &p) in out.classes.iter().zip(&out.q) {
            acc += f(c) / p;
        }
        let est = acc / runs as f64;
        assert!((est - truth).abs() < 0.05 * truth, "est {est} vs {truth}");
    }

    #[test]
    fn runs_produce_whole_leaves() {
        let (n, d) = (16, 2);
        let mut tree = KernelTreeSampler::new(QuadraticMap::new(d, 100.0), n, Some(4));
        let mut rng = Rng::new(5);
        let mut emb = vec![0.0f32; n * d];
        rng.fill_normal(&mut emb, 0.5);
        tree.reset_embeddings(&emb, n, d);
        let sampler = PartialLeafSampler::new(tree);
        let h = vec![0.5f32, -0.5];
        let input = SampleInput { h: Some(&h), ..Default::default() };
        let mut out = Sample::default();
        sampler.sample(&input, 3, &mut rng, &mut out).unwrap();
        assert_eq!(out.classes.len(), 12, "3 runs × leaf_size 4");
        // classes of one run are contiguous and share the same q
        for run in 0..3 {
            let qs = &out.q[run * 4..(run + 1) * 4];
            assert!(qs.iter().all(|&q| (q - qs[0]).abs() < 1e-15));
        }
    }
}
