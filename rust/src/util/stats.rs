//! Online statistics and timing utilities shared by the trainer, the metric
//! sinks and the bench harness.

use crate::obs::{Counter, Histogram, MetricsRegistry};
use crate::util::json::Value;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Welford online mean/variance accumulator.
#[derive(Clone, Debug, Default)]
pub struct Online {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Online {
    pub fn new() -> Online {
        Online { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.mean }
    }

    /// Sample variance (n-1 denominator).
    pub fn var(&self) -> f64 {
        if self.n < 2 { f64::NAN } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Percentile summary over a recorded sample set (bench harness output).
#[derive(Clone, Debug, Default)]
pub struct Samples {
    xs: Vec<f64>,
}

impl Samples {
    pub fn new() -> Samples {
        Samples { xs: Vec::new() }
    }

    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }

    /// Percentile via linear interpolation on the sorted sample, p in [0,100].
    pub fn percentile(&self, p: f64) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        let mut sorted = self.xs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Self::percentile_of_sorted(&sorted, p)
    }

    /// Several percentiles with one clone + sort (latency reports ask for
    /// p50/p95/p99/max together; per-call [`Self::percentile`] would re-sort
    /// each time).
    pub fn percentiles(&self, ps: &[f64]) -> Vec<f64> {
        if self.xs.is_empty() {
            return vec![f64::NAN; ps.len()];
        }
        let mut sorted = self.xs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        ps.iter().map(|&p| Self::percentile_of_sorted(&sorted, p)).collect()
    }

    fn percentile_of_sorted(sorted: &[f64], p: f64) -> f64 {
        let rank = (p / 100.0) * (sorted.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            sorted[lo]
        } else {
            let frac = rank - lo as f64;
            sorted[lo] * (1.0 - frac) + sorted[hi] * frac
        }
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p95(&self) -> f64 {
        self.percentile(95.0)
    }
}

/// Pearson chi-square statistic of observed `counts` against a probability
/// vector `probs` over `total` draws: `Σ (obs − exp)² / exp` over the bins
/// with non-negligible expected mass, plus one *pooled* bin holding every
/// tiny-expectation bin (observed and expected summed, denominator clamped
/// to 1). Pooling — rather than dropping — keeps mass misplaced onto
/// ~zero-probability classes visible without letting a near-zero
/// denominator dominate the statistic. Under the null the statistic is
/// ≈ χ²(df) with `df ≈ kept_bins − 1`: tests compare against
/// `df + c·√(2·df)` for a c-sigma bound. Used by the sharded-vs-unsharded
/// draw-distribution tests.
pub fn chi_square_stat(counts: &[u64], probs: &[f64], total: f64) -> f64 {
    assert_eq!(counts.len(), probs.len(), "counts/probs length mismatch");
    let mut stat = 0.0f64;
    let (mut pooled_obs, mut pooled_exp) = (0.0f64, 0.0f64);
    for (&c, &p) in counts.iter().zip(probs) {
        let expect = p * total;
        if expect >= 1.0 {
            let diff = c as f64 - expect;
            stat += diff * diff / expect;
        } else {
            pooled_obs += c as f64;
            pooled_exp += expect;
        }
    }
    if pooled_obs > 0.0 || pooled_exp > 0.0 {
        let diff = pooled_obs - pooled_exp;
        stat += diff * diff / pooled_exp.max(1.0);
    }
    stat
}

/// Total-variation distance `½ Σ |p_i − q_i|` between two probability
/// vectors. The single TV implementation in the tree: the samplers' test
/// harness ([`tv_from_counts`]) and the bias benches ([`tv_from_scores`])
/// both reduce to it.
pub fn tv_distance(p: &[f64], q: &[f64]) -> f64 {
    assert_eq!(p.len(), q.len(), "TV over mismatched supports");
    0.5 * p.iter().zip(q).map(|(&a, &b)| (a - b).abs()).sum::<f64>()
}

/// TV distance between *unnormalized* non-negative scores (a kernel row,
/// closed-form proposal weights) and a probability vector `target`: the
/// scores are normalized by their sum, then delegated to [`tv_distance`].
/// Used by the closed-form bias sweeps (`benches/ablation_rff_dim.rs`).
pub fn tv_from_scores(scores: &[f64], target: &[f64]) -> f64 {
    let z: f64 = scores.iter().sum();
    // same degenerate-total convention as the sampling paths (fill_cum
    // callers, draw_from_shards): a zero/non-finite mass must fail loudly,
    // not flow into a bias table as NaN
    assert!(z > 0.0 && z.is_finite(), "degenerate score total {z} in tv_from_scores");
    let p: Vec<f64> = scores.iter().map(|&s| s / z).collect();
    tv_distance(&p, target)
}

/// TV distance between empirical draw counts (over `total` draws) and an
/// expected distribution — the samplers' empirical-bias metric
/// (`sampler::test_util::empirical_tv` reduces to this via
/// [`tv_distance`]).
pub fn tv_from_counts(counts: &[usize], total: usize, expected: &[f64]) -> f64 {
    let p: Vec<f64> = counts.iter().map(|&c| c as f64 / total as f64).collect();
    tv_distance(&p, expected)
}

/// Wall-clock stopwatch with named laps; powers the trainer's step-phase
/// breakdown (encode / sample / step / tree-update) used in the perf pass.
pub struct Stopwatch {
    start: Instant,
    last: Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Stopwatch {
        let now = Instant::now();
        Stopwatch { start: now, last: now }
    }

    /// Seconds since construction.
    pub fn elapsed(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Seconds since the last lap (and reset the lap timer).
    pub fn lap(&mut self) -> f64 {
        let now = Instant::now();
        let dt = now.duration_since(self.last).as_secs_f64();
        self.last = now;
        dt
    }
}

/// Measure a closure's wall time in seconds.
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Accumulates per-phase durations across many steps.
///
/// Two books are kept: `phases` is critical-path wall time (the terms sum
/// to the accounted wall the throughput line divides by), and `overlapped`
/// is work the pipelined trainer hid behind another phase on a background
/// thread (depth-2 sampling/publishing) — reported for visibility but
/// excluded from [`PhaseTimes::total`], since counting it would double-book
/// the wall clock.
///
/// Since the telemetry PR this is a thin adapter over [`crate::obs`]
/// cells rather than its own `Vec<(String, Duration)>` accumulator: each
/// phase owns an exact nanosecond [`Counter`] (`kss_phase_<name>_*_total`)
/// plus a per-call seconds [`Histogram`] (`kss_phase_<name>[_bg]_seconds`),
/// all registered in a [`MetricsRegistry`] shared with the rest of the
/// trainer's telemetry. The report / JSON output is **byte-stable** with
/// the pre-adapter implementation (pinned by `phase_times_output_pin`):
/// totals read back through `Duration::from_nanos`, reproducing the old
/// exact Duration-sum arithmetic.
#[derive(Debug)]
pub struct PhaseTimes {
    book: Vec<PhaseCell>,
    hidden: Vec<PhaseCell>,
    registry: Arc<MetricsRegistry>,
}

/// One phase's storage: the obs cells, shared with the registry.
#[derive(Debug)]
struct PhaseCell {
    name: String,
    /// Exact Σ of per-add durations in nanoseconds (integer, associative —
    /// the report arithmetic matches the old `Duration` sums bit-for-bit).
    nanos: Arc<Counter>,
    /// Per-add seconds distribution (approximate, for p50/p95 readout).
    dist: Arc<Histogram>,
}

impl PhaseCell {
    fn secs(&self) -> f64 {
        Duration::from_nanos(self.nanos.get()).as_secs_f64()
    }
}

impl Default for PhaseTimes {
    fn default() -> Self {
        Self::with_registry(Arc::new(MetricsRegistry::new()))
    }
}

impl Clone for PhaseTimes {
    /// Deep copy (fresh cells + fresh registry carrying the current
    /// values), preserving the value semantics of the pre-adapter struct.
    fn clone(&self) -> Self {
        let mut out = PhaseTimes::default();
        for c in &self.book {
            out.add(&c.name, c.secs());
        }
        for c in &self.hidden {
            out.add_overlapped(&c.name, c.secs());
        }
        out
    }
}

impl PhaseTimes {
    /// Build over a caller-owned registry so phase cells export alongside
    /// the owner's other telemetry (the trainer shares one registry across
    /// phases, sampler internals and the pipeline driver).
    pub fn with_registry(registry: Arc<MetricsRegistry>) -> Self {
        PhaseTimes { book: Vec::new(), hidden: Vec::new(), registry }
    }

    /// The registry the phase cells are registered in.
    pub fn registry(&self) -> &Arc<MetricsRegistry> {
        &self.registry
    }

    pub fn add(&mut self, name: &str, secs: f64) {
        let i = Self::cell(&mut self.book, &self.registry, name, false);
        Self::record(&self.book, i, secs);
    }

    /// Record work that ran concurrently with an accounted phase (hidden
    /// wall time — see the struct docs).
    pub fn add_overlapped(&mut self, name: &str, secs: f64) {
        let i = Self::cell(&mut self.hidden, &self.registry, name, true);
        Self::record(&self.hidden, i, secs);
    }

    /// Find-or-mint the cell for `name` (insertion order preserved — the
    /// reports list phases in first-seen order, as before).
    fn cell(book: &mut Vec<PhaseCell>, registry: &MetricsRegistry, name: &str, bg: bool) -> usize {
        if let Some(i) = book.iter().position(|c| c.name == name) {
            return i;
        }
        let slug: String = name
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() { c.to_ascii_lowercase() } else { '_' })
            .collect();
        let suffix = if bg { "_bg" } else { "" };
        let nanos = registry.counter(
            &format!("kss_phase_{slug}{suffix}_nanos_total"),
            "nanoseconds",
            "trainer",
            if bg {
                "hidden (overlapped) wall time accumulated in this phase"
            } else {
                "accounted critical-path wall time accumulated in this phase"
            },
        );
        let dist = registry.histogram(
            &format!("kss_phase_{slug}{suffix}_seconds"),
            "seconds",
            "trainer",
            "per-call phase duration",
        );
        book.push(PhaseCell { name: name.to_string(), nanos, dist });
        book.len() - 1
    }

    fn record(book: &[PhaseCell], i: usize, secs: f64) {
        let d = Duration::from_secs_f64(secs.max(0.0));
        if let Some(c) = book.get(i) {
            c.nanos.add(d.as_nanos() as u64);
            c.dist.record(d.as_secs_f64());
        }
    }

    /// Critical-path seconds (overlapped work excluded).
    pub fn total(&self) -> f64 {
        self.book.iter().map(|c| c.secs()).sum()
    }

    pub fn report(&self) -> String {
        let total = self.total().max(1e-12);
        let mut s = String::new();
        for c in &self.book {
            let secs = c.secs();
            s.push_str(&format!(
                "  {:<14} {:>9.3}s  ({:>5.1}%)\n",
                c.name,
                secs,
                100.0 * secs / total
            ));
        }
        for c in &self.hidden {
            let secs = c.secs();
            s.push_str(&format!(
                "  {:<14} {:>9.3}s  (hidden behind other phases; not in total)\n",
                format!("{} (bg)", c.name),
                secs
            ));
        }
        s
    }

    /// [`Self::report`] plus throughput: a trailing line with the total
    /// accounted wall time, the step count, and steps/sec — the number an
    /// ops-layer win moves outside the benches (`kss train` prints this at
    /// the end of every run).
    pub fn report_with_throughput(&self, steps: usize) -> String {
        let mut s = self.report();
        let total = self.total();
        let rate = if total > 0.0 { steps as f64 / total } else { f64::NAN };
        s.push_str(&format!(
            "  {:<14} {:>9.3}s  ({} steps, {:.1} steps/s)\n",
            "total", total, steps, rate
        ));
        s
    }

    /// Machine-readable form for the metrics JSONL: per-phase seconds and
    /// share of accounted wall, plus hidden (overlapped) phase seconds,
    /// the total and steps/sec.
    pub fn to_json(&self, steps: usize) -> Value {
        let total = self.total();
        let denom = total.max(1e-12);
        Value::object(vec![
            (
                "phases",
                Value::Array(
                    self.book
                        .iter()
                        .map(|c| {
                            let secs = c.secs();
                            Value::object(vec![
                                ("name", Value::str(&c.name)),
                                ("secs", Value::num(secs)),
                                ("share", Value::num(secs / denom)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "overlapped",
                Value::Array(
                    self.hidden
                        .iter()
                        .map(|c| {
                            Value::object(vec![
                                ("name", Value::str(&c.name)),
                                ("secs", Value::num(c.secs())),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("total_s", Value::num(total)),
            ("steps", Value::num(steps as f64)),
            (
                "steps_per_s",
                Value::num(if total > 0.0 { steps as f64 / total } else { 0.0 }),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_mean_var() {
        let mut o = Online::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            o.push(x);
        }
        assert!((o.mean() - 5.0).abs() < 1e-12);
        assert!((o.var() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(o.min(), 2.0);
        assert_eq!(o.max(), 9.0);
        assert_eq!(o.count(), 8);
    }

    #[test]
    fn online_empty_is_nan() {
        let o = Online::new();
        assert!(o.mean().is_nan());
        assert!(o.var().is_nan());
    }

    #[test]
    fn percentiles() {
        let mut s = Samples::new();
        for x in 1..=100 {
            s.push(x as f64);
        }
        assert!((s.p50() - 50.5).abs() < 1e-9);
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-9);
        assert!((s.percentile(100.0) - 100.0).abs() < 1e-9);
        assert!(s.p95() > 90.0 && s.p95() < 100.0);
        // batch form sorts once and matches the per-call results
        let batch = s.percentiles(&[0.0, 50.0, 95.0, 100.0]);
        assert_eq!(batch, vec![s.percentile(0.0), s.p50(), s.p95(), s.percentile(100.0)]);
        assert!(Samples::new().percentiles(&[50.0]).iter().all(|x| x.is_nan()));
    }

    #[test]
    fn phase_times_accumulate() {
        let mut p = PhaseTimes::default();
        p.add("sample", 0.25);
        p.add("step", 0.75);
        p.add("sample", 0.25);
        assert!((p.total() - 1.25).abs() < 1e-9);
        let rep = p.report();
        assert!(rep.contains("sample") && rep.contains("40.0%"));
        // throughput report appends steps/sec over the accounted wall
        let rep = p.report_with_throughput(10);
        assert!(rep.contains("10 steps") && rep.contains("8.0 steps/s"), "{rep}");
        // machine-readable form carries shares and steps/sec
        let j = p.to_json(10);
        assert!((j.get("steps_per_s").unwrap().as_f64().unwrap() - 8.0).abs() < 1e-9);
        assert!((j.get("total_s").unwrap().as_f64().unwrap() - 1.25).abs() < 1e-9);
        let phases = j.get("phases").unwrap().as_array().unwrap();
        assert_eq!(phases.len(), 2);
        assert!((phases[0].get("share").unwrap().as_f64().unwrap() - 0.4).abs() < 1e-9);
    }

    #[test]
    fn overlapped_phases_are_reported_but_not_totalled() {
        let mut p = PhaseTimes::default();
        p.add("step", 1.0);
        p.add_overlapped("sample", 0.8);
        p.add_overlapped("sample", 0.2);
        // hidden work must not inflate the accounted wall (steps/s would
        // double-book the clock otherwise)
        assert!((p.total() - 1.0).abs() < 1e-9);
        let rep = p.report();
        assert!(rep.contains("sample (bg)") && rep.contains("hidden"), "{rep}");
        let rep = p.report_with_throughput(10);
        assert!(rep.contains("10.0 steps/s"), "{rep}");
        let j = p.to_json(10);
        let over = j.get("overlapped").unwrap().as_array().unwrap();
        assert_eq!(over.len(), 1);
        assert!((over[0].get("secs").unwrap().as_f64().unwrap() - 1.0).abs() < 1e-9);
        assert!((j.get("steps_per_s").unwrap().as_f64().unwrap() - 10.0).abs() < 1e-9);
    }

    /// Byte-stability pin for the obs-adapter re-implementation: the
    /// exact strings the pre-adapter `Vec<(String, Duration)>` code
    /// produced for this input, captured verbatim. A formatting or
    /// arithmetic drift in the adapter fails here, not in a downstream
    /// log diff.
    #[test]
    fn phase_times_output_pin() {
        let mut p = PhaseTimes::default();
        p.add("encode", 0.125);
        p.add("step", 0.5);
        p.add("encode", 0.375);
        p.add_overlapped("publish", 0.25);
        assert_eq!(
            p.report(),
            "  encode             0.500s  ( 50.0%)\n\
             \x20 step               0.500s  ( 50.0%)\n\
             \x20 publish (bg)       0.250s  (hidden behind other phases; not in total)\n"
        );
        assert_eq!(
            p.report_with_throughput(4),
            "  encode             0.500s  ( 50.0%)\n\
             \x20 step               0.500s  ( 50.0%)\n\
             \x20 publish (bg)       0.250s  (hidden behind other phases; not in total)\n\
             \x20 total              1.000s  (4 steps, 4.0 steps/s)\n"
        );
        assert_eq!(
            p.to_json(4).to_string_compact(),
            "{\"phases\":[{\"name\":\"encode\",\"secs\":0.5,\"share\":0.5},\
             {\"name\":\"step\",\"secs\":0.5,\"share\":0.5}],\
             \"overlapped\":[{\"name\":\"publish\",\"secs\":0.25}],\
             \"total_s\":1,\"steps\":4,\"steps_per_s\":4}"
        );
    }

    /// The adapter's storage IS the obs registry: every phase shows up as
    /// an exact nanosecond counter and a per-call histogram, so trainer
    /// phase reports and telemetry exports can never disagree.
    #[test]
    fn phase_times_cells_registered() {
        let mut p = PhaseTimes::default();
        p.add("sample", 0.25);
        p.add("sample", 0.25);
        p.add_overlapped("publish", 0.125);
        let snap = p.registry().snapshot();
        assert_eq!(snap.counter("kss_phase_sample_nanos_total"), Some(500_000_000));
        assert_eq!(snap.hist("kss_phase_sample_seconds").unwrap().count(), 2);
        assert_eq!(snap.hist("kss_phase_sample_seconds").unwrap().p50(), 0.25);
        assert_eq!(snap.counter("kss_phase_publish_bg_nanos_total"), Some(125_000_000));
        // clone is a deep copy: mutating the clone leaves the original alone
        let mut q = p.clone();
        q.add("sample", 1.0);
        assert!((p.total() - 0.5).abs() < 1e-12);
        assert!((q.total() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn tv_helpers_agree() {
        let p = [0.5, 0.25, 0.25];
        let q = [0.25, 0.5, 0.25];
        assert!((tv_distance(&p, &q) - 0.25).abs() < 1e-12);
        // unnormalized scores proportional to q give the same TV
        let scores = [1.0, 2.0, 1.0];
        assert!((tv_from_scores(&scores, &p) - 0.25).abs() < 1e-12);
        // counts realizing q exactly give the same TV
        let counts = [25usize, 50, 25];
        assert!((tv_from_counts(&counts, 100, &p) - 0.25).abs() < 1e-12);
        assert_eq!(tv_distance(&p, &p), 0.0);
    }

    #[test]
    fn chi_square_accepts_true_distribution_and_rejects_wrong_one() {
        use crate::util::rng::Rng;
        let probs = [0.5, 0.25, 0.125, 0.125];
        let mut rng = Rng::new(3);
        let total = 40_000u64;
        let mut counts = [0u64; 4];
        for _ in 0..total {
            let u = rng.f64();
            let mut acc = 0.0;
            let mut idx = probs.len() - 1;
            for (i, p) in probs.iter().enumerate() {
                acc += p;
                if u < acc {
                    idx = i;
                    break;
                }
            }
            counts[idx] += 1;
        }
        let stat = chi_square_stat(&counts, &probs, total as f64);
        // df = 3: mean 3, std √6 ≈ 2.45; 3 + 5σ ≈ 15
        assert!(stat < 15.0, "true distribution rejected: {stat}");
        let wrong = [0.25, 0.25, 0.25, 0.25];
        let bad = chi_square_stat(&counts, &wrong, total as f64);
        assert!(bad > 100.0, "wrong distribution accepted: {bad}");
        // tiny-expectation bins are pooled (clamped denominator), not
        // divided by ~0 — and not silently dropped: dumping half the mass
        // onto a ~zero-probability bin must blow the statistic up
        let sparse = chi_square_stat(&[0, 1], &[1.0 - 1e-9, 1e-9], 100.0);
        assert!(sparse.is_finite());
        let misplaced = chi_square_stat(&[50_000, 50_000], &[1.0 - 1e-9, 1e-9], 100_000.0);
        assert!(misplaced > 1e6, "misplaced mass accepted: {misplaced}");
    }

    #[test]
    fn stopwatch_monotonic() {
        let mut sw = Stopwatch::new();
        let a = sw.lap();
        let b = sw.lap();
        assert!(a >= 0.0 && b >= 0.0);
        assert!(sw.elapsed() >= a + b - 1e-6);
    }
}
