//! §3.2.2 "Multiple Partial Samples" — the paper's faster-sampling variant.
//!
//! Instead of m independent O(D log n) descents, run `runs` descents and
//! return *every* class of each reached leaf. A run that reaches leaf C with
//! probability P(C) contributes each of C's classes once; weighting those
//! contributions by 1/P(C) keeps the importance-corrected partition-function
//! estimate unbiased:
//!
//!   E[ Σ_{j ∈ C} exp(o_j) / P(C) ] = Σ_leaves P(C) Σ_{j∈C} exp(o_j)/P(C)
//!                                  = Σ_j exp(o_j)
//!
//! so the trainer can use `q_j = P(leaf(j))` with `m = runs` in the eq. (2)
//! correction. The paper notes (and our ablation bench confirms) that the
//! samples are correlated, so more total classes are needed for the same
//! bias — the trade is descent count vs sample quality.

use super::tree::KernelTreeSampler;
use super::FeatureMap;
use crate::sampler::{row_rng, BatchSampleInput, Needs, Sample, SampleInput, Sampler};
use crate::util::rng::Rng;
use crate::util::threadpool::par_chunks_mut;
use anyhow::Result;

/// Wraps a [`KernelTreeSampler`] to return whole leaves per descent.
/// `sample(.., m, ..)` interprets m as the number of *descents*; the output
/// contains up to `m × leaf_size` classes.
pub struct PartialLeafSampler<M: FeatureMap> {
    tree: KernelTreeSampler<M>,
}

impl<M: FeatureMap> PartialLeafSampler<M> {
    pub fn new(tree: KernelTreeSampler<M>) -> Self {
        PartialLeafSampler { tree }
    }

    pub fn tree(&self) -> &KernelTreeSampler<M> {
        &self.tree
    }
}

impl<M: FeatureMap> Sampler for PartialLeafSampler<M> {
    fn name(&self) -> &str {
        "quadratic-partial"
    }

    fn needs(&self) -> Needs {
        Needs { h: true, ..Needs::default() }
    }

    fn sample(&self, input: &SampleInput, runs: usize, rng: &mut Rng, out: &mut Sample) -> Result<()> {
        let h = input.h.ok_or_else(|| anyhow::anyhow!("partial-leaf sampler needs h"))?;
        out.clear();
        // Scratch-based descents: node masses are memoized across the
        // `runs` descents of this example (and the scratch itself comes
        // from the tree's freelist), exactly like the full draw path.
        // draw_leaf_scratch shares the tree's guarded branch step, so
        // p_leaf is strictly positive even when subset masses underflow to
        // zero (the eq. 2 correction ln(runs·q) stays finite).
        let mut scratch = self.tree.take_scratch();
        self.tree.begin_example(h, &mut scratch);
        for _ in 0..runs {
            let (range, p_leaf) = self.tree.draw_leaf_scratch(&mut scratch, rng);
            for class in range {
                out.push(class, p_leaf);
            }
        }
        self.tree.put_scratch(scratch);
        Ok(())
    }

    /// Batched descent engine, mirroring `KernelTreeSampler::sample_batch`:
    /// each worker checks one `DrawScratch` out of the tree's freelist and
    /// reuses it across all of that worker's rows (zero steady-state
    /// allocation), instead of inheriting the per-row default loop. Row `i`
    /// draws from [`row_rng`]`(step_seed, i)`, bit-identical to the
    /// per-example [`Sampler::sample`] loop for any thread count.
    fn sample_batch(
        &self,
        inputs: &BatchSampleInput,
        runs: usize,
        step_seed: u64,
        out: &mut [Sample],
    ) -> Result<()> {
        anyhow::ensure!(
            out.len() == inputs.n,
            "out has {} slots, batch has {} rows",
            out.len(),
            inputs.n
        );
        inputs.validate(self.name(), self.needs())?;
        let d = self.tree.embed_dim();
        anyhow::ensure!(inputs.d == d, "batch h dim {} != sampler d {}", inputs.d, d);
        let h_all = inputs.h.expect("validated: partial-leaf sampler needs h");
        par_chunks_mut(out, inputs.threads, |base, chunk| {
            let mut scratch = self.tree.take_scratch();
            for (k, slot) in chunk.iter_mut().enumerate() {
                let i = base + k;
                let h = &h_all[i * d..(i + 1) * d];
                let mut rng = row_rng(step_seed, i);
                self.tree.begin_example(h, &mut scratch);
                slot.clear();
                for _ in 0..runs {
                    let (range, p_leaf) = self.tree.draw_leaf_scratch(&mut scratch, &mut rng);
                    for class in range {
                        slot.push(class, p_leaf);
                    }
                }
            }
            self.tree.put_scratch(scratch);
        });
        Ok(())
    }

    fn prob(&self, input: &SampleInput, class: u32) -> Option<f64> {
        // probability of *the class's leaf* being returned per run
        let h = input.h?;
        let phi_h = self.tree.phi_query(h);
        Some(self.tree.leaf_prob_of_class(&phi_h, class))
    }

    fn update(&mut self, class: usize, w_new: &[f32]) {
        self.tree.update(class, w_new);
    }

    fn update_many(&mut self, classes: &[usize], rows: &[f32]) {
        self.tree.update_many(classes, rows);
    }

    fn reset_embeddings(&mut self, w: &[f32], n: usize, d: usize) {
        self.tree.reset_embeddings(w, n, d);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::kernel::QuadraticMap;

    #[test]
    fn partial_sampler_importance_weights_are_unbiased() {
        // E[ Σ_{j∈leaf} f(j) / P(leaf) ] per run must equal Σ_j f(j).
        let (n, d) = (30, 3);
        let mut rng = Rng::new(3);
        let mut emb = vec![0.0f32; n * d];
        rng.fill_normal(&mut emb, 0.6);
        let mut tree = KernelTreeSampler::new(QuadraticMap::new(d, 100.0), n, Some(5));
        tree.reset_embeddings(&emb, n, d);
        let sampler = PartialLeafSampler::new(tree);
        let h: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let input = SampleInput { h: Some(&h), ..Default::default() };
        let f = |j: u32| 1.0 + (j as f64) * 0.1; // arbitrary positive payload
        let truth: f64 = (0..n as u32).map(f).sum();
        let runs = 40_000;
        let mut out = Sample::default();
        let mut acc = 0.0;
        sampler.sample(&input, runs, &mut rng, &mut out).unwrap();
        for (&c, &p) in out.classes.iter().zip(&out.q) {
            acc += f(c) / p;
        }
        let est = acc / runs as f64;
        assert!((est - truth).abs() < 0.05 * truth, "est {est} vs {truth}");
    }

    #[test]
    fn runs_produce_whole_leaves() {
        let (n, d) = (16, 2);
        let mut tree = KernelTreeSampler::new(QuadraticMap::new(d, 100.0), n, Some(4));
        let mut rng = Rng::new(5);
        let mut emb = vec![0.0f32; n * d];
        rng.fill_normal(&mut emb, 0.5);
        tree.reset_embeddings(&emb, n, d);
        let sampler = PartialLeafSampler::new(tree);
        let h = vec![0.5f32, -0.5];
        let input = SampleInput { h: Some(&h), ..Default::default() };
        let mut out = Sample::default();
        sampler.sample(&input, 3, &mut rng, &mut out).unwrap();
        assert_eq!(out.classes.len(), 12, "3 runs × leaf_size 4");
        // classes of one run are contiguous and share the same q
        for run in 0..3 {
            let qs = &out.q[run * 4..(run + 1) * 4];
            assert!(qs.iter().all(|&q| (q - qs[0]).abs() < 1e-15));
        }
    }

    #[test]
    fn partial_sample_batch_reproduces_per_row_streams() {
        // the scratch-reusing override must be bit-identical to a per-row
        // sample() loop over the row_rng streams, for any thread count
        let (n_classes, d, rows, runs) = (48, 3, 13, 5);
        let mut rng = Rng::new(23);
        let mut emb = vec![0.0f32; n_classes * d];
        rng.fill_normal(&mut emb, 0.6);
        let mut tree = KernelTreeSampler::new(QuadraticMap::new(d, 100.0), n_classes, Some(4));
        tree.reset_embeddings(&emb, n_classes, d);
        let sampler = PartialLeafSampler::new(tree);
        let mut hs = vec![0.0f32; rows * d];
        rng.fill_normal(&mut hs, 1.0);
        let step_seed = 0x9A17;
        let mut per_row: Vec<Sample> = (0..rows).map(|_| Sample::default()).collect();
        for (i, slot) in per_row.iter_mut().enumerate() {
            let input = SampleInput { h: Some(&hs[i * d..(i + 1) * d]), ..Default::default() };
            let mut r = row_rng(step_seed, i);
            sampler.sample(&input, runs, &mut r, slot).unwrap();
        }
        for threads in [0usize, 1, 4, 8] {
            let inputs = BatchSampleInput {
                n: rows,
                d,
                n_classes,
                h: Some(&hs),
                threads,
                ..Default::default()
            };
            let mut batched: Vec<Sample> = (0..rows).map(|_| Sample::default()).collect();
            sampler.sample_batch(&inputs, runs, step_seed, &mut batched).unwrap();
            for (i, (a, b)) in batched.iter().zip(&per_row).enumerate() {
                assert_eq!(a.classes, b.classes, "threads {threads} row {i}");
                assert_eq!(a.q, b.q, "threads {threads} row {i}");
            }
        }
    }
}
